//! Performance microbenches for the §Perf pass (EXPERIMENTS.md):
//!
//! * the L3 screening sweep — fused single-pass vs naive two-pass,
//!   plus effective memory bandwidth;
//! * the dot-product kernel — unrolled vs naive (the before/after of the
//!   L3 hot-loop optimization);
//! * the XLA engine sweep vs the native sweep (runtime dispatch overhead);
//! * FISTA vs BCD on a reduced problem (solver ablation).

use tlfre::bench_harness::BenchArgs;
use tlfre::data::synthetic::{generate_synthetic, SyntheticSpec};
use tlfre::linalg::ops;
use tlfre::prox::shrink_norm_sq;
use tlfre::screening::tlfre::{apply_rules, TlfreContext};
use tlfre::sgl::bcd::{solve_bcd, BcdOptions};
use tlfre::sgl::{solve_fista, FistaOptions, SglParams, SglProblem};
use tlfre::screening::lambda_max::sgl_lambda_max;
use tlfre::util::harness::{bench, black_box, BenchConfig};
use tlfre::util::Rng;

fn naive_dot(a: &[f32], b: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for i in 0..a.len() {
        s += (a[i] * b[i]) as f64;
    }
    s
}

fn main() {
    tlfre::util::logger::init();
    let args = BenchArgs::from_env();
    let (n, p, g) = args.synthetic_dims();
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(n, p, g), args.seed);
    let prob = SglProblem::new(&ds.x, &ds.y, &ds.groups);
    let cfg = BenchConfig { warmup: 2, runs: 10, max_seconds: 60.0 };

    println!("== dot kernel (length {n}) ==");
    let mut rng = Rng::seed_from_u64(1);
    let a: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
    let b: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
    let reps = 200_000;
    for (label, f) in [
        ("naive", &naive_dot as &dyn Fn(&[f32], &[f32]) -> f64),
        ("unrolled-f64", &(|x: &[f32], y: &[f32]| ops::dot(x, y)) as &dyn Fn(&[f32], &[f32]) -> f64),
        ("unrolled-f32", &(|x: &[f32], y: &[f32]| ops::dot_f32(x, y) as f64) as &dyn Fn(&[f32], &[f32]) -> f64),
    ] {
        let r = bench(label, &cfg, || {
            let mut acc = 0.0f64;
            for _ in 0..reps {
                acc += f(black_box(&a), black_box(&b));
            }
            black_box(acc);
        });
        let flops = 2.0 * n as f64 * reps as f64 / r.seconds.median;
        println!("  {:14} {:8.2} ms   {:6.2} Gflop/s", r.label, r.seconds.median * 1e3, flops / 1e9);
    }

    println!("\n== screening sweep (X {n}×{p}) ==");
    let o: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
    let mut c = vec![0.0f32; p];
    // (a) two-pass: full matvec_t, then separate group reductions
    let two_pass = bench("two-pass", &cfg, || {
        prob.x.matvec_t(black_box(&o), &mut c);
        let mut acc = 0.0f64;
        for (gi, s, e) in prob.groups.iter() {
            acc += shrink_norm_sq(&c[s..e], 1.0) + gi as f64;
        }
        black_box(acc);
    });
    // (b) fused rule application (what the coordinator runs)
    let ctx = TlfreContext::precompute(&prob);
    let fused = bench("fused rules", &cfg, || {
        prob.x.matvec_t(black_box(&o), &mut c);
        black_box(apply_rules(&prob, 1.0, &c, 0.1, &ctx));
    });
    let bytes = (n * p * 4) as f64;
    for r in [&two_pass, &fused] {
        println!(
            "  {:14} {:8.2} ms   {:6.2} GB/s effective",
            r.label,
            r.seconds.median * 1e3,
            bytes / r.seconds.median / 1e9
        );
    }

    // XLA engine sweep (if artifacts are available for this shape).
    if let Ok(manifest) = tlfre::runtime::ArtifactManifest::load(&tlfre::runtime::artifacts_dir()) {
        if manifest.find("tlfre_screen", n, p).is_some() {
            let mut rt = tlfre::runtime::Runtime::cpu().expect("pjrt");
            let engine =
                tlfre::runtime::ScreenEngine::for_matrix(&mut rt, &manifest, &ds.x).expect("engine");
            let r = bench("xla engine", &cfg, || {
                black_box(engine.run(&rt, black_box(&o)).expect("run"));
            });
            println!(
                "  {:14} {:8.2} ms   {:6.2} GB/s effective (PJRT dispatch included)",
                r.label,
                r.seconds.median * 1e3,
                bytes / r.seconds.median / 1e9
            );
        } else {
            println!("  (no tlfre_screen artifact for {n}×{p}; run `make artifacts`)");
        }
    }

    println!("\n== solver ablation (single λ, reduced-size problem) ==");
    let small = generate_synthetic(&SyntheticSpec::synthetic1_scaled(100, 500, 50), args.seed);
    let sp = SglProblem::new(&small.x, &small.y, &small.groups);
    let lmax = sgl_lambda_max(&sp, 1.0);
    let params = SglParams::from_alpha_lambda(1.0, 0.2 * lmax.lambda_max);
    let scfg = BenchConfig { warmup: 1, runs: 5, max_seconds: 60.0 };
    let rf = bench("fista", &scfg, || {
        black_box(solve_fista(&sp, &params, None, &FistaOptions { tol: 1e-6, ..Default::default() }));
    });
    let rb = bench("bcd", &scfg, || {
        black_box(solve_bcd(&sp, &params, None, &BcdOptions { tol: 1e-6, ..Default::default() }));
    });
    println!("  fista {:8.2} ms   bcd {:8.2} ms", rf.seconds.median * 1e3, rb.seconds.median * 1e3);
}
