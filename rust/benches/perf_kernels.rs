//! Performance microbenches for the §Perf pass (EXPERIMENTS.md):
//!
//! * the L3 screening sweep — fused single-pass vs naive two-pass,
//!   plus effective memory bandwidth;
//! * the dot-product kernel — unrolled vs naive (the before/after of the
//!   L3 hot-loop optimization);
//! * the design-matrix backends — dense vs CSC vs ScreenedView `Xᵀv`
//!   sweeps at 1 %, 5 % and 100 % density (written to
//!   `BENCH_backends.json`);
//! * the XLA engine sweep vs the native sweep (runtime dispatch overhead);
//! * FISTA vs BCD on a reduced problem (solver ablation);
//! * the persistent worker pool vs the legacy per-call scoped threads
//!   (dispatch overhead of the hot `parallel_fill` sweep);
//! * the forward matvec `Xβ` — serial column-order accumulation vs the
//!   row-blocked pool dispatch (bitwise-equal by construction, asserted
//!   before publishing; feeds `parallel_matvec` in `BENCH_backends.json`);
//! * red-black pool-parallel BCD vs the sequential sweep on a paired-block
//!   CSC design (bitwise-equal, asserted; feeds `red_black_bcd` in
//!   `BENCH_solver_path.json`);
//! * the whole-path before/after of the spectral cache — `run_tlfre_path`
//!   with cached full-matrix Lipschitz constants vs exact per-view power
//!   iteration (written to `BENCH_solver_path.json`);
//! * fold-parallel cross-validation — the serial reference sweep vs
//!   sharding fold×α path tasks across the persistent pool (single-pass
//!   spectral accounting and bitwise serial/sharded equality asserted
//!   before publishing; feeds `cv_fold_parallel` in
//!   `BENCH_solver_path.json`);
//! * the working-set outer loop — the safe `tlfre+gap` pipeline vs the
//!   celer-style `tlfre+ws` heuristic (supports asserted equal at every λ
//!   before publishing; wall/iteration ratios, mean outer rounds, and the
//!   final solved set size vs the safe survivor count; feeds
//!   `working_set` in `BENCH_solver_path.json`);
//! * the checkpointed path driver vs the plain coefficient-collecting run —
//!   sidecar overhead at every-2-steps cadence, with a stop-mid-grid +
//!   resume round trip asserted bitwise equal to the uninterrupted path
//!   before publishing (feeds `checkpoint_overhead` in
//!   `BENCH_solver_path.json`);
//! * the out-of-core scale section — stream-generates a TLFREDS1 file
//!   whose X payload is ≥ 4× the `--scale-budget` RAM budget, then
//!   measures blocked column norms, streaming λmax, the mmap-vs-dense
//!   `Xᵀv` sweep and the end-to-end TLFre path on the mmap backend
//!   (every number gated on a bitwise-equality assertion against the
//!   in-RAM dense result; written to `BENCH_scale.json`);
//! * the serve layer — an in-process resident engine on a unix socket:
//!   cold vs warm full-path and single-point request latency, and
//!   p50/p95 round-trip latency under 4 concurrent clients, with the
//!   served coefficient bytes asserted identical to the batch walk
//!   before publishing (written to `BENCH_serve.json`).

use tlfre::bench_harness::BenchArgs;
use tlfre::coordinator::{
    cross_validate, cross_validate_serial, make_folds, path_coefficients, run_tlfre_path,
    run_tlfre_path_checkpointed, run_tlfre_path_with_coefficients, CheckpointOptions, PathConfig,
    SolveControls,
};
use tlfre::screening::ScreenKind;
use tlfre::linalg::SelectRows;
use tlfre::data::synthetic::{
    generate_sparse_synthetic, generate_synthetic, generate_synthetic_streaming,
    SparseSyntheticSpec, SyntheticSpec,
};
use tlfre::groups::GroupStructure;
use tlfre::linalg::ops;
use tlfre::linalg::{col_norms_blocked, CscMatrix, DenseMatrix, DesignMatrix, ScreenedView};
use tlfre::sgl::GroupColoring;
use tlfre::prox::shrink_norm_sq;
use tlfre::screening::tlfre::{apply_rules, TlfreContext};
use tlfre::sgl::bcd::{solve_bcd, BcdOptions};
use tlfre::sgl::{solve_fista, FistaOptions, SglParams, SglProblem};
use tlfre::screening::lambda_max::{sgl_lambda_max, sgl_lambda_max_streaming};
use tlfre::data::registry::resolve_dataset;
use tlfre::server::wire;
use tlfre::server::{
    coef_hex_dump, serve_on, DatasetSpec, RequestKind, SessionRegistry, SolveRequest,
    SolveResponse,
};
use tlfre::util::harness::{bench, black_box, BenchConfig};
use tlfre::util::pool;
use tlfre::util::json::Json;
use tlfre::util::{Rng, Timer};

fn naive_dot(a: &[f32], b: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for i in 0..a.len() {
        s += (a[i] * b[i]) as f64;
    }
    s
}

fn main() {
    tlfre::util::logger::init();
    let args = BenchArgs::from_env();
    let (n, p, g) = args.synthetic_dims();
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(n, p, g), args.seed);
    let prob = SglProblem::new(&ds.x, &ds.y, &ds.groups);
    let cfg = BenchConfig { warmup: 2, runs: 10, max_seconds: 60.0 };

    println!("== dot kernel (length {n}) ==");
    let mut rng = Rng::seed_from_u64(1);
    let a: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
    let b: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
    let reps = 200_000;
    for (label, f) in [
        ("naive", &naive_dot as &dyn Fn(&[f32], &[f32]) -> f64),
        ("unrolled-f64", &(|x: &[f32], y: &[f32]| ops::dot(x, y)) as &dyn Fn(&[f32], &[f32]) -> f64),
        ("unrolled-f32", &(|x: &[f32], y: &[f32]| ops::dot_f32(x, y) as f64) as &dyn Fn(&[f32], &[f32]) -> f64),
    ] {
        let r = bench(label, &cfg, || {
            let mut acc = 0.0f64;
            for _ in 0..reps {
                acc += f(black_box(&a), black_box(&b));
            }
            black_box(acc);
        });
        let flops = 2.0 * n as f64 * reps as f64 / r.seconds.median;
        println!("  {:14} {:8.2} ms   {:6.2} Gflop/s", r.label, r.seconds.median * 1e3, flops / 1e9);
    }

    println!("\n== screening sweep (X {n}×{p}) ==");
    let o: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
    let mut c = vec![0.0f32; p];
    // (a) two-pass: full matvec_t, then separate group reductions
    let two_pass = bench("two-pass", &cfg, || {
        prob.x.matvec_t(black_box(&o), &mut c);
        let mut acc = 0.0f64;
        for (gi, s, e) in prob.groups.iter() {
            acc += shrink_norm_sq(&c[s..e], 1.0) + gi as f64;
        }
        black_box(acc);
    });
    // (b) fused rule application (what the coordinator runs)
    let ctx = TlfreContext::precompute(&prob);
    let fused = bench("fused rules", &cfg, || {
        prob.x.matvec_t(black_box(&o), &mut c);
        black_box(apply_rules(&prob, 1.0, &c, 0.1, &ctx));
    });
    let bytes = (n * p * 4) as f64;
    for r in [&two_pass, &fused] {
        println!(
            "  {:14} {:8.2} ms   {:6.2} GB/s effective",
            r.label,
            r.seconds.median * 1e3,
            bytes / r.seconds.median / 1e9
        );
    }

    // Backend comparison: dense vs CSC vs ScreenedView matvec_t at several
    // densities. CSC cost scales with nnz; the view adds one indirection
    // over its base backend. Results land in BENCH_backends.json.
    println!("\n== backend matvec_t (X {n}×{p}) ==");
    let mut backend_rows: Vec<Json> = Vec::new();
    for &density in &[0.01f64, 0.05, 1.0] {
        let sds = generate_sparse_synthetic(
            &SparseSyntheticSpec::new(n, p, p / 10, density),
            args.seed,
        );
        let csc = &sds.x;
        let dense = csc.to_dense();
        // Survivor view over the dense backend: every other column (a
        // mid-path screening outcome shape).
        let keep: Vec<usize> = (0..p).step_by(2).collect();
        let view = ScreenedView::new(&dense, keep.clone());
        let gathered = dense.select_cols(&keep);

        let mut out_p = vec![0.0f32; p];
        let mut out_k = vec![0.0f32; keep.len()];
        let r_dense = bench("dense", &cfg, || {
            DesignMatrix::matvec_t(&dense, black_box(&o), &mut out_p);
            black_box(&out_p);
        });
        let r_csc = bench("csc", &cfg, || {
            DesignMatrix::matvec_t(csc, black_box(&o), &mut out_p);
            black_box(&out_p);
        });
        let r_view = bench("view", &cfg, || {
            DesignMatrix::matvec_t(&view, black_box(&o), &mut out_k);
            black_box(&out_k);
        });
        let r_gathered = bench("gathered", &cfg, || {
            DesignMatrix::matvec_t(&gathered, black_box(&o), &mut out_k);
            black_box(&out_k);
        });
        println!(
            "  density {:5.1}%  nnz {:9}  dense {:8.3} ms  csc {:8.3} ms ({:4.2}x)  view/half {:8.3} ms  gathered/half {:8.3} ms",
            density * 100.0,
            csc.nnz(),
            r_dense.seconds.median * 1e3,
            r_csc.seconds.median * 1e3,
            r_dense.seconds.median / r_csc.seconds.median.max(1e-12),
            r_view.seconds.median * 1e3,
            r_gathered.seconds.median * 1e3,
        );
        backend_rows.push(
            Json::obj()
                .set("density", density)
                .set("nnz", csc.nnz())
                .set("dense_ms", r_dense.seconds.median * 1e3)
                .set("csc_ms", r_csc.seconds.median * 1e3)
                .set("csc_speedup_vs_dense", r_dense.seconds.median / r_csc.seconds.median.max(1e-12))
                .set("view_half_ms", r_view.seconds.median * 1e3)
                .set("gathered_half_ms", r_gathered.seconds.median * 1e3),
        );
    }
    // Forward sweep: serial column-order accumulation vs the row-blocked
    // pool dispatch (bitwise identical; asserted below so the published
    // speedup is of a *verified-equal* kernel). Dense β, the worst case
    // for the nonzero-column skip.
    println!("\n== forward matvec Xβ (X {n}×{p}, {} workers) ==", pool::num_threads());
    let mv_workers = pool::num_threads();
    let beta_full: Vec<f32> = (0..p).map(|_| rng.gaussian() as f32).collect();
    let mut mv = vec![0.0f32; n];
    let mv_reps = 50;
    let r_mv_serial = bench("serial", &cfg, || {
        for _ in 0..mv_reps {
            ds.x.matvec_serial(black_box(&beta_full), &mut mv);
        }
        black_box(&mv);
    });
    let mut mv_serial_out = vec![0.0f32; n];
    ds.x.matvec_serial(&beta_full, &mut mv_serial_out);
    let r_mv_par = bench("row-blocked", &cfg, || {
        for _ in 0..mv_reps {
            ds.x.matvec_with_workers(black_box(&beta_full), &mut mv, mv_workers);
        }
        black_box(&mv);
    });
    assert!(
        mv.iter().zip(&mv_serial_out).all(|(a, b)| a.to_bits() == b.to_bits()),
        "row-blocked matvec diverged from serial — bench numbers would be meaningless"
    );
    let parallel_matvec_speedup =
        r_mv_serial.seconds.median / r_mv_par.seconds.median.max(1e-12);
    println!(
        "  serial {:8.3} ms / sweep   row-blocked {:8.3} ms / sweep   ({:4.2}x, bitwise equal)",
        r_mv_serial.seconds.median * 1e3 / mv_reps as f64,
        r_mv_par.seconds.median * 1e3 / mv_reps as f64,
        parallel_matvec_speedup,
    );

    let report = Json::obj()
        .set("bench", "perf_kernels/backend_matvec_t")
        .set("n", n)
        .set("p", p)
        .set("threads", tlfre::util::pool::num_threads())
        .set(
            "parallel_matvec",
            Json::obj()
                .set("workers", mv_workers)
                .set("serial_ms_per_sweep", r_mv_serial.seconds.median * 1e3 / mv_reps as f64)
                .set(
                    "row_blocked_ms_per_sweep",
                    r_mv_par.seconds.median * 1e3 / mv_reps as f64,
                )
                .set("parallel_matvec_speedup", parallel_matvec_speedup),
        )
        .set("rows", Json::Arr(backend_rows));
    // Cargo runs bench binaries with CWD = the package root (rust/); pin
    // the report next to the checked-in copy at the workspace root so CI's
    // schema check reads the fresh run, not the placeholder.
    let backend_json = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_backends.json");
    match std::fs::write(backend_json, report.to_string_pretty()) {
        Ok(()) => println!("  backend results written to {backend_json}"),
        Err(e) => eprintln!("  warning: could not write {backend_json}: {e}"),
    }

    // XLA engine sweep (if artifacts are available for this shape).
    if let Ok(manifest) = tlfre::runtime::ArtifactManifest::load(&tlfre::runtime::artifacts_dir()) {
        if manifest.find("tlfre_screen", n, p).is_some() {
            let mut rt = tlfre::runtime::Runtime::cpu().expect("pjrt");
            let engine =
                tlfre::runtime::ScreenEngine::for_matrix(&mut rt, &manifest, &ds.x).expect("engine");
            let r = bench("xla engine", &cfg, || {
                black_box(engine.run(&rt, black_box(&o)).expect("run"));
            });
            println!(
                "  {:14} {:8.2} ms   {:6.2} GB/s effective (PJRT dispatch included)",
                r.label,
                r.seconds.median * 1e3,
                bytes / r.seconds.median / 1e9
            );
        } else {
            println!("  (no tlfre_screen artifact for {n}×{p}; run `make artifacts`)");
        }
    }

    println!("\n== solver ablation (single λ, reduced-size problem) ==");
    let small = generate_synthetic(&SyntheticSpec::synthetic1_scaled(100, 500, 50), args.seed);
    let sp = SglProblem::new(&small.x, &small.y, &small.groups);
    let lmax = sgl_lambda_max(&sp, 1.0);
    let params = SglParams::from_alpha_lambda(1.0, 0.2 * lmax.lambda_max);
    let scfg = BenchConfig { warmup: 1, runs: 5, max_seconds: 60.0 };
    let rf = bench("fista", &scfg, || {
        black_box(solve_fista(&sp, &params, None, &FistaOptions { tol: 1e-6, ..Default::default() }));
    });
    let rb = bench("bcd", &scfg, || {
        black_box(solve_bcd(&sp, &params, None, &BcdOptions { tol: 1e-6, ..Default::default() }));
    });
    println!("  fista {:8.2} ms   bcd {:8.2} ms", rf.seconds.median * 1e3, rb.seconds.median * 1e3);

    // Pool dispatch overhead: the persistent parked-worker pool vs the
    // legacy per-call std::thread::scope (the before/after of the
    // spawn-free rework). Same chunking, bitwise-identical output; only
    // dispatch cost differs — and it's paid once per solver iteration.
    println!(
        "\n== pool dispatch (parallel_fill over {p} column dots, {} workers) ==",
        pool::num_threads()
    );
    // Honest comparison: use the real process worker count. With 1 worker
    // the pool never spawns and all three rows legitimately measure the
    // serial loop (speedup ≈ 1); `pool_enabled` records which case ran.
    let workers = pool::num_threads();
    if workers <= 1 {
        println!("  (TLFRE_THREADS=1 / single core: pool disabled, rows below are all serial)");
    }
    let mut fill = vec![0.0f32; p];
    let sweep_reps = 50;
    let r_fill_serial = bench("serial", &cfg, || {
        for _ in 0..sweep_reps {
            for (j, slot) in fill.iter_mut().enumerate() {
                *slot = ds.x.col_dot(j, black_box(&o));
            }
        }
        black_box(&fill);
    });
    let r_fill_scoped = bench("scoped", &cfg, || {
        for _ in 0..sweep_reps {
            let dot = |j: usize| ds.x.col_dot(j, black_box(&o));
            pool::scoped_fill_with_workers(&mut fill, workers, dot);
        }
        black_box(&fill);
    });
    let r_fill_pool = bench("persistent", &cfg, || {
        for _ in 0..sweep_reps {
            let dot = |j: usize| ds.x.col_dot(j, black_box(&o));
            pool::parallel_fill_with_workers(&mut fill, workers, dot);
        }
        black_box(&fill);
    });
    for r in [&r_fill_serial, &r_fill_scoped, &r_fill_pool] {
        println!(
            "  {:14} {:8.3} ms / sweep",
            r.label,
            r.seconds.median * 1e3 / sweep_reps as f64
        );
    }

    // Whole-path before/after of the spectral cache: default mode reuses
    // the full-matrix Lipschitz data across every λ (zero power iterations
    // in the loop); exact mode re-estimates per survivor view (the old
    // behaviour). Written to BENCH_solver_path.json for the CI schema check.
    println!("\n== solver path: cached vs exact per-view Lipschitz ==");
    let path_n_lambda = args.n_lambda().min(16);
    let cached_cfg = PathConfig {
        alpha: 1.0,
        controls: SolveControls {
            n_lambda: path_n_lambda,
            lambda_min_ratio: 0.05,
            tol: 1e-5,
            ..Default::default()
        },
        ..Default::default()
    };
    let exact_cfg = PathConfig { exact_view_lipschitz: true, ..cached_cfg.clone() };
    // Warmed multi-run medians: the first path run also pays the lazy pool
    // spawn and cold page faults, which must not bias the published
    // before/after ratio.
    let pcfg = BenchConfig { warmup: 1, runs: 3, max_seconds: 300.0 };
    let mut cached_path = None;
    let r_cached = bench("cached", &pcfg, || {
        cached_path = Some(run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cached_cfg));
    });
    let mut exact_path = None;
    let r_exact = bench("exact", &pcfg, || {
        exact_path = Some(run_tlfre_path(&ds.x, &ds.y, &ds.groups, &exact_cfg));
    });
    let cached_path = cached_path.expect("cached path ran");
    let exact_path = exact_path.expect("exact path ran");
    for (r, out) in [(&r_cached, &cached_path), (&r_exact, &exact_path)] {
        println!(
            "  {:8} wall {:8.2} ms   screen {:8.2} ms   solve {:8.2} ms   rejection {:.3}",
            r.label,
            r.seconds.median * 1e3,
            out.screen_total_s * 1e3,
            out.solve_total_s * 1e3,
            out.mean_total_rejection(),
        );
    }

    // Red-black pool-parallel BCD on the canonical paired-block sparse
    // design (`sgl::coloring::paired_block_band`: groups 2k/2k+1 overlap
    // inside row block k, blocks disjoint → 2 color classes — the same
    // structure the coloring tests validate as 2-colorable). The colored
    // sweep is bitwise identical to the sequential sweep — asserted below,
    // and recorded in the JSON so CI gates on it.
    println!("\n== red-black BCD sweep (paired-block CSC design) ==");
    let rb_blocks = 32usize;
    let rb_cols = 8usize;
    let rb_n = 8 * rb_blocks;
    let rb_groups_n = 2 * rb_blocks;
    let rb_p = rb_groups_n * rb_cols;
    let rb_groups = GroupStructure::uniform(rb_p, rb_groups_n);
    let mut rb_rng = Rng::seed_from_u64(args.seed ^ 0xB1AC);
    let rb_dense = DenseMatrix::from_fn(rb_n, rb_p, |i, j| {
        let (lo, hi) = tlfre::sgl::coloring::paired_block_band(j / rb_cols);
        if i >= lo && i < hi {
            rb_rng.gaussian() as f32
        } else {
            0.0
        }
    });
    let rb_x = CscMatrix::from_dense(&rb_dense);
    let mut rb_beta = vec![0.0f32; rb_p];
    for g in 0..rb_groups_n {
        if g % 3 != 2 {
            rb_beta[g * rb_cols] = rb_rng.gaussian() as f32;
        }
    }
    let mut rb_y = vec![0.0f32; rb_n];
    DesignMatrix::matvec(&rb_x, &rb_beta, &mut rb_y);
    for v in rb_y.iter_mut() {
        *v += (rb_rng.gaussian() * 0.01) as f32;
    }
    let rb_prob = SglProblem::new(&rb_x, &rb_y, &rb_groups);
    let rb_lmax = sgl_lambda_max(&rb_prob, 1.0);
    let rb_params = SglParams::from_alpha_lambda(1.0, 0.2 * rb_lmax.lambda_max);
    let rb_coloring = GroupColoring::compute(&rb_x, &rb_groups);
    let rb_opts = BcdOptions { tol: 1e-6, ..Default::default() };
    let mut rb_seq = None;
    let r_rb_seq = bench("sequential", &scfg, || {
        rb_seq = Some(solve_bcd(&rb_prob, &rb_params, None, &rb_opts));
    });
    let mut rb_par = None;
    let r_rb_par = bench("red-black", &scfg, || {
        rb_par = Some(solve_bcd(
            &rb_prob,
            &rb_params,
            None,
            &BcdOptions {
                parallel_groups: true,
                coloring: Some(&rb_coloring),
                ..rb_opts.clone()
            },
        ));
    });
    let rb_seq = rb_seq.expect("sequential BCD ran");
    let rb_par = rb_par.expect("colored BCD ran");
    let rb_bitwise_equal = rb_seq.iters == rb_par.iters
        && rb_seq
            .beta
            .iter()
            .zip(&rb_par.beta)
            .all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(rb_bitwise_equal, "colored BCD diverged from the sequential sweep");
    let red_black_speedup = r_rb_seq.seconds.median / r_rb_par.seconds.median.max(1e-12);
    println!(
        "  {} groups, {} classes (largest {})   sequential {:8.2} ms   red-black {:8.2} ms   ({:4.2}x, bitwise equal)",
        rb_groups_n,
        rb_coloring.n_classes(),
        rb_coloring.max_class_len(),
        r_rb_seq.seconds.median * 1e3,
        r_rb_par.seconds.median * 1e3,
        red_black_speedup,
    );

    // Fold-parallel cross-validation: the serial reference sweep vs
    // sharding fold×α path tasks across the persistent pool. Three
    // published properties, the first two asserted before the numbers go
    // out: `single_pass` (the spectral-call accounting shows exactly one
    // screened walk per fold×α — the pre-driver CV walked every path
    // twice), `bitwise_equal` (sharded output == serial output, bit for
    // bit), and the serial/sharded wall-clock ratio.
    println!(
        "\n== cross-validation: serial vs fold-parallel sharding ({} workers) ==",
        pool::num_threads()
    );
    let cv_n = 60usize;
    let cv_ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(cv_n, 240, 24), args.seed);
    let cv_folds = args.k_folds();
    let cv_alphas = [0.5f64, 1.0];
    let cv_seed = args.seed ^ 0xCF;
    let cv_cfg = PathConfig {
        alpha: 1.0,
        controls: SolveControls {
            n_lambda: path_n_lambda.min(8),
            lambda_min_ratio: 0.05,
            tol: 1e-5,
            ..Default::default()
        },
        ..Default::default()
    };
    // Expected one-walk cost: one runner path per fold×α over the same
    // splits (thread-local counter; everything below runs on this thread).
    let folds = make_folds(cv_n, cv_folds, cv_seed);
    let c0 = tlfre::linalg::power::spectral_call_count();
    for fold in &folds {
        let in_fold: std::collections::BTreeSet<usize> = fold.iter().copied().collect();
        let train_rows: Vec<usize> = (0..cv_n).filter(|i| !in_fold.contains(i)).collect();
        let x_train = cv_ds.x.select_rows(&train_rows);
        let y_train: Vec<f32> = train_rows.iter().map(|&i| cv_ds.y[i]).collect();
        for &alpha in &cv_alphas {
            let pc = PathConfig { alpha, ..cv_cfg.clone() };
            run_tlfre_path(&x_train, &y_train, &cv_ds.groups, &pc);
        }
    }
    let one_walk_cost = tlfre::linalg::power::spectral_call_count() - c0;
    let c1 = tlfre::linalg::power::spectral_call_count();
    let serial_cv = cross_validate_serial(
        &cv_ds.x, &cv_ds.y, &cv_ds.groups, &cv_alphas, cv_folds, &cv_cfg, cv_seed,
    );
    let cv_calls = tlfre::linalg::power::spectral_call_count() - c1;
    let cv_single_pass = cv_calls == one_walk_cost;
    assert!(
        cv_single_pass,
        "cross_validate must perform one screened walk per fold×α \
         ({cv_calls} spectral calls vs {one_walk_cost} for the runner paths)"
    );
    let cvcfg = BenchConfig { warmup: 1, runs: 3, max_seconds: 300.0 };
    let r_cv_serial = bench("serial", &cvcfg, || {
        black_box(cross_validate_serial(
            &cv_ds.x, &cv_ds.y, &cv_ds.groups, &cv_alphas, cv_folds, &cv_cfg, cv_seed,
        ));
    });
    let mut sharded_cv = None;
    let r_cv_sharded = bench("sharded", &cvcfg, || {
        sharded_cv = Some(cross_validate(
            &cv_ds.x, &cv_ds.y, &cv_ds.groups, &cv_alphas, cv_folds, &cv_cfg, cv_seed,
        ));
    });
    let sharded_cv = sharded_cv.expect("sharded CV ran");
    let cv_bitwise_equal = serial_cv.points.len() == sharded_cv.points.len()
        && serial_cv.points.iter().zip(&sharded_cv.points).all(|(a, b)| {
            a.alpha.to_bits() == b.alpha.to_bits()
                && a.lambda_ratio.to_bits() == b.lambda_ratio.to_bits()
                && a.mse.to_bits() == b.mse.to_bits()
                && a.mean_nnz.to_bits() == b.mean_nnz.to_bits()
        })
        && serial_cv.nonfinite_points == sharded_cv.nonfinite_points;
    assert!(cv_bitwise_equal, "fold-parallel CV diverged from the serial sweep");
    let cv_speedup = r_cv_serial.seconds.median / r_cv_sharded.seconds.median.max(1e-12);
    println!(
        "  {} folds × {} α × {} λ   serial {:8.2} ms   sharded {:8.2} ms   ({:4.2}x, single pass, bitwise equal)",
        cv_folds,
        cv_alphas.len(),
        cv_cfg.n_lambda,
        r_cv_serial.seconds.median * 1e3,
        r_cv_sharded.seconds.median * 1e3,
        cv_speedup,
    );

    // Dynamic GAP-safe screening: static TLFre vs the tlfre+gap pipeline
    // (same grid, same tolerance; the dynamic half keeps shrinking the
    // live problem inside the solver at gap-check cadence). Three
    // published properties, the first asserted before the numbers go out:
    // `support_equal` (final supports at solver resolution match at every
    // λ — dynamic evictions are certificates, not guesses),
    // `evicted_total` (the dynamic layer actually fired), and the
    // solver-iteration / wall-clock ratios vs the static pipeline.
    println!("\n== dynamic screening: static tlfre vs tlfre+gap ==");
    let static_cfg = cached_cfg.clone();
    let dynamic_cfg = PathConfig { screen: ScreenKind::TlfreGap, ..cached_cfg.clone() };
    let static_betas = path_coefficients(&ds.x, &ds.y, &ds.groups, &static_cfg);
    let dynamic_betas = path_coefficients(&ds.x, &ds.y, &ds.groups, &dynamic_cfg);
    // The shared hysteresis comparator (see its docs for why single-cut
    // thresholds would misread borderline coordinates as support changes).
    let dyn_support_equal = static_betas.len() == dynamic_betas.len()
        && static_betas
            .iter()
            .zip(&dynamic_betas)
            .all(|(a, b)| tlfre::screening::same_support_at_resolution(a, b));
    assert!(
        dyn_support_equal,
        "dynamic screening changed a final support — bench numbers would be meaningless"
    );
    let mut static_path = None;
    let r_dyn_static = bench("static", &pcfg, || {
        static_path = Some(run_tlfre_path(&ds.x, &ds.y, &ds.groups, &static_cfg));
    });
    let mut dynamic_path = None;
    let r_dyn_dynamic = bench("dynamic", &pcfg, || {
        dynamic_path = Some(run_tlfre_path(&ds.x, &ds.y, &ds.groups, &dynamic_cfg));
    });
    let static_path = static_path.expect("static path ran");
    let dynamic_path = dynamic_path.expect("dynamic path ran");
    let static_iters: usize = static_path.steps.iter().map(|s| s.iters).sum();
    let dynamic_iters: usize = dynamic_path.steps.iter().map(|s| s.iters).sum();
    let evicted_total: usize = dynamic_path.steps.iter().map(|s| s.dynamic_evicted).sum();
    assert!(evicted_total > 0, "dynamic screening never fired on the bench problem");
    let dyn_iter_ratio = dynamic_iters as f64 / static_iters.max(1) as f64;
    let dyn_wall_ratio =
        r_dyn_dynamic.seconds.median / r_dyn_static.seconds.median.max(1e-12);
    println!(
        "  static {:8.2} ms ({static_iters} iters)   tlfre+gap {:8.2} ms ({dynamic_iters} iters, {evicted_total} evicted)   iter ratio {:.3}  wall ratio {:.3}  (supports equal)",
        r_dyn_static.seconds.median * 1e3,
        r_dyn_dynamic.seconds.median * 1e3,
        dyn_iter_ratio,
        dyn_wall_ratio,
    );

    // Working-set outer loop: the fully safe tlfre+gap pipeline vs the
    // celer-style tlfre+ws heuristic (same grid, same tolerance; ws seeds
    // a small set from the previous support + strong-rule scores, solves
    // it loosely, and grows geometrically on full-problem KKT violations
    // before one tight final solve). `support_equal` is asserted before
    // any number is published — a working set that changed a final
    // support would make the ratios meaningless; the set-size column is
    // the point of the optimization (final solved set vs the safe
    // pipeline's survivor count).
    println!("\n== working set: tlfre+gap vs tlfre+ws ==");
    let ws_cfg = PathConfig { screen: ScreenKind::TlfreWs, ..cached_cfg.clone() };
    let ws_betas = path_coefficients(&ds.x, &ds.y, &ds.groups, &ws_cfg);
    let ws_support_equal = dynamic_betas.len() == ws_betas.len()
        && dynamic_betas
            .iter()
            .zip(&ws_betas)
            .all(|(a, b)| tlfre::screening::same_support_at_resolution(a, b));
    assert!(
        ws_support_equal,
        "working set changed a final support — bench numbers would be meaningless"
    );
    let mut ws_path = None;
    let r_ws = bench("tlfre+ws", &pcfg, || {
        ws_path = Some(run_tlfre_path(&ds.x, &ds.y, &ds.groups, &ws_cfg));
    });
    let ws_path = ws_path.expect("working-set path ran");
    let ws_iters: usize = ws_path.steps.iter().map(|s| s.iters).sum();
    let ws_wall_ratio = r_ws.seconds.median / r_dyn_dynamic.seconds.median.max(1e-12);
    let ws_iter_ratio = ws_iters as f64 / dynamic_iters.max(1) as f64;
    // Post-λmax means: the zero step never runs the outer loop.
    let ws_steps = ws_path.steps.len().saturating_sub(1).max(1);
    let ws_mean_rounds =
        ws_path.steps.iter().skip(1).map(|s| s.ws_rounds).sum::<usize>() as f64 / ws_steps as f64;
    let ws_mean_final = ws_path.steps.iter().skip(1).map(|s| s.ws_final_size).sum::<usize>()
        as f64
        / ws_steps as f64;
    // Survivor reference: the static tlfre path's per-step active set.
    let surv_steps = static_path.steps.len().saturating_sub(1).max(1);
    let ws_mean_survivors = static_path
        .steps
        .iter()
        .skip(1)
        .map(|s| s.active_features)
        .sum::<usize>() as f64
        / surv_steps as f64;
    let ws_set_over_survivors = ws_mean_final / ws_mean_survivors.max(1e-12);
    println!(
        "  tlfre+gap {:8.2} ms ({dynamic_iters} iters)   tlfre+ws {:8.2} ms ({ws_iters} iters)   iter ratio {:.3}  wall ratio {:.3}",
        r_dyn_dynamic.seconds.median * 1e3,
        r_ws.seconds.median * 1e3,
        ws_iter_ratio,
        ws_wall_ratio,
    );
    println!(
        "  mean rounds {:.2}   mean final set {:.1} features vs {:.1} tlfre survivors ({:.3}x, supports equal)",
        ws_mean_rounds, ws_mean_final, ws_mean_survivors, ws_set_over_survivors,
    );

    // Checkpoint overhead: the kill-safe checkpointed driver (sidecar
    // rewritten every 2 completed grid points) vs the plain
    // coefficient-collecting path on the identical problem and config.
    // Before any number is published, a stop-at-mid-grid + resume round
    // trip is asserted bitwise identical — stats and per-λ coefficients —
    // to the uninterrupted run, so the published overhead is the cost of a
    // *verified* recovery mechanism, not of a lookalike.
    println!("\n== checkpoint overhead (sidecar every 2 steps) ==");
    let ck_every = 2usize;
    let ck_sidecar =
        std::env::temp_dir().join(format!("tlfre-bench-ck-{}.bin", std::process::id()));
    let mut ck_plain = None;
    let r_ck_plain = bench("plain", &pcfg, || {
        ck_plain = Some(run_tlfre_path_with_coefficients(&ds.x, &ds.y, &ds.groups, &cached_cfg));
    });
    let mut ck_opts = CheckpointOptions::new(&ck_sidecar);
    ck_opts.every = ck_every;
    let mut ck_full = None;
    let r_ck = bench("checkpointed", &pcfg, || {
        ck_full = Some(
            run_tlfre_path_checkpointed(&ds.x, &ds.y, &ds.groups, &cached_cfg, &ck_opts)
                .expect("checkpointed path"),
        );
    });
    let (plain_path, plain_coefs) = ck_plain.expect("plain path ran");
    let (ck_path_out, ck_coefs) = ck_full.expect("checkpointed path ran");

    // Kill-and-resume round trip: stop mid-grid (off a save boundary so
    // resume actually recomputes lost steps), then resume from the sidecar.
    let ck_stop = (cached_cfg.n_lambda / 2).max(1) | 1;
    let mut stop_opts = CheckpointOptions::new(&ck_sidecar);
    stop_opts.every = ck_every;
    stop_opts.stop_after = Some(ck_stop);
    let (stopped, _) =
        run_tlfre_path_checkpointed(&ds.x, &ds.y, &ds.groups, &cached_cfg, &stop_opts)
            .expect("stopped checkpointed path");
    assert!(stopped.truncated, "stop_after={ck_stop} must truncate the {path_n_lambda}-point grid");
    let mut resume_opts = CheckpointOptions::new(&ck_sidecar);
    resume_opts.every = ck_every;
    resume_opts.resume = true;
    let (resumed, resumed_coefs) =
        run_tlfre_path_checkpointed(&ds.x, &ds.y, &ds.groups, &cached_cfg, &resume_opts)
            .expect("resumed checkpointed path");
    let path_eq = |a: &tlfre::coordinator::PathOutput, b: &tlfre::coordinator::PathOutput| {
        a.lambda_max.to_bits() == b.lambda_max.to_bits()
            && a.steps.len() == b.steps.len()
            && a.steps.iter().zip(&b.steps).all(|(sa, sb)| {
                sa.lambda.to_bits() == sb.lambda.to_bits()
                    && sa.iters == sb.iters
                    && sa.gap.to_bits() == sb.gap.to_bits()
                    && sa.nonzeros == sb.nonzeros
            })
    };
    let coefs_eq = |a: &[Vec<f32>], b: &[Vec<f32>]| {
        a.len() == b.len()
            && a.iter()
                .zip(b)
                .all(|(ba, bb)| ba.iter().zip(bb).all(|(x, y)| x.to_bits() == y.to_bits()))
    };
    let resume_bitwise_equal = path_eq(&ck_path_out, &plain_path)
        && coefs_eq(&ck_coefs, &plain_coefs)
        && path_eq(&resumed, &plain_path)
        && coefs_eq(&resumed_coefs, &plain_coefs);
    assert!(
        resume_bitwise_equal,
        "checkpointed/resumed path diverged from the plain run — overhead numbers would be meaningless"
    );
    let _ = std::fs::remove_file(&ck_sidecar);
    let checkpoint_overhead_ratio =
        r_ck.seconds.median / r_ck_plain.seconds.median.max(1e-12);
    println!(
        "  plain {:8.2} ms   checkpointed {:8.2} ms   ({:4.3}x, resume @ step {} bitwise equal)",
        r_ck_plain.seconds.median * 1e3,
        r_ck.seconds.median * 1e3,
        checkpoint_overhead_ratio,
        ck_stop,
    );

    let path_json = |out: &tlfre::coordinator::PathOutput, wall_s: f64| {
        Json::obj()
            .set("wall_s", wall_s)
            .set("screen_s", out.screen_total_s)
            .set("solve_s", out.solve_total_s)
            .set("total_s", out.total_s())
            .set("mean_rejection", out.mean_total_rejection())
    };
    let report = Json::obj()
        .set("bench", "perf_kernels/solver_path")
        .set("n", n)
        .set("p", p)
        .set("n_groups", g)
        .set("n_lambda", path_n_lambda)
        .set("threads", pool::num_threads())
        .set(
            "pool",
            Json::obj()
                .set("fill_len", p)
                .set("workers", workers)
                .set("pool_enabled", workers > 1)
                .set("serial_ms_per_sweep", r_fill_serial.seconds.median * 1e3 / sweep_reps as f64)
                .set("scoped_ms_per_sweep", r_fill_scoped.seconds.median * 1e3 / sweep_reps as f64)
                .set(
                    "persistent_ms_per_sweep",
                    r_fill_pool.seconds.median * 1e3 / sweep_reps as f64,
                )
                .set(
                    "persistent_speedup_vs_scoped",
                    r_fill_scoped.seconds.median / r_fill_pool.seconds.median.max(1e-12),
                ),
        )
        .set(
            "path",
            Json::obj()
                .set("cached", path_json(&cached_path, r_cached.seconds.median))
                .set("exact", path_json(&exact_path, r_exact.seconds.median))
                .set(
                    "exact_over_cached_solve",
                    exact_path.solve_total_s / cached_path.solve_total_s.max(1e-12),
                ),
        )
        .set(
            "red_black_bcd",
            Json::obj()
                .set("n", rb_n)
                .set("p", rb_p)
                .set("n_groups", rb_groups_n)
                .set("n_classes", rb_coloring.n_classes())
                .set("max_class_len", rb_coloring.max_class_len())
                .set("sequential_ms", r_rb_seq.seconds.median * 1e3)
                .set("colored_ms", r_rb_par.seconds.median * 1e3)
                .set("colored_speedup_vs_sequential", red_black_speedup)
                .set("bitwise_equal", rb_bitwise_equal),
        )
        .set(
            "cv_fold_parallel",
            Json::obj()
                .set("k_folds", cv_folds)
                .set("n_alphas", cv_alphas.len())
                .set("n_lambda", cv_cfg.n_lambda)
                .set("workers", pool::num_threads())
                .set("serial_s", r_cv_serial.seconds.median)
                .set("sharded_s", r_cv_sharded.seconds.median)
                .set("sharded_speedup_vs_serial", cv_speedup)
                .set("single_pass", cv_single_pass)
                .set("bitwise_equal", cv_bitwise_equal),
        )
        .set(
            "dynamic_screening",
            Json::obj()
                .set("n_lambda", path_n_lambda)
                .set("static_wall_s", r_dyn_static.seconds.median)
                .set("dynamic_wall_s", r_dyn_dynamic.seconds.median)
                .set("wall_ratio_dynamic_over_static", dyn_wall_ratio)
                .set("static_iters", static_iters)
                .set("dynamic_iters", dynamic_iters)
                .set("iter_ratio_dynamic_over_static", dyn_iter_ratio)
                .set("evicted_total", evicted_total)
                .set("support_equal", dyn_support_equal),
        )
        .set(
            "working_set",
            Json::obj()
                .set("n_lambda", path_n_lambda)
                .set("gap_wall_s", r_dyn_dynamic.seconds.median)
                .set("ws_wall_s", r_ws.seconds.median)
                .set("wall_ratio_ws_over_gap", ws_wall_ratio)
                .set("gap_iters", dynamic_iters)
                .set("ws_iters", ws_iters)
                .set("iter_ratio_ws_over_gap", ws_iter_ratio)
                .set("mean_rounds", ws_mean_rounds)
                .set("mean_final_size", ws_mean_final)
                .set("mean_survivors", ws_mean_survivors)
                .set("final_size_over_survivors", ws_set_over_survivors)
                .set("support_equal", ws_support_equal),
        )
        .set(
            "checkpoint_overhead",
            Json::obj()
                .set("every_k", ck_every)
                .set("steps", path_n_lambda)
                .set("resume_stop_after", ck_stop)
                .set("plain_wall_s", r_ck_plain.seconds.median)
                .set("checkpointed_wall_s", r_ck.seconds.median)
                .set("overhead_ratio", checkpoint_overhead_ratio)
                .set("resume_bitwise_equal", resume_bitwise_equal),
        );
    // Workspace root for the same reason as BENCH_backends.json above.
    let path_out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_solver_path.json");
    match std::fs::write(path_out, report.to_string_pretty()) {
        Ok(()) => println!("  solver-path results written to {path_out}"),
        Err(e) => eprintln!("  warning: could not write {path_out}: {e}"),
    }

    // Out-of-core scale section. Stream-generate a TLFREDS1 file whose X
    // payload is at least 4× the configured RAM budget (`--scale-budget`,
    // MiB), then drive the out-of-core machinery against it: blocked
    // column norms, streaming λmax, the mmap-vs-dense Xᵀv sweep, and the
    // end-to-end TLFre path on the mmap backend. The dense in-RAM copy is
    // the reference for every bitwise gate — the budget bounds what the
    // *out-of-core* path is allowed to keep resident, not this process.
    let budget_mib = args.scale_budget_mib();
    let budget_bytes = budget_mib as u64 * (1 << 20);
    let sc_n = 500usize;
    // p: smallest multiple of 10 (uniform groups of 10) putting the f32
    // col-major X payload at ≥ 4× the budget.
    let sc_p = (4 * budget_bytes as usize).div_ceil(4 * sc_n).div_ceil(10) * 10;
    let sc_spec = SyntheticSpec::synthetic1_scaled(sc_n, sc_p, sc_p / 10);
    println!(
        "\n== out-of-core scale ({sc_n}×{sc_p}, budget {budget_mib} MiB, {} workers) ==",
        pool::num_threads()
    );
    let sc_path = std::env::temp_dir().join(format!("tlfre-scale-{}.bin", std::process::id()));
    let t_gen = Timer::start();
    generate_synthetic_streaming(&sc_spec, args.seed, &sc_path, 1024).expect("stream generate");
    let stream_generate_s = t_gen.elapsed_s();
    let file_bytes = std::fs::metadata(&sc_path).expect("stat streamed file").len();
    let mds = tlfre::data::io::open_mmap(&sc_path).expect("open mmap");
    let x_bytes = mds.x.x_payload_bytes();
    let budget_to_file_ratio = x_bytes as f64 / budget_bytes as f64;
    assert!(
        budget_to_file_ratio >= 4.0,
        "streamed X payload ({x_bytes} B) is under 4× the {budget_mib} MiB budget"
    );
    println!(
        "  streamed {} B file in {:.2} s ({} backend, X payload {:.1}× budget)",
        file_bytes,
        stream_generate_s,
        tlfre::linalg::MmapDenseMatrix::backend_kind(),
        budget_to_file_ratio,
    );

    let sc_cfg = BenchConfig { warmup: 1, runs: 3, max_seconds: 300.0 };

    // Blocked column norms over the mapped payload, vs the unblocked sweep.
    let norm_block_cols = 2048usize;
    let mut blocked_norms: Vec<f64> = Vec::new();
    let r_norms = bench("blocked col_norms", &sc_cfg, || {
        blocked_norms = col_norms_blocked(&mds.x, norm_block_cols);
        black_box(&blocked_norms);
    });
    let full_norms = mds.x.col_norms();
    let norms_equal = full_norms.len() == blocked_norms.len()
        && full_norms.iter().zip(&blocked_norms).all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(norms_equal, "blocked col_norms diverged from the unblocked sweep");
    let norms_gbs = x_bytes as f64 / r_norms.seconds.median / 1e9;

    // Streaming λmax in group blocks, vs the in-RAM Xᵀy materialization.
    let sc_prob = SglProblem::new(&mds.x, &mds.y, &mds.groups);
    let mut lm_stream = None;
    let r_lmax = bench("streaming λmax", &sc_cfg, || {
        lm_stream = Some(sgl_lambda_max_streaming(&sc_prob, 1.0, 64));
    });
    let lm_stream = lm_stream.expect("streaming λmax ran");
    let lm_full = sgl_lambda_max(&sc_prob, 1.0);
    let lmax_equal = lm_full.lambda_max.to_bits() == lm_stream.lambda_max.to_bits()
        && lm_full.argmax_group == lm_stream.argmax_group;
    assert!(lmax_equal, "streaming λmax diverged from the in-RAM value");
    let lmax_gbs = x_bytes as f64 / r_lmax.seconds.median / 1e9;
    println!(
        "  blocked col_norms {:8.2} ms ({:5.2} GB/s)   streaming λmax {:8.2} ms ({:5.2} GB/s)   both bitwise equal",
        r_norms.seconds.median * 1e3,
        norms_gbs,
        r_lmax.seconds.median * 1e3,
        lmax_gbs,
    );

    // Same file loaded fully into RAM: the dense reference for sweep cost
    // and for the end-to-end path's bitwise gate.
    let sc_ds = tlfre::data::io::load(&sc_path).expect("load streamed file");
    let mut sc_rng = Rng::seed_from_u64(args.seed ^ 0x5CA1E);
    let sc_v: Vec<f32> = (0..sc_n).map(|_| sc_rng.gaussian() as f32).collect();
    let mut sc_out = vec![0.0f32; sc_p];
    let r_sweep_mmap = bench("mmap matvec_t", &sc_cfg, || {
        mds.x.matvec_t(black_box(&sc_v), &mut sc_out);
        black_box(&sc_out);
    });
    let sweep_mmap = sc_out.clone();
    let r_sweep_dense = bench("dense matvec_t", &sc_cfg, || {
        sc_ds.x.matvec_t(black_box(&sc_v), &mut sc_out);
        black_box(&sc_out);
    });
    let sweep_equal =
        sweep_mmap.iter().zip(&sc_out).all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(sweep_equal, "mmap Xᵀv sweep diverged from the dense sweep");
    let sweep_ratio = r_sweep_mmap.seconds.median / r_sweep_dense.seconds.median.max(1e-12);
    println!(
        "  Xᵀv sweep: mmap {:8.2} ms   dense {:8.2} ms   ({:4.2}x dense cost, bitwise equal)",
        r_sweep_mmap.seconds.median * 1e3,
        r_sweep_dense.seconds.median * 1e3,
        sweep_ratio,
    );

    // End-to-end TLFre path against the on-disk design, with the in-RAM
    // dense path as the bitwise reference for every per-step statistic.
    let sc_path_cfg = PathConfig {
        alpha: 1.0,
        controls: SolveControls {
            n_lambda: args.n_lambda().min(8),
            lambda_min_ratio: 0.1,
            tol: 1e-5,
            ..Default::default()
        },
        ..Default::default()
    };
    let t_path_m = Timer::start();
    let sc_path_mmap = run_tlfre_path(&mds.x, &mds.y, &mds.groups, &sc_path_cfg);
    let mmap_path_wall_s = t_path_m.elapsed_s();
    let t_path_d = Timer::start();
    let sc_path_dense = run_tlfre_path(&sc_ds.x, &sc_ds.y, &sc_ds.groups, &sc_path_cfg);
    let dense_path_wall_s = t_path_d.elapsed_s();
    let path_equal = sc_path_mmap.lambda_max.to_bits() == sc_path_dense.lambda_max.to_bits()
        && sc_path_mmap.steps.len() == sc_path_dense.steps.len()
        && sc_path_mmap.steps.iter().zip(&sc_path_dense.steps).all(|(a, b)| {
            a.lambda.to_bits() == b.lambda.to_bits()
                && a.r1.to_bits() == b.r1.to_bits()
                && a.r2.to_bits() == b.r2.to_bits()
                && a.zeros == b.zeros
                && a.nonzeros == b.nonzeros
                && a.active_features == b.active_features
                && a.iters == b.iters
                && a.gap.to_bits() == b.gap.to_bits()
        });
    assert!(path_equal, "mmap TLFre path diverged from the in-RAM dense path");
    println!(
        "  end-to-end path ({} λ): mmap {:8.2} ms   dense {:8.2} ms   (bitwise equal, rejection {:.3})",
        sc_path_cfg.n_lambda,
        mmap_path_wall_s * 1e3,
        dense_path_wall_s * 1e3,
        sc_path_mmap.mean_total_rejection(),
    );

    let scale_bitwise_equal = norms_equal && lmax_equal && sweep_equal && path_equal;
    let scale_report = Json::obj()
        .set("bench", "perf_kernels/scale")
        .set("budget_mib", budget_mib)
        .set("n", sc_n)
        .set("p", sc_p)
        .set("threads", pool::num_threads())
        .set("backend_kind", tlfre::linalg::MmapDenseMatrix::backend_kind())
        .set("file_bytes", file_bytes as f64)
        .set("x_payload_bytes", x_bytes as f64)
        .set("budget_to_file_ratio", budget_to_file_ratio)
        .set("stream_generate_s", stream_generate_s)
        .set(
            "blocked_col_norms",
            Json::obj()
                .set("block_cols", norm_block_cols)
                .set("seconds", r_norms.seconds.median)
                .set("gb_per_s", norms_gbs)
                .set("bitwise_equal", norms_equal),
        )
        .set(
            "streaming_lambda_max",
            Json::obj()
                .set("block_groups", 64)
                .set("seconds", r_lmax.seconds.median)
                .set("gb_per_s", lmax_gbs)
                .set("bitwise_equal", lmax_equal),
        )
        .set(
            "sweep_matvec_t",
            Json::obj()
                .set("mmap_ms", r_sweep_mmap.seconds.median * 1e3)
                .set("dense_ms", r_sweep_dense.seconds.median * 1e3)
                .set("mmap_over_dense", sweep_ratio)
                .set("bitwise_equal", sweep_equal),
        )
        .set(
            "path_end_to_end",
            Json::obj()
                .set("n_lambda", sc_path_cfg.n_lambda)
                .set("mmap_wall_s", mmap_path_wall_s)
                .set("dense_wall_s", dense_path_wall_s)
                .set("mean_rejection", sc_path_mmap.mean_total_rejection())
                .set("bitwise_equal", path_equal),
        )
        .set("bitwise_equal", scale_bitwise_equal);
    let scale_out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_scale.json");
    match std::fs::write(scale_out, scale_report.to_string_pretty()) {
        Ok(()) => println!("  scale results written to {scale_out}"),
        Err(e) => eprintln!("  warning: could not write {scale_out}: {e}"),
    }
    drop(mds);
    let _ = std::fs::remove_file(&sc_path);

    // Serve-layer section: an in-process resident engine on a unix socket.
    // Cold = first request pays the dataset load + full walk; warm = the
    // resident cache answers with zero solver work. Every published number
    // is gated on the served bytes matching the batch walk bitwise.
    println!("\n== serve layer (resident engine on a unix socket) ==");
    let srv_socket =
        std::env::temp_dir().join(format!("tlfre-serve-bench-{}.sock", std::process::id()));
    let srv_reg = std::sync::Arc::new(SessionRegistry::new());
    let srv_stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let srv_handle = {
        let (s, r, f) = (srv_socket.clone(), srv_reg.clone(), srv_stop.clone());
        std::thread::spawn(move || serve_on(&s, r, f))
    };
    for _ in 0..500 {
        if std::os::unix::net::UnixStream::connect(&srv_socket).is_ok() {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let serve_call = |req: &SolveRequest| -> SolveResponse {
        let (status, body) =
            wire::call(&srv_socket, &req.to_json().to_string_compact()).expect("serve call");
        assert_eq!(status, 200, "{body}");
        let resp = SolveResponse::parse(&body).expect("serve response");
        assert!(resp.ok, "{:?}", resp.error);
        resp
    };
    let serve_req = |alpha: f64| -> SolveRequest {
        let mut req = SolveRequest::new(RequestKind::SolvePath);
        let mut spec = DatasetSpec::new("synthetic1");
        spec.seed = args.seed;
        spec.scale = 0.05;
        req.dataset = Some(spec);
        req.alpha = alpha;
        req.controls.n_lambda = 10;
        req.controls.lambda_min_ratio = 0.1;
        req.controls.tol = 1e-5;
        req
    };

    let path_req = serve_req(0.5);
    let t_cold = Timer::start();
    let cold_resp = serve_call(&path_req);
    let cold_path_s = t_cold.elapsed_s();
    assert!(!cold_resp.warm, "first path request must not be warm");
    let t_warm = Timer::start();
    let warm_resp = serve_call(&path_req);
    let warm_path_s = t_warm.elapsed_s();
    assert!(warm_resp.warm, "second identical path request must be warm");

    // Bitwise gate: served bytes vs the batch walk over the same dataset.
    let srv_spec = path_req.dataset.as_ref().expect("path request carries a dataset");
    let srv_ds = resolve_dataset(&srv_spec.name, srv_spec.seed, srv_spec.scale)
        .expect("resolve serve dataset");
    let (_srv_out, srv_betas) = run_tlfre_path_with_coefficients(
        &srv_ds.x,
        &srv_ds.y,
        &srv_ds.groups,
        &path_req.path_config(),
    );
    let batch_bytes = coef_hex_dump(&srv_betas);
    let serve_bitwise_equal =
        cold_resp.coef_dump() == batch_bytes && warm_resp.coef_dump() == batch_bytes;
    assert!(serve_bitwise_equal, "served coefficient bytes diverged from the batch walk");

    // Point requests on a fresh cache line (different α → different key):
    // cold pays the prefix walk to the index, warm answers from the cache.
    let mut point_req = serve_req(0.75);
    point_req.kind = RequestKind::SolvePoint;
    point_req.lambda_index = Some(5);
    let t_pcold = Timer::start();
    let pcold = serve_call(&point_req);
    let cold_point_s = t_pcold.elapsed_s();
    assert!(!pcold.warm);
    let t_pwarm = Timer::start();
    let pwarm = serve_call(&point_req);
    let warm_point_s = t_pwarm.elapsed_s();
    assert!(pwarm.warm);
    assert_eq!(pcold.coef_hex, pwarm.coef_hex, "warm point bytes diverged");

    // Round-trip latency under concurrency: 4 clients × 25 warm point
    // requests each — measures the wire + engine overhead of a cache hit.
    let (srv_clients, srv_reps) = (4usize, 25usize);
    let mut lat_joins = Vec::new();
    for _ in 0..srv_clients {
        let (socket, req) = (srv_socket.clone(), point_req.clone());
        lat_joins.push(std::thread::spawn(move || {
            let body = req.to_json().to_string_compact();
            let mut lat_s = Vec::with_capacity(srv_reps);
            for _ in 0..srv_reps {
                let t = Timer::start();
                let (status, text) = wire::call(&socket, &body).expect("latency call");
                lat_s.push(t.elapsed_s());
                assert_eq!(status, 200, "{text}");
            }
            lat_s
        }));
    }
    let mut lat_ms: Vec<f64> =
        lat_joins.into_iter().flat_map(|j| j.join().expect("latency client")).collect();
    lat_ms.iter_mut().for_each(|v| *v *= 1e3);
    lat_ms.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let p50_ms = lat_ms[lat_ms.len() / 2];
    let p95_ms = lat_ms[(lat_ms.len() * 95 / 100).min(lat_ms.len() - 1)];

    let warm_lt_cold = warm_path_s < cold_path_s && warm_point_s < cold_point_s;
    println!(
        "  path: cold {:8.2} ms   warm {:8.2} ms   point: cold {:8.2} ms   warm {:8.2} ms",
        cold_path_s * 1e3,
        warm_path_s * 1e3,
        cold_point_s * 1e3,
        warm_point_s * 1e3,
    );
    println!(
        "  {} clients × {} warm points: p50 {:6.2} ms   p95 {:6.2} ms   (bitwise equal: {})",
        srv_clients, srv_reps, p50_ms, p95_ms, serve_bitwise_equal,
    );

    let (shut_status, _) = wire::call(&srv_socket, r#"{"v": 1, "kind": "shutdown"}"#)
        .expect("shutdown call");
    assert_eq!(shut_status, 200);
    srv_handle.join().expect("server thread").expect("server exit");

    let serve_report = Json::obj()
        .set("bench", "perf_kernels/serve")
        .set("threads", pool::num_threads())
        .set("dataset", "synthetic1 @ scale 0.05")
        .set("n_lambda", 10usize)
        .set("cold_path_s", cold_path_s)
        .set("warm_path_s", warm_path_s)
        .set("cold_point_s", cold_point_s)
        .set("warm_point_s", warm_point_s)
        .set(
            "concurrent",
            Json::obj()
                .set("clients", srv_clients)
                .set("requests_per_client", srv_reps)
                .set("p50_ms", p50_ms)
                .set("p95_ms", p95_ms),
        )
        .set("warm_lt_cold", warm_lt_cold)
        .set("bitwise_equal", serve_bitwise_equal);
    let serve_out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    match std::fs::write(serve_out, serve_report.to_string_pretty()) {
        Ok(()) => println!("  serve results written to {serve_out}"),
        Err(e) => eprintln!("  warning: could not write {serve_out}: {e}"),
    }
}
