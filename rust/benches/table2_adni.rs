//! Table 2 — running time on the (simulated) ADNI SNP data set with GMV
//! and WMV responses: solver / TLFre / TLFre+solver / speedup per α.
//!
//! Default profile: 1/200-scale feature dimension (747×~2130, ragged gene
//! groups), 2 α values, 25 λ points. `--full` uses the paper's 426040-SNP
//! width (memory: ~1.2 GB; wall time: hours).

use tlfre::bench_harness::tables::{render_speedup_table, speedup_to_json, SpeedupColumn};
use tlfre::bench_harness::BenchArgs;
use tlfre::coordinator::{run_baseline_path, run_tlfre_path, PathConfig, SolveControls};
use tlfre::data::registry::RealDataset;
use tlfre::util::json::Json;

fn main() {
    tlfre::util::logger::init();
    let mut args = BenchArgs::from_env();
    if args.scale.is_none() && !args.full {
        args.scale = Some(0.004); // ADNI default: ~1/250 width
    }
    if args.n_alpha.is_none() && !args.full {
        args.n_alpha = Some(2);
    }
    if args.n_lambda.is_none() && !args.full {
        args.n_lambda = Some(25);
    }
    let alphas = args.alphas();
    let labels = args.alpha_labels();

    let mut report = Json::obj().set("bench", "table2");
    for set in [RealDataset::AdniGmv, RealDataset::AdniWmv] {
        let ds = set.generate(args.scale(), args.seed);
        eprintln!("[table2] {}", ds.describe());
        let mut cols = Vec::new();
        for (alpha, label) in alphas.iter().zip(&labels) {
            let cfg = PathConfig {
                alpha: *alpha,
                controls: SolveControls {
                    n_lambda: args.n_lambda(),
                    lambda_min_ratio: 0.01,
                    tol: 1e-5,
                    max_iter: 10_000,
                    ..Default::default()
                },
                ..Default::default()
            };
            let screened = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
            let baseline = run_baseline_path(&ds.x, &ds.y, &ds.groups, &cfg);
            eprintln!(
                "[table2]   α={label}: baseline {:.2}s screened {:.2}s (rejection {:.3})",
                baseline.total_s(),
                screened.total_s(),
                screened.mean_total_rejection()
            );
            cols.push(SpeedupColumn {
                label: label.clone(),
                solver_s: baseline.total_s(),
                screen_s: screened.screen_total_s,
                combined_s: screened.total_s(),
            });
        }
        println!("\n{}", render_speedup_table(&ds.name, &cols));
        report = report.set(&ds.name, speedup_to_json(&ds.name, &cols));
    }
    args.maybe_write_json(&report);
}
