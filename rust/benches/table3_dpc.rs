//! Table 3 — nonnegative Lasso with/without DPC on the eight data sets:
//! solver / DPC / DPC+solver / speedup per data set.
//!
//! Default profile uses the simulated sets at reduced feature scale and a
//! per-set λ-grid sized so the whole table completes on one core.

use tlfre::bench_harness::BenchArgs;
use tlfre::coordinator::{run_dpc_path, run_nonneg_baseline, DpcPathConfig, SolveControls};
use tlfre::data::registry::RealDataset;
use tlfre::data::synthetic::{generate_synthetic, SyntheticSpec};
use tlfre::data::Dataset;
use tlfre::util::harness::Table;
use tlfre::util::json::Json;
use tlfre::util::Rng;

/// Nonnegative Synthetic 1/2 (Section 6.2: same matrices, β* from 10% of
/// features, values |N(0,1)| so the nonneg model is well-specified).
fn nonneg_synthetic(spec: &SyntheticSpec, seed: u64) -> Dataset {
    let mut ds = generate_synthetic(spec, seed);
    let mut rng = Rng::seed_from_u64(seed ^ 0x99);
    let p = ds.p();
    let mut beta = vec![0.0f32; p];
    for &j in &rng.sample_indices(p, p / 10) {
        beta[j] = rng.gaussian().abs() as f32;
    }
    let mut y = vec![0.0f32; ds.n()];
    ds.x.matvec(&beta, &mut y);
    for v in y.iter_mut() {
        *v += (0.01 * rng.gaussian()) as f32;
    }
    ds.y = y;
    ds.beta_star = Some(beta);
    ds
}

fn main() {
    tlfre::util::logger::init();
    let args = BenchArgs::from_env();
    let (n, p, g) = args.synthetic_dims();

    // (dataset, n_lambda, max_iter): biggest sets get shorter grids in the
    // default profile; --full restores 100 points everywhere.
    let mut jobs: Vec<(Dataset, usize, usize)> = vec![
        (nonneg_synthetic(&SyntheticSpec::synthetic1_scaled(n, p, g), args.seed), 50, 3000),
        (nonneg_synthetic(&SyntheticSpec::synthetic2_scaled(n, p, g), args.seed), 50, 3000),
    ];
    for set in RealDataset::dpc_sets() {
        let (nl, mi) = match set {
            RealDataset::Svhn => (12, 2000),
            RealDataset::Pie | RealDataset::Mnist => (25, 4000),
            _ => (50, 10_000),
        };
        jobs.push((set.generate(args.scale(), args.seed), nl, mi));
    }

    let mut table = Table::new(&["", "solver", "DPC", "DPC+solver", "speedup", "rejection"]);
    let mut report = Json::obj().set("bench", "table3");
    for (ds, nl_default, mi) in jobs {
        let nl = if args.full { 100 } else { args.n_lambda.unwrap_or(nl_default) };
        let cfg = DpcPathConfig {
            controls: SolveControls {
                n_lambda: nl,
                lambda_min_ratio: if args.full { 0.01 } else { 0.1 },
                tol: 1e-5,
                max_iter: mi,
                ..Default::default()
            },
            ..Default::default()
        };
        eprintln!("[table3] {} ({} λ values)", ds.describe(), nl);
        let screened = run_dpc_path(&ds.x, &ds.y, &cfg);
        let baseline = run_nonneg_baseline(&ds.x, &ds.y, &cfg);
        let speedup = baseline.total_s() / screened.total_s().max(1e-12);
        eprintln!(
            "[table3]   baseline {:.2}s screened {:.2}s speedup {:.2} rejection {:.3}",
            baseline.total_s(),
            screened.total_s(),
            speedup,
            screened.mean_rejection()
        );
        table.row(vec![
            ds.name.clone(),
            format!("{:.2}", baseline.total_s()),
            format!("{:.2}", screened.screen_total_s),
            format!("{:.2}", screened.total_s()),
            format!("{:.2}", speedup),
            format!("{:.3}", screened.mean_rejection()),
        ]);
        report = report.set(
            &ds.name,
            Json::obj()
                .set("solver_s", baseline.total_s())
                .set("dpc_s", screened.screen_total_s)
                .set("combined_s", screened.total_s())
                .set("speedup", speedup)
                .set("rejection", screened.mean_rejection()),
        );
    }
    println!("\nTable 3 — nonnegative Lasso, 8 data sets\n{}", table.render());
    args.maybe_write_json(&report);
}
