//! Table 1 — running time for solving SGL along the λ path on Synthetic 1
//! and Synthetic 2, by (a) the solver without screening, (b) TLFre alone,
//! (c) TLFre + solver, plus the speedup row. Columns are the paper's α
//! grid (`tan ψ`).
//!
//! Default profile: 250×2000 (1/5 width), 3 α values, 50 λ points.
//! `cargo bench --bench table1_synthetic -- --full` reproduces the paper's
//! exact 250×10000 / 7 α / 100 λ grid (hours on one core).

use tlfre::bench_harness::tables::{render_speedup_table, speedup_to_json, SpeedupColumn};
use tlfre::bench_harness::BenchArgs;
use tlfre::coordinator::{run_baseline_path, run_tlfre_path, PathConfig, SolveControls};
use tlfre::data::synthetic::{generate_synthetic, SyntheticSpec};
use tlfre::util::json::Json;

fn main() {
    tlfre::util::logger::init();
    let args = BenchArgs::from_env();
    let (n, p, g) = args.synthetic_dims();
    let alphas = args.alphas();
    let labels = args.alpha_labels();

    let mut report = Json::obj().set("bench", "table1");
    for spec in [
        SyntheticSpec::synthetic1_scaled(n, p, g),
        SyntheticSpec::synthetic2_scaled(n, p, g),
    ] {
        let ds = generate_synthetic(&spec, args.seed);
        eprintln!("[table1] {}", ds.describe());
        let mut cols = Vec::new();
        for (alpha, label) in alphas.iter().zip(&labels) {
            let cfg = PathConfig {
                alpha: *alpha,
                controls: SolveControls {
                    n_lambda: args.n_lambda(),
                    lambda_min_ratio: 0.01,
                    tol: 1e-6,
                    max_iter: 20_000,
                    ..Default::default()
                },
                ..Default::default()
            };
            let screened = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
            let baseline = run_baseline_path(&ds.x, &ds.y, &ds.groups, &cfg);
            eprintln!(
                "[table1]   α={label}: baseline {:.2}s screened {:.2}s (rejection {:.3})",
                baseline.total_s(),
                screened.total_s(),
                screened.mean_total_rejection()
            );
            cols.push(SpeedupColumn {
                label: label.clone(),
                solver_s: baseline.total_s(),
                screen_s: screened.screen_total_s,
                combined_s: screened.total_s(),
            });
        }
        println!("\n{}", render_speedup_table(&ds.name, &cols));
        report = report.set(&ds.name, speedup_to_json(&ds.name, &cols));
    }
    args.maybe_write_json(&report);
}
