//! Figure 5 — DPC rejection-ratio series on the eight data sets
//! (Synthetic 1/2 + six simulated real sets).

use tlfre::bench_harness::tables::render_dpc_series;
use tlfre::bench_harness::BenchArgs;
use tlfre::coordinator::{run_dpc_path, DpcPathConfig, SolveControls};
use tlfre::data::registry::RealDataset;
use tlfre::data::synthetic::SyntheticSpec;
use tlfre::data::Dataset;
use tlfre::util::json::Json;
use tlfre::util::Rng;

fn nonneg_synthetic(spec: &SyntheticSpec, seed: u64) -> Dataset {
    let mut ds = tlfre::data::synthetic::generate_synthetic(spec, seed);
    let mut rng = Rng::seed_from_u64(seed ^ 0x99);
    let p = ds.p();
    let mut beta = vec![0.0f32; p];
    for &j in &rng.sample_indices(p, p / 10) {
        beta[j] = rng.gaussian().abs() as f32;
    }
    let mut y = vec![0.0f32; ds.n()];
    ds.x.matvec(&beta, &mut y);
    for v in y.iter_mut() {
        *v += (0.01 * rng.gaussian()) as f32;
    }
    ds.y = y;
    ds
}

fn main() {
    tlfre::util::logger::init();
    let args = BenchArgs::from_env();
    let (n, p, g) = args.synthetic_dims();
    let mut sets: Vec<(Dataset, usize)> = vec![
        (nonneg_synthetic(&SyntheticSpec::synthetic1_scaled(n, p, g), args.seed), 50),
        (nonneg_synthetic(&SyntheticSpec::synthetic2_scaled(n, p, g), args.seed), 50),
    ];
    for set in RealDataset::dpc_sets() {
        let nl = match set {
            RealDataset::Svhn => 15,
            RealDataset::Pie | RealDataset::Mnist => 30,
            _ => 50,
        };
        sets.push((set.generate(args.scale(), args.seed), nl));
    }
    let mut report = Json::obj().set("bench", "fig5");
    for (ds, nl_default) in sets {
        let nl = if args.full { 100 } else { args.n_lambda.unwrap_or(nl_default) };
        let cfg = DpcPathConfig {
            controls: SolveControls {
                n_lambda: nl,
                lambda_min_ratio: if args.full { 0.01 } else { 0.1 },
                tol: 1e-4,
                max_iter: 2000,
                ..Default::default()
            },
            ..Default::default()
        };
        let out = run_dpc_path(&ds.x, &ds.y, &cfg);
        println!("{}", render_dpc_series(&ds.name, &out));
        report = report.set(
            &ds.name,
            Json::obj()
                .set("mean_rejection", out.mean_rejection())
                .set("lambda_max", out.lambda_max)
                .set(
                    "rejection",
                    out.steps.iter().map(|s| s.rejection).collect::<Vec<_>>(),
                ),
        );
    }
    args.maybe_write_json(&report);
}
