//! Figures 1 and 2 — TLFre rejection-ratio series (r₁ stacked with r₂ per
//! λ) on Synthetic 1 and Synthetic 2, one panel per α, plus the λ₁^max(λ₂)
//! boundary curve of the upper-left panels (Corollary 10).

use tlfre::bench_harness::tables::{render_rejection_series, series_to_json};
use tlfre::bench_harness::BenchArgs;
use tlfre::coordinator::{run_tlfre_path, PathConfig, SolveControls};
use tlfre::data::synthetic::{generate_synthetic, SyntheticSpec};
use tlfre::screening::lambda_max::lambda1_max;
use tlfre::sgl::SglProblem;
use tlfre::util::json::Json;

fn main() {
    tlfre::util::logger::init();
    let args = BenchArgs::from_env();
    let (n, p, g) = args.synthetic_dims();
    let alphas = args.alphas();
    let labels = args.alpha_labels();

    let mut report = Json::obj().set("bench", "fig1_2");
    for (fig, spec) in [
        ("Figure 1", SyntheticSpec::synthetic1_scaled(n, p, g)),
        ("Figure 2", SyntheticSpec::synthetic2_scaled(n, p, g)),
    ] {
        let ds = generate_synthetic(&spec, args.seed);
        println!("==== {fig}: {} ====", ds.describe());

        // Upper-left panel: the λ₁max(λ₂) boundary (Corollary 10).
        let prob = SglProblem::new(&ds.x, &ds.y, &ds.groups);
        println!("λ₁^max(λ₂) boundary (Corollary 10):");
        let l2max = {
            let mut c = vec![0.0f32; ds.p()];
            ds.x.matvec_t(&ds.y, &mut c);
            c.iter().fold(0.0f64, |m, &v| m.max((v as f64).abs()))
        };
        for k in 0..=8 {
            let l2 = l2max * k as f64 / 8.0;
            println!("  λ₂ = {l2:9.3} → λ₁max = {:9.3}", lambda1_max(&prob, l2));
        }

        let mut fig_json = Json::obj();
        for (alpha, label) in alphas.iter().zip(&labels) {
            let cfg = PathConfig {
                alpha: *alpha,
                controls: SolveControls {
                    n_lambda: args.n_lambda(),
                    lambda_min_ratio: 0.01,
                    tol: 1e-5,
                    max_iter: 3000,
                    ..Default::default()
                },
                ..Default::default()
            };
            let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
            println!("{}", render_rejection_series(&format!("{} α={label}", ds.name), &out));
            fig_json = fig_json.set(label, series_to_json(&out));
        }
        report = report.set(fig, fig_json);
    }
    args.maybe_write_json(&report);
}
