//! Figures 3 and 4 — TLFre rejection-ratio series on the (simulated) ADNI
//! data set with GMV (Fig. 3) and WMV (Fig. 4) responses, plus the
//! Corollary-10 boundary panel.

use tlfre::bench_harness::tables::{render_rejection_series, series_to_json};
use tlfre::bench_harness::BenchArgs;
use tlfre::coordinator::{run_tlfre_path, PathConfig, SolveControls};
use tlfre::data::registry::RealDataset;
use tlfre::screening::lambda_max::lambda1_max;
use tlfre::sgl::SglProblem;
use tlfre::util::json::Json;

fn main() {
    tlfre::util::logger::init();
    let mut args = BenchArgs::from_env();
    if args.scale.is_none() && !args.full {
        args.scale = Some(0.005);
    }
    if args.n_lambda.is_none() && !args.full {
        args.n_lambda = Some(30);
    }
    let alphas = args.alphas();
    let labels = args.alpha_labels();

    let mut report = Json::obj().set("bench", "fig3_4");
    for (fig, set) in [("Figure 3", RealDataset::AdniGmv), ("Figure 4", RealDataset::AdniWmv)] {
        let ds = set.generate(args.scale(), args.seed);
        println!("==== {fig}: {} ====", ds.describe());
        let prob = SglProblem::new(&ds.x, &ds.y, &ds.groups);
        println!("λ₁^max(λ₂) boundary (Corollary 10):");
        let l2max = {
            let mut c = vec![0.0f32; ds.p()];
            ds.x.matvec_t(&ds.y, &mut c);
            c.iter().fold(0.0f64, |m, &v| m.max((v as f64).abs()))
        };
        for k in 0..=6 {
            let l2 = l2max * k as f64 / 6.0;
            println!("  λ₂ = {l2:9.3} → λ₁max = {:9.3}", lambda1_max(&prob, l2));
        }
        let mut fig_json = Json::obj();
        for (alpha, label) in alphas.iter().zip(&labels) {
            let cfg = PathConfig {
                alpha: *alpha,
                controls: SolveControls {
                    n_lambda: args.n_lambda(),
                    lambda_min_ratio: 0.01,
                    tol: 1e-4,
                    max_iter: 2500,
                    ..Default::default()
                },
                ..Default::default()
            };
            let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
            println!("{}", render_rejection_series(&format!("{} α={label}", ds.name), &out));
            fig_json = fig_json.set(label, series_to_json(&out));
        }
        report = report.set(fig, fig_json);
    }
    args.maybe_write_json(&report);
}
