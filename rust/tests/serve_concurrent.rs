//! The serve layer under concurrent clients: N parallel connections
//! interleaving `solve-path` and `solve-point` requests get answers that
//! are **bitwise identical** to in-process batch runs, the shared path
//! cache only ever helps (warm answers carry the same bytes), and a
//! client that hangs up mid-request poisons neither the worker pool nor
//! the cache. The CI `TLFRE_THREADS ∈ {1,2,4,8}` matrix runs this whole
//! file under each process-level thread count.

#![cfg(not(miri))] // unix sockets + dataset files

use std::io::Write;
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::thread;
use std::time::Duration;
use tlfre::coordinator::run_tlfre_path_with_coefficients;
use tlfre::data::registry::resolve_dataset;
use tlfre::server::wire;
use tlfre::server::{
    coef_hex_dump, serve_on, BackendKind, DatasetSpec, RequestKind, SessionRegistry, SolveRequest,
    SolveResponse,
};

fn temp_socket(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("tlfre-conc-{}-{tag}.sock", std::process::id()))
}

/// Start an in-process server on a fresh socket and wait until it accepts.
fn start(tag: &str) -> (PathBuf, thread::JoinHandle<tlfre::error::Result<()>>) {
    let socket = temp_socket(tag);
    let reg = Arc::new(SessionRegistry::new());
    let stop = Arc::new(AtomicBool::new(false));
    let s = socket.clone();
    let handle = thread::spawn(move || serve_on(&s, reg, stop));
    for _ in 0..500 {
        if socket.exists() && UnixStream::connect(&socket).is_ok() {
            break;
        }
        thread::sleep(Duration::from_millis(5));
    }
    (socket, handle)
}

fn shutdown(socket: &Path) {
    let (status, _) = wire::call(socket, r#"{"v": 1, "kind": "shutdown"}"#).unwrap();
    assert_eq!(status, 200);
}

/// An 8-point synthetic1 path request at scale 0.01 (250×100, 10 groups).
fn path_request(backend: BackendKind) -> SolveRequest {
    let mut req = SolveRequest::new(RequestKind::SolvePath);
    let mut spec = DatasetSpec::new("synthetic1");
    spec.scale = 0.01;
    spec.backend = backend;
    req.dataset = Some(spec);
    req.alpha = 0.5;
    req.controls.n_lambda = 8;
    req.controls.lambda_min_ratio = 0.1;
    req
}

/// The batch reference: the same walk run in-process through the public
/// coordinator API, dumped with the same hex encoder.
fn batch_dump(req: &SolveRequest) -> String {
    let spec = req.dataset.as_ref().unwrap();
    let ds = resolve_dataset(&spec.name, spec.seed, spec.scale).unwrap();
    let (_out, betas) =
        run_tlfre_path_with_coefficients(&ds.x, &ds.y, &ds.groups, &req.path_config());
    coef_hex_dump(&betas)
}

fn send(socket: &Path, req: &SolveRequest) -> SolveResponse {
    let (status, body) = wire::call(socket, &req.to_json().to_string_compact()).unwrap();
    assert_eq!(status, 200, "{body}");
    let resp = SolveResponse::parse(&body).unwrap();
    assert!(resp.ok, "{:?}", resp.error);
    resp
}

#[test]
fn parallel_clients_interleaving_paths_and_points_get_bitwise_identical_answers() {
    let (socket, handle) = start("parallel");
    let expected = batch_dump(&path_request(BackendKind::Dense));
    let expected_lines: Vec<&str> = expected.lines().collect();
    assert_eq!(expected_lines.len(), 8);

    // Four concurrent clients against one registry: a dense full path, a
    // sharded full path (backend parity: same bytes), and two point
    // requests racing the path requests on the same dense cache line.
    let mut joins = Vec::new();
    for c in 0..4usize {
        let socket = socket.clone();
        joins.push(thread::spawn(move || {
            let req = match c {
                0 => path_request(BackendKind::Dense),
                1 => path_request(BackendKind::Sharded),
                _ => {
                    let mut r = path_request(BackendKind::Dense);
                    r.kind = RequestKind::SolvePoint;
                    r.lambda_index = Some(if c == 2 { 3 } else { 6 });
                    r
                }
            };
            (c, send(&socket, &req))
        }));
    }
    for j in joins {
        let (c, resp) = j.join().unwrap();
        match c {
            0 | 1 => {
                assert_eq!(resp.coef_hex.len(), 8, "client {c}");
                assert_eq!(resp.coef_dump(), expected, "client {c}");
                assert!(!resp.truncated);
            }
            _ => {
                let idx = if c == 2 { 3 } else { 6 };
                assert_eq!(resp.coef_hex.len(), 1, "client {c}");
                assert_eq!(resp.coef_hex[0], expected_lines[idx], "client {c}");
                assert!(resp.certified_suboptimality.is_some());
            }
        }
    }

    // After the race settles the full dense walk is resident: the same
    // requests answer warm with identical bytes.
    let warm_path = send(&socket, &path_request(BackendKind::Dense));
    assert!(warm_path.warm);
    assert_eq!(warm_path.coef_dump(), expected);
    let mut point = path_request(BackendKind::Dense);
    point.kind = RequestKind::SolvePoint;
    point.lambda_index = Some(5);
    let warm_point = send(&socket, &point);
    assert!(warm_point.warm);
    assert_eq!(warm_point.coef_hex[0], expected_lines[5]);

    shutdown(&socket);
    handle.join().unwrap().unwrap();
    assert!(!socket.exists());
}

#[test]
fn mid_request_disconnects_poison_neither_pool_nor_cache() {
    let (socket, handle) = start("disconnect");
    let req = path_request(BackendKind::Dense);
    let body = req.to_json().to_string_compact();

    // Client 1: hangs up mid-frame (headers promise more bytes than sent).
    {
        let mut s = UnixStream::connect(&socket).unwrap();
        s.write_all(b"POST /v1/solve HTTP/1.0\r\nContent-Length: 999\r\n\r\n{\"v\": 1").unwrap();
    }
    // Client 2: sends a complete, valid solve-path request but disconnects
    // before reading the response — the server finishes the walk and keeps
    // it cached; the EPIPE on write is discarded.
    {
        let mut s = UnixStream::connect(&socket).unwrap();
        let frame =
            format!("POST /v1/solve HTTP/1.0\r\nContent-Length: {}\r\n\r\n{body}", body.len());
        s.write_all(frame.as_bytes()).unwrap();
    }
    // Client 3: connects and says nothing (EOF) — a clean no-op.
    drop(UnixStream::connect(&socket).unwrap());

    // The server still answers, and the bytes still match the batch run.
    let resp = send(&socket, &req);
    assert_eq!(resp.coef_dump(), batch_dump(&req));

    shutdown(&socket);
    handle.join().unwrap().unwrap();
}
