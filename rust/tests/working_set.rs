//! End-to-end tests for the celer-style working-set outer loop
//! (`--screen ws` family):
//!
//! * **exactness** — `ws`, `tlfre+ws`, and `ws+gap` paths must match the
//!   no-screening baseline's and `tlfre+gap`'s final supports at every λ
//!   on the dense *and* CSC backends, with gap-bounded objectives (runs
//!   under the CI `TLFRE_THREADS ∈ {1,2,4,8}` matrix, which covers the
//!   acceptance thread sweep);
//! * **counters** — ws pipelines report `ws_rounds ≥ 1` and a nonzero
//!   final set size per step; non-ws pipelines report zeros;
//! * **adversarial recovery** — a working-set rule seeded in the WORST
//!   order (support admitted last) must still converge to the exact path
//!   through KKT-violation-driven growth alone.

use tlfre::coordinator::{
    drive_tlfre_path_with_pipeline, run_tlfre_path, CoefficientSink, PathConfig, SolveControls,
    StepSink,
};
use tlfre::data::synthetic::{
    generate_sparse_synthetic, generate_synthetic, SparseSyntheticSpec, SyntheticSpec,
};
use tlfre::linalg::DesignMatrix;
use tlfre::screening::{ScreenKind, ScreenPipeline, WorkingSetRule};

use tlfre::screening::same_support_at_resolution as same_support;

fn cfg(screen: ScreenKind) -> PathConfig {
    PathConfig {
        alpha: 1.0,
        screen,
        controls: SolveControls {
            n_lambda: 10,
            lambda_min_ratio: 0.05,
            tol: 1e-6,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn path_betas<M: DesignMatrix>(
    x: &M,
    y: &[f32],
    groups: &tlfre::groups::GroupStructure,
    c: &PathConfig,
) -> Vec<Vec<f32>> {
    tlfre::coordinator::path_coefficients(x, y, groups, c)
}

/// Supports equal at every λ and objectives within the summed duality
/// gaps — the working-set safety contract against a reference pipeline.
fn assert_path_matches<M: DesignMatrix>(
    x: &M,
    y: &[f32],
    groups: &tlfre::groups::GroupStructure,
    screen: ScreenKind,
    reference: ScreenKind,
    backend: &str,
) {
    use tlfre::sgl::{SglParams, SglProblem};
    let ws_cfg = cfg(screen);
    let ref_cfg = cfg(reference);
    let sa = run_tlfre_path(x, y, groups, &ws_cfg);
    let sb = run_tlfre_path(x, y, groups, &ref_cfg);
    let a = path_betas(x, y, groups, &ws_cfg);
    let b = path_betas(x, y, groups, &ref_cfg);
    assert_eq!(a.len(), b.len());
    let prob = SglProblem::new(x, y, groups);
    let mut r = vec![0.0f32; y.len()];
    for li in 0..a.len() {
        assert!(
            same_support(&a[li], &b[li]),
            "{backend}/{screen:?} vs {reference:?}: support diverged at λ index {li}"
        );
        // Both solves end within their own duality gap of the shared
        // optimum, so objectives differ by at most the summed gaps (plus
        // f32 objective-evaluation noise).
        let params = SglParams::from_alpha_lambda(ws_cfg.alpha, sa.steps[li].lambda);
        tlfre::sgl::objective::residual(&prob, &a[li], &mut r);
        let pa =
            tlfre::sgl::objective::objective_with_residual(&prob, &params, &a[li], &r).total();
        tlfre::sgl::objective::residual(&prob, &b[li], &mut r);
        let pb =
            tlfre::sgl::objective::objective_with_residual(&prob, &params, &b[li], &r).total();
        let noise = 1e-5 * pa.abs().max(pb.abs()).max(1.0);
        let budget = sa.steps[li].gap + sb.steps[li].gap + noise;
        assert!(
            (pa - pb).abs() <= budget,
            "{backend}/{screen:?} λ index {li}: objectives {pa} vs {pb} differ beyond \
             the gap budget {budget}"
        );
    }
}

#[test]
fn working_set_paths_match_baseline_and_safe_pipelines_dense() {
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 160, 16), 3041);
    for screen in [ScreenKind::Ws, ScreenKind::TlfreWs, ScreenKind::WsGap] {
        assert_path_matches(&ds.x, &ds.y, &ds.groups, screen, ScreenKind::None, "dense");
        assert_path_matches(&ds.x, &ds.y, &ds.groups, screen, ScreenKind::TlfreGap, "dense");
    }
}

#[test]
fn working_set_paths_match_baseline_and_safe_pipelines_csc() {
    let ds = generate_sparse_synthetic(&SparseSyntheticSpec::new(40, 160, 16, 0.2), 3042);
    for screen in [ScreenKind::Ws, ScreenKind::TlfreWs, ScreenKind::WsGap] {
        assert_path_matches(&ds.x, &ds.y, &ds.groups, screen, ScreenKind::None, "csc");
        assert_path_matches(&ds.x, &ds.y, &ds.groups, screen, ScreenKind::TlfreGap, "csc");
    }
}

#[test]
fn ws_round_counters_are_reported_and_zero_elsewhere() {
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 160, 16), 3043);
    let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg(ScreenKind::TlfreWs));
    // Every post-λmax step ran the outer loop at least once (one loose
    // round + the tight finish ⇒ ≥ 2 when any violation fired, ≥ 1 when
    // the seed was already KKT-clean) and solved a nonempty final set.
    for (li, s) in out.steps.iter().enumerate().skip(1) {
        assert!(s.ws_rounds >= 1, "λ index {li}: ws_rounds = {}", s.ws_rounds);
        // The final solved set always covers the support (an all-zero
        // step may legitimately have an empty set under tlfre+ws).
        assert!(
            s.ws_final_size >= s.nonzeros,
            "λ index {li}: final set {} smaller than the support {}",
            s.ws_final_size,
            s.nonzeros
        );
    }
    assert!(
        out.steps.iter().any(|s| s.ws_final_size > 0),
        "the working set never held a feature along the whole path"
    );
    // Non-ws pipelines leave both counters at zero.
    let plain = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg(ScreenKind::TlfreGap));
    assert!(plain.steps.iter().all(|s| s.ws_rounds == 0 && s.ws_final_size == 0));
}

#[test]
fn adversarial_seed_order_is_recovered_by_kkt_growth() {
    // The adversarial rule reverses the admission order: the known
    // support and the highest-scored groups are admitted LAST, so the
    // initial working set is maximally wrong. Only the KKT-violation
    // growth loop (and, past the round cap, the safe-fallback union) can
    // make this path exact.
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 120, 12), 3044);
    let c = {
        let mut c = cfg(ScreenKind::Ws);
        // A tight round cap forces the safe-fallback path to fire too.
        c.ws_max_rounds = 3;
        c
    };
    let pipeline =
        ScreenPipeline::new(vec![Box::new(WorkingSetRule::adversarial())], false);
    assert!(pipeline.has_working_set());
    let mut steps = StepSink::new();
    drive_tlfre_path_with_pipeline(&ds.x, &ds.y, &ds.groups, &c, pipeline, &mut steps);
    let readmitted: usize = steps.steps.iter().map(|s| s.kkt_readmitted).sum();
    assert!(readmitted > 0, "the adversarial seed never tripped a KKT violation");
    // The recovered path matches the exact TLFre walk support-for-support.
    let pipeline =
        ScreenPipeline::new(vec![Box::new(WorkingSetRule::adversarial())], false);
    let mut sink = CoefficientSink::new();
    drive_tlfre_path_with_pipeline(&ds.x, &ds.y, &ds.groups, &c, pipeline, &mut sink);
    let reference = path_betas(&ds.x, &ds.y, &ds.groups, &cfg(ScreenKind::Tlfre));
    assert_eq!(sink.betas.len(), reference.len());
    for (li, (ba, bb)) in sink.betas.iter().zip(&reference).enumerate() {
        assert!(same_support(ba, bb), "adversarial ws left a wrong support at λ {li}");
    }
}
