//! Property tests for the paper's central claim: TLFre and DPC are *exact*
//! (safe) rules — every discarded group/feature is zero at the optimum.
//!
//! proptest is unavailable offline, so these run a seeded-trial loop over
//! randomized problem families (dimensions, group layouts, α, λ steps,
//! correlation structures), solving to tight duality gaps and asserting
//! the safety property for each screening outcome.

use tlfre::data::synthetic::{generate_synthetic, Correlation, SyntheticSpec};
use tlfre::groups::GroupStructure;
use tlfre::linalg::DenseMatrix;
use tlfre::nonneg::{lambda_max as nn_lambda_max, solve_nonneg, NonnegOptions, NonnegProblem};
use tlfre::screening::dpc::dpc_screen;
use tlfre::screening::lambda_max::sgl_lambda_max;
use tlfre::screening::tlfre::{tlfre_screen, TlfreContext};
use tlfre::sgl::{solve_fista, FistaOptions, SglParams, SglProblem};
use tlfre::util::Rng;

/// One randomized TLFre safety trial.
fn tlfre_trial(seed: u64) -> (usize, usize) {
    let mut rng = Rng::seed_from_u64(seed);
    // Random problem family.
    let n = 10 + rng.below(30);
    let g_cnt = 3 + rng.below(10);
    let sizes: Vec<usize> = (0..g_cnt).map(|_| 1 + rng.below(8)).collect();
    let p: usize = sizes.iter().sum();
    let correlated = rng.below(2) == 1;
    let x = if correlated {
        // AR columns
        let rho = 0.5;
        let w = (1.0 - rho * rho as f64).sqrt();
        let mut prev: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        DenseMatrix::from_fn(n, p, |i, j| {
            if j == 0 {
                prev[i] as f32
            } else {
                if i == 0 { /* advance row-wise per column visit */ }
                let v = rho * prev[i] + w * rng.gaussian();
                prev[i] = v;
                v as f32
            }
        })
    } else {
        DenseMatrix::from_fn(n, p, |_, _| rng.gaussian() as f32)
    };
    let groups = GroupStructure::from_sizes(&sizes);
    // Sparse planted signal.
    let mut beta = vec![0.0f32; p];
    for _ in 0..1 + p / 6 {
        beta[rng.below(p)] = rng.normal(0.0, 1.0) as f32;
    }
    let mut y = vec![0.0f32; n];
    x.matvec(&beta, &mut y);
    for v in y.iter_mut() {
        *v += rng.normal(0.0, 0.02) as f32;
    }

    let prob = SglProblem::new(&x, &y, &groups);
    let alpha = rng.uniform_range(0.1, 4.0);
    let lmax = sgl_lambda_max(&prob, alpha);
    if lmax.lambda_max <= 0.0 {
        return (0, 0);
    }
    let ctx = TlfreContext::precompute(&prob);
    let opts = FistaOptions { tol: 1e-11, ..Default::default() };

    // Two-step path with a random step ratio.
    let ratio = rng.uniform_range(0.3, 0.98);
    let lambda1 = lmax.lambda_max * rng.uniform_range(0.5, 0.999);
    let lambda2 = lambda1 * ratio;

    // Exact solve at λ₁, then screen λ₂ from it.
    let params1 = SglParams::from_alpha_lambda(alpha, lambda1);
    let sol1 = solve_fista(&prob, &params1, None, &opts);
    let mut r = vec![0.0f32; n];
    tlfre::sgl::objective::residual(&prob, &sol1.beta, &mut r);
    let theta_bar: Vec<f32> = r.iter().map(|&v| (v as f64 / lambda1) as f32).collect();

    let out = tlfre_screen(&prob, alpha, lambda2, lambda1, &theta_bar, &lmax, &ctx);
    let params2 = SglParams::from_alpha_lambda(alpha, lambda2);
    let sol2 = solve_fista(&prob, &params2, None, &opts);
    let mut violations = 0usize;
    for j in 0..p {
        if !out.feature_kept[j] && sol2.beta[j].abs() > 1e-4 {
            eprintln!(
                "seed {seed}: feature {j} screened, |β|={} (α={alpha}, λ̄={lambda1}, λ={lambda2})",
                sol2.beta[j]
            );
            violations += 1;
        }
    }
    (violations, out.total_rejected())
}

#[test]
fn tlfre_safety_randomized_families() {
    let mut total_rejected = 0usize;
    for seed in 0..40 {
        let (violations, rejected) = tlfre_trial(1000 + seed);
        assert_eq!(violations, 0, "safety violated for seed {}", 1000 + seed);
        total_rejected += rejected;
    }
    // The rules must actually do something across the family.
    assert!(total_rejected > 100, "screening rejected almost nothing: {total_rejected}");
}

/// Screening directly from λmax (the path entry case, Theorem 12's
/// λ̄ = λmax branch) across random problems.
#[test]
fn tlfre_safety_from_lambda_max() {
    for seed in 0..25 {
        let mut rng = Rng::seed_from_u64(2000 + seed);
        let n = 12 + rng.below(20);
        let g_cnt = 4 + rng.below(6);
        let gs = 1 + rng.below(5);
        let p = g_cnt * gs;
        let x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian() as f32);
        let groups = GroupStructure::uniform(p, g_cnt);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
        let prob = SglProblem::new(&x, &y, &groups);
        let alpha = rng.uniform_range(0.2, 3.0);
        let lmax = sgl_lambda_max(&prob, alpha);
        let ctx = TlfreContext::precompute(&prob);
        let theta: Vec<f32> = y.iter().map(|&v| (v as f64 / lmax.lambda_max) as f32).collect();
        let lambda = lmax.lambda_max * rng.uniform_range(0.5, 0.99);
        let out = tlfre_screen(&prob, alpha, lambda, lmax.lambda_max, &theta, &lmax, &ctx);
        let sol = solve_fista(
            &prob,
            &SglParams::from_alpha_lambda(alpha, lambda),
            None,
            &FistaOptions { tol: 1e-11, ..Default::default() },
        );
        for j in 0..p {
            if !out.feature_kept[j] {
                assert!(
                    sol.beta[j].abs() < 1e-4,
                    "seed {}: feature {j} screened but β={}",
                    2000 + seed,
                    sol.beta[j]
                );
            }
        }
    }
}

/// DPC safety across random nonnegative problems.
#[test]
fn dpc_safety_randomized() {
    for seed in 0..30 {
        let mut rng = Rng::seed_from_u64(3000 + seed);
        let n = 10 + rng.below(25);
        let p = 20 + rng.below(80);
        let x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian().abs() as f32);
        let mut y = vec![0.0f32; n];
        for _ in 0..3 {
            let j = rng.below(p);
            tlfre::linalg::ops::axpy(rng.uniform_range(0.2, 1.0) as f32, x.col(j), &mut y);
        }
        let prob = NonnegProblem::new(&x, &y);
        let (lmax, arg) = nn_lambda_max(&prob);
        if lmax <= 0.0 {
            continue;
        }
        let col_norms = x.col_norms();
        let lambda1 = lmax * rng.uniform_range(0.4, 0.99);
        let lambda2 = lambda1 * rng.uniform_range(0.4, 0.95);
        let o1 = solve_nonneg(
            &prob,
            lambda1,
            None,
            &NonnegOptions { tol: 1e-11, ..Default::default() },
        );
        let mut r = vec![0.0f32; n];
        x.matvec(&o1.beta, &mut r);
        for i in 0..n {
            r[i] = y[i] - r[i];
        }
        let theta: Vec<f32> = r.iter().map(|&v| (v as f64 / lambda1) as f32).collect();
        let out = dpc_screen(&prob, lambda2, lambda1, &theta, lmax, arg, &col_norms);
        let sol = solve_nonneg(
            &prob,
            lambda2,
            None,
            &NonnegOptions { tol: 1e-11, ..Default::default() },
        );
        for j in 0..p {
            if !out.feature_kept[j] {
                assert!(
                    sol.beta[j].abs() < 1e-4,
                    "seed {}: feature {j} screened but β={}",
                    3000 + seed,
                    sol.beta[j]
                );
            }
        }
    }
}

/// Theorem 8 equivalences on the paper's own synthetic recipe.
#[test]
fn theorem8_equivalences_on_synthetic() {
    for (spec, seed) in [
        (SyntheticSpec::synthetic1_scaled(30, 120, 12), 1u64),
        (SyntheticSpec::synthetic2_scaled(30, 120, 12), 2u64),
    ] {
        assert!(matches!(
            spec.correlation,
            Correlation::Iid | Correlation::Ar(_)
        ));
        let ds = generate_synthetic(&spec, seed);
        let prob = SglProblem::new(&ds.x, &ds.y, &ds.groups);
        for alpha in [0.3, 1.0, 2.5] {
            let lmax = sgl_lambda_max(&prob, alpha);
            let opts = FistaOptions { tol: 1e-10, ..Default::default() };
            // (iv) ⇒ (iii): λ ≥ λmax gives β* = 0.
            let above = solve_fista(
                &prob,
                &SglParams::from_alpha_lambda(alpha, lmax.lambda_max * 1.01),
                None,
                &opts,
            );
            assert!(above.beta.iter().all(|&b| b == 0.0));
            // ¬(iv) ⇒ ¬(iii): λ < λmax gives β* ≠ 0.
            let below = solve_fista(
                &prob,
                &SglParams::from_alpha_lambda(alpha, lmax.lambda_max * 0.95),
                None,
                &opts,
            );
            assert!(below.beta.iter().any(|&b| b != 0.0), "α={alpha}");
        }
    }
}
