//! Checkpoint/resume fault tolerance: a path run stopped mid-grid and
//! resumed from its sidecar must be **bitwise identical** — per-step stats
//! and per-λ coefficient vectors both — to the run never having been
//! interrupted. Exercised on the dense and mmap backends, both solvers,
//! with the amortized Lipschitz refresher on (its `since`/mask/value state
//! is part of the snapshot, so a resume that dropped it would change
//! step sizes bit-for-bit detectably). Stop points cover both the
//! checkpoint-boundary case (nothing to recompute on resume) and the
//! mid-cadence case (the steps since the last save are recomputed).
//!
//! These run under the CI `TLFRE_THREADS` ∈ {1,2,4,8} matrix: the resumed
//! path must agree with the uninterrupted one at every worker count.

#![cfg(not(miri))] // real dataset + sidecar files

use tlfre::coordinator::{
    run_tlfre_path_checkpointed, run_tlfre_path_with_coefficients, CheckpointOptions, PathConfig,
    PathOutput, SolveControls, SolverKind,
};
use tlfre::data::synthetic::{generate_synthetic, SyntheticSpec};
use tlfre::screening::ScreenKind;

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("tlfre-ckresume-{name}-{}.bin", std::process::id()))
}

fn cfg(solver: SolverKind) -> PathConfig {
    PathConfig {
        alpha: 1.0,
        solver,
        screen: ScreenKind::TlfreGap,
        controls: SolveControls {
            n_lambda: 12,
            lambda_min_ratio: 0.05,
            tol: 1e-7,
            // Stateful across steps — the part of the engine a naive resume
            // would silently lose.
            lipschitz_refresh_every: Some(2),
            ..Default::default()
        },
        ..Default::default()
    }
}

/// Both stats and coefficients must agree bit for bit.
fn assert_bitwise_equal(
    (oa, ca): (&PathOutput, &[Vec<f32>]),
    (ob, cb): (&PathOutput, &[Vec<f32>]),
    tag: &str,
) {
    assert_eq!(oa.lambda_max.to_bits(), ob.lambda_max.to_bits(), "{tag}: λmax");
    assert_eq!(oa.steps.len(), ob.steps.len(), "{tag}: step counts");
    for (sa, sb) in oa.steps.iter().zip(&ob.steps) {
        assert_eq!(sa.lambda.to_bits(), sb.lambda.to_bits(), "{tag}: λ grid");
        assert_eq!(sa.r1.to_bits(), sb.r1.to_bits(), "{tag}: r1 at λ={}", sa.lambda);
        assert_eq!(sa.r2.to_bits(), sb.r2.to_bits(), "{tag}: r2 at λ={}", sa.lambda);
        assert_eq!(sa.active_features, sb.active_features, "{tag}: active at λ={}", sa.lambda);
        assert_eq!(sa.iters, sb.iters, "{tag}: iters at λ={}", sa.lambda);
        assert_eq!(sa.gap.to_bits(), sb.gap.to_bits(), "{tag}: gap at λ={}", sa.lambda);
        assert_eq!(sa.zeros, sb.zeros, "{tag}: zeros at λ={}", sa.lambda);
        assert_eq!(sa.nonzeros, sb.nonzeros, "{tag}: nonzeros at λ={}", sa.lambda);
        assert_eq!(sa.budget_exhausted, sb.budget_exhausted, "{tag}: budget flag");
        assert_eq!(
            sa.certified_suboptimality.to_bits(),
            sb.certified_suboptimality.to_bits(),
            "{tag}: certified bound at λ={}",
            sa.lambda
        );
    }
    assert_eq!(ca.len(), cb.len(), "{tag}: coefficient path lengths");
    for (k, (ba, bb)) in ca.iter().zip(cb).enumerate() {
        assert_eq!(ba.len(), bb.len(), "{tag}: β dims at step {k}");
        for j in 0..ba.len() {
            assert_eq!(ba[j].to_bits(), bb[j].to_bits(), "{tag}: β[{j}] at step {k}");
        }
    }
}

/// Stop a checkpointed run after `stop_after` completed grid points, then
/// resume it from the sidecar and compare the stitched result against the
/// plain uninterrupted runner.
fn stop_resume_roundtrip<M: tlfre::linalg::DesignMatrix>(
    x: &M,
    y: &[f32],
    groups: &tlfre::groups::GroupStructure,
    pc: &PathConfig,
    every: usize,
    stop_after: usize,
    tag: &str,
) {
    let (ref_out, ref_coefs) = run_tlfre_path_with_coefficients(x, y, groups, pc);
    assert!(!ref_out.truncated);
    assert_eq!(ref_out.steps.len(), pc.n_lambda);

    let path = tmp(tag);
    let mut opts = CheckpointOptions::new(&path);
    opts.every = every;
    opts.stop_after = Some(stop_after);
    let (stopped, stopped_coefs) = run_tlfre_path_checkpointed(x, y, groups, pc, &opts).unwrap();
    assert!(stopped.truncated, "{tag}: stopped run must report truncation");
    assert_eq!(stopped.steps.len(), stop_after, "{tag}: stopped prefix length");
    // The stopped prefix itself is already bitwise equal to the reference.
    for (k, (sa, sb)) in stopped.steps.iter().zip(&ref_out.steps).enumerate() {
        assert_eq!(sa.lambda.to_bits(), sb.lambda.to_bits(), "{tag}: prefix λ at {k}");
        assert_eq!(sa.gap.to_bits(), sb.gap.to_bits(), "{tag}: prefix gap at {k}");
    }
    for (k, (ba, bb)) in stopped_coefs.iter().zip(&ref_coefs).enumerate() {
        for j in 0..ba.len() {
            assert_eq!(ba[j].to_bits(), bb[j].to_bits(), "{tag}: prefix β[{j}] at step {k}");
        }
    }

    let mut resume = CheckpointOptions::new(&path);
    resume.every = every;
    resume.resume = true;
    let (resumed, resumed_coefs) = run_tlfre_path_checkpointed(x, y, groups, pc, &resume).unwrap();
    assert!(!resumed.truncated, "{tag}: resumed run completes the grid");
    assert_bitwise_equal(
        (&resumed, &resumed_coefs),
        (&ref_out, &ref_coefs),
        &format!("{tag} resume"),
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn dense_fista_resume_is_bitwise_identical() {
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(40, 400, 40), 2014);
    let pc = cfg(SolverKind::Fista);
    // 5 is mid-cadence for every=2 (the 5th step is recomputed on resume);
    // 4 is exactly a save boundary (resume recomputes nothing).
    for (stop_after, tag) in [(5usize, "dense-fista-mid"), (4, "dense-fista-boundary")] {
        stop_resume_roundtrip(&ds.x, &ds.y, &ds.groups, &pc, 2, stop_after, tag);
    }
}

#[test]
fn dense_bcd_resume_is_bitwise_identical() {
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(40, 400, 40), 2015);
    let pc = cfg(SolverKind::Bcd);
    stop_resume_roundtrip(&ds.x, &ds.y, &ds.groups, &pc, 3, 7, "dense-bcd-mid");
}

#[test]
fn mmap_resume_is_bitwise_identical_to_dense_uninterrupted() {
    // Out-of-core variant: the checkpointed/resumed run on the mmap-backed
    // matrix must reproduce the *dense in-RAM* uninterrupted path bit for
    // bit — resume safety and backend parity in one assertion.
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(40, 400, 40), 2016);
    let data = tmp("mmap-dataset");
    tlfre::data::io::save(&ds, &data).unwrap();
    let mds = tlfre::data::io::open_mmap(&data).unwrap();
    let pc = cfg(SolverKind::Fista);

    let (ref_out, ref_coefs) = run_tlfre_path_with_coefficients(&ds.x, &ds.y, &ds.groups, &pc);

    let ck = tmp("mmap-sidecar");
    let mut opts = CheckpointOptions::new(&ck);
    opts.every = 2;
    opts.stop_after = Some(5);
    let (stopped, _) =
        run_tlfre_path_checkpointed(&mds.x, &mds.y, &mds.groups, &pc, &opts).unwrap();
    assert!(stopped.truncated);

    let mut resume = CheckpointOptions::new(&ck);
    resume.every = 2;
    resume.resume = true;
    let (resumed, resumed_coefs) =
        run_tlfre_path_checkpointed(&mds.x, &mds.y, &mds.groups, &pc, &resume).unwrap();
    assert_bitwise_equal((&resumed, &resumed_coefs), (&ref_out, &ref_coefs), "mmap resume");

    drop(mds);
    let _ = std::fs::remove_file(&data);
    let _ = std::fs::remove_file(&ck);
}

#[test]
fn max_seconds_budget_truncates_to_a_clean_prefix() {
    // A microscopic wall-clock budget: the driver must stop the grid walk
    // at a step boundary, mark the output truncated, and any step that ran
    // out mid-solve must carry `converged`-failure markers with a
    // *certified* (finite, non-negative) suboptimality bound. With ~50 μs
    // the preamble alone blows the budget, so only the analytic λmax step
    // is guaranteed; the invariants below hold for whatever prefix ran.
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 200, 20), 77);
    let pc = PathConfig {
        alpha: 1.0,
        controls: SolveControls {
            n_lambda: 40,
            lambda_min_ratio: 0.01,
            tol: 1e-9,
            max_seconds: Some(50e-6),
            ..Default::default()
        },
        ..Default::default()
    };
    let out = tlfre::coordinator::run_tlfre_path(&ds.x, &ds.y, &ds.groups, &pc);
    assert!(out.truncated, "50 μs cannot fit a 40-point path");
    assert!(!out.steps.is_empty(), "the λmax step is analytic and always emitted");
    assert!(out.steps.len() < 40);
    for st in &out.steps {
        assert!(
            st.certified_suboptimality >= 0.0,
            "certified bound must be non-negative, got {}",
            st.certified_suboptimality
        );
        if st.budget_exhausted {
            assert!(
                st.certified_suboptimality.is_finite(),
                "an exhausted step still certifies a finite gap bound"
            );
        }
    }

    // No budget ⇒ no truncation, and no step reports exhaustion.
    let pc_free = {
        let mut c = pc;
        c.max_seconds = None;
        c.n_lambda = 8;
        c.tol = 1e-6;
        c
    };
    let free = tlfre::coordinator::run_tlfre_path(&ds.x, &ds.y, &ds.groups, &pc_free);
    assert!(!free.truncated);
    assert!(free.steps.iter().all(|s| !s.budget_exhausted));
}
