//! Fault-injection harness (compiled only under `--features fault-inject`):
//! every injected fault must surface as a typed error, a propagated panic,
//! or a documented degradation — never silent garbage.
//!
//! The hooks are process-global countdown counters
//! ([`tlfre::util::fault`]), so tests serialize on a private mutex and
//! disarm everything on exit. The mmap positioned-read faults (short
//! reads, `EINTR`, hard errors) are exercised by the in-crate unit tests
//! next to the instrumented fallback path (`linalg::mmap`); this file
//! covers the pool-dispatch and solver-residual fault points through the
//! public API.

#![cfg(feature = "fault-inject")]

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Mutex;

use tlfre::coordinator::{run_tlfre_path, PathConfig, SolveControls};
use tlfre::data::synthetic::{generate_synthetic, SyntheticSpec};
use tlfre::screening::lambda_max::sgl_lambda_max;
use tlfre::sgl::{solve_fista, FistaOptions, SglParams, SglProblem};
use tlfre::util::fault;

/// The fault counters are process-global; never run two armed tests at
/// once. `cargo test` threads within this binary all funnel through here.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// Hold the lock even if a previous test panicked while armed.
fn lock() -> std::sync::MutexGuard<'static, ()> {
    FAULT_LOCK.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[test]
fn injected_pool_panic_propagates_and_pool_survives() {
    let _g = lock();
    fault::reset();
    if tlfre::util::pool::num_threads() < 2 {
        // TLFRE_THREADS=1 disables the pool; the dispatch fault point is
        // unreachable (the serial loop runs the closure directly). The
        // propagation machinery itself is covered at explicit worker
        // counts by the pool's own unit tests.
        return;
    }

    fault::arm_pool_panic(2);
    let result = catch_unwind(AssertUnwindSafe(|| {
        let mut out = vec![0usize; 1024];
        tlfre::util::pool::parallel_fill_with_workers(&mut out, 4, |i| i * 3);
        out
    }));
    assert!(result.is_err(), "the injected task panic must reach the dispatching thread");
    fault::reset();

    // The pool must survive a panicked round: the next dispatch runs to
    // completion with correct contents.
    let mut out = vec![0usize; 1024];
    tlfre::util::pool::parallel_fill_with_workers(&mut out, 4, |i| i * 3);
    assert!(out.iter().enumerate().all(|(i, &v)| v == i * 3));
}

#[test]
fn poisoned_residual_stops_the_solve_without_silent_garbage() {
    let _g = lock();
    fault::reset();

    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(25, 120, 12), 31);
    let prob = SglProblem::new(&ds.x, &ds.y, &ds.groups);
    let lm = sgl_lambda_max(&prob, 1.0);
    let params = SglParams::from_alpha_lambda(1.0, 0.3 * lm.lambda_max);
    let opts = FistaOptions::default();

    // Poison the first residual evaluation: the gap check sees NaN, can
    // never satisfy the stopping rule, and must abort the solve instead of
    // spinning to the iteration cap.
    fault::arm_nan_poison(1);
    let res = solve_fista(&prob, &params, None, &opts);
    fault::reset();
    assert!(!res.converged, "a poisoned solve must not claim convergence");
    assert!(!res.gap.is_finite(), "the non-finite gap is surfaced, got {}", res.gap);
    assert!(
        res.iters < opts.max_iter,
        "the solve aborts at the poisoned check, not the iteration cap"
    );
    assert!(
        res.beta.iter().all(|b| b.is_finite()),
        "β is the best completed iterate, not the poisoned evaluation"
    );

    // Disarmed, the identical solve converges — the abort above came from
    // the injection, not the problem.
    let clean = solve_fista(&prob, &params, None, &opts);
    assert!(clean.converged, "gap={}", clean.gap);
}

#[test]
fn poisoned_step_in_a_path_is_contained_to_its_step() {
    let _g = lock();
    fault::reset();

    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(25, 120, 12), 32);
    let pc = PathConfig {
        alpha: 1.0,
        controls: SolveControls {
            n_lambda: 8,
            lambda_min_ratio: 0.05,
            tol: 1e-6,
            ..Default::default()
        },
        ..Default::default()
    };
    let clean = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &pc);
    assert!(clean.steps.iter().all(|s| s.gap.is_finite()));

    // Poison one residual evaluation somewhere mid-path: the path must
    // still complete every grid point (warm starts are the last *good*
    // iterate), and the poisoned step must advertise its non-finite gap as
    // an infinite certified bound rather than a silently-wrong model.
    fault::arm_nan_poison(3);
    let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &pc);
    fault::reset();
    assert_eq!(out.steps.len(), pc.n_lambda, "the path completes despite the poisoned step");
    assert!(!out.truncated);
    let poisoned: Vec<usize> = out
        .steps
        .iter()
        .enumerate()
        .filter(|(_, s)| !s.gap.is_finite())
        .map(|(k, _)| k)
        .collect();
    assert!(!poisoned.is_empty(), "the injected NaN must be visible in some step's gap");
    for &k in &poisoned {
        assert!(
            out.steps[k].certified_suboptimality.is_infinite(),
            "a non-finite gap certifies nothing — the bound must be +∞, got {}",
            out.steps[k].certified_suboptimality
        );
    }
    // Steps before the poisoned one match the clean run bit for bit (the
    // injection stream is deterministic and strictly later).
    let first = poisoned[0];
    for k in 0..first {
        assert_eq!(out.steps[k].gap.to_bits(), clean.steps[k].gap.to_bits(), "step {k}");
    }
}
