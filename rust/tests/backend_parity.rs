//! Backend parity: the dense, CSC and screened-view backends must agree.
//!
//! * kernel parity — `matvec`, `matvec_t`, `matvec_t_subset`, `col_norms`
//!   agree between dense and CSC to f32 accumulation tolerance on random
//!   matrices (several shapes/densities);
//! * screening parity — TLFre outcomes computed over the CSC backend match
//!   the dense backend (identical masks up to borderline-margin cases, and
//!   both are *safe* against a tight reference solve);
//! * view-vs-copy equivalence — a full TLFre path solved on zero-copy
//!   [`ScreenedView`] reduced problems is **bitwise identical** (per-step
//!   r₁/r₂, sparsity, iteration counts) to the same path solved on
//!   materialized gathered copies (the seed behaviour);
//! * pool parity — the persistent worker pool's `matvec_t` sweep is
//!   bitwise identical to the serial sweep and to the legacy per-call
//!   `std::thread::scope` implementation at multiple worker counts;
//! * out-of-core parity — whole TLFre and DPC paths on the mmap-backed
//!   and row-sharded backends are **bitwise identical** (per-step stats
//!   AND per-λ coefficient vectors) to the in-RAM dense backend, and the
//!   streaming λmax / blocked column norms equal the in-RAM values bit
//!   for bit. These run under the CI `TLFRE_THREADS` ∈ {1,2,4,8} matrix.

#![cfg(not(miri))] // real temp files (mmap backend)

use tlfre::coordinator::{
    path_coefficients, run_dpc_path, run_tlfre_path, DpcPathConfig, PathConfig, SolveControls,
};
use tlfre::data::synthetic::{
    generate_sparse_synthetic, generate_synthetic, SparseSyntheticSpec, SyntheticSpec,
};
use tlfre::linalg::{col_norms_blocked, CscMatrix, DenseMatrix, DesignMatrix, ScreenedView, ShardedMatrix};
use tlfre::screening::lambda_max::sgl_lambda_max;
use tlfre::screening::tlfre::{tlfre_screen, TlfreContext};
use tlfre::sgl::{solve_fista, FistaOptions, SglParams, SglProblem};
use tlfre::util::Rng;

fn random_sparse_dense(n: usize, p: usize, density: f64, seed: u64) -> DenseMatrix {
    let mut rng = Rng::seed_from_u64(seed);
    DenseMatrix::from_fn(n, p, |_, _| {
        if rng.uniform_range(0.0, 1.0) < density {
            rng.gaussian() as f32
        } else {
            0.0
        }
    })
}

#[test]
fn dense_csc_kernel_parity() {
    for (n, p, density, seed) in [
        (17usize, 29usize, 1.0f64, 1u64),
        (40, 120, 0.3, 2),
        (64, 200, 0.05, 3),
        (8, 5, 0.5, 4),
    ] {
        let d = random_sparse_dense(n, p, density, seed);
        let s = CscMatrix::from_dense(&d);
        let mut rng = Rng::seed_from_u64(seed ^ 0xFF);
        let v: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
        let beta: Vec<f32> = (0..p)
            .map(|_| if rng.below(3) == 0 { rng.gaussian() as f32 } else { 0.0 })
            .collect();

        // matvec
        let mut md = vec![0.0f32; n];
        let mut ms = vec![0.0f32; n];
        d.matvec(&beta, &mut md);
        DesignMatrix::matvec(&s, &beta, &mut ms);
        for i in 0..n {
            let tol = 1e-4 * (1.0 + md[i].abs());
            assert!((md[i] - ms[i]).abs() < tol, "matvec[{i}] {} vs {}", md[i], ms[i]);
        }

        // matvec_t
        let mut td = vec![0.0f32; p];
        let mut ts = vec![0.0f32; p];
        d.matvec_t(&v, &mut td);
        DesignMatrix::matvec_t(&s, &v, &mut ts);
        for j in 0..p {
            let tol = 1e-4 * (1.0 + td[j].abs());
            assert!((td[j] - ts[j]).abs() < tol, "matvec_t[{j}] {} vs {}", td[j], ts[j]);
        }

        // matvec_t_subset
        let idx: Vec<usize> = (0..p).step_by(3).collect();
        let mut sd = vec![0.0f32; idx.len()];
        let mut ss = vec![0.0f32; idx.len()];
        d.matvec_t_subset(&v, &idx, &mut sd);
        DesignMatrix::matvec_t_subset(&s, &v, &idx, &mut ss);
        for k in 0..idx.len() {
            assert!((sd[k] - ss[k]).abs() < 1e-4 * (1.0 + sd[k].abs()), "subset[{k}]");
        }

        // col_norms (f64 accumulation on both sides — tight tolerance)
        let nd = d.col_norms();
        let ns = DesignMatrix::col_norms(&s);
        for j in 0..p {
            assert!((nd[j] - ns[j]).abs() < 1e-9 * (1.0 + nd[j]), "col_norms[{j}]");
        }
    }
}

#[test]
fn persistent_pool_matvec_t_bitwise_matches_serial_and_scoped() {
    // The acceptance-criterion test for the spawn-free pool: the Xᵀv sweep
    // dispatched through the persistent pool must be bitwise identical to
    // the serial sweep AND to the legacy per-call `std::thread::scope`
    // implementation, at several worker counts, on dense and CSC backends.
    let d = random_sparse_dense(48, 311, 0.6, 9);
    let s = CscMatrix::from_dense(&d);
    let mut rng = Rng::seed_from_u64(0x900);
    let v: Vec<f32> = (0..48).map(|_| rng.gaussian() as f32).collect();

    let p = d.cols();
    let mut serial_d = vec![0.0f32; p];
    let mut serial_s = vec![0.0f32; p];
    for j in 0..p {
        serial_d[j] = d.col_dot(j, &v);
        serial_s[j] = DesignMatrix::col_dot(&s, j, &v);
    }

    for workers in [2usize, 3, 4, 8] {
        let mut pool_d = vec![0.0f32; p];
        tlfre::util::pool::parallel_fill_with_workers(&mut pool_d, workers, |j| d.col_dot(j, &v));
        let mut scoped_d = vec![0.0f32; p];
        tlfre::util::pool::scoped_fill_with_workers(&mut scoped_d, workers, |j| d.col_dot(j, &v));
        for j in 0..p {
            assert_eq!(
                pool_d[j].to_bits(),
                serial_d[j].to_bits(),
                "dense pool≠serial at col {j}, workers={workers}"
            );
            assert_eq!(
                pool_d[j].to_bits(),
                scoped_d[j].to_bits(),
                "dense pool≠scoped at col {j}, workers={workers}"
            );
        }

        let mut pool_s = vec![0.0f32; p];
        tlfre::util::pool::parallel_fill_with_workers(&mut pool_s, workers, |j| {
            DesignMatrix::col_dot(&s, j, &v)
        });
        let mut scoped_s = vec![0.0f32; p];
        tlfre::util::pool::scoped_fill_with_workers(&mut scoped_s, workers, |j| {
            DesignMatrix::col_dot(&s, j, &v)
        });
        for j in 0..p {
            assert_eq!(
                pool_s[j].to_bits(),
                serial_s[j].to_bits(),
                "csc pool≠serial at col {j}, workers={workers}"
            );
            assert_eq!(
                pool_s[j].to_bits(),
                scoped_s[j].to_bits(),
                "csc pool≠scoped at col {j}, workers={workers}"
            );
        }
    }

    // The production entry point (trait matvec_t → parallel_fill with the
    // process worker count) agrees too — on a matrix big enough that
    // rows·cols ≥ PAR_MIN_WORK, so the pooled branch actually runs when
    // the process has >1 worker (the small matrix above stays serial).
    let big = random_sparse_dense(96, 2800, 0.3, 10);
    assert!(
        96 * 2800 >= tlfre::linalg::traits::PAR_MIN_WORK,
        "test matrix no longer crosses the parallel-dispatch threshold"
    );
    let vb: Vec<f32> = (0..96).map(|_| rng.gaussian() as f32).collect();
    let mut serial_big = vec![0.0f32; 2800];
    for (j, o) in serial_big.iter_mut().enumerate() {
        *o = big.col_dot(j, &vb);
    }
    let mut trait_big = vec![0.0f32; 2800];
    big.matvec_t(&vb, &mut trait_big);
    for j in 0..2800 {
        assert_eq!(
            trait_big[j].to_bits(),
            serial_big[j].to_bits(),
            "trait matvec_t≠serial at col {j} (pooled sweep)"
        );
    }
}

#[test]
fn row_blocked_matvec_bitwise_matches_serial_at_all_worker_counts() {
    // The acceptance-criterion test for the row-blocked forward sweep: the
    // Xβ accumulation dispatched over row chunks must be bitwise identical
    // to the serial column-order loop, at several worker counts, on dense,
    // CSC and view backends — and through all three trait entry points
    // (matvec, residual_matvec, residual), which share one accumulation
    // core and differ only in the output's initialization.
    let d = random_sparse_dense(53, 90, 0.4, 21);
    let s = CscMatrix::from_dense(&d);
    let keep: Vec<usize> = (0..90).filter(|j| j % 4 != 1).collect();
    let view = ScreenedView::new(&s, keep.clone());
    let mut rng = Rng::seed_from_u64(0xA11);
    let beta: Vec<f32> = (0..90)
        .map(|_| if rng.below(3) != 0 { rng.gaussian() as f32 } else { 0.0 })
        .collect();
    let beta_view: Vec<f32> = keep.iter().map(|&j| beta[j]).collect();
    let y: Vec<f32> = (0..53).map(|_| rng.gaussian() as f32).collect();

    // matvec: explicit worker counts against the serial reference.
    let mut serial_d = vec![0.0f32; 53];
    d.matvec_serial(&beta, &mut serial_d);
    let mut serial_s = vec![0.0f32; 53];
    s.matvec_serial(&beta, &mut serial_s);
    let mut serial_v = vec![0.0f32; 53];
    view.matvec_serial(&beta_view, &mut serial_v);
    for workers in [1usize, 2, 3, 4, 8] {
        let mut out = vec![0.0f32; 53];
        d.matvec_with_workers(&beta, &mut out, workers);
        assert!(
            out.iter().zip(&serial_d).all(|(a, b)| a.to_bits() == b.to_bits()),
            "dense matvec workers={workers}"
        );
        s.matvec_with_workers(&beta, &mut out, workers);
        assert!(
            out.iter().zip(&serial_s).all(|(a, b)| a.to_bits() == b.to_bits()),
            "csc matvec workers={workers}"
        );
        view.matvec_with_workers(&beta_view, &mut out, workers);
        assert!(
            out.iter().zip(&serial_v).all(|(a, b)| a.to_bits() == b.to_bits()),
            "view matvec workers={workers}"
        );
    }

    // residual / residual_matvec: the production entry points (worker
    // count = TLFRE_THREADS, exercised at 1/2/4/8 by the CI matrix)
    // against serial recomputations of the same fused form.
    let mut want = vec![0.0f32; 53];
    for (o, &yi) in want.iter_mut().zip(&y) {
        *o = -yi;
    }
    for (j, &bj) in beta.iter().enumerate() {
        if bj != 0.0 {
            d.col_axpy(j, bj, &mut want);
        }
    }
    let mut got = vec![0.0f32; 53];
    d.residual_matvec(&beta, &y, &mut got);
    assert!(got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()), "residual_matvec");

    want.copy_from_slice(&y);
    for (j, &bj) in beta.iter().enumerate() {
        if bj != 0.0 {
            s.col_axpy(j, -bj, &mut want);
        }
    }
    DesignMatrix::residual(&s, &beta, &y, &mut got);
    assert!(got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()), "residual");

    // Production matvec on a matrix big enough to cross the dispatch
    // threshold, so the pooled branch actually runs when TLFRE_THREADS > 1.
    let big = random_sparse_dense(640, 1200, 0.8, 22);
    assert!(
        640 * 1200 >= tlfre::linalg::traits::PAR_MIN_WORK,
        "test matrix no longer crosses the parallel-dispatch threshold"
    );
    let beta_big: Vec<f32> = (0..1200).map(|_| rng.gaussian() as f32).collect();
    let mut serial_big = vec![0.0f32; 640];
    big.matvec_serial(&beta_big, &mut serial_big);
    let mut par_big = vec![0.0f32; 640];
    big.matvec(&beta_big, &mut par_big);
    for i in 0..640 {
        assert_eq!(
            par_big[i].to_bits(),
            serial_big[i].to_bits(),
            "trait matvec≠serial at row {i} (pooled row-blocked sweep)"
        );
    }
}

#[test]
fn colored_bcd_path_bitwise_matches_sequential_bcd_path() {
    // Whole-path A/B over the CSC backend: `parallel_bcd_groups` must not
    // move a single bit of any per-step statistic relative to the
    // sequential sweep, at any worker count (the CI TLFRE_THREADS matrix
    // covers 1/2/4/8). On this random sparse design most groups conflict,
    // so the schedule is near-sequential — the group-level parallel
    // machinery itself is exercised by the paired-block cases in
    // sgl/bcd.rs and sgl/coloring.rs; this test pins the end-to-end
    // runner plumbing (path-level coloring cache + per-λ projection).
    let spec = SparseSyntheticSpec::new(30, 200, 20, 0.1);
    let ds = generate_sparse_synthetic(&spec, 424);
    let base = PathConfig {
        alpha: 1.0,
        solver: tlfre::coordinator::SolverKind::Bcd,
        controls: SolveControls {
            n_lambda: 10,
            lambda_min_ratio: 0.05,
            tol: 1e-7,
            ..Default::default()
        },
        ..Default::default()
    };
    let seq = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &base);
    let par_cfg = PathConfig { parallel_bcd_groups: true, ..base };
    let par = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &par_cfg);
    assert_eq!(seq.steps.len(), par.steps.len());
    for (ss, sp) in seq.steps.iter().zip(&par.steps) {
        assert_eq!(ss.lambda.to_bits(), sp.lambda.to_bits(), "λ grids diverged");
        assert_eq!(ss.r1.to_bits(), sp.r1.to_bits(), "r1 diverged at λ={}", ss.lambda);
        assert_eq!(ss.r2.to_bits(), sp.r2.to_bits(), "r2 diverged at λ={}", ss.lambda);
        assert_eq!(ss.zeros, sp.zeros, "zeros diverged at λ={}", ss.lambda);
        assert_eq!(ss.iters, sp.iters, "sweep counts diverged at λ={}", ss.lambda);
        assert_eq!(ss.gap.to_bits(), sp.gap.to_bits(), "gap diverged at λ={}", ss.lambda);
    }
}

#[test]
fn dense_csc_screening_parity_and_safety() {
    // Same numerical inputs through both backends: outcomes must agree up
    // to borderline f32-margin cases, and every rejection must be safe.
    let spec = SparseSyntheticSpec::new(30, 200, 20, 0.2);
    let ds = generate_sparse_synthetic(&spec, 77);
    let xd = ds.x.to_dense();

    let alpha = 1.0;
    let pd = SglProblem::new(&xd, &ds.y, &ds.groups);
    let ps = SglProblem::new(&ds.x, &ds.y, &ds.groups);

    let lmd = sgl_lambda_max(&pd, alpha);
    let lms = sgl_lambda_max(&ps, alpha);
    assert!(
        (lmd.lambda_max - lms.lambda_max).abs() < 1e-6 * lmd.lambda_max,
        "λmax dense {} vs csc {}",
        lmd.lambda_max,
        lms.lambda_max
    );

    let ctxd = TlfreContext::precompute(&pd);
    let ctxs = TlfreContext::precompute(&ps);

    let theta: Vec<f32> =
        ds.y.iter().map(|&v| (v as f64 / lmd.lambda_max) as f32).collect();
    let lambda = 0.8 * lmd.lambda_max;
    let od = tlfre_screen(&pd, alpha, lambda, lmd.lambda_max, &theta, &lmd, &ctxd);
    let os = tlfre_screen(&ps, alpha, lambda, lms.lambda_max, &theta, &lms, &ctxs);

    // Masks agree except possibly at f32-borderline margins: allow a tiny
    // disagreement budget, and require the bulk to match exactly.
    let p = pd.n_features();
    let diffs = (0..p).filter(|&j| od.feature_kept[j] != os.feature_kept[j]).count();
    assert!(diffs <= p / 50, "{diffs} of {p} screening decisions differ");
    assert!(od.total_rejected() > 0, "dense rejected nothing");
    assert!(os.total_rejected() > 0, "csc rejected nothing");

    // Safety of BOTH outcomes against a tight dense reference solve.
    let params = SglParams::from_alpha_lambda(alpha, lambda);
    let sol = solve_fista(&pd, &params, None, &FistaOptions { tol: 1e-10, ..Default::default() });
    for j in 0..p {
        for (name, out) in [("dense", &od), ("csc", &os)] {
            if !out.feature_kept[j] {
                assert!(
                    sol.beta[j].abs() < 1e-5,
                    "{name}: feature {j} screened but β={}",
                    sol.beta[j]
                );
            }
        }
    }
}

#[test]
fn csc_end_to_end_path_matches_dense() {
    // Full TLFre-screened λ-path over the CSC backend vs the dense backend:
    // sparsity trajectories must agree closely (identical data, f32
    // accumulation-order differences only).
    let spec = SparseSyntheticSpec::new(30, 200, 20, 0.1);
    let ds = generate_sparse_synthetic(&spec, 99);
    let xd = ds.x.to_dense();
    let cfg = PathConfig {
        alpha: 1.0,
        controls: SolveControls {
            n_lambda: 10,
            lambda_min_ratio: 0.05,
            tol: 1e-7,
            ..Default::default()
        },
        ..Default::default()
    };
    let a = run_tlfre_path(&xd, &ds.y, &ds.groups, &cfg);
    let b = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
    assert_eq!(a.steps.len(), b.steps.len());
    for (sa, sb) in a.steps.iter().zip(&b.steps) {
        assert!((sa.lambda - sb.lambda).abs() < 1e-9 * sa.lambda.max(1e-300));
        let diff = (sa.nonzeros as i64 - sb.nonzeros as i64).abs();
        assert!(diff <= 2, "λ={}: nnz {} vs {}", sa.lambda, sa.nonzeros, sb.nonzeros);
    }
    assert!(b.mean_total_rejection() > 0.3, "csc path rejection {}", b.mean_total_rejection());
}

#[test]
fn screened_view_path_bitwise_matches_gathered_copy_path() {
    // The acceptance-criterion test: the zero-copy ScreenedView path must
    // produce bitwise-identical per-step statistics (r₁, r₂ as f64, exact
    // sparsity, iteration counts, duality gaps) to the gathered-copy path
    // on the Table-1 synthetic config.
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(40, 400, 40), 2014);
    let base = PathConfig {
        alpha: 1.0,
        controls: SolveControls {
            n_lambda: 15,
            lambda_min_ratio: 0.05,
            tol: 1e-7,
            ..Default::default()
        },
        ..Default::default()
    };
    let view_path = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &base);
    let copy_cfg = PathConfig { materialize_reduced: true, ..base };
    let copy_path = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &copy_cfg);

    assert_eq!(view_path.steps.len(), copy_path.steps.len());
    for (sv, sc) in view_path.steps.iter().zip(&copy_path.steps) {
        assert_eq!(sv.lambda.to_bits(), sc.lambda.to_bits(), "λ grids diverged");
        assert_eq!(sv.r1.to_bits(), sc.r1.to_bits(), "r1 not bitwise equal at λ={}", sv.lambda);
        assert_eq!(sv.r2.to_bits(), sc.r2.to_bits(), "r2 not bitwise equal at λ={}", sv.lambda);
        assert_eq!(sv.zeros, sc.zeros, "zeros differ at λ={}", sv.lambda);
        assert_eq!(sv.nonzeros, sc.nonzeros, "nonzeros differ at λ={}", sv.lambda);
        assert_eq!(sv.active_features, sc.active_features, "active differ at λ={}", sv.lambda);
        assert_eq!(sv.iters, sc.iters, "solver iters differ at λ={}", sv.lambda);
        assert_eq!(sv.gap.to_bits(), sc.gap.to_bits(), "gap not bitwise equal at λ={}", sv.lambda);
    }
}

/// Per-step statistics of two TLFre paths must agree bit for bit.
fn assert_paths_bitwise_equal(
    a: &tlfre::coordinator::PathOutput,
    b: &tlfre::coordinator::PathOutput,
    tag: &str,
) {
    assert_eq!(a.lambda_max.to_bits(), b.lambda_max.to_bits(), "{tag}: λmax diverged");
    assert_eq!(a.steps.len(), b.steps.len(), "{tag}: step counts diverged");
    for (sa, sb) in a.steps.iter().zip(&b.steps) {
        assert_eq!(sa.lambda.to_bits(), sb.lambda.to_bits(), "{tag}: λ grids diverged");
        assert_eq!(sa.r1.to_bits(), sb.r1.to_bits(), "{tag}: r1 at λ={}", sa.lambda);
        assert_eq!(sa.r2.to_bits(), sb.r2.to_bits(), "{tag}: r2 at λ={}", sa.lambda);
        assert_eq!(sa.zeros, sb.zeros, "{tag}: zeros at λ={}", sa.lambda);
        assert_eq!(sa.nonzeros, sb.nonzeros, "{tag}: nonzeros at λ={}", sa.lambda);
        assert_eq!(sa.active_features, sb.active_features, "{tag}: active at λ={}", sa.lambda);
        assert_eq!(sa.iters, sb.iters, "{tag}: iters at λ={}", sa.lambda);
        assert_eq!(sa.gap.to_bits(), sb.gap.to_bits(), "{tag}: gap at λ={}", sa.lambda);
    }
}

/// Per-λ coefficient vectors from [`path_coefficients`] must agree bit
/// for bit.
fn assert_coefficients_bitwise_equal(a: &[Vec<f32>], b: &[Vec<f32>], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: path lengths diverged");
    for (k, (ca, cb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ca.len(), cb.len(), "{tag}: β dims at step {k}");
        for j in 0..ca.len() {
            assert_eq!(
                ca[j].to_bits(),
                cb[j].to_bits(),
                "{tag}: β[{j}] at step {k}: {} vs {}",
                ca[j],
                cb[j]
            );
        }
    }
}

#[test]
fn mmap_backend_whole_path_bitwise_matches_dense() {
    // The tentpole acceptance test: save a dataset to TLFREDS1, map its X
    // payload from disk, and run the full TLFre-screened path on the
    // mmap-backed matrix. Every per-step statistic and every per-λ
    // coefficient must be bitwise identical to the in-RAM dense backend —
    // the mmap backend runs the same kernels over the same bytes.
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(40, 400, 40), 2014);
    let path = std::env::temp_dir().join(format!("tlfre-parity-mmap-{}.bin", std::process::id()));
    tlfre::data::io::save(&ds, &path).unwrap();
    let mds = tlfre::data::io::open_mmap(&path).unwrap();
    assert_eq!(mds.x.rows(), ds.x.rows());
    assert_eq!(mds.x.cols(), ds.x.cols());

    let cfg = PathConfig {
        alpha: 1.0,
        controls: SolveControls {
            n_lambda: 12,
            lambda_min_ratio: 0.05,
            tol: 1e-7,
            ..Default::default()
        },
        ..Default::default()
    };
    let dense = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
    let mapped = run_tlfre_path(&mds.x, &mds.y, &mds.groups, &cfg);
    assert_paths_bitwise_equal(&dense, &mapped, "mmap");

    let cd = path_coefficients(&ds.x, &ds.y, &ds.groups, &cfg);
    let cm = path_coefficients(&mds.x, &mds.y, &mds.groups, &cfg);
    assert_coefficients_bitwise_equal(&cd, &cm, "mmap");

    drop(mds);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn sharded_backend_whole_path_bitwise_matches_dense() {
    // Row-sharded composite over 1/2/3/5 shards (including shard counts
    // that do not divide n): per-step stats and per-λ coefficients must be
    // bitwise identical to the unsharded dense backend at every worker
    // count in the CI matrix.
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(40, 400, 40), 2014);
    let cfg = PathConfig {
        alpha: 1.0,
        controls: SolveControls {
            n_lambda: 12,
            lambda_min_ratio: 0.05,
            tol: 1e-7,
            ..Default::default()
        },
        ..Default::default()
    };
    let dense = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
    let cd = path_coefficients(&ds.x, &ds.y, &ds.groups, &cfg);
    for shards in [1usize, 2, 3, 5] {
        let sx = ShardedMatrix::from_dense(&ds.x, shards);
        let tag = format!("sharded×{shards}");
        let sp = run_tlfre_path(&sx, &ds.y, &ds.groups, &cfg);
        assert_paths_bitwise_equal(&dense, &sp, &tag);
        let cs = path_coefficients(&sx, &ds.y, &ds.groups, &cfg);
        assert_coefficients_bitwise_equal(&cd, &cs, &tag);
    }
}

#[test]
fn mmap_and_sharded_dpc_paths_bitwise_match_dense() {
    // Same contract for the nonnegative-Lasso DPC path: per-λ rejection,
    // support size and iteration counts move by zero bits across backends.
    let ds = generate_synthetic(&SyntheticSpec::synthetic2_scaled(30, 200, 20), 7);
    let cfg = DpcPathConfig {
        controls: SolveControls {
            n_lambda: 10,
            lambda_min_ratio: 0.05,
            tol: 1e-7,
            ..Default::default()
        },
        ..Default::default()
    };
    let dense = run_dpc_path(&ds.x, &ds.y, &cfg);

    let path = std::env::temp_dir().join(format!("tlfre-parity-dpc-{}.bin", std::process::id()));
    tlfre::data::io::save(&ds, &path).unwrap();
    let mds = tlfre::data::io::open_mmap(&path).unwrap();
    let mapped = run_dpc_path(&mds.x, &mds.y, &cfg);
    drop(mds);
    let _ = std::fs::remove_file(&path);

    let sx = ShardedMatrix::from_dense(&ds.x, 3);
    let sharded = run_dpc_path(&sx, &ds.y, &cfg);

    for (tag, other) in [("mmap", &mapped), ("sharded", &sharded)] {
        assert_eq!(dense.lambda_max.to_bits(), other.lambda_max.to_bits(), "{tag}: λmax");
        assert_eq!(dense.steps.len(), other.steps.len(), "{tag}: step counts");
        for (sa, sb) in dense.steps.iter().zip(&other.steps) {
            assert_eq!(sa.lambda.to_bits(), sb.lambda.to_bits(), "{tag}: λ grid");
            assert_eq!(sa.rejection.to_bits(), sb.rejection.to_bits(), "{tag}: rejection");
            assert_eq!(sa.active_features, sb.active_features, "{tag}: active");
            assert_eq!(sa.iters, sb.iters, "{tag}: iters");
            assert_eq!(sa.zeros, sb.zeros, "{tag}: zeros");
        }
    }
}

#[test]
fn streaming_lambda_max_and_blocked_norms_bitwise_match_in_ram() {
    // The streaming λmax visits X in column blocks and the blocked norm
    // sweep bounds resident pages; both must reproduce the in-RAM values
    // exactly (same per-column kernels, same fold order).
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(35, 300, 30), 11);
    let prob = SglProblem::new(&ds.x, &ds.y, &ds.groups);
    for alpha in [0.5, 1.0, 2.0] {
        let full = sgl_lambda_max(&prob, alpha);
        for block_groups in [1usize, 4, 7, 1000] {
            let st = tlfre::screening::sgl_lambda_max_streaming(&prob, alpha, block_groups);
            assert_eq!(
                full.lambda_max.to_bits(),
                st.lambda_max.to_bits(),
                "λmax α={alpha} blocks={block_groups}"
            );
            assert_eq!(full.argmax_group, st.argmax_group, "argmax α={alpha}");
        }
    }

    let full_norms = ds.x.col_norms();
    for block_cols in [1usize, 17, 64, 10_000] {
        let blocked = col_norms_blocked(&ds.x, block_cols);
        assert_eq!(full_norms.len(), blocked.len());
        for j in 0..full_norms.len() {
            assert_eq!(
                full_norms[j].to_bits(),
                blocked[j].to_bits(),
                "col_norms[{j}] blocks={block_cols}"
            );
        }
    }
}

#[test]
fn view_solver_bitwise_matches_gathered_solver() {
    // Direct single-solve check (stronger localization than the path test):
    // FISTA on a ScreenedView vs FISTA on the gathered dense copy.
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(25, 120, 12), 5);
    let keep: Vec<usize> = (0..120).filter(|j| j % 3 != 0).collect();
    let view = ScreenedView::new(&ds.x, keep.clone());
    let gathered = ds.x.select_cols(&keep);
    let groups = tlfre::groups::GroupStructure::uniform(keep.len(), 8);

    let pv = SglProblem::new(&view, &ds.y, &groups);
    let pg = SglProblem::new(&gathered, &ds.y, &groups);
    let lm = sgl_lambda_max(&pg, 1.0);
    let params = SglParams::from_alpha_lambda(1.0, 0.4 * lm.lambda_max);
    let opts = FistaOptions { tol: 1e-8, ..Default::default() };
    let rv = solve_fista(&pv, &params, None, &opts);
    let rg = solve_fista(&pg, &params, None, &opts);
    assert_eq!(rv.iters, rg.iters);
    for j in 0..keep.len() {
        assert_eq!(
            rv.beta[j].to_bits(),
            rg.beta[j].to_bits(),
            "β[{j}] view {} vs gathered {}",
            rv.beta[j],
            rg.beta[j]
        );
    }
}
