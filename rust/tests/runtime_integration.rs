//! Integration tests over the PJRT runtime: the AOT artifacts produced by
//! `python/compile/aot.py` must load, compile, execute, and agree with the
//! native rust implementation to f32 tolerance.
//!
//! Requires `make artifacts` to have run (skips gracefully otherwise so
//! `cargo test` works in a fresh checkout).

#![cfg(not(miri))] // loads AOT artifacts from disk

use tlfre::data::synthetic::{generate_synthetic, SyntheticSpec};
use tlfre::linalg::DenseMatrix;
use tlfre::prox::shrink_norm_sq;
use tlfre::runtime::{artifacts_dir, ArtifactManifest, Runtime, ScreenEngine};
use tlfre::util::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP runtime tests: {e:#}");
            None
        }
    }
}

fn manifest_or_skip() -> Option<ArtifactManifest> {
    let dir = artifacts_dir();
    match ArtifactManifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP runtime tests: {e:#}");
            None
        }
    }
}

#[test]
fn screen_artifact_matches_native_tiny() {
    let Some(manifest) = manifest_or_skip() else { return };
    let Some(mut rt) = runtime_or_skip() else { return };
    let (n, p, gs) = (8usize, 32usize, 4usize);
    let mut rng = Rng::seed_from_u64(7);
    let x = DenseMatrix::from_fn(n, p, |_, _| rng.normal(0.0, 1.2) as f32);
    let o: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 0.8) as f32).collect();

    let engine = ScreenEngine::for_matrix(&mut rt, &manifest, &x).expect("engine");
    assert_eq!(engine.group_size, gs);
    let out = engine.run(&rt, &o).expect("screen run");

    // Native reference.
    let mut c = vec![0.0f32; p];
    x.matvec_t(&o, &mut c);
    for j in 0..p {
        assert!(
            (out.c[j] - c[j]).abs() < 1e-4 * (1.0 + c[j].abs()),
            "c[{j}]: hlo={} native={}",
            out.c[j],
            c[j]
        );
    }
    for g in 0..p / gs {
        let seg = &c[g * gs..(g + 1) * gs];
        let gsn = shrink_norm_sq(seg, 1.0);
        let gmax = seg.iter().fold(0.0f64, |m, &v| m.max((v as f64).abs()));
        assert!(
            (out.group_shrink_sq[g] as f64 - gsn).abs() < 1e-4 * (1.0 + gsn),
            "gsn[{g}]: hlo={} native={}",
            out.group_shrink_sq[g],
            gsn
        );
        assert!(
            (out.group_cinf[g] as f64 - gmax).abs() < 1e-5 * (1.0 + gmax),
            "gmax[{g}]"
        );
    }
}

#[test]
fn screen_artifact_matches_native_e2e_shape() {
    let Some(manifest) = manifest_or_skip() else { return };
    if manifest.find("tlfre_screen", 100, 1000).is_none() {
        eprintln!("SKIP: e2e artifact not built");
        return;
    }
    let Some(mut rt) = runtime_or_skip() else { return };
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(100, 1000, 100), 11);
    let engine = ScreenEngine::for_matrix(&mut rt, &manifest, &ds.x).expect("engine");
    let mut rng = Rng::seed_from_u64(12);
    for _ in 0..3 {
        let o: Vec<f32> = (0..100).map(|_| rng.normal(0.0, 1.0) as f32).collect();
        let out = engine.run(&rt, &o).expect("run");
        let mut c = vec![0.0f32; 1000];
        ds.x.matvec_t(&o, &mut c);
        let max_err = out
            .c
            .iter()
            .zip(&c)
            .map(|(a, b)| (a - b).abs() as f64 / (1.0 + b.abs() as f64))
            .fold(0.0f64, f64::max);
        assert!(max_err < 1e-4, "max relative error {max_err}");
    }
}

#[test]
fn dpc_artifact_executes() {
    let Some(manifest) = manifest_or_skip() else { return };
    let Some(spec) = manifest.find("dpc_screen", 8, 32) else {
        eprintln!("SKIP: dpc tiny artifact missing");
        return;
    };
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::seed_from_u64(13);
    let xt: Vec<f32> = (0..8 * 32).map(|_| rng.gaussian() as f32).collect();
    let o: Vec<f32> = (0..8).map(|_| rng.gaussian() as f32).collect();
    let outs = rt
        .execute_f32(&manifest.path_of(spec), &[(&xt, &[32, 8]), (&o, &[8])])
        .expect("execute dpc");
    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].len(), 32);
    // Native check: row-major (32, 8) => column j of X is xt[j*8..(j+1)*8].
    for j in 0..32 {
        let dot: f32 = (0..8).map(|i| xt[j * 8 + i] * o[i]).sum();
        assert!((outs[0][j] - dot).abs() < 1e-4 * (1.0 + dot.abs()), "col {j}");
    }
}

#[test]
fn fista_step_artifact_reduces_objective() {
    let Some(manifest) = manifest_or_skip() else { return };
    let Some(spec) = manifest.find("fista_step", 8, 32) else {
        eprintln!("SKIP: fista tiny artifact missing");
        return;
    };
    let Some(mut rt) = runtime_or_skip() else { return };
    let mut rng = Rng::seed_from_u64(14);
    let (n, p) = (8usize, 32usize);
    let x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian() as f32);
    let y: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
    let groups = tlfre::groups::GroupStructure::uniform(p, 8);
    let prob = tlfre::sgl::SglProblem::new(&x, &y, &groups);
    let params = tlfre::sgl::SglParams { lambda1: 0.05, lambda2: 0.05 };
    let lip = tlfre::sgl::fista::lipschitz(&prob);

    let mut beta = vec![0.0f32; p];
    let mut z = beta.clone();
    let mut t_k = 1.0f32;
    let path = manifest.path_of(spec);
    let obj0 = tlfre::sgl::objective::objective(&prob, &params, &beta).total();
    for _ in 0..50 {
        let scalars = [t_k, (1.0 / lip) as f32, params.lambda1 as f32, params.lambda2 as f32];
        let outs = rt
            .execute_f32(
                &path,
                &[
                    (x.data(), &[p as i64, n as i64]),
                    (&y, &[n as i64]),
                    (&beta, &[p as i64]),
                    (&z, &[p as i64]),
                    (&scalars, &[4]),
                ],
            )
            .expect("fista step");
        beta = outs[0].clone();
        z = outs[1].clone();
        t_k = outs[2][0];
    }
    let obj1 = tlfre::sgl::objective::objective(&prob, &params, &beta).total();
    assert!(obj1 < obj0, "objective did not decrease: {obj0} -> {obj1}");
    // Cross-check against the native solver's optimum.
    let res = tlfre::sgl::solve_fista(
        &prob,
        &params,
        None,
        &tlfre::sgl::FistaOptions { tol: 1e-9, ..Default::default() },
    );
    assert!(
        obj1 <= res.objective * 1.05 + 1e-6,
        "HLO FISTA far from optimum: {obj1} vs {}",
        res.objective
    );
}
