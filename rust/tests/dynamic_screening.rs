//! End-to-end tests for the composable screening pipeline and dynamic
//! GAP-safe screening:
//!
//! * **inexact-warm-start safety** — with a deliberately loose solver
//!   tolerance, `tlfre+gap` / `gap` paths must match the no-screening
//!   baseline's final supports at every λ on the dense *and* CSC backends
//!   and keep gap-bounded objectives (runs under the CI
//!   `TLFRE_THREADS ∈ {1,2,4,8}` matrix, which covers the acceptance
//!   thread sweep);
//! * **KKT recovery** — a manufactured heuristic rule that wrongly
//!   discards live groups must be corrected by the driver's re-admission
//!   loop, leaving the exact solution.

use tlfre::coordinator::{
    drive_tlfre_path_with_pipeline, run_tlfre_path, PathConfig, SolveControls, StepSink,
};
use tlfre::data::synthetic::{
    generate_sparse_synthetic, generate_synthetic, SparseSyntheticSpec, SyntheticSpec,
};
use tlfre::linalg::DesignMatrix;
use tlfre::screening::{
    LayerCount, Safety, ScreenInput, ScreenKind, ScreenPipeline, ScreeningRule, SurvivorMask,
};

// The single support comparator shared with the solver unit tests and the
// CI-gated perf_kernels section — see its docs for the hysteresis rationale.
use tlfre::screening::same_support_at_resolution as same_support;

fn loose_cfg(screen: ScreenKind) -> PathConfig {
    PathConfig {
        alpha: 1.0,
        screen,
        controls: SolveControls {
            n_lambda: 10,
            lambda_min_ratio: 0.05,
            // Deliberately loose: the previous-λ solutions handed to the
            // sequential rules are visibly inexact.
            tol: 1e-4,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// βs per λ via the runner's own driver (CoefficientSink equivalent).
fn path_betas<M: DesignMatrix>(
    x: &M,
    y: &[f32],
    groups: &tlfre::groups::GroupStructure,
    cfg: &PathConfig,
) -> Vec<Vec<f32>> {
    tlfre::coordinator::path_coefficients(x, y, groups, cfg)
}

fn assert_supports_match<M: DesignMatrix>(
    x: &M,
    y: &[f32],
    groups: &tlfre::groups::GroupStructure,
    screen: ScreenKind,
    backend: &str,
) {
    use tlfre::sgl::{SglParams, SglProblem};
    let screened_cfg = loose_cfg(screen);
    let baseline_cfg = loose_cfg(ScreenKind::None);
    // Steps (per-λ gaps) and βs come from the same deterministic walk.
    let sa = run_tlfre_path(x, y, groups, &screened_cfg);
    let sb = run_tlfre_path(x, y, groups, &baseline_cfg);
    let a = path_betas(x, y, groups, &screened_cfg);
    let b = path_betas(x, y, groups, &baseline_cfg);
    assert_eq!(a.len(), b.len());
    let prob = SglProblem::new(x, y, groups);
    let mut r = vec![0.0f32; y.len()];
    for li in 0..a.len() {
        assert!(
            same_support(&a[li], &b[li]),
            "{backend}/{screen:?}: support diverged from baseline at λ index {li}"
        );
        // Gap-bounded objectives: each solve is within its own duality gap
        // of the shared optimum, so |P(β_a) − P(β_b)| ≤ gap_a + gap_b
        // (plus f32 objective-evaluation noise).
        let params = SglParams::from_alpha_lambda(screened_cfg.alpha, sa.steps[li].lambda);
        tlfre::sgl::objective::residual(&prob, &a[li], &mut r);
        let pa = tlfre::sgl::objective::objective_with_residual(&prob, &params, &a[li], &r)
            .total();
        tlfre::sgl::objective::residual(&prob, &b[li], &mut r);
        let pb = tlfre::sgl::objective::objective_with_residual(&prob, &params, &b[li], &r)
            .total();
        let noise = 1e-5 * pa.abs().max(pb.abs()).max(1.0);
        let budget = sa.steps[li].gap + sb.steps[li].gap + noise;
        assert!(
            (pa - pb).abs() <= budget,
            "{backend}/{screen:?} λ index {li}: objectives {pa} vs {pb} differ beyond \
             the gap budget {budget}"
        );
    }
}

#[test]
fn inexact_warm_start_support_safety_dense() {
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 160, 16), 2031);
    for screen in [ScreenKind::TlfreGap, ScreenKind::Gap] {
        assert_supports_match(&ds.x, &ds.y, &ds.groups, screen, "dense");
    }
}

#[test]
fn inexact_warm_start_support_safety_csc() {
    let ds = generate_sparse_synthetic(&SparseSyntheticSpec::new(40, 160, 16, 0.2), 2032);
    for screen in [ScreenKind::TlfreGap, ScreenKind::Gap] {
        assert_supports_match(&ds.x, &ds.y, &ds.groups, screen, "csc");
    }
}

#[test]
fn dynamic_evictions_fire_and_are_counted() {
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 160, 16), 2033);
    let cfg = {
        let mut c = loose_cfg(ScreenKind::TlfreGap);
        c.tol = 1e-6;
        c
    };
    let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
    assert!(
        out.steps.iter().any(|s| s.dynamic_evicted > 0),
        "dynamic screening never fired along the path"
    );
    // Static pipelines must never report evictions.
    let static_out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &loose_cfg(ScreenKind::Tlfre));
    assert!(static_out.steps.iter().all(|s| s.dynamic_evicted == 0));
    // Per-rule marginals are recorded in pipeline order.
    let with_layers = out.steps.iter().skip(1).find(|s| !s.layers.is_empty()).unwrap();
    assert_eq!(with_layers.layers[0].rule, "tlfre");
    assert_eq!(with_layers.layers[1].rule, "gap");
}

/// A deliberately WRONG heuristic rule: unconditionally discards every
/// group with index ≥ keep_groups — including live ones. Only the driver's
/// KKT recovery loop can make a path using it correct.
struct WronglyAggressiveRule {
    keep_groups: usize,
}

impl<M: DesignMatrix> ScreeningRule<M> for WronglyAggressiveRule {
    fn name(&self) -> &'static str {
        "wrong"
    }

    fn safety(&self) -> Safety {
        Safety::Heuristic
    }

    fn screen(&self, input: &ScreenInput<'_, '_, M>, mask: &mut SurvivorMask) -> LayerCount {
        let groups = input.prob.groups;
        let mut g_new = 0usize;
        let mut f_new = 0usize;
        for (g, s, e) in groups.iter() {
            if g >= self.keep_groups && mask.group_kept[g] {
                mask.group_kept[g] = false;
                g_new += 1;
                for k in mask.feature_kept[s..e].iter_mut() {
                    if *k {
                        *k = false;
                        f_new += 1;
                    }
                }
            }
        }
        LayerCount { rule: "wrong", safety: Safety::Heuristic, groups: g_new, features: f_new }
    }
}

#[test]
fn kkt_recovery_readmits_manufactured_violations() {
    // Plant signal in groups spread across the index range so the "keep
    // only the first two groups" rule is guaranteed wrong at small λ.
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 120, 12), 2034);
    let cfg = PathConfig {
        alpha: 1.0,
        controls: SolveControls {
            n_lambda: 8,
            lambda_min_ratio: 0.05,
            tol: 1e-6,
            ..Default::default()
        },
        ..Default::default()
    };
    let pipeline =
        ScreenPipeline::new(vec![Box::new(WronglyAggressiveRule { keep_groups: 2 })], false);
    assert!(!pipeline.all_safe());
    let mut sink = StepSink::new();
    drive_tlfre_path_with_pipeline(&ds.x, &ds.y, &ds.groups, &cfg, pipeline, &mut sink);
    let readmitted: usize = sink.steps.iter().map(|s| s.kkt_readmitted).sum();
    assert!(readmitted > 0, "the manufactured violation was never detected");
    // Recovery must leave the exact path: compare against the plain TLFre
    // runner's supports.
    let reference = path_betas(&ds.x, &ds.y, &ds.groups, &cfg);
    let wrong_betas = path_coeffs_with_wrong_rule(&ds, &cfg);
    for (li, (ba, bb)) in wrong_betas.iter().zip(&reference).enumerate() {
        assert!(same_support(ba, bb), "KKT recovery left a wrong support at λ {li}");
    }
}

fn path_coeffs_with_wrong_rule(
    ds: &tlfre::data::Dataset,
    cfg: &PathConfig,
) -> Vec<Vec<f32>> {
    let pipeline =
        ScreenPipeline::new(vec![Box::new(WronglyAggressiveRule { keep_groups: 2 })], false);
    let mut sink = tlfre::coordinator::CoefficientSink::new();
    drive_tlfre_path_with_pipeline(&ds.x, &ds.y, &ds.groups, cfg, pipeline, &mut sink);
    sink.betas
}

#[test]
fn strong_kkt_pipeline_reports_layer_stats() {
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(25, 100, 10), 2035);
    let cfg = PathConfig {
        screen: ScreenKind::StrongKkt,
        controls: SolveControls {
            n_lambda: 8,
            lambda_min_ratio: 0.05,
            tol: 1e-6,
            ..Default::default()
        },
        ..Default::default()
    };
    let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
    // The strong rule's marginal rejections are recorded under its name.
    let busy = out.steps.iter().skip(1).find(|s| !s.layers.is_empty()).unwrap();
    assert_eq!(busy.layers[0].rule, "strong");
    assert_eq!(busy.layers[0].safety, Safety::Heuristic);
    // Final supports match the exact TLFre path.
    let exact = run_tlfre_path(
        &ds.x,
        &ds.y,
        &ds.groups,
        &PathConfig { screen: ScreenKind::Tlfre, ..cfg },
    );
    for (sa, sb) in out.steps.iter().zip(&exact.steps) {
        let diff = (sa.nonzeros as i64 - sb.nonzeros as i64).abs();
        assert!(diff <= 2, "λ={}: nnz {} vs {}", sa.lambda, sa.nonzeros, sb.nonzeros);
    }
}
