//! Fold-parallel cross-validation: bitwise parity with the serial sweep,
//! the single-screened-walk-per-fold×α guarantee, and solver-dispatch
//! lockstep through the public CV API.
//!
//! The determinism claim under test: `cross_validate` shards fold×α path
//! tasks across the persistent pool, but its output is **bitwise
//! identical** to `cross_validate_serial` at every worker count — the
//! pooled map preserves item order, the accumulation replays the serial
//! fold-major order, and every kernel inside a path is worker-count
//! invariant. The CI `TLFRE_THREADS ∈ {1,2,4,8}` matrix runs this whole
//! file under each process-level thread count on top of the explicit
//! worker sweep below.

use tlfre::coordinator::{
    cross_validate_serial, cross_validate_with_workers, make_folds, run_tlfre_path, CvOutput,
    PathConfig, SolveControls, SolverKind,
};
use tlfre::data::synthetic::{generate_synthetic, SyntheticSpec};
use tlfre::linalg::power::spectral_call_count;
use tlfre::linalg::{CscMatrix, SelectRows};

fn assert_cv_bitwise_eq(a: &CvOutput, b: &CvOutput, ctx: &str) {
    assert_eq!(a.points.len(), b.points.len(), "{ctx}: grid size");
    for (i, (pa, pb)) in a.points.iter().zip(&b.points).enumerate() {
        assert_eq!(pa.alpha.to_bits(), pb.alpha.to_bits(), "{ctx}: alpha at point {i}");
        assert_eq!(
            pa.lambda_ratio.to_bits(),
            pb.lambda_ratio.to_bits(),
            "{ctx}: lambda_ratio at point {i}"
        );
        assert_eq!(pa.mse.to_bits(), pb.mse.to_bits(), "{ctx}: mse at point {i}");
        assert_eq!(pa.mean_nnz.to_bits(), pb.mean_nnz.to_bits(), "{ctx}: nnz at point {i}");
    }
    assert_eq!(a.best.mse.to_bits(), b.best.mse.to_bits(), "{ctx}: best.mse");
    assert_eq!(a.best.alpha.to_bits(), b.best.alpha.to_bits(), "{ctx}: best.alpha");
    assert_eq!(a.nonfinite_points, b.nonfinite_points, "{ctx}: nonfinite count");
}

#[test]
fn fold_parallel_cv_bitwise_matches_serial_at_every_worker_count() {
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(36, 120, 12), 901);
    let cfg = PathConfig {
        controls: SolveControls {
            n_lambda: 6,
            lambda_min_ratio: 0.05,
            tol: 1e-5,
            ..Default::default()
        },
        ..Default::default()
    };
    let alphas = [0.5, 1.0];
    let serial = cross_validate_serial(&ds.x, &ds.y, &ds.groups, &alphas, 3, &cfg, 7);
    for workers in [1usize, 2, 4, 8] {
        let sharded =
            cross_validate_with_workers(&ds.x, &ds.y, &ds.groups, &alphas, 3, &cfg, 7, workers);
        assert_cv_bitwise_eq(&serial, &sharded, &format!("dense, workers={workers}"));
    }
}

#[test]
fn fold_parallel_cv_bitwise_matches_serial_on_csc_backend() {
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 90, 9), 902);
    let xs = CscMatrix::from_dense(&ds.x);
    let cfg = PathConfig {
        controls: SolveControls {
            n_lambda: 5,
            lambda_min_ratio: 0.1,
            tol: 1e-5,
            ..Default::default()
        },
        ..Default::default()
    };
    let serial = cross_validate_serial(&xs, &ds.y, &ds.groups, &[1.0], 3, &cfg, 11);
    for workers in [2usize, 4, 8] {
        let sharded =
            cross_validate_with_workers(&xs, &ds.y, &ds.groups, &[1.0], 3, &cfg, 11, workers);
        assert_cv_bitwise_eq(&serial, &sharded, &format!("csc, workers={workers}"));
    }
}

#[test]
fn cv_performs_exactly_one_screened_walk_per_fold_alpha() {
    // The power-iteration counter is thread-local, so the serial sweep
    // (everything on this thread) gives an exact accounting. One screened
    // walk per fold×α means the CV delta equals the sum of the per-path
    // deltas of `run_tlfre_path` on the same fold data — the old
    // two-walk implementation (stats pass + coefficient pass) spent
    // exactly double.
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 100, 10), 903);
    let cfg = PathConfig {
        controls: SolveControls {
            n_lambda: 5,
            lambda_min_ratio: 0.1,
            tol: 1e-5,
            ..Default::default()
        },
        ..Default::default()
    };
    let alphas = [0.5, 1.0];
    let (k_folds, seed) = (3usize, 13u64);

    // Expected cost: one runner path per fold×α over the same splits.
    let n = 30;
    let folds = make_folds(n, k_folds, seed);
    let c0 = spectral_call_count();
    for fold in &folds {
        let in_fold: std::collections::BTreeSet<usize> = fold.iter().copied().collect();
        let train_rows: Vec<usize> = (0..n).filter(|i| !in_fold.contains(i)).collect();
        let x_train = ds.x.select_rows(&train_rows);
        let y_train: Vec<f32> = train_rows.iter().map(|&i| ds.y[i]).collect();
        for &alpha in &alphas {
            let pc = PathConfig { alpha, ..cfg.clone() };
            run_tlfre_path(&x_train, &y_train, &ds.groups, &pc);
        }
    }
    let one_walk_cost = spectral_call_count() - c0;
    assert!(one_walk_cost > 0, "paths must pay their spectral preamble");

    let c1 = spectral_call_count();
    cross_validate_serial(&ds.x, &ds.y, &ds.groups, &alphas, k_folds, &cfg, seed);
    let cv_cost = spectral_call_count() - c1;
    assert_eq!(
        cv_cost, one_walk_cost,
        "cross_validate must perform exactly one screened walk per fold×α \
         (a second coefficient pass would double the spectral accounting)"
    );
}

#[test]
fn cv_honors_bcd_solver_through_the_public_api() {
    // End-to-end solver dispatch: per-grid-point mean nnz reported by a
    // BCD-configured CV must equal the fold-average of the BCD runner's
    // per-step nonzero counts on the same splits — exactly (integer
    // counts, identical accumulation order).
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(28, 96, 8), 904);
    let cfg = PathConfig {
        solver: SolverKind::Bcd,
        controls: SolveControls {
            n_lambda: 6,
            lambda_min_ratio: 0.05,
            tol: 1e-5,
            ..Default::default()
        },
        ..Default::default()
    };
    let (k_folds, seed) = (2usize, 17u64);
    let out = cross_validate_serial(&ds.x, &ds.y, &ds.groups, &[1.0], k_folds, &cfg, seed);
    assert_eq!(out.points.len(), cfg.n_lambda);

    let n = 28;
    let folds = make_folds(n, k_folds, seed);
    let mut fold_nnz = vec![0.0f64; cfg.n_lambda];
    for fold in &folds {
        let in_fold: std::collections::BTreeSet<usize> = fold.iter().copied().collect();
        let train_rows: Vec<usize> = (0..n).filter(|i| !in_fold.contains(i)).collect();
        let x_train = ds.x.select_rows(&train_rows);
        let y_train: Vec<f32> = train_rows.iter().map(|&i| ds.y[i]).collect();
        let path = run_tlfre_path(&x_train, &y_train, &ds.groups, &cfg);
        assert_eq!(path.steps.len(), cfg.n_lambda);
        for (li, s) in path.steps.iter().enumerate() {
            fold_nnz[li] += s.nonzeros as f64;
        }
    }
    for (li, point) in out.points.iter().enumerate() {
        let want = fold_nnz[li] / k_folds as f64;
        assert_eq!(
            point.mean_nnz, want,
            "BCD CV nnz diverged from the BCD runner at grid point {li}"
        );
    }
}

#[test]
fn single_point_grid_cv_smoke() {
    // n_lambda == 1: the λmax endpoint alone. Used to NaN the
    // lambda_ratio (division by n_lambda − 1 == 0).
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(24, 60, 6), 905);
    let cfg = PathConfig {
        controls: SolveControls { n_lambda: 1, lambda_min_ratio: 0.1, ..Default::default() },
        ..Default::default()
    };
    for workers in [1usize, 4] {
        let out =
            cross_validate_with_workers(&ds.x, &ds.y, &ds.groups, &[0.5, 1.0], 3, &cfg, 3, workers);
        assert_eq!(out.points.len(), 2);
        for p in &out.points {
            assert_eq!(p.lambda_ratio, 1.0);
            assert!(p.mse.is_finite());
            assert_eq!(p.mean_nnz, 0.0);
        }
        assert_eq!(out.nonfinite_points, 0);
    }
}
