//! Path-level spectral caching: correctness of the subset-Lipschitz bound
//! and equivalence of the cached vs exact-per-view path modes.
//!
//! The cache rests on one inequality: for any survivor set `S`,
//! `σmax(X[:,S]) ≤ σmax(X)` (and per group `σmax(X_g[:,S]) ≤ σmax(X_g)`),
//! because a column-subset operator norm is a supremum over a smaller set
//! of unit vectors (pad with zeros). So the full-matrix constants computed
//! once per path are valid — merely conservative — FISTA/BCD step bounds
//! for every reduced problem, and `run_tlfre_path` performs **zero** power
//! iterations inside its per-λ loop by default.

use tlfre::coordinator::cv::path_coefficients;
use tlfre::coordinator::{run_tlfre_path, PathConfig, SolveControls};
use tlfre::data::synthetic::{generate_synthetic, SyntheticSpec};
use tlfre::groups::GroupStructure;
use tlfre::linalg::power::{spectral_call_count, spectral_norm, spectral_norm_block};
use tlfre::linalg::{CscMatrix, DenseMatrix, ScreenedView};
use tlfre::util::Rng;

fn random_dense(n: usize, p: usize, seed: u64) -> DenseMatrix {
    let mut rng = Rng::seed_from_u64(seed);
    DenseMatrix::from_fn(n, p, |_, _| rng.gaussian() as f32)
}

/// Random survivor set keeping roughly `keep_frac` of `p` columns (always
/// at least one).
fn random_survivors(p: usize, keep_frac: f64, rng: &mut Rng) -> Vec<usize> {
    let mut keep: Vec<usize> =
        (0..p).filter(|_| rng.uniform_range(0.0, 1.0) < keep_frac).collect();
    if keep.is_empty() {
        keep.push(rng.below(p));
    }
    keep
}

#[test]
fn subset_spectral_norm_bounded_by_full_all_backends() {
    // Property test: σmax over random survivor subsets never exceeds the
    // full-matrix σmax, on dense, CSC and view backends. Both sides are
    // tight power-iteration estimates (tol 1e-10), so a small relative
    // slack covers estimation error; the production cache additionally
    // inflates the full-matrix value by 2%.
    let tol = 1e-10;
    let iters = 2000;
    for seed in [1u64, 2, 3] {
        let d = random_dense(24, 60, seed);
        let csc = CscMatrix::from_dense(&d);
        let mut rng = Rng::seed_from_u64(seed ^ 0xABCD);
        let sig_full_d = spectral_norm(&d, tol, iters, &mut Rng::seed_from_u64(seed + 1)).sigma;
        let sig_full_s = spectral_norm(&csc, tol, iters, &mut Rng::seed_from_u64(seed + 1)).sigma;

        for keep_frac in [0.1, 0.4, 0.8] {
            let keep = random_survivors(60, keep_frac, &mut rng);
            let vd = ScreenedView::new(&d, keep.clone());
            let vs = ScreenedView::new(&csc, keep.clone());
            let sig_sub_d = spectral_norm(&vd, tol, iters, &mut Rng::seed_from_u64(seed + 2)).sigma;
            let sig_sub_s = spectral_norm(&vs, tol, iters, &mut Rng::seed_from_u64(seed + 2)).sigma;
            let slack = 1e-5 * sig_full_d.max(1.0);
            assert!(
                sig_sub_d <= sig_full_d + slack,
                "dense: σ(S)={sig_sub_d} > σ(full)={sig_full_d} (|S|={})",
                keep.len()
            );
            assert!(
                sig_sub_s <= sig_full_s + slack,
                "csc: σ(S)={sig_sub_s} > σ(full)={sig_full_s} (|S|={})",
                keep.len()
            );
        }
    }
}

#[test]
fn per_group_subset_norm_bounded_by_full_group_norm() {
    // The BCD analogue: for each group, the norm of the surviving columns
    // within the group is bounded by the full group's norm.
    let d = random_dense(20, 48, 7);
    let groups = GroupStructure::uniform(48, 8);
    let mut rng = Rng::seed_from_u64(0x66);
    for (g, s, e) in groups.iter() {
        let sig_full =
            spectral_norm_block(&d, s, e, 1e-10, 2000, &mut Rng::seed_from_u64(g as u64)).sigma;
        // A random non-empty subset of the group's columns.
        let keep = random_survivors(e - s, 0.5, &mut rng);
        let cols: Vec<usize> = keep.iter().map(|&k| s + k).collect();
        let view = ScreenedView::new(&d, cols);
        let sig_sub =
            spectral_norm(&view, 1e-10, 2000, &mut Rng::seed_from_u64(g as u64 + 100)).sigma;
        assert!(
            sig_sub <= sig_full + 1e-5 * sig_full.max(1.0),
            "group {g}: σ(S∩g)={sig_sub} > σ(g)={sig_full}"
        );
    }
}

#[test]
fn cached_and_exact_lipschitz_paths_reach_same_solutions() {
    // A/B over the whole λ-path: the default cached-Lipschitz mode and the
    // exact per-view mode (PathConfig::exact_view_lipschitz) must converge
    // to the same solutions at every step — the cache changes step sizes,
    // never optima.
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 160, 16), 314);
    let cached_cfg = PathConfig {
        alpha: 1.0,
        controls: SolveControls {
            n_lambda: 10,
            lambda_min_ratio: 0.05,
            tol: 1e-7,
            ..Default::default()
        },
        ..Default::default()
    };
    let exact_cfg = PathConfig { exact_view_lipschitz: true, ..cached_cfg.clone() };

    let a = path_coefficients(&ds.x, &ds.y, &ds.groups, &cached_cfg);
    let b = path_coefficients(&ds.x, &ds.y, &ds.groups, &exact_cfg);
    assert_eq!(a.len(), b.len());
    for (step, (ba, bb)) in a.iter().zip(&b).enumerate() {
        let scale = ba
            .iter()
            .chain(bb.iter())
            .fold(0.0f32, |m, &v| m.max(v.abs()))
            .max(1e-3) as f64;
        let mut max_diff = 0.0f64;
        for (x, y) in ba.iter().zip(bb) {
            max_diff = max_diff.max((x - y).abs() as f64);
        }
        assert!(
            max_diff <= 0.02 * scale,
            "step {step}: max |β_cached − β_exact| = {max_diff} (scale {scale})"
        );
        // Substantial supports agree exactly.
        for (j, (x, y)) in ba.iter().zip(bb).enumerate() {
            let za = (x.abs() as f64) < 1e-3 * scale;
            let zb = (y.abs() as f64) < 1e-3 * scale;
            if za != zb {
                assert!(
                    (x - y).abs() as f64 <= 5e-3 * scale,
                    "step {step}, coord {j}: borderline support mismatch {x} vs {y}"
                );
            }
        }
    }

    // The runner's per-step statistics agree too (nnz trajectories within
    // a borderline-coordinate budget, same shape as the solver-A/B tests).
    let ra = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cached_cfg);
    let rb = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &exact_cfg);
    for (sa, sb) in ra.steps.iter().zip(&rb.steps) {
        let diff = (sa.nonzeros as i64 - sb.nonzeros as i64).abs();
        assert!(diff <= 3, "λ={}: nnz {} vs {}", sa.lambda, sa.nonzeros, sb.nonzeros);
    }
}

#[test]
fn refreshed_lipschitz_path_matches_cached_and_exact_solutions() {
    // Three-way A/B: cached (full-matrix constants), amortized refresh
    // (every 2 steps, subset-validity fallback between refreshes) and
    // exact per-view. All change step sizes only — coefficients must agree
    // to the same tolerance the cached-vs-exact test uses.
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 160, 16), 314);
    let cached_cfg = PathConfig {
        alpha: 1.0,
        controls: SolveControls {
            n_lambda: 10,
            lambda_min_ratio: 0.05,
            tol: 1e-7,
            ..Default::default()
        },
        ..Default::default()
    };
    let refresh_cfg = {
        let mut c = cached_cfg.clone();
        c.lipschitz_refresh_every = Some(2);
        c
    };

    let a = path_coefficients(&ds.x, &ds.y, &ds.groups, &cached_cfg);
    let b = path_coefficients(&ds.x, &ds.y, &ds.groups, &refresh_cfg);
    assert_eq!(a.len(), b.len());
    for (step, (ba, bb)) in a.iter().zip(&b).enumerate() {
        let scale = ba
            .iter()
            .chain(bb.iter())
            .fold(0.0f32, |m, &v| m.max(v.abs()))
            .max(1e-3) as f64;
        let mut max_diff = 0.0f64;
        for (x, y) in ba.iter().zip(bb) {
            max_diff = max_diff.max((x - y).abs() as f64);
        }
        assert!(
            max_diff <= 0.02 * scale,
            "step {step}: max |β_cached − β_refreshed| = {max_diff} (scale {scale})"
        );
    }

    // Runner statistics stay in the usual borderline-coordinate budget,
    // and the runner agrees with the coefficient walk under refresh (the
    // lockstep property that cv::path_coefficients mirrors every step-size
    // decision).
    let ra = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &refresh_cfg);
    assert_eq!(ra.steps.len(), b.len());
    for (bi, s) in b.iter().zip(&ra.steps) {
        let nnz = bi.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nnz, s.nonzeros, "runner/coefficient-walk lockstep broke at λ={}", s.lambda);
    }
}

#[test]
fn refresh_cadence_amortizes_power_iterations() {
    // Power-iteration accounting across the three modes, same grid:
    //   cached   — grid-length-independent (existing test);
    //   refresh  — grows with the grid, but slower than exact for K > 1;
    //   exact    — one estimation per λ (the ceiling).
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(25, 120, 12), 2718);
    let base = PathConfig {
        alpha: 1.0,
        controls: SolveControls {
            n_lambda: 16,
            lambda_min_ratio: 0.05,
            tol: 1e-6,
            ..Default::default()
        },
        ..Default::default()
    };

    let c0 = spectral_call_count();
    run_tlfre_path(&ds.x, &ds.y, &ds.groups, &base);
    let cached_calls = spectral_call_count() - c0;

    let refresh = {
        let mut c = base.clone();
        c.lipschitz_refresh_every = Some(4);
        c
    };
    let c1 = spectral_call_count();
    run_tlfre_path(&ds.x, &ds.y, &ds.groups, &refresh);
    let refresh_calls = spectral_call_count() - c1;

    let exact = PathConfig { exact_view_lipschitz: true, ..base.clone() };
    let c2 = spectral_call_count();
    run_tlfre_path(&ds.x, &ds.y, &ds.groups, &exact);
    let exact_calls = spectral_call_count() - c2;

    assert!(
        refresh_calls > cached_calls,
        "refresh mode must run per-view estimations ({refresh_calls} vs cached {cached_calls})"
    );
    assert!(
        refresh_calls < exact_calls,
        "refresh every 4 must stay under the exact mode's per-λ cost \
         ({refresh_calls} vs exact {exact_calls})"
    );

    // Exact mode wins precedence when both knobs are set.
    let both = {
        let mut c = base;
        c.exact_view_lipschitz = true;
        c.lipschitz_refresh_every = Some(4);
        c
    };
    let c3 = spectral_call_count();
    run_tlfre_path(&ds.x, &ds.y, &ds.groups, &both);
    let both_calls = spectral_call_count() - c3;
    assert_eq!(both_calls, exact_calls, "exact_view_lipschitz must supersede the refresh cadence");
}

#[test]
fn default_path_runs_zero_power_iterations_per_lambda() {
    // The spectral-call counter is thread-local, so the deltas below see
    // only this test's own work. If the per-λ loop ran any power
    // iteration, a longer grid would cost more calls; by default the cost
    // must be exactly grid-length-independent (the cache is built once, in
    // the screening preamble).
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(25, 120, 12), 2718);
    let base = PathConfig {
        alpha: 1.0,
        controls: SolveControls { lambda_min_ratio: 0.05, tol: 1e-6, ..Default::default() },
        ..Default::default()
    };

    let short = {
        let mut c = base.clone();
        c.n_lambda = 4;
        c
    };
    let long = {
        let mut c = base.clone();
        c.n_lambda = 16;
        c
    };

    let c0 = spectral_call_count();
    run_tlfre_path(&ds.x, &ds.y, &ds.groups, &short);
    let short_calls = spectral_call_count() - c0;
    let c1 = spectral_call_count();
    run_tlfre_path(&ds.x, &ds.y, &ds.groups, &long);
    let long_calls = spectral_call_count() - c1;
    assert_eq!(
        short_calls, long_calls,
        "cached mode: power-iteration count must not depend on the λ-grid length"
    );
    assert!(short_calls > 0, "the once-per-path cache itself uses power iteration");

    // Exact mode is the control: per-λ power iteration makes the longer
    // grid strictly more expensive.
    let c2 = spectral_call_count();
    run_tlfre_path(&ds.x, &ds.y, &ds.groups, &PathConfig { exact_view_lipschitz: true, ..short });
    let exact_short = spectral_call_count() - c2;
    let c3 = spectral_call_count();
    run_tlfre_path(&ds.x, &ds.y, &ds.groups, &PathConfig { exact_view_lipschitz: true, ..long });
    let exact_long = spectral_call_count() - c3;
    assert!(
        exact_long > exact_short,
        "exact mode control: expected per-λ power iterations ({exact_short} vs {exact_long})"
    );
}
