//! End-to-end coordinator integration: full paths on the paper's synthetic
//! recipes, screened vs baseline agreement, speedup sanity, and DPC paths
//! on the simulated real data sets.

use tlfre::coordinator::{
    run_baseline_path, run_dpc_path, run_nonneg_baseline, run_tlfre_path, DpcPathConfig,
    PathConfig, SolveControls,
};
use tlfre::data::registry::RealDataset;
use tlfre::data::synthetic::{generate_synthetic, SyntheticSpec};
use tlfre::util::harness::black_box;

fn cfg(alpha: f64, n_lambda: usize) -> PathConfig {
    PathConfig {
        alpha,
        controls: SolveControls {
            n_lambda,
            lambda_min_ratio: 0.05,
            tol: 1e-6,
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn synthetic1_path_screened_vs_baseline_objectives() {
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(60, 600, 60), 7);
    let c = cfg(1.0, 30);
    let screened = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &c);
    let baseline = run_baseline_path(&ds.x, &ds.y, &ds.groups, &c);
    // High rejection on the paper's own workload.
    assert!(
        screened.mean_total_rejection() > 0.8,
        "rejection {}",
        screened.mean_total_rejection()
    );
    // The screened path should touch far fewer features in total.
    let screened_work: usize = screened.steps.iter().map(|s| s.active_features).sum();
    let baseline_work: usize = baseline.steps.iter().map(|s| s.active_features).sum();
    assert!(
        screened_work * 3 < baseline_work,
        "screened {screened_work} vs baseline {baseline_work}"
    );
}

#[test]
fn synthetic2_path_runs_with_correlated_design() {
    // Paper-like per-step ratio needs a reasonably fine grid (100 points
    // over two decades in the paper; 30 points over 1.3 decades here).
    let ds = generate_synthetic(&SyntheticSpec::synthetic2_scaled(50, 400, 40), 8);
    let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg(1.0, 30));
    assert_eq!(out.steps.len(), 30);
    assert!(out.mean_total_rejection() > 0.5);
    for s in &out.steps {
        assert!(s.gap.is_finite());
        assert!(s.r1 + s.r2 <= 1.0 + 1e-9);
    }
}

#[test]
fn adni_sim_path_group_structure_respected() {
    // Small-scale ADNI sim: ragged groups (2..=20 SNPs).
    let ds = RealDataset::AdniGmv.generate(0.002, 9);
    assert!(ds.groups.is_uniform().is_none(), "ADNI groups should be ragged");
    let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg(1.0, 8));
    assert!(out.mean_total_rejection() > 0.5, "rejection {}", out.mean_total_rejection());
}

#[test]
fn dpc_path_on_image_dictionary() {
    let ds = RealDataset::Mnist.generate(0.004, 10);
    let c = DpcPathConfig {
        controls: SolveControls {
            n_lambda: 30,
            lambda_min_ratio: 0.1,
            tol: 1e-5,
            ..Default::default()
        },
        ..Default::default()
    };
    let screened = run_dpc_path(&ds.x, &ds.y, &c);
    let baseline = run_nonneg_baseline(&ds.x, &ds.y, &c);
    assert!(screened.mean_rejection() > 0.8, "rejection {}", screened.mean_rejection());
    let s_work: usize = screened.steps.iter().map(|s| s.active_features).sum();
    let b_work: usize = baseline.steps.iter().map(|s| s.active_features).sum();
    assert!(s_work * 5 < b_work, "screened {s_work} vs baseline {b_work}");
}

#[test]
fn screening_cost_is_negligible() {
    // The paper's headline operational property: TLFre time ≪ solver time.
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(80, 800, 80), 11);
    let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg(1.0, 15));
    black_box(&out);
    assert!(
        out.screen_total_s < out.solve_total_s.max(0.05),
        "screening {}s vs solving {}s",
        out.screen_total_s,
        out.solve_total_s
    );
}

#[test]
fn verify_mode_full_paths_small() {
    // verify_safety re-solves unscreened every step and asserts internally.
    let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(25, 150, 15), 12);
    for alpha in [0.3, 1.0, 3.0] {
        let c = {
            let mut c = cfg(alpha, 10);
            c.verify_safety = true;
            c.tol = 1e-8;
            c
        };
        let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &c);
        assert!(out.steps.len() == 10);
    }
}

#[test]
fn dpc_verify_mode_small() {
    let ds = RealDataset::Pie.generate(0.01, 13);
    let c = DpcPathConfig {
        controls: SolveControls {
            n_lambda: 8,
            lambda_min_ratio: 0.05,
            tol: 1e-8,
            verify_safety: true,
            ..Default::default()
        },
        ..Default::default()
    };
    let out = run_dpc_path(&ds.x, &ds.y, &c);
    assert!(out.steps.len() == 8);
}
