//! Repo-local invariant linter: statically enforces the determinism and
//! unsafe-memory contracts over `rust/src`. See the README next to this
//! crate for the rule catalog and the allowlist format.
//!
//! Zero dependencies by design — a hand-rolled line lexer (comments,
//! strings and char literals stripped; `#[cfg(test)] mod` regions
//! skipped) feeds six token-level rules:
//!
//! * `unsafe-safety` — every `unsafe` block/impl needs a `// SAFETY:`
//!   comment (same line or the contiguous comment block above);
//! * `hash-iteration` — no iteration over `HashMap`/`HashSet` outside
//!   allowlisted sites: iteration order is per-instance nondeterministic
//!   and anything serialized from it would break the bitwise-determinism
//!   contract;
//! * `relaxed-ordering` — no `Ordering::Relaxed` outside allowlisted
//!   sites;
//! * `float-narrowing` — no `as f32` in the solver dirs (`sgl/`,
//!   `screening/`, `nonneg/`) outside allowlisted widen-compute-narrow
//!   kernel sites (a line that also widens `as f64` is the sanctioned
//!   idiom and passes);
//! * `thread-spawn` — thread creation only in `util/pool.rs` and
//!   `server/serve.rs`;
//! * `solver-timers` — no `Instant::now` / `SystemTime` reads inside
//!   solver code (wall-clock must never influence numeric output).
//!
//! The `hash-iteration` rule joins statement continuation lines upward
//! (up to 8) before matching, so a builder chain like
//! `map\n.iter()\n.map(..)` is still caught.
//!
//! Exit status: 0 clean, 1 violations or stale allowlist entries, 2 bad
//! invocation or malformed allowlist.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

const SOLVER_DIRS: [&str; 3] = ["/sgl/", "/screening/", "/nonneg/"];
const SPAWN_OK: [&str; 2] = ["util/pool.rs", "server/serve.rs"];
const ITER_METHODS: [&str; 7] = [
    ".keys()",
    ".values()",
    ".values_mut()",
    ".iter()",
    ".iter_mut()",
    ".into_iter()",
    ".drain(",
];

/// One source line, lexed: `code` has comments stripped and string/char
/// contents blanked (delimiters kept); `raw` is the original text.
struct Line {
    code: String,
    raw: String,
}

struct Violation {
    line: usize,
    rule: &'static str,
    msg: String,
    raw: String,
}

struct AllowEntry {
    rule: String,
    path: String,
    frag: String,
    line_no: usize,
    used: bool,
}

fn is_word_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_' || b >= 0x80
}

/// First word-bounded occurrence of `word` (ASCII) in `hay` at or after
/// byte `from`.
fn find_word_from(hay: &str, from: usize, word: &str) -> Option<usize> {
    let b = hay.as_bytes();
    let mut i = from;
    while let Some(off) = hay[i..].find(word) {
        let s = i + off;
        let e = s + word.len();
        let pre = s == 0 || !is_word_byte(b[s - 1]);
        let post = e == b.len() || !is_word_byte(b[e]);
        if pre && post {
            return Some(s);
        }
        i = s + 1;
    }
    None
}

// ---------------------------------------------------------------- lexer

#[derive(Clone, Copy)]
enum LexState {
    Normal,
    Block,
    Str,
    RawStr,
}

/// Match `b?r#*"` at the start of `s`: returns (chars consumed, hash count).
fn raw_str_open(s: &[char]) -> Option<(usize, usize)> {
    let mut i = 0;
    if s.first() == Some(&'b') {
        i += 1;
    }
    if s.get(i) != Some(&'r') {
        return None;
    }
    i += 1;
    let mut hashes = 0;
    while s.get(i + hashes) == Some(&'#') {
        hashes += 1;
    }
    if s.get(i + hashes) != Some(&'"') {
        return None;
    }
    Some((i + hashes + 1, hashes))
}

/// Match a char literal (`'a'`, `'\n'`) at the start of `s` (which begins
/// with `'`): returns chars consumed, or None for a lifetime.
fn char_literal(s: &[char]) -> Option<usize> {
    match *s.get(1)? {
        '\\' => {
            s.get(2)?;
            if *s.get(3)? == '\'' {
                Some(4)
            } else {
                None
            }
        }
        '\'' => None,
        _ => {
            if *s.get(2)? == '\'' {
                Some(3)
            } else {
                None
            }
        }
    }
}

fn lex(text: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut state = LexState::Normal;
    let mut depth = 0usize;
    let mut raw_hashes = 0usize;
    for raw in text.split('\n') {
        let chars: Vec<char> = raw.chars().collect();
        let n = chars.len();
        let mut code = String::new();
        let mut i = 0;
        while i < n {
            let c = chars[i];
            let nxt = *chars.get(i + 1).unwrap_or(&'\0');
            match state {
                LexState::Block => {
                    if c == '/' && nxt == '*' {
                        depth += 1;
                        i += 2;
                    } else if c == '*' && nxt == '/' {
                        depth -= 1;
                        i += 2;
                        if depth == 0 {
                            state = LexState::Normal;
                        }
                    } else {
                        i += 1;
                    }
                }
                LexState::Str => {
                    if c == '\\' {
                        i += 2;
                    } else if c == '"' {
                        state = LexState::Normal;
                        code.push('"');
                        i += 1;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                LexState::RawStr => {
                    let closes = c == '"'
                        && i + 1 + raw_hashes <= n
                        && chars[i + 1..i + 1 + raw_hashes].iter().all(|&h| h == '#');
                    if closes {
                        state = LexState::Normal;
                        code.push('"');
                        i += 1 + raw_hashes;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                LexState::Normal => {
                    if c == '/' && nxt == '/' {
                        break;
                    }
                    if c == '/' && nxt == '*' {
                        state = LexState::Block;
                        depth = 1;
                        i += 2;
                    } else if c == '"' {
                        state = LexState::Str;
                        code.push('"');
                        i += 1;
                    } else if let Some((consumed, hashes)) = raw_str_open(&chars[i..]) {
                        state = LexState::RawStr;
                        raw_hashes = hashes;
                        code.push('"');
                        i += consumed;
                    } else if c == '\'' {
                        if let Some(consumed) = char_literal(&chars[i..]) {
                            code.push_str("' '");
                            i += consumed;
                        } else {
                            code.push('\'');
                            i += 1;
                        }
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
            }
        }
        out.push(Line { code, raw: raw.to_string() });
    }
    out
}

// -------------------------------------------------- test-region skipping

/// `(pub\s+)?mod` at the start of a trimmed code line.
fn is_mod_decl(t: &str) -> bool {
    let rest = match t.strip_prefix("pub") {
        Some(r) if r.starts_with(char::is_whitespace) => r.trim_start(),
        Some(_) => return false,
        None => t,
    };
    rest.starts_with("mod") && !rest.as_bytes().get(3).is_some_and(|&b| is_word_byte(b))
}

/// Mark lines inside `#[cfg(..test..)] mod` blocks (brace-counted), so
/// test-only code is exempt from the rules.
fn test_regions(lines: &[Line]) -> Vec<bool> {
    let mut in_test = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        let code = lines[i].code.trim();
        if code.starts_with("#[cfg(") && find_word_from(code, 0, "test").is_some() {
            let mut j = i + 1;
            while j < lines.len() {
                let t = lines[j].code.trim();
                if t.is_empty() || t.starts_with("#[") {
                    j += 1;
                } else {
                    break;
                }
            }
            if j < lines.len() && is_mod_decl(lines[j].code.trim()) {
                let mut depth: i64 = 0;
                let mut started = false;
                let mut k = j;
                while k < lines.len() {
                    for ch in lines[k].code.chars() {
                        if ch == '{' {
                            depth += 1;
                            started = true;
                        } else if ch == '}' {
                            depth -= 1;
                        }
                    }
                    in_test[k] = true;
                    if started && depth <= 0 {
                        break;
                    }
                    k += 1;
                }
                for t in in_test.iter_mut().take(j).skip(i) {
                    *t = true;
                }
                i = k + 1;
                continue;
            }
        }
        i += 1;
    }
    in_test
}

// ------------------------------------------------ hash-name collection

/// Names declared with a `HashMap`/`HashSet` type annotation
/// (`name: ..Hash{Map,Set}<..`) — struct fields, fn params, typed lets.
fn field_decl_names(code: &str, out: &mut BTreeSet<String>) {
    let b = code.as_bytes();
    for token in ["HashMap", "HashSet"] {
        let mut from = 0;
        while let Some(off) = code[from..].find(token) {
            let s = from + off;
            from = s + 1;
            if s > 0 && is_word_byte(b[s - 1]) {
                continue;
            }
            let mut e = s + token.len();
            while e < b.len() && b[e].is_ascii_whitespace() {
                e += 1;
            }
            if e >= b.len() || b[e] != b'<' {
                continue;
            }
            // Walk back over the type expression (stop at `=`, `;`, `(`),
            // then take the word before the first `:` in that segment.
            let mut st = s;
            while st > 0 && !matches!(b[st - 1], b'=' | b';' | b'(') {
                st -= 1;
            }
            let mut q = st;
            while q < s {
                if b[q] != b':' {
                    q += 1;
                    continue;
                }
                let mut w = q;
                while w > st && b[w - 1].is_ascii_whitespace() {
                    w -= 1;
                }
                let mut ws = w;
                while ws > st && is_word_byte(b[ws - 1]) {
                    ws -= 1;
                }
                if ws < w {
                    out.insert(code[ws..w].to_string());
                    break;
                }
                q += 1;
            }
        }
    }
}

/// Names bound with `let [mut] name [: ty] = Hash{Map,Set}::..`.
fn let_decl_names(code: &str, out: &mut BTreeSet<String>) {
    let b = code.as_bytes();
    let mut from = 0;
    while let Some(s) = find_word_from(code, from, "let") {
        from = s + 1;
        let mut i = s + 3;
        let ws0 = i;
        while i < b.len() && b[i].is_ascii_whitespace() {
            i += 1;
        }
        if i == ws0 {
            continue;
        }
        if code[i..].starts_with("mut") {
            let mut k = i + 3;
            while k < b.len() && b[k].is_ascii_whitespace() {
                k += 1;
            }
            if k > i + 3 {
                i = k;
            }
        }
        let id0 = i;
        while i < b.len() && is_word_byte(b[i]) {
            i += 1;
        }
        if i == id0 {
            continue;
        }
        let name = &code[id0..i];
        while i < b.len() && b[i].is_ascii_whitespace() {
            i += 1;
        }
        if b.get(i) == Some(&b':') {
            while i < b.len() && b[i] != b'=' {
                i += 1;
            }
        }
        if b.get(i) != Some(&b'=') {
            continue;
        }
        i += 1;
        while i < b.len() && b[i].is_ascii_whitespace() {
            i += 1;
        }
        if !code[i..].starts_with("HashMap") && !code[i..].starts_with("HashSet") {
            continue;
        }
        i += 7;
        while i < b.len() && b[i].is_ascii_whitespace() {
            i += 1;
        }
        if code[i..].starts_with("::") {
            out.insert(name.to_string());
        }
    }
}

// ------------------------------------------------------- rule matchers

/// `as <ty>` cast on a lexed code line.
fn has_cast(code: &str, ty: &str) -> bool {
    let b = code.as_bytes();
    let mut from = 0;
    while let Some(p) = find_word_from(code, from, "as") {
        from = p + 1;
        let mut i = p + 2;
        let ws0 = i;
        while i < b.len() && b[i].is_ascii_whitespace() {
            i += 1;
        }
        let bounded = code[i..].starts_with(ty)
            && !b.get(i + ty.len()).is_some_and(|&c| is_word_byte(c));
        if i > ws0 && bounded {
            return true;
        }
    }
    false
}

/// `thread::spawn` / `thread::Builder` / `thread::scope`.
fn has_thread_spawn(code: &str) -> bool {
    let b = code.as_bytes();
    let mut from = 0;
    while let Some(p) = find_word_from(code, from, "thread") {
        from = p + 1;
        let mut i = p + 6;
        while i < b.len() && b[i].is_ascii_whitespace() {
            i += 1;
        }
        if !code[i..].starts_with("::") {
            continue;
        }
        i += 2;
        while i < b.len() && b[i].is_ascii_whitespace() {
            i += 1;
        }
        for w in ["spawn", "Builder", "scope"] {
            let bounded = !b.get(i + w.len()).is_some_and(|&c| is_word_byte(c));
            if code[i..].starts_with(w) && bounded {
                return true;
            }
        }
    }
    false
}

/// `for .. in` somewhere on one lexed code line.
fn has_for_in(code: &str) -> bool {
    find_word_from(code, 0, "for").is_some_and(|f| find_word_from(code, f + 3, "in").is_some())
}

/// `for .. in .. name` within one statement (no `;`/`{` crossed).
fn for_in_name(hay: &str, name: &str) -> bool {
    let mut from = 0;
    while let Some(f) = find_word_from(hay, from, "for") {
        from = f + 1;
        let tail = &hay[f + 3..];
        let stop = tail.find(|c| c == ';' || c == '{').unwrap_or(tail.len());
        let seg = &tail[..stop];
        if let Some(p) = find_word_from(seg, 0, "in") {
            if find_word_from(&seg[p + 2..], 0, name).is_some() {
                return true;
            }
        }
    }
    false
}

/// Did the contiguous comment block (or same line) above `idx` state a
/// `SAFETY:` justification? Attributes, blank lines and other
/// `unsafe impl` lines between the comment and the site are skipped.
fn has_safety_comment(lines: &[Line], idx: usize) -> bool {
    if lines[idx].raw.contains("SAFETY:") {
        return true;
    }
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let t = lines[j].raw.trim();
        let tc = lines[j].code.trim();
        if t.is_empty() || tc.starts_with("#[") || tc.starts_with("#![") {
            continue;
        }
        if lines[j].code.contains("unsafe impl") {
            continue;
        }
        if t.starts_with("//") {
            let mut k = j + 1;
            while k > 0 && lines[k - 1].raw.trim().starts_with("//") {
                k -= 1;
                if lines[k].raw.contains("SAFETY:") {
                    return true;
                }
            }
        }
        return false;
    }
    false
}

/// Join up to 8 continuation lines above `idx` into one statement: a line
/// whose predecessor ends with `;`, `{` or `}` (or is blank) starts fresh.
fn joined_statement(lines: &[Line], idx: usize) -> String {
    let mut stmt: Vec<&str> = vec![&lines[idx].code];
    let mut j = idx;
    while j > 0 && stmt.len() < 8 {
        j -= 1;
        let prev = lines[j].code.trim_end();
        let t = prev.trim();
        if t.is_empty() || t.ends_with(';') || t.ends_with('{') || t.ends_with('}') {
            break;
        }
        stmt.push(prev);
    }
    stmt.reverse();
    stmt.join(" ")
}

fn hash_iter_msg(name: &str) -> String {
    format!("iteration over HashMap/HashSet `{name}` (nondeterministic order)")
}

// ------------------------------------------------------------ lint core

/// Lint one file's source text. `rel` is the forward-slash relative path
/// (used for the solver-dir and spawn-site checks).
fn lint_source(rel: &str, text: &str) -> Vec<Violation> {
    let lines = lex(text);
    let in_test = test_regions(&lines);
    let solver = SOLVER_DIRS.iter().any(|d| rel.contains(d));
    let spawn_ok = SPAWN_OK.iter().any(|p| rel.ends_with(p));

    let mut hash_names = BTreeSet::new();
    for line in &lines {
        field_decl_names(&line.code, &mut hash_names);
        let_decl_names(&line.code, &mut hash_names);
    }
    hash_names.remove("self");

    let mut vs = Vec::new();
    let mut report = |line: usize, rule: &'static str, msg: String, raw: &str| {
        vs.push(Violation { line, rule, msg, raw: raw.to_string() });
    };

    for (idx, line) in lines.iter().enumerate() {
        if in_test[idx] {
            continue;
        }
        let code = &line.code;
        let ln = idx + 1;

        // unsafe-safety: one report per line; fn/extern decls are exempt
        // (those carry `# Safety` docs instead).
        let mut from = 0;
        while let Some(p) = find_word_from(code, from, "unsafe") {
            from = p + 1;
            let after = code[p + 6..].trim_start();
            if after.starts_with("fn") || after.starts_with("extern") {
                continue;
            }
            if !has_safety_comment(&lines, idx) {
                report(
                    ln,
                    "unsafe-safety",
                    "`unsafe` block/impl without a preceding `// SAFETY:` comment".to_string(),
                    &line.raw,
                );
            }
            break;
        }

        // hash-iteration (statement-level: continuation lines joined)
        let may_iterate = ITER_METHODS.iter().any(|m| code.contains(m)) || has_for_in(code);
        if may_iterate {
            let joined = joined_statement(&lines, idx);
            for name in &hash_names {
                if find_word_from(&joined, 0, name).is_none() {
                    continue;
                }
                let hits = ITER_METHODS.iter().any(|m| joined.contains(m))
                    || for_in_name(&joined, name);
                if hits {
                    report(ln, "hash-iteration", hash_iter_msg(name), &line.raw);
                    break;
                }
            }
        }

        if code.contains("Ordering::Relaxed") {
            report(
                ln,
                "relaxed-ordering",
                "`Ordering::Relaxed` outside allowlisted sites".to_string(),
                &line.raw,
            );
        }

        if solver && has_cast(code, "f32") && !has_cast(code, "f64") {
            report(
                ln,
                "float-narrowing",
                "`as f32` narrowing in solver code".to_string(),
                &line.raw,
            );
        }

        if has_thread_spawn(code) && !spawn_ok {
            report(
                ln,
                "thread-spawn",
                "direct thread creation outside util/pool.rs / server/serve.rs".to_string(),
                &line.raw,
            );
        }

        if solver && (code.contains("Instant::now") || code.contains("SystemTime")) {
            report(
                ln,
                "solver-timers",
                "wall-clock read inside solver code".to_string(),
                &line.raw,
            );
        }
    }
    vs
}

// ------------------------------------------------------------ allowlist

fn parse_allowlist_text(text: &str) -> Result<Vec<AllowEntry>, String> {
    let mut entries = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let s = raw.trim();
        if s.is_empty() || s.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = s.split('|').map(str::trim).collect();
        if parts.len() < 4 {
            let want = "want `rule | path-suffix | line-fragment | reason`";
            return Err(format!("allowlist:{}: malformed entry ({want})", idx + 1));
        }
        entries.push(AllowEntry {
            rule: parts[0].to_string(),
            path: parts[1].to_string(),
            frag: parts[2].to_string(),
            line_no: idx + 1,
            used: false,
        });
    }
    Ok(entries)
}

fn entry_matches(e: &AllowEntry, rule: &str, rel: &str, raw: &str) -> bool {
    e.rule == rule && rel.ends_with(&e.path) && (e.frag == "*" || raw.contains(&e.frag))
}

/// Drop allowlisted violations, marking the entries they matched as used.
fn filter_with_allowlist(
    rel: &str,
    vs: Vec<Violation>,
    entries: &mut [AllowEntry],
) -> Vec<Violation> {
    let mut kept = Vec::new();
    for v in vs {
        let mut suppressed = false;
        for e in entries.iter_mut() {
            if entry_matches(e, v.rule, rel, &v.raw) {
                e.used = true;
                suppressed = true;
            }
        }
        if !suppressed {
            kept.push(v);
        }
    }
    kept
}

// ----------------------------------------------------------------- main

fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            rs_files(&p, out)?;
        } else if p.extension().and_then(|x| x.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

const USAGE: &str = "usage: invariant-lint [--root DIR] [--allowlist FILE]
  --root DIR        source tree to lint (default: rust/src)
  --allowlist FILE  allowlist path (default: rust/tools/invariant-lint/allowlist.txt)";

fn main() -> ExitCode {
    let mut root = PathBuf::from("rust/src");
    let mut allowlist_path = PathBuf::from("rust/tools/invariant-lint/allowlist.txt");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        let value = |args: &mut dyn Iterator<Item = String>, flag: &str| {
            args.next().ok_or_else(|| format!("{flag} needs a value"))
        };
        let r = match a.as_str() {
            "--root" => value(&mut args, "--root").map(|v| root = PathBuf::from(v)),
            "--allowlist" => {
                value(&mut args, "--allowlist").map(|v| allowlist_path = PathBuf::from(v))
            }
            "--help" | "-h" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other => Err(format!("unknown argument `{other}`")),
        };
        if let Err(e) = r {
            eprintln!("invariant-lint: {e}\n{USAGE}");
            return ExitCode::from(2);
        }
    }

    let mut entries = match std::fs::read_to_string(&allowlist_path) {
        Ok(text) => match parse_allowlist_text(&text) {
            Ok(entries) => entries,
            Err(e) => {
                eprintln!("invariant-lint: {e}");
                return ExitCode::from(2);
            }
        },
        // No allowlist file: every violation reports.
        Err(_) => Vec::new(),
    };

    let mut files = Vec::new();
    if let Err(e) = rs_files(&root, &mut files) {
        eprintln!("invariant-lint: cannot walk {}: {e}", root.display());
        return ExitCode::from(2);
    }

    let mut n_bad = 0usize;
    for path in &files {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("invariant-lint: cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        };
        let rel = path.to_string_lossy().replace('\\', "/");
        let vs = filter_with_allowlist(&rel, lint_source(&rel, &text), &mut entries);
        for v in vs {
            n_bad += 1;
            println!("{rel}:{}: [{}] {}\n    {}", v.line, v.rule, v.msg, v.raw.trim());
        }
    }

    let stale: Vec<&AllowEntry> = entries.iter().filter(|e| !e.used).collect();
    for e in &stale {
        println!(
            "allowlist:{}: stale entry ({} | {} | {}) matched nothing",
            e.line_no, e.rule, e.path, e.frag
        );
    }
    if n_bad > 0 || !stale.is_empty() {
        println!("\n{n_bad} violation(s), {} stale allowlist entr(ies)", stale.len());
        return ExitCode::FAILURE;
    }
    println!("invariant-lint: clean ({} files)", files.len());
    ExitCode::SUCCESS
}

// ----------------------------------------------------------------- tests

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(rel: &str, src: &str) -> Vec<(usize, &'static str)> {
        lint_source(rel, src).iter().map(|v| (v.line, v.rule)).collect()
    }

    #[test]
    fn unsafe_block_without_safety_comment_is_flagged() {
        let bad = "pub fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
        assert_eq!(rules("rust/src/util/fake.rs", bad), vec![(2, "unsafe-safety")]);

        let good = "pub fn f(p: *const u8) -> u8 {\n\
                    // SAFETY: caller keeps p valid.\n\
                    unsafe { *p }\n}\n";
        assert!(rules("rust/src/util/fake.rs", good).is_empty());
    }

    #[test]
    fn safety_comment_is_found_past_attributes_and_sibling_impls() {
        let src = "// SAFETY: plain shared state, no interior mutation.\n\
                   #[allow(dead_code)]\n\
                   unsafe impl Send for S {}\n\
                   unsafe impl Sync for S {}\n";
        assert!(rules("rust/src/util/fake.rs", src).is_empty());
    }

    #[test]
    fn unsafe_fn_declarations_are_exempt() {
        let src = "pub unsafe fn g() {}\nunsafe extern \"C\" fn h() {}\n";
        assert!(rules("rust/src/util/fake.rs", src).is_empty());
    }

    #[test]
    fn hash_iteration_is_flagged_for_fields_lets_and_for_loops() {
        let field = "use std::collections::HashMap;\n\
                     struct S { cache: HashMap<String, u32> }\n\
                     impl S {\n\
                     fn dump(&self) -> Vec<String> {\n\
                     self.cache.keys().cloned().collect()\n\
                     }\n\
                     }\n";
        assert_eq!(rules("rust/src/util/fake.rs", field), vec![(5, "hash-iteration")]);

        let let_bound = "fn f() -> usize {\n\
                         let m = HashMap::<u32, u32>::new();\n\
                         m.iter().count()\n\
                         }\n";
        assert_eq!(rules("rust/src/util/fake.rs", let_bound), vec![(3, "hash-iteration")]);

        let for_loop = "use std::collections::HashSet;\n\
                        fn f(s: HashSet<u32>) -> u32 {\n\
                        let mut t = 0;\n\
                        for v in s { t += v; }\n\
                        t\n\
                        }\n";
        assert_eq!(rules("rust/src/util/fake.rs", for_loop), vec![(4, "hash-iteration")]);
    }

    #[test]
    fn hash_iteration_catches_builder_chains_across_lines() {
        let src = "use std::collections::HashMap;\n\
                   struct S { cache: HashMap<String, u32> }\n\
                   impl S {\n\
                   fn dump(&self) -> Vec<String> {\n\
                   let mut v: Vec<String> = self.cache\n\
                   .iter()\n\
                   .map(|(k, _)| k.clone())\n\
                   .collect();\n\
                   v.sort();\n\
                   v\n\
                   }\n\
                   }\n";
        assert_eq!(rules("rust/src/util/fake.rs", src), vec![(6, "hash-iteration")]);
    }

    #[test]
    fn btreemap_iteration_is_fine() {
        let src = "use std::collections::BTreeMap;\n\
                   fn f(m: &BTreeMap<String, u32>) -> Vec<String> {\n\
                   m.keys().cloned().collect()\n\
                   }\n";
        assert!(rules("rust/src/util/fake.rs", src).is_empty());
    }

    #[test]
    fn relaxed_ordering_is_flagged() {
        let src = "fn f(x: &std::sync::atomic::AtomicUsize) -> usize {\n\
                   x.load(std::sync::atomic::Ordering::Relaxed)\n\
                   }\n";
        assert_eq!(rules("rust/src/util/fake.rs", src), vec![(2, "relaxed-ordering")]);
    }

    #[test]
    fn float_narrowing_only_fires_in_solver_dirs() {
        let src = "pub fn f(x: f64) -> f32 {\n    x as f32\n}\n";
        assert_eq!(rules("rust/src/sgl/fake.rs", src), vec![(2, "float-narrowing")]);
        assert_eq!(rules("rust/src/screening/fake.rs", src), vec![(2, "float-narrowing")]);
        assert!(rules("rust/src/util/fake.rs", src).is_empty());
    }

    #[test]
    fn widen_compute_narrow_on_one_line_passes() {
        let src = "pub fn f(x: f32, k: f32) -> f32 {\n    (x as f64 * k as f64) as f32\n}\n";
        assert!(rules("rust/src/sgl/fake.rs", src).is_empty());
    }

    #[test]
    fn thread_spawn_is_flagged_outside_pool_and_serve() {
        let src = "fn f() {\n    let h = std::thread::spawn(|| {});\n    h.join().unwrap();\n}\n";
        assert_eq!(rules("rust/src/sgl/fake.rs", src), vec![(2, "thread-spawn")]);
        assert!(rules("rust/src/util/pool.rs", src).is_empty());
        assert!(rules("rust/src/server/serve.rs", src).is_empty());
    }

    #[test]
    fn solver_timers_are_flagged() {
        let src = "fn f() -> std::time::Instant {\n    std::time::Instant::now()\n}\n";
        assert_eq!(rules("rust/src/screening/fake.rs", src), vec![(2, "solver-timers")]);
        assert!(rules("rust/src/server/fake.rs", src).is_empty());
    }

    #[test]
    fn test_regions_are_skipped() {
        let src = "pub fn run() {}\n\
                   \n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   use std::sync::atomic::{AtomicUsize, Ordering};\n\
                   fn helper(x: &AtomicUsize) -> usize {\n\
                   x.load(Ordering::Relaxed)\n\
                   }\n\
                   }\n";
        assert!(rules("rust/src/util/fake.rs", src).is_empty());
    }

    #[test]
    fn strings_and_comments_never_trigger() {
        let src = "fn f() -> String {\n\
                   // unsafe { } and Ordering::Relaxed in a comment\n\
                   /* thread::spawn in a block comment */\n\
                   let s = \"unsafe { Ordering::Relaxed }\".to_string();\n\
                   let r = r#\"thread::spawn(|| {})\"#;\n\
                   format!(\"{s}{r}\")\n\
                   }\n";
        assert!(rules("rust/src/util/fake.rs", src).is_empty());
    }

    #[test]
    fn char_literals_and_lifetimes_lex_cleanly() {
        let src = "fn f<'a>(x: &'a str) -> char {\n\
                   let c = '\"';\n\
                   let s = \"as f32\";\n\
                   if s.is_empty() { ' ' } else { c }\n\
                   }\n";
        assert!(rules("rust/src/sgl/fake.rs", src).is_empty());
    }

    #[test]
    fn allowlist_suppresses_and_tracks_usage() {
        let text = "# comment\nrelaxed-ordering | util/fake.rs | * | telemetry counter\n";
        let mut entries = parse_allowlist_text(text).unwrap();
        assert_eq!(entries.len(), 1);

        let src = "fn f(x: &std::sync::atomic::AtomicUsize) -> usize {\n\
                   x.load(std::sync::atomic::Ordering::Relaxed)\n\
                   }\n";
        let rel = "rust/src/util/fake.rs";
        let kept = filter_with_allowlist(rel, lint_source(rel, src), &mut entries);
        assert!(kept.is_empty());
        assert!(entries[0].used);

        // The same entry must not leak to other files.
        let vs2 = lint_source(rel, src);
        let other = filter_with_allowlist("rust/src/util/other.rs", vs2, &mut entries);
        assert_eq!(other.len(), 1);
    }

    #[test]
    fn stale_allowlist_entries_are_detectable() {
        let text = "float-narrowing | sgl/gone.rs | x as f32 | removed code\n";
        let entries = parse_allowlist_text(text).unwrap();
        assert!(!entries[0].used);
        assert_eq!(entries[0].line_no, 1);
    }

    #[test]
    fn malformed_allowlist_lines_are_rejected_with_position() {
        let err = parse_allowlist_text("rule-only\n").unwrap_err();
        assert!(err.contains("allowlist:1"), "{err}");
        assert!(err.contains("malformed"), "{err}");
    }

    #[test]
    fn fragment_matching_is_rule_path_and_line_scoped() {
        let e = AllowEntry {
            rule: "float-narrowing".to_string(),
            path: "sgl/fista.rs".to_string(),
            frag: "let stepf = step as f32".to_string(),
            line_no: 1,
            used: false,
        };
        let hit = |rule: &str, rel: &str, raw: &str| entry_matches(&e, rule, rel, raw);
        assert!(hit("float-narrowing", "rust/src/sgl/fista.rs", "let stepf = step as f32;"));
        assert!(!hit("float-narrowing", "rust/src/sgl/fista.rs", "let other = x as f32;"));
        assert!(!hit("float-narrowing", "rust/src/sgl/bcd.rs", "let stepf = step as f32;"));
        assert!(!hit("solver-timers", "rust/src/sgl/fista.rs", "let stepf = step as f32;"));
    }
}
