//! Group structure over the feature dimension.
//!
//! SGL partitions the `p` features into `G` contiguous groups
//! `X = [X_1 … X_G]` with `n_g` features each (the paper's eq. (2)).
//! Contiguity is without loss of generality — any partition can be made
//! contiguous by permuting columns, which the data generators do up front.

/// Immutable group partition of `0..p` into contiguous ranges.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupStructure {
    /// `offsets[g]..offsets[g+1]` are the feature indices of group `g`;
    /// length `G + 1`, `offsets[0] == 0`, strictly increasing.
    offsets: Vec<usize>,
    /// Map feature index -> group index (for O(1) lookups in the
    /// feature-layer rule).
    feature_group: Vec<u32>,
    /// Penalty weight per group; `√n_g` by default. Reduced problems carry
    /// the *original* group's weight — the penalty `λ₁√n_g‖β_g‖` keeps the
    /// full-problem `n_g` even after screened (certified-zero) features
    /// are dropped from the group, otherwise the reduced problem is not
    /// equivalent to the restricted full problem.
    weights: Vec<f64>,
}

impl GroupStructure {
    /// Build from per-group sizes with the standard `√n_g` weights.
    /// Panics on empty groups.
    pub fn from_sizes(sizes: &[usize]) -> GroupStructure {
        let weights: Vec<f64> = sizes.iter().map(|&s| (s as f64).sqrt()).collect();
        GroupStructure::from_sizes_weighted(sizes, &weights)
    }

    /// Build with explicit penalty weights (used for reduced problems).
    pub fn from_sizes_weighted(sizes: &[usize], weights: &[f64]) -> GroupStructure {
        assert!(!sizes.is_empty(), "at least one group required");
        assert_eq!(sizes.len(), weights.len(), "one weight per group");
        let mut offsets = Vec::with_capacity(sizes.len() + 1);
        offsets.push(0usize);
        for (g, &s) in sizes.iter().enumerate() {
            assert!(s > 0, "group {g} is empty");
            assert!(weights[g] > 0.0, "group {g} has nonpositive weight");
            offsets.push(offsets.last().unwrap() + s);
        }
        let p = *offsets.last().unwrap();
        let mut feature_group = vec![0u32; p];
        for g in 0..sizes.len() {
            for f in offsets[g]..offsets[g + 1] {
                feature_group[f] = g as u32;
            }
        }
        GroupStructure { offsets, feature_group, weights: weights.to_vec() }
    }

    /// `G` equal groups of size `p / n_groups` (requires divisibility).
    pub fn uniform(p: usize, n_groups: usize) -> GroupStructure {
        assert!(n_groups > 0 && p % n_groups == 0, "p={p} not divisible into {n_groups} groups");
        GroupStructure::from_sizes(&vec![p / n_groups; n_groups])
    }

    /// Trivial structure: every feature its own group (reduces SGL to
    /// (1+α)-scaled Lasso; used in tests).
    pub fn singletons(p: usize) -> GroupStructure {
        GroupStructure::from_sizes(&vec![1; p])
    }

    #[inline]
    pub fn n_groups(&self) -> usize {
        self.offsets.len() - 1
    }

    #[inline]
    pub fn n_features(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    /// Feature range `[start, end)` of group `g`.
    #[inline]
    pub fn range(&self, g: usize) -> (usize, usize) {
        (self.offsets[g], self.offsets[g + 1])
    }

    /// Size `n_g` of group `g`.
    #[inline]
    pub fn size(&self, g: usize) -> usize {
        self.offsets[g + 1] - self.offsets[g]
    }

    /// The group's penalty weight (`√n_g` unless explicitly overridden for
    /// a reduced problem).
    #[inline]
    pub fn weight(&self, g: usize) -> f64 {
        self.weights[g]
    }

    /// Group containing feature `f`.
    #[inline]
    pub fn group_of(&self, f: usize) -> usize {
        self.feature_group[f] as usize
    }

    /// All `(start, end)` ranges.
    pub fn ranges(&self) -> Vec<(usize, usize)> {
        (0..self.n_groups()).map(|g| self.range(g)).collect()
    }

    /// Iterator over `(g, start, end)`.
    pub fn iter(&self) -> impl Iterator<Item = (usize, usize, usize)> + '_ {
        (0..self.n_groups()).map(move |g| {
            let (s, e) = self.range(g);
            (g, s, e)
        })
    }

    /// Whether all groups have the same size (enables the uniform-group AOT
    /// kernels).
    pub fn is_uniform(&self) -> Option<usize> {
        let s = self.size(0);
        if (0..self.n_groups()).all(|g| self.size(g) == s) {
            Some(s)
        } else {
            None
        }
    }

    /// Restrict the structure to the features where `kept[i]` is true,
    /// dropping groups that lose every feature. Reduced groups carry the
    /// **original** penalty weights (dropped features are certified zero,
    /// so the group norm over the survivors equals the norm over the full
    /// group — same argument as [`Self::select_groups`]-based reduction).
    /// Returns `None` when nothing survives, otherwise the reduced
    /// structure plus the map reduced-group → original group index. Used by
    /// the solvers' dynamic GAP-safe eviction to compact the live problem
    /// mid-solve.
    pub fn compact(&self, kept: &[bool]) -> Option<(GroupStructure, Vec<usize>)> {
        assert_eq!(kept.len(), self.n_features(), "keep mask must cover every feature");
        let mut sizes = Vec::new();
        let mut weights = Vec::new();
        let mut group_map = Vec::new();
        for (g, s, e) in self.iter() {
            let k = kept[s..e].iter().filter(|&&b| b).count();
            if k > 0 {
                sizes.push(k);
                weights.push(self.weight(g));
                group_map.push(g);
            }
        }
        if sizes.is_empty() {
            return None;
        }
        Some((GroupStructure::from_sizes_weighted(&sizes, &weights), group_map))
    }

    /// Restrict to a subset of groups, producing the reduced structure
    /// (carrying the original weights) and the flat feature indices it
    /// came from (reduced-problem extraction).
    pub fn select_groups(&self, keep: &[usize]) -> (GroupStructure, Vec<usize>) {
        assert!(!keep.is_empty(), "cannot build an empty group structure");
        let sizes: Vec<usize> = keep.iter().map(|&g| self.size(g)).collect();
        let weights: Vec<f64> = keep.iter().map(|&g| self.weight(g)).collect();
        let mut features = Vec::with_capacity(sizes.iter().sum());
        for &g in keep {
            let (s, e) = self.range(g);
            features.extend(s..e);
        }
        (GroupStructure::from_sizes_weighted(&sizes, &weights), features)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_sizes_basic() {
        let g = GroupStructure::from_sizes(&[2, 3, 1]);
        assert_eq!(g.n_groups(), 3);
        assert_eq!(g.n_features(), 6);
        assert_eq!(g.range(1), (2, 5));
        assert_eq!(g.size(2), 1);
        assert!((g.weight(1) - 3f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn group_of_consistent() {
        let g = GroupStructure::from_sizes(&[2, 3, 1]);
        for f in 0..g.n_features() {
            let gr = g.group_of(f);
            let (s, e) = g.range(gr);
            assert!(f >= s && f < e);
        }
    }

    #[test]
    fn uniform_and_singletons() {
        let u = GroupStructure::uniform(10, 5);
        assert_eq!(u.is_uniform(), Some(2));
        let s = GroupStructure::singletons(4);
        assert_eq!(s.n_groups(), 4);
        assert_eq!(s.is_uniform(), Some(1));
        let r = GroupStructure::from_sizes(&[1, 2]);
        assert_eq!(r.is_uniform(), None);
    }

    #[test]
    fn select_groups_reduced() {
        let g = GroupStructure::from_sizes(&[2, 3, 1, 4]);
        let (red, feats) = g.select_groups(&[0, 2, 3]);
        assert_eq!(red.n_groups(), 3);
        assert_eq!(red.n_features(), 7);
        assert_eq!(feats, vec![0, 1, 5, 6, 7, 8, 9]);
        assert_eq!(red.range(1), (2, 3));
    }

    #[test]
    fn compact_drops_emptied_groups_and_keeps_weights() {
        let g = GroupStructure::from_sizes(&[2, 3, 1, 4]);
        // Empty group 1 entirely; shrink group 3 to one feature.
        let kept = vec![true, true, false, false, false, true, false, true, false, false];
        let (red, map) = g.compact(&kept).unwrap();
        assert_eq!(red.n_groups(), 3);
        assert_eq!(map, vec![0, 2, 3]);
        assert_eq!(red.size(0), 2);
        assert_eq!(red.size(1), 1);
        assert_eq!(red.size(2), 1);
        // Original weights survive (√4 for the shrunken group 3).
        assert!((red.weight(2) - 2.0).abs() < 1e-12);
        // Nothing kept → None.
        assert!(g.compact(&vec![false; 10]).is_none());
    }

    #[test]
    #[should_panic]
    fn empty_group_panics() {
        GroupStructure::from_sizes(&[2, 0, 1]);
    }

    #[test]
    #[should_panic]
    fn non_divisible_uniform_panics() {
        GroupStructure::uniform(10, 3);
    }
}
