//! Command-line interface (hand-rolled; clap is unavailable offline).
//!
//! Every path-solving command translates its flags into the same typed
//! [`SolveRequest`] the serve-mode wire protocol parses
//! ([`solve_request_from_args`] / [`dataset_spec_from_args`]), so the
//! batch CLI and the resident engine cannot drift: same dataset
//! materialization ([`LoadedData::load`]), same [`PathConfig`]
//! translation, same defaults ([`crate::config::Config::default`] over
//! [`crate::coordinator::SolveControls::default`]). Unknown flags are
//! typed errors naming the flag ([`Args::expect_known`]), like unknown
//! keys in the `--config` file and in wire requests.
//!
//! ```text
//! tlfre generate  --dataset synthetic1 --out ds.bin [--seed 42] [--scale 0.1]
//!                  [--stream] [--n 250] [--block-cols 256]
//! tlfre solve-path --dataset synthetic1|synthetic2|sparse1|adni-gmv|... [--alpha 1.0]
//!                  [--n-lambda 100] [--no-screening] [--verify] [--config cfg.json]
//!                  [--backend dense|csc|mmap|sharded] [--file ds.bin]
//!                  [--shards k] [--density 0.05]
//!                  [--checkpoint ck.tlfreck [--resume] [--checkpoint-every 5]
//!                   [--stop-after 7]] [--max-seconds 60]
//!                  [--validate-data|--no-validate] [--coef-out coefs.hex]
//! tlfre cv         --dataset ... [--k-folds 5] [--alpha 1.0] [--solver bcd]
//!                  [--cv-serial] [--backend dense|csc]
//! tlfre dpc-path   --dataset mnist|pie|... [--n-lambda 100] [--no-screening]
//!                  [--backend dense|csc|mmap|sharded] [--max-seconds 60]
//! tlfre lambda-max --dataset ... [--alpha 1.0] [--streaming] [--block-groups 64]
//! tlfre serve      --socket /tmp/tlfre.sock
//! tlfre client     --socket /tmp/tlfre.sock --kind solve-path --dataset ...
//!                  [--lambda-index 17] [--coef-out coefs.hex]
//! tlfre runtime-info
//! ```

use crate::bail;
use crate::config::Config;
use crate::coordinator::runner::{PathConfig, PathOutput, SolverKind};
use crate::coordinator::{
    cross_validate, cross_validate_serial, run_baseline_path, run_dpc_path, run_nonneg_baseline,
    run_tlfre_path, run_tlfre_path_checkpointed, run_tlfre_path_with_coefficients,
    CheckpointOptions, CvOutput, DpcPathConfig,
};
use crate::data::registry::scaled;
use crate::data::synthetic::{generate_synthetic_streaming, SyntheticSpec};
use crate::error::{Context, Result};
use crate::groups::GroupStructure;
use crate::linalg::{CscMatrix, DesignMatrix, MmapDenseMatrix, SelectRows};
use crate::server::api::{
    coef_hex_dump, BackendKind, DatasetSpec, RequestKind, SolveRequest, SolveResponse,
};
use crate::server::registry::LoadedData;
use crate::server::wire;
use crate::util::{fmt_duration, Timer};
// BTreeMap, not HashMap: `expect_known` iterates the keys to report an
// unknown flag, and the error must deterministically name the same flag
// on every run (repo invariant-lint rule `hash-iteration`).
use std::collections::BTreeMap;

// Re-exported so existing callers of `cli::resolve_dataset` keep working;
// the CLI itself materializes datasets through [`LoadedData::load`].
pub use crate::data::registry::resolve_dataset;

/// Flags every config-bearing command accepts (parsed by `common_config`);
/// [`Args::expect_known`] always allows these.
const CONFIG_FLAGS: &[&str] =
    &["config", "n-lambda", "min-ratio", "tol", "seed", "scale", "solver", "screen"];

/// Parsed command line: subcommand + flag map.
#[derive(Debug, Clone)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `--key value` flags and bare `--switch`es after a subcommand.
    pub fn parse(argv: &[String]) -> Result<Args> {
        if argv.is_empty() {
            bail!("no subcommand; try `tlfre help`");
        }
        let command = argv[0].clone();
        let mut flags = BTreeMap::new();
        let mut switches = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare switch
                if let Some((k, v)) = key.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    switches.push(key.to_string());
                }
            } else {
                bail!("unexpected positional argument '{a}'");
            }
            i += 1;
        }
        Ok(Args { command, flags, switches })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| crate::anyhow!("--{key}: cannot parse '{v}'")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Reject flags/switches this command does not accept: a typo like
    /// `--n-lamda` becomes a typed error naming the flag instead of a
    /// silently applied default. [`CONFIG_FLAGS`] are always allowed.
    pub fn expect_known(&self, flags: &[&str], switches: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !CONFIG_FLAGS.contains(&k.as_str()) && !flags.contains(&k.as_str()) {
                bail!("unknown flag --{k} for '{}' (see `tlfre help`)", self.command);
            }
        }
        for s in &self.switches {
            if !switches.contains(&s.as_str()) {
                bail!("unknown switch --{s} for '{}' (see `tlfre help`)", self.command);
            }
        }
        Ok(())
    }
}

/// Spec for the streaming generator (`generate --stream`): same scaled
/// dimensions as [`resolve_dataset`] but with an overridable row count so
/// files larger than RAM can be produced.
fn streaming_spec(name: &str, n: usize, scale: f64) -> Result<SyntheticSpec> {
    let p = scaled(10_000, scale);
    Ok(match name {
        "synthetic1" => SyntheticSpec::synthetic1_scaled(n, p, p / 10),
        "synthetic2" => SyntheticSpec::synthetic2_scaled(n, p, p / 10),
        other => bail!("--stream supports synthetic1|synthetic2, got '{other}'"),
    })
}

const HELP: &str = "\
tlfre — Two-Layer Feature Reduction for Sparse-Group Lasso (NIPS 2014 reproduction)

USAGE: tlfre <command> [flags]

COMMANDS:
  solve-path    run a TLFre-screened SGL λ-path on a dataset
  cv            k-fold cross-validation over the (α, λ) grid — one
                screened path walk per fold×α, sharded across the
                worker pool (bitwise identical to the serial sweep)
  dpc-path      run a DPC-screened nonnegative-Lasso λ-path
  generate      generate a dataset and save it to disk
  lambda-max    print λmax^α and the Corollary 10 curve sample
  serve         start the resident path-serving engine on a unix socket
                (datasets and completed path prefixes stay loaded across
                requests; served results are bitwise identical to batch
                runs — see rust/src/server/README.md)
  client        send one request to a running serve engine
  runtime-info  probe the PJRT runtime and list artifacts
  help          this text

COMMON FLAGS:
  --dataset <name>     synthetic1|synthetic2|sparse1|adni-gmv|adni-wmv|
                       breast-cancer|leukemia|prostate|pie|mnist|svhn
  --backend <name>     design-matrix backend: dense (default) | csc | mmap |
                       sharded (csc converts dense sets; sparse1 is
                       CSC-native; mmap pages X from a TLFREDS1 file on
                       disk; sharded splits rows across the worker pool —
                       all backends produce bitwise-identical paths)
  --file <path>        mmap backend: existing TLFREDS1 file to map (without
                       it the dataset is saved to a temp file first)
  --shards <usize>     sharded backend: row-shard count (default: one per
                       pool worker)
  --density <f64>      nonzero fraction for the sparse1 generator (default 0.05)
  --stream             generate: write X in column blocks with bounded
                       memory (synthetic1|synthetic2; byte-identical file)
  --n <usize>          generate --stream: row count override (default 250)
  --block-cols <usize> generate --stream: columns per block (default 256)
  --streaming          lambda-max: column-blocked streaming computation
                       (bitwise identical to the in-RAM value)
  --block-groups <g>   lambda-max --streaming: groups per block (default 64)
  --seed <u64>         dataset seed (default 42)
  --scale <f64>        feature-dimension scale for simulated sets (default 0.1)
  --alpha <f64>        SGL α (default 1.0)
  --n-lambda <usize>   λ grid size (default 100)
  --min-ratio <f64>    λmin/λmax (default 0.01)
  --tol <f64>          relative duality-gap tolerance (default 1e-6)
  --solver <name>      path solver: fista (default) | bcd
  --screen <name>      screening pipeline: tlfre (default, the paper's
                       exact two-layer rule) | tlfre+gap | gap (GAP-safe
                       static rules + dynamic in-solver screening) |
                       strong+kkt (heuristic + KKT recovery) | ws |
                       tlfre+ws | ws+gap (celer-style working sets:
                       loose solves on a small heuristic set, geometric
                       growth on KKT violation, one tight final solve;
                       same support/coefficients as the safe rules) | none
  --ws-max-rounds <K>  working-set pipelines: outer-round cap before the
                       set falls back to the full safe survivor set
                       (default 20, must be ≥ 2)
  --ws-growth <f64>    working-set geometric growth factor per violating
                       round (default 2.0, must be > 1)
  --config <path>      JSON config (overridden by explicit flags)
  --k-folds <usize>    CV fold count (cv command; default 5)
  --cv-serial          run CV folds serially on one thread (reference
                       sweep; output is bitwise identical either way)
  --no-screening       baseline path without screening
  --verify             re-solve unscreened each step and assert safety
  --refresh-every <K>  re-estimate survivor-view Lipschitz data every K
                       path steps (0 = cached full-matrix constants, the
                       default; counted as screening time)
  --parallel-bcd       red-black pool-parallel BCD group sweeps (bcd
                       solver, sparse backends; bitwise identical to the
                       sequential sweep)
  --dynamic            dpc-path: GAP-safe dynamic screening inside the
                       nonneg solver (evictions per λ in the 'dyn' column)
  --checkpoint <path>  solve-path: record completed λ steps to an atomic
                       TLFRECK1 sidecar every K steps so a killed run can
                       continue (screened engine only)
  --resume             solve-path: continue the run recorded in the
                       --checkpoint sidecar; the continuation is bitwise
                       identical to the uninterrupted path at every
                       TLFRE_THREADS (a problem/config mismatch is a typed
                       error, never a silent restart)
  --checkpoint-every K checkpoint save cadence in completed λ steps
                       (default 5; a kill loses at most K-1 steps)
  --stop-after <K>     solve-path --checkpoint: stop cleanly after K total
                       completed λ steps (deterministic stand-in for a
                       mid-path kill; used by the CI resume smoke)
  --max-seconds <S>    wall-clock budget for the whole path (solve-path,
                       dpc-path, serve requests); an expiring solve
                       returns its best iterate with a certified
                       suboptimality bound, and the path truncates to a
                       clean completed prefix
  --validate-data      pre-solve scan of X/y: NaN/Inf entries, zero-norm
                       columns, empty groups → typed error naming the
                       coordinate (default for --file-backed inputs)
  --no-validate        skip the pre-solve data scan
  --coef-out <path>    solve-path / client: per-λ coefficient dump, one
                       line per step, each f32 as its 8-hex-digit bit
                       pattern — byte-stable for diffing runs/backends
  --socket <path>      serve/client: unix socket the engine listens on
  --kind <name>        client: load-dataset|solve-path|solve-point|cv|
                       stats|shutdown
  --lambda-index <i>   client --kind solve-point: 0-based λ grid index
                       (0 = λmax)
  --out <path>         output file (generate / JSON reports)
";

/// Entry point used by `main.rs`.
pub fn run(argv: &[String]) -> Result<i32> {
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n\n{HELP}");
            return Ok(2);
        }
    };
    match args.command.as_str() {
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(0)
        }
        "generate" => cmd_generate(&args),
        "solve-path" => cmd_solve_path(&args),
        "cv" => cmd_cv(&args),
        "dpc-path" => cmd_dpc_path(&args),
        "lambda-max" => cmd_lambda_max(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "runtime-info" => cmd_runtime_info(),
        other => {
            eprintln!("unknown command '{other}'\n\n{HELP}");
            Ok(2)
        }
    }
}

fn common_config(args: &Args) -> Result<Config> {
    let mut cfg = match args.get("config") {
        Some(p) => Config::from_file(std::path::Path::new(p))?,
        None => Config::default(),
    };
    if let Some(v) = args.get_parsed::<usize>("n-lambda")? {
        cfg.n_lambda = v;
    }
    if let Some(v) = args.get_parsed::<f64>("min-ratio")? {
        cfg.lambda_min_ratio = v;
    }
    if let Some(v) = args.get_parsed::<f64>("tol")? {
        cfg.tol = v;
    }
    if let Some(v) = args.get_parsed::<u64>("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = args.get_parsed::<f64>("scale")? {
        cfg.scale = v;
    }
    if let Some(v) = args.get("solver") {
        cfg.solver =
            SolverKind::parse(v).with_context(|| format!("unknown solver '{v}' (fista|bcd)"))?;
    }
    if let Some(v) = args.get("screen") {
        cfg.screen = crate::screening::ScreenKind::parse(v).with_context(|| {
            format!(
                "unknown screening pipeline '{v}' \
                 (tlfre|tlfre+gap|gap|strong+kkt|ws|tlfre+ws|ws+gap|none)"
            )
        })?;
    }
    Ok(cfg)
}

/// Build the [`DatasetSpec`] a command's flags describe — the same struct
/// a serve-mode request carries, so CLI and wire dataset resolution are
/// one code path ([`LoadedData::load`]).
fn dataset_spec_from_args(args: &Args, cfg: &Config) -> Result<DatasetSpec> {
    let name = args.get("dataset").context("--dataset is required")?;
    let mut spec = DatasetSpec::new(name);
    spec.seed = cfg.seed;
    spec.scale = cfg.scale;
    if let Some(b) = args.get("backend") {
        spec.backend = BackendKind::parse(b)
            .with_context(|| format!("unknown backend '{b}' (dense|csc|mmap|sharded)"))?;
    }
    if let Some(d) = args.get_parsed::<f64>("density")? {
        if !(d > 0.0 && d <= 1.0) {
            bail!("--density must be in (0, 1], got {d}");
        }
        spec.density = d;
    }
    spec.file = args.get("file").map(str::to_string);
    if let Some(k) = args.get_parsed::<usize>("shards")? {
        if k == 0 {
            bail!("--shards must be ≥ 1");
        }
        spec.shards = Some(k);
    }
    Ok(spec)
}

/// Translate parsed flags into the typed [`SolveRequest`] the engine
/// executes — the same struct the wire JSON parses into, so the batch
/// commands, the `client` command, and serve mode cannot drift.
fn solve_request_from_args(args: &Args, cfg: &Config, kind: RequestKind) -> Result<SolveRequest> {
    let mut req = SolveRequest::new(kind);
    req.solver = cfg.solver;
    req.screen = cfg.screen;
    req.controls = cfg.controls;
    req.parallel_bcd_groups = cfg.parallel_bcd_groups || args.has("parallel-bcd");
    req.controls.verify_safety = req.controls.verify_safety || args.has("verify");
    if let Some(k) = args.get_parsed::<usize>("refresh-every")? {
        req.controls.lipschitz_refresh_every = if k == 0 { None } else { Some(k) };
    }
    if let Some(s) = args.get_parsed::<f64>("max-seconds")? {
        if !(s.is_finite() && s > 0.0) {
            bail!("--max-seconds must be positive and finite, got {s}");
        }
        req.controls.max_seconds = Some(s);
    }
    if let Some(k) = args.get_parsed::<usize>("ws-max-rounds")? {
        if k < 2 {
            bail!("--ws-max-rounds must be ≥ 2, got {k}");
        }
        req.controls.ws_max_rounds = k;
    }
    if let Some(g) = args.get_parsed::<f64>("ws-growth")? {
        if !(g > 1.0 && g.is_finite()) {
            bail!("--ws-growth must be a finite factor > 1, got {g}");
        }
        req.controls.ws_growth = g;
    }
    match args.get_parsed::<f64>("alpha")? {
        Some(a) => {
            if !(a > 0.0 && a.is_finite()) {
                bail!("--alpha must be positive and finite, got {a}");
            }
            req.alpha = a;
            req.alphas = vec![a];
        }
        None => req.alphas = cfg.alphas.clone(),
    }
    match args.get_parsed::<usize>("k-folds")? {
        Some(k) if k < 2 => bail!("--k-folds must be ≥ 2"),
        Some(k) => req.k_folds = k,
        None => req.k_folds = cfg.k_folds,
    }
    if kind.needs_dataset() {
        req.dataset = Some(dataset_spec_from_args(args, cfg)?);
    }
    req.lambda_index = args.get_parsed::<usize>("lambda-index")?;
    if kind == RequestKind::SolvePoint {
        let idx = req.lambda_index.context("--lambda-index is required for solve-point")?;
        if idx >= req.controls.n_lambda {
            bail!(
                "--lambda-index {idx} out of range for the {}-point grid",
                req.controls.n_lambda
            );
        }
    }
    Ok(req)
}

fn cmd_generate(args: &Args) -> Result<i32> {
    args.expect_known(&["dataset", "out", "n", "block-cols"], &["stream"])?;
    let cfg = common_config(args)?;
    let name = args.get("dataset").context("--dataset is required")?;
    let out = args.get("out").context("--out is required")?;
    if args.has("stream") {
        // Bounded-memory path: X goes to disk in column blocks and is never
        // resident as a whole; the file is byte-identical to the in-RAM save.
        let n = args.get_parsed::<usize>("n")?.unwrap_or(250);
        let block_cols = args.get_parsed::<usize>("block-cols")?.unwrap_or(256).max(1);
        let spec = streaming_spec(name, n, cfg.scale)?;
        generate_synthetic_streaming(&spec, cfg.seed, std::path::Path::new(out), block_cols)?;
        let bytes = std::fs::metadata(out)?.len();
        println!(
            "streamed {} ({} groups) to {out}: {bytes} bytes, {block_cols}-column blocks",
            spec.name, spec.n_groups
        );
        return Ok(0);
    }
    let ds = resolve_dataset(name, cfg.seed, cfg.scale)?;
    crate::data::io::save(&ds, std::path::Path::new(out))?;
    println!("wrote {} to {out}", ds.describe());
    Ok(0)
}

fn cmd_solve_path(args: &Args) -> Result<i32> {
    args.expect_known(
        &[
            "dataset",
            "alpha",
            "backend",
            "file",
            "shards",
            "density",
            "refresh-every",
            "max-seconds",
            "ws-max-rounds",
            "ws-growth",
            "checkpoint",
            "checkpoint-every",
            "stop-after",
            "coef-out",
            "out",
        ],
        &["verify", "parallel-bcd", "no-screening", "resume", "validate-data", "no-validate"],
    )?;
    let cfg = common_config(args)?;
    let req = solve_request_from_args(args, &cfg, RequestKind::SolvePath)?;
    let pc = req.path_config();
    let spec = req.dataset.as_ref().expect("solve-path requests carry a dataset");
    let data = LoadedData::load(spec)?;
    println!("{}", data.describe());
    match &data {
        LoadedData::Dense(d) => {
            run_sgl_path(args, &d.x, &d.y, &d.groups, &pc, &d.name, req.alpha)
        }
        LoadedData::Csc(d) => {
            println!("csc backend: nnz {} ({:.2}% dense)", d.x.nnz(), d.x.density() * 100.0);
            run_sgl_path(args, &d.x, &d.y, &d.groups, &pc, &d.name, req.alpha)
        }
        LoadedData::Mmap(m) => {
            println!(
                "{} backend: {}×{} X payload, {} MiB on disk",
                MmapDenseMatrix::backend_kind(),
                m.ds.x.rows(),
                m.ds.x.cols(),
                m.ds.x.x_payload_bytes() >> 20
            );
            run_sgl_path(args, &m.ds.x, &m.ds.y, &m.ds.groups, &pc, &m.ds.name, req.alpha)
        }
        LoadedData::Sharded(d) => {
            println!("sharded backend: {} row shards over {} rows", d.x.n_shards(), d.x.rows());
            run_sgl_path(args, &d.x, &d.y, &d.groups, &pc, &d.name, req.alpha)
        }
    }
}

/// Run a (screened or baseline) SGL path on any backend and render output.
fn run_sgl_path<M: DesignMatrix>(
    args: &Args,
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    pc: &PathConfig,
    name: &str,
    alpha: f64,
) -> Result<i32> {
    // Pre-solve validation: on by default when the bytes came from outside
    // the process (`--file`), opt-in (`--validate-data`) for generated
    // data, and `--no-validate` always wins.
    let file_backed = args.get("file").is_some();
    if (args.has("validate-data") || file_backed) && !args.has("no-validate") {
        let vt = Timer::start();
        crate::data::validate::validate_problem(x, y, groups)
            .context("input validation failed (--no-validate skips this scan)")?;
        println!(
            "validated X/y: all entries finite, no zero-norm columns, no empty groups ({})",
            fmt_duration(vt.elapsed_s())
        );
    }

    let want_coefs = args.get("coef-out").is_some();
    if want_coefs && args.has("no-screening") {
        bail!("--coef-out requires the screened path (drop --no-screening)");
    }
    let t = Timer::start();
    let (out, betas): (PathOutput, Option<Vec<Vec<f32>>>) = match args.get("checkpoint") {
        Some(ck) => {
            if args.has("no-screening") {
                bail!("--checkpoint requires the screened TLFre engine (drop --no-screening)");
            }
            let mut opts = CheckpointOptions::new(ck);
            if let Some(k) = args.get_parsed::<usize>("checkpoint-every")? {
                if k == 0 {
                    bail!("--checkpoint-every must be ≥ 1");
                }
                opts.every = k;
            }
            opts.resume = args.has("resume");
            opts.stop_after = args.get_parsed::<usize>("stop-after")?;
            let (out, betas) = run_tlfre_path_checkpointed(x, y, groups, pc, &opts)?;
            (out, Some(betas))
        }
        None if args.has("no-screening") => (run_baseline_path(x, y, groups, pc), None),
        None if want_coefs => {
            let (out, betas) = run_tlfre_path_with_coefficients(x, y, groups, pc);
            (out, Some(betas))
        }
        None => (run_tlfre_path(x, y, groups, pc), None),
    };
    let wall = t.elapsed_s();
    println!(
        "{}",
        crate::bench_harness::tables::render_rejection_series(&format!("{name} α={alpha}"), &out)
    );
    if out.truncated {
        println!(
            "path truncated: {} of {} grid points completed (--max-seconds / --stop-after)",
            out.steps.len(),
            pc.n_lambda
        );
    }
    let exhausted = out.steps.iter().filter(|s| s.budget_exhausted).count();
    if exhausted > 0 {
        let worst = out
            .steps
            .iter()
            .filter(|s| s.budget_exhausted)
            .map(|s| s.certified_suboptimality)
            .fold(0.0f64, f64::max);
        println!(
            "{exhausted} step(s) stopped early; worst certified suboptimality {worst:.3e}"
        );
    }
    println!(
        "screen {}  solve {}  wall {}",
        fmt_duration(out.screen_total_s),
        fmt_duration(out.solve_total_s),
        fmt_duration(wall)
    );
    if let Some(path) = args.get("coef-out") {
        let betas = betas.expect("coefficients are captured whenever --coef-out is set");
        std::fs::write(path, coef_hex_dump(&betas))
            .with_context(|| format!("writing --coef-out {path}"))?;
        println!("coefficient bit dump ({} steps) written to {path}", betas.len());
    }
    if let Some(path) = args.get("out") {
        std::fs::write(
            path,
            crate::bench_harness::tables::series_to_json(&out).to_string_pretty(),
        )?;
        println!("json written to {path}");
    }
    Ok(0)
}

fn cmd_cv(args: &Args) -> Result<i32> {
    args.expect_known(
        &["dataset", "alpha", "backend", "k-folds", "refresh-every", "ws-max-rounds", "ws-growth"],
        &["cv-serial", "parallel-bcd"],
    )?;
    let cfg = common_config(args)?;
    let req = solve_request_from_args(args, &cfg, RequestKind::Cv)?;
    let pc = req.path_config();
    let (alphas, k_folds) = (&req.alphas, req.k_folds);
    let spec = req.dataset.as_ref().expect("cv requests carry a dataset");
    let data = LoadedData::load(spec)?;
    println!("{}", data.describe());
    let t = Timer::start();
    let out = match &data {
        LoadedData::Dense(d) => {
            run_cv(&d.x, &d.y, &d.groups, alphas, k_folds, &pc, cfg.seed, args)
        }
        LoadedData::Csc(d) => {
            println!("csc backend: nnz {} ({:.2}% dense)", d.x.nnz(), d.x.density() * 100.0);
            run_cv(&d.x, &d.y, &d.groups, alphas, k_folds, &pc, cfg.seed, args)
        }
        other => bail!("cv supports dense|csc backends, got '{}'", other.backend().as_str()),
    };
    let wall = t.elapsed_s();
    println!(
        "cv: {k_folds} folds × {} α × {} λ = {} fold-paths ({} grid points){}",
        alphas.len(),
        pc.n_lambda,
        k_folds * alphas.len(),
        out.points.len(),
        if args.has("cv-serial") { ", serial sweep" } else { "" },
    );
    check_cv_grid(&out)?;
    println!(
        "best: α={:.4}  λ/λmax={:.4}  mse={:.6}  mean nnz={:.1}",
        out.best.alpha, out.best.lambda_ratio, out.best.mse, out.best.mean_nnz
    );
    println!(
        "screen {}  solve {}  wall {}",
        fmt_duration(out.screen_total_s),
        fmt_duration(out.solve_total_s),
        fmt_duration(wall)
    );
    Ok(0)
}

/// Post-CV grid verdict: a partially non-finite grid is a warning (those
/// points are skipped in model selection), but a grid with *no* finite
/// point means `best` is meaningless — fail loudly with a nonzero exit
/// instead of reporting a garbage model.
fn check_cv_grid(out: &CvOutput) -> Result<()> {
    if out.nonfinite_points > 0 {
        println!(
            "warning: {} grid point(s) with non-finite MSE skipped in model selection",
            out.nonfinite_points
        );
    }
    if !out.points.is_empty() && out.nonfinite_points == out.points.len() {
        bail!(
            "cross-validation failed: all {} (α, λ) grid points have non-finite held-out MSE — \
             the data or solves are degenerate, there is no model to select",
            out.points.len()
        );
    }
    Ok(())
}

/// Dispatch CV on the sharded or serial sweep (same output bitwise).
#[allow(clippy::too_many_arguments)]
fn run_cv<M: DesignMatrix + SelectRows>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    alphas: &[f64],
    k_folds: usize,
    pc: &PathConfig,
    seed: u64,
    args: &Args,
) -> CvOutput {
    if args.has("cv-serial") {
        cross_validate_serial(x, y, groups, alphas, k_folds, pc, seed)
    } else {
        cross_validate(x, y, groups, alphas, k_folds, pc, seed)
    }
}

fn cmd_dpc_path(args: &Args) -> Result<i32> {
    args.expect_known(
        &["dataset", "backend", "file", "shards", "density", "refresh-every", "max-seconds"],
        &["verify", "dynamic", "no-screening"],
    )?;
    let cfg = common_config(args)?;
    let req = solve_request_from_args(args, &cfg, RequestKind::SolvePath)?;
    let pc = DpcPathConfig { controls: req.controls, dynamic_screening: args.has("dynamic") };
    let baseline = args.has("no-screening");
    let spec = req.dataset.as_ref().expect("dpc-path requests carry a dataset");
    let data = LoadedData::load(spec)?;
    println!("{}", data.describe());
    let out = match &data {
        LoadedData::Dense(d) => {
            if baseline {
                run_nonneg_baseline(&d.x, &d.y, &pc)
            } else {
                run_dpc_path(&d.x, &d.y, &pc)
            }
        }
        LoadedData::Csc(d) => {
            println!("csc backend: nnz {} ({:.2}% dense)", d.x.nnz(), d.x.density() * 100.0);
            if baseline {
                run_nonneg_baseline(&d.x, &d.y, &pc)
            } else {
                run_dpc_path(&d.x, &d.y, &pc)
            }
        }
        LoadedData::Mmap(m) => {
            println!(
                "{} backend: {}×{} X payload, {} MiB on disk",
                MmapDenseMatrix::backend_kind(),
                m.ds.x.rows(),
                m.ds.x.cols(),
                m.ds.x.x_payload_bytes() >> 20
            );
            if baseline {
                run_nonneg_baseline(&m.ds.x, &m.ds.y, &pc)
            } else {
                run_dpc_path(&m.ds.x, &m.ds.y, &pc)
            }
        }
        LoadedData::Sharded(d) => {
            println!("sharded backend: {} row shards over {} rows", d.x.n_shards(), d.x.rows());
            if baseline {
                run_nonneg_baseline(&d.x, &d.y, &pc)
            } else {
                run_dpc_path(&d.x, &d.y, &pc)
            }
        }
    };
    println!("{}", crate::bench_harness::tables::render_dpc_series(data.name(), &out));
    if out.truncated {
        println!(
            "path truncated: {} of {} grid points completed (--max-seconds)",
            out.steps.len(),
            pc.n_lambda
        );
    }
    let exhausted = out.steps.iter().filter(|s| s.budget_exhausted).count();
    if exhausted > 0 {
        println!("{exhausted} step(s) stopped before convergence (wall-clock budget)");
    }
    println!(
        "screen {}  solve {}",
        fmt_duration(out.screen_total_s),
        fmt_duration(out.solve_total_s)
    );
    Ok(0)
}

fn cmd_serve(args: &Args) -> Result<i32> {
    args.expect_known(&["socket"], &[])?;
    let socket = args.get("socket").context("--socket is required")?;
    println!(
        "tlfre serve: listening on {socket} ({} pool workers); \
         SIGTERM or a shutdown request stops cleanly",
        crate::util::pool::num_threads()
    );
    crate::server::serve(std::path::Path::new(socket))?;
    println!("tlfre serve: shut down cleanly");
    Ok(0)
}

fn cmd_client(args: &Args) -> Result<i32> {
    args.expect_known(
        &[
            "socket",
            "kind",
            "dataset",
            "backend",
            "file",
            "shards",
            "density",
            "alpha",
            "lambda-index",
            "k-folds",
            "refresh-every",
            "max-seconds",
            "ws-max-rounds",
            "ws-growth",
            "coef-out",
            "out",
        ],
        &["verify", "parallel-bcd"],
    )?;
    let socket = args.get("socket").context("--socket is required")?;
    let kind_s = args
        .get("kind")
        .context("--kind is required (load-dataset|solve-path|solve-point|cv|stats|shutdown)")?;
    let kind = RequestKind::parse(kind_s).with_context(|| {
        format!("unknown kind '{kind_s}' (load-dataset|solve-path|solve-point|cv|stats|shutdown)")
    })?;
    let cfg = common_config(args)?;
    let req = solve_request_from_args(args, &cfg, kind)?;
    let body = req.to_json().to_string_compact();
    let (status, text) = wire::call(std::path::Path::new(socket), &body)?;
    if status != 200 {
        bail!("server answered {status}: {text}");
    }
    let resp = SolveResponse::parse(&text)?;
    if !resp.ok {
        bail!("'{}' request failed: {}", kind.as_str(), resp.error.unwrap_or_default());
    }
    render_response(args, &resp)
}

/// Render a successful serve-mode response (and write `--coef-out` /
/// `--out` side files).
fn render_response(args: &Args, resp: &SolveResponse) -> Result<i32> {
    let warm = if resp.warm { " [warm: served from the resident path cache]" } else { "" };
    if resp.dataset.is_empty() {
        println!("{} ok{warm}", resp.kind.as_str());
    } else {
        println!("{} ok — {}{warm}", resp.kind.as_str(), resp.dataset);
    }
    match resp.kind {
        RequestKind::SolvePath => {
            println!(
                "λmax = {:.6}; {} of {} grid points{}",
                resp.lambda_max,
                resp.steps.len(),
                resp.grid.len(),
                if resp.truncated { " (truncated: wall-clock budget)" } else { "" }
            );
        }
        RequestKind::SolvePoint => {
            println!(
                "λ = {:.6} (grid index {}); certified suboptimality {:.3e}",
                resp.lambda.unwrap_or(f64::NAN),
                args.get("lambda-index").unwrap_or("?"),
                resp.certified_suboptimality.unwrap_or(f64::INFINITY)
            );
        }
        RequestKind::LoadDataset | RequestKind::Cv | RequestKind::Stats => {
            print!("{}", resp.payload.to_string_pretty());
        }
        RequestKind::Shutdown => println!("server is shutting down"),
    }
    if resp.screen_total_s > 0.0 || resp.solve_total_s > 0.0 {
        println!(
            "screen {}  solve {}{}",
            fmt_duration(resp.screen_total_s),
            fmt_duration(resp.solve_total_s),
            if resp.warm { " (paid by an earlier request)" } else { "" }
        );
    }
    if let Some(path) = args.get("coef-out") {
        std::fs::write(path, resp.coef_dump())
            .with_context(|| format!("writing --coef-out {path}"))?;
        println!("coefficient bit dump ({} line(s)) written to {path}", resp.coef_hex.len());
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, resp.to_json().to_string_pretty())
            .with_context(|| format!("writing --out {path}"))?;
        println!("json written to {path}");
    }
    Ok(0)
}

fn cmd_lambda_max(args: &Args) -> Result<i32> {
    args.expect_known(&["dataset", "alpha", "block-groups"], &["streaming"])?;
    let cfg = common_config(args)?;
    let name = args.get("dataset").context("--dataset is required")?;
    let alpha: f64 = args.get_parsed("alpha")?.unwrap_or(1.0);
    let ds = resolve_dataset(name, cfg.seed, cfg.scale)?;
    let prob = crate::sgl::SglProblem::new(&ds.x, &ds.y, &ds.groups);
    let lm = if args.has("streaming") {
        // Column-blocked visit of X; bitwise identical to the in-RAM result.
        let block_groups = args.get_parsed::<usize>("block-groups")?.unwrap_or(64).max(1);
        crate::screening::sgl_lambda_max_streaming(&prob, alpha, block_groups)
    } else {
        crate::screening::sgl_lambda_max(&prob, alpha)
    };
    println!("{}", ds.describe());
    println!("λmax^α(α={alpha}) = {:.6} (argmax group {})", lm.lambda_max, lm.argmax_group);
    // Corollary 10 curve sample.
    println!("Corollary 10 boundary λ₁max(λ₂):");
    for frac in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let l2 = lm.lambda_max * frac;
        let l1 = crate::screening::lambda_max::lambda1_max(&prob, l2);
        println!("  λ₂ = {l2:10.4} → λ₁max = {l1:10.4}");
    }
    Ok(0)
}

fn cmd_runtime_info() -> Result<i32> {
    let mut rt = match crate::runtime::Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!("PJRT runtime unavailable: {e:#}");
            println!("(pjrt compiled in: {})", crate::runtime::pjrt_available());
            return Ok(0);
        }
    };
    println!("PJRT platform: {}", rt.platform());
    let dir = crate::runtime::artifacts_dir();
    match crate::runtime::ArtifactManifest::load(&dir) {
        Ok(m) => {
            println!("artifacts in {:?}:", dir);
            for a in &m.artifacts {
                let path = m.path_of(a);
                let status = match rt.load(&path) {
                    Ok(_) => "compiles OK",
                    Err(_) => "FAILED to compile",
                };
                println!(
                    "  {:24} kind={:14} n={:6} p={:7} gs={:4}  {}",
                    a.name, a.kind, a.n, a.p, a.group_size, status
                );
            }
        }
        Err(e) => println!("no artifact manifest: {e:#}"),
    }
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_flags_and_switches() {
        let a = Args::parse(&sv(&[
            "solve-path",
            "--dataset",
            "synthetic1",
            "--alpha=2.5",
            "--verify",
            "--n-lambda",
            "10",
        ]))
        .unwrap();
        assert_eq!(a.command, "solve-path");
        assert_eq!(a.get("dataset"), Some("synthetic1"));
        assert_eq!(a.get_parsed::<f64>("alpha").unwrap(), Some(2.5));
        assert_eq!(a.get_parsed::<usize>("n-lambda").unwrap(), Some(10));
        assert!(a.has("verify"));
        assert!(!a.has("no-screening"));
    }

    #[test]
    fn parse_rejects_positional() {
        assert!(Args::parse(&sv(&["solve-path", "oops"])).is_err());
        assert!(Args::parse(&sv(&[])).is_err());
    }

    #[test]
    fn bad_parse_value_errors() {
        let a = Args::parse(&sv(&["x", "--alpha", "abc"])).unwrap();
        assert!(a.get_parsed::<f64>("alpha").is_err());
    }

    #[test]
    fn unknown_flags_and_switches_are_typed_errors() {
        let a =
            Args::parse(&sv(&["solve-path", "--dataset", "synthetic1", "--n-lamda", "10"]))
                .unwrap();
        let err = a.expect_known(&["dataset"], &[]).unwrap_err();
        assert!(format!("{err:#}").contains("--n-lamda"), "{err:#}");
        let a = Args::parse(&sv(&["dpc-path", "--dataset", "mnist", "--verfy"])).unwrap();
        let err = a.expect_known(&["dataset"], &["verify"]).unwrap_err();
        assert!(format!("{err:#}").contains("--verfy"), "{err:#}");
        // Config flags are always allowed; known flags/switches pass.
        let a = Args::parse(&sv(&["cv", "--seed", "7", "--dataset", "x", "--cv-serial"]))
            .unwrap();
        assert!(a.expect_known(&["dataset"], &["cv-serial"]).is_ok());
    }

    #[test]
    fn cli_flags_translate_into_the_wire_request() {
        let a = Args::parse(&sv(&[
            "client",
            "--dataset",
            "sparse1",
            "--backend",
            "csc",
            "--alpha",
            "0.5",
            "--n-lambda",
            "12",
            "--max-seconds",
            "5",
            "--lambda-index",
            "3",
            "--screen",
            "tlfre+ws",
            "--ws-max-rounds",
            "9",
            "--ws-growth",
            "1.5",
            "--parallel-bcd",
        ]))
        .unwrap();
        let cfg = common_config(&a).unwrap();
        let req = solve_request_from_args(&a, &cfg, RequestKind::SolvePoint).unwrap();
        assert_eq!(req.alpha, 0.5);
        assert_eq!(req.controls.n_lambda, 12);
        assert_eq!(req.controls.max_seconds, Some(5.0));
        assert_eq!(req.lambda_index, Some(3));
        assert_eq!(req.screen, crate::screening::ScreenKind::TlfreWs);
        assert_eq!(req.controls.ws_max_rounds, 9);
        assert_eq!(req.controls.ws_growth, 1.5);
        assert!(req.parallel_bcd_groups);
        let spec = req.dataset.as_ref().unwrap();
        assert_eq!(spec.name, "sparse1");
        assert_eq!(spec.backend, BackendKind::Csc);
        // The flag translation round-trips through the wire schema.
        let back = SolveRequest::parse(&req.to_json().to_string_compact()).unwrap();
        assert_eq!(req, back);
        // Out-of-range point index is a typed error at translation time.
        let a = Args::parse(&sv(&[
            "client",
            "--dataset",
            "synthetic1",
            "--n-lambda",
            "4",
            "--lambda-index",
            "4",
        ]))
        .unwrap();
        let cfg = common_config(&a).unwrap();
        assert!(solve_request_from_args(&a, &cfg, RequestKind::SolvePoint).is_err());
    }

    #[test]
    fn resolve_known_datasets() {
        let ds = resolve_dataset("synthetic1", 1, 0.01).unwrap();
        assert_eq!(ds.n(), 250);
        assert!(resolve_dataset("nope", 1, 0.01).is_err());
    }

    #[test]
    fn scaled_is_divisible_by_ten() {
        for s in [0.01, 0.037, 0.1, 1.0] {
            assert_eq!(scaled(10_000, s) % 10, 0);
        }
        assert_eq!(scaled(10_000, 1.0), 10_000);
    }

    #[test]
    fn coef_hex_dump_is_bit_exact() {
        let betas = vec![vec![0.0f32, 1.0, -2.5], vec![f32::MIN_POSITIVE, 0.0, 0.0]];
        let dump = coef_hex_dump(&betas);
        // 1.0f32 = 0x3f800000, -2.5f32 = 0xc0200000, MIN_POSITIVE = 0x00800000.
        assert_eq!(dump, "00000000 3f800000 c0200000\n00800000 00000000 00000000\n");
        // Bit patterns round-trip: the dump distinguishes -0.0 from 0.0.
        assert!(coef_hex_dump(&[vec![-0.0f32]]).starts_with("80000000"));
    }

    #[test]
    fn cv_all_nonfinite_grid_is_an_error() {
        use crate::coordinator::SolveControls;
        // One +∞ response poisons every grid point's cross-fold MSE sum
        // (each fold holds row 0 out exactly once). n_lambda = 1 keeps the
        // path at the analytic β ≡ 0 step, so no solver runs on the
        // poisoned training folds; all-nonzero X keeps λmax at +∞ (not
        // NaN) in the folds that train on row 0.
        let (n, p) = (12, 40);
        let x = DenseMatrix::from_fn(n, p, |i, j| 0.1 + ((i * p + j) % 7) as f32 * 0.05);
        let mut y: Vec<f32> = (0..n).map(|i| i as f32 * 0.3 - 1.0).collect();
        y[0] = f32::INFINITY;
        let g = GroupStructure::uniform(p, 4);
        let pc = PathConfig {
            controls: SolveControls { n_lambda: 1, lambda_min_ratio: 0.5, ..Default::default() },
            ..Default::default()
        };
        let out = cross_validate_serial(&x, &y, &g, &[1.0], 3, &pc, 9);
        assert_eq!(out.nonfinite_points, out.points.len());
        assert!(!out.points.is_empty());
        let err = check_cv_grid(&out).unwrap_err();
        assert!(format!("{err:#}").contains("non-finite held-out MSE"), "{err:#}");
        // A partially finite grid is only a warning, not an error.
        let mut partial = out.clone();
        partial.nonfinite_points = partial.points.len() - 1;
        assert!(check_cv_grid(&partial).is_ok());
    }
}
