//! # TLFre — Two-Layer Feature Reduction for Sparse-Group Lasso
//!
//! A production-quality reproduction of *"Two-Layer Feature Reduction for
//! Sparse-Group Lasso via Decomposition of Convex Sets"* (Wang & Ye,
//! NIPS 2014), built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the pathwise coordinator: a single
//!   streaming path driver ([`coordinator::driver`]) that interleaves
//!   screening with SGL / nonnegative-Lasso solvers and streams each
//!   warm-started step to pluggable sinks (per-λ statistics, dense
//!   coefficients, fold-parallel cross-validation), plus every substrate
//!   the paper's evaluation depends on (multi-backend linear algebra, data
//!   generators, solvers, an optional PJRT runtime for AOT-compiled
//!   artifacts, metrics, CLI, bench harness).
//! * **Layer 2 (python/compile/model.py)** — the full-matrix screening graph
//!   in JAX, lowered once to HLO text via `python/compile/aot.py`.
//! * **Layer 1 (python/compile/kernels/)** — the fused screening kernel
//!   (`Xᵀθ` → shrink `S₁` → per-group norm reduction) as a Pallas kernel.
//!
//! ## The composable screening pipeline
//!
//! Screening is a pipeline of [`screening::rule::ScreeningRule`]s, each
//! marked [`screening::rule::Safety::Safe`] (rejections are certificates:
//! the paper's TLFre two-layer rule, DPC, and GAP-safe spheres) or
//! `Heuristic` (the strong rule — automatically guarded by the driver's
//! KKT-violation recovery loop). `PathConfig::screen` selects a named
//! pipeline (`tlfre` — the default, the paper's protocol — `tlfre+gap`,
//! `gap`, `strong+kkt`, `none`); custom rule stacks enter through
//! [`coordinator::drive_tlfre_path_with_pipeline`].
//!
//! The GAP pipelines additionally screen **dynamically, inside the
//! solvers**: at every duality-gap check the `√(2·gap)/λ` sphere
//! ([`screening::gap_safe`]) certifies more coordinates zero, and the
//! solver compacts its live problem (iterate, group maps, cached
//! Lipschitz data, the BCD coloring projection) and keeps iterating on
//! the survivor view — screening keeps paying off after the per-λ static
//! pass, at zero extra matvecs. See `rust/src/screening/README.md` for
//! the taxonomy and the dynamic-screening contract.
//!
//! ## The `DesignMatrix` backend abstraction
//!
//! Everything above the linalg layer — both solvers ([`sgl::fista`],
//! [`sgl::bcd`]), every screening rule ([`screening::tlfre`],
//! [`screening::dpc`], [`screening::strong_rule`], [`screening::lambda_max`]),
//! the nonnegative-Lasso solver ([`nonneg`]) and the whole coordinator
//! ([`coordinator`]) — is generic over [`linalg::DesignMatrix`], the
//! column-oriented backend trait. Three backends ship:
//!
//! | backend | storage | when it wins |
//! |---|---|---|
//! | [`linalg::DenseMatrix`] | column-major `f32` | dense designs (the paper's synthetic/ADNI recipes) |
//! | [`linalg::CscMatrix`] | compressed sparse column | sparse workloads (one-hot genomics, n-grams, dictionaries): sweeps scale with nnz |
//! | [`linalg::ScreenedView`] | zero-copy survivor view | reduced problems after screening — no per-λ column gather |
//!
//! The hot `Xᵀv` screening sweep is parallelized over column chunks on every
//! backend (`TLFRE_THREADS` bounds the workers; the result is bitwise
//! independent of the worker count). Path steps build reduced problems as
//! [`linalg::ScreenedView`]s, so as λ descends the solver's view of `X`
//! shrinks without the O(N·p) copy tax the paper's protocol would otherwise
//! pay at every grid point. See `rust/src/linalg/README.md` for backend
//! selection guidance.
//!
//! ## The resident serve layer
//!
//! `tlfre serve` ([`server`]) keeps everything the batch CLI rebuilds per
//! invocation — generated datasets on any backend, spectral preambles,
//! completed path prefixes — resident in one long-running engine behind a
//! unix socket. Requests are typed [`server::SolveRequest`]s in a
//! versioned JSON schema (the same schema the CLI flags translate into;
//! HTTP/1.0-style framing, zero dependencies); `solve-path` streams the
//! full walk, `solve-point` answers single grid points warm-started from
//! the longest resident prefix and carries a certified suboptimality
//! bound, and concurrent clients share one dataset copy and one path
//! cache. Served results are **bitwise identical** to the equivalent
//! batch runs — caching only skips work whose output is already known.
//! See `rust/src/server/README.md` for the schema and the cache/warm-start
//! contract.
//!
//! ## Offline, dependency-free build
//!
//! The crate builds with **zero external dependencies**: vendored stand-ins
//! live in [`util`] (rng, json, logging, thread pool, bench harness) and
//! [`error`] (anyhow-style error chains). The PJRT/XLA runtime ([`runtime`])
//! is gated behind the `pjrt` cargo feature and compiles to an
//! API-compatible stub by default; python never runs on the request path —
//! `make artifacts` produces `artifacts/*.hlo.txt` which the `pjrt`-enabled
//! build loads through the PJRT C API.
//!
//! See `examples/` for full workloads and `rust/benches/` for the
//! reproduction of every table and figure in the paper (plus
//! `perf_kernels`, which includes the dense/CSC/view backend comparison
//! recorded in `BENCH_backends.json`).

#![deny(unsafe_op_in_unsafe_fn)]

pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod groups;
pub mod linalg;
pub mod nonneg;
pub mod prox;
pub mod runtime;
pub mod screening;
pub mod server;
pub mod sgl;
pub mod util;

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
