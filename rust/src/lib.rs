//! # TLFre — Two-Layer Feature Reduction for Sparse-Group Lasso
//!
//! A production-quality reproduction of *"Two-Layer Feature Reduction for
//! Sparse-Group Lasso via Decomposition of Convex Sets"* (Wang & Ye,
//! NIPS 2014), built as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the pathwise coordinator: a warm-started
//!   regularization-path driver that interleaves exact (safe) screening with
//!   SGL / nonnegative-Lasso solvers, plus every substrate the paper's
//!   evaluation depends on (dense linear algebra, data generators, solvers,
//!   a PJRT runtime for AOT-compiled artifacts, metrics, CLI, bench harness).
//! * **Layer 2 (python/compile/model.py)** — the full-matrix screening graph
//!   in JAX, lowered once to HLO text via `python/compile/aot.py`.
//! * **Layer 1 (python/compile/kernels/)** — the fused screening kernel
//!   (`Xᵀθ` → shrink `S₁` → per-group norm reduction) as a Pallas kernel.
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt` which [`runtime`] loads through the PJRT C API.
//!
//! See `examples/` for full workloads and `rust/benches/` for the
//! reproduction of every table and figure in the paper.

pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod groups;
pub mod linalg;
pub mod nonneg;
pub mod prox;
pub mod runtime;
pub mod screening;
pub mod sgl;
pub mod util;

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
