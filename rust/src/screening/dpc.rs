//! DPC — screening for nonnegative Lasso (Section 5, Theorems 20–22).
//!
//! Same normal-cone geometry as TLFre, instantiated for the polytope dual
//! feasible set `F = {θ : ⟨x_i, θ⟩ ≤ 1}`. The rule (Theorem 22):
//!
//! ```text
//! ⟨x_i, o⟩ + radius·‖x_i‖ < 1  ⇒  [β*(λ)]_i = 0,
//! ```
//!
//! with `o, radius` from the Theorem 21 ball. Note the rule is one-sided —
//! only *positive* correlation can activate a nonnegative coefficient.

use super::dual_est::{estimate_ball, normal_interior, Ball};
use crate::linalg::ops;
use crate::linalg::DesignMatrix;
use crate::nonneg::NonnegProblem;

/// Outcome of one DPC screening.
#[derive(Debug, Clone)]
pub struct DpcOutcome {
    /// Per-feature survival (false ⇒ coefficient certified zero).
    pub feature_kept: Vec<bool>,
    /// Number rejected.
    pub rejected: usize,
    /// Ball radius used.
    pub radius: f64,
}

impl DpcOutcome {
    pub fn active_features(&self) -> Vec<usize> {
        self.feature_kept
            .iter()
            .enumerate()
            .filter_map(|(i, &k)| if k { Some(i) } else { None })
            .collect()
    }
}

/// Theorem 21's normal vector.
///
/// * λ̄ < λmax: `n = y/λ̄ − θ̄`;
/// * λ̄ = λmax: `n = x_*`, the column attaining `λmax = max_i ⟨x_i, y⟩`.
pub fn normal_vector<M: DesignMatrix>(
    prob: &NonnegProblem<'_, M>,
    lambda_bar: f64,
    theta_bar: &[f32],
    lambda_max: f64,
    argmax_col: usize,
) -> Vec<f32> {
    if lambda_bar >= lambda_max * (1.0 - 1e-12) {
        let mut n = vec![0.0f32; prob.x.rows()];
        prob.x.col_to_dense(argmax_col, &mut n);
        n
    } else {
        let y_over: Vec<f32> = prob.y.iter().map(|&v| (v as f64 / lambda_bar) as f32).collect();
        normal_interior(theta_bar, &y_over)
    }
}

/// The Theorem 21 ball for a step λ̄ → λ.
pub fn screen_ball<M: DesignMatrix>(
    prob: &NonnegProblem<'_, M>,
    lambda: f64,
    lambda_bar: f64,
    theta_bar: &[f32],
    lambda_max: f64,
    argmax_col: usize,
) -> Ball {
    let n_vec = normal_vector(prob, lambda_bar, theta_bar, lambda_max, argmax_col);
    let y_over: Vec<f32> = prob.y.iter().map(|&v| (v as f64 / lambda) as f32).collect();
    estimate_ball(theta_bar, &n_vec, &y_over)
}

/// Apply the DPC rule (89) given `c = Xᵀo` and the radius.
pub fn apply_rule(c: &[f32], radius: f64, col_norms: &[f64]) -> DpcOutcome {
    let p = c.len();
    let mut feature_kept = vec![true; p];
    let mut rejected = 0usize;
    for i in 0..p {
        if (c[i] as f64) + radius * col_norms[i] < 1.0 {
            feature_kept[i] = false;
            rejected += 1;
        }
    }
    DpcOutcome { feature_kept, rejected, radius }
}

/// One full DPC screening step (Theorem 22).
///
/// `theta_bar` must be the dual optimum at λ̄: `(y − Xβ̄)/λ̄`.
pub fn dpc_screen<M: DesignMatrix>(
    prob: &NonnegProblem<'_, M>,
    lambda: f64,
    lambda_bar: f64,
    theta_bar: &[f32],
    lambda_max: f64,
    argmax_col: usize,
    col_norms: &[f64],
) -> DpcOutcome {
    dpc_screen_inexact(prob, lambda, lambda_bar, theta_bar, 0.0, lambda_max, argmax_col, col_norms)
}

/// DPC step robust to an inexact previous solve: the estimate-ball radius
/// is inflated by `2·√(2·gap_bar)/λ̄` (strong-convexity bound on the
/// distance from the feasible dual point to the true optimum; same
/// reasoning as [`crate::screening::tlfre::tlfre_screen_inexact`]).
#[allow(clippy::too_many_arguments)]
pub fn dpc_screen_inexact<M: DesignMatrix>(
    prob: &NonnegProblem<'_, M>,
    lambda: f64,
    lambda_bar: f64,
    theta_bar: &[f32],
    gap_bar: f64,
    lambda_max: f64,
    argmax_col: usize,
    col_norms: &[f64],
) -> DpcOutcome {
    assert!(lambda > 0.0 && lambda < lambda_bar * (1.0 + 1e-12));
    let mut ball = screen_ball(prob, lambda, lambda_bar, theta_bar, lambda_max, argmax_col);
    if gap_bar > 0.0 {
        ball.radius += 2.0 * (2.0 * gap_bar).sqrt() / lambda_bar;
    }
    let mut c = vec![0.0f32; prob.x.cols()];
    prob.x.matvec_t(&ball.center, &mut c);
    apply_rule(&c, ball.radius, col_norms)
}

/// Normal-cone membership check used by tests: `⟨n, θ − θ̄⟩ ≤ 0` ∀θ ∈ F.
pub fn normal_cone_margin<M: DesignMatrix>(
    prob: &NonnegProblem<'_, M>,
    n_vec: &[f32],
    theta_bar: &[f32],
    probe: &[f32],
) -> f64 {
    // Scale the probe into F: ⟨x_i, sθ⟩ ≤ 1.
    let mut c = vec![0.0f32; prob.x.cols()];
    prob.x.matvec_t(probe, &mut c);
    let cmax = c.iter().fold(f64::NEG_INFINITY, |m, &v| m.max(v as f64));
    let s = if cmax <= 1.0 { 1.0 } else { 1.0 / cmax };
    let mut diff = vec![0.0f32; probe.len()];
    for i in 0..probe.len() {
        diff[i] = (probe[i] as f64 * s) as f32 - theta_bar[i];
    }
    ops::dot(n_vec, &diff)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;
    use crate::nonneg::{lambda_max, solve_nonneg, NonnegOptions};
    use crate::util::Rng;

    fn make_problem(seed: u64, n: usize, p: usize) -> (DenseMatrix, Vec<f32>) {
        let mut rng = Rng::seed_from_u64(seed);
        let x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian().abs() as f32);
        let mut beta = vec![0.0f32; p];
        for k in 0..p / 8 + 1 {
            beta[(k * 11) % p] = rng.uniform_range(0.3, 1.2) as f32;
        }
        let mut y = vec![0.0f32; n];
        x.matvec(&beta, &mut y);
        for v in y.iter_mut() {
            *v += rng.normal(0.0, 0.01) as f32;
        }
        (x, y)
    }

    #[test]
    fn dpc_safe_from_lambda_max() {
        let (x, y) = make_problem(81, 20, 60);
        let prob = NonnegProblem::new(&x, &y);
        let (lmax, arg) = lambda_max(&prob);
        let col_norms = x.col_norms();
        let theta_bar: Vec<f32> = y.iter().map(|&v| (v as f64 / lmax) as f32).collect();
        let lambda = 0.85 * lmax;
        let out = dpc_screen(&prob, lambda, lmax, &theta_bar, lmax, arg, &col_norms);
        let sol = solve_nonneg(&prob, lambda, None, &NonnegOptions { tol: 1e-10, ..Default::default() });
        for j in 0..x.cols() {
            if !out.feature_kept[j] {
                assert!(sol.beta[j].abs() < 1e-5, "feature {j} screened but β={}", sol.beta[j]);
            }
        }
        assert!(out.rejected > x.cols() / 2, "rejected only {}", out.rejected);
    }

    #[test]
    fn dpc_safe_sequential() {
        let (x, y) = make_problem(82, 15, 40);
        let prob = NonnegProblem::new(&x, &y);
        let (lmax, arg) = lambda_max(&prob);
        let col_norms = x.col_norms();
        let opts = NonnegOptions { tol: 1e-10, ..Default::default() };
        let mut lambda_bar = lmax;
        let mut beta_bar = vec![0.0f32; x.cols()];
        for step in 1..=6 {
            let lambda = lmax * (0.9f64).powi(step);
            let mut r = vec![0.0f32; x.rows()];
            x.matvec(&beta_bar, &mut r);
            for i in 0..r.len() {
                r[i] = y[i] - r[i];
            }
            let theta_bar: Vec<f32> = r.iter().map(|&v| (v as f64 / lambda_bar) as f32).collect();
            let out = dpc_screen(&prob, lambda, lambda_bar, &theta_bar, lmax, arg, &col_norms);
            let sol = solve_nonneg(&prob, lambda, Some(&beta_bar), &opts);
            for j in 0..x.cols() {
                if !out.feature_kept[j] {
                    assert!(
                        sol.beta[j].abs() < 1e-5,
                        "step {step} feature {j}: screened but β={}",
                        sol.beta[j]
                    );
                }
            }
            beta_bar = sol.beta;
            lambda_bar = lambda;
        }
    }

    #[test]
    fn normal_vector_at_lambda_max_is_in_cone() {
        // Theorem 21(i): n = x_* ∈ N_F(y/λmax).
        let (x, y) = make_problem(83, 12, 25);
        let prob = NonnegProblem::new(&x, &y);
        let (lmax, arg) = lambda_max(&prob);
        let theta_bar: Vec<f32> = y.iter().map(|&v| (v as f64 / lmax) as f32).collect();
        let n_vec = normal_vector(&prob, lmax, &theta_bar, lmax, arg);
        let mut rng = Rng::seed_from_u64(84);
        for _ in 0..50 {
            let probe: Vec<f32> = (0..x.rows()).map(|_| rng.gaussian() as f32).collect();
            let m = normal_cone_margin(&prob, &n_vec, &theta_bar, &probe);
            assert!(m <= 1e-3, "margin {m} > 0");
        }
    }

    #[test]
    fn negative_correlation_always_rejected() {
        // Columns anti-correlated with the ball center are certified zero
        // whenever radius·‖x_i‖ < 1.
        let (x, y) = make_problem(85, 10, 20);
        let prob = NonnegProblem::new(&x, &y);
        let (lmax, arg) = lambda_max(&prob);
        let col_norms = x.col_norms();
        let theta_bar: Vec<f32> = y.iter().map(|&v| (v as f64 / lmax) as f32).collect();
        let out = dpc_screen(&prob, 0.95 * lmax, lmax, &theta_bar, lmax, arg, &col_norms);
        // the argmax column must never be rejected at λ close to λmax
        assert!(out.feature_kept[arg], "argmax column rejected");
    }
}
