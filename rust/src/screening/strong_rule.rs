//! Strong-rule-style *heuristic* screening baseline (Tibshirani et al.
//! [26]) with KKT correction — the comparison point the paper draws in its
//! introduction: heuristic rules may wrongly discard active features and
//! therefore need a post-solve KKT check + re-solve loop, whereas TLFre's
//! rejections are certificates.
//!
//! Sequential rule at step λ̄ → λ, with `c = Xᵀr(λ̄)` the correlations at
//! the previous solution (problem-(3) parameterization, λ₁ = αλ):
//!
//! * **group**:   `‖S_λ(c_g)‖ + (1+α)√n_g·(λ̄−λ) < αλ√n_g`  ⇒ discard g;
//! * **feature**: `|c_i| < 2λ − λ̄`                          ⇒ discard i
//!
//! (the unit-slope heuristic of the strong-rules paper applied to each
//! KKT condition; *not* safe). [`solve_with_strong_rule`] wraps the rule
//! in the standard KKT-violation loop so the final solution is exact —
//! what makes it a fair wall-clock baseline against TLFre in the ablation
//! bench.

use crate::coordinator::reduce::ReducedProblem;
use crate::linalg::DesignMatrix;
use crate::prox::shrink_norm;
use crate::screening::tlfre::{ScreenStats, TlfreOutcome};
use crate::sgl::fista::{solve_fista, FistaOptions, SolveResult};
use crate::sgl::problem::{SglParams, SglProblem};

/// Apply the heuristic rule. `c` must be `Xᵀ(y − Xβ̄)` at the previous λ̄.
pub fn strong_rule_screen<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    alpha: f64,
    lambda: f64,
    lambda_bar: f64,
    c: &[f32],
) -> TlfreOutcome {
    let p = prob.n_features();
    let g_cnt = prob.n_groups();
    let mut group_kept = vec![true; g_cnt];
    let mut feature_kept = vec![true; p];
    let mut stats = ScreenStats::default();
    let feat_thresh = (2.0 * lambda - lambda_bar).max(0.0);
    for (g, s, e) in prob.groups.iter() {
        let w = prob.groups.weight(g);
        let lhs = shrink_norm(&c[s..e], lambda) + (1.0 + alpha) * w * (lambda_bar - lambda);
        if lhs < alpha * lambda * w {
            group_kept[g] = false;
            feature_kept[s..e].iter_mut().for_each(|k| *k = false);
            stats.groups_rejected += 1;
            stats.features_in_rejected_groups += e - s;
        } else {
            for i in s..e {
                if (c[i].abs() as f64) < feat_thresh {
                    feature_kept[i] = false;
                    stats.features_rejected_l2 += 1;
                }
            }
        }
    }
    TlfreOutcome { group_kept, feature_kept, stats }
}

/// KKT residual of a *discarded* coordinate set: returns the features whose
/// optimality condition is violated by the reduced solution (they must be
/// re-admitted). Conditions, per group g of the reduced solution β:
///
/// * group screened entirely, or kept but solved to `β_g = 0`: the zero
///   group must satisfy `‖S_{λ₂}(c_g)‖ ≤ λ₁√n_g` (eq. (30));
/// * feature i screened inside a group with `β_g ≠ 0`: the group-norm
///   subgradient component at `β_i = 0` is `λ₁√n_g·β_i/‖β_g‖ = 0`, so the
///   inactive-coordinate condition is `|c_i| ≤ λ₂` — *not* the
///   `λ₂ + λ₁√n_g` relaxation, which is only valid for zero groups and
///   would let feature-level mis-rejections inside active groups slip
///   through the recovery loop undetected.
pub fn kkt_violations<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    params: &SglParams,
    beta: &[f32],
    screened: &TlfreOutcome,
) -> Vec<usize> {
    let n = prob.n_samples();
    let mut r = vec![0.0f32; n];
    crate::sgl::objective::residual(prob, beta, &mut r);
    kkt_violations_with_resid(prob, params, beta, screened, &r)
}

/// [`kkt_violations`] with the residual `y − Xβ` supplied by the caller —
/// the driver's outer loop reuses the solver's final residual
/// ([`crate::sgl::fista::SolveResult::resid`]), skipping one full matvec
/// per KKT round. The caller owns the invariant that `resid` matches
/// `beta`; a reduced solve's residual qualifies, since discarded
/// coordinates are zero.
pub fn kkt_violations_with_resid<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    params: &SglParams,
    beta: &[f32],
    screened: &TlfreOutcome,
    resid: &[f32],
) -> Vec<usize> {
    debug_assert_eq!(resid.len(), prob.n_samples());
    let mut c = vec![0.0f32; prob.n_features()];
    prob.x.matvec_t(resid, &mut c);
    let mut bad = Vec::new();
    for (g, s, e) in prob.groups.iter() {
        let w = prob.groups.weight(g);
        let group_is_zero =
            !screened.group_kept[g] || beta[s..e].iter().all(|&v| v == 0.0);
        if group_is_zero {
            // β_g = 0 must satisfy ‖S_{λ₂}(c_g)‖ ≤ λ₁√n_g (eq. (30));
            // only the *screened* coordinates need re-admission (kept ones
            // are already in the solver's problem).
            if crate::prox::shrink_norm(&c[s..e], params.lambda2) > params.lambda1 * w * (1.0 + 1e-6) {
                bad.extend((s..e).filter(|&i| !screened.feature_kept[i]));
            }
        } else {
            for i in s..e {
                if !screened.feature_kept[i]
                    && (c[i].abs() as f64) > params.lambda2 * (1.0 + 1e-6) + 1e-6
                {
                    bad.push(i);
                }
            }
        }
    }
    bad
}

/// Solve at λ using the strong rule with the KKT-correction loop: screen,
/// solve reduced, check discarded coordinates, re-admit violators, repeat.
/// Returns the exact solution plus the number of correction rounds.
///
/// This is the standalone single-λ reference form (ablation benches,
/// tests). The **production** recovery loop lives in the path driver
/// (`coordinator::driver`), which runs this same
/// screen→solve→[`kkt_violations`]→re-admit cycle for any pipeline
/// containing a heuristic rule (`--screen strong+kkt`) — changes to the
/// recovery logic belong there first, with this helper kept in step.
pub fn solve_with_strong_rule<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    alpha: f64,
    lambda: f64,
    lambda_bar: f64,
    beta_bar: &[f32],
    opts: &FistaOptions<'_>,
) -> (SolveResult, usize) {
    let params = SglParams::from_alpha_lambda(alpha, lambda);
    let n = prob.n_samples();
    let mut r = vec![0.0f32; n];
    crate::sgl::objective::residual(prob, beta_bar, &mut r);
    let mut c = vec![0.0f32; prob.n_features()];
    prob.x.matvec_t(&r, &mut c);

    let mut screened = strong_rule_screen(prob, alpha, lambda, lambda_bar, &c);
    let mut rounds = 0usize;
    loop {
        rounds += 1;
        let result = match ReducedProblem::build(prob.x, prob.groups, &screened) {
            None => SolveResult {
                beta: vec![0.0; prob.n_features()],
                iters: 0,
                gap: 0.0,
                objective: crate::sgl::dual::null_objective(prob.y),
                converged: true,
                budget_exhausted: false,
                resid: prob.y.to_vec(),
            },
            Some(red) => {
                let rp = SglProblem::new(&red.x, prob.y, &red.groups);
                let warm = red.gather(beta_bar);
                let res = solve_fista(&rp, &params, Some(&warm), opts);
                let mut full = vec![0.0f32; prob.n_features()];
                red.scatter(&res.beta, &mut full);
                SolveResult { beta: full, ..res }
            }
        };
        let bad = kkt_violations(prob, &params, &result.beta, &screened);
        if bad.is_empty() || rounds > 16 {
            return (result, rounds);
        }
        // Re-admit violators (and their groups at the group level).
        for &i in &bad {
            screened.feature_kept[i] = true;
            screened.group_kept[prob.groups.group_of(i)] = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_synthetic, SyntheticSpec};
    use crate::screening::lambda_max::sgl_lambda_max;

    #[test]
    fn strong_rule_with_kkt_matches_exact_solution() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 160, 16), 301);
        let prob = SglProblem::new(&ds.x, &ds.y, &ds.groups);
        let alpha = 1.0;
        let lmax = sgl_lambda_max(&prob, alpha);
        let opts = FistaOptions { tol: 1e-8, ..Default::default() };
        let mut beta_bar = vec![0.0f32; prob.n_features()];
        let mut lambda_bar = lmax.lambda_max;
        for step in 1..=5 {
            let lambda = lmax.lambda_max * (0.85f64).powi(step);
            let (res, rounds) =
                solve_with_strong_rule(&prob, alpha, lambda, lambda_bar, &beta_bar, &opts);
            let exact = solve_fista(
                &prob,
                &SglParams::from_alpha_lambda(alpha, lambda),
                None,
                &opts,
            );
            assert!(
                (res.objective - exact.objective).abs()
                    < 1e-4 * exact.objective.abs().max(1.0),
                "step {step}: {} vs {} ({} rounds)",
                res.objective,
                exact.objective,
                rounds
            );
            beta_bar = res.beta;
            lambda_bar = lambda;
        }
    }

    #[test]
    fn strong_rule_rejects_more_than_tlfre_but_unsafely() {
        // The heuristic typically discards at least as much as the exact
        // rule (that is its appeal); safety is provided only by the KKT
        // loop. We check the discard count relation on a typical problem.
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(25, 120, 12), 302);
        let prob = SglProblem::new(&ds.x, &ds.y, &ds.groups);
        let alpha = 1.0;
        let lmax = sgl_lambda_max(&prob, alpha);
        let lambda_bar = lmax.lambda_max;
        let lambda = 0.8 * lmax.lambda_max;
        let mut c = vec![0.0f32; prob.n_features()];
        prob.x.matvec_t(&ds.y, &mut c);
        let strong = strong_rule_screen(&prob, alpha, lambda, lambda_bar, &c);
        let ctx = crate::screening::tlfre::TlfreContext::precompute(&prob);
        let theta: Vec<f32> =
            ds.y.iter().map(|&v| (v as f64 / lambda_bar) as f32).collect();
        let exact = crate::screening::tlfre::tlfre_screen(
            &prob, alpha, lambda, lambda_bar, &theta, &lmax, &ctx,
        );
        // Both should reject plenty here; strong usually ≥ exact.
        assert!(strong.total_rejected() > 0);
        assert!(exact.total_rejected() > 0);
    }

    #[test]
    fn kkt_flags_feature_violation_inside_active_group() {
        // Regression: the per-feature check once used the zero-group
        // relaxation |c_i| ≤ λ₂ + λ₁√n_g for screened features inside
        // *active* groups, where the correct inactive-coordinate condition
        // is |c_i| ≤ λ₂ (the group-norm subgradient component vanishes at
        // β_i = 0 when ‖β_g‖ ≠ 0) — feature-level mis-rejections in active
        // groups slipped through. Wrongly screen one substantial feature
        // of a group that stays active and re-solve: the violation must be
        // flagged.
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 120, 12), 304);
        let prob = SglProblem::new(&ds.x, &ds.y, &ds.groups);
        let alpha = 1.0;
        let lmax = sgl_lambda_max(&prob, alpha);
        let lambda = 0.2 * lmax.lambda_max;
        let params = SglParams::from_alpha_lambda(alpha, lambda);
        let opts = FistaOptions { tol: 1e-9, ..Default::default() };
        let exact = solve_fista(&prob, &params, None, &opts);
        // A group with at least two substantial features; screen one.
        let mut target = None;
        'outer: for (g, s, e) in prob.groups.iter() {
            let strong: Vec<usize> =
                (s..e).filter(|&i| exact.beta[i].abs() > 0.05).collect();
            if strong.len() >= 2 {
                target = Some((g, strong[0]));
                break 'outer;
            }
        }
        let (_, victim) = target.expect("test problem must have a multi-active group");
        let mut screened = TlfreOutcome {
            group_kept: vec![true; prob.n_groups()],
            feature_kept: vec![true; prob.n_features()],
            stats: ScreenStats::default(),
        };
        screened.feature_kept[victim] = false;
        let red = ReducedProblem::build(prob.x, prob.groups, &screened).unwrap();
        let rp = SglProblem::new(&red.x, prob.y, &red.groups);
        let res = solve_fista(&rp, &params, None, &opts);
        let mut full = vec![0.0f32; prob.n_features()];
        red.scatter(&res.beta, &mut full);
        let bad = kkt_violations(&prob, &params, &full, &screened);
        assert!(
            bad.contains(&victim),
            "screened-but-active feature {victim} not flagged (bad = {bad:?})"
        );
    }

    #[test]
    fn kkt_violation_detector_flags_planted_violation() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(20, 80, 8), 303);
        let prob = SglProblem::new(&ds.x, &ds.y, &ds.groups);
        let params = SglParams::from_alpha_lambda(1.0, 1e-3); // tiny λ: everything active
        // Screen away everything (wrongly), β = 0: violations must appear.
        let screened = TlfreOutcome {
            group_kept: vec![false; prob.n_groups()],
            feature_kept: vec![false; prob.n_features()],
            stats: ScreenStats::default(),
        };
        let beta = vec![0.0f32; prob.n_features()];
        let bad = kkt_violations(&prob, &params, &beta, &screened);
        assert!(!bad.is_empty());
    }
}
