//! Celer-style aggressive working sets as a composable screening rule.
//!
//! The safe rules (TLFre, GAP) certify zeros, but most of what survives
//! them *still* ends at zero — the inner solver wastes sweeps on it.
//! [`WorkingSetRule`] is a [`Safety::Heuristic`] rule that keeps only a
//! small prioritized subset of the mask-kept groups: the previous step's
//! support plus the top-scoring groups by strong-rule proximity, computed
//! from the dual preamble the driver already paid for (`corr_bar` — no
//! extra matvec). Everything else is rejected *heuristically*; the
//! driver's outer loop (see `coordinator/driver.rs`) solves on the working
//! set at a loose tolerance, checks full-problem KKT, grows the set
//! geometrically on violations via [`ScreeningRule::grow`], and runs one
//! tight solve at the end. The safe fallback is structural: if the set
//! grows to all safe survivors, the path degenerates to today's behavior.
//!
//! Determinism contract: admission order is a total order — previous
//! support first (ascending group index), then descending score with
//! ascending-index tie-break — derived only from `beta_bar`/`corr_bar`,
//! which are worker-count-invariant and restored bitwise by checkpoint
//! resume. The rule carries **no cross-step mutable state**: the
//! [`RefCell`] below is recomputed from scratch at every [`screen`] call,
//! which is what keeps `EngineSnapshot`/checkpoint resume bitwise
//! identical with working sets enabled.
//!
//! [`screen`]: ScreeningRule::screen

use super::rule::{LayerCount, Safety, ScreenInput, ScreeningRule, SurvivorMask};
use super::tlfre::TlfreOutcome;
use crate::groups::GroupStructure;
use crate::linalg::DesignMatrix;
use crate::prox::shrink_norm;
use std::cell::RefCell;

/// Minimum number of groups seeded into a fresh working set (beyond the
/// previous support) — keeps the first reduced solve from being trivially
/// small on cold steps near λmax.
const MIN_SEED_GROUPS: usize = 10;

/// Per-step working-set bookkeeping, rebuilt on every screen call.
#[derive(Default)]
struct WsState {
    /// Mask-kept groups in admission order: previous support, then the
    /// rest by descending strong-rule score (index-ascending ties).
    order: Vec<usize>,
    /// Prefix of `order` currently admitted to the working set.
    admitted: usize,
}

/// The heuristic working-set rule. Construct with [`WorkingSetRule::new`]
/// for the real admission order, or [`WorkingSetRule::adversarial`] for a
/// deliberately reversed one (test seam for the KKT recovery contract).
pub struct WorkingSetRule {
    state: RefCell<WsState>,
    adversarial: bool,
}

impl WorkingSetRule {
    pub fn new() -> WorkingSetRule {
        WorkingSetRule { state: RefCell::new(WsState::default()), adversarial: false }
    }

    /// Admission order deliberately reversed — worst-scoring groups first,
    /// previous support last — so the initial working set is as wrong as
    /// the scoring allows. The driver's KKT loop must still converge to
    /// the exact path; `tests/working_set.rs` proves it does.
    pub fn adversarial() -> WorkingSetRule {
        WorkingSetRule { state: RefCell::new(WsState::default()), adversarial: true }
    }
}

impl Default for WorkingSetRule {
    fn default() -> Self {
        Self::new()
    }
}

impl<M: DesignMatrix> ScreeningRule<M> for WorkingSetRule {
    fn name(&self) -> &'static str {
        "ws"
    }

    fn safety(&self) -> Safety {
        Safety::Heuristic
    }

    fn is_working_set(&self) -> bool {
        true
    }

    fn screen(&self, input: &ScreenInput<'_, '_, M>, mask: &mut SurvivorMask) -> LayerCount {
        let groups = input.prob.groups;
        // Problem-(3) parameterization: λ₁ = αλ on groups, λ₂ = λ on
        // features (matches `strong_rule_screen` / `kkt_violations`).
        let lambda2 = input.lambda;
        let lambda1 = input.alpha * input.lambda;
        let mut support: Vec<usize> = Vec::new();
        let mut scored: Vec<(f64, usize)> = Vec::new();
        for (g, s, e) in groups.iter() {
            if !mask.group_kept[g] {
                continue;
            }
            if input.beta_bar[s..e].iter().any(|&v| v != 0.0) {
                support.push(g);
            } else {
                // Strong-rule proximity: how close the group's zero-block
                // KKT margin ‖S_{λ₂}(c̄_g)‖ is to its bound λ₁·w_g. Finite
                // by construction (weights are positive).
                let sc = shrink_norm(&input.corr_bar[s..e], lambda2)
                    / (lambda1 * groups.weight(g)).max(f64::MIN_POSITIVE);
                scored.push((sc, g));
            }
        }
        // Descending score, ascending index on ties: a deterministic total
        // order over finite scores.
        scored.sort_by(|a, b| {
            b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
        });
        let support_n = support.len();
        let mut order = support;
        order.extend(scored.iter().map(|&(_, g)| g));
        if self.adversarial {
            order.reverse();
        }
        let admitted = order.len().min(support_n.max(MIN_SEED_GROUPS));
        let mut g_new = 0usize;
        let mut f_new = 0usize;
        for &g in &order[admitted..] {
            mask.group_kept[g] = false;
            g_new += 1;
            let (s, e) = groups.range(g);
            for k in mask.feature_kept[s..e].iter_mut() {
                if *k {
                    *k = false;
                    f_new += 1;
                }
            }
        }
        *self.state.borrow_mut() = WsState { order, admitted };
        LayerCount { rule: "ws", safety: Safety::Heuristic, groups: g_new, features: f_new }
    }

    fn grow(
        &self,
        groups: &GroupStructure,
        outcome: &mut TlfreOutcome,
        safe_mask: &SurvivorMask,
        growth: f64,
    ) -> usize {
        let mut st = self.state.borrow_mut();
        // Geometric doubling (configurable factor), always admitting at
        // least one more group so growth can never stall below the cap.
        let target = ((st.admitted as f64 * growth).ceil() as usize)
            .max(st.admitted + 1)
            .min(st.order.len());
        let mut added = 0usize;
        for &g in &st.order[st.admitted..target] {
            if !outcome.group_kept[g] {
                outcome.group_kept[g] = true;
                added += 1;
                let (s, e) = groups.range(g);
                for i in s..e {
                    // Never re-admit a feature a safe rule certified zero.
                    if safe_mask.feature_kept[i] {
                        outcome.feature_kept[i] = true;
                    }
                }
            }
        }
        st.admitted = target;
        added
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;
    use crate::screening::lambda_max::sgl_lambda_max;
    use crate::screening::rule::stats_from_masks;
    use crate::screening::tlfre::TlfreContext;
    use crate::sgl::problem::SglProblem;
    use crate::util::Rng;

    fn setup(seed: u64) -> (DenseMatrix, Vec<f32>, GroupStructure) {
        let mut rng = Rng::seed_from_u64(seed);
        let n = 30;
        let p = 96;
        let x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian() as f32);
        let groups = GroupStructure::uniform(p, 16);
        let mut beta = vec![0.0f32; p];
        for j in 0..5 {
            beta[j * 11 % p] = rng.normal(0.0, 1.0) as f32;
        }
        let mut y = vec![0.0f32; n];
        x.matvec(&beta, &mut y);
        (x, y, groups)
    }

    #[test]
    fn seeds_support_plus_top_scores_and_growth_is_monotone() {
        // 24 groups of 4; previous support in group 8 (features 32, 33).
        let (x, y, _) = setup(417);
        let groups = GroupStructure::uniform(96, 4);
        let prob = SglProblem::new(&x, &y, &groups);
        let lmax = sgl_lambda_max(&prob, 1.0);
        let ctx = TlfreContext::precompute(&prob);
        let mut beta_bar = vec![0.0f32; 96];
        beta_bar[32] = 0.5;
        beta_bar[33] = -0.25;
        let mut resid = vec![0.0f32; y.len()];
        crate::sgl::objective::residual(&prob, &beta_bar, &mut resid);
        let mut corr = vec![0.0f32; 96];
        prob.x.matvec_t(&resid, &mut corr);
        let theta: Vec<f32> =
            resid.iter().map(|&v| (v as f64 / lmax.lambda_max) as f32).collect();
        let inp = ScreenInput {
            prob: &prob,
            alpha: 1.0,
            lambda: 0.4 * lmax.lambda_max,
            lambda_bar: lmax.lambda_max,
            beta_bar: &beta_bar,
            resid_bar: &resid,
            corr_bar: &corr,
            theta_bar: &theta,
            gap_bar: 0.0,
            lmax: &lmax,
            ctx: &ctx,
        };
        let rule = WorkingSetRule::new();
        let mut mask = SurvivorMask::all_kept(&groups);
        let layer = ScreeningRule::<DenseMatrix>::screen(&rule, &inp, &mut mask);
        assert_eq!(layer.rule, "ws");
        assert_eq!(layer.safety, Safety::Heuristic);
        // Support group always admitted; seed truncates the rest.
        assert!(mask.group_kept[8], "previous-support group was screened out");
        assert_eq!(layer.groups, 24 - MIN_SEED_GROUPS);

        // Growth honours the safe mask and is monotone kept-wise.
        let safe_mask = SurvivorMask::all_kept(&groups);
        let mut outcome = TlfreOutcome {
            group_kept: mask.group_kept.clone(),
            feature_kept: mask.feature_kept.clone(),
            stats: stats_from_masks(&groups, &mask.group_kept, &mask.feature_kept),
        };
        let before: usize = outcome.group_kept.iter().filter(|&&k| k).count();
        let added =
            ScreeningRule::<DenseMatrix>::grow(&rule, &groups, &mut outcome, &safe_mask, 2.0);
        assert!(added > 0);
        let after: usize = outcome.group_kept.iter().filter(|&&k| k).count();
        assert_eq!(after, before + added);
        for i in 0..96 {
            if mask.feature_kept[i] {
                assert!(outcome.feature_kept[i], "growth un-kept feature {i}");
            }
        }
    }

    #[test]
    fn admission_truncates_and_grows_to_cap() {
        // 24 groups of 4 (p=96, group size 4): MIN_SEED_GROUPS=10 < 24, so
        // a cold start must heuristically reject 14 groups, and repeated
        // doubling must reach the full set.
        let (x, y, _) = setup(418);
        let groups = GroupStructure::uniform(96, 4);
        let prob = SglProblem::new(&x, &y, &groups);
        let lmax = sgl_lambda_max(&prob, 1.0);
        let ctx = TlfreContext::precompute(&prob);
        let beta_bar = vec![0.0f32; 96];
        let resid = y.clone();
        let mut corr = vec![0.0f32; 96];
        prob.x.matvec_t(&resid, &mut corr);
        let theta: Vec<f32> =
            resid.iter().map(|&v| (v as f64 / lmax.lambda_max) as f32).collect();
        let inp = ScreenInput {
            prob: &prob,
            alpha: 1.0,
            lambda: 0.5 * lmax.lambda_max,
            lambda_bar: lmax.lambda_max,
            beta_bar: &beta_bar,
            resid_bar: &resid,
            corr_bar: &corr,
            theta_bar: &theta,
            gap_bar: 0.0,
            lmax: &lmax,
            ctx: &ctx,
        };
        let rule = WorkingSetRule::new();
        let mut mask = SurvivorMask::all_kept(&groups);
        let layer = ScreeningRule::<DenseMatrix>::screen(&rule, &inp, &mut mask);
        assert_eq!(layer.groups, 24 - MIN_SEED_GROUPS);
        assert_eq!(layer.features, (24 - MIN_SEED_GROUPS) * 4);

        let safe_mask = SurvivorMask::all_kept(&groups);
        let mut outcome = TlfreOutcome {
            group_kept: mask.group_kept.clone(),
            feature_kept: mask.feature_kept.clone(),
            stats: stats_from_masks(&groups, &mask.group_kept, &mask.feature_kept),
        };
        let mut rounds = 0;
        while outcome.group_kept.iter().any(|&k| !k) {
            let added = ScreeningRule::<DenseMatrix>::grow(
                &rule, &groups, &mut outcome, &safe_mask, 2.0,
            );
            assert!(added > 0, "growth stalled before reaching the cap");
            rounds += 1;
            assert!(rounds < 10, "growth failed to reach all survivors");
        }
        // Further growth at the cap is a no-op.
        assert_eq!(
            ScreeningRule::<DenseMatrix>::grow(&rule, &groups, &mut outcome, &safe_mask, 2.0),
            0
        );
    }

    #[test]
    fn adversarial_order_is_reversed_but_same_set_family() {
        let (x, y, _) = setup(419);
        let groups = GroupStructure::uniform(96, 4);
        let prob = SglProblem::new(&x, &y, &groups);
        let lmax = sgl_lambda_max(&prob, 1.0);
        let ctx = TlfreContext::precompute(&prob);
        let beta_bar = vec![0.0f32; 96];
        let resid = y.clone();
        let mut corr = vec![0.0f32; 96];
        prob.x.matvec_t(&resid, &mut corr);
        let theta: Vec<f32> =
            resid.iter().map(|&v| (v as f64 / lmax.lambda_max) as f32).collect();
        let inp = ScreenInput {
            prob: &prob,
            alpha: 1.0,
            lambda: 0.5 * lmax.lambda_max,
            lambda_bar: lmax.lambda_max,
            beta_bar: &beta_bar,
            resid_bar: &resid,
            corr_bar: &corr,
            theta_bar: &theta,
            gap_bar: 0.0,
            lmax: &lmax,
            ctx: &ctx,
        };
        let real = WorkingSetRule::new();
        let adv = WorkingSetRule::adversarial();
        let mut m_real = SurvivorMask::all_kept(&groups);
        let mut m_adv = SurvivorMask::all_kept(&groups);
        ScreeningRule::<DenseMatrix>::screen(&real, &inp, &mut m_real);
        ScreeningRule::<DenseMatrix>::screen(&adv, &inp, &mut m_adv);
        // Same admitted count, disjoint-leaning membership (reversed order):
        // the adversarial seed must differ from the real one.
        assert_eq!(
            m_real.group_kept.iter().filter(|&&k| k).count(),
            m_adv.group_kept.iter().filter(|&&k| k).count()
        );
        assert_ne!(m_real.group_kept, m_adv.group_kept);
    }
}
