//! The zero-solution boundary of the parameter space.
//!
//! Theorem 8: `β*(λ, α) = 0 ⇔ λ ≥ λ_max^α = max_g ρ_g`, where ρ_g solves
//! `‖S₁(X_gᵀ y / ρ)‖ = α√n_g`. Lemma 9 gives the closed form: on the
//! interval where exactly the top-k magnitudes survive the shrink, the
//! equation is the quadratic
//!
//! ```text
//! (k − α²n_g) ρ² − 2ρ‖z^(k)‖₁ + ‖z^(k)‖² = 0,     z = sort desc |X_gᵀy|.
//! ```
//!
//! A bisection fallback (the function is continuous and strictly monotone)
//! guards the degenerate cases and is cross-checked against the closed form
//! in the tests.
//!
//! Corollary 10 additionally gives the (λ₁, λ₂)-space boundary
//! `λ₁^max(λ₂) = max_g ‖S_{λ₂}(X_gᵀy)‖ / √n_g` used in the upper-left
//! panels of Figures 1–4.

use crate::linalg::DesignMatrix;
use crate::prox::shrink_norm_sq;
use crate::sgl::problem::SglProblem;
use crate::util::pool;

/// λ_max computation output.
#[derive(Debug, Clone)]
pub struct LambdaMaxInfo {
    /// λ_max^α = max_g ρ_g.
    pub lambda_max: f64,
    /// The argmax group `g*` (the paper's `X_*`).
    pub argmax_group: usize,
    /// Every ρ_g.
    pub rho: Vec<f64>,
}

/// `‖S₁(z/ρ)‖² − α²n_g` for a *nonnegative, descending* magnitude vector z.
fn crit(z: &[f64], rho: f64, alpha_sq_ng: f64) -> f64 {
    let mut acc = 0.0f64;
    for &zi in z {
        let t = zi / rho - 1.0;
        if t <= 0.0 {
            break; // z is descending — all later terms vanish
        }
        acc += t * t;
    }
    acc - alpha_sq_ng
}

/// Solve `‖S₁(z/ρ)‖ = α√n_g` for ρ via Lemma 9's piecewise quadratic.
///
/// `z` must be the descending-sorted magnitudes `|X_gᵀy|` with `z[0] > 0`.
/// Returns `ρ_g ∈ (0, z[0])`.
pub fn rho_group(z: &[f64], alpha: f64, n_g: usize) -> f64 {
    debug_assert!(z[0] > 0.0, "rho_group requires X_gᵀy ≠ 0");
    debug_assert!(z.windows(2).all(|w| w[0] >= w[1]), "z must be descending");
    let a2n = alpha * alpha * (n_g as f64);
    // Walk the knots ρ = z[k-1] downwards; in interval (z[k], z[k-1]) exactly
    // the top-k entries are active.
    for k in 1..=z.len() {
        let lo = if k < z.len() { z[k] } else { 0.0 };
        let hi = z[k - 1];
        if hi <= lo {
            continue; // ties — empty interval
        }
        // crit is decreasing in ρ; root lies in (lo, hi] iff
        // crit(hi) ≤ 0 ≤ crit(lo⁺).
        let s1: f64 = z[..k].iter().sum();
        let s2: f64 = z[..k].iter().map(|v| v * v).sum();
        let a = k as f64 - a2n;
        let b = -2.0 * s1;
        let c = s2;
        // Quadratic a·ρ² + b·ρ + c = 0 (Lemma 9(ii)); also handles the
        // boundary case Lemma 9(i) since hitting a knot exactly is a root.
        let root = if a.abs() < 1e-12 {
            // Linear: bρ + c = 0.
            -c / b
        } else {
            let disc = b * b - 4.0 * a * c;
            if disc < 0.0 {
                continue;
            }
            let sq = disc.sqrt();
            // Two candidate roots; pick the one in the interval.
            let r1 = (-b - sq) / (2.0 * a);
            let r2 = (-b + sq) / (2.0 * a);
            let in_iv = |r: f64| r > lo * (1.0 - 1e-12) && r <= hi * (1.0 + 1e-12);
            if in_iv(r1) && r1 > 0.0 {
                r1
            } else if in_iv(r2) && r2 > 0.0 {
                r2
            } else {
                continue;
            }
        };
        if root > lo * (1.0 - 1e-12) && root <= hi * (1.0 + 1e-12) && root > 0.0 {
            return root.min(hi).max(lo.max(f64::MIN_POSITIVE));
        }
    }
    // Fallback: bisection on the continuous monotone criterion.
    rho_group_bisect(z, alpha, n_g)
}

/// Bisection solver for the same root (robust fallback + test oracle).
pub fn rho_group_bisect(z: &[f64], alpha: f64, n_g: usize) -> f64 {
    let a2n = alpha * alpha * (n_g as f64);
    let mut hi = z[0];
    // crit(hi) = −a2n < 0; find lo with crit(lo) > 0.
    let mut lo = hi * 0.5;
    while crit(z, lo, a2n) <= 0.0 {
        lo *= 0.5;
        if lo < 1e-300 {
            return 0.0;
        }
    }
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if crit(z, mid, a2n) > 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
        if (hi - lo) / hi < 1e-15 {
            break;
        }
    }
    0.5 * (lo + hi)
}

/// λ_max^α for the full SGL problem (Theorem 8): one `Xᵀy` sweep, then a
/// per-group root solve.
pub fn sgl_lambda_max<M: DesignMatrix>(prob: &SglProblem<'_, M>, alpha: f64) -> LambdaMaxInfo {
    let p = prob.n_features();
    let mut c = vec![0.0f32; p];
    prob.x.matvec_t(prob.y, &mut c);
    lambda_max_from_correlations(&c, prob, alpha)
}

/// λ_max^α given a precomputed correlation vector `c = Xᵀy`.
pub fn lambda_max_from_correlations<M: DesignMatrix>(
    c: &[f32],
    prob: &SglProblem<'_, M>,
    alpha: f64,
) -> LambdaMaxInfo {
    let g_cnt = prob.n_groups();
    let mut rho = Vec::with_capacity(g_cnt);
    let mut best = f64::NEG_INFINITY;
    let mut arg = 0usize;
    for (g, s, e) in prob.groups.iter() {
        let mut z: Vec<f64> = c[s..e].iter().map(|&v| (v as f64).abs()).collect();
        z.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let r = if z[0] <= 0.0 { 0.0 } else { rho_group(&z, alpha, e - s) };
        if r > best {
            best = r;
            arg = g;
        }
        rho.push(r);
    }
    LambdaMaxInfo { lambda_max: best, argmax_group: arg, rho }
}

/// Streaming λ_max^α: visits X in **blocks of `block_groups` groups**
/// without ever materializing the full correlation vector `Xᵀy`.
///
/// The out-of-core form of [`sgl_lambda_max`]: each group's correlations
/// `X_gᵀy` are computed column-by-column (`col_dot`, the same kernel the
/// `matvec_t` sweep applies per column), sorted, and root-solved in place —
/// the transient working set is one group's magnitudes plus one block of X
/// columns, so over an [`crate::linalg::MmapDenseMatrix`] the kernel only
/// keeps `rows · Σ_{g∈block} n_g · 4` payload bytes hot at a time. Groups
/// within a block fan out over the pool (per-group roots are independent),
/// and the final max folds in ascending group order with the same strict
/// comparison as [`lambda_max_from_correlations`] — so the result
/// (`lambda_max`, `argmax_group`, every `rho[g]`) is **exactly** equal,
/// bitwise, for every `block_groups` and worker count.
pub fn sgl_lambda_max_streaming<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    alpha: f64,
    block_groups: usize,
) -> LambdaMaxInfo {
    let g_cnt = prob.n_groups();
    let block = block_groups.max(1);
    let bounds: Vec<(usize, usize)> = prob.groups.iter().map(|(_, s, e)| (s, e)).collect();
    let mut rho = vec![0.0f64; g_cnt];
    let mut g0 = 0;
    while g0 < g_cnt {
        let g1 = (g0 + block).min(g_cnt);
        pool::parallel_fill(&mut rho[g0..g1], |k| {
            let (s, e) = bounds[g0 + k];
            let mut z: Vec<f64> =
                (s..e).map(|j| (prob.x.col_dot(j, prob.y) as f64).abs()).collect();
            z.sort_by(|a, b| b.partial_cmp(a).unwrap());
            if z[0] <= 0.0 {
                0.0
            } else {
                rho_group(&z, alpha, e - s)
            }
        });
        g0 = g1;
    }
    let mut best = f64::NEG_INFINITY;
    let mut arg = 0usize;
    for (g, &r) in rho.iter().enumerate() {
        if r > best {
            best = r;
            arg = g;
        }
    }
    LambdaMaxInfo { lambda_max: best, argmax_group: arg, rho }
}

/// Corollary 10's boundary `λ₁^max(λ₂) = max_g ‖S_{λ₂}(X_gᵀy)‖/√n_g`.
pub fn lambda1_max<M: DesignMatrix>(prob: &SglProblem<'_, M>, lambda2: f64) -> f64 {
    let mut c = vec![0.0f32; prob.n_features()];
    prob.x.matvec_t(prob.y, &mut c);
    let mut best = 0.0f64;
    for (g, s, e) in prob.groups.iter() {
        let v = shrink_norm_sq(&c[s..e], lambda2).sqrt() / prob.groups.weight(g);
        best = best.max(v);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::groups::GroupStructure;
    use crate::linalg::DenseMatrix;
    use crate::prox::shrink_norm;
    use crate::util::Rng;

    #[test]
    fn closed_form_matches_bisection() {
        let mut rng = Rng::seed_from_u64(51);
        for trial in 0..200 {
            let n_g = 1 + rng.below(12);
            let mut z: Vec<f64> = (0..n_g).map(|_| rng.uniform_range(0.01, 5.0)).collect();
            z.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let alpha = rng.uniform_range(0.05, 12.0);
            let r1 = rho_group(&z, alpha, n_g);
            let r2 = rho_group_bisect(&z, alpha, n_g);
            assert!(
                (r1 - r2).abs() < 1e-8 * r2.max(1.0),
                "trial {trial}: closed={r1} bisect={r2} z={z:?} alpha={alpha}"
            );
        }
    }

    #[test]
    fn rho_satisfies_defining_equation() {
        let mut rng = Rng::seed_from_u64(52);
        for _ in 0..100 {
            let n_g = 2 + rng.below(8);
            let zf: Vec<f32> = (0..n_g).map(|_| rng.normal(0.0, 2.0) as f32).collect();
            let mut z: Vec<f64> = zf.iter().map(|&v| (v as f64).abs()).collect();
            z.sort_by(|a, b| b.partial_cmp(a).unwrap());
            if z[0] <= 0.0 {
                continue;
            }
            let alpha = rng.uniform_range(0.1, 4.0);
            let rho = rho_group(&z, alpha, n_g);
            // ‖S₁(c/ρ)‖ must equal α√n_g
            let scaled: Vec<f32> = zf.iter().map(|&v| (v as f64 / rho) as f32).collect();
            let lhs = shrink_norm(&scaled, 1.0);
            let rhs = alpha * (n_g as f64).sqrt();
            assert!((lhs - rhs).abs() < 1e-5 * rhs, "lhs={lhs} rhs={rhs}");
        }
    }

    #[test]
    fn lambda_max_boundary_behaviour() {
        // ‖S₁(X_gᵀ y/λ)‖ ≤ α√n_g for all g at λ = λmax, with equality at g*.
        let mut rng = Rng::seed_from_u64(53);
        let x = DenseMatrix::from_fn(15, 24, |_, _| rng.gaussian() as f32);
        let y: Vec<f32> = (0..15).map(|_| rng.gaussian() as f32).collect();
        let g = GroupStructure::from_sizes(&[3, 5, 4, 6, 2, 4]);
        let prob = SglProblem::new(&x, &y, &g);
        for alpha in [0.2, 1.0, 3.0] {
            let lm = sgl_lambda_max(&prob, alpha);
            let mut c = vec![0.0f32; 24];
            let th: Vec<f32> = y.iter().map(|&v| v / lm.lambda_max as f32).collect();
            prob.x.matvec_t(&th, &mut c);
            for (gi, s, e) in prob.groups.iter() {
                let norm = shrink_norm(&c[s..e], 1.0);
                let lim = alpha * prob.groups.weight(gi);
                assert!(norm <= lim * (1.0 + 1e-4), "group {gi} violates at λmax");
                if gi == lm.argmax_group {
                    assert!((norm - lim).abs() < 1e-4 * lim, "argmax group not tight");
                }
            }
        }
    }

    #[test]
    fn lambda1_max_consistent_with_rho() {
        // In (λ₁,λ₂) space: λ₂ = λmax^α, λ₁ = αλmax^α must sit on the
        // boundary curve λ₁ = λ₁^max(λ₂).
        let mut rng = Rng::seed_from_u64(54);
        let x = DenseMatrix::from_fn(10, 12, |_, _| rng.gaussian() as f32);
        let y: Vec<f32> = (0..10).map(|_| rng.gaussian() as f32).collect();
        let g = GroupStructure::uniform(12, 4);
        let prob = SglProblem::new(&x, &y, &g);
        let alpha = 1.5;
        let lm = sgl_lambda_max(&prob, alpha);
        let l1m = lambda1_max(&prob, lm.lambda_max);
        assert!(
            (l1m - alpha * lm.lambda_max).abs() < 1e-6 * l1m.max(1e-12),
            "λ₁max({})={} vs αλmax={}",
            lm.lambda_max,
            l1m,
            alpha * lm.lambda_max
        );
    }

    #[test]
    fn corollary10_limits() {
        // λ₂ ≥ ‖Xᵀy‖∞ ⇒ λ₁^max(λ₂) = 0 (any λ₁ gives zero solution).
        let mut rng = Rng::seed_from_u64(55);
        let x = DenseMatrix::from_fn(8, 6, |_, _| rng.gaussian() as f32);
        let y: Vec<f32> = (0..8).map(|_| rng.gaussian() as f32).collect();
        let g = GroupStructure::uniform(6, 2);
        let prob = SglProblem::new(&x, &y, &g);
        let mut c = vec![0.0f32; 6];
        prob.x.matvec_t(&y, &mut c);
        let linf = c.iter().fold(0.0f64, |m, &v| m.max((v as f64).abs()));
        assert_eq!(lambda1_max(&prob, linf * 1.001), 0.0);
        assert!(lambda1_max(&prob, linf * 0.9) > 0.0);
    }

    #[test]
    fn streaming_lambda_max_bitwise_matches_in_ram() {
        let mut rng = Rng::seed_from_u64(56);
        let x = DenseMatrix::from_fn(20, 30, |_, _| rng.gaussian() as f32);
        let y: Vec<f32> = (0..20).map(|_| rng.gaussian() as f32).collect();
        let g = GroupStructure::from_sizes(&[4, 6, 5, 7, 3, 5]);
        let prob = SglProblem::new(&x, &y, &g);
        for alpha in [0.3, 1.0, 2.5] {
            let full = sgl_lambda_max(&prob, alpha);
            for block in [1usize, 2, 4, 100] {
                let st = sgl_lambda_max_streaming(&prob, alpha, block);
                assert_eq!(
                    st.lambda_max.to_bits(),
                    full.lambda_max.to_bits(),
                    "alpha={alpha} block={block}"
                );
                assert_eq!(st.argmax_group, full.argmax_group);
                assert_eq!(st.rho.len(), full.rho.len());
                for (a, b) in st.rho.iter().zip(&full.rho) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
        }
    }

    #[test]
    fn single_feature_groups_reduce_to_soft_threshold() {
        // n_g = 1: ρ solves (|c|/ρ − 1) = α → ρ = |c|/(1+α).
        let z = [2.0f64];
        for alpha in [0.5, 1.0, 2.0] {
            let rho = rho_group(&z, alpha, 1);
            assert!((rho - 2.0 / (1.0 + alpha)).abs() < 1e-10, "alpha={alpha} rho={rho}");
        }
    }

    #[test]
    fn ties_in_z_handled() {
        let z = [1.0f64, 1.0, 1.0];
        let rho = rho_group(&z, 1.0, 3);
        let rb = rho_group_bisect(&z, 1.0, 3);
        assert!((rho - rb).abs() < 1e-9, "{rho} vs {rb}");
        // Defining equation: 3(1/ρ−1)² = 3 → 1/ρ − 1 = 1 → ρ = ½.
        assert!((rho - 0.5).abs() < 1e-9);
    }
}
