//! Safe screening rules — the paper's contribution.
//!
//! * [`lambda_max`] — the smallest λ with β* = 0 (Theorem 8 / Lemma 9 for
//!   SGL, Theorem 20 for nonnegative Lasso) and the λ₁^max(λ₂) curve
//!   (Corollary 10).
//! * [`dual_est`] — the normal-cone ball estimate of the dual optimum
//!   (Theorem 12 / Theorem 21).
//! * [`supremum`] — closed-form suprema of the nonconvex problems (54)/(55)
//!   (Theorems 15 and 16).
//! * [`tlfre`] — the two-layer rules (L₁)/(L₂) of Theorem 17.
//! * [`dpc`] — the DPC rule for nonnegative Lasso (Theorem 22).
//!
//! All rules are **exact**: a discarded group/feature is guaranteed to be
//! zero at the optimum (verified end-to-end by the safety property tests in
//! `rust/tests/`).

pub mod dpc;
pub mod dual_est;
pub mod lambda_max;
pub mod strong_rule;
pub mod supremum;
pub mod tlfre;

pub use dpc::{dpc_screen, DpcOutcome};
pub use dual_est::{estimate_ball, Ball};
pub use lambda_max::{sgl_lambda_max, LambdaMaxInfo};
pub use tlfre::{tlfre_screen, ScreenStats, TlfreContext, TlfreOutcome};
