//! Safe screening rules — the paper's contribution.
//!
//! * [`lambda_max`] — the smallest λ with β* = 0 (Theorem 8 / Lemma 9 for
//!   SGL, Theorem 20 for nonnegative Lasso) and the λ₁^max(λ₂) curve
//!   (Corollary 10).
//! * [`dual_est`] — the normal-cone ball estimate of the dual optimum
//!   (Theorem 12 / Theorem 21).
//! * [`supremum`] — closed-form suprema of the nonconvex problems (54)/(55)
//!   (Theorems 15 and 16).
//! * [`tlfre`] — the two-layer rules (L₁)/(L₂) of Theorem 17.
//! * [`dpc`] — the DPC rule for nonnegative Lasso (Theorem 22).
//! * [`gap_safe`] — GAP-safe spheres (Ndiaye et al.) built from the duality
//!   gap of *any* primal/dual pair: the static pipeline rule plus the
//!   dynamic states the solvers consult at gap-check cadence.
//! * [`rule`] — the composable [`rule::ScreeningRule`] pipeline unifying
//!   all of the above, with an explicit [`rule::Safety`] marker so
//!   heuristic rules ([`strong_rule`]) always compose with a KKT
//!   post-check in the driver.
//! * [`working_set`] — celer-style aggressive working sets: a heuristic
//!   rule the driver pairs with a loose-then-tight outer loop (grow on
//!   KKT violations, one tight solve at the end).
//!
//! The TLFre/DPC/GAP rules are **exact**: a discarded group/feature is
//! guaranteed to be zero at the optimum (verified end-to-end by the safety
//! property tests in `rust/tests/`). The strong rule is heuristic and only
//! ever runs behind the driver's KKT recovery loop. See
//! `rust/src/screening/README.md` for the full taxonomy and the dynamic
//! screening contract.

pub mod dpc;
pub mod dual_est;
pub mod gap_safe;
pub mod lambda_max;
pub mod rule;
pub mod strong_rule;
pub mod supremum;
pub mod tlfre;
pub mod working_set;

pub use dpc::{dpc_screen, DpcOutcome};
pub use dual_est::{estimate_ball, Ball};
pub use gap_safe::{
    gap_sphere_radius, gap_with_noise_floor, same_support_at_resolution, EvictPlan,
    GapSafeDynamic, GapSafeDynamicNonneg,
};
pub use lambda_max::{sgl_lambda_max, sgl_lambda_max_streaming, LambdaMaxInfo};
pub use rule::{
    stats_from_masks, GapSafeRule, LayerCount, Safety, ScreenInput, ScreenKind, ScreenPipeline,
    ScreeningRule, StrongRule, SurvivorMask, TlfreRule,
};
pub use tlfre::{tlfre_screen, ScreenStats, TlfreContext, TlfreOutcome};
pub use working_set::WorkingSetRule;
