//! Closed-form suprema over the dual-estimate ball (Theorems 15 and 16).
//!
//! Problem (54): `s*_g = sup { ‖S₁(ξ)‖ : ‖ξ − c‖ ≤ r }` with
//! `c = X_gᵀo`, `r = radius·‖X_g‖₂` — a *maximization of a convex function
//! over a ball*, solved in closed form via the decomposition
//! `ξ = P_B∞(ξ) + S₁(ξ)`:
//!
//! * `‖c‖∞ > 1`:  `s* = ‖S₁(c)‖ + r`                         (Thm 15(i))
//! * `‖c‖∞ ≤ 1`:  `s* = (‖c‖∞ + r − 1)₊`                     (Thm 15(ii)+(iii);
//!   the boundary case (ii) is the `‖c‖∞ = 1` limit of (iii), value `r`)
//!
//! Problem (55): `t*_i = sup { |x_iᵀθ| : ‖θ − o‖ ≤ radius }
//!             = |x_iᵀo| + radius·‖x_i‖` (Cauchy–Schwarz, Thm 16).

use crate::prox::shrink_norm_sq;

/// `s*_g` from the group correlation block `c = X_gᵀo` and ball radius
/// `r = radius·‖X_g‖₂` (Theorem 15).
#[inline]
pub fn s_star(c: &[f32], r: f64) -> f64 {
    let cinf = c.iter().fold(0.0f64, |m, &v| m.max((v as f64).abs()));
    if cinf > 1.0 {
        shrink_norm_sq(c, 1.0).sqrt() + r
    } else {
        (cinf + r - 1.0).max(0.0)
    }
}

/// Fused variant returning `(s*_g, ‖c‖∞, ‖S₁(c)‖)` in one pass over `c`
/// (the screening sweep calls this per group).
#[inline]
pub fn s_star_fused(c: &[f32], r: f64) -> (f64, f64, f64) {
    let mut cinf = 0.0f64;
    let mut acc = 0.0f64;
    for &v in c {
        let a = (v as f64).abs();
        cinf = cinf.max(a);
        let t = a - 1.0;
        if t > 0.0 {
            acc += t * t;
        }
    }
    let shrunk = acc.sqrt();
    let s = if cinf > 1.0 { shrunk + r } else { (cinf + r - 1.0).max(0.0) };
    (s, cinf, shrunk)
}

/// `t*_i = |c_i| + radius·‖x_i‖` (Theorem 16) where `c_i = x_iᵀo`.
#[inline]
pub fn t_star(c_i: f64, radius: f64, col_norm: f64) -> f64 {
    c_i.abs() + radius * col_norm
}

/// [`s_star`] evaluated on `scale·c` without materializing the scaled
/// copy — the GAP-safe rules' form, whose sphere center is the gap
/// check's correlation sweep rescaled by `s_feas/λ`. Keeping this next to
/// the canonical accumulation single-sources the Theorem 15 closed form
/// for every consumer (TLFre, static GAP rule, in-solver dynamic states).
#[inline]
pub fn s_star_scaled(c: &[f32], scale: f64, r: f64) -> f64 {
    let mut cinf = 0.0f64;
    let mut acc = 0.0f64;
    for &v in c {
        let a = ((v as f64) * scale).abs();
        cinf = cinf.max(a);
        let t = a - 1.0;
        if t > 0.0 {
            acc += t * t;
        }
    }
    if cinf > 1.0 {
        acc.sqrt() + r
    } else {
        (cinf + r - 1.0).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prox::shrink_norm;
    use crate::util::Rng;

    /// Brute-force the supremum by sampling the sphere ‖ξ−c‖ = r (the max
    /// of a convex function over a ball is attained on the boundary).
    fn s_star_sampled(c: &[f32], r: f64, rng: &mut Rng, trials: usize) -> f64 {
        let m = c.len();
        let mut best = shrink_norm(c, 1.0);
        for _ in 0..trials {
            let dir: Vec<f64> = (0..m).map(|_| rng.gaussian()).collect();
            let n = dir.iter().map(|d| d * d).sum::<f64>().sqrt().max(1e-300);
            let xi: Vec<f32> =
                (0..m).map(|i| (c[i] as f64 + r * dir[i] / n) as f32).collect();
            best = best.max(shrink_norm(&xi, 1.0));
        }
        best
    }

    #[test]
    fn s_star_is_upper_bound_and_tight() {
        let mut rng = Rng::seed_from_u64(61);
        for trial in 0..60 {
            let m = 1 + rng.below(6);
            let scale = if trial % 3 == 0 { 0.5 } else { 2.0 };
            let c: Vec<f32> = (0..m).map(|_| rng.normal(0.0, scale) as f32).collect();
            let r = rng.uniform_range(0.01, 2.0);
            let s = s_star(&c, r);
            let sampled = s_star_sampled(&c, r, &mut rng, 4000);
            assert!(s >= sampled - 1e-4, "not an upper bound: s*={s} sampled={sampled}");
            // Tightness: random sampling gets close for small dims.
            if m <= 3 && sampled > 1e-3 {
                assert!(
                    sampled >= 0.8 * s,
                    "too loose (m={m}): s*={s} sampled={sampled} c={c:?} r={r}"
                );
            }
        }
    }

    #[test]
    fn s_star_maximizer_attains_case_i() {
        // Theorem 15(i): maximizer is c + r·S₁(c)/‖S₁(c)‖.
        let c = vec![2.0f32, -0.5, 1.5];
        let r = 0.7;
        let s = s_star(&c, r);
        let sn = shrink_norm(&c, 1.0);
        let mut xi = c.clone();
        let mut sh = vec![0.0f32; 3];
        crate::prox::shrink(&c, 1.0, &mut sh);
        for i in 0..3 {
            xi[i] += (r * sh[i] as f64 / sn) as f32;
        }
        assert!((shrink_norm(&xi, 1.0) - s).abs() < 1e-6);
    }

    #[test]
    fn s_star_maximizer_attains_case_iii() {
        // Theorem 15(iii): maximizer c + r·sgn(c_{i*})e_{i*}.
        let c = vec![0.6f32, -0.2, 0.3];
        let r = 0.9;
        let s = s_star(&c, r);
        // tolerance: 0.6f32 is not exactly representable
        assert!((s - (0.6 + 0.9 - 1.0)).abs() < 1e-6);
        let mut xi = c.clone();
        xi[0] += r as f32;
        assert!((shrink_norm(&xi, 1.0) - s).abs() < 1e-6);
    }

    #[test]
    fn s_star_scaled_matches_s_star_on_scaled_copy() {
        // The copy-free scaled form must agree with s_star on an
        // explicitly scaled f64-exact input (scale by powers of two so
        // the f32 materialization is lossless).
        let mut rng = Rng::seed_from_u64(62);
        for _ in 0..40 {
            let m = 1 + rng.below(6);
            let c: Vec<f32> = (0..m).map(|_| rng.normal(0.0, 1.5) as f32).collect();
            let r = rng.uniform_range(0.01, 1.5);
            for scale in [0.25f64, 0.5, 1.0, 2.0] {
                let scaled: Vec<f32> = c.iter().map(|&v| (v as f64 * scale) as f32).collect();
                let a = s_star_scaled(&c, scale, r);
                let b = s_star(&scaled, r);
                assert!((a - b).abs() < 1e-12, "scale={scale}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn s_star_boundary_case_ii() {
        // ‖c‖∞ = 1 exactly → s* = r.
        let c = vec![1.0f32, 0.2];
        assert!((s_star(&c, 0.35) - 0.35).abs() < 1e-9);
    }

    #[test]
    fn s_star_zero_when_ball_inside_box() {
        // ‖c‖∞ + r ≤ 1 ⇒ entire ball inside B∞ ⇒ s* = 0 (Thm 15(iii), Ξ⊂B∞).
        let c = vec![0.3f32, -0.2];
        assert_eq!(s_star(&c, 0.4), 0.0);
    }

    #[test]
    fn fused_matches_plain() {
        let mut rng = Rng::seed_from_u64(62);
        for _ in 0..200 {
            let m = 1 + rng.below(10);
            let c: Vec<f32> = (0..m).map(|_| rng.normal(0.0, 1.2) as f32).collect();
            let r = rng.uniform_range(0.0, 1.5);
            let (s, cinf, shrunk) = s_star_fused(&c, r);
            assert!((s - s_star(&c, r)).abs() < 1e-12);
            assert!((shrunk - shrink_norm(&c, 1.0)).abs() < 1e-9);
            let want_inf = c.iter().fold(0.0f64, |mx, &v| mx.max((v as f64).abs()));
            assert!((cinf - want_inf).abs() < 1e-12);
        }
    }

    #[test]
    fn t_star_is_supremum_over_ball() {
        let mut rng = Rng::seed_from_u64(63);
        for _ in 0..50 {
            let n = 4;
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
            let o: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
            let radius = rng.uniform_range(0.1, 1.0);
            let ci = crate::linalg::ops::dot(&x, &o);
            let xnorm = crate::linalg::ops::nrm2(&x);
            let bound = t_star(ci, radius, xnorm);
            // sample θ in the ball
            for _ in 0..500 {
                let dir: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
                let dn = dir.iter().map(|d| d * d).sum::<f64>().sqrt().max(1e-300);
                let scale = radius * rng.uniform();
                let theta: Vec<f32> =
                    (0..n).map(|i| (o[i] as f64 + scale * dir[i] / dn) as f32).collect();
                let v = crate::linalg::ops::dot(&x, &theta).abs();
                assert!(v <= bound + 1e-5, "violated: {v} > {bound}");
            }
            // attained at o + radius·x/‖x‖ (sign-adjusted)
            let sgn = if ci >= 0.0 { 1.0 } else { -1.0 };
            let theta: Vec<f32> =
                (0..n).map(|i| (o[i] as f64 + sgn * radius * x[i] as f64 / xnorm) as f32).collect();
            let attained = crate::linalg::ops::dot(&x, &theta).abs();
            assert!((attained - bound).abs() < 1e-4 * bound.max(1.0));
        }
    }
}
