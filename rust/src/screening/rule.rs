//! The composable screening pipeline: one trait, many rules.
//!
//! Before this module, every screening flavor (TLFre two-layer, strong
//! rule, DPC) was a bespoke function with its own context plumbing through
//! the path driver. [`ScreeningRule`] unifies them behind one interface:
//! each rule *refines* a shared survivor mask (it may only flip
//! kept → rejected), declares whether it is [`Safety::Safe`] (rejections
//! are certificates) or [`Safety::Heuristic`] (rejections may be wrong and
//! must be guarded by a KKT post-check), and reports its marginal
//! rejections so per-rule efficacy is visible in the path statistics.
//!
//! A [`ScreenPipeline`] is an ordered list of rules plus a flag for
//! in-solver dynamic GAP screening ([`crate::screening::gap_safe`]). The
//! named pipelines the config/CLI expose ([`ScreenKind`]):
//!
//! | kind | static rules | dynamic | KKT loop |
//! |---|---|---|---|
//! | `tlfre` (default) | TLFre (L₁)+(L₂) | — | — |
//! | `tlfre+gap` | TLFre, GAP-safe | ✓ | — |
//! | `gap` | GAP-safe | ✓ | — |
//! | `strong+kkt` | strong rule | — | ✓ |
//! | `ws` | working set | — | ✓ (outer loop) |
//! | `tlfre+ws` | TLFre, working set | — | ✓ (outer loop) |
//! | `ws+gap` | GAP-safe, working set | final solve only | ✓ (outer loop) |
//! | `none` | — | — | — |
//!
//! The driver runs the KKT-violation recovery loop
//! ([`crate::screening::strong_rule::kkt_violations`]) whenever *any* rule
//! in the pipeline is heuristic, so heuristic rules always compose into an
//! exact path — by construction, not by caller discipline. Pipelines
//! containing a *working-set* rule ([`ScreenPipeline::has_working_set`] via
//! [`ScreeningRule::is_working_set`]) upgrade that loop to the celer-style
//! loose-then-tight outer loop: loose solves on the working set, geometric
//! growth on violation ([`ScreeningRule::grow`]), one tight solve at the
//! end — see `coordinator/driver.rs` and `screening/working_set.rs`.

use super::gap_safe::gap_sphere_radius;
use super::lambda_max::LambdaMaxInfo;
use super::strong_rule::strong_rule_screen;
use super::supremum::s_star_scaled;
use super::tlfre::{tlfre_screen_inexact, ScreenStats, TlfreContext, TlfreOutcome};
use crate::groups::GroupStructure;
use crate::linalg::DesignMatrix;
use crate::sgl::dual::duality_gap;
use crate::sgl::problem::{SglParams, SglProblem};

/// Whether a rule's rejections are certificates or guesses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Safety {
    /// Rejected coordinates are guaranteed zero at the optimum.
    Safe,
    /// Rejections may be wrong; the driver must run a KKT post-check and
    /// re-admit violators.
    Heuristic,
}

/// Everything a static (per-λ) rule may consult. All dual-side quantities
/// are computed **once** per path step by the driver and shared by every
/// rule in the pipeline — adding a rule adds no matvec.
pub struct ScreenInput<'s, 'a, M: DesignMatrix> {
    pub prob: &'s SglProblem<'a, M>,
    pub alpha: f64,
    /// Target λ of this step.
    pub lambda: f64,
    /// Previous grid point λ̄ (λmax on the first step).
    pub lambda_bar: f64,
    /// Previous solution β̄ (zero on the first step).
    pub beta_bar: &'s [f32],
    /// Residual `y − Xβ̄`.
    pub resid_bar: &'s [f32],
    /// Correlations `c = Xᵀ(y − Xβ̄)`.
    pub corr_bar: &'s [f32],
    /// Feasibility-scaled dual point `s·(y − Xβ̄)/λ̄` (normalized θ-space).
    /// Populated only when some rule in the pipeline declares
    /// [`ScreeningRule::needs_previous_dual`] — otherwise empty, and rules
    /// that did not declare the need must not read it (the driver skips
    /// the feasibility bisection and θ̄ allocation entirely).
    pub theta_bar: &'s [f32],
    /// Duality gap of `(β̄, θ̄)` at λ̄, pre-multiplied by the configured
    /// inflation (the TLFre inexactness guard). Same availability contract
    /// as [`Self::theta_bar`] (0.0 when not populated).
    pub gap_bar: f64,
    pub lmax: &'s LambdaMaxInfo,
    pub ctx: &'s TlfreContext,
}

/// Marginal rejections contributed by one rule, in pipeline order.
#[derive(Debug, Clone)]
pub struct LayerCount {
    pub rule: &'static str,
    pub safety: Safety,
    /// Groups this rule newly rejected.
    pub groups: usize,
    /// Features this rule newly rejected (including those inside its
    /// newly-rejected groups).
    pub features: usize,
}

/// The shared survivor mask a pipeline's rules refine in order.
#[derive(Debug, Clone)]
pub struct SurvivorMask {
    pub group_kept: Vec<bool>,
    pub feature_kept: Vec<bool>,
}

impl SurvivorMask {
    pub fn all_kept(groups: &GroupStructure) -> SurvivorMask {
        SurvivorMask {
            group_kept: vec![true; groups.n_groups()],
            feature_kept: vec![true; groups.n_features()],
        }
    }

    /// AND another outcome's masks into this one, returning the marginal
    /// `(groups, features)` newly rejected. Maintains the invariant that a
    /// rejected group's features are all rejected.
    pub fn intersect(&mut self, group_kept: &[bool], feature_kept: &[bool]) -> (usize, usize) {
        debug_assert_eq!(group_kept.len(), self.group_kept.len());
        debug_assert_eq!(feature_kept.len(), self.feature_kept.len());
        let mut g_new = 0usize;
        for (mine, &theirs) in self.group_kept.iter_mut().zip(group_kept) {
            if *mine && !theirs {
                *mine = false;
                g_new += 1;
            }
        }
        let mut f_new = 0usize;
        for (mine, &theirs) in self.feature_kept.iter_mut().zip(feature_kept) {
            if *mine && !theirs {
                *mine = false;
                f_new += 1;
            }
        }
        (g_new, f_new)
    }
}

/// Recompute [`ScreenStats`] from final masks. Attribution is
/// rule-order-independent: features in rejected groups count toward the
/// paper's r₁ numerator, rejected features inside kept groups toward r₂.
pub fn stats_from_masks(
    groups: &GroupStructure,
    group_kept: &[bool],
    feature_kept: &[bool],
) -> ScreenStats {
    let mut stats = ScreenStats::default();
    for (g, s, e) in groups.iter() {
        if !group_kept[g] {
            stats.groups_rejected += 1;
            stats.features_in_rejected_groups += e - s;
        } else {
            stats.features_rejected_l2 +=
                feature_kept[s..e].iter().filter(|&&k| !k).count();
        }
    }
    stats
}

/// One composable screening rule. Implementations must be *monotone*: they
/// may flip mask entries kept → rejected, never the reverse.
pub trait ScreeningRule<M: DesignMatrix> {
    fn name(&self) -> &'static str;
    fn safety(&self) -> Safety;
    /// Whether this rule reads [`ScreenInput::theta_bar`] /
    /// [`ScreenInput::gap_bar`] (the previous-λ dual point and its gap).
    /// The driver pays the feasibility bisection + θ̄ allocation only when
    /// some rule in the pipeline returns true; rules leaving the default
    /// `false` must confine themselves to `beta_bar`/`resid_bar`/
    /// `corr_bar` and the per-dataset context.
    fn needs_previous_dual(&self) -> bool {
        false
    }
    /// Refine `mask`; return the marginal rejections.
    fn screen(&self, input: &ScreenInput<'_, '_, M>, mask: &mut SurvivorMask) -> LayerCount;
    /// Whether this rule maintains a growable working set
    /// ([`crate::screening::working_set::WorkingSetRule`]). The driver runs
    /// such pipelines through the loose-then-tight outer loop and calls
    /// [`Self::grow`] on KKT violations instead of re-solving immediately
    /// at full accuracy.
    fn is_working_set(&self) -> bool {
        false
    }
    /// Working-set growth hook: admit the next tranche of groups (a
    /// geometric `growth` factor over the currently admitted prefix) into
    /// `outcome`, honouring `safe_mask` — a feature a *safe* rule certified
    /// zero stays rejected. Returns the number of groups newly admitted;
    /// the default (non-working-set rules) admits nothing.
    fn grow(
        &self,
        _groups: &GroupStructure,
        _outcome: &mut TlfreOutcome,
        _safe_mask: &SurvivorMask,
        _growth: f64,
    ) -> usize {
        0
    }
}

// ---------------------------------------------------------------------------
// Concrete rules
// ---------------------------------------------------------------------------

/// The paper's two-layer rule (Theorem 17), inexactness-robust via the
/// `√(2·gap)` radius inflation of `tlfre_screen_inexact`.
#[derive(Debug, Clone, Copy, Default)]
pub struct TlfreRule;

impl<M: DesignMatrix> ScreeningRule<M> for TlfreRule {
    fn name(&self) -> &'static str {
        "tlfre"
    }

    fn safety(&self) -> Safety {
        Safety::Safe
    }

    fn needs_previous_dual(&self) -> bool {
        // Theorem 12's ball is anchored at the previous-λ dual optimum.
        true
    }

    fn screen(&self, input: &ScreenInput<'_, '_, M>, mask: &mut SurvivorMask) -> LayerCount {
        let out = tlfre_screen_inexact(
            input.prob,
            input.alpha,
            input.lambda,
            input.lambda_bar,
            input.theta_bar,
            input.gap_bar,
            input.lmax,
            input.ctx,
        );
        let (groups, features) = mask.intersect(&out.group_kept, &out.feature_kept);
        LayerCount { rule: "tlfre", safety: Safety::Safe, groups, features }
    }
}

/// GAP-safe sphere rule (Ndiaye et al.): sphere of radius `√(2·gap)/λ`
/// around the feasibility-scaled residual, with the gap evaluated **at the
/// target λ** — valid for arbitrarily inexact previous solves, no
/// sequential-exactness assumption at all. Reuses the step's existing
/// residual/correlation sweeps; the only extra cost is two O(p) probes.
#[derive(Debug, Clone, Copy, Default)]
pub struct GapSafeRule;

impl<M: DesignMatrix> ScreeningRule<M> for GapSafeRule {
    fn name(&self) -> &'static str {
        "gap"
    }

    fn safety(&self) -> Safety {
        Safety::Safe
    }

    fn screen(&self, input: &ScreenInput<'_, '_, M>, mask: &mut SurvivorMask) -> LayerCount {
        let params = SglParams::from_alpha_lambda(input.alpha, input.lambda);
        let (gap, s_feas) = duality_gap(
            input.prob,
            &params,
            input.beta_bar,
            input.resid_bar,
            input.corr_bar,
        );
        // Floor at the f32 gap-evaluation noise scale (see
        // `gap_safe::gap_with_noise_floor`).
        let gap = super::gap_safe::gap_with_noise_floor(
            gap,
            crate::sgl::dual::null_objective(input.prob.y),
        );
        let rho = gap_sphere_radius(gap, input.lambda);
        let scale = s_feas / input.lambda;
        let groups = input.prob.groups;
        let ctx = input.ctx;
        let mut g_new = 0usize;
        let mut f_new = 0usize;
        for (g, s_idx, e_idx) in groups.iter() {
            if !mask.group_kept[g] {
                continue;
            }
            let r_g = rho * ctx.group_spectral[g];
            // Theorem 15 supremum over the rescaled correlations
            // (single-sourced in `supremum::s_star_scaled`).
            let s_g = s_star_scaled(&input.corr_bar[s_idx..e_idx], scale, r_g);
            if s_g < input.alpha * groups.weight(g) {
                mask.group_kept[g] = false;
                g_new += 1;
                for k in mask.feature_kept[s_idx..e_idx].iter_mut() {
                    if *k {
                        *k = false;
                        f_new += 1;
                    }
                }
            } else {
                for i in s_idx..e_idx {
                    if mask.feature_kept[i]
                        && ((input.corr_bar[i] as f64) * scale).abs() + rho * ctx.col_norms[i]
                            <= 1.0
                    {
                        mask.feature_kept[i] = false;
                        f_new += 1;
                    }
                }
            }
        }
        LayerCount { rule: "gap", safety: Safety::Safe, groups: g_new, features: f_new }
    }
}

/// The strong-rule heuristic (Tibshirani et al.) — *not* safe; the driver
/// pairs it with the KKT recovery loop whenever it appears in a pipeline.
#[derive(Debug, Clone, Copy, Default)]
pub struct StrongRule;

impl<M: DesignMatrix> ScreeningRule<M> for StrongRule {
    fn name(&self) -> &'static str {
        "strong"
    }

    fn safety(&self) -> Safety {
        Safety::Heuristic
    }

    fn screen(&self, input: &ScreenInput<'_, '_, M>, mask: &mut SurvivorMask) -> LayerCount {
        let out = strong_rule_screen(
            input.prob,
            input.alpha,
            input.lambda,
            input.lambda_bar,
            input.corr_bar,
        );
        let (groups, features) = mask.intersect(&out.group_kept, &out.feature_kept);
        LayerCount { rule: "strong", safety: Safety::Heuristic, groups, features }
    }
}

// ---------------------------------------------------------------------------
// Pipeline
// ---------------------------------------------------------------------------

/// Named pipeline selection for config/CLI (`PathConfig::screen`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScreenKind {
    /// The paper's exact two-layer rule (the default; PR-4 behaviour).
    #[default]
    Tlfre,
    /// TLFre + static GAP-safe, plus dynamic GAP screening in the solver.
    TlfreGap,
    /// Static GAP-safe only, plus dynamic GAP screening in the solver.
    Gap,
    /// Strong-rule heuristic guarded by the KKT recovery loop.
    StrongKkt,
    /// Celer-style working set alone, grown on KKT violations under the
    /// driver's loose-then-tight outer loop.
    Ws,
    /// TLFre safe screening first, working set inside the survivors.
    TlfreWs,
    /// Static GAP-safe screening first, working set inside the survivors;
    /// dynamic GAP eviction rides only the final tight solve.
    WsGap,
    /// No screening: the pipeline keeps everything (full solve per λ
    /// through the engine's reduced-problem plumbing — a keep-all view).
    /// For timing-grade no-screening baselines prefer
    /// `run_baseline_path`, which solves on the raw matrix with zero
    /// per-step reduction bookkeeping; `none` exists so pipeline
    /// selection is total and A/B-able through one code path.
    None,
}

impl ScreenKind {
    /// Parse the config/CLI spelling.
    pub fn parse(s: &str) -> Option<ScreenKind> {
        match s {
            "tlfre" => Some(ScreenKind::Tlfre),
            "tlfre+gap" => Some(ScreenKind::TlfreGap),
            "gap" => Some(ScreenKind::Gap),
            "strong+kkt" => Some(ScreenKind::StrongKkt),
            "ws" => Some(ScreenKind::Ws),
            "tlfre+ws" => Some(ScreenKind::TlfreWs),
            "ws+gap" => Some(ScreenKind::WsGap),
            "none" => Some(ScreenKind::None),
            _ => Option::None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ScreenKind::Tlfre => "tlfre",
            ScreenKind::TlfreGap => "tlfre+gap",
            ScreenKind::Gap => "gap",
            ScreenKind::StrongKkt => "strong+kkt",
            ScreenKind::Ws => "ws",
            ScreenKind::TlfreWs => "tlfre+ws",
            ScreenKind::WsGap => "ws+gap",
            ScreenKind::None => "none",
        }
    }

    /// Whether this kind turns on in-solver dynamic GAP screening. For
    /// `ws+gap` the driver attaches it only to tight solve rounds.
    pub fn dynamic(&self) -> bool {
        matches!(self, ScreenKind::TlfreGap | ScreenKind::Gap | ScreenKind::WsGap)
    }
}

/// An ordered rule list plus the dynamic-screening flag. Build a named one
/// with [`ScreenPipeline::for_kind`] or compose your own with
/// [`ScreenPipeline::new`] (the driver exposes
/// `drive_tlfre_path_with_pipeline` for custom pipelines).
///
/// `dynamic` only takes effect when the pipeline is [`Self::all_safe`]:
/// the in-solver GAP sphere certifies zeros of the problem the solver is
/// actually given, so a heuristically mis-reduced problem (correct only
/// after the KKT recovery loop) must not feed it — the driver enforces
/// this.
pub struct ScreenPipeline<M: DesignMatrix> {
    rules: Vec<Box<dyn ScreeningRule<M>>>,
    dynamic: bool,
}

impl<M: DesignMatrix> ScreenPipeline<M> {
    pub fn new(rules: Vec<Box<dyn ScreeningRule<M>>>, dynamic: bool) -> ScreenPipeline<M> {
        ScreenPipeline { rules, dynamic }
    }

    pub fn for_kind(kind: ScreenKind) -> ScreenPipeline<M> {
        let (rules, dynamic): (Vec<Box<dyn ScreeningRule<M>>>, bool) = match kind {
            ScreenKind::Tlfre => (vec![Box::new(TlfreRule)], false),
            ScreenKind::TlfreGap => (vec![Box::new(TlfreRule), Box::new(GapSafeRule)], true),
            ScreenKind::Gap => (vec![Box::new(GapSafeRule)], true),
            ScreenKind::StrongKkt => (vec![Box::new(StrongRule)], false),
            // Safe rules come first so `screen_full`'s safe-mask snapshot
            // (the set working-set growth may re-admit into) is exactly the
            // safe survivor set.
            ScreenKind::Ws => {
                (vec![Box::new(super::working_set::WorkingSetRule::new())], false)
            }
            ScreenKind::TlfreWs => (
                vec![
                    Box::new(TlfreRule),
                    Box::new(super::working_set::WorkingSetRule::new()),
                ],
                false,
            ),
            ScreenKind::WsGap => (
                vec![
                    Box::new(GapSafeRule),
                    Box::new(super::working_set::WorkingSetRule::new()),
                ],
                true,
            ),
            ScreenKind::None => (Vec::new(), false),
        };
        ScreenPipeline { rules, dynamic }
    }

    /// No rules at all (the `none` pipeline): the driver skips the dual
    /// preamble entirely.
    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }

    /// Whether the driver should attach the dynamic GAP state to solves.
    pub fn dynamic(&self) -> bool {
        self.dynamic
    }

    /// True iff every rule's rejections are certificates. The driver runs
    /// the KKT recovery loop exactly when this is false.
    pub fn all_safe(&self) -> bool {
        self.rules.iter().all(|r| r.safety() == Safety::Safe)
    }

    /// Whether any rule needs the previous-λ dual point (θ̄ + its gap);
    /// the driver skips that part of the preamble otherwise.
    pub fn needs_previous_dual(&self) -> bool {
        self.rules.iter().any(|r| r.needs_previous_dual())
    }

    /// Whether some rule maintains a growable working set — the driver then
    /// runs the loose-then-tight outer loop instead of the plain KKT
    /// recovery loop.
    pub fn has_working_set(&self) -> bool {
        self.rules.iter().any(|r| r.is_working_set())
    }

    /// Forward a growth request to the working-set rule(s); pipelines
    /// without one admit nothing and return 0.
    pub fn grow(
        &self,
        groups: &GroupStructure,
        outcome: &mut TlfreOutcome,
        safe_mask: &SurvivorMask,
        growth: f64,
    ) -> usize {
        self.rules
            .iter()
            .map(|r| r.grow(groups, outcome, safe_mask, growth))
            .sum()
    }

    /// Run every rule in order over a fresh mask; returns the merged
    /// outcome (stats recomputed from the final masks) and the per-rule
    /// marginal rejection counts.
    pub fn screen(&self, input: &ScreenInput<'_, '_, M>) -> (TlfreOutcome, Vec<LayerCount>) {
        let (outcome, layers, _) = self.screen_full(input);
        (outcome, layers)
    }

    /// [`Self::screen`] that additionally returns the mask as the *safe*
    /// rules left it, snapshotted just before the first heuristic rule runs
    /// (the built-in pipelines order safe rules first). The driver's
    /// working-set outer loop grows into exactly this set, so a feature a
    /// safe rule certified zero is never re-admitted by growth; for
    /// all-safe pipelines the snapshot equals the final mask.
    pub fn screen_full(
        &self,
        input: &ScreenInput<'_, '_, M>,
    ) -> (TlfreOutcome, Vec<LayerCount>, SurvivorMask) {
        let groups = input.prob.groups;
        let mut mask = SurvivorMask::all_kept(groups);
        let mut safe_mask: Option<SurvivorMask> = Option::None;
        let mut layers = Vec::with_capacity(self.rules.len());
        for rule in &self.rules {
            if safe_mask.is_none() && rule.safety() == Safety::Heuristic {
                safe_mask = Some(mask.clone());
            }
            layers.push(rule.screen(input, &mut mask));
        }
        let safe_mask = safe_mask.unwrap_or_else(|| mask.clone());
        let stats = stats_from_masks(groups, &mask.group_kept, &mask.feature_kept);
        (
            TlfreOutcome {
                group_kept: mask.group_kept,
                feature_kept: mask.feature_kept,
                stats,
            },
            layers,
            safe_mask,
        )
    }
}

impl<M: DesignMatrix> std::fmt::Debug for ScreenPipeline<M> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ScreenPipeline")
            .field("rules", &self.rules.iter().map(|r| r.name()).collect::<Vec<_>>())
            .field("dynamic", &self.dynamic)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;
    use crate::screening::lambda_max::sgl_lambda_max;
    use crate::util::Rng;

    fn setup(
        seed: u64,
    ) -> (DenseMatrix, Vec<f32>, GroupStructure) {
        let mut rng = Rng::seed_from_u64(seed);
        let n = 25;
        let p = 48;
        let x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian() as f32);
        let groups = GroupStructure::uniform(p, 8);
        let mut beta = vec![0.0f32; p];
        for j in 0..6 {
            beta[j * 7 % p] = rng.normal(0.0, 1.0) as f32;
        }
        let mut y = vec![0.0f32; n];
        x.matvec(&beta, &mut y);
        (x, y, groups)
    }

    /// Build a full ScreenInput for the first path step (from λmax).
    fn first_step_input<'s, 'a>(
        prob: &'s SglProblem<'a, DenseMatrix>,
        alpha: f64,
        lambda: f64,
        lmax: &'s LambdaMaxInfo,
        ctx: &'s TlfreContext,
        bufs: &'s (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>),
    ) -> ScreenInput<'s, 'a, DenseMatrix> {
        ScreenInput {
            prob,
            alpha,
            lambda,
            lambda_bar: lmax.lambda_max,
            beta_bar: &bufs.0,
            resid_bar: &bufs.1,
            corr_bar: &bufs.2,
            theta_bar: &bufs.3,
            gap_bar: 0.0,
            lmax,
            ctx,
        }
    }

    fn make_bufs(
        prob: &SglProblem<'_, DenseMatrix>,
        lambda_bar: f64,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let beta = vec![0.0f32; prob.n_features()];
        let resid = prob.y.to_vec();
        let mut corr = vec![0.0f32; prob.n_features()];
        prob.x.matvec_t(&resid, &mut corr);
        let theta: Vec<f32> =
            resid.iter().map(|&v| (v as f64 / lambda_bar) as f32).collect();
        (beta, resid, corr, theta)
    }

    #[test]
    fn tlfre_pipeline_matches_direct_rule() {
        let (x, y, groups) = setup(911);
        let prob = SglProblem::new(&x, &y, &groups);
        let alpha = 1.0;
        let lmax = sgl_lambda_max(&prob, alpha);
        let ctx = TlfreContext::precompute(&prob);
        let lambda = 0.8 * lmax.lambda_max;
        let bufs = make_bufs(&prob, lmax.lambda_max);
        let input = first_step_input(&prob, alpha, lambda, &lmax, &ctx, &bufs);
        let pipe: ScreenPipeline<DenseMatrix> = ScreenPipeline::for_kind(ScreenKind::Tlfre);
        let (out, layers) = pipe.screen(&input);
        let direct = crate::screening::tlfre::tlfre_screen(
            &prob, alpha, lambda, lmax.lambda_max, &bufs.3, &lmax, &ctx,
        );
        assert_eq!(out.group_kept, direct.group_kept);
        assert_eq!(out.feature_kept, direct.feature_kept);
        assert_eq!(out.stats.groups_rejected, direct.stats.groups_rejected);
        assert_eq!(
            out.stats.features_in_rejected_groups,
            direct.stats.features_in_rejected_groups
        );
        assert_eq!(out.stats.features_rejected_l2, direct.stats.features_rejected_l2);
        assert_eq!(layers.len(), 1);
        assert_eq!(layers[0].rule, "tlfre");
        assert_eq!(layers[0].features, direct.total_rejected());
    }

    #[test]
    fn composed_pipeline_is_monotone_and_marginal_counts_sum() {
        let (x, y, groups) = setup(912);
        let prob = SglProblem::new(&x, &y, &groups);
        let alpha = 1.0;
        let lmax = sgl_lambda_max(&prob, alpha);
        let ctx = TlfreContext::precompute(&prob);
        let lambda = 0.7 * lmax.lambda_max;
        let bufs = make_bufs(&prob, lmax.lambda_max);
        let input = first_step_input(&prob, alpha, lambda, &lmax, &ctx, &bufs);
        let solo: ScreenPipeline<DenseMatrix> = ScreenPipeline::for_kind(ScreenKind::Tlfre);
        let combo: ScreenPipeline<DenseMatrix> = ScreenPipeline::for_kind(ScreenKind::TlfreGap);
        assert!(combo.dynamic() && combo.all_safe());
        let (a, _) = solo.screen(&input);
        let (b, layers) = combo.screen(&input);
        // Adding a safe rule can only reject more.
        for i in 0..prob.n_features() {
            if !a.feature_kept[i] {
                assert!(!b.feature_kept[i], "composition un-rejected feature {i}");
            }
        }
        let total: usize = layers.iter().map(|l| l.features).sum();
        assert_eq!(total, b.feature_kept.iter().filter(|&&k| !k).count());
    }

    #[test]
    fn gap_rule_rejections_are_safe() {
        let (x, y, groups) = setup(913);
        let prob = SglProblem::new(&x, &y, &groups);
        let alpha = 1.0;
        let lmax = sgl_lambda_max(&prob, alpha);
        let ctx = TlfreContext::precompute(&prob);
        let lambda = 0.75 * lmax.lambda_max;
        let bufs = make_bufs(&prob, lmax.lambda_max);
        let input = first_step_input(&prob, alpha, lambda, &lmax, &ctx, &bufs);
        let pipe: ScreenPipeline<DenseMatrix> = ScreenPipeline::for_kind(ScreenKind::Gap);
        let (out, _) = pipe.screen(&input);
        let params = SglParams::from_alpha_lambda(alpha, lambda);
        let sol = crate::sgl::fista::solve_fista(
            &prob,
            &params,
            Option::None,
            &crate::sgl::fista::FistaOptions { tol: 1e-10, ..Default::default() },
        );
        for j in 0..prob.n_features() {
            if !out.feature_kept[j] {
                assert!(sol.beta[j].abs() < 1e-5, "gap rule screened live feature {j}");
            }
        }
    }

    #[test]
    fn kind_parse_roundtrip() {
        for kind in [
            ScreenKind::Tlfre,
            ScreenKind::TlfreGap,
            ScreenKind::Gap,
            ScreenKind::StrongKkt,
            ScreenKind::Ws,
            ScreenKind::TlfreWs,
            ScreenKind::WsGap,
            ScreenKind::None,
        ] {
            assert_eq!(ScreenKind::parse(kind.as_str()), Some(kind));
        }
        assert_eq!(ScreenKind::parse("magic"), Option::None);
        assert_eq!(ScreenKind::default(), ScreenKind::Tlfre);
        assert!(!ScreenKind::Tlfre.dynamic());
        assert!(ScreenKind::TlfreGap.dynamic() && ScreenKind::Gap.dynamic());
        assert!(ScreenKind::WsGap.dynamic());
        assert!(!ScreenKind::Ws.dynamic() && !ScreenKind::TlfreWs.dynamic());
    }

    #[test]
    fn ws_pipelines_flag_working_set_and_snapshot_safe_mask() {
        for kind in [ScreenKind::Ws, ScreenKind::TlfreWs, ScreenKind::WsGap] {
            let pipe: ScreenPipeline<DenseMatrix> = ScreenPipeline::for_kind(kind);
            assert!(pipe.has_working_set(), "{kind:?} should carry a working set");
            assert!(!pipe.all_safe(), "{kind:?} must be guarded by the KKT loop");
        }
        let safe: ScreenPipeline<DenseMatrix> = ScreenPipeline::for_kind(ScreenKind::TlfreGap);
        assert!(!safe.has_working_set());

        // The safe-mask snapshot from `tlfre+ws` equals the plain `tlfre`
        // survivor set (what growth may re-admit into), while the outcome
        // itself is a subset of it.
        let (x, y, groups) = setup(914);
        let prob = SglProblem::new(&x, &y, &groups);
        let alpha = 1.0;
        let lmax = sgl_lambda_max(&prob, alpha);
        let ctx = TlfreContext::precompute(&prob);
        let lambda = 0.7 * lmax.lambda_max;
        let bufs = make_bufs(&prob, lmax.lambda_max);
        let input = first_step_input(&prob, alpha, lambda, &lmax, &ctx, &bufs);
        let solo: ScreenPipeline<DenseMatrix> = ScreenPipeline::for_kind(ScreenKind::Tlfre);
        let (tlfre_out, _) = solo.screen(&input);
        let combo: ScreenPipeline<DenseMatrix> = ScreenPipeline::for_kind(ScreenKind::TlfreWs);
        let (out, layers, safe_mask) = combo.screen_full(&input);
        assert_eq!(safe_mask.group_kept, tlfre_out.group_kept);
        assert_eq!(safe_mask.feature_kept, tlfre_out.feature_kept);
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[1].rule, "ws");
        assert_eq!(layers[1].safety, Safety::Heuristic);
        for i in 0..prob.n_features() {
            if out.feature_kept[i] {
                assert!(safe_mask.feature_kept[i], "ws admitted a safely-screened feature");
            }
        }
    }

    #[test]
    fn strong_pipeline_flags_heuristic() {
        let pipe: ScreenPipeline<DenseMatrix> = ScreenPipeline::for_kind(ScreenKind::StrongKkt);
        assert!(!pipe.all_safe());
        let none: ScreenPipeline<DenseMatrix> = ScreenPipeline::for_kind(ScreenKind::None);
        assert!(none.is_empty() && none.all_safe() && !none.dynamic());
    }

    #[test]
    fn stats_from_masks_attribution() {
        let groups = GroupStructure::from_sizes(&[2, 3, 1]);
        // Group 0 rejected entirely; one feature of group 1 rejected.
        let gk = vec![false, true, true];
        let fk = vec![false, false, true, false, true, true];
        let s = stats_from_masks(&groups, &gk, &fk);
        assert_eq!(s.groups_rejected, 1);
        assert_eq!(s.features_in_rejected_groups, 2);
        assert_eq!(s.features_rejected_l2, 1);
    }
}
