//! Dual-optimum estimation via normal cones (Theorem 12 / Theorem 21).
//!
//! Given the exact dual optimum `θ̄ = θ*(λ̄)` at a previous path point λ̄ and
//! a vector `n ∈ N_F(θ̄)` in the normal cone of the dual feasible set at θ̄,
//! the next dual optimum satisfies
//!
//! ```text
//! ‖θ*(λ) − (θ̄ + ½v⊥)‖ ≤ ½‖v⊥‖,     v = y/λ − θ̄,
//! v⊥ = v − (⟨v, n⟩/‖n‖²)·n.
//! ```
//!
//! The geometry is shared between TLFre (SGL) and DPC (nonnegative Lasso);
//! only the normal vector construction differs:
//! * λ̄ < λmax: `n = y/λ̄ − θ̄` (projection residual, Prop. 11(iii));
//! * λ̄ = λmax (SGL): `n = X_* S₁(X_*ᵀ y/λmax)` for the argmax group `X_*`;
//! * λ̄ = λmax (DPC): `n = x_*`, the argmax column.

use crate::linalg::ops;

/// A ball `‖θ − o‖ ≤ radius` guaranteed to contain the dual optimum.
#[derive(Debug, Clone)]
pub struct Ball {
    /// Center `o = θ̄ + ½ v⊥`.
    pub center: Vec<f32>,
    /// Radius `½‖v⊥‖`.
    pub radius: f64,
}

/// Compute the Theorem 12(ii) ball from `θ̄`, the normal `n`, and `y/λ`.
///
/// `y_over_lambda` is the *new* λ's scaled response. Degenerate `n ≈ 0`
/// (can happen with approximately-solved previous problems whose residual
/// normal vanishes) falls back to the un-projected `v`, which is still a
/// valid — just looser — bound (it is the plain SAFE-style ball).
pub fn estimate_ball(theta_bar: &[f32], n_vec: &[f32], y_over_lambda: &[f32]) -> Ball {
    let n = theta_bar.len();
    debug_assert_eq!(n_vec.len(), n);
    debug_assert_eq!(y_over_lambda.len(), n);
    // v = y/λ − θ̄
    let mut v = vec![0.0f32; n];
    ops::sub(y_over_lambda, theta_bar, &mut v);
    let nn = ops::nrm2_sq(n_vec);
    let mut vperp = v.clone();
    if nn > 1e-30 {
        let coef = (ops::dot(&v, n_vec) / nn) as f32;
        for i in 0..n {
            vperp[i] -= coef * n_vec[i];
        }
    }
    let radius = 0.5 * ops::nrm2(&vperp);
    let mut center = vec![0.0f32; n];
    for i in 0..n {
        center[i] = theta_bar[i] + 0.5 * vperp[i];
    }
    Ball { center, radius }
}

/// The normal vector for an *interior* path step (λ̄ < λmax):
/// `n = y/λ̄ − θ̄`.
pub fn normal_interior(theta_bar: &[f32], y_over_lambda_bar: &[f32]) -> Vec<f32> {
    let mut n = vec![0.0f32; theta_bar.len()];
    ops::sub(y_over_lambda_bar, theta_bar, &mut n);
    n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perpendicular_component_orthogonal_to_n() {
        let theta = vec![1.0f32, 0.0, 0.0];
        let nvec = vec![0.0f32, 1.0, 0.0];
        let yl = vec![2.0f32, 3.0, 4.0];
        let ball = estimate_ball(&theta, &nvec, &yl);
        // v = (1,3,4); v⊥ = (1,0,4); center = θ̄+½v⊥ = (1.5,0,2); r = ½√17
        assert!((ball.radius - 0.5 * (17.0f64).sqrt()).abs() < 1e-6);
        assert!((ball.center[0] - 1.5).abs() < 1e-6);
        assert!((ball.center[1] - 0.0).abs() < 1e-6);
        assert!((ball.center[2] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn projection_shrinks_radius() {
        // ‖v⊥‖ ≤ ‖v‖ always — the two-layer estimate is at least as tight
        // as the naive ball.
        let theta = vec![0.5f32, -0.25, 1.0, 0.0];
        let nvec = vec![1.0f32, 2.0, -1.0, 0.5];
        let yl = vec![1.0f32, 1.0, 1.0, 1.0];
        let ball = estimate_ball(&theta, &nvec, &yl);
        let mut v = vec![0.0f32; 4];
        ops::sub(&yl, &theta, &mut v);
        assert!(ball.radius <= 0.5 * ops::nrm2(&v) + 1e-9);
    }

    #[test]
    fn zero_normal_falls_back_to_v() {
        let theta = vec![1.0f32, 1.0];
        let nvec = vec![0.0f32, 0.0];
        let yl = vec![3.0f32, 1.0];
        let ball = estimate_ball(&theta, &nvec, &yl);
        assert!((ball.radius - 1.0).abs() < 1e-6); // ½‖(2,0)‖
        assert!((ball.center[0] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn same_lambda_gives_zero_radius_interior() {
        // λ = λ̄ ⇒ v = n (interior case) ⇒ v⊥ = 0 ⇒ the ball is {θ̄}.
        let theta = vec![0.3f32, -0.7, 0.2];
        let yl_bar = vec![1.0f32, 0.5, -0.25];
        let nvec = normal_interior(&theta, &yl_bar);
        let ball = estimate_ball(&theta, &nvec, &yl_bar);
        assert!(ball.radius < 1e-7);
        for i in 0..3 {
            assert!((ball.center[i] - theta[i]).abs() < 1e-6);
        }
    }
}
