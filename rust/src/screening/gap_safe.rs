//! GAP-safe sphere screening (Ndiaye et al., *GAP Safe Screening Rules for
//! Sparse-Group Lasso*), adapted to this crate's dual geometry.
//!
//! TLFre's Theorem 12 ball needs the **exact** dual optimum at the previous
//! path point — which warm-started iterative solves never provide. The
//! GAP-safe construction needs only a primal/dual *pair*: the dual
//! objective `D(θ) = ½‖y‖² − ½‖y − θ‖²` is 1-strongly concave, so for the
//! dual optimum `θ*` and any feasible `θ`
//!
//! ```text
//! ½‖θ − θ*‖² ≤ D(θ*) − D(θ) ≤ P(β) − D(θ) = gap(β, θ),
//! ```
//!
//! i.e. `θ*` lies in the sphere of radius `√(2·gap)` around `θ`. In the
//! normalized θ̃ = θ/λ space every screening rule in this crate operates in
//! (see [`crate::screening::tlfre`]), the radius is `√(2·gap)/λ` — see
//! [`gap_sphere_radius`]. The feasible `θ` is exactly the
//! feasibility-scaled residual the solvers already build for every gap
//! check ([`crate::sgl::dual::duality_gap`] returns the scale), so a
//! GAP-safe screen costs **no extra matvec**: the correlation sweep
//! `c = Xᵀr` from the gap check doubles as the sphere-center correlations
//! after an `s/λ` rescale.
//!
//! Two consumers:
//!
//! * the **static** pipeline rule (`screening::rule::GapSafeRule`) screens
//!   once per path step from the previous solution's gap *at the new λ* —
//!   safe under inexact warm starts by construction, no exactness caveat;
//! * the **dynamic** states in this module ([`GapSafeDynamic`],
//!   [`GapSafeDynamicNonneg`]) ride *inside* the solvers: at every gap
//!   check the sphere shrinks with the gap, certifying more features zero
//!   while the solve is still running. The solver compacts its live
//!   problem on each eviction (see `sgl::fista` / `sgl::bcd` /
//!   [`crate::nonneg`]), so later iterations run on fewer columns.
//!
//! Both apply the *same* closed-form layer tests as TLFre (Theorems 15/16
//! suprema) — those are valid for **any** ball containing the dual optimum,
//! which is what makes the rules composable.

use super::supremum::s_star_scaled;
use crate::groups::GroupStructure;
use crate::util::retain_by_mask;

/// Radius of the GAP-safe sphere in the normalized dual space θ̃ = θ/λ:
/// `‖θ̃ − θ̃*‖ ≤ √(2·gap)/λ` (1-strong concavity of the dual in θ).
#[inline]
pub fn gap_sphere_radius(gap: f64, lambda: f64) -> f64 {
    (2.0 * gap.max(0.0)).sqrt() / lambda
}

/// Guard against the f32 gap-evaluation noise floor: the residual
/// `r = y − Xβ` is stored in f32, so the measured `P(β) − D(θ)` can
/// understate the true gap by O(ε_f32·‖y‖²) — in the worst case clamping
/// to 0 and collapsing the sphere onto the (inexact) dual point, where an
/// active feature's KKT equality `|x_iᵀθ̃*| = 1` would read as rejectable.
/// Flooring the gap at a small multiple of the objective scale `½‖y‖²`
/// keeps the sphere honestly sized; at `1e-7` relative the extra radius
/// is far below any screening threshold's slack, so evictions near
/// convergence are unaffected. Every sphere construction (static rule and
/// dynamic states) routes through this.
#[inline]
pub fn gap_with_noise_floor(gap: f64, objective_scale: f64) -> f64 {
    gap.max(1e-7 * objective_scale.max(0.0))
}

/// Support equality at solver resolution — the single comparator behind
/// every dynamic-screening support-equality assertion (solver unit tests,
/// `tests/dynamic_screening.rs`, and the CI-gated `support_equal` field
/// of `perf_kernels`' dynamic_screening section). Single-cut thresholds
/// misread borderline coordinates at finite tolerance as support changes
/// (two equally valid approximate solutions can land a |β| ≈ noise-floor
/// coordinate on either side of one cut), so this uses a hysteresis band:
/// a clearly active coordinate in one solution (|β| > 1e-2, the
/// planted-signal scale of the test problems) must not be clearly zero in
/// the other (|β| < 1e-4, the solvers' noise floor).
pub fn same_support_at_resolution(a: &[f32], b: &[f32]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).all(|(&x, &y)| {
        !((x.abs() > 1e-2 && y.abs() < 1e-4) || (y.abs() > 1e-2 && x.abs() < 1e-4))
    })
}

/// Outcome of one dynamic check: the per-feature keep mask over the
/// solver's *current* (already reduced) feature space.
#[derive(Debug, Clone)]
pub struct EvictPlan {
    /// `false` ⇒ the feature is certified zero and must be dropped.
    pub feature_kept: Vec<bool>,
    /// Number of surviving features.
    pub kept: usize,
}

/// Dynamic GAP-safe screening state for an SGL solve (FISTA or BCD).
///
/// Built by the path driver per reduced solve (projecting the path-level
/// [`crate::screening::tlfre::TlfreContext`] onto the survivor view — see
/// `ReducedProblem::project_screen_context`) and handed to the solver via
/// `FistaOptions::dynamic_screen` / `BcdOptions::dynamic_screen`. The state
/// compacts its own per-column/per-group data in lockstep with the solver's
/// compaction, so the two always agree on the index space.
///
/// Safety: every eviction is certified by the sphere bound above with a
/// *feasible* dual point and conservative (full-matrix) group spectral
/// norms — `σmax(X_g[:,S]) ≤ σmax(X_g)` only enlarges the group ball, never
/// the other way. Evictions are therefore exactly as safe as the static
/// rules, and the tier-1 support-equality tests exercise this end to end.
#[derive(Debug)]
pub struct GapSafeDynamic {
    alpha: f64,
    /// `‖x_i‖` per current column (exact — columns are shared with `X`).
    col_norms: Vec<f64>,
    /// Upper bound on `‖X_g‖₂` per current group.
    group_spectral: Vec<f64>,
    /// Current column → index in the state's *construction* space (the
    /// solver's input problem); compacts in lockstep with everything else
    /// so evictions can be reported in stable coordinates.
    ids: Vec<usize>,
    /// Construction-space indices of every feature evicted so far — the
    /// driver maps these through the reduced problem's feature map to
    /// verify dynamic evictions against an independent full solve.
    evicted_ids: Vec<usize>,
}

impl GapSafeDynamic {
    /// `col_norms`/`group_spectral` must be indexed by the solver's current
    /// (reduced) columns/groups.
    pub fn new(alpha: f64, col_norms: Vec<f64>, group_spectral: Vec<f64>) -> GapSafeDynamic {
        let p = col_norms.len();
        GapSafeDynamic {
            alpha,
            col_norms,
            group_spectral,
            ids: (0..p).collect(),
            evicted_ids: Vec::new(),
        }
    }

    /// Features evicted so far (the driver reports this per path step).
    #[inline]
    pub fn evicted(&self) -> usize {
        self.evicted_ids.len()
    }

    /// The evicted features, as indices into the solver's *input* problem
    /// (the space `col_norms` was constructed over).
    #[inline]
    pub fn evicted_ids(&self) -> &[usize] {
        &self.evicted_ids
    }

    /// GAP-safe test at a solver gap check.
    ///
    /// * `groups` — the solver's current group structure;
    /// * `lambda` — `params.lambda2` (the λ of the (λ, α) parameterization);
    /// * `c = Xᵀr` at the current iterate (the gap check's own sweep);
    /// * `gap`, `s_feas` — the pair returned by
    ///   [`crate::sgl::dual::duality_gap`] for that same `(β, r, c)`.
    ///
    /// Returns `None` when nothing new is certified zero; otherwise the
    /// keep mask (and this state is already compacted to match it).
    pub fn check(
        &mut self,
        groups: &GroupStructure,
        lambda: f64,
        c: &[f32],
        gap: f64,
        s_feas: f64,
    ) -> Option<EvictPlan> {
        let p = groups.n_features();
        debug_assert_eq!(c.len(), p);
        debug_assert_eq!(self.col_norms.len(), p);
        debug_assert_eq!(self.group_spectral.len(), groups.n_groups());
        if !gap.is_finite() || s_feas <= 0.0 || lambda <= 0.0 {
            return None;
        }
        let rho = gap_sphere_radius(gap, lambda);
        // Sphere center in normalized space is s·r/λ, so its correlations
        // are the gap check's c rescaled by s/λ.
        let scale = s_feas / lambda;
        let mut feature_kept = vec![true; p];
        let mut n_evicted = 0usize;
        for (g, s_idx, e_idx) in groups.iter() {
            let r_g = rho * self.group_spectral[g];
            // s*_g = sup over the group ball of ‖S₁(ξ)‖ (Theorem 15 closed
            // form, single-sourced in `supremum::s_star_scaled`).
            let s_g = s_star_scaled(&c[s_idx..e_idx], scale, r_g);
            if s_g < self.alpha * groups.weight(g) {
                // Whole group certified zero.
                feature_kept[s_idx..e_idx].iter_mut().for_each(|k| *k = false);
                n_evicted += e_idx - s_idx;
            } else {
                // Feature layer inside the surviving group (Theorem 16 form).
                for i in s_idx..e_idx {
                    if ((c[i] as f64) * scale).abs() + rho * self.col_norms[i] <= 1.0 {
                        feature_kept[i] = false;
                        n_evicted += 1;
                    }
                }
            }
        }
        if n_evicted == 0 {
            return None;
        }
        // Compact our own projections in lockstep with the solver.
        for (i, &kept) in feature_kept.iter().enumerate() {
            if !kept {
                self.evicted_ids.push(self.ids[i]);
            }
        }
        retain_by_mask(&mut self.ids, &feature_kept);
        retain_by_mask(&mut self.col_norms, &feature_kept);
        let mut survivors = Vec::with_capacity(groups.n_groups());
        for (g, s_idx, e_idx) in groups.iter() {
            if feature_kept[s_idx..e_idx].iter().any(|&b| b) {
                survivors.push(self.group_spectral[g]);
            }
        }
        self.group_spectral = survivors;
        Some(EvictPlan { kept: p - n_evicted, feature_kept })
    }
}

/// Dynamic GAP-safe state for the nonnegative Lasso (Theorem 22 geometry).
///
/// The dual feasible set is the polytope `{θ : ⟨x_i, θ⟩ ≤ 1}` in the
/// already-normalized θ-space; [`crate::nonneg::duality_gap`]'s dual
/// candidate is `θ = s·r/λ` and its objective is λ²-strongly concave in θ,
/// giving the same `√(2·gap)/λ` sphere radius. The rule is one-sided:
/// `⟨x_i, o⟩ + ρ‖x_i‖ < 1 ⇒ β*_i = 0`.
#[derive(Debug)]
pub struct GapSafeDynamicNonneg {
    col_norms: Vec<f64>,
    /// Same stable-identity bookkeeping as [`GapSafeDynamic`].
    ids: Vec<usize>,
    evicted_ids: Vec<usize>,
}

impl GapSafeDynamicNonneg {
    pub fn new(col_norms: Vec<f64>) -> GapSafeDynamicNonneg {
        let p = col_norms.len();
        GapSafeDynamicNonneg { col_norms, ids: (0..p).collect(), evicted_ids: Vec::new() }
    }

    #[inline]
    pub fn evicted(&self) -> usize {
        self.evicted_ids.len()
    }

    /// Evicted features as indices into the solver's input problem.
    #[inline]
    pub fn evicted_ids(&self) -> &[usize] {
        &self.evicted_ids
    }

    /// Test at a gap check: `c = Xᵀr` (current columns), `(gap, s_feas)`
    /// from [`crate::nonneg::duality_gap`].
    pub fn check(
        &mut self,
        lambda: f64,
        c: &[f32],
        gap: f64,
        s_feas: f64,
    ) -> Option<EvictPlan> {
        let p = c.len();
        debug_assert_eq!(self.col_norms.len(), p);
        if !gap.is_finite() || s_feas <= 0.0 || lambda <= 0.0 {
            return None;
        }
        let rho = gap_sphere_radius(gap, lambda);
        let scale = s_feas / lambda;
        let mut feature_kept = vec![true; p];
        let mut n_evicted = 0usize;
        for i in 0..p {
            if (c[i] as f64) * scale + rho * self.col_norms[i] < 1.0 {
                feature_kept[i] = false;
                n_evicted += 1;
            }
        }
        if n_evicted == 0 {
            return None;
        }
        for (i, &kept) in feature_kept.iter().enumerate() {
            if !kept {
                self.evicted_ids.push(self.ids[i]);
            }
        }
        retain_by_mask(&mut self.ids, &feature_kept);
        retain_by_mask(&mut self.col_norms, &feature_kept);
        Some(EvictPlan { kept: p - n_evicted, feature_kept })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::power::group_spectral_norms;
    use crate::linalg::{DenseMatrix, DesignMatrix};
    use crate::sgl::dual::duality_gap;
    use crate::sgl::fista::{solve_fista, FistaOptions};
    use crate::sgl::problem::{SglParams, SglProblem};
    use crate::util::Rng;

    fn make_problem(
        seed: u64,
        n: usize,
        p: usize,
        g: usize,
    ) -> (DenseMatrix, Vec<f32>, crate::groups::GroupStructure) {
        let mut rng = Rng::seed_from_u64(seed);
        let x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian() as f32);
        let groups = crate::groups::GroupStructure::uniform(p, g);
        let mut beta = vec![0.0f32; p];
        for j in 0..p / 6 {
            beta[j * 5 % p] = rng.normal(0.0, 1.0) as f32;
        }
        let mut y = vec![0.0f32; n];
        x.matvec(&beta, &mut y);
        for v in y.iter_mut() {
            *v += rng.normal(0.0, 0.01) as f32;
        }
        (x, y, groups)
    }

    fn state_for(prob: &SglProblem<'_, DenseMatrix>, alpha: f64) -> GapSafeDynamic {
        let mut rng = Rng::seed_from_u64(0x6A9);
        let gs = group_spectral_norms(prob.x, &prob.groups.ranges(), 1e-6, 500, &mut rng);
        GapSafeDynamic::new(alpha, prob.x.col_norms(), gs)
    }

    #[test]
    fn sphere_contains_tight_optimum() {
        // The normalized dual optimum must lie inside the gap sphere built
        // from a *loose* iterate's feasible dual point.
        let (x, y, groups) = make_problem(901, 25, 40, 8);
        let prob = SglProblem::new(&x, &y, &groups);
        let lmax = crate::screening::lambda_max::sgl_lambda_max(&prob, 1.0);
        let lambda = 0.3 * lmax.lambda_max;
        let params = SglParams::from_alpha_lambda(1.0, lambda);
        let loose =
            solve_fista(&prob, &params, None, &FistaOptions { tol: 1e-2, ..Default::default() });
        let tight =
            solve_fista(&prob, &params, None, &FistaOptions { tol: 1e-10, ..Default::default() });
        let n = prob.n_samples();
        let p = prob.n_features();
        let mut r = vec![0.0f32; n];
        let mut c = vec![0.0f32; p];
        crate::sgl::objective::residual(&prob, &loose.beta, &mut r);
        prob.x.matvec_t(&r, &mut c);
        let (gap, s) = duality_gap(&prob, &params, &loose.beta, &r, &c);
        let rho = gap_sphere_radius(gap, lambda);
        // θ̃* ≈ (y − Xβ_tight)/λ; θ̃ = s·r/λ.
        let mut rt = vec![0.0f32; n];
        crate::sgl::objective::residual(&prob, &tight.beta, &mut rt);
        let mut dist_sq = 0.0f64;
        for i in 0..n {
            let d = (rt[i] as f64 - s * r[i] as f64) / lambda;
            dist_sq += d * d;
        }
        // Small slack for the f32 residual evaluation and the fact that
        // the "tight" solve is itself only gap-1e-10 accurate.
        assert!(
            dist_sq.sqrt() <= rho * 1.05 + 1e-4,
            "optimum outside gap sphere: dist {} radius {rho}",
            dist_sq.sqrt()
        );
    }

    #[test]
    fn dynamic_evictions_are_zero_in_tight_solve() {
        let (x, y, groups) = make_problem(902, 25, 48, 8);
        let prob = SglProblem::new(&x, &y, &groups);
        let lmax = crate::screening::lambda_max::sgl_lambda_max(&prob, 1.0);
        let lambda = 0.4 * lmax.lambda_max;
        let params = SglParams::from_alpha_lambda(1.0, lambda);
        // Mid-solve iterate: a loose solve's state stands in for it.
        let loose =
            solve_fista(&prob, &params, None, &FistaOptions { tol: 1e-4, ..Default::default() });
        let n = prob.n_samples();
        let p = prob.n_features();
        let mut r = vec![0.0f32; n];
        let mut c = vec![0.0f32; p];
        crate::sgl::objective::residual(&prob, &loose.beta, &mut r);
        prob.x.matvec_t(&r, &mut c);
        let (gap, s) = duality_gap(&prob, &params, &loose.beta, &r, &c);
        let mut st = state_for(&prob, 1.0);
        let plan = st.check(&groups, lambda, &c, gap, s);
        let tight =
            solve_fista(&prob, &params, None, &FistaOptions { tol: 1e-10, ..Default::default() });
        if let Some(plan) = plan {
            assert_eq!(st.evicted(), p - plan.kept);
            for (i, &kept) in plan.feature_kept.iter().enumerate() {
                if !kept {
                    assert!(
                        tight.beta[i].abs() < 1e-5,
                        "evicted feature {i} has β = {}",
                        tight.beta[i]
                    );
                }
            }
            // Internal projections compacted in lockstep.
            assert_eq!(st.col_norms.len(), plan.kept);
        }
    }

    #[test]
    fn huge_gap_evicts_nothing() {
        let (x, y, groups) = make_problem(903, 10, 12, 4);
        let prob = SglProblem::new(&x, &y, &groups);
        let mut st = state_for(&prob, 1.0);
        let c = vec![0.5f32; 12];
        assert!(st.check(&groups, 1.0, &c, 1e12, 1.0).is_none());
        assert_eq!(st.evicted(), 0);
        // Non-finite gap is a no-op, never a panic.
        assert!(st.check(&groups, 1.0, &c, f64::NAN, 1.0).is_none());
    }

    #[test]
    fn nonneg_dynamic_safe() {
        let mut rng = Rng::seed_from_u64(904);
        let n = 20;
        let p = 50;
        let x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian().abs() as f32);
        let mut beta = vec![0.0f32; p];
        for k in 0..5 {
            beta[k * 9 % p] = rng.uniform_range(0.3, 1.0) as f32;
        }
        let mut y = vec![0.0f32; n];
        x.matvec(&beta, &mut y);
        let prob = crate::nonneg::NonnegProblem::new(&x, &y);
        let (lmax, _) = crate::nonneg::lambda_max(&prob);
        let lambda = 0.4 * lmax;
        let loose = crate::nonneg::solve_nonneg(
            &prob,
            lambda,
            None,
            &crate::nonneg::NonnegOptions { tol: 1e-3, ..Default::default() },
        );
        let mut r = vec![0.0f32; n];
        let mut c = vec![0.0f32; p];
        x.residual(&loose.beta, &y, &mut r);
        x.matvec_t(&r, &mut c);
        let (gap, s) = crate::nonneg::duality_gap(&prob, lambda, &loose.beta, &r, &c);
        let mut st = GapSafeDynamicNonneg::new(x.col_norms());
        let tight = crate::nonneg::solve_nonneg(
            &prob,
            lambda,
            None,
            &crate::nonneg::NonnegOptions { tol: 1e-10, ..Default::default() },
        );
        if let Some(plan) = st.check(lambda, &c, gap, s) {
            for (i, &kept) in plan.feature_kept.iter().enumerate() {
                if !kept {
                    assert!(tight.beta[i].abs() < 1e-5, "evicted {i} has β={}", tight.beta[i]);
                }
            }
        }
    }
}
