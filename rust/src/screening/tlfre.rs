//! The TLFre two-layer screening rule (Theorem 17).
//!
//! One path step: given the (exact) solution at the previous parameter λ̄
//! (through its dual point `θ̄ = (y − Xβ̄)/λ̄`), screen the problem at λ < λ̄:
//!
//! 1. Build the dual-estimate ball (Theorem 12).
//! 2. Sweep `c = Xᵀo` — the hot kernel, also available as an AOT-compiled
//!    Pallas/XLA artifact through [`crate::runtime`].
//! 3. **(L₁)** reject group g if `s*_g < α√n_g` (Theorem 15 closed form).
//! 4. **(L₂)** in surviving groups, reject feature i if
//!    `|x_iᵀo| + radius·‖x_i‖ ≤ 1` (Theorem 16).
//!
//! Rejected groups/features are *guaranteed* zero at the optimum of the
//! λ-problem — the safety property tests verify this end to end.

use super::dual_est::{estimate_ball, normal_interior, Ball};
use super::lambda_max::LambdaMaxInfo;
use super::supremum::{s_star_fused, t_star};
use crate::linalg::power::group_spectral_norms;
use crate::linalg::DesignMatrix;
use crate::prox::shrink_inplace;
use crate::sgl::problem::SglProblem;
use crate::util::Rng;

/// Per-data-set precomputation shared across all (α, λ) screenings:
/// column norms `‖x_i‖` and group spectral norms `‖X_g‖₂`.
/// The paper notes this cost is shared across the whole grid (power
/// method, [8]); we compute it once per data set.
#[derive(Debug, Clone)]
pub struct TlfreContext {
    pub col_norms: Vec<f64>,
    pub group_spectral: Vec<f64>,
}

impl TlfreContext {
    /// Precompute from the problem (one power iteration per group).
    pub fn precompute<M: DesignMatrix>(prob: &SglProblem<'_, M>) -> TlfreContext {
        let mut rng = Rng::seed_from_u64(0x7_1F4E);
        let col_norms = prob.x.col_norms();
        let ranges = prob.groups.ranges();
        let group_spectral = group_spectral_norms(prob.x, &ranges, 1e-6, 500, &mut rng);
        TlfreContext { col_norms, group_spectral }
    }
}

/// Screening statistics for one path step.
#[derive(Debug, Clone, Default)]
pub struct ScreenStats {
    /// Groups discarded by (L₁).
    pub groups_rejected: usize,
    /// Features inside (L₁)-discarded groups (numerator of the paper's r₁).
    pub features_in_rejected_groups: usize,
    /// Features discarded by (L₂) in surviving groups (numerator of r₂).
    pub features_rejected_l2: usize,
    /// Ball radius used.
    pub radius: f64,
}

/// Outcome of one TLFre screening.
#[derive(Debug, Clone)]
pub struct TlfreOutcome {
    /// Per-group survival (false ⇒ whole group certified zero).
    pub group_kept: Vec<bool>,
    /// Per-feature survival (false ⇒ coefficient certified zero).
    pub feature_kept: Vec<bool>,
    pub stats: ScreenStats,
}

impl TlfreOutcome {
    /// Indices of surviving features.
    pub fn active_features(&self) -> Vec<usize> {
        self.feature_kept
            .iter()
            .enumerate()
            .filter_map(|(i, &k)| if k { Some(i) } else { None })
            .collect()
    }

    /// Indices of surviving groups.
    pub fn active_groups(&self) -> Vec<usize> {
        self.group_kept
            .iter()
            .enumerate()
            .filter_map(|(g, &k)| if k { Some(g) } else { None })
            .collect()
    }

    /// Total features rejected by either layer.
    pub fn total_rejected(&self) -> usize {
        self.stats.features_in_rejected_groups + self.stats.features_rejected_l2
    }
}

/// The normal-cone vector `n_α(λ̄)` of Theorem 12.
///
/// * λ̄ < λmax: `n = y/λ̄ − θ̄`.
/// * λ̄ = λmax: `n = X_* S₁(X_*ᵀ y/λmax)` with `X_*` the argmax group.
pub fn normal_vector<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    lambda_bar: f64,
    theta_bar: &[f32],
    lmax: &LambdaMaxInfo,
) -> Vec<f32> {
    let n = prob.n_samples();
    let at_max = lambda_bar >= lmax.lambda_max * (1.0 - 1e-12);
    if !at_max {
        let y_over: Vec<f32> = prob.y.iter().map(|&v| (v as f64 / lambda_bar) as f32).collect();
        return normal_interior(theta_bar, &y_over);
    }
    // n = X_* S₁(X_*ᵀ y/λmax)
    let g = lmax.argmax_group;
    let (s, e) = prob.groups.range(g);
    let y_over: Vec<f32> =
        prob.y.iter().map(|&v| (v as f64 / lmax.lambda_max) as f32).collect();
    let mut cg = vec![0.0f32; e - s];
    for (k, c) in cg.iter_mut().enumerate() {
        *c = prob.x.col_dot(s + k, &y_over);
    }
    shrink_inplace(&mut cg, 1.0);
    let mut out = vec![0.0f32; n];
    for (k, &ck) in cg.iter().enumerate() {
        if ck != 0.0 {
            prob.x.col_axpy(s + k, ck, &mut out);
        }
    }
    out
}

/// Apply the (L₁)/(L₂) rules given the already-computed correlation sweep
/// `c = Xᵀo` and the ball radius. Split out so the XLA runtime path (which
/// produces `c` and the per-group reductions on-device) reuses the exact
/// same rule logic.
pub fn apply_rules<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    alpha: f64,
    c: &[f32],
    radius: f64,
    ctx: &TlfreContext,
) -> TlfreOutcome {
    let g_cnt = prob.n_groups();
    let p = prob.n_features();
    let mut group_kept = vec![true; g_cnt];
    let mut feature_kept = vec![true; p];
    let mut stats = ScreenStats { radius, ..Default::default() };

    for (g, s, e) in prob.groups.iter() {
        let r_g = radius * ctx.group_spectral[g];
        let (s_g, _cinf, _shrunk) = s_star_fused(&c[s..e], r_g);
        if s_g < alpha * prob.groups.weight(g) {
            // (L₁): whole group certified zero.
            group_kept[g] = false;
            feature_kept[s..e].iter_mut().for_each(|k| *k = false);
            stats.groups_rejected += 1;
            stats.features_in_rejected_groups += e - s;
        } else {
            // (L₂): feature-level rule inside the surviving group.
            for i in s..e {
                if t_star(c[i] as f64, radius, ctx.col_norms[i]) <= 1.0 {
                    feature_kept[i] = false;
                    stats.features_rejected_l2 += 1;
                }
            }
        }
    }
    TlfreOutcome { group_kept, feature_kept, stats }
}

/// Apply the rules from *device-computed reductions* — the variant used
/// when the sweep ran through the AOT/PJRT screening engine, which returns
/// `c = Xᵀo` plus per-group `‖S₁(c_g)‖²` and `‖c_g‖∞` (uniform groups).
/// Must agree exactly with [`apply_rules`]; a unit test enforces it.
pub fn apply_rules_from_reductions<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    alpha: f64,
    c: &[f32],
    group_shrink_sq: &[f32],
    group_cinf: &[f32],
    radius: f64,
    ctx: &TlfreContext,
) -> TlfreOutcome {
    let g_cnt = prob.n_groups();
    assert_eq!(group_shrink_sq.len(), g_cnt);
    assert_eq!(group_cinf.len(), g_cnt);
    let p = prob.n_features();
    let mut group_kept = vec![true; g_cnt];
    let mut feature_kept = vec![true; p];
    let mut stats = ScreenStats { radius, ..Default::default() };
    for (g, s, e) in prob.groups.iter() {
        let r_g = radius * ctx.group_spectral[g];
        let cinf = group_cinf[g] as f64;
        let s_g = if cinf > 1.0 {
            (group_shrink_sq[g] as f64).sqrt() + r_g
        } else {
            (cinf + r_g - 1.0).max(0.0)
        };
        if s_g < alpha * prob.groups.weight(g) {
            group_kept[g] = false;
            feature_kept[s..e].iter_mut().for_each(|k| *k = false);
            stats.groups_rejected += 1;
            stats.features_in_rejected_groups += e - s;
        } else {
            for i in s..e {
                if t_star(c[i] as f64, radius, ctx.col_norms[i]) <= 1.0 {
                    feature_kept[i] = false;
                    stats.features_rejected_l2 += 1;
                }
            }
        }
    }
    TlfreOutcome { group_kept, feature_kept, stats }
}

/// One full TLFre screening step (Theorem 17).
///
/// * `lambda` — target λ^{(j+1)};
/// * `lambda_bar` — previous λ^{(j)} (may equal `lmax.lambda_max`);
/// * `theta_bar` — exact dual optimum at λ̄, i.e. `(y − Xβ̄)/λ̄`.
pub fn tlfre_screen<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    alpha: f64,
    lambda: f64,
    lambda_bar: f64,
    theta_bar: &[f32],
    lmax: &LambdaMaxInfo,
    ctx: &TlfreContext,
) -> TlfreOutcome {
    tlfre_screen_inexact(prob, alpha, lambda, lambda_bar, theta_bar, 0.0, lmax, ctx)
}

/// TLFre step that is robust to an *inexact* previous solve.
///
/// The paper's Theorem 12 assumes the exact dual optimum at λ̄. A solver
/// stopped at duality gap `gap_bar` (absolute, in the (λ₁,λ₂)
/// parameterization where θ = y − Xβ) yields a *feasible* dual point within
/// `δ = √(2·gap_bar)` of the true optimum (1-strong convexity of the dual
/// objective), i.e. within `δ/λ̄` in the problem-(3) θ-space used here.
/// Inflating the estimate-ball radius by `2δ/λ̄` absorbs both the center
/// shift and the normal-cone perturbation, preserving the safety guarantee
/// at practical tolerances. `gap_bar = 0` recovers the paper's exact rule.
#[allow(clippy::too_many_arguments)]
pub fn tlfre_screen_inexact<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    alpha: f64,
    lambda: f64,
    lambda_bar: f64,
    theta_bar: &[f32],
    gap_bar: f64,
    lmax: &LambdaMaxInfo,
    ctx: &TlfreContext,
) -> TlfreOutcome {
    assert!(lambda > 0.0 && lambda < lambda_bar * (1.0 + 1e-12), "need 0 < λ ≤ λ̄");
    let mut ball = screen_ball(prob, lambda, lambda_bar, theta_bar, lmax);
    if gap_bar > 0.0 {
        ball.radius += 2.0 * (2.0 * gap_bar).sqrt() / lambda_bar;
    }
    let mut c = vec![0.0f32; prob.n_features()];
    prob.x.matvec_t(&ball.center, &mut c);
    apply_rules(prob, alpha, &c, ball.radius, ctx)
}

/// The Theorem 12 ball for a step λ̄ → λ.
pub fn screen_ball<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    lambda: f64,
    lambda_bar: f64,
    theta_bar: &[f32],
    lmax: &LambdaMaxInfo,
) -> Ball {
    let n_vec = normal_vector(prob, lambda_bar, theta_bar, lmax);
    let y_over: Vec<f32> = prob.y.iter().map(|&v| (v as f64 / lambda) as f32).collect();
    estimate_ball(theta_bar, &n_vec, &y_over)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::groups::GroupStructure;
    use crate::linalg::ops;
    use crate::linalg::DenseMatrix;
    use crate::screening::lambda_max::sgl_lambda_max;
    use crate::sgl::fista::{solve_fista, FistaOptions};
    use crate::sgl::problem::SglParams;
    use crate::util::Rng;

    fn make_problem(seed: u64, n: usize, p: usize, g: usize) -> (DenseMatrix, Vec<f32>, GroupStructure) {
        let mut rng = Rng::seed_from_u64(seed);
        let x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian() as f32);
        let groups = GroupStructure::uniform(p, g);
        let mut beta = vec![0.0f32; p];
        let per = p / g;
        for gi in 0..g / 3 {
            for k in 0..per / 2 + 1 {
                beta[gi * 3 * per + k] = rng.normal(0.0, 1.0) as f32;
            }
        }
        let mut y = vec![0.0f32; n];
        x.matvec(&beta, &mut y);
        for v in y.iter_mut() {
            *v += rng.normal(0.0, 0.01) as f32;
        }
        (x, y, groups)
    }

    #[test]
    fn screening_from_lambda_max_is_safe() {
        // Screen at λ = 0.9λmax starting from (λmax, β=0); every rejection
        // must be zero in a tight solve.
        let (x, y, groups) = make_problem(71, 25, 40, 8);
        let prob = SglProblem::new(&x, &y, &groups);
        let alpha = 1.0;
        let lmax = sgl_lambda_max(&prob, alpha);
        let ctx = TlfreContext::precompute(&prob);
        let theta_bar: Vec<f32> =
            y.iter().map(|&v| (v as f64 / lmax.lambda_max) as f32).collect();
        let lambda = 0.9 * lmax.lambda_max;
        let out =
            tlfre_screen(&prob, alpha, lambda, lmax.lambda_max, &theta_bar, &lmax, &ctx);
        let params = SglParams::from_alpha_lambda(alpha, lambda);
        let sol = solve_fista(&prob, &params, None, &FistaOptions { tol: 1e-10, ..Default::default() });
        for j in 0..prob.n_features() {
            if !out.feature_kept[j] {
                assert!(
                    sol.beta[j].abs() < 1e-5,
                    "feature {j} screened but β={}",
                    sol.beta[j]
                );
            }
        }
        // Near λmax nearly everything should be rejected.
        assert!(out.total_rejected() > prob.n_features() / 2);
    }

    #[test]
    fn sequential_screening_is_safe_along_path() {
        let (x, y, groups) = make_problem(72, 20, 36, 6);
        let prob = SglProblem::new(&x, &y, &groups);
        let alpha = 0.8;
        let lmax = sgl_lambda_max(&prob, alpha);
        let ctx = TlfreContext::precompute(&prob);
        let opts = FistaOptions { tol: 1e-10, ..Default::default() };

        let mut lambda_bar = lmax.lambda_max;
        let mut beta_bar = vec![0.0f32; prob.n_features()];
        for step in 1..=6 {
            let lambda = lmax.lambda_max * (0.95f64).powi(step * 2);
            // θ̄ from the previous solution.
            let mut r = vec![0.0f32; prob.n_samples()];
            crate::sgl::objective::residual(&prob, &beta_bar, &mut r);
            let theta_bar: Vec<f32> =
                r.iter().map(|&v| (v as f64 / lambda_bar) as f32).collect();
            let out = tlfre_screen(&prob, alpha, lambda, lambda_bar, &theta_bar, &lmax, &ctx);
            let params = SglParams::from_alpha_lambda(alpha, lambda);
            let sol = solve_fista(&prob, &params, Some(&beta_bar), &opts);
            for j in 0..prob.n_features() {
                if !out.feature_kept[j] {
                    assert!(
                        sol.beta[j].abs() < 1e-5,
                        "step {step} feature {j}: screened but β={}",
                        sol.beta[j]
                    );
                }
            }
            beta_bar = sol.beta;
            lambda_bar = lambda;
        }
    }

    #[test]
    fn rejection_monotone_near_lambda_max() {
        // As λ → λmax the ball shrinks to θ*(λmax)'s neighbourhood and
        // everything inactive at λmax gets rejected.
        let (x, y, groups) = make_problem(73, 15, 24, 6);
        let prob = SglProblem::new(&x, &y, &groups);
        let alpha = 1.5;
        let lmax = sgl_lambda_max(&prob, alpha);
        let ctx = TlfreContext::precompute(&prob);
        let theta_bar: Vec<f32> =
            y.iter().map(|&v| (v as f64 / lmax.lambda_max) as f32).collect();
        let r99 = tlfre_screen(&prob, alpha, 0.99 * lmax.lambda_max, lmax.lambda_max, &theta_bar, &lmax, &ctx);
        let r50 = tlfre_screen(&prob, alpha, 0.50 * lmax.lambda_max, lmax.lambda_max, &theta_bar, &lmax, &ctx);
        assert!(r99.total_rejected() >= r50.total_rejected());
    }

    #[test]
    fn normal_vector_in_normal_cone_at_lambda_max() {
        // Theorem 12(i), λ̄ = λmax case: ⟨n, θ − y/λmax⟩ ≤ 0 for dual
        // feasible θ. Verify against the scaled-to-feasibility points.
        let (x, y, groups) = make_problem(74, 12, 18, 6);
        let prob = SglProblem::new(&x, &y, &groups);
        let alpha = 1.0;
        let lmax = sgl_lambda_max(&prob, alpha);
        let theta_star: Vec<f32> =
            y.iter().map(|&v| (v as f64 / lmax.lambda_max) as f32).collect();
        let n_vec = normal_vector(&prob, lmax.lambda_max, &theta_star, &lmax);
        assert!(ops::nrm2(&n_vec) > 0.0);
        let params = SglParams { lambda1: alpha, lambda2: 1.0 };
        let mut rng = Rng::seed_from_u64(99);
        for _ in 0..50 {
            // Random direction scaled into the feasible set.
            let cand: Vec<f32> = (0..prob.n_samples()).map(|_| rng.gaussian() as f32).collect();
            let mut c = vec![0.0f32; prob.n_features()];
            prob.x.matvec_t(&cand, &mut c);
            let s = crate::sgl::dual::dual_feasible_scale(&prob, &params, &c);
            let feas: Vec<f32> = cand.iter().map(|&v| (v as f64 * s) as f32).collect();
            let mut diff = vec![0.0f32; prob.n_samples()];
            ops::sub(&feas, &theta_star, &mut diff);
            let ip = ops::dot(&n_vec, &diff);
            assert!(ip <= 1e-3, "normal cone violated: ⟨n, θ−θ*⟩ = {ip}");
        }
    }

    #[test]
    fn reduction_variant_matches_apply_rules() {
        // The device-reduction path must reproduce apply_rules bit-for-bit
        // given consistent inputs.
        let (x, y, groups) = make_problem(76, 14, 24, 6);
        let prob = SglProblem::new(&x, &y, &groups);
        let ctx = TlfreContext::precompute(&prob);
        let mut rng = Rng::seed_from_u64(77);
        for _ in 0..20 {
            let o: Vec<f32> = (0..14).map(|_| rng.normal(0.0, 0.7) as f32).collect();
            let radius = rng.uniform_range(0.01, 0.5);
            let alpha = rng.uniform_range(0.3, 2.0);
            let mut c = vec![0.0f32; 24];
            prob.x.matvec_t(&o, &mut c);
            // emulate the device reductions
            let mut gsn = vec![0.0f32; prob.n_groups()];
            let mut gmax = vec![0.0f32; prob.n_groups()];
            for (g, s, e) in prob.groups.iter() {
                gsn[g] = crate::prox::shrink_norm_sq(&c[s..e], 1.0) as f32;
                gmax[g] = c[s..e].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            }
            let a = apply_rules(&prob, alpha, &c, radius, &ctx);
            let b = apply_rules_from_reductions(&prob, alpha, &c, &gsn, &gmax, radius, &ctx);
            assert_eq!(a.feature_kept, b.feature_kept);
            assert_eq!(a.group_kept, b.group_kept);
            assert_eq!(a.stats.groups_rejected, b.stats.groups_rejected);
        }
    }

    #[test]
    fn outcome_helpers() {
        let (x, y, groups) = make_problem(75, 10, 12, 4);
        let prob = SglProblem::new(&x, &y, &groups);
        let alpha = 1.0;
        let lmax = sgl_lambda_max(&prob, alpha);
        let ctx = TlfreContext::precompute(&prob);
        let theta_bar: Vec<f32> =
            y.iter().map(|&v| (v as f64 / lmax.lambda_max) as f32).collect();
        let out = tlfre_screen(&prob, alpha, 0.8 * lmax.lambda_max, lmax.lambda_max, &theta_bar, &lmax, &ctx);
        let af = out.active_features();
        let ag = out.active_groups();
        assert_eq!(af.len(), out.feature_kept.iter().filter(|&&k| k).count());
        assert_eq!(ag.len(), out.group_kept.iter().filter(|&&k| k).count());
        assert_eq!(
            out.total_rejected(),
            prob.n_features() - af.len()
        );
    }
}
