//! HTTP/1.0-style framing over unix-domain sockets.
//!
//! The engine speaks a deliberately tiny subset of HTTP/1.0 over
//! `std::os::unix::net` (the crate stays zero-dependency — no HTTP or
//! async stack):
//!
//! ```text
//! POST /v1/solve HTTP/1.0\r\n          HTTP/1.0 200 OK\r\n
//! Content-Length: <n>\r\n              Content-Type: application/json\r\n
//! \r\n                                 Content-Length: <n>\r\n
//! <request JSON, n bytes>              \r\n
//!                                      <response JSON, n bytes>
//! ```
//!
//! One request per connection (no keep-alive): the client connects,
//! writes, reads one response, and the server closes. That keeps the
//! server's per-connection state machine trivial — a disconnect at any
//! point aborts exactly one request — and plain `curl --unix-socket` can
//! poke the engine for debugging.
//!
//! Framing is `Content-Length`-based; malformed heads (no POST, missing
//! or non-numeric length, oversized bodies, over-long header lines) are
//! typed errors the server answers with a 400 envelope, never a hang or
//! a partial read.

use crate::bail;
use crate::error::{Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::os::unix::net::UnixStream;
use std::path::Path;

/// Upper bound on a request/response body. Generous (coefficient dumps of
/// big paths are tens of MiB) while keeping a malformed length from
/// driving an OOM-sized allocation.
pub const MAX_BODY_BYTES: usize = 1 << 30;

/// Upper bound on a single head/header line.
const MAX_HEAD_BYTES: u64 = 8192;

/// Read one `\n`-terminated line with a length cap. `Ok(None)` is clean
/// EOF before any byte.
fn read_line_limited(r: &mut impl BufRead) -> Result<Option<String>> {
    let mut buf = Vec::new();
    let n = r.by_ref().take(MAX_HEAD_BYTES).read_until(b'\n', &mut buf)?;
    if n == 0 {
        return Ok(None);
    }
    if !buf.ends_with(b"\n") && n as u64 >= MAX_HEAD_BYTES {
        bail!("header line exceeds {MAX_HEAD_BYTES} bytes");
    }
    let s = String::from_utf8(buf).context("header line is not utf-8")?;
    Ok(Some(s.trim_end().to_string()))
}

/// Read the head line plus headers up to the blank separator; returns the
/// head line and the parsed `Content-Length`. `Ok(None)` is clean EOF.
fn read_head(r: &mut impl BufRead) -> Result<Option<(String, usize)>> {
    let head = match read_line_limited(r)? {
        None => return Ok(None),
        Some(h) => h,
    };
    let mut content_length: Option<usize> = None;
    loop {
        let line = read_line_limited(r)?.context("connection closed mid-headers")?;
        if line.is_empty() {
            break;
        }
        let (name, value) = line
            .split_once(':')
            .with_context(|| format!("malformed header line {line:?}"))?;
        if name.eq_ignore_ascii_case("content-length") {
            let v: usize = value
                .trim()
                .parse()
                .with_context(|| format!("bad Content-Length {:?}", value.trim()))?;
            content_length = Some(v);
        }
        // Other headers (Content-Type, User-Agent, …) are ignored.
    }
    let len = content_length.context("missing Content-Length header")?;
    if len > MAX_BODY_BYTES {
        bail!("body length {len} exceeds the {MAX_BODY_BYTES}-byte cap");
    }
    Ok(Some((head, len)))
}

/// Read exactly `len` body bytes as utf-8 JSON text.
fn read_body(r: &mut impl BufRead, len: usize) -> Result<String> {
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).context("connection closed mid-body")?;
    String::from_utf8(buf).context("request body is not utf-8")
}

/// Server side: read one framed request body. `Ok(None)` means the client
/// closed the connection cleanly before sending anything.
pub fn read_request(r: &mut impl BufRead) -> Result<Option<String>> {
    let (head, len) = match read_head(r)? {
        None => return Ok(None),
        Some(h) => h,
    };
    let method = head.split_whitespace().next().unwrap_or("");
    if method != "POST" {
        bail!("unsupported method '{method}' (the engine only speaks POST)");
    }
    Ok(Some(read_body(r, len)?))
}

/// Server side: frame and write one response.
pub fn write_response(w: &mut impl Write, status: u16, body: &str) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        500 => "Internal Server Error",
        _ => "Unknown",
    };
    write!(
        w,
        "HTTP/1.0 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n",
        body.len()
    )?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

/// Client side: frame and write one request.
pub fn write_request(w: &mut impl Write, body: &str) -> std::io::Result<()> {
    write!(w, "POST /v1/solve HTTP/1.0\r\nContent-Length: {}\r\n\r\n", body.len())?;
    w.write_all(body.as_bytes())?;
    w.flush()
}

/// Client side: read one framed response as `(status, body)`.
pub fn read_response(r: &mut impl BufRead) -> Result<(u16, String)> {
    let (head, len) = read_head(r)?.context("connection closed before the response")?;
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("malformed status line {head:?}"))?;
    Ok((status, read_body(r, len)?))
}

/// One full client round trip on a fresh connection: connect to the unix
/// socket, send `body`, read `(status, body)` back.
pub fn call(socket: &Path, body: &str) -> Result<(u16, String)> {
    let stream =
        UnixStream::connect(socket).with_context(|| format!("connecting to {socket:?}"))?;
    write_request(&mut &stream, body)
        .with_context(|| format!("sending request to {socket:?}"))?;
    let mut r = BufReader::new(&stream);
    read_response(&mut r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn request_roundtrip_through_the_framing() {
        let body = r#"{"v": 1, "kind": "stats"}"#;
        let mut wire = Vec::new();
        write_request(&mut wire, body).unwrap();
        let text = String::from_utf8(wire.clone()).unwrap();
        assert!(text.starts_with("POST /v1/solve HTTP/1.0\r\n"));
        assert!(text.contains(&format!("Content-Length: {}\r\n", body.len())));
        let back = read_request(&mut Cursor::new(wire)).unwrap();
        assert_eq!(back.as_deref(), Some(body));
    }

    #[test]
    fn response_roundtrip_through_the_framing() {
        let body = r#"{"v": 1, "ok": true}"#;
        let mut wire = Vec::new();
        write_response(&mut wire, 200, body).unwrap();
        let (status, back) = read_response(&mut Cursor::new(wire)).unwrap();
        assert_eq!(status, 200);
        assert_eq!(back, body);
        let mut wire = Vec::new();
        write_response(&mut wire, 400, "{}").unwrap();
        let (status, _) = read_response(&mut Cursor::new(wire)).unwrap();
        assert_eq!(status, 400);
    }

    #[test]
    fn clean_eof_is_none_not_an_error() {
        assert!(read_request(&mut Cursor::new(Vec::new())).unwrap().is_none());
    }

    #[test]
    fn malformed_frames_are_typed_errors() {
        // Wrong method.
        let wire = b"GET /v1/solve HTTP/1.0\r\nContent-Length: 2\r\n\r\n{}".to_vec();
        let err = format!("{:#}", read_request(&mut Cursor::new(wire)).unwrap_err());
        assert!(err.contains("unsupported method"), "{err}");
        // Missing Content-Length.
        let wire = b"POST /v1/solve HTTP/1.0\r\n\r\n{}".to_vec();
        let err = format!("{:#}", read_request(&mut Cursor::new(wire)).unwrap_err());
        assert!(err.contains("missing Content-Length"), "{err}");
        // Non-numeric Content-Length.
        let wire = b"POST /x HTTP/1.0\r\nContent-Length: lots\r\n\r\n{}".to_vec();
        assert!(read_request(&mut Cursor::new(wire)).is_err());
        // Oversized declared body.
        let wire =
            format!("POST /x HTTP/1.0\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        let err =
            format!("{:#}", read_request(&mut Cursor::new(wire.into_bytes())).unwrap_err());
        assert!(err.contains("exceeds"), "{err}");
        // Header line without a colon.
        let wire = b"POST /x HTTP/1.0\r\nnot a header\r\n\r\n".to_vec();
        assert!(read_request(&mut Cursor::new(wire)).is_err());
        // Body shorter than declared (mid-body disconnect).
        let wire = b"POST /x HTTP/1.0\r\nContent-Length: 10\r\n\r\n{}".to_vec();
        let err = format!("{:#}", read_request(&mut Cursor::new(wire)).unwrap_err());
        assert!(err.contains("mid-body"), "{err}");
        // Truncated headers (disconnect before the blank line).
        let wire = b"POST /x HTTP/1.0\r\nContent-Length: 2\r\n".to_vec();
        let err = format!("{:#}", read_request(&mut Cursor::new(wire)).unwrap_err());
        assert!(err.contains("mid-headers"), "{err}");
    }
}
