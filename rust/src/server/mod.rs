//! L5 serve layer: the resident path-serving engine behind the unified
//! solve-request API.
//!
//! The batch CLI re-generates its dataset, re-runs the spectral preamble,
//! and re-walks the whole λ path on every invocation. This layer keeps
//! all of that resident in one long-running process: a unix-socket server
//! ([`serve`]) over a [`registry::SessionRegistry`] holding loaded
//! datasets (any backend — dense, CSC, mmap, row-sharded) and completed
//! path prefixes, executing typed [`api::SolveRequest`]s
//! ([`engine::execute`]) framed over the wire by [`wire`].
//!
//! The load-bearing invariant, inherited from the streaming driver it is
//! built on: **a served result is bitwise identical to the equivalent
//! batch CLI run** — same engine, same grid, same loop body; caching and
//! prefix solving only skip work whose output is already known, never
//! change it. CI `cmp`s a served coefficient dump against a batch
//! `--coef-out` file byte for byte, at several `TLFRE_THREADS` settings.
//!
//! `README.md` in this directory documents the versioned JSON schema, the
//! cache-key/warm-start contract, and the failure modes.

pub mod api;
pub mod engine;
pub mod registry;
pub mod serve;
pub mod wire;

pub use api::{
    beta_hex, coef_hex_dump, BackendKind, DatasetSpec, RequestKind, SolveRequest, SolveResponse,
    StepSummary, PROTOCOL_VERSION,
};
pub use engine::execute;
pub use registry::{CachedPath, LoadedData, SessionRegistry};
pub use serve::{serve, serve_on};
