//! The unified solve-request API: one typed [`SolveRequest`] /
//! [`SolveResponse`] pair, one versioned JSON schema.
//!
//! Every entry point into a path solve — the batch CLI commands, the
//! `client` command, and the serve-mode wire protocol — translates into
//! the same [`SolveRequest`] struct, and every result is rendered through
//! the same [`SolveResponse`]. The shared solve-control knobs parse
//! through [`SolveControls::apply_json_key`] (the single JSON parse path
//! in `config.rs`), so key names, validation, and error wording cannot
//! drift between surfaces. Unknown keys are typed errors everywhere, like
//! the `--config` file.
//!
//! The schema is versioned: every request and response carries `"v"` (see
//! [`PROTOCOL_VERSION`]); a request without `"v"`, or with a version this
//! build does not speak, is rejected with a typed error rather than
//! misinterpreted. `rust/src/server/README.md` documents the full schema.
//!
//! Coefficients travel as the same 8-hex-digit bit dump the batch CLI's
//! `--coef-out` writes ([`coef_hex_dump`] / [`beta_hex`] live here and the
//! CLI uses them), so a served path can be `cmp`-verified bitwise against
//! a batch run without any float parsing.

use crate::bail;
use crate::coordinator::runner::{PathConfig, PathStep, SolveControls, SolverKind};
use crate::error::{Context, Result};
use crate::screening::rule::ScreenKind;
use crate::util::json::Json;

/// Wire-schema version this build speaks. Bump on any incompatible change
/// to the request or response shape.
pub const PROTOCOL_VERSION: usize = 1;

// ---------------------------------------------------------------------------
// Request kinds and dataset specs
// ---------------------------------------------------------------------------

/// What a [`SolveRequest`] asks the engine to do.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestKind {
    /// Load (or pre-warm) a dataset into the session registry.
    LoadDataset,
    /// Solve the full λ path; the response carries per-λ steps and the
    /// coefficient bit dump.
    SolvePath,
    /// Solve a single grid point, warm-started from the longest cached
    /// path prefix; the response carries `certified_suboptimality`.
    SolvePoint,
    /// k-fold cross-validation over an α grid (dense/csc backends).
    Cv,
    /// Engine counters: datasets resident, cached paths, hit rates.
    Stats,
    /// Ask the engine to exit its accept loop cleanly.
    Shutdown,
}

impl RequestKind {
    /// Parse the canonical kebab-case name.
    pub fn parse(s: &str) -> Option<RequestKind> {
        match s {
            "load-dataset" => Some(RequestKind::LoadDataset),
            "solve-path" => Some(RequestKind::SolvePath),
            "solve-point" => Some(RequestKind::SolvePoint),
            "cv" => Some(RequestKind::Cv),
            "stats" => Some(RequestKind::Stats),
            "shutdown" => Some(RequestKind::Shutdown),
            _ => None,
        }
    }

    /// The canonical name [`Self::parse`] accepts.
    pub fn as_str(&self) -> &'static str {
        match self {
            RequestKind::LoadDataset => "load-dataset",
            RequestKind::SolvePath => "solve-path",
            RequestKind::SolvePoint => "solve-point",
            RequestKind::Cv => "cv",
            RequestKind::Stats => "stats",
            RequestKind::Shutdown => "shutdown",
        }
    }

    /// Whether this request kind operates on a dataset.
    pub fn needs_dataset(&self) -> bool {
        !matches!(self, RequestKind::Stats | RequestKind::Shutdown)
    }
}

/// Design-matrix backend the dataset should be materialized behind. The
/// same names as the CLI's `--backend` flag; every backend produces
/// bitwise-identical paths (the backend-parity invariant).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Dense,
    Csc,
    Mmap,
    Sharded,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "dense" => Some(BackendKind::Dense),
            "csc" => Some(BackendKind::Csc),
            "mmap" => Some(BackendKind::Mmap),
            "sharded" => Some(BackendKind::Sharded),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Dense => "dense",
            BackendKind::Csc => "csc",
            BackendKind::Mmap => "mmap",
            BackendKind::Sharded => "sharded",
        }
    }
}

/// Everything needed to materialize a dataset deterministically. Carried
/// by every dataset-touching request, so clients are stateless: the
/// registry loads on first use and serves the resident copy afterwards.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetSpec {
    /// Registry name (`synthetic1`, `adni-gmv`, `sparse1`, …) — the same
    /// names the CLI's `--dataset` flag accepts.
    pub name: String,
    /// Storage backend for the design matrix.
    pub backend: BackendKind,
    /// Generator seed.
    pub seed: u64,
    /// Feature-dimension scale in `(0, 1]` (1.0 = paper dims).
    pub scale: f64,
    /// Nonzero fraction for the `sparse1` generator.
    pub density: f64,
    /// Mmap backend: an existing `TLFREDS1` file to map instead of
    /// generating (the CLI's `--file`).
    pub file: Option<String>,
    /// Sharded backend: row-shard count (default: one per worker).
    pub shards: Option<usize>,
}

impl DatasetSpec {
    /// Spec for `name` with the same defaults as the batch CLI
    /// ([`crate::config::Config::default`]'s seed and scale).
    pub fn new(name: &str) -> DatasetSpec {
        let defaults = crate::config::Config::default();
        DatasetSpec {
            name: name.to_string(),
            backend: BackendKind::Dense,
            seed: defaults.seed,
            scale: defaults.scale,
            density: 0.05,
            file: None,
            shards: None,
        }
    }

    /// Parse from the request's `"dataset"` object; unknown keys are
    /// typed errors.
    pub fn from_json(v: &Json) -> Result<DatasetSpec> {
        let obj = v.as_obj().context("\"dataset\" must be a JSON object")?;
        let name = obj
            .get("name")
            .and_then(Json::as_str)
            .context("dataset spec requires a \"name\" string")?;
        let mut spec = DatasetSpec::new(name);
        for (k, val) in obj {
            match k.as_str() {
                "name" => {}
                "backend" => {
                    let s = val.as_str().context("dataset backend must be a string")?;
                    spec.backend = BackendKind::parse(s).with_context(|| {
                        format!("unknown backend '{s}' (dense|csc|mmap|sharded)")
                    })?;
                }
                "seed" => {
                    spec.seed = val.as_usize().context("dataset seed must be an integer")? as u64;
                }
                "scale" => {
                    spec.scale = val.as_f64().context("dataset scale must be a number")?;
                    if !(spec.scale > 0.0 && spec.scale <= 1.0) {
                        bail!("dataset scale must be in (0, 1]");
                    }
                }
                "density" => {
                    spec.density = val.as_f64().context("dataset density must be a number")?;
                    if !(spec.density > 0.0 && spec.density <= 1.0) {
                        bail!("dataset density must be in (0, 1]");
                    }
                }
                "file" => {
                    spec.file = match val {
                        Json::Null => None,
                        other => Some(
                            other
                                .as_str()
                                .context("dataset file must be a string or null")?
                                .to_string(),
                        ),
                    };
                }
                "shards" => {
                    spec.shards = match val {
                        Json::Null => None,
                        other => {
                            let k = other
                                .as_usize()
                                .context("dataset shards must be a positive integer or null")?;
                            if k == 0 {
                                bail!("dataset shards must be ≥ 1 (or null for the default)");
                            }
                            Some(k)
                        }
                    };
                }
                other => bail!("unknown dataset key '{other}'"),
            }
        }
        Ok(spec)
    }

    /// Emit the spec as the request's `"dataset"` object.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("backend", self.backend.as_str())
            .set("seed", self.seed as usize)
            .set("scale", self.scale)
            .set("density", self.density)
            .set(
                "file",
                match &self.file {
                    Some(f) => Json::from(f.as_str()),
                    None => Json::Null,
                },
            )
            .set(
                "shards",
                match self.shards {
                    Some(k) => Json::from(k),
                    None => Json::Null,
                },
            )
    }

    /// Registry key: the canonical compact JSON of the spec (object keys
    /// sort, so equal specs always produce equal keys).
    pub fn key(&self) -> String {
        self.to_json().to_string_compact()
    }
}

// ---------------------------------------------------------------------------
// SolveRequest
// ---------------------------------------------------------------------------

/// One solve request — the typed struct both the CLI flags and the wire
/// JSON translate into. Solve-control knobs live in the embedded
/// [`SolveControls`] (reachable via `Deref`); the JSON surface flattens
/// them into the top-level object exactly like the `--config` file.
#[derive(Debug, Clone, PartialEq)]
pub struct SolveRequest {
    /// Schema version; must equal [`PROTOCOL_VERSION`].
    pub v: usize,
    pub kind: RequestKind,
    /// Dataset to operate on; required by every kind except
    /// `stats`/`shutdown`.
    pub dataset: Option<DatasetSpec>,
    /// α (problem (3)); paths and points fix one α.
    pub alpha: f64,
    pub solver: SolverKind,
    pub screen: ScreenKind,
    /// Pool-parallel red-black BCD group sweeps (no effect under FISTA).
    pub parallel_bcd_groups: bool,
    /// The shared solve-control knobs — reachable directly via `Deref`.
    pub controls: SolveControls,
    /// `solve-point`: 0-based index into the λ grid (0 = λmax).
    pub lambda_index: Option<usize>,
    /// `cv`: fold count.
    pub k_folds: usize,
    /// `cv`: α grid (default: the paper's seven tan(ψ) values).
    pub alphas: Vec<f64>,
}

impl std::ops::Deref for SolveRequest {
    type Target = SolveControls;
    fn deref(&self) -> &SolveControls {
        &self.controls
    }
}

impl std::ops::DerefMut for SolveRequest {
    fn deref_mut(&mut self) -> &mut SolveControls {
        &mut self.controls
    }
}

impl SolveRequest {
    /// A request of `kind` with the batch CLI's defaults everywhere else.
    pub fn new(kind: RequestKind) -> SolveRequest {
        let defaults = crate::config::Config::default();
        SolveRequest {
            v: PROTOCOL_VERSION,
            kind,
            dataset: None,
            alpha: 1.0,
            solver: defaults.solver,
            screen: defaults.screen,
            parallel_bcd_groups: defaults.parallel_bcd_groups,
            controls: defaults.controls,
            lambda_index: None,
            k_folds: defaults.k_folds,
            alphas: defaults.alphas,
        }
    }

    /// Parse a request from JSON text. Unknown keys, bad values, a
    /// missing or unsupported `"v"`, and kind/field mismatches are all
    /// typed errors — nothing is silently ignored.
    pub fn parse(text: &str) -> Result<SolveRequest> {
        let v = Json::parse(text).context("request is not valid JSON")?;
        let obj = v.as_obj().context("request must be a JSON object")?;
        let kind_s = obj
            .get("kind")
            .and_then(Json::as_str)
            .context("request requires a \"kind\" string")?;
        let kind = RequestKind::parse(kind_s).with_context(|| {
            format!(
                "unknown request kind '{kind_s}' \
                 (load-dataset|solve-path|solve-point|cv|stats|shutdown)"
            )
        })?;
        let mut req = SolveRequest::new(kind);
        let mut saw_version = false;
        for (k, val) in obj {
            match k.as_str() {
                "kind" => {}
                "v" => {
                    let ver = val.as_usize().context("\"v\" must be an integer")?;
                    if ver != PROTOCOL_VERSION {
                        bail!(
                            "unsupported protocol version {ver} \
                             (this build speaks v{PROTOCOL_VERSION})"
                        );
                    }
                    req.v = ver;
                    saw_version = true;
                }
                "dataset" => req.dataset = Some(DatasetSpec::from_json(val)?),
                "alpha" => {
                    req.alpha = val.as_f64().context("alpha must be a number")?;
                    if !(req.alpha > 0.0 && req.alpha.is_finite()) {
                        bail!("alpha must be positive and finite");
                    }
                }
                "alphas" => {
                    let arr = val.as_arr().context("alphas must be an array")?;
                    req.alphas = arr
                        .iter()
                        .map(|x| x.as_f64().context("alpha must be a number"))
                        .collect::<Result<_>>()?;
                    if req.alphas.is_empty() {
                        bail!("alphas must be non-empty");
                    }
                    if req.alphas.iter().any(|&a| a <= 0.0) {
                        bail!("alphas must be positive");
                    }
                }
                "solver" => {
                    req.solver = val
                        .as_str()
                        .and_then(SolverKind::parse)
                        .with_context(|| {
                            format!("unknown solver {val:?} (want \"fista\" or \"bcd\")")
                        })?;
                }
                "screen" => {
                    let s = val.as_str().context("screen must be a string")?;
                    req.screen = ScreenKind::parse(s).with_context(|| {
                        format!(
                            "unknown screen pipeline '{s}' \
                             (tlfre|tlfre+gap|gap|strong+kkt|ws|tlfre+ws|ws+gap|none)"
                        )
                    })?;
                }
                "parallel_bcd_groups" => {
                    req.parallel_bcd_groups =
                        val.as_bool().context("parallel_bcd_groups must be a boolean")?;
                }
                "k_folds" => {
                    req.k_folds = val.as_usize().context("k_folds must be an integer")?;
                    if req.k_folds < 2 {
                        bail!("k_folds must be ≥ 2");
                    }
                }
                "lambda_index" => {
                    req.lambda_index =
                        Some(val.as_usize().context("lambda_index must be an integer ≥ 0")?);
                }
                other => {
                    if !req.controls.apply_json_key(other, val)? {
                        bail!("unknown request key '{other}'");
                    }
                }
            }
        }
        if !saw_version {
            bail!("request is missing protocol version key \"v\" ({PROTOCOL_VERSION} expected)");
        }
        if kind.needs_dataset() && req.dataset.is_none() {
            bail!("'{}' request requires a \"dataset\" object", kind.as_str());
        }
        if kind == RequestKind::SolvePoint {
            let idx = req
                .lambda_index
                .context("'solve-point' request requires \"lambda_index\"")?;
            if idx >= req.controls.n_lambda {
                bail!(
                    "lambda_index {idx} out of range for the {}-point grid",
                    req.controls.n_lambda
                );
            }
        }
        Ok(req)
    }

    /// Serialize to the wire JSON (the inverse of [`Self::parse`]; control
    /// fields are emitted by [`SolveControls::emit_json`], the same single
    /// source as parsing).
    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj()
            .set("v", self.v)
            .set("kind", self.kind.as_str())
            .set("alpha", self.alpha)
            .set("alphas", self.alphas.clone())
            .set("solver", self.solver.as_str())
            .set("screen", self.screen.as_str())
            .set("parallel_bcd_groups", self.parallel_bcd_groups)
            .set("k_folds", self.k_folds);
        if let Some(spec) = &self.dataset {
            obj = obj.set("dataset", spec.to_json());
        }
        if let Some(idx) = self.lambda_index {
            obj = obj.set("lambda_index", idx);
        }
        self.controls.emit_json(obj)
    }

    /// The per-α path configuration this request describes — the same
    /// translation [`crate::config::Config::path_config`] performs for the
    /// batch CLI, so served and batch solves are driven by identical
    /// configs by construction.
    pub fn path_config(&self) -> PathConfig {
        PathConfig {
            alpha: self.alpha,
            solver: self.solver,
            materialize_reduced: false,
            exact_view_lipschitz: false,
            parallel_bcd_groups: self.parallel_bcd_groups,
            screen: self.screen,
            controls: self.controls,
        }
    }

    /// Cache key for completed path prefixes: dataset identity plus every
    /// field that influences the walk, floats by bit pattern. Two requests
    /// share a cache entry iff their walks are bitwise identical.
    pub fn cache_key(&self) -> String {
        let c = &self.controls;
        format!(
            "{}|alpha={:016x}|solver={}|screen={}|pbcd={}|nl={}|ratio={:016x}|tol={:016x}\
             |mi={}|vs={}|gi={:016x}|lre={:?}|ms={:?}|wsr={}|wsg={:016x}",
            self.dataset.as_ref().map(DatasetSpec::key).unwrap_or_default(),
            self.alpha.to_bits(),
            self.solver.as_str(),
            self.screen.as_str(),
            self.parallel_bcd_groups,
            c.n_lambda,
            c.lambda_min_ratio.to_bits(),
            c.tol.to_bits(),
            c.max_iter,
            c.verify_safety,
            c.gap_inflation.to_bits(),
            c.lipschitz_refresh_every,
            c.max_seconds.map(f64::to_bits),
            c.ws_max_rounds,
            c.ws_growth.to_bits(),
        )
    }
}

// ---------------------------------------------------------------------------
// SolveResponse
// ---------------------------------------------------------------------------

/// Per-λ step summary carried by path/point responses.
#[derive(Debug, Clone, PartialEq)]
pub struct StepSummary {
    pub lambda: f64,
    /// Final duality gap of the step's solve.
    pub gap: f64,
    pub iters: usize,
    pub active_features: usize,
    /// Certified distance to the optimum (max(gap, 0); +∞ when the gap
    /// never became finite).
    pub certified_suboptimality: f64,
    /// True when the step's solver stopped on the wall-clock budget
    /// rather than the tolerance.
    pub budget_exhausted: bool,
}

impl From<&PathStep> for StepSummary {
    fn from(s: &PathStep) -> StepSummary {
        StepSummary {
            lambda: s.lambda,
            gap: s.gap,
            iters: s.iters,
            active_features: s.active_features,
            certified_suboptimality: s.certified_suboptimality,
            budget_exhausted: s.budget_exhausted,
        }
    }
}

impl StepSummary {
    fn to_json(&self) -> Json {
        Json::obj()
            .set("lambda", self.lambda)
            .set("gap", self.gap)
            .set("iters", self.iters)
            .set("active_features", self.active_features)
            .set("certified_suboptimality", self.certified_suboptimality)
            .set("budget_exhausted", self.budget_exhausted)
    }

    fn from_json(v: &Json) -> Result<StepSummary> {
        let get = |key: &str| {
            v.get(key)
                .and_then(Json::as_f64)
                .with_context(|| format!("step is missing numeric '{key}'"))
        };
        Ok(StepSummary {
            lambda: get("lambda")?,
            gap: get("gap")?,
            iters: get("iters")? as usize,
            active_features: get("active_features")? as usize,
            certified_suboptimality: get("certified_suboptimality")?,
            budget_exhausted: v
                .get("budget_exhausted")
                .and_then(Json::as_bool)
                .context("step is missing 'budget_exhausted'")?,
        })
    }
}

/// The engine's answer to a [`SolveRequest`] — one shape for every kind;
/// kind-specific extras (dataset dims, the CV table, engine counters) ride
/// in [`Self::payload`].
#[derive(Debug, Clone, PartialEq)]
pub struct SolveResponse {
    /// Schema version (= [`PROTOCOL_VERSION`]).
    pub v: usize,
    /// False when the request failed; [`Self::error`] carries the chain.
    pub ok: bool,
    pub kind: RequestKind,
    /// Describe line of the dataset operated on (empty when n/a).
    pub dataset: String,
    /// True when the answer came from a resident cached path prefix
    /// (no solver ran for this request).
    pub warm: bool,
    /// True when the walk stopped early (wall-clock budget).
    pub truncated: bool,
    pub lambda_max: f64,
    /// The resolved descending λ grid.
    pub grid: Vec<f64>,
    pub steps: Vec<StepSummary>,
    /// `solve-path`: one [`beta_hex`] line per grid point (identical bytes
    /// to the batch CLI's `--coef-out`). `solve-point`: exactly one line.
    pub coef_hex: Vec<String>,
    /// `solve-point`: the λ value solved.
    pub lambda: Option<f64>,
    /// `solve-point`: certified distance to the optimum at that point.
    pub certified_suboptimality: Option<f64>,
    pub screen_total_s: f64,
    pub solve_total_s: f64,
    /// Kind-specific extras (load-dataset dims, cv table, stats counters).
    pub payload: Json,
    /// Error chain when `ok` is false.
    pub error: Option<String>,
}

impl SolveResponse {
    /// An empty successful response of `kind`.
    pub fn new(kind: RequestKind) -> SolveResponse {
        SolveResponse {
            v: PROTOCOL_VERSION,
            ok: true,
            kind,
            dataset: String::new(),
            warm: false,
            truncated: false,
            lambda_max: 0.0,
            grid: Vec::new(),
            steps: Vec::new(),
            coef_hex: Vec::new(),
            lambda: None,
            certified_suboptimality: None,
            screen_total_s: 0.0,
            solve_total_s: 0.0,
            payload: Json::Null,
            error: None,
        }
    }

    /// The error response for a failed request ('{e:#}' chain flattened by
    /// the caller).
    pub fn failure(kind: RequestKind, error: String) -> SolveResponse {
        let mut r = SolveResponse::new(kind);
        r.ok = false;
        r.error = Some(error);
        r
    }

    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj()
            .set("v", self.v)
            .set("ok", self.ok)
            .set("kind", self.kind.as_str())
            .set("dataset", self.dataset.as_str())
            .set("warm", self.warm)
            .set("truncated", self.truncated)
            .set("lambda_max", self.lambda_max)
            .set("grid", self.grid.clone())
            .set("steps", self.steps.iter().map(StepSummary::to_json).collect::<Vec<_>>())
            .set("coef_hex", self.coef_hex.iter().map(String::as_str).collect::<Vec<_>>())
            .set("screen_total_s", self.screen_total_s)
            .set("solve_total_s", self.solve_total_s)
            .set("payload", self.payload.clone());
        if let Some(l) = self.lambda {
            obj = obj.set("lambda", l);
        }
        if let Some(c) = self.certified_suboptimality {
            obj = obj.set("certified_suboptimality", c);
        }
        if let Some(e) = &self.error {
            obj = obj.set("error", e.as_str());
        }
        obj
    }

    /// Parse a response from JSON text (the client side of the wire).
    pub fn parse(text: &str) -> Result<SolveResponse> {
        let v = Json::parse(text).context("response is not valid JSON")?;
        let ver = v.get("v").and_then(Json::as_usize).context("response is missing \"v\"")?;
        if ver != PROTOCOL_VERSION {
            bail!("unsupported response version {ver} (this build speaks v{PROTOCOL_VERSION})");
        }
        let kind_s = v
            .get("kind")
            .and_then(Json::as_str)
            .context("response is missing \"kind\"")?;
        let kind = RequestKind::parse(kind_s)
            .with_context(|| format!("unknown response kind '{kind_s}'"))?;
        let mut r = SolveResponse::new(kind);
        r.ok = v.get("ok").and_then(Json::as_bool).context("response is missing \"ok\"")?;
        r.error = v.get("error").and_then(Json::as_str).map(str::to_string);
        r.dataset = v.get("dataset").and_then(Json::as_str).unwrap_or_default().to_string();
        r.warm = v.get("warm").and_then(Json::as_bool).unwrap_or(false);
        r.truncated = v.get("truncated").and_then(Json::as_bool).unwrap_or(false);
        r.lambda_max = v.get("lambda_max").and_then(Json::as_f64).unwrap_or(0.0);
        if let Some(grid) = v.get("grid").and_then(Json::as_arr) {
            r.grid = grid
                .iter()
                .map(|x| x.as_f64().context("grid entries must be numbers"))
                .collect::<Result<_>>()?;
        }
        if let Some(steps) = v.get("steps").and_then(Json::as_arr) {
            r.steps = steps.iter().map(StepSummary::from_json).collect::<Result<_>>()?;
        }
        if let Some(lines) = v.get("coef_hex").and_then(Json::as_arr) {
            r.coef_hex = lines
                .iter()
                .map(|x| {
                    x.as_str().map(str::to_string).context("coef_hex entries must be strings")
                })
                .collect::<Result<_>>()?;
        }
        r.lambda = v.get("lambda").and_then(Json::as_f64);
        r.certified_suboptimality = v.get("certified_suboptimality").and_then(Json::as_f64);
        r.screen_total_s = v.get("screen_total_s").and_then(Json::as_f64).unwrap_or(0.0);
        r.solve_total_s = v.get("solve_total_s").and_then(Json::as_f64).unwrap_or(0.0);
        r.payload = v.get("payload").cloned().unwrap_or(Json::Null);
        Ok(r)
    }

    /// The exact byte stream the batch CLI's `--coef-out` would hold for
    /// the same walk: coef_hex lines joined with trailing newlines.
    pub fn coef_dump(&self) -> String {
        let mut s =
            String::with_capacity(self.coef_hex.iter().map(|l| l.len() + 1).sum::<usize>());
        for line in &self.coef_hex {
            s.push_str(line);
            s.push('\n');
        }
        s
    }
}

// ---------------------------------------------------------------------------
// Coefficient bit dumps
// ---------------------------------------------------------------------------

/// One coefficient vector as its 8-hex-digit f32 bit patterns, space
/// separated — one `--coef-out` line. Text-stable across platforms and
/// backends (and distinguishes `-0.0` from `0.0`), so `cmp` is a bitwise
/// equality check.
pub fn beta_hex(beta: &[f32]) -> String {
    let mut s = String::with_capacity(beta.len() * 9);
    for (i, v) in beta.iter().enumerate() {
        if i > 0 {
            s.push(' ');
        }
        s.push_str(&format!("{:08x}", v.to_bits()));
    }
    s
}

/// Per-λ coefficient dump for bitwise comparison: one [`beta_hex`] line
/// per grid point plus trailing newline — the byte format of the CLI's
/// `--coef-out` and the serve smoke test's `cmp` target.
pub fn coef_hex_dump(betas: &[Vec<f32>]) -> String {
    let per_line = betas.first().map_or(0, |b| b.len() * 9 + 1);
    let mut s = String::with_capacity(betas.len() * per_line);
    for b in betas {
        s.push_str(&beta_hex(b));
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip_through_json() {
        let mut req = SolveRequest::new(RequestKind::SolvePath);
        req.dataset = Some(DatasetSpec::new("synthetic1"));
        req.alpha = 0.5;
        req.solver = SolverKind::Bcd;
        req.screen = ScreenKind::TlfreGap;
        req.controls.n_lambda = 17;
        req.controls.tol = 1e-7;
        req.controls.max_seconds = Some(2.5);
        let back = SolveRequest::parse(&req.to_json().to_string_pretty()).unwrap();
        assert_eq!(req, back);
        assert_eq!(req.cache_key(), back.cache_key());
        // Working-set pipelines and their knobs ride the same wire schema.
        req.screen = ScreenKind::Ws;
        req.controls.ws_max_rounds = 9;
        req.controls.ws_growth = 1.5;
        let back = SolveRequest::parse(&req.to_json().to_string_pretty()).unwrap();
        assert_eq!(req, back);
        assert_eq!(back.screen, ScreenKind::Ws);
        for kind in ["ws", "tlfre+ws", "ws+gap"] {
            let txt = format!(
                r#"{{"v": 1, "kind": "solve-path", "screen": "{kind}",
                   "dataset": {{"name": "synthetic1"}}}}"#
            );
            assert!(SolveRequest::parse(&txt).is_ok(), "{kind} must parse");
        }
    }

    #[test]
    fn point_request_roundtrip_and_range_check() {
        let mut req = SolveRequest::new(RequestKind::SolvePoint);
        req.dataset = Some(DatasetSpec::new("synthetic2"));
        req.controls.n_lambda = 10;
        req.lambda_index = Some(9);
        let back = SolveRequest::parse(&req.to_json().to_string_pretty()).unwrap();
        assert_eq!(req, back);
        req.lambda_index = Some(10); // out of range
        assert!(SolveRequest::parse(&req.to_json().to_string_pretty()).is_err());
    }

    #[test]
    fn rejects_unknown_keys_bad_versions_and_missing_fields() {
        let ds = r#""dataset": {"name": "synthetic1"}"#;
        // Unknown top-level key (config-key typos included).
        let bad = format!(r#"{{"v": 1, "kind": "solve-path", {ds}, "n_lamda": 10}}"#);
        let err = format!("{:#}", SolveRequest::parse(&bad).unwrap_err());
        assert!(err.contains("unknown request key 'n_lamda'"), "{err}");
        // Unknown dataset key.
        let bad = r#"{"v": 1, "kind": "solve-path", "dataset": {"name": "s1", "sede": 3}}"#;
        assert!(SolveRequest::parse(bad).is_err());
        // Missing / wrong protocol version.
        let bad = format!(r#"{{"kind": "solve-path", {ds}}}"#);
        assert!(SolveRequest::parse(&bad).is_err());
        let bad = format!(r#"{{"v": 2, "kind": "solve-path", {ds}}}"#);
        assert!(format!("{:#}", SolveRequest::parse(&bad).unwrap_err())
            .contains("unsupported protocol version"));
        // Unknown kind; missing dataset; missing lambda_index.
        assert!(SolveRequest::parse(r#"{"v": 1, "kind": "solve-everything"}"#).is_err());
        assert!(SolveRequest::parse(r#"{"v": 1, "kind": "solve-path"}"#).is_err());
        let bad = format!(r#"{{"v": 1, "kind": "solve-point", {ds}}}"#);
        assert!(SolveRequest::parse(&bad).is_err());
        // Control-key validation flows through the shared parse path.
        let bad = format!(r#"{{"v": 1, "kind": "solve-path", {ds}, "lambda_min_ratio": 2.0}}"#);
        assert!(SolveRequest::parse(&bad).is_err());
        let bad = format!(r#"{{"v": 1, "kind": "solve-path", {ds}, "ws_growth": 0.5}}"#);
        assert!(SolveRequest::parse(&bad).is_err());
        // An unknown screen kind stays a typed error naming the pipeline.
        let bad = format!(r#"{{"v": 1, "kind": "solve-path", {ds}, "screen": "magic"}}"#);
        let err = format!("{:#}", SolveRequest::parse(&bad).unwrap_err());
        assert!(err.contains("unknown screen pipeline 'magic'"), "{err}");
        // stats/shutdown need no dataset.
        assert!(SolveRequest::parse(r#"{"v": 1, "kind": "stats"}"#).is_ok());
        assert!(SolveRequest::parse(r#"{"v": 1, "kind": "shutdown"}"#).is_ok());
    }

    #[test]
    fn cache_key_separates_configs_and_floats_bitwise() {
        let mut a = SolveRequest::new(RequestKind::SolvePath);
        a.dataset = Some(DatasetSpec::new("synthetic1"));
        let mut b = a.clone();
        assert_eq!(a.cache_key(), b.cache_key());
        b.controls.tol = a.controls.tol * (1.0 + f64::EPSILON); // 1-ulp apart
        assert_ne!(a.cache_key(), b.cache_key());
        b = a.clone();
        b.screen = ScreenKind::Gap;
        assert_ne!(a.cache_key(), b.cache_key());
        b = a.clone();
        b.dataset.as_mut().unwrap().seed += 1;
        assert_ne!(a.cache_key(), b.cache_key());
        // Working-set knobs change the iterate trajectory (loose rounds
        // warm-start the tight solve), so they separate cache lines too.
        b = a.clone();
        b.controls.ws_max_rounds += 1;
        assert_ne!(a.cache_key(), b.cache_key());
        b = a.clone();
        b.controls.ws_growth *= 1.0 + f64::EPSILON; // 1-ulp apart
        assert_ne!(a.cache_key(), b.cache_key());
        // A point request at the same config shares the path's cache line.
        let mut p = a.clone();
        p.kind = RequestKind::SolvePoint;
        p.lambda_index = Some(3);
        assert_eq!(a.cache_key(), p.cache_key());
    }

    #[test]
    fn response_roundtrip_and_coef_dump_bytes() {
        let mut r = SolveResponse::new(RequestKind::SolvePath);
        r.dataset = "synthetic1: 50×100 (10 groups)".into();
        r.lambda_max = 3.5;
        r.grid = vec![3.5, 1.75];
        r.steps = vec![StepSummary {
            lambda: 3.5,
            gap: 0.0,
            iters: 0,
            active_features: 0,
            certified_suboptimality: 0.0,
            budget_exhausted: false,
        }];
        r.coef_hex = vec![beta_hex(&[0.0, -0.0]), beta_hex(&[1.0, 2.0])];
        let back = SolveResponse::parse(&r.to_json().to_string_pretty()).unwrap();
        assert_eq!(r, back);
        // coef_dump reproduces coef_hex_dump's bytes exactly.
        assert_eq!(
            back.coef_dump(),
            coef_hex_dump(&[vec![0.0, -0.0], vec![1.0, 2.0]])
        );
        assert!(back.coef_dump().starts_with("00000000 80000000\n"));
    }

    #[test]
    fn failure_responses_carry_the_error() {
        let r = SolveResponse::failure(RequestKind::SolvePath, "boom: reason".into());
        let back = SolveResponse::parse(&r.to_json().to_string_pretty()).unwrap();
        assert!(!back.ok);
        assert_eq!(back.error.as_deref(), Some("boom: reason"));
    }
}
