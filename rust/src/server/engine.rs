//! Request execution: one [`SolveRequest`] in, one [`SolveResponse`] out,
//! against the resident [`SessionRegistry`].
//!
//! Every solve goes through the same streaming driver as the batch CLI
//! (`coordinator::driver`), so a served result is bitwise identical to
//! the equivalent batch run by construction — same engine, same grid,
//! same loop body. The serve layer adds exactly two things on top:
//!
//! * **Path caching.** A completed walk is stored under the request's
//!   [`SolveRequest::cache_key`]; a later identical `solve-path` answers
//!   from the cache without running a solver (`warm: true`).
//! * **Prefix solving.** `solve-point` at grid index `i` runs
//!   [`drive_prefix`] to index `i` and stops — a prefix of the full walk
//!   is bitwise identical to the same prefix of the full walk, and the
//!   cached prefix (each entry warm-started from its predecessor during
//!   the walk) serves later points at indexes `≤ i` with zero solves.
//!
//! Execution never panics a connection thread on bad input: [`execute`]
//! converts every error chain into a `SolveResponse::failure` envelope.

use super::api::{beta_hex, RequestKind, SolveRequest, SolveResponse, StepSummary};
use super::registry::{CachedPath, LoadedData, SessionRegistry};
use crate::bail;
use crate::coordinator::driver::{drive_prefix, PathSink, TlfreEngine};
use crate::coordinator::runner::PathStep;
use crate::coordinator::{cross_validate, CvOutput, CvPoint};
use crate::error::{Context, Result};
use crate::util::json::Json;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Captures everything a walk streams — grid, per-λ step records, and the
/// full-space coefficient vector per step — so the result can live in the
/// path cache and be re-served without re-solving.
struct RecordingSink {
    lambda_max: f64,
    grid: Vec<f64>,
    steps: Vec<PathStep>,
    betas: Vec<Vec<f32>>,
}

impl RecordingSink {
    fn new() -> RecordingSink {
        RecordingSink { lambda_max: 0.0, grid: Vec::new(), steps: Vec::new(), betas: Vec::new() }
    }
}

impl PathSink<PathStep> for RecordingSink {
    fn on_grid(&mut self, lambda_max: f64, grid: &[f64]) {
        self.lambda_max = lambda_max;
        self.grid = grid.to_vec();
        self.steps.reserve(grid.len());
        self.betas.reserve(grid.len());
    }

    fn on_step(&mut self, step: &PathStep, beta: &[f32]) {
        self.steps.push(step.clone());
        self.betas.push(beta.to_vec());
    }
}

/// Dispatch a body over the concrete design-matrix type behind a
/// [`LoadedData`] ([`crate::linalg::DesignMatrix`] is not object-safe —
/// static dispatch per backend, like the CLI's command bodies).
macro_rules! with_matrix {
    ($data:expr, |$x:ident| $body:expr) => {
        match &*$data {
            LoadedData::Dense(d) => {
                let $x = &d.x;
                $body
            }
            LoadedData::Csc(d) => {
                let $x = &d.x;
                $body
            }
            LoadedData::Mmap(d) => {
                let $x = &d.ds.x;
                $body
            }
            LoadedData::Sharded(d) => {
                let $x = &d.x;
                $body
            }
        }
    };
}

/// Walk the path for `req` on `data`, stopping after `stop_after` grid
/// points (`None` = the full grid), and package the result for the cache.
fn walk_prefix(data: &LoadedData, req: &SolveRequest, stop_after: Option<usize>) -> CachedPath {
    let cfg = req.path_config();
    let mut sink = RecordingSink::new();
    let totals = with_matrix!(data, |x| drive_prefix(
        TlfreEngine::new(x, data.y(), data.groups(), &cfg),
        &mut sink,
        stop_after
    ));
    let complete = sink.steps.len() == sink.grid.len();
    CachedPath {
        lambda_max: totals.lambda_max,
        grid: sink.grid,
        steps: sink.steps,
        betas: sink.betas,
        screen_total_s: totals.screen_total_s,
        solve_total_s: totals.solve_total_s,
        complete,
    }
}

/// Execute one request. Never returns an error: failures become a
/// `SolveResponse::failure` envelope (and bump the error counter), so a
/// bad request can only ever cost its own connection.
pub fn execute(reg: &SessionRegistry, req: &SolveRequest) -> SolveResponse {
    reg.stats.requests.fetch_add(1, Ordering::Relaxed);
    match run(reg, req) {
        Ok(resp) => resp,
        Err(e) => {
            reg.stats.errors.fetch_add(1, Ordering::Relaxed);
            SolveResponse::failure(req.kind, format!("{e:#}"))
        }
    }
}

fn run(reg: &SessionRegistry, req: &SolveRequest) -> Result<SolveResponse> {
    match req.kind {
        RequestKind::Stats => {
            let mut r = SolveResponse::new(req.kind);
            r.payload = reg.stats_json();
            Ok(r)
        }
        RequestKind::Shutdown => {
            // The accept loop flips its stop flag after answering; the
            // engine itself has nothing to tear down.
            let mut r = SolveResponse::new(req.kind);
            r.payload = Json::obj().set("shutting_down", true);
            Ok(r)
        }
        RequestKind::LoadDataset => {
            let data = reg.dataset(dataset_spec(req)?)?;
            let mut r = SolveResponse::new(req.kind);
            r.dataset = data.describe();
            r.payload = Json::obj()
                .set("n", data.n())
                .set("p", data.p())
                .set("groups", data.groups().n_groups())
                .set("backend", data.backend().as_str());
            Ok(r)
        }
        RequestKind::SolvePath => solve_path(reg, req),
        RequestKind::SolvePoint => solve_point(reg, req),
        RequestKind::Cv => run_cv(reg, req),
    }
}

fn dataset_spec(req: &SolveRequest) -> Result<&super::api::DatasetSpec> {
    req.dataset
        .as_ref()
        .with_context(|| format!("'{}' request requires a dataset", req.kind.as_str()))
}

fn solve_path(reg: &SessionRegistry, req: &SolveRequest) -> Result<SolveResponse> {
    let data = reg.dataset(dataset_spec(req)?)?;
    let key = req.cache_key();
    let (path, warm) = match reg.cached_path(&key) {
        Some(p) if p.complete => {
            reg.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
            (p, true)
        }
        _ => {
            reg.stats.cache_misses.fetch_add(1, Ordering::Relaxed);
            let p = Arc::new(walk_prefix(&data, req, None));
            reg.stats.paths_solved.fetch_add(1, Ordering::Relaxed);
            reg.store_path(key, p.clone());
            (p, false)
        }
    };
    let mut r = SolveResponse::new(req.kind);
    r.dataset = data.describe();
    r.warm = warm;
    r.truncated = !path.complete;
    fill_path_fields(&mut r, &path);
    r.steps = path.steps.iter().map(StepSummary::from).collect();
    r.coef_hex = path.betas.iter().map(|b| beta_hex(b)).collect();
    Ok(r)
}

fn solve_point(reg: &SessionRegistry, req: &SolveRequest) -> Result<SolveResponse> {
    let idx = req.lambda_index.context("'solve-point' request requires \"lambda_index\"")?;
    if idx >= req.controls.n_lambda {
        bail!("lambda_index {idx} out of range for the {}-point grid", req.controls.n_lambda);
    }
    let data = reg.dataset(dataset_spec(req)?)?;
    let key = req.cache_key();
    let (path, warm) = match reg.cached_path(&key) {
        Some(p) if p.covers(idx) => {
            reg.stats.cache_hits.fetch_add(1, Ordering::Relaxed);
            (p, true)
        }
        _ => {
            reg.stats.cache_misses.fetch_add(1, Ordering::Relaxed);
            let p = Arc::new(walk_prefix(&data, req, Some(idx + 1)));
            reg.stats.paths_solved.fetch_add(1, Ordering::Relaxed);
            reg.store_path(key, p.clone());
            (p, false)
        }
    };
    if !path.covers(idx) {
        // Only a wall-clock budget can stop a prefix walk short of its cut.
        bail!(
            "wall-clock budget exhausted at grid index {} (requested index {idx})",
            path.steps.len()
        );
    }
    let step = &path.steps[idx];
    let mut r = SolveResponse::new(req.kind);
    r.dataset = data.describe();
    r.warm = warm;
    fill_path_fields(&mut r, &path);
    r.lambda = Some(step.lambda);
    r.certified_suboptimality = Some(step.certified_suboptimality);
    r.steps = vec![StepSummary::from(step)];
    r.coef_hex = vec![beta_hex(&path.betas[idx])];
    Ok(r)
}

/// Shared path/point response fields. The timing totals always describe
/// the walk that *produced* the data — for a warm response that walk ran
/// on an earlier request, and `warm: true` says so.
fn fill_path_fields(r: &mut SolveResponse, path: &CachedPath) {
    r.lambda_max = path.lambda_max;
    r.grid = path.grid.clone();
    r.screen_total_s = path.screen_total_s;
    r.solve_total_s = path.solve_total_s;
}

fn run_cv(reg: &SessionRegistry, req: &SolveRequest) -> Result<SolveResponse> {
    let spec = dataset_spec(req)?;
    let seed = spec.seed;
    let data = reg.dataset(spec)?;
    let cfg = req.path_config();
    // CV needs row selection for fold extraction (`SelectRows`), which the
    // out-of-core backends deliberately do not implement.
    let out = match &*data {
        LoadedData::Dense(d) => {
            cross_validate(&d.x, &d.y, &d.groups, &req.alphas, req.k_folds, &cfg, seed)
        }
        LoadedData::Csc(d) => {
            cross_validate(&d.x, &d.y, &d.groups, &req.alphas, req.k_folds, &cfg, seed)
        }
        other => bail!("cv supports dense|csc backends, got '{}'", other.backend().as_str()),
    };
    let mut r = SolveResponse::new(req.kind);
    r.dataset = data.describe();
    r.screen_total_s = out.screen_total_s;
    r.solve_total_s = out.solve_total_s;
    r.payload = cv_json(&out);
    Ok(r)
}

fn cv_json(out: &CvOutput) -> Json {
    fn point(p: &CvPoint) -> Json {
        Json::obj()
            .set("alpha", p.alpha)
            .set("lambda_ratio", p.lambda_ratio)
            .set("mse", p.mse)
            .set("mean_nnz", p.mean_nnz)
    }
    Json::obj()
        .set("best", point(&out.best))
        .set("points", out.points.iter().map(point).collect::<Vec<_>>())
        .set("nonfinite_points", out.nonfinite_points)
}

#[cfg(test)]
mod tests {
    use super::super::api::{coef_hex_dump, BackendKind, DatasetSpec};
    use super::*;
    use crate::coordinator::run_tlfre_path_with_coefficients;
    use crate::data::registry::resolve_dataset;

    fn path_request(backend: BackendKind) -> SolveRequest {
        let mut req = SolveRequest::new(RequestKind::SolvePath);
        let mut spec = DatasetSpec::new("synthetic1");
        spec.backend = backend;
        spec.scale = 0.01;
        req.dataset = Some(spec);
        req.alpha = 0.5;
        req.controls.n_lambda = 8;
        req.controls.lambda_min_ratio = 0.1;
        req
    }

    fn batch_dump(req: &SolveRequest) -> String {
        let spec = req.dataset.as_ref().unwrap();
        let ds = resolve_dataset(&spec.name, spec.seed, spec.scale).unwrap();
        let (out, betas) =
            run_tlfre_path_with_coefficients(&ds.x, &ds.y, &ds.groups, &req.path_config());
        assert!(!out.steps.is_empty());
        coef_hex_dump(&betas)
    }

    #[test]
    fn served_path_is_bitwise_identical_to_the_batch_run() {
        let reg = SessionRegistry::new();
        for backend in [BackendKind::Dense, BackendKind::Csc, BackendKind::Sharded] {
            let req = path_request(backend);
            let resp = execute(&reg, &req);
            assert!(resp.ok, "{:?}", resp.error);
            assert!(!resp.warm);
            assert_eq!(resp.coef_dump(), batch_dump(&req), "{}", backend.as_str());
            // Second identical request is served warm with the same bytes.
            let again = execute(&reg, &req);
            assert!(again.ok && again.warm);
            assert_eq!(again.coef_hex, resp.coef_hex);
        }
    }

    #[test]
    fn point_prefixes_match_the_full_path_and_warm_from_the_cache() {
        let reg = SessionRegistry::new();
        let full = batch_dump(&path_request(BackendKind::Dense));
        let lines: Vec<&str> = full.lines().collect();
        // Cold point at index 4 walks the prefix from scratch.
        let mut point = path_request(BackendKind::Dense);
        point.kind = RequestKind::SolvePoint;
        point.lambda_index = Some(4);
        let resp = execute(&reg, &point);
        assert!(resp.ok, "{:?}", resp.error);
        assert!(!resp.warm);
        assert_eq!(resp.coef_hex, vec![lines[4].to_string()]);
        assert!(resp.certified_suboptimality.is_some());
        assert_eq!(resp.steps.len(), 1);
        // An earlier index is inside the cached prefix: warm, zero solves.
        point.lambda_index = Some(2);
        let resp = execute(&reg, &point);
        assert!(resp.ok && resp.warm);
        assert_eq!(resp.coef_hex, vec![lines[2].to_string()]);
        // A later index extends the prefix (cold) and matches the batch walk.
        point.lambda_index = Some(7);
        let resp = execute(&reg, &point);
        assert!(resp.ok && !resp.warm);
        assert_eq!(resp.coef_hex, vec![lines[7].to_string()]);
        // The path request now finds the complete prefix resident.
        let path = execute(&reg, &path_request(BackendKind::Dense));
        assert!(path.ok && path.warm);
        assert_eq!(path.coef_dump(), full);
    }

    #[test]
    fn errors_become_failure_envelopes_not_panics() {
        let reg = SessionRegistry::new();
        let mut req = path_request(BackendKind::Dense);
        req.dataset.as_mut().unwrap().name = "no-such-dataset".into();
        let resp = execute(&reg, &req);
        assert!(!resp.ok);
        assert!(resp.error.as_deref().unwrap_or("").contains("unknown dataset"));
        // A point past a budget-stopped walk is a typed error too.
        let mut req = path_request(BackendKind::Dense);
        req.kind = RequestKind::SolvePoint;
        req.lambda_index = Some(3);
        req.dataset = None;
        assert!(!execute(&reg, &req).ok);
    }

    #[test]
    fn load_stats_and_cv_round_trip() {
        let reg = SessionRegistry::new();
        let mut load = path_request(BackendKind::Dense);
        load.kind = RequestKind::LoadDataset;
        let resp = execute(&reg, &load);
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.payload.get("n").and_then(Json::as_usize), Some(250));
        let mut cv = path_request(BackendKind::Dense);
        cv.kind = RequestKind::Cv;
        cv.alphas = vec![0.5];
        cv.k_folds = 2;
        cv.controls.n_lambda = 4;
        let resp = execute(&reg, &cv);
        assert!(resp.ok, "{:?}", resp.error);
        assert!(resp.payload.get("best").is_some());
        // CV on an out-of-core backend is a typed error.
        cv.dataset.as_mut().unwrap().backend = BackendKind::Mmap;
        let resp = execute(&reg, &cv);
        assert!(!resp.ok);
        assert!(resp.error.as_deref().unwrap_or("").contains("dense|csc"));
        let stats = execute(&reg, &SolveRequest::new(RequestKind::Stats));
        assert!(stats.ok);
        assert!(stats.payload.get("requests").and_then(Json::as_usize).unwrap() >= 4);
    }
}
