//! The resident accept loop: a unix-socket server over the
//! [`SessionRegistry`].
//!
//! One thread per connection, one request per connection (see
//! [`super::wire`]). The listener runs non-blocking so the loop can poll
//! its stop flag between accepts; `SIGTERM`/`SIGINT` flip a static flag
//! from a minimal async-signal-safe handler (raw `signal(2)` through an
//! `extern "C"` declaration — same zero-dependency pattern as the mmap
//! backend), and a `shutdown` request flips the loop's own flag after its
//! response is written. Either way the loop stops accepting, joins every
//! in-flight connection thread, removes the socket file, and returns.
//!
//! Failure containment: a connection that sends a malformed frame or
//! unparseable request gets a 400 envelope and costs nothing else; a
//! client that disconnects mid-request (mid-headers, mid-body, or before
//! reading its response) aborts only its own thread — the registry locks
//! recover from panics and are never held across a solve, so later
//! requests see an intact pool and cache. Rust's runtime ignores
//! `SIGPIPE`, so writing to a dead peer surfaces as an `EPIPE` error the
//! handler discards, never process death.

use super::api::{RequestKind, SolveRequest};
use super::engine::execute;
use super::registry::SessionRegistry;
use super::wire;
use crate::bail;
use crate::error::{Context, Result};
use std::io::BufReader;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

/// Flipped by the signal handler; checked by every accept loop in the
/// process alongside its own stop flag.
static SIGNALLED: AtomicBool = AtomicBool::new(false);

extern "C" fn on_signal(_signum: i32) {
    // Only an atomic store: the handler must stay async-signal-safe.
    SIGNALLED.store(true, Ordering::SeqCst);
}

fn install_signal_handlers() {
    type SigHandler = extern "C" fn(i32);
    extern "C" {
        fn signal(signum: i32, handler: SigHandler) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    // SAFETY: libc `signal` is called with valid constant signal numbers
    // and a handler that is async-signal-safe (a single atomic store, no
    // allocation or locking). The handler has `extern "C"` ABI and static
    // lifetime, and replacing a prior disposition is the intended effect.
    unsafe {
        let _ = signal(SIGTERM, on_signal);
        let _ = signal(SIGINT, on_signal);
    }
}

/// Serve on `socket` with a fresh registry until `SIGTERM`/`SIGINT` or a
/// `shutdown` request — the `tlfre serve` entry point.
pub fn serve(socket: &Path) -> Result<()> {
    install_signal_handlers();
    serve_on(socket, Arc::new(SessionRegistry::new()), Arc::new(AtomicBool::new(false)))
}

/// [`serve`] with an explicit registry and stop flag — the in-process
/// seam the concurrency tests drive (no signals involved).
pub fn serve_on(socket: &Path, reg: Arc<SessionRegistry>, stop: Arc<AtomicBool>) -> Result<()> {
    if socket.exists() {
        // A live server answers a connect; a stale file from a killed
        // process refuses it and is safe to reclaim.
        if UnixStream::connect(socket).is_ok() {
            bail!("{} is already being served", socket.display());
        }
        let _ = std::fs::remove_file(socket);
    }
    let listener =
        UnixListener::bind(socket).with_context(|| format!("binding {}", socket.display()))?;
    listener.set_nonblocking(true).context("setting the listener non-blocking")?;
    let mut handles: Vec<thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) && !SIGNALLED.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                let reg = reg.clone();
                let stop = stop.clone();
                handles.push(thread::spawn(move || {
                    if answer(&stream, &reg) {
                        stop.store(true, Ordering::SeqCst);
                    }
                }));
            }
            Err(_) => thread::sleep(Duration::from_millis(10)),
        }
        handles.retain(|h| !h.is_finished());
    }
    for h in handles {
        let _ = h.join();
    }
    let _ = std::fs::remove_file(socket);
    Ok(())
}

/// Handle one connection end to end; returns true when the request was a
/// successfully answered `shutdown`. Write failures (peer gone) are
/// discarded — the work is already done or already abandoned.
fn answer(stream: &UnixStream, reg: &SessionRegistry) -> bool {
    // A stalled or half-dead client must not pin a thread forever.
    let _ = stream.set_read_timeout(Some(Duration::from_secs(60)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(60)));
    let mut reader = BufReader::new(stream);
    let body = match wire::read_request(&mut reader) {
        Ok(Some(body)) => body,
        // Clean disconnect before a request: nothing to answer.
        Ok(None) => return false,
        Err(e) => {
            let _ = wire::write_response(&mut &*stream, 400, &error_envelope(&e));
            return false;
        }
    };
    let req = match SolveRequest::parse(&body) {
        Ok(req) => req,
        Err(e) => {
            reg.stats.requests.fetch_add(1, Ordering::Relaxed);
            reg.stats.errors.fetch_add(1, Ordering::Relaxed);
            let _ = wire::write_response(&mut &*stream, 400, &error_envelope(&e));
            return false;
        }
    };
    let resp = execute(reg, &req);
    let shutdown = req.kind == RequestKind::Shutdown && resp.ok;
    let _ = wire::write_response(&mut &*stream, 200, &resp.to_json().to_string_compact());
    shutdown
}

/// Body for 400 answers (frame or request unparseable — no [`RequestKind`]
/// to build a full [`super::api::SolveResponse`] envelope around).
fn error_envelope(e: &crate::error::Error) -> String {
    crate::util::json::Json::obj()
        .set("v", super::api::PROTOCOL_VERSION)
        .set("ok", false)
        .set("error", format!("{e:#}"))
        .to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::super::api::{DatasetSpec, SolveResponse};
    use super::*;
    use crate::util::json::Json;

    fn temp_socket(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("tlfre-serve-test-{}-{tag}.sock", std::process::id()))
    }

    fn start(tag: &str) -> (std::path::PathBuf, thread::JoinHandle<Result<()>>) {
        let socket = temp_socket(tag);
        let reg = Arc::new(SessionRegistry::new());
        let stop = Arc::new(AtomicBool::new(false));
        let s = socket.clone();
        let handle = thread::spawn(move || serve_on(&s, reg, stop));
        for _ in 0..500 {
            if socket.exists() && UnixStream::connect(&socket).is_ok() {
                break;
            }
            thread::sleep(Duration::from_millis(5));
        }
        (socket, handle)
    }

    fn shutdown(socket: &Path) {
        let (status, _) = wire::call(socket, r#"{"v": 1, "kind": "shutdown"}"#).unwrap();
        assert_eq!(status, 200);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // unix socket I/O (unsupported under Miri)
    fn stats_load_and_shutdown_round_trip() {
        let (socket, handle) = start("stats");
        let (status, body) = wire::call(&socket, r#"{"v": 1, "kind": "stats"}"#).unwrap();
        assert_eq!(status, 200);
        let resp = SolveResponse::parse(&body).unwrap();
        assert!(resp.ok);
        let mut req = SolveRequest::new(super::super::api::RequestKind::LoadDataset);
        let mut spec = DatasetSpec::new("synthetic1");
        spec.scale = 0.01;
        req.dataset = Some(spec);
        let (status, body) =
            wire::call(&socket, &req.to_json().to_string_compact()).unwrap();
        assert_eq!(status, 200);
        let resp = SolveResponse::parse(&body).unwrap();
        assert!(resp.ok, "{:?}", resp.error);
        assert_eq!(resp.payload.get("n").and_then(Json::as_usize), Some(250));
        shutdown(&socket);
        handle.join().unwrap().unwrap();
        assert!(!socket.exists(), "socket file must be removed on shutdown");
    }

    #[test]
    #[cfg_attr(miri, ignore)] // unix socket I/O (unsupported under Miri)
    fn malformed_requests_get_400_envelopes_and_do_not_kill_the_server() {
        let (socket, handle) = start("bad");
        // Unparseable JSON body.
        let (status, body) = wire::call(&socket, "this is not json").unwrap();
        assert_eq!(status, 400);
        assert!(body.contains("\"ok\":false"), "{body}");
        // Unknown key → typed error naming the key.
        let (status, body) =
            wire::call(&socket, r#"{"v": 1, "kind": "stats", "bogus_key": 3}"#).unwrap();
        assert_eq!(status, 400);
        assert!(body.contains("bogus_key"), "{body}");
        // Mid-request disconnect: write half a frame and hang up.
        {
            use std::io::Write;
            let mut s = UnixStream::connect(&socket).unwrap();
            s.write_all(b"POST /v1/solve HTTP/1.0\r\nContent-Length: 100\r\n\r\n{").unwrap();
        }
        // The server is still alive and correct afterwards.
        let (status, _) = wire::call(&socket, r#"{"v": 1, "kind": "stats"}"#).unwrap();
        assert_eq!(status, 200);
        shutdown(&socket);
        handle.join().unwrap().unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // unix socket I/O (unsupported under Miri)
    fn double_bind_is_a_typed_error_and_stale_sockets_are_reclaimed() {
        let (socket, handle) = start("bind");
        let err = serve_on(
            &socket,
            Arc::new(SessionRegistry::new()),
            Arc::new(AtomicBool::new(false)),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("already being served"));
        shutdown(&socket);
        handle.join().unwrap().unwrap();
        // A stale socket file (no listener behind it) is reclaimed.
        std::fs::write(&socket, b"").unwrap();
        let stop = Arc::new(AtomicBool::new(false));
        let reg = Arc::new(SessionRegistry::new());
        let s = socket.clone();
        let h = thread::spawn(move || serve_on(&s, reg, stop));
        for _ in 0..500 {
            if UnixStream::connect(&socket).is_ok() {
                break;
            }
            thread::sleep(Duration::from_millis(5));
        }
        shutdown(&socket);
        h.join().unwrap().unwrap();
    }
}
