//! The engine's resident state: loaded datasets and completed path
//! prefixes.
//!
//! A [`SessionRegistry`] outlives every connection. Datasets load once per
//! [`DatasetSpec`] (keyed by the spec's canonical JSON) and are served as
//! `Arc`s, so concurrent requests share one copy of `X` — including the
//! mmap backend, whose mapping is immutable shared memory
//! (`linalg::mmap::Store` is `Send + Sync`). Completed path prefixes are
//! cached under [`crate::server::api::SolveRequest::cache_key`] — dataset
//! identity plus every walk-shaping field, floats by bit pattern — so a
//! cache line is only ever shared between requests whose walks are
//! bitwise identical, and `solve-point` can answer from a resident prefix
//! without running a solver.
//!
//! Locking discipline: the two maps sit behind plain `Mutex`es held only
//! for lookups and inserts — loads and solves run outside any lock, so a
//! slow request never blocks the registry. Poisoned locks are recovered
//! (`PoisonError::into_inner`): a panicking request must not take the
//! cache down for every later client (both maps hold only fully
//! constructed values, inserted after the fallible work succeeded).

use super::api::{BackendKind, DatasetSpec};
use crate::bail;
use crate::coordinator::runner::PathStep;
use crate::data::io::MmapDataset;
use crate::data::registry::{resolve_dataset, resolve_sparse_dataset};
use crate::data::Dataset;
use crate::error::Result;
use crate::groups::GroupStructure;
use crate::linalg::{CscMatrix, DesignMatrix, ShardedMatrix};
use crate::util::json::Json;
use crate::util::race;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Loaded datasets
// ---------------------------------------------------------------------------

/// A dataset behind the CSC or sharded backend (converted from the dense
/// generator output; `sparse1` is CSC-native).
pub struct BackedData<M> {
    pub name: String,
    pub x: M,
    pub y: Vec<f32>,
    pub groups: GroupStructure,
}

/// An mmap-backed dataset plus the temp file backing it when the engine
/// generated (rather than was handed) the file. The mapping stays valid
/// after the unlink in `Drop` — unix keeps the inode alive until unmapped.
pub struct MmapData {
    pub ds: MmapDataset,
    pub(crate) temp_path: Option<PathBuf>,
}

impl Drop for MmapData {
    fn drop(&mut self) {
        if let Some(p) = &self.temp_path {
            let _ = std::fs::remove_file(p);
        }
    }
}

/// One resident dataset, in whichever backend the spec asked for.
pub enum LoadedData {
    Dense(Dataset),
    Csc(BackedData<CscMatrix>),
    Mmap(MmapData),
    Sharded(BackedData<ShardedMatrix>),
}

/// Monotonic suffix so concurrent loads of the same spec never share a
/// temp file (each loser cleans up only its own).
static TEMP_SEQ: AtomicUsize = AtomicUsize::new(0);

impl LoadedData {
    /// Materialize the dataset a spec describes. Deterministic in the
    /// spec: equal specs produce bitwise-equal data on every call.
    pub fn load(spec: &DatasetSpec) -> Result<LoadedData> {
        if spec.name == "sparse1" || spec.name == "sparse" {
            let ds = resolve_sparse_dataset(spec.seed, spec.scale, spec.density);
            return match spec.backend {
                BackendKind::Csc => Ok(LoadedData::Csc(BackedData {
                    name: ds.name,
                    x: ds.x,
                    y: ds.y,
                    groups: ds.groups,
                })),
                BackendKind::Dense => Ok(LoadedData::Dense(Dataset {
                    name: ds.name,
                    x: ds.x.to_dense(),
                    y: ds.y,
                    groups: ds.groups,
                    beta_star: Some(ds.beta_star),
                })),
                other => {
                    bail!("sparse1 supports backend dense|csc, got '{}'", other.as_str())
                }
            };
        }
        match spec.backend {
            BackendKind::Dense => {
                Ok(LoadedData::Dense(resolve_dataset(&spec.name, spec.seed, spec.scale)?))
            }
            BackendKind::Csc => {
                let ds = resolve_dataset(&spec.name, spec.seed, spec.scale)?;
                Ok(LoadedData::Csc(BackedData {
                    name: ds.name,
                    x: CscMatrix::from_dense(&ds.x),
                    y: ds.y,
                    groups: ds.groups,
                }))
            }
            BackendKind::Sharded => {
                let ds = resolve_dataset(&spec.name, spec.seed, spec.scale)?;
                let k = spec.shards.unwrap_or_else(crate::util::pool::num_threads).max(1);
                Ok(LoadedData::Sharded(BackedData {
                    name: ds.name,
                    x: ShardedMatrix::from_dense(&ds.x, k),
                    y: ds.y,
                    groups: ds.groups,
                }))
            }
            BackendKind::Mmap => {
                let (path, temp) = match &spec.file {
                    Some(f) => (PathBuf::from(f), false),
                    None => {
                        let ds = resolve_dataset(&spec.name, spec.seed, spec.scale)?;
                        let path = std::env::temp_dir().join(format!(
                            "tlfre-serve-{}-{}-{}.bin",
                            std::process::id(),
                            TEMP_SEQ.fetch_add(1, Ordering::Relaxed),
                            spec.name
                        ));
                        crate::data::io::save(&ds, &path)?;
                        (path, true)
                    }
                };
                let ds = crate::data::io::open_mmap(&path)?;
                Ok(LoadedData::Mmap(MmapData { ds, temp_path: temp.then_some(path) }))
            }
        }
    }

    pub fn name(&self) -> &str {
        match self {
            LoadedData::Dense(d) => &d.name,
            LoadedData::Csc(d) => &d.name,
            LoadedData::Mmap(d) => &d.ds.name,
            LoadedData::Sharded(d) => &d.name,
        }
    }

    pub fn y(&self) -> &[f32] {
        match self {
            LoadedData::Dense(d) => &d.y,
            LoadedData::Csc(d) => &d.y,
            LoadedData::Mmap(d) => &d.ds.y,
            LoadedData::Sharded(d) => &d.y,
        }
    }

    pub fn groups(&self) -> &GroupStructure {
        match self {
            LoadedData::Dense(d) => &d.groups,
            LoadedData::Csc(d) => &d.groups,
            LoadedData::Mmap(d) => &d.ds.groups,
            LoadedData::Sharded(d) => &d.groups,
        }
    }

    pub fn backend(&self) -> BackendKind {
        match self {
            LoadedData::Dense(_) => BackendKind::Dense,
            LoadedData::Csc(_) => BackendKind::Csc,
            LoadedData::Mmap(_) => BackendKind::Mmap,
            LoadedData::Sharded(_) => BackendKind::Sharded,
        }
    }

    pub fn n(&self) -> usize {
        match self {
            LoadedData::Dense(d) => d.x.rows(),
            LoadedData::Csc(d) => d.x.rows(),
            LoadedData::Mmap(d) => d.ds.x.rows(),
            LoadedData::Sharded(d) => d.x.rows(),
        }
    }

    pub fn p(&self) -> usize {
        match self {
            LoadedData::Dense(d) => d.x.cols(),
            LoadedData::Csc(d) => d.x.cols(),
            LoadedData::Mmap(d) => d.ds.x.cols(),
            LoadedData::Sharded(d) => d.x.cols(),
        }
    }

    /// One stable description line for responses and logs.
    pub fn describe(&self) -> String {
        format!(
            "{}: {}×{} ({} groups) [{}]",
            self.name(),
            self.n(),
            self.p(),
            self.groups().n_groups(),
            self.backend().as_str()
        )
    }
}

// ---------------------------------------------------------------------------
// Cached path prefixes
// ---------------------------------------------------------------------------

/// A completed prefix of one path walk: per-λ step records and dense
/// coefficient vectors, exactly as the driver streamed them. Because a
/// prefix of `drive`'s walk is bitwise identical to the same prefix of
/// the full walk, serving entry `i` from this cache is bitwise identical
/// to re-solving grid points `0..=i` from scratch.
pub struct CachedPath {
    pub lambda_max: f64,
    /// The full resolved grid (even when only a prefix was walked).
    pub grid: Vec<f64>,
    pub steps: Vec<PathStep>,
    pub betas: Vec<Vec<f32>>,
    pub screen_total_s: f64,
    pub solve_total_s: f64,
    /// True when the walk covered the whole grid — neither a
    /// `solve-point` prefix cut nor the wall-clock budget stopped it.
    pub complete: bool,
}

impl CachedPath {
    /// Whether grid index `idx` is inside the cached prefix.
    pub fn covers(&self, idx: usize) -> bool {
        idx < self.steps.len()
    }
}

// ---------------------------------------------------------------------------
// The registry
// ---------------------------------------------------------------------------

/// Engine counters reported by the `stats` request.
#[derive(Default)]
pub struct RegistryStats {
    pub requests: AtomicUsize,
    pub errors: AtomicUsize,
    pub paths_solved: AtomicUsize,
    pub cache_hits: AtomicUsize,
    pub cache_misses: AtomicUsize,
}

/// Lock names fed to the `race-check` lock-order table; every
/// acquisition of a registry mutex goes through [`SessionRegistry::lock`]
/// with one of these.
const DATASETS_LOCK: &str = "registry.datasets";
const PATHS_LOCK: &str = "registry.paths";

/// The resident session state shared by every connection thread.
pub struct SessionRegistry {
    datasets: Mutex<HashMap<String, Arc<LoadedData>>>,
    paths: Mutex<HashMap<String, Arc<CachedPath>>>,
    pub stats: RegistryStats,
    started: Instant,
}

impl Default for SessionRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl SessionRegistry {
    pub fn new() -> SessionRegistry {
        SessionRegistry {
            datasets: Mutex::new(HashMap::new()),
            paths: Mutex::new(HashMap::new()),
            stats: RegistryStats::default(),
            started: Instant::now(),
        }
    }

    /// Lock with poison recovery: a connection thread that panicked while
    /// holding the lock left a fully consistent map (values are inserted
    /// whole), so later requests keep working. The guard is wrapped in a
    /// named [`race::OrderedGuard`]: under `--features race-check` every
    /// acquisition feeds the global lock-order table, so a future code
    /// path that nests these locks in contradictory orders panics naming
    /// both locks instead of deadlocking some unlucky pair of requests.
    #[track_caller]
    fn lock<'a, T>(name: &'static str, m: &'a Mutex<T>) -> race::OrderedGuard<'a, T> {
        race::track_guard(name, m.lock().unwrap_or_else(PoisonError::into_inner))
    }

    /// Snapshot a keyed cache map as `(key, value)` pairs **sorted by
    /// key**. `HashMap` iteration order varies per map instance, and
    /// everything rendered from these maps (the `stats` arrays) must be
    /// byte-identical across equal registries — so this is the only place
    /// allowed to iterate them (invariant-lint `hash-iteration`
    /// allowlist), and it sorts before anything downstream can observe
    /// the order.
    fn sorted_entries<V: Clone>(
        name: &'static str,
        m: &Mutex<HashMap<String, V>>,
    ) -> Vec<(String, V)> {
        let mut v: Vec<(String, V)> =
            Self::lock(name, m).iter().map(|(k, x)| (k.clone(), x.clone())).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// The resident dataset for `spec`, loading it on first use. The load
    /// runs outside the lock; when two requests race, the first insert
    /// wins and the duplicate is dropped (generation is deterministic, so
    /// both copies are bitwise identical).
    pub fn dataset(&self, spec: &DatasetSpec) -> Result<Arc<LoadedData>> {
        let key = spec.key();
        if let Some(d) = Self::lock(DATASETS_LOCK, &self.datasets).get(&key) {
            return Ok(d.clone());
        }
        let loaded = Arc::new(LoadedData::load(spec)?);
        let mut map = Self::lock(DATASETS_LOCK, &self.datasets);
        Ok(map.entry(key).or_insert(loaded).clone())
    }

    /// The cached path prefix for a request's cache key, if any.
    pub fn cached_path(&self, key: &str) -> Option<Arc<CachedPath>> {
        Self::lock(PATHS_LOCK, &self.paths).get(key).cloned()
    }

    /// Insert a walked prefix. A shorter prefix never clobbers a longer
    /// resident one, so concurrent point/path requests can only grow the
    /// cache line (and every entry of equal index is bitwise identical
    /// regardless of which request produced it).
    pub fn store_path(&self, key: String, path: Arc<CachedPath>) {
        let mut map = Self::lock(PATHS_LOCK, &self.paths);
        match map.get(&key) {
            Some(old) if old.steps.len() >= path.steps.len() => {}
            _ => {
                map.insert(key, path);
            }
        }
    }

    /// Counters and resident-state summary for the `stats` request. The
    /// `datasets` / `cached_paths` arrays are rendered in registry-key
    /// order, so two registries holding equal content serialize them
    /// byte-identically no matter what order requests arrived in (or how
    /// each `HashMap` instance hashed its keys).
    pub fn stats_json(&self) -> Json {
        let dataset_snapshot = Self::sorted_entries(DATASETS_LOCK, &self.datasets);
        let dataset_arr: Vec<Json> = dataset_snapshot
            .into_iter()
            .map(|(_, d)| {
                Json::obj()
                    .set("describe", d.describe())
                    .set("n", d.n())
                    .set("p", d.p())
                    .set("backend", d.backend().as_str())
            })
            .collect();
        let path_snapshot = Self::sorted_entries(PATHS_LOCK, &self.paths);
        let path_arr: Vec<Json> = path_snapshot
            .into_iter()
            .map(|(_, p)| {
                Json::obj()
                    .set("steps_cached", p.steps.len())
                    .set("grid_len", p.grid.len())
                    .set("complete", p.complete)
            })
            .collect();
        let s = &self.stats;
        Json::obj()
            .set("uptime_s", self.started.elapsed().as_secs_f64())
            .set("requests", s.requests.load(Ordering::Relaxed))
            .set("errors", s.errors.load(Ordering::Relaxed))
            .set("paths_solved", s.paths_solved.load(Ordering::Relaxed))
            .set("cache_hits", s.cache_hits.load(Ordering::Relaxed))
            .set("cache_misses", s.cache_misses.load(Ordering::Relaxed))
            .set("datasets", dataset_arr)
            .set("cached_paths", path_arr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec(backend: BackendKind) -> DatasetSpec {
        let mut spec = DatasetSpec::new("synthetic1");
        spec.backend = backend;
        spec.scale = 0.01;
        spec
    }

    #[test]
    fn dataset_loads_once_and_is_shared() {
        let reg = SessionRegistry::new();
        let a = reg.dataset(&small_spec(BackendKind::Dense)).unwrap();
        let b = reg.dataset(&small_spec(BackendKind::Dense)).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the resident copy");
        assert_eq!(a.n(), 250);
        assert_eq!(a.p(), 100);
        // A different backend is a different registry entry.
        let c = reg.dataset(&small_spec(BackendKind::Csc)).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(c.backend(), BackendKind::Csc);
        assert_eq!((c.n(), c.p()), (a.n(), a.p()));
    }

    #[test]
    fn every_backend_loads_with_matching_dims() {
        for backend in
            [BackendKind::Dense, BackendKind::Csc, BackendKind::Mmap, BackendKind::Sharded]
        {
            let d = LoadedData::load(&small_spec(backend)).unwrap();
            assert_eq!(d.backend(), backend);
            assert_eq!((d.n(), d.p()), (250, 100), "{}", backend.as_str());
            assert_eq!(d.y().len(), 250);
            assert_eq!(d.groups().n_groups(), 10);
            assert!(d.describe().contains(backend.as_str()));
        }
    }

    #[test]
    fn generated_mmap_backing_file_is_cleaned_up_on_drop() {
        let d = LoadedData::load(&small_spec(BackendKind::Mmap)).unwrap();
        let path = match &d {
            LoadedData::Mmap(m) => m.temp_path.clone().expect("generated file is temp"),
            _ => unreachable!(),
        };
        assert!(path.exists());
        drop(d);
        assert!(!path.exists(), "temp backing file must be removed with the dataset");
    }

    #[test]
    fn sparse_dataset_loads_dense_and_csc_only() {
        let mut spec = DatasetSpec::new("sparse1");
        spec.scale = 0.01;
        spec.backend = BackendKind::Csc;
        let c = LoadedData::load(&spec).unwrap();
        spec.backend = BackendKind::Dense;
        let d = LoadedData::load(&spec).unwrap();
        assert_eq!((c.n(), c.p()), (d.n(), d.p()));
        spec.backend = BackendKind::Mmap;
        assert!(LoadedData::load(&spec).is_err());
    }

    #[test]
    fn shorter_prefix_never_clobbers_longer() {
        let reg = SessionRegistry::new();
        let mk = |steps: usize| {
            Arc::new(CachedPath {
                lambda_max: 1.0,
                grid: vec![1.0; 10],
                steps: vec![Default::default(); steps],
                betas: vec![vec![0.0]; steps],
                screen_total_s: 0.0,
                solve_total_s: 0.0,
                complete: false,
            })
        };
        reg.store_path("k".into(), mk(5));
        reg.store_path("k".into(), mk(3));
        assert_eq!(reg.cached_path("k").unwrap().steps.len(), 5);
        reg.store_path("k".into(), mk(8));
        assert_eq!(reg.cached_path("k").unwrap().steps.len(), 8);
        assert!(reg.cached_path("k").unwrap().covers(7));
        assert!(!reg.cached_path("k").unwrap().covers(8));
        assert!(reg.cached_path("other").is_none());
    }

    #[test]
    fn stats_arrays_are_byte_identical_across_equal_registries() {
        // Two registries, same cached content inserted in opposite orders:
        // separate `HashMap` instances hash differently (per-instance
        // RandomState) and would render in different orders — the stats
        // arrays must come out byte-identical anyway (key-sorted).
        let mk = |steps: usize| {
            Arc::new(CachedPath {
                lambda_max: 1.0,
                grid: vec![1.0; 16],
                steps: vec![Default::default(); steps],
                betas: vec![vec![0.0]; steps],
                screen_total_s: 0.0,
                solve_total_s: 0.0,
                complete: false,
            })
        };
        let keys: Vec<String> = (0..8).map(|i| format!("key-{i}")).collect();
        let a = SessionRegistry::new();
        let b = SessionRegistry::new();
        for (i, k) in keys.iter().enumerate() {
            a.store_path(k.clone(), mk(i + 1));
        }
        for (i, k) in keys.iter().enumerate().rev() {
            b.store_path(k.clone(), mk(i + 1));
        }
        let render = |reg: &SessionRegistry| {
            let stats = reg.stats_json();
            stats.get("cached_paths").expect("stats has cached_paths").to_string_compact()
        };
        let ra = render(&a);
        assert_eq!(ra, render(&b), "stats arrays must not depend on insertion order");
        // Repeated requests against one registry are byte-identical too.
        assert_eq!(ra, render(&a));
        // And the order is the sorted key order: steps_cached 1..=8 ascending.
        let arr = a.stats_json();
        let arr = arr.get("cached_paths").unwrap().as_arr().unwrap().to_vec();
        let steps: Vec<usize> =
            arr.iter().map(|j| j.get("steps_cached").unwrap().as_usize().unwrap()).collect();
        assert_eq!(steps, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn poisoned_locks_recover() {
        let reg = Arc::new(SessionRegistry::new());
        let r2 = reg.clone();
        // Panic while holding the paths lock: later callers must still
        // get through (no permanent cache poisoning).
        let _ = std::thread::spawn(move || {
            let _guard = r2.paths.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(reg.cached_path("k").is_none());
        reg.store_path(
            "k".into(),
            Arc::new(CachedPath {
                lambda_max: 1.0,
                grid: vec![1.0],
                steps: vec![Default::default()],
                betas: vec![vec![0.0]],
                screen_total_s: 0.0,
                solve_total_s: 0.0,
                complete: true,
            }),
        );
        assert!(reg.cached_path("k").is_some());
    }
}
