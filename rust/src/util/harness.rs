//! Micro/macro benchmark harness.
//!
//! `criterion` is not in the offline crate set, so the `rust/benches/*`
//! binaries (declared with `harness = false`) use this module: warmup,
//! repeated timed runs, black-box value sinking, and aligned table output
//! matching the paper's row format.

use super::stats::Summary;
use super::Timer;

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Benchmark configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Number of warmup runs (not recorded).
    pub warmup: usize,
    /// Number of measured runs.
    pub runs: usize,
    /// Optional cap on total measurement wall time (seconds); measurement
    /// stops early (but after ≥1 run) when exceeded.
    pub max_seconds: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup: 1, runs: 5, max_seconds: 120.0 }
    }
}

/// One benchmarked quantity: a label and its timing summary (seconds).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub label: String,
    pub seconds: Summary,
}

/// Time `f` under `cfg`, returning a [`BenchResult`].
pub fn bench<F: FnMut()>(label: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.runs);
    let wall = Timer::start();
    for _ in 0..cfg.runs {
        let t = Timer::start();
        f();
        samples.push(t.elapsed_s());
        if wall.elapsed_s() > cfg.max_seconds && !samples.is_empty() {
            break;
        }
    }
    BenchResult { label: label.to_string(), seconds: Summary::of(&samples) }
}

/// Time a single execution of `f` (for long end-to-end paths where repeats
/// are too expensive); still returns a `Summary` with `n = 1`.
pub fn bench_once<F: FnOnce()>(label: &str, f: F) -> BenchResult {
    let t = Timer::start();
    f();
    BenchResult { label: label.to_string(), seconds: Summary::of(&[t.elapsed_s()]) }
}

// ---------------------------------------------------------------------------
// Table rendering

/// A simple aligned text table, used to print the paper's tables.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    /// Render with column alignment (first column left, rest right).
    pub fn render(&self) -> String {
        let ncol = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.header.len()))
            .max()
            .unwrap_or(0);
        let mut widths = vec![0usize; ncol];
        let all = std::iter::once(&self.header).chain(self.rows.iter());
        for row in all {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |row: &[String], out: &mut String| {
            for (i, c) in row.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                let pad = widths[i].saturating_sub(c.chars().count());
                if i == 0 {
                    out.push_str(c);
                    out.push_str(&" ".repeat(pad));
                } else {
                    out.push_str(&" ".repeat(pad));
                    out.push_str(c);
                }
            }
            out.push('\n');
        };
        fmt_row(&self.header, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol.saturating_sub(1));
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }
}

/// Format seconds for table cells (matches the paper's 2-decimal style).
pub fn cell_secs(s: f64) -> String {
    format!("{:.2}", s)
}

/// Format a speedup ratio for table cells.
pub fn cell_speedup(s: f64) -> String {
    format!("{:.2}", s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let cfg = BenchConfig { warmup: 1, runs: 3, max_seconds: 10.0 };
        let r = bench("spin", &cfg, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert_eq!(r.seconds.n, 3);
        assert!(r.seconds.min >= 0.0);
        assert!(r.seconds.mean > 0.0);
    }

    #[test]
    fn bench_once_records_one_sample() {
        let r = bench_once("one", || {
            black_box(42);
        });
        assert_eq!(r.seconds.n, 1);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "a", "bb"]);
        t.row(vec!["x".into(), "1.00".into(), "2.00".into()]);
        t.row(vec!["longer".into(), "10.00".into(), "3.50".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // all data lines same width
        assert_eq!(lines[2].len(), lines[3].len());
        assert!(lines[0].starts_with("name"));
    }

    #[test]
    fn max_seconds_stops_early() {
        let cfg = BenchConfig { warmup: 0, runs: 1000, max_seconds: 0.05 };
        let r = bench("sleepy", &cfg, || std::thread::sleep(std::time::Duration::from_millis(20)));
        assert!(r.seconds.n < 1000);
        assert!(r.seconds.n >= 1);
    }
}
