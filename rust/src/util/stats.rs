//! Descriptive statistics over measurement samples.
//!
//! Backs the benchmark harness ([`crate::util::harness`]) and the experiment
//! reports: every timing row in the reproduced tables is a [`Summary`] over
//! repeated runs.

/// Summary statistics of a sample set.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub median: f64,
    pub p95: f64,
    pub max: f64,
    pub total: f64,
}

impl Summary {
    /// Compute a summary; returns a zeroed summary for an empty sample.
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, median: 0.0, p95: 0.0, max: 0.0, total: 0.0 };
        }
        let n = samples.len();
        let total: f64 = samples.iter().sum();
        let mean = total / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            median: percentile_sorted(&sorted, 0.5),
            p95: percentile_sorted(&sorted, 0.95),
            max: sorted[n - 1],
            total,
        }
    }

    /// Coefficient of variation (std / mean); 0 for degenerate samples.
    pub fn cv(&self) -> f64 {
        if self.mean.abs() < f64::EPSILON {
            0.0
        } else {
            self.std / self.mean
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Online mean/min/max accumulator (used by long-running path drivers to
/// avoid storing every per-λ timing).
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    pub n: usize,
    pub sum: f64,
    pub sum_sq: f64,
    pub min: f64,
    pub max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Accumulator { n: 0, sum: 0.0, sum_sq: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sum_sq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        ((self.sum_sq / self.n as f64 - m * m).max(0.0) * self.n as f64 / (self.n - 1) as f64).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert!((s.total - 15.0).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_and_single() {
        let e = Summary::of(&[]);
        assert_eq!(e.n, 0);
        let s = Summary::of(&[7.0]);
        assert_eq!(s.n, 1);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn accumulator_matches_summary() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut a = Accumulator::new();
        for &x in &xs {
            a.push(x);
        }
        let s = Summary::of(&xs);
        assert_eq!(a.n, s.n);
        assert!((a.mean() - s.mean).abs() < 1e-12);
        assert!((a.std() - s.std).abs() < 1e-9);
        assert_eq!(a.min, s.min);
        assert_eq!(a.max, s.max);
    }
}
