//! Deterministic pseudo-random number generation.
//!
//! `xoshiro256++` seeded via SplitMix64 — the same generator family used by
//! `rand_xoshiro`, reimplemented here because the offline crate set ships no
//! `rand`. Every experiment in this repository is seeded, so data sets are
//! bit-reproducible across runs and machines.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second gaussian from Box–Muller.
    gauss_spare: Option<f64>,
}

#[inline(always)]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64 — used to expand a single `u64` seed into the xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Construct from a 64-bit seed (SplitMix64-expanded).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply trick; bias is negligible (< 2^-64 * n).
        let m = (self.next_u64() as u128).wrapping_mul(n as u128);
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let t = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * t.sin());
            return r * t.cos();
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.gaussian()
    }

    /// Fill a slice with standard gaussians (f32).
    pub fn fill_gaussian_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.gaussian() as f32;
        }
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k ≤ n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher–Yates: only the first k positions need settling.
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self) -> Rng {
        Rng::seed_from_u64(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::seed_from_u64(7);
        let mut b = Rng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seed_from_u64(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::seed_from_u64(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::seed_from_u64(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::seed_from_u64(6);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(8);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from_u64(9);
        let s = r.sample_indices(100, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(d.iter().all(|&i| i < 100));
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::seed_from_u64(10);
        let mut a = base.fork();
        let mut b = base.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
