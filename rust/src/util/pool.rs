//! Scoped-thread parallelism.
//!
//! `rayon` is unavailable offline; this provides chunked parallel primitives
//! built on `std::thread::scope`. On a single-core box every entry point
//! degrades to a serial loop with zero thread overhead; on multi-core boxes
//! the linalg backends use [`parallel_fill`] to scale the dominant `Xᵀv`
//! sweep and the coordinator uses [`parallel_map`] for independent α-paths.
//!
//! Worker count comes from `TLFRE_THREADS` (default: available parallelism).

/// Number of worker threads to use (respects `TLFRE_THREADS`, defaults to
/// available parallelism). Resolved once per process and cached —
/// `parallel_fill` sits on the solvers' per-iteration sweep path, where an
/// env-map read plus an `available_parallelism` syscall per call would be
/// measurable; changing `TLFRE_THREADS` mid-process therefore has no effect.
pub fn num_threads() -> usize {
    static THREADS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *THREADS.get_or_init(|| {
        if let Ok(v) = std::env::var("TLFRE_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Run `f(chunk_index, start, end)` over `n` items split into contiguous
/// chunks, one per worker. `f` must be `Sync` (called from multiple threads).
pub fn parallel_for_chunks<F>(n: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n == 0 {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(w, start, end));
        }
    });
}

/// Fill `out[i] = f(i)` in parallel over contiguous chunks.
///
/// This is the hot-sweep primitive: the `DesignMatrix::matvec_t` default
/// implementation calls it with `f = |j| x_jᵀv`. Entirely safe — each worker
/// receives a disjoint `&mut` sub-slice via `chunks_mut`.
pub fn parallel_fill<U, F>(out: &mut [U], f: F)
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    let n = out.len();
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n == 0 {
        for (i, o) in out.iter_mut().enumerate() {
            *o = f(i);
        }
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for (w, slice) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                let base = w * chunk;
                for (k, o) in slice.iter_mut().enumerate() {
                    *o = f(base + k);
                }
            });
        }
    });
}

/// Map a function over items in parallel, preserving order.
///
/// Results are collected per worker chunk and concatenated, so `U` needs no
/// `Default + Clone` bound (and no placeholder zero-fill pass happens).
pub fn parallel_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n == 0 {
        return items.iter().map(f).collect();
    }
    let chunk = n.div_ceil(workers);
    let parts: Vec<Vec<U>> = std::thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks(chunk)
            .map(|part| {
                let f = &f;
                s.spawn(move || part.iter().map(f).collect::<Vec<U>>())
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("parallel_map worker panicked")).collect()
    });
    let mut out = Vec::with_capacity(n);
    for p in parts {
        out.extend(p);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::num::NonZeroUsize;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_all_indices_once() {
        let n = 1000;
        let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(n, |_, s, e| {
            for i in s..e {
                counts[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let xs: Vec<usize> = (0..257).collect();
        let ys = parallel_map(&xs, |&x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_non_default_type() {
        // NonZeroUsize has no Default impl — the old bound rejected this.
        let xs: Vec<usize> = (0..100).collect();
        let ys = parallel_map(&xs, |&x| NonZeroUsize::new(x + 1).unwrap());
        assert_eq!(ys.len(), 100);
        assert_eq!(ys[41].get(), 42);
    }

    #[test]
    fn parallel_fill_matches_serial() {
        let mut out = vec![0usize; 513];
        parallel_fill(&mut out, |i| i * i);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * i);
        }
        // empty slice is fine
        let mut empty: Vec<usize> = Vec::new();
        parallel_fill(&mut empty, |i| i);
    }

    #[test]
    fn zero_items_ok() {
        parallel_for_chunks(0, |_, s, e| assert_eq!(s, e));
        let ys: Vec<usize> = parallel_map(&Vec::<usize>::new(), |&x| x);
        assert!(ys.is_empty());
    }

    #[test]
    fn num_threads_at_least_one() {
        assert!(num_threads() >= 1);
    }
}
