//! Spawn-free parallelism: a persistent, parked worker pool.
//!
//! `rayon` is unavailable offline; this provides chunked parallel primitives
//! built on a **process-lifetime worker pool** instead of the former
//! per-call `std::thread::scope`. The scoped design paid a thread
//! spawn+join (tens of microseconds) on *every* dispatch — and the hot
//! caller, [`crate::linalg::DesignMatrix::matvec_t`], dispatches once per
//! FISTA/BCD iteration, so the spawn tax was paid thousands of times per
//! solve. The persistent pool pays it once per process.
//!
//! ## Lifecycle
//!
//! * Workers are spawned **lazily** on the first parallel dispatch —
//!   `num_threads() − 1` of them (the dispatching thread always executes
//!   chunk 0 itself, so total concurrency equals `num_threads()`).
//! * Between dispatches the workers are **parked** in a blocking channel
//!   `recv` — zero CPU while idle.
//! * Workers live for the remainder of the process; there is no shutdown
//!   (the pool is a `'static` singleton, and the OS reclaims the threads
//!   at exit).
//!
//! ## Worker count: `TLFRE_THREADS`
//!
//! Worker count comes from `TLFRE_THREADS` (default: available
//! parallelism), resolved once per process and cached. `TLFRE_THREADS=1`
//! disables the pool entirely — every entry point degrades to a serial
//! loop with zero thread overhead and no worker is ever spawned.
//!
//! ## Determinism guarantee
//!
//! Chunk boundaries are computed exactly as the scoped implementation
//! computed them (`chunk = n.div_ceil(workers)`, worker `w` owns
//! `[w·chunk, min((w+1)·chunk, n))`), and every chunk writes a disjoint
//! output region from independent inputs — so results are **bitwise
//! identical** to the serial loop and to the old per-call-scope
//! implementation for every worker count. `tests/backend_parity.rs`
//! enforces this for the `matvec_t` sweep at multiple worker counts;
//! [`scoped_fill_with_workers`] is kept as the legacy reference
//! implementation for those tests and for the before/after bench in
//! `benches/perf_kernels.rs`.
//!
//! ## Nesting
//!
//! A dispatch issued *from a pool worker* (e.g. a `matvec_t` inside a task
//! that itself runs on the pool) falls back to the serial loop instead of
//! re-entering the pool — identical results, and no possibility of the
//! pool waiting on itself. The same rule applies to the dispatching
//! thread's **own chunk**: while a round is in flight, the caller executes
//! chunk 0 flagged as a worker, so nested dispatches from inside it also
//! degrade to serial loops instead of queuing behind the busy workers the
//! round is waiting on (load-bearing for coarse-grained sharding like
//! fold-parallel CV, where the caller's chunk is itself a whole path task).

use crate::util::race;
use std::any::Any;
use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};

/// Number of worker threads to use (respects `TLFRE_THREADS`, defaults to
/// available parallelism). Resolved once per process and cached —
/// `parallel_fill` sits on the solvers' per-iteration sweep path, where an
/// env-map read plus an `available_parallelism` syscall per call would be
/// measurable; changing `TLFRE_THREADS` mid-process therefore has no effect.
pub fn num_threads() -> usize {
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS.get_or_init(|| {
        if let Ok(v) = std::env::var("TLFRE_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// A unit of work shipped to a pool worker. The `'static` bound is a lie
/// told through [`erase`]: tasks borrow the dispatcher's stack, and the
/// dispatch functions below block on the round's latch before returning,
/// which is what makes the lie sound.
type Task = Box<dyn FnOnce() + Send + 'static>;

/// Erase a task's borrow lifetimes so it can cross the channel.
///
/// # Safety
///
/// The caller must not return (or otherwise invalidate the task's borrows)
/// until the task has finished executing — in this module, every dispatcher
/// blocks on [`Round::wait`] before its borrowed data goes out of scope.
unsafe fn erase<'a>(task: Box<dyn FnOnce() + Send + 'a>) -> Task {
    // SAFETY: only the lifetime is transmuted away (same layout either
    // side); the caller upholds the contract above — the borrows stay
    // live because every dispatcher blocks on the round's latch.
    unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + 'a>, Task>(task) }
}

/// Count-down latch for one dispatch round, carrying any worker panic back
/// to the dispatcher.
struct Round {
    remaining: Mutex<usize>,
    done: Condvar,
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl Round {
    fn new(count: usize) -> Arc<Round> {
        Arc::new(Round {
            remaining: Mutex::new(count),
            done: Condvar::new(),
            panic: Mutex::new(None),
        })
    }

    /// Record one finished task (with its panic payload, if it panicked).
    fn finish_one(&self, panicked: Option<Box<dyn Any + Send>>) {
        if let Some(p) = panicked {
            *self.panic.lock().unwrap() = Some(p);
        }
        let mut left = self.remaining.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.done.notify_all();
        }
    }

    /// Block until every task in the round has finished.
    fn wait(&self) {
        let mut left = self.remaining.lock().unwrap();
        while *left > 0 {
            left = self.done.wait(left).unwrap();
        }
    }

    fn take_panic(&self) -> Option<Box<dyn Any + Send>> {
        self.panic.lock().unwrap().take()
    }
}

/// The process-wide pool: one task channel per persistent worker. Senders
/// are wrapped in a `Mutex` so concurrent dispatchers (e.g. parallel CV
/// folds each sweeping `matvec_t`) can share the pool; each round's latch
/// counts only its own tasks, so interleaved rounds never cross-talk.
struct Pool {
    senders: Vec<Mutex<mpsc::Sender<Task>>>,
}

impl Pool {
    /// Hand a task to a worker. **Never panics** — this is load-bearing for
    /// the lifetime-erasure safety contract: a panic between the first send
    /// of a round and its `wait` would unwind the dispatcher while workers
    /// still hold borrows into its stack. Sender-mutex poisoning is
    /// absorbed (`Sender` has no invariant a poisoned lock could break) and
    /// a closed channel (unreachable: workers never exit) degrades to
    /// running the task inline, which settles the round's latch correctly.
    fn send(&self, worker: usize, task: Task) {
        let slot = &self.senders[worker % self.senders.len()];
        let sender = match slot.lock() {
            Ok(guard) => guard,
            Err(poisoned) => poisoned.into_inner(),
        };
        if let Err(mpsc::SendError(task)) = sender.send(task) {
            drop(sender);
            task();
        }
    }
}

/// One dispatch round: ship `tasks` (chunks 1..) to the pool workers, run
/// `own` (chunk 0) on the calling thread, block until every task finished,
/// then re-raise the first recorded panic. This is the **single** home of
/// the lifetime-erasure machinery shared by [`parallel_for_chunks`] and
/// [`parallel_fill_with_workers`].
fn dispatch_round<'a>(
    p: &'static Pool,
    tasks: Vec<Box<dyn FnOnce() + Send + 'a>>,
    own: impl FnOnce(),
) {
    let round = Round::new(tasks.len());
    for (i, task) in tasks.into_iter().enumerate() {
        let round_c = Arc::clone(&round);
        let wrapped: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
            let res = catch_unwind(AssertUnwindSafe(move || {
                // Fault-injection probe (constant false in normal builds):
                // the Nth dispatched task panics, exercising exactly the
                // propagation path a real task panic would take.
                if crate::util::fault::take_pool_panic() {
                    panic!("fault-inject: pool task panic");
                }
                task()
            }));
            round_c.finish_one(res.err());
        });
        // SAFETY: `round.wait()` below runs before this function returns,
        // and nothing on the path from here to it can unwind (`Pool::send`
        // is panic-free by construction; the own-chunk closure is caught),
        // so every borrow the task carries outlives its execution.
        p.send(i, unsafe { erase(wrapped) });
    }
    // The dispatcher's own chunk runs flagged like a pool worker: a
    // *nested* dispatch issued from inside `own` must degrade to the
    // serial loop rather than queue behind the very workers this round is
    // waiting on. Without this, a coarse-grained own-chunk task (e.g. a CV
    // fold-path on the caller's thread) that internally sweeps `matvec_t`
    // would enqueue fill-chunks behind multi-second tasks and stall in
    // their latch — a self-inflicted convoy, not a deadlock, but it
    // serializes the caller's share of the round. Serial nested execution
    // is bitwise identical by the module's determinism guarantee.
    let prev = IS_POOL_WORKER.get();
    IS_POOL_WORKER.set(true);
    let own_res = catch_unwind(AssertUnwindSafe(own));
    IS_POOL_WORKER.set(prev);
    round.wait();
    if let Some(payload) = round.take_panic() {
        resume_unwind(payload);
    }
    if let Err(payload) = own_res {
        resume_unwind(payload);
    }
}

thread_local! {
    /// Set on pool-worker threads; dispatches from a worker run serially.
    static IS_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

fn in_pool_worker() -> bool {
    IS_POOL_WORKER.get()
}

/// The lazily-initialized singleton. Spawns `num_threads() − 1` parked
/// workers on first use (zero if the process is single-threaded).
fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let spawn = num_threads().saturating_sub(1);
        let mut senders = Vec::with_capacity(spawn);
        for w in 0..spawn {
            let (tx, rx) = mpsc::channel::<Task>();
            std::thread::Builder::new()
                .name(format!("tlfre-pool-{w}"))
                .spawn(move || {
                    IS_POOL_WORKER.set(true);
                    // Tasks arrive pre-wrapped in catch_unwind; the loop
                    // itself cannot panic, so a worker never dies.
                    while let Ok(task) = rx.recv() {
                        task();
                    }
                })
                .expect("failed to spawn pool worker");
            senders.push(Mutex::new(tx));
        }
        Pool { senders }
    })
}

/// Run `f(chunk_index, start, end)` over `n` items split into contiguous
/// chunks, one per worker. `f` must be `Sync` (called from multiple threads).
pub fn parallel_for_chunks<F>(n: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n == 0 || in_pool_worker() {
        f(0, 0, n);
        return;
    }
    let p = pool();
    if p.senders.is_empty() {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    let n_chunks = n.div_ceil(chunk);
    let f_ref = &f;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (1..n_chunks)
        .map(|w| {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(n);
            Box::new(move || f_ref(w, start, end)) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    dispatch_round(p, tasks, || f(0, 0, chunk.min(n)));
}

/// Fill `out[i] = f(i)` in parallel over contiguous chunks.
///
/// This is the hot-sweep primitive: the `DesignMatrix::matvec_t` default
/// implementation calls it with `f = |j| x_jᵀv`, once per solver iteration.
/// Each chunk is a disjoint `&mut` sub-slice; the dispatching thread
/// executes chunk 0 while the persistent workers execute the rest, so the
/// per-call cost is one channel send per worker instead of a thread
/// spawn+join.
pub fn parallel_fill<U, F>(out: &mut [U], f: F)
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    parallel_fill_with_workers(out, num_threads(), f);
}

/// [`parallel_fill`] with an explicit chunking worker count.
///
/// Chunk boundaries are derived from `workers` exactly as the legacy scoped
/// implementation derived them, so results are bitwise identical to
/// [`scoped_fill_with_workers`] and to the serial loop for any `workers`.
/// Exposed for the parity tests and the dispatch-overhead bench; production
/// callers use [`parallel_fill`]. If `workers` exceeds the number of
/// persistent workers + 1, the extra chunks are queued round-robin — same
/// results, bounded concurrency.
pub fn parallel_fill_with_workers<U, F>(out: &mut [U], workers: usize, f: F)
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    let n = out.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n == 0 || in_pool_worker() {
        for (i, o) in out.iter_mut().enumerate() {
            *o = f(i);
        }
        return;
    }
    let p = pool();
    if p.senders.is_empty() {
        for (i, o) in out.iter_mut().enumerate() {
            *o = f(i);
        }
        return;
    }
    let chunk = n.div_ceil(workers);
    let mut chunks = out.chunks_mut(chunk).enumerate();
    let (_, first) = chunks.next().expect("n > 0");
    let f_ref = &f;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
        .map(|(w, slice)| {
            Box::new(move || {
                let base = w * chunk;
                for (k, o) in slice.iter_mut().enumerate() {
                    *o = f_ref(base + k);
                }
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    dispatch_round(p, tasks, || {
        for (k, o) in first.iter_mut().enumerate() {
            *o = f(k);
        }
    });
}

/// Split `out` into `workers` contiguous chunks and run
/// `f(chunk_start_index, chunk_slice)` on each, chunks 1.. on the pool and
/// chunk 0 on the calling thread.
///
/// This is the **row-blocked forward-sweep primitive**: the
/// `DesignMatrix::matvec` / `residual_matvec` / `residual` defaults call it
/// with `f = accumulate the β-weighted columns into this row range`. Each
/// chunk is a disjoint `&mut` sub-slice of the output, so there is no merge
/// step and no per-worker partial vector to reduce — and because the
/// accumulation inside a chunk visits columns in exactly the serial order,
/// the result is bitwise identical to the serial sweep for **every**
/// partition (the chunk boundaries only decide which thread owns a row,
/// never the order of additions into it).
///
/// The serial fallbacks (1 worker, empty slice, dispatch from inside a pool
/// worker) invoke `f(0, out)` once over the whole slice — callers must keep
/// `f` partition-agnostic, which every accumulation kernel here is.
pub fn parallel_chunks_mut<U, F>(out: &mut [U], workers: usize, f: F)
where
    U: Send,
    F: Fn(usize, &mut [U]) + Sync,
{
    let n = out.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n == 0 || in_pool_worker() {
        f(0, out);
        return;
    }
    let p = pool();
    if p.senders.is_empty() {
        f(0, out);
        return;
    }
    let chunk = n.div_ceil(workers);
    // Shadow-ownership claims (race-check builds only): each chunk claims
    // its index range at partition time, so a future partition-math bug
    // handing two workers overlapping rows panics naming both claims.
    let region_key = out.as_ptr() as usize;
    let _region = race::write_region(region_key);
    let mut chunks = out.chunks_mut(chunk).enumerate();
    let (_, first) = chunks.next().expect("n > 0");
    race::claim_range(region_key, 0, 0, first.len(), "pool::parallel_chunks_mut chunk 0");
    let f_ref = &f;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
        .map(|(w, slice)| {
            race::claim_range(
                region_key,
                w,
                w * chunk,
                w * chunk + slice.len(),
                "pool::parallel_chunks_mut pool chunk",
            );
            Box::new(move || f_ref(w * chunk, slice)) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    dispatch_round(p, tasks, || f(0, first));
}

/// [`parallel_chunks_mut`] with **caller-chosen chunk boundaries** instead
/// of an even split: `out` is cut at the interior split points in `bounds`
/// (strictly ascending, each in `(0, out.len())`) and `f(chunk_start,
/// chunk_slice)` runs on each piece — chunks 1.. on the pool, chunk 0 on the
/// calling thread.
///
/// This exists for storage-aligned sweeps: `ShardedMatrix` passes its shard
/// row offsets so each pool worker accumulates into exactly one shard's row
/// range and never splits a shard's `col_axpy_rows` across workers. The
/// determinism contract of [`parallel_chunks_mut`] carries over unchanged —
/// boundaries decide which thread owns a row, never the order of additions
/// into it — so results stay bitwise identical to the serial loop for every
/// boundary choice. Serial fallbacks (no pool, nested dispatch, empty
/// `bounds`) invoke `f(0, out)` once; `f` must stay partition-agnostic.
pub fn parallel_chunks_mut_at<U, F>(out: &mut [U], bounds: &[usize], f: F)
where
    U: Send,
    F: Fn(usize, &mut [U]) + Sync,
{
    let n = out.len();
    debug_assert!(
        bounds.windows(2).all(|w| w[0] < w[1])
            && bounds.first().map_or(true, |&b| b > 0)
            && bounds.last().map_or(true, |&b| b < n),
        "bounds must be strictly ascending interior split points"
    );
    if bounds.is_empty() || n == 0 || num_threads() <= 1 || in_pool_worker() {
        f(0, out);
        return;
    }
    let p = pool();
    if p.senders.is_empty() {
        f(0, out);
        return;
    }
    // Shadow-ownership claims (race-check builds only): caller-chosen
    // boundaries are exactly where a partition bug would slip in, so each
    // piece claims its range before any task runs.
    let region_key = out.as_ptr() as usize;
    let _region = race::write_region(region_key);
    let mut pieces: Vec<(usize, &mut [U])> = Vec::with_capacity(bounds.len() + 1);
    let mut rest = out;
    let mut start = 0;
    for &b in bounds {
        let (head, tail) = rest.split_at_mut(b - start);
        pieces.push((start, head));
        start = b;
        rest = tail;
    }
    pieces.push((start, rest));
    for (w, (s, slice)) in pieces.iter().enumerate() {
        race::claim_range(
            region_key,
            w,
            *s,
            *s + slice.len(),
            "pool::parallel_chunks_mut_at piece",
        );
    }
    let mut pieces = pieces.into_iter();
    let (_, first) = pieces.next().expect("bounds nonempty ⇒ ≥ 2 pieces");
    let f_ref = &f;
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = pieces
        .map(|(s, slice)| Box::new(move || f_ref(s, slice)) as Box<dyn FnOnce() + Send + '_>)
        .collect();
    dispatch_round(p, tasks, || f(0, first));
}

/// Map a function over items **on the persistent pool**, preserving order,
/// with an explicit chunking worker count.
///
/// This is the coarse-grained sharding primitive behind fold-parallel
/// cross-validation: each item is a whole screened path (milliseconds to
/// seconds), chunked contiguously over the pool exactly like
/// [`parallel_chunks_mut`] chunks a row range. Three properties matter to
/// its callers:
///
/// * **Order-preserving**: `out[i] = f(&items[i])` for every `i`, whatever
///   the worker count — so a caller that folds the results in index order
///   gets the same floating-point accumulation order as a serial loop, and
///   therefore bitwise identical output.
/// * **Nesting degrades serial**: a task that itself dispatches
///   fine-grained sweeps (`matvec_t` etc.) from a pool worker runs those
///   sweeps serially (the pool never waits on itself). That is the right
///   trade for CV: with `folds × alphas ≥ workers` the coarse tasks
///   already saturate the pool, and the fine-grained results are bitwise
///   identical either way.
/// * `workers <= 1` (or `TLFRE_THREADS=1`, or a call from inside a pool
///   worker) is the plain serial loop — the reference the parity tests
///   compare against.
///
/// Unlike [`parallel_map`] (scoped threads, spawn per call) this rides the
/// parked workers, so repeated CV sweeps pay no spawn tax.
pub fn parallel_map_with_workers<T, U, F>(items: &[T], workers: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n == 0 || in_pool_worker() {
        return items.iter().map(f).collect();
    }
    let mut out: Vec<Option<U>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    parallel_chunks_mut(&mut out, workers, |start, chunk| {
        for (k, slot) in chunk.iter_mut().enumerate() {
            *slot = Some(f(&items[start + k]));
        }
    });
    out.into_iter().map(|o| o.expect("every chunk filled its slots")).collect()
}

/// The legacy per-call `std::thread::scope` fill, kept as the reference
/// implementation for the bitwise-parity tests (`tests/backend_parity.rs`)
/// and the spawn-vs-dispatch overhead comparison in `benches/perf_kernels.rs`.
/// Production code paths all use the persistent pool.
pub fn scoped_fill_with_workers<U, F>(out: &mut [U], workers: usize, f: F)
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    let n = out.len();
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n == 0 {
        for (i, o) in out.iter_mut().enumerate() {
            *o = f(i);
        }
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for (w, slice) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                let base = w * chunk;
                for (k, o) in slice.iter_mut().enumerate() {
                    *o = f(base + k);
                }
            });
        }
    });
}

/// Map a function over items in parallel, preserving order.
///
/// Results are collected per worker chunk and concatenated, so `U` needs no
/// `Default + Clone` bound (and no placeholder zero-fill pass happens).
///
/// Deliberately **not** routed through the persistent pool: this is the
/// coarse-grained helper (whole α-paths, CV folds — milliseconds to seconds
/// per item), where a per-call `std::thread::scope` spawn is noise and the
/// scoped threads may themselves dispatch fine-grained sweeps to the pool.
/// Keeping it on scoped threads avoids a second copy of the pool's
/// lifetime-erasure machinery for a path that doesn't need it.
pub fn parallel_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let n = items.len();
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n == 0 {
        return items.iter().map(f).collect();
    }
    let chunk = n.div_ceil(workers);
    // Join every handle and carry the first panic payload out, re-raising
    // only after the scope has reaped all threads — the same contract as
    // `dispatch_round`. The former `join().expect(...)` here panicked
    // *inside* the scope with the payload discarded; with a second
    // panicked (and then unjoined) thread, the scope's own unwind check
    // turned that into a double panic and aborted the whole process.
    let mut first_panic: Option<Box<dyn Any + Send>> = None;
    let parts: Vec<Vec<U>> = std::thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks(chunk)
            .map(|part| {
                let f = &f;
                s.spawn(move || part.iter().map(f).collect::<Vec<U>>())
            })
            .collect();
        let mut parts = Vec::with_capacity(handles.len());
        for h in handles {
            match h.join() {
                Ok(part) => parts.push(part),
                Err(payload) => {
                    if first_panic.is_none() {
                        first_panic = Some(payload);
                    }
                }
            }
        }
        parts
    });
    if let Some(payload) = first_panic {
        resume_unwind(payload);
    }
    let mut out = Vec::with_capacity(n);
    for p in parts {
        out.extend(p);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::num::NonZeroUsize;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_all_indices_once() {
        let n = 1000;
        let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(n, |_, s, e| {
            for i in s..e {
                counts[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let xs: Vec<usize> = (0..257).collect();
        let ys = parallel_map(&xs, |&x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_non_default_type() {
        // NonZeroUsize has no Default impl — the old bound rejected this.
        let xs: Vec<usize> = (0..100).collect();
        let ys = parallel_map(&xs, |&x| NonZeroUsize::new(x + 1).unwrap());
        assert_eq!(ys.len(), 100);
        assert_eq!(ys[41].get(), 42);
    }

    #[test]
    fn parallel_fill_matches_serial() {
        let mut out = vec![0usize; 513];
        parallel_fill(&mut out, |i| i * i);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * i);
        }
        // empty slice is fine
        let mut empty: Vec<usize> = Vec::new();
        parallel_fill(&mut empty, |i| i);
    }

    #[test]
    fn explicit_worker_counts_match_serial_and_scoped() {
        let n = 777;
        let f = |i: usize| (i as f64 * 0.37).sin();
        let mut serial = vec![0.0f64; n];
        for (i, o) in serial.iter_mut().enumerate() {
            *o = f(i);
        }
        for workers in [1usize, 2, 3, 5, 8, 16] {
            let mut pooled = vec![0.0f64; n];
            parallel_fill_with_workers(&mut pooled, workers, f);
            assert_eq!(pooled, serial, "pool workers={workers}");
            let mut scoped = vec![0.0f64; n];
            scoped_fill_with_workers(&mut scoped, workers, f);
            assert_eq!(scoped, serial, "scoped workers={workers}");
        }
    }

    #[test]
    fn repeated_dispatch_reuses_pool() {
        // Many small rounds back-to-back: exercises the parked-worker
        // wake/finish cycle rather than any one-shot path.
        let mut out = vec![0usize; 64];
        let rounds = if cfg!(miri) { 20 } else { 200 };
        for round in 0..rounds {
            parallel_fill_with_workers(&mut out, 4, |i| i + round);
            assert_eq!(out[63], 63 + round);
        }
    }

    #[test]
    fn concurrent_dispatchers_do_not_cross_talk() {
        // Two non-worker threads dispatching simultaneously: each round's
        // latch must only count its own tasks.
        std::thread::scope(|s| {
            for t in 0..4 {
                s.spawn(move || {
                    let mut out = vec![0usize; 301];
                    let rounds = if cfg!(miri) { 5 } else { 50 };
                    for _ in 0..rounds {
                        parallel_fill_with_workers(&mut out, 3, |i| i * (t + 1));
                        assert_eq!(out[300], 300 * (t + 1));
                    }
                });
            }
        });
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let result = std::panic::catch_unwind(|| {
            let mut out = vec![0usize; 100];
            parallel_fill_with_workers(&mut out, 4, |i| {
                assert!(i != 90, "injected failure");
                i
            });
        });
        assert!(result.is_err(), "panic must propagate to the dispatcher");
        // The pool must still be usable after a panicked round.
        let mut out = vec![0usize; 100];
        parallel_fill_with_workers(&mut out, 4, |i| i + 1);
        assert_eq!(out[99], 100);
    }

    #[test]
    fn parallel_map_worker_panic_propagates_with_payload() {
        // Regression: the fallback join path must propagate a worker panic
        // to the caller (payload intact) instead of double-panicking inside
        // the scope — which aborted the process when two chunks panicked.
        let xs: Vec<usize> = (0..100).collect();
        let result = std::panic::catch_unwind(|| {
            parallel_map(&xs, |&x| {
                // Panic in (at least) two different chunks at 2+ workers.
                assert!(x != 10 && x != 90, "injected failure");
                x
            })
        });
        let payload = result.expect_err("panic must propagate to the caller");
        let msg = payload
            .downcast_ref::<String>()
            .map(String::as_str)
            .or_else(|| payload.downcast_ref::<&str>().copied())
            .unwrap_or("");
        assert!(msg.contains("injected failure"), "payload lost: {msg:?}");
        // Scoped threads must all be reaped; later maps still work.
        let ys = parallel_map(&xs, |&x| x + 1);
        assert_eq!(ys[99], 100);
    }

    #[test]
    fn fills_nested_inside_map_tasks_are_correct() {
        // parallel_map's scoped threads may dispatch fine-grained fills to
        // the pool concurrently; every nested fill must still be exact.
        let xs: Vec<usize> = (0..16).collect();
        let ys = parallel_map(&xs, |&x| {
            let mut inner = vec![0usize; 32];
            parallel_fill(&mut inner, |i| i * x);
            inner.iter().sum::<usize>()
        });
        let expect: Vec<usize> = xs.iter().map(|&x| (0..32).map(|i| i * x).sum()).collect();
        assert_eq!(ys, expect);
    }

    #[test]
    fn chunks_mut_covers_disjointly_with_correct_starts() {
        for workers in [1usize, 2, 3, 5, 8, 40] {
            let mut out = vec![usize::MAX; 1001];
            parallel_chunks_mut(&mut out, workers, |start, chunk| {
                for (k, o) in chunk.iter_mut().enumerate() {
                    // Write the global index: proves the reported start
                    // matches the chunk's true position in the slice.
                    *o = start + k;
                }
            });
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, i, "workers={workers}");
            }
        }
        let mut empty: Vec<usize> = Vec::new();
        parallel_chunks_mut(&mut empty, 4, |_, _| panic!("no chunk for empty slice"));
    }

    #[test]
    fn chunks_mut_accumulation_is_partition_invariant() {
        // The forward-sweep usage pattern: accumulate a fixed sequence of
        // additions into each element. Any partition must give bitwise the
        // same floats as the serial whole-slice call.
        let terms: Vec<f32> = (0..37).map(|t| (t as f32 * 0.713).sin()).collect();
        let accumulate = |start: usize, chunk: &mut [f32]| {
            for (k, o) in chunk.iter_mut().enumerate() {
                let i = start + k;
                for &t in &terms {
                    *o += t * (i as f32 + 1.0);
                }
            }
        };
        let mut serial = vec![0.0f32; 513];
        accumulate(0, &mut serial);
        for workers in [2usize, 3, 4, 8] {
            let mut par = vec![0.0f32; 513];
            parallel_chunks_mut(&mut par, workers, accumulate);
            for i in 0..513 {
                assert_eq!(par[i].to_bits(), serial[i].to_bits(), "i={i} workers={workers}");
            }
        }
    }

    #[test]
    fn chunks_mut_at_covers_disjointly_with_correct_starts() {
        for bounds in [vec![], vec![1], vec![500], vec![1, 2, 3], vec![100, 400, 1000]] {
            let mut out = vec![usize::MAX; 1001];
            parallel_chunks_mut_at(&mut out, &bounds, |start, chunk| {
                for (k, o) in chunk.iter_mut().enumerate() {
                    *o = start + k;
                }
            });
            for (i, &v) in out.iter().enumerate() {
                assert_eq!(v, i, "bounds={bounds:?}");
            }
        }
        let mut empty: Vec<usize> = Vec::new();
        parallel_chunks_mut_at(&mut empty, &[], |_, chunk| assert!(chunk.is_empty()));
    }

    #[test]
    fn chunks_mut_at_accumulation_matches_even_partition_bitwise() {
        let terms: Vec<f32> = (0..29).map(|t| (t as f32 * 0.417).cos()).collect();
        let accumulate = |start: usize, chunk: &mut [f32]| {
            for (k, o) in chunk.iter_mut().enumerate() {
                let i = start + k;
                for &t in &terms {
                    *o += t * (i as f32 + 1.0);
                }
            }
        };
        let mut serial = vec![0.0f32; 257];
        accumulate(0, &mut serial);
        for bounds in [vec![7usize], vec![64, 128], vec![1, 2, 200, 256]] {
            let mut par = vec![0.0f32; 257];
            parallel_chunks_mut_at(&mut par, &bounds, accumulate);
            for i in 0..257 {
                assert_eq!(par[i].to_bits(), serial[i].to_bits(), "i={i} bounds={bounds:?}");
            }
        }
    }

    #[test]
    fn zero_items_ok() {
        parallel_for_chunks(0, |_, s, e| assert_eq!(s, e));
        let ys: Vec<usize> = parallel_map(&Vec::<usize>::new(), |&x| x);
        assert!(ys.is_empty());
        let zs: Vec<usize> = parallel_map_with_workers(&Vec::<usize>::new(), 4, |&x| x);
        assert!(zs.is_empty());
    }

    #[test]
    fn pooled_map_preserves_order_at_every_worker_count() {
        let xs: Vec<usize> = (0..101).collect();
        let serial: Vec<usize> = xs.iter().map(|&x| x * 3 + 1).collect();
        for workers in [1usize, 2, 3, 5, 8, 40] {
            let ys = parallel_map_with_workers(&xs, workers, |&x| x * 3 + 1);
            assert_eq!(ys, serial, "workers={workers}");
        }
    }

    #[test]
    fn pooled_map_tasks_can_dispatch_nested_fills() {
        // The CV usage pattern: coarse tasks on the pool, each internally
        // running fine-grained fills. Nested dispatches from pool workers
        // degrade to serial loops; results must be exact either way.
        let xs: Vec<usize> = (0..12).collect();
        let ys = parallel_map_with_workers(&xs, 4, |&x| {
            let mut inner = vec![0usize; 40];
            parallel_fill(&mut inner, |i| i * x);
            inner.iter().sum::<usize>()
        });
        let expect: Vec<usize> = xs.iter().map(|&x| (0..40).map(|i| i * x).sum()).collect();
        assert_eq!(ys, expect);
    }

    #[test]
    fn num_threads_at_least_one() {
        assert!(num_threads() >= 1);
    }
}
