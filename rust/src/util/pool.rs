//! Scoped-thread parallelism.
//!
//! `rayon` is unavailable offline; this provides a `parallel_for_chunks`
//! built on `std::thread::scope`. On the single-core benchmark box it
//! degrades to a serial loop with zero thread overhead, but the coordinator
//! uses it so multi-core deployments scale (e.g. running independent
//! α-paths concurrently).

/// Number of worker threads to use (respects `TLFRE_THREADS`, defaults to
/// available parallelism).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("TLFRE_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_index, start, end)` over `n` items split into contiguous
/// chunks, one per worker. `f` must be `Sync` (called from multiple threads).
pub fn parallel_for_chunks<F>(n: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let workers = num_threads().min(n.max(1));
    if workers <= 1 || n == 0 {
        f(0, 0, n);
        return;
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|s| {
        for w in 0..workers {
            let start = w * chunk;
            let end = ((w + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(w, start, end));
        }
    });
}

/// Map a function over items in parallel, preserving order.
pub fn parallel_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send + Default + Clone,
    F: Fn(&T) -> U + Sync,
{
    let mut out = vec![U::default(); items.len()];
    {
        let out_ptr = SyncSlice(out.as_mut_ptr());
        parallel_for_chunks(items.len(), |_, start, end| {
            // Capture the whole wrapper (edition-2021 disjoint capture would
            // otherwise move the raw pointer field, which is not Sync).
            let ptr = &out_ptr;
            for i in start..end {
                // SAFETY: chunks are disjoint index ranges; each element is
                // written by exactly one worker.
                unsafe { *ptr.0.add(i) = f(&items[i]) };
            }
        });
    }
    out
}

/// Wrapper making a raw pointer Sync for disjoint-range writes.
struct SyncSlice<U>(*mut U);
unsafe impl<U> Sync for SyncSlice<U> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_all_indices_once() {
        let n = 1000;
        let counts: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_chunks(n, |_, s, e| {
            for i in s..e {
                counts[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let xs: Vec<usize> = (0..257).collect();
        let ys = parallel_map(&xs, |&x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn zero_items_ok() {
        parallel_for_chunks(0, |_, s, e| assert_eq!(s, e));
        let ys: Vec<usize> = parallel_map(&Vec::<usize>::new(), |&x| x);
        assert!(ys.is_empty());
    }

    #[test]
    fn num_threads_at_least_one() {
        assert!(num_threads() >= 1);
    }
}
