//! Minimal self-contained logger (no `log`/`once_cell` in the offline
//! crate set).
//!
//! Filters by the `TLFRE_LOG` environment variable (`off|error|warn|info|
//! debug|trace`, default `info`) and writes single-line records with
//! elapsed time to stderr. Installed once via [`init`]; [`log`] is the
//! low-level entry point, with the [`info`]/[`warn`]/[`debug`] helpers for
//! the common levels.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severities, in increasing verbosity order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn label(self) -> &'static str {
        match self {
            Level::Off => "OFF  ",
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static START: OnceLock<Instant> = OnceLock::new();
static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Parse a level name; unknown names fall back to `Info`.
fn parse_level(s: &str) -> Level {
    match s.to_ascii_lowercase().as_str() {
        "off" => Level::Off,
        "error" => Level::Error,
        "warn" => Level::Warn,
        "info" => Level::Info,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    }
}

/// Install the logger (idempotent). Level from `TLFRE_LOG`, default `info`.
pub fn init() {
    START.get_or_init(Instant::now);
    let level =
        std::env::var("TLFRE_LOG").map(|v| parse_level(&v)).unwrap_or(Level::Info);
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether records at `level` are currently emitted.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed) && level != Level::Off
}

/// Emit one record (no-op when filtered out).
pub fn log(level: Level, target: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    eprintln!("[{t:9.3}s {} {target}] {msg}", level.label());
}

/// Info-level record.
pub fn info(target: &str, msg: &str) {
    log(Level::Info, target, msg);
}

/// Warn-level record.
pub fn warn(target: &str, msg: &str) {
    log(Level::Warn, target, msg);
}

/// Debug-level record.
pub fn debug(target: &str, msg: &str) {
    log(Level::Debug, target, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("error"), Level::Error);
        assert_eq!(parse_level("TRACE"), Level::Trace);
        assert_eq!(parse_level("bogus"), Level::Info);
        assert_eq!(parse_level("off"), Level::Off);
    }

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        info("logger", "smoke test line");
    }

    #[test]
    fn off_filters_everything() {
        assert!(!enabled(Level::Off));
        // Error is the least verbose real level, always ≤ info default.
        init();
        assert!(enabled(Level::Error));
    }
}
