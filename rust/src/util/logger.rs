//! Minimal `log`-facade backend.
//!
//! Filters by the `TLFRE_LOG` environment variable (`error|warn|info|debug|
//! trace`, default `info`) and writes single-line records with elapsed time
//! to stderr. Installed once via [`init`].

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::OnceCell;
use std::time::Instant;

struct Logger {
    start: Instant,
}

static LOGGER: OnceCell<Logger> = OnceCell::new();

impl log::Log for Logger {
    fn enabled(&self, _metadata: &Metadata) -> bool {
        true // filtering handled by max_level
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Parse a level name; unknown names fall back to `Info`.
fn parse_level(s: &str) -> LevelFilter {
    match s.to_ascii_lowercase().as_str() {
        "off" => LevelFilter::Off,
        "error" => LevelFilter::Error,
        "warn" => LevelFilter::Warn,
        "info" => LevelFilter::Info,
        "debug" => LevelFilter::Debug,
        "trace" => LevelFilter::Trace,
        _ => LevelFilter::Info,
    }
}

/// Install the logger (idempotent). Level from `TLFRE_LOG`, default `info`.
pub fn init() {
    let logger = LOGGER.get_or_init(|| Logger { start: Instant::now() });
    let level = std::env::var("TLFRE_LOG").map(|v| parse_level(&v)).unwrap_or(LevelFilter::Info);
    // set_logger fails if already set (e.g. by a test harness) — ignore.
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("error"), LevelFilter::Error);
        assert_eq!(parse_level("TRACE"), LevelFilter::Trace);
        assert_eq!(parse_level("bogus"), LevelFilter::Info);
        assert_eq!(parse_level("off"), LevelFilter::Off);
    }

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        log::info!("logger smoke test line");
    }
}
