//! Deterministic fault injection for robustness tests.
//!
//! Compiled to real hooks only under the `fault-inject` cargo feature; in
//! normal builds every probe below is an inlined constant-`false` no-op, so
//! production call sites carry zero cost and no `cfg` noise. The hooks are
//! process-global countdown counters: a test arms a fault point with "fire
//! at the Nth event" (or "affect the next N events"), runs the workload,
//! and the fault fires at a deterministic, worker-count-independent point
//! in the *I/O or dispatch* stream — never in solver arithmetic, so the
//! bitwise-parity invariants stay meaningful even under injection.
//!
//! Fault points:
//! * **positioned reads** (`linalg::mmap` pread fallback): short reads
//!   ([`take_short_read`]), spurious `EINTR` ([`take_eintr`]), and hard
//!   I/O errors ([`take_read_error`]);
//! * **pool dispatch** ([`take_pool_panic`]): panic inside the Nth task a
//!   pool round executes — exercises the single panic-propagation home in
//!   `pool::dispatch_round` and the scoped fallbacks;
//! * **residual poisoning** ([`take_nan_poison`]): overwrite one residual
//!   entry with NaN mid-solve — exercises the solvers' "never silent
//!   garbage" contract (non-finite gap ⇒ `converged = false`).
//!
//! Tests must call [`reset`] (or arm exactly what they consume) — the
//! counters are process-global and `cargo test` shares one process per
//! target. The fault-injection integration tests therefore serialize on a
//! private mutex.

#[cfg(feature = "fault-inject")]
mod armed {
    use std::sync::atomic::{AtomicIsize, Ordering};

    /// Disarmed sentinel: negative counters never fire.
    const OFF: isize = -1;

    pub(super) static SHORT_READS: AtomicIsize = AtomicIsize::new(OFF);
    pub(super) static EINTRS: AtomicIsize = AtomicIsize::new(OFF);
    pub(super) static READ_ERROR_AT: AtomicIsize = AtomicIsize::new(OFF);
    pub(super) static POOL_PANIC_AT: AtomicIsize = AtomicIsize::new(OFF);
    pub(super) static NAN_POISON_AT: AtomicIsize = AtomicIsize::new(OFF);

    /// Consume one event from a "next N events" counter: true while the
    /// counter is positive.
    pub(super) fn consume(cell: &AtomicIsize) -> bool {
        if cell.load(Ordering::Acquire) <= 0 {
            return false;
        }
        cell.fetch_sub(1, Ordering::AcqRel) > 0
    }

    /// Fire exactly once at the Nth event of a countdown counter
    /// (`arm(1)` = the very next event).
    pub(super) fn countdown(cell: &AtomicIsize) -> bool {
        if cell.load(Ordering::Acquire) <= 0 {
            return false;
        }
        cell.fetch_sub(1, Ordering::AcqRel) == 1
    }
}

#[cfg(feature = "fault-inject")]
mod api {
    use super::armed::*;
    use std::sync::atomic::Ordering;

    /// Disarm every fault point (call between tests).
    pub fn reset() {
        for cell in [&SHORT_READS, &EINTRS, &READ_ERROR_AT, &POOL_PANIC_AT, &NAN_POISON_AT] {
            cell.store(-1, Ordering::Release);
        }
    }

    /// The next `n` positioned reads return only half the requested bytes.
    pub fn arm_short_reads(n: usize) {
        SHORT_READS.store(n as isize, Ordering::Release);
    }

    /// Probe: should this positioned read come up short?
    pub fn take_short_read() -> bool {
        consume(&SHORT_READS)
    }

    /// The next `n` positioned reads fail with `ErrorKind::Interrupted`.
    pub fn arm_eintrs(n: usize) {
        EINTRS.store(n as isize, Ordering::Release);
    }

    /// Probe: should this positioned read be interrupted?
    pub fn take_eintr() -> bool {
        consume(&EINTRS)
    }

    /// The `nth` positioned read (1-based) fails with a hard I/O error.
    pub fn arm_read_error(nth: usize) {
        READ_ERROR_AT.store(nth as isize, Ordering::Release);
    }

    /// Probe: should this positioned read fail hard?
    pub fn take_read_error() -> bool {
        countdown(&READ_ERROR_AT)
    }

    /// The `nth` pool task executed (1-based, across all rounds from now)
    /// panics.
    pub fn arm_pool_panic(nth: usize) {
        POOL_PANIC_AT.store(nth as isize, Ordering::Release);
    }

    /// Probe: should this pool task panic?
    pub fn take_pool_panic() -> bool {
        countdown(&POOL_PANIC_AT)
    }

    /// The `nth` residual evaluation (1-based) gets one entry overwritten
    /// with NaN.
    pub fn arm_nan_poison(nth: usize) {
        NAN_POISON_AT.store(nth as isize, Ordering::Release);
    }

    /// Probe: should this residual be poisoned?
    pub fn take_nan_poison() -> bool {
        countdown(&NAN_POISON_AT)
    }
}

#[cfg(not(feature = "fault-inject"))]
mod api {
    //! No-op stubs: every probe is a constant `false` the optimizer erases.

    /// Disarm every fault point (no-op without `fault-inject`).
    pub fn reset() {}

    /// Arm short positioned reads (no-op without `fault-inject`).
    pub fn arm_short_reads(_n: usize) {}

    /// Probe: should this positioned read come up short?
    #[inline(always)]
    pub fn take_short_read() -> bool {
        false
    }

    /// Arm interrupted positioned reads (no-op without `fault-inject`).
    pub fn arm_eintrs(_n: usize) {}

    /// Probe: should this positioned read be interrupted?
    #[inline(always)]
    pub fn take_eintr() -> bool {
        false
    }

    /// Arm a hard positioned-read error (no-op without `fault-inject`).
    pub fn arm_read_error(_nth: usize) {}

    /// Probe: should this positioned read fail hard?
    #[inline(always)]
    pub fn take_read_error() -> bool {
        false
    }

    /// Arm a pool-task panic (no-op without `fault-inject`).
    pub fn arm_pool_panic(_nth: usize) {}

    /// Probe: should this pool task panic?
    #[inline(always)]
    pub fn take_pool_panic() -> bool {
        false
    }

    /// Arm residual NaN poisoning (no-op without `fault-inject`).
    pub fn arm_nan_poison(_nth: usize) {}

    /// Probe: should this residual be poisoned?
    #[inline(always)]
    pub fn take_nan_poison() -> bool {
        false
    }
}

pub use api::*;

/// Poison one entry of a residual buffer when armed (no-op otherwise).
/// Centralized here so the solver call sites stay one line.
#[inline]
pub fn maybe_poison_residual(r: &mut [f32]) {
    if take_nan_poison() {
        if let Some(slot) = r.first_mut() {
            *slot = f32::NAN;
        }
    }
}

#[cfg(all(test, feature = "fault-inject"))]
mod tests {
    use super::*;

    #[test]
    fn counters_fire_deterministically() {
        reset();
        assert!(!take_short_read());
        arm_short_reads(2);
        assert!(take_short_read());
        assert!(take_short_read());
        assert!(!take_short_read());

        arm_pool_panic(3);
        assert!(!take_pool_panic());
        assert!(!take_pool_panic());
        assert!(take_pool_panic());
        assert!(!take_pool_panic());
        reset();
    }
}
