//! Minimal JSON value type, parser and writer.
//!
//! Used for experiment manifests, artifact metadata (`artifacts/manifest.json`
//! produced by the python AOT pipeline), config files and bench reports.
//! `serde` is not in the offline crate set, so this is a small hand-rolled
//! recursive-descent implementation covering the full JSON grammar.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a `BTreeMap` so emission is
/// deterministic (stable diffs for generated manifests).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----- constructors ----------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Builder-style insert; panics if `self` is not an object.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ----- accessors -------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style lookup; returns `None` on any miss.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    // ----- parse / emit ----------------------------------------------------

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact single-line encoding.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed encoding with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{}", x));
                    }
                } else {
                    // JSON has no Inf/NaN; emit null (documented lossy case).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

// ---------------------------------------------------------------------------

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        s.push(c);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-by-byte.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        let re = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nulls").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("01a").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
        // Raw multibyte UTF-8 passes through.
        let v = Json::parse("\"héllo → 😀\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 😀"));
    }

    #[test]
    fn builder_and_accessors() {
        let v = Json::obj()
            .set("name", "tlfre")
            .set("n", 250usize)
            .set("ok", true)
            .set("xs", vec![1.0, 2.0]);
        assert_eq!(v.get("n").unwrap().as_usize(), Some(250));
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("xs").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn numbers_roundtrip_precisely() {
        for x in [0.0, 1.5, -3.25, 1e-9, 123456789.0, -0.0001] {
            let s = Json::Num(x).to_string_compact();
            let v = Json::parse(&s).unwrap();
            assert_eq!(v.as_f64(), Some(x), "for {x} -> {s}");
        }
    }

    #[test]
    fn nonfinite_emits_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string_compact(), "null");
    }

    #[test]
    fn deep_nesting() {
        let mut s = String::new();
        for _ in 0..50 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..50 {
            s.push(']');
        }
        assert!(Json::parse(&s).is_ok());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::obj());
        assert_eq!(Json::parse(" { } ").unwrap().to_string_compact(), "{}");
    }
}
