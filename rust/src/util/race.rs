//! Runtime concurrency checkers behind the `race-check` cargo feature.
//!
//! Two debug-only checkers in the mold of [`crate::util::fault`]: real
//! implementations under `--features race-check`, inlined no-ops
//! otherwise, so production call sites carry zero cost and no `cfg`
//! noise.
//!
//! * **Shadow-ownership writes** — the parallel writers
//!   ([`crate::util::pool::parallel_chunks_mut`] /
//!   [`crate::util::pool::parallel_chunks_mut_at`] and the colored-BCD
//!   dispatch in `sgl/bcd.rs`) *claim* the index ranges they are about
//!   to write, keyed by the destination buffer's address. Two different
//!   workers claiming overlapping indices of one buffer is a partition
//!   or coloring bug; the checker panics immediately, naming both claim
//!   sites and both workers, instead of letting a silent lost update
//!   skew the solve. Claims validate the *ownership protocol*, not raw
//!   memory — the cheap deterministic companion to the ThreadSanitizer
//!   CI job, and it works where TSan cannot go (Miri, single-run CI).
//! * **Lock order** — named mutexes (the [`crate::server::registry`]
//!   maps) record every "acquired B while holding A" edge in a global
//!   table; a later acquisition contradicting a recorded edge panics
//!   naming both locks and both acquisition sites — a potential
//!   deadlock caught on the first run that exercises either order, not
//!   the unlucky run that interleaves into it.
//!
//! Keying write regions by buffer address means concurrent solves (CV
//! folds, serve connections) never cross-talk: each residual/β buffer is
//! its own claim space, opened by [`write_region`] and cleared when the
//! returned guard drops.

#[cfg(feature = "race-check")]
mod armed {
    use std::collections::HashMap;
    use std::ops::{Deref, DerefMut};
    use std::panic::Location;
    use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};

    /// Checkers are compiled in (callers may gate claim *preparation*
    /// work, e.g. building row bitsets, on this).
    pub const ENABLED: bool = true;

    #[derive(Clone, Copy)]
    struct Claim {
        start: usize,
        end: usize,
        worker: usize,
        site: &'static str,
    }

    /// Claimed half-open ranges per open write region, keyed by the
    /// destination buffer's address.
    static CLAIMS: OnceLock<Mutex<HashMap<usize, Vec<Claim>>>> = OnceLock::new();

    fn claims() -> MutexGuard<'static, HashMap<usize, Vec<Claim>>> {
        // Poison recovery: a claim panic (the checker firing) must not
        // wedge every later region in the test process.
        CLAIMS
            .get_or_init(|| Mutex::new(HashMap::new()))
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
    }

    /// An open shadow-ownership region over one destination buffer;
    /// claims under its key live no longer than this guard.
    pub struct WriteRegion {
        key: usize,
    }

    impl Drop for WriteRegion {
        fn drop(&mut self) {
            claims().remove(&self.key);
        }
    }

    /// Open a claim region for the buffer at address `key`, clearing any
    /// stale claims left under a recycled address.
    pub fn write_region(key: usize) -> WriteRegion {
        claims().insert(key, Vec::new());
        WriteRegion { key }
    }

    /// Claim `[start, end)` of the buffer at `key` for `worker`; panics
    /// if a *different* worker holds an overlapping claim.
    pub fn claim_range(key: usize, worker: usize, start: usize, end: usize, site: &'static str) {
        if start >= end {
            return;
        }
        let mut map = claims();
        let list = map.entry(key).or_default();
        for c in list.iter() {
            if c.worker != worker && start < c.end && c.start < end {
                panic!(
                    "race-check: overlapping write claims on buffer {key:#x}: worker {worker} \
                     claims [{start}, {end}) at [{site}], but worker {} already claimed \
                     [{}, {}) at [{}]",
                    c.worker, c.start, c.end, c.site
                );
            }
        }
        list.push(Claim { start, end, worker, site });
    }

    /// Claim every set bit of the bitset `bits` (bit `i` ⇔ index `i`)
    /// for `worker`, compressing runs of set bits into range claims.
    pub fn claim_bits(key: usize, worker: usize, bits: &[u64], site: &'static str) {
        let n = bits.len() * 64;
        let mut i = 0;
        while i < n {
            if (bits[i / 64] >> (i % 64)) & 1 == 1 {
                let s = i;
                while i < n && (bits[i / 64] >> (i % 64)) & 1 == 1 {
                    i += 1;
                }
                claim_range(key, worker, s, i, site);
            } else {
                i += 1;
            }
        }
    }

    /// First-recorded site of every `held(A) → acquire(B)` order edge.
    type EdgeMap = HashMap<(&'static str, &'static str), &'static Location<'static>>;
    static EDGES: OnceLock<Mutex<EdgeMap>> = OnceLock::new();

    thread_local! {
        /// Names of the tracked locks this thread currently holds.
        static HELD: std::cell::RefCell<Vec<&'static str>> =
            const { std::cell::RefCell::new(Vec::new()) };
    }

    /// Record lock `name` acquired on this thread; panics if a recorded
    /// edge says the opposite order was taken before (deadlock cycle).
    #[track_caller]
    pub fn lock_acquired(name: &'static str) {
        let here = Location::caller();
        let mut edges = EDGES
            .get_or_init(|| Mutex::new(HashMap::new()))
            .lock()
            .unwrap_or_else(PoisonError::into_inner);
        HELD.with(|h| {
            for &held in h.borrow().iter() {
                if held == name {
                    continue;
                }
                if let Some(prev) = edges.get(&(name, held)) {
                    panic!(
                        "race-check: lock-order inversion: '{name}' acquired while holding \
                         '{held}' at {here}, but '{held}' was previously acquired while \
                         holding '{name}' at {prev} — potential deadlock"
                    );
                }
                edges.entry((held, name)).or_insert(here);
            }
            h.borrow_mut().push(name);
        });
    }

    /// Record lock `name` released on this thread.
    pub fn lock_released(name: &'static str) {
        HELD.with(|h| {
            let mut v = h.borrow_mut();
            if let Some(pos) = v.iter().rposition(|&n| n == name) {
                v.remove(pos);
            }
        });
    }

    /// A mutex guard whose acquisition order is tracked by name.
    pub struct OrderedGuard<'a, T> {
        name: &'static str,
        guard: MutexGuard<'a, T>,
    }

    /// Wrap an already-acquired guard under `name` for order tracking
    /// (acquisition is recorded here, release when the wrapper drops).
    #[track_caller]
    pub fn track_guard<'a, T>(name: &'static str, guard: MutexGuard<'a, T>) -> OrderedGuard<'a, T> {
        lock_acquired(name);
        OrderedGuard { name, guard }
    }

    impl<T> Deref for OrderedGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            &self.guard
        }
    }

    impl<T> DerefMut for OrderedGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            &mut self.guard
        }
    }

    impl<T> Drop for OrderedGuard<'_, T> {
        fn drop(&mut self) {
            lock_released(self.name);
        }
    }
}

#[cfg(feature = "race-check")]
pub use armed::*;

#[cfg(not(feature = "race-check"))]
mod api {
    use std::ops::{Deref, DerefMut};
    use std::sync::MutexGuard;

    /// Checkers are compiled out: every probe below is an inlined no-op.
    pub const ENABLED: bool = false;

    /// No-op region token.
    pub struct WriteRegion;

    #[inline(always)]
    pub fn write_region(_key: usize) -> WriteRegion {
        WriteRegion
    }

    #[inline(always)]
    pub fn claim_range(_key: usize, _worker: usize, _start: usize, _end: usize, _site: &str) {}

    #[inline(always)]
    pub fn claim_bits(_key: usize, _worker: usize, _bits: &[u64], _site: &str) {}

    #[inline(always)]
    pub fn lock_acquired(_name: &'static str) {}

    #[inline(always)]
    pub fn lock_released(_name: &'static str) {}

    /// Transparent guard wrapper (no tracking compiled in).
    pub struct OrderedGuard<'a, T> {
        guard: MutexGuard<'a, T>,
    }

    #[inline(always)]
    pub fn track_guard<'a, T>(_name: &'static str, guard: MutexGuard<'a, T>) -> OrderedGuard<'a, T> {
        OrderedGuard { guard }
    }

    impl<T> Deref for OrderedGuard<'_, T> {
        type Target = T;
        #[inline(always)]
        fn deref(&self) -> &T {
            &self.guard
        }
    }

    impl<T> DerefMut for OrderedGuard<'_, T> {
        #[inline(always)]
        fn deref_mut(&mut self) -> &mut T {
            &mut self.guard
        }
    }
}

#[cfg(not(feature = "race-check"))]
pub use api::*;

#[cfg(all(test, feature = "race-check"))]
mod tests {
    use super::*;
    use std::panic::catch_unwind;
    use std::sync::Mutex;

    fn panic_message(err: Box<dyn std::any::Any + Send>) -> String {
        err.downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_else(|| "<non-string panic>".into())
    }

    #[test]
    fn disjoint_and_same_worker_claims_pass() {
        let key = 0x1001;
        let _region = write_region(key);
        claim_range(key, 0, 0, 64, "a");
        claim_range(key, 1, 64, 128, "b");
        // Same worker may overlap itself (sequential re-writes race nothing).
        claim_range(key, 0, 0, 32, "a again");
    }

    #[test]
    fn overlapping_cross_worker_claims_panic_with_both_sites() {
        let key = 0x1002;
        let _region = write_region(key);
        claim_range(key, 0, 0, 70, "site-alpha");
        let err = catch_unwind(|| claim_range(key, 1, 60, 90, "site-beta"))
            .expect_err("cross-worker overlap must panic");
        let msg = panic_message(err);
        assert!(msg.contains("race-check"), "{msg}");
        assert!(msg.contains("site-alpha") && msg.contains("site-beta"), "{msg}");
        assert!(msg.contains("worker 0") && msg.contains("worker 1"), "{msg}");
    }

    #[test]
    fn bitset_claims_catch_single_shared_row() {
        let key = 0x1003;
        let _region = write_region(key);
        let mut a = [0u64; 2];
        a[0] = 0b1111; // rows 0..4
        a[1] = 1 << 5; // row 69
        claim_bits(key, 0, &a, "bits-a");
        let mut b = [0u64; 2];
        b[1] = 1 << 5; // row 69 again, different worker
        let err = catch_unwind(|| claim_bits(key, 1, &b, "bits-b"))
            .expect_err("shared row must panic");
        let msg = panic_message(err);
        assert!(msg.contains("[69, 70)"), "{msg}");
    }

    #[test]
    fn dropping_a_region_clears_its_claims() {
        let key = 0x1004;
        {
            let _region = write_region(key);
            claim_range(key, 0, 0, 10, "first run");
        }
        // New region over a recycled address: the old claims are gone.
        let _region = write_region(key);
        claim_range(key, 1, 0, 10, "second run");
    }

    #[test]
    fn lock_order_inversion_panics_naming_both_locks() {
        let a = Mutex::new(());
        let b = Mutex::new(());
        {
            let _ga = track_guard("race.test.a", a.lock().unwrap());
            let _gb = track_guard("race.test.b", b.lock().unwrap());
        }
        // Same order again is fine.
        {
            let _ga = track_guard("race.test.a", a.lock().unwrap());
            let _gb = track_guard("race.test.b", b.lock().unwrap());
        }
        // Opposite order: the recorded a→b edge makes this a cycle.
        let _gb = track_guard("race.test.b", b.lock().unwrap());
        let err = catch_unwind(|| {
            let _ga = track_guard("race.test.a", a.lock().unwrap());
        })
        .expect_err("inversion must panic");
        let msg = panic_message(err);
        assert!(msg.contains("lock-order inversion"), "{msg}");
        assert!(msg.contains("race.test.a") && msg.contains("race.test.b"), "{msg}");
    }

    #[test]
    fn uncontradicted_nesting_never_fires() {
        let outer = Mutex::new(());
        let inner = Mutex::new(());
        for _ in 0..3 {
            let _go = track_guard("race.test.outer", outer.lock().unwrap());
            let _gi = track_guard("race.test.inner", inner.lock().unwrap());
        }
    }
}
