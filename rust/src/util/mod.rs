//! Utility substrates.
//!
//! The build environment is fully offline with a small vendored crate set
//! (no `rand`, `serde`, `clap`, `criterion`, `rayon`, `tokio`), so the
//! pieces a production crate would normally pull in are implemented here
//! from scratch:
//!
//! * [`rng`] — `xoshiro256++` PRNG with gaussian / permutation helpers.
//! * [`json`] — a small JSON value type with parser and pretty-printer
//!   (configs, experiment manifests, artifact metadata).
//! * [`stats`] — descriptive statistics over timing samples.
//! * [`harness`] — a micro-benchmark harness (warmup + repeated timing)
//!   standing in for criterion; used by every `rust/benches/*` binary.
//! * [`logger`] — a tiny `log`-facade backend with env-based filtering.
//! * [`pool`] — persistent parked-worker pool for chunked parallel-for
//!   (sized to available cores, spawn-free after first use).
//! * [`fault`] — deterministic fault-injection hooks (real only under the
//!   `fault-inject` feature; inlined-`false` no-ops otherwise).
//! * [`race`] — shadow-ownership write claims + lock-order checking (real
//!   only under the `race-check` feature; inlined no-ops otherwise).

pub mod fault;
pub mod harness;
pub mod json;
pub mod logger;
pub mod pool;
pub mod race;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::Summary;

/// Wall-clock stopwatch with lap support.
#[derive(Debug, Clone)]
pub struct Timer {
    start: std::time::Instant,
}

impl Timer {
    /// Start a new timer.
    pub fn start() -> Self {
        Timer { start: std::time::Instant::now() }
    }

    /// Seconds elapsed since construction.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds elapsed since construction.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    /// Reset the stopwatch and return the elapsed seconds up to the reset.
    pub fn lap_s(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = std::time::Instant::now();
        e
    }
}

/// Drop the entries of `v` where `kept[i]` is false, preserving order —
/// the survivor-compaction primitive shared by the dynamic-screening
/// solvers (iterate/momentum/column-map vectors) and the GAP-safe states
/// (their projected norm tables), so every consumer compacts by the exact
/// same index-tracking rule.
pub fn retain_by_mask<T>(v: &mut Vec<T>, kept: &[bool]) {
    assert_eq!(v.len(), kept.len(), "keep mask must cover every entry");
    let mut k = 0usize;
    v.retain(|_| {
        let keep = kept[k];
        k += 1;
        keep
    });
}

/// Format a duration in seconds with sensible units for log lines.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.2}s", secs)
    } else {
        format!("{:.1}min", secs / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed_s();
        let b = t.elapsed_s();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn retain_by_mask_preserves_order() {
        let mut v = vec![10, 11, 12, 13, 14];
        retain_by_mask(&mut v, &[true, false, true, false, true]);
        assert_eq!(v, vec![10, 12, 14]);
        let mut empty: Vec<f32> = Vec::new();
        retain_by_mask(&mut empty, &[]);
        assert!(empty.is_empty());
    }

    #[test]
    fn duration_formatting() {
        assert!(fmt_duration(0.5e-9).ends_with("ns"));
        assert!(fmt_duration(5e-6).ends_with("µs"));
        assert!(fmt_duration(5e-3).ends_with("ms"));
        assert!(fmt_duration(5.0).ends_with('s'));
        assert!(fmt_duration(600.0).ends_with("min"));
    }
}
