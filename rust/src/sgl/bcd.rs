//! Block coordinate descent solver for SGL (SLEP-style baseline).
//!
//! Cyclic sweeps over groups maintaining the residual incrementally. For
//! each group the zero test `‖S_{λ₂}(X_gᵀ r̃_g)‖ ≤ λ₁√n_g` (the group-level
//! KKT condition, cf. the paper's eq. (30)) is checked first; surviving
//! groups run a few inner proximal-gradient steps with the *group-local*
//! Lipschitz constant `‖X_g‖₂²`, which converges far faster per flop than
//! global-step methods when groups are small.
//!
//! This is the solver role SLEP [12] plays in the paper's experiments; the
//! benches compare it against [`super::fista`] as an ablation.

use super::coloring::GroupColoring;
use super::dual::{duality_gap, null_objective};
use super::objective::{objective_with_residual, residual};
use super::problem::{SglParams, SglProblem};
use crate::groups::GroupStructure;
use crate::linalg::power::group_spectral_norms;
use crate::linalg::{DesignMatrix, ScreenedView};
use crate::prox::{sgl_prox_group, shrink_norm};
use crate::screening::gap_safe::{EvictPlan, GapSafeDynamic};
use crate::util::{pool, race, retain_by_mask, Rng};
use std::cell::RefCell;
use std::sync::Mutex;

/// Options for the BCD solver.
#[derive(Debug, Clone)]
pub struct BcdOptions<'a> {
    /// Max full sweeps over all groups.
    pub max_sweeps: usize,
    /// Relative duality-gap tolerance (same semantics as FISTA's).
    pub tol: f64,
    /// Inner proximal-gradient steps per group per sweep.
    pub inner_steps: usize,
    /// Gap-check cadence in sweeps.
    pub check_every: usize,
    /// Pre-computed per-group Lipschitz constants `L_g = ‖X_g‖₂²` (one per
    /// group, in group order). When `None` (the default, and the behaviour
    /// of standalone calls) they are computed by power iteration per call.
    /// The path runners supply the full-matrix values cached once per path:
    /// for a screened subproblem `σmax(X_g[:,S]) ≤ σmax(X_g)`, so the
    /// cached constants are valid (conservative) upper bounds.
    pub group_lipschitz: Option<&'a [f64]>,
    /// Sweep independent groups concurrently on the worker pool, scheduled
    /// by a red-black conflict-graph coloring ([`GroupColoring`]). Groups
    /// whose columns touch disjoint row sets commute exactly, so the
    /// colored sweep — at any `TLFRE_THREADS` — is **bitwise identical** to
    /// the sequential sweep (`false`, the default, kept as the A/B parity
    /// reference; `tests/backend_parity.rs` enforces the equality). Only
    /// sparse backends have non-trivial colorings; on dense designs the
    /// schedule degenerates to the sequential order and the pool is skipped.
    pub parallel_groups: bool,
    /// Pre-computed coloring for `parallel_groups` (the path runners cache
    /// one per path and project it per reduced problem). Computed per call
    /// when `None`.
    pub coloring: Option<&'a GroupColoring>,
    /// In-solver dynamic GAP-safe screening (same contract as
    /// [`crate::sgl::fista::FistaOptions::dynamic_screen`]): checked at
    /// every gap check on the check's own sweep, certified-zero features
    /// are folded out of the residual and the live problem compacts —
    /// group structure, per-group Lipschitz constants and the coloring
    /// projection included, so pool-parallel colored sweeps keep their
    /// class invariant on the shrunken problem. `None` (default) is the
    /// plain solve.
    pub dynamic_screen: Option<&'a RefCell<GapSafeDynamic>>,
    /// Wall-clock deadline for graceful degradation (same contract as
    /// [`crate::sgl::fista::FistaOptions::deadline`]): checked at gap-check
    /// cadence after the gap is measured; once past it the solve returns
    /// best-so-far with `converged = false` and `budget_exhausted = true`.
    /// `None` (default) never times out.
    pub deadline: Option<std::time::Instant>,
}

impl Default for BcdOptions<'_> {
    fn default() -> Self {
        BcdOptions {
            max_sweeps: 2000,
            tol: 1e-6,
            inner_steps: 4,
            check_every: 5,
            group_lipschitz: None,
            parallel_groups: false,
            coloring: None,
            dynamic_screen: None,
            deadline: None,
        }
    }
}

/// Per-worker scratch for one group update (hoisted out of the sweep loop —
/// the sequential hot path stays allocation-free, the colored path allocates
/// one set per pool worker per solve).
struct GroupScratch {
    cg: Vec<f32>,
    wg: Vec<f32>,
    bg_new: Vec<f32>,
    xb: Vec<f32>,
}

impl GroupScratch {
    fn new(max_group: usize, n: usize) -> GroupScratch {
        GroupScratch {
            cg: vec![0.0f32; max_group],
            wg: vec![0.0f32; max_group],
            bg_new: vec![0.0f32; max_group],
            xb: vec![0.0f32; n],
        }
    }
}

/// One BCD group update: zero-test, inner prox-gradient steps, residual
/// maintenance. The **single** arithmetic home shared by the sequential and
/// the colored sweeps — both execute byte-for-byte the same operations per
/// group, which is what makes the schedules bitwise comparable.
#[allow(clippy::too_many_arguments)]
fn update_group<M: DesignMatrix>(
    x: &M,
    params: &SglParams,
    inner_steps: usize,
    lg: f64,
    weight: f64,
    s_idx: usize,
    e_idx: usize,
    bg: &mut [f32],
    r: &mut [f32],
    scratch: &mut GroupScratch,
) {
    let m = e_idx - s_idx;
    let has_nonzero = bg.iter().any(|&v| v != 0.0);
    // r̃_g = r + X_g β_g (residual with this group removed).
    if has_nonzero {
        for (k, &bj) in bg.iter().enumerate() {
            if bj != 0.0 {
                x.col_axpy(s_idx + k, bj, r);
            }
        }
    }
    // c_g = X_gᵀ r̃_g
    for k in 0..m {
        scratch.cg[k] = x.col_dot(s_idx + k, r);
    }
    // Group-level zero test (KKT / eq. (30)).
    let lim = params.lambda1 * weight;
    if shrink_norm(&scratch.cg[..m], params.lambda2) <= lim {
        bg.fill(0.0);
        return; // r already excludes the group
    }
    // Inner prox-gradient on the group subproblem.
    let step = 1.0 / lg;
    for _ in 0..inner_steps {
        // grad = X_gᵀ(X_g β_g − r̃_g) = (X_gᵀ X_g β_g) − c_g.
        // Compute X_g β_g then dot per column (m is small).
        // u = β_g − step * grad
        // Using: grad_k = dot(x_k, X_g β_g) − c_k.
        scratch.xb.fill(0.0);
        for (k, &bj) in bg.iter().enumerate() {
            if bj != 0.0 {
                x.col_axpy(s_idx + k, bj, &mut scratch.xb);
            }
        }
        for k in 0..m {
            let grad_k = x.col_dot(s_idx + k, &scratch.xb) - scratch.cg[k];
            scratch.wg[k] = bg[k] - (step as f32) * grad_k;
        }
        sgl_prox_group(
            &scratch.wg[..m],
            step * params.lambda2,
            step * lim,
            &mut scratch.bg_new[..m],
        );
        bg.copy_from_slice(&scratch.bg_new[..m]);
    }
    // Put the group's contribution back into the residual.
    for (k, &bj) in bg.iter().enumerate() {
        if bj != 0.0 {
            x.col_axpy(s_idx + k, -bj, r);
        }
    }
}

/// Raw handles to the sweep's shared state for the colored-class dispatch.
/// `Sync` is sound only under the coloring invariant — see the SAFETY
/// comment at the dispatch site.
struct SweepShared {
    beta: *mut f32,
    r: *mut f32,
    n: usize,
}

// SAFETY: the raw pointers are only dereferenced inside a colored-class
// dispatch, where the coloring invariant guarantees that concurrently
// processed groups touch disjoint β ranges and disjoint residual rows —
// see the SAFETY comment at the dispatch site in `sweep_once`.
unsafe impl Sync for SweepShared {}

/// One full sweep over the groups — sequential index order, or the colored
/// class schedule when `coloring` is given. The **single** sweep home
/// shared by [`solve_bcd`]'s static loop and the dynamic-screening loop,
/// so both execute byte-for-byte the same per-group operations (which is
/// what keeps the colored/sequential bitwise-parity guarantee intact).
#[allow(clippy::too_many_arguments)]
fn sweep_once<M: DesignMatrix>(
    x: &M,
    groups: &GroupStructure,
    ranges: &[(usize, usize)],
    params: &SglParams,
    inner_steps: usize,
    group_l: &[f64],
    coloring: Option<&GroupColoring>,
    beta: &mut [f32],
    r: &mut [f32],
    scratch: &mut GroupScratch,
    worker_scratch: &mut Option<Vec<Mutex<GroupScratch>>>,
    max_group: usize,
    n: usize,
) {
    match coloring {
        None => {
            // Sequential reference sweep: groups in index order.
            for (g, s_idx, e_idx) in groups.iter() {
                update_group(
                    x,
                    params,
                    inner_steps,
                    group_l[g],
                    groups.weight(g),
                    s_idx,
                    e_idx,
                    &mut beta[s_idx..e_idx],
                    r,
                    scratch,
                );
            }
        }
        Some(col) => {
            // Colored sweep: classes in level order; groups inside a
            // class commute exactly (disjoint touched rows), so the
            // pool dispatch is bitwise identical to the sequential
            // sweep at every worker count.
            for class in col.classes() {
                if class.len() <= 1 || pool::num_threads() <= 1 {
                    for &g in class {
                        let (s_idx, e_idx) = ranges[g];
                        update_group(
                            x,
                            params,
                            inner_steps,
                            group_l[g],
                            groups.weight(g),
                            s_idx,
                            e_idx,
                            &mut beta[s_idx..e_idx],
                            r,
                            scratch,
                        );
                    }
                    continue;
                }
                let scratches = worker_scratch.get_or_insert_with(|| {
                    (0..pool::num_threads())
                        .map(|_| Mutex::new(GroupScratch::new(max_group, n)))
                        .collect()
                });
                // Shadow-ownership claims (race-check builds only): before
                // writing, each task claims its group's β range and touched
                // residual rows under regions keyed by the buffer addresses.
                // A coloring bug — two concurrent workers sharing a row —
                // panics naming both claim sites instead of corrupting the
                // solve. `row_claims[k]` is the touched-row bitset of group
                // `class[k]`.
                let beta_key = beta.as_ptr() as usize;
                let r_key = r.as_ptr() as usize;
                let _beta_region = race::write_region(beta_key);
                let _r_region = race::write_region(r_key);
                let row_claims: Vec<Vec<u64>> = if race::ENABLED {
                    class
                        .iter()
                        .map(|&g| {
                            let mut bits = vec![0u64; n.div_ceil(64).max(1)];
                            let (s_idx, e_idx) = ranges[g];
                            for j in s_idx..e_idx {
                                x.col_touched_rows(j, &mut bits);
                            }
                            bits
                        })
                        .collect()
                } else {
                    Vec::new()
                };
                let row_claims_ref = &row_claims;
                let shared = SweepShared { beta: beta.as_mut_ptr(), r: r.as_mut_ptr(), n };
                let shared_ref = &shared;
                pool::parallel_for_chunks(class.len(), |w, cs, ce| {
                    let mut ws = scratches[w].lock().unwrap();
                    for (k, &g) in class[cs..ce].iter().enumerate() {
                        let (s_idx, e_idx) = ranges[g];
                        race::claim_range(
                            beta_key,
                            w,
                            s_idx,
                            e_idx,
                            "sgl/bcd.rs colored sweep β group range",
                        );
                        if race::ENABLED {
                            race::claim_bits(
                                r_key,
                                w,
                                &row_claims_ref[cs + k],
                                "sgl/bcd.rs colored sweep residual touched rows",
                            );
                        }
                        // SAFETY: groups within one color class have
                        // pairwise-disjoint coefficient ranges and
                        // pairwise-disjoint touched-row sets (the
                        // GroupColoring invariant, property-tested in
                        // sgl/coloring.rs), and `update_group` only
                        // reads/writes β in `[s_idx, e_idx)` and `r` at
                        // the group's touched rows. Every *dynamic*
                        // access across concurrent tasks is therefore
                        // disjoint, and the dispatch's latch blocks
                        // until every task finishes before β/r are
                        // touched again (release/acquire via the
                        // round's mutex). Caveat, stated openly: the
                        // `r` slices below span the full residual, so
                        // concurrent tasks hold *overlapping* `&mut
                        // [f32]` whose accessed elements never overlap.
                        // LLVM `noalias` is not violated (each call's
                        // accessed set is disjoint from every other
                        // pointer's accesses during that call), but
                        // strict aliasing checkers (Miri/Stacked
                        // Borrows) reject overlapping `&mut` on
                        // principle — the slice-based column kernels
                        // leave no dependency-free way to hand each
                        // task only its non-contiguous touched rows.
                        // Confined to this block; the sequential sweep
                        // shares none of it.
                        let (bg, rr) = unsafe {
                            (
                                std::slice::from_raw_parts_mut(
                                    shared_ref.beta.add(s_idx),
                                    e_idx - s_idx,
                                ),
                                std::slice::from_raw_parts_mut(shared_ref.r, shared_ref.n),
                            )
                        };
                        update_group(
                            x,
                            params,
                            inner_steps,
                            group_l[g],
                            groups.weight(g),
                            s_idx,
                            e_idx,
                            bg,
                            rr,
                            &mut ws,
                        );
                    }
                });
            }
        }
    }
}

/// Per-group Lipschitz constants `L_g = ‖X_g‖₂²` with the solver's
/// canonical power-iteration recipe (seed `0xBCD`, tol `1e-6`, ≤500
/// iterations). The single source of truth shared by [`solve_bcd`]'s
/// self-computing fallback and the path runners' once-per-path caches —
/// keeping both sites on one recipe guarantees the cached constants match
/// what the solver would compute for the full problem.
pub fn bcd_group_lipschitz<M: DesignMatrix>(x: &M, ranges: &[(usize, usize)]) -> Vec<f64> {
    let mut rng = Rng::seed_from_u64(0xBCD);
    group_spectral_norms(x, ranges, 1e-6, 500, &mut rng)
        .into_iter()
        .map(|s| (s * s).max(f64::MIN_POSITIVE))
        .collect()
}

/// Solve SGL by cyclic block coordinate descent.
///
/// Pathwise consumers never call this directly: the streaming driver's
/// [`crate::coordinator::driver`] solver dispatch owns the
/// `SolverKind::Bcd` arm (per-group Lipschitz cache, projected coloring),
/// so runner and CV paths are guaranteed to construct identical
/// [`BcdOptions`] — the divergence that motivated the driver (CV
/// hardcoding FISTA) cannot recur per-solver either.
pub fn solve_bcd<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    params: &SglParams,
    warm_start: Option<&[f32]>,
    opts: &BcdOptions<'_>,
) -> super::fista::SolveResult {
    if let Some(state) = opts.dynamic_screen {
        return solve_bcd_dynamic(prob, params, warm_start, opts, state);
    }
    let n = prob.n_samples();
    let p = prob.n_features();
    let scale_ref = null_objective(prob.y).max(1e-10);

    // Group-local Lipschitz constants ‖X_g‖₂² — taken from the caller's
    // path-level cache when provided, otherwise computed here (one power
    // iteration per group, per call).
    let ranges = prob.groups.ranges();
    let computed_l: Vec<f64>;
    let group_l: &[f64] = match opts.group_lipschitz {
        Some(gl) => {
            assert_eq!(
                gl.len(),
                ranges.len(),
                "group_lipschitz has {} entries for {} groups",
                gl.len(),
                ranges.len()
            );
            gl
        }
        None => {
            computed_l = bcd_group_lipschitz(prob.x, &ranges);
            &computed_l
        }
    };

    // Colored schedule for pool-parallel sweeps (see [`GroupColoring`]):
    // taken from the caller's path-level cache when provided, otherwise
    // computed here. `None` = the sequential reference sweep.
    let computed_coloring: GroupColoring;
    let coloring: Option<&GroupColoring> = if opts.parallel_groups {
        match opts.coloring {
            Some(c) => {
                assert_eq!(
                    c.n_groups(),
                    ranges.len(),
                    "coloring covers {} groups for {} groups",
                    c.n_groups(),
                    ranges.len()
                );
                Some(c)
            }
            None => {
                computed_coloring = GroupColoring::compute(prob.x, prob.groups);
                Some(&computed_coloring)
            }
        }
    } else {
        None
    };
    // An all-singleton coloring IS the sequential schedule (dense designs:
    // every pair conflicts, so levels come out in index order) — drop to
    // the plain sequential sweep instead of paying per-class bookkeeping
    // for zero parallelism. Bitwise-neutral by the linear-extension
    // argument in `sgl::coloring`.
    let coloring = coloring.filter(|c| !c.is_trivially_sequential());

    let mut beta: Vec<f32> = match warm_start {
        Some(b) => b.to_vec(),
        None => vec![0.0; p],
    };
    let mut r = vec![0.0f32; n];
    residual(prob, &beta, &mut r);

    let max_group = ranges.iter().map(|&(s, e)| e - s).max().unwrap_or(0);
    // Work buffers hoisted out of the sweep loop — the sequential hot solve
    // is allocation-free after this point. The colored sweep gets one
    // scratch set per pool worker, lazily (only when a class is actually
    // dispatched in parallel).
    let mut scratch = GroupScratch::new(max_group, n);
    let mut worker_scratch: Option<Vec<Mutex<GroupScratch>>> = None;
    let mut c = vec![0.0f32; p];

    let mut gap = f64::INFINITY;
    let mut converged = false;
    let mut deadline_hit = false;
    let mut sweeps = 0;

    for sweep in 0..opts.max_sweeps {
        sweeps = sweep + 1;
        sweep_once(
            prob.x,
            prob.groups,
            &ranges,
            params,
            opts.inner_steps,
            group_l,
            coloring,
            &mut beta,
            &mut r,
            &mut scratch,
            &mut worker_scratch,
            max_group,
            n,
        );

        if (sweep + 1) % opts.check_every == 0 || sweep + 1 == opts.max_sweeps {
            crate::util::fault::maybe_poison_residual(&mut r);
            prob.x.matvec_t(&r, &mut c);
            let (g, _) = duality_gap(prob, params, &beta, &r, &c);
            gap = g;
            if gap <= opts.tol * scale_ref {
                converged = true;
                break;
            }
            if !gap.is_finite() {
                // A non-finite gap can never satisfy the stopping rule —
                // stop and surface `converged = false` instead of
                // sweeping (and propagating NaN) to the cap.
                break;
            }
            if super::fista::deadline_passed(opts.deadline) {
                deadline_hit = true;
                break;
            }
        }
    }

    residual(prob, &beta, &mut r);
    let objective = objective_with_residual(prob, params, &beta, &r).total();
    let budget_exhausted = deadline_hit || (!converged && sweeps == opts.max_sweeps);
    super::fista::SolveResult {
        beta,
        iters: sweeps,
        gap,
        objective,
        converged,
        budget_exhausted,
        resid: r,
    }
}

/// Mutable state of a dynamic-screening BCD solve, shared across epochs.
struct BcdDynCore {
    beta: Vec<f32>,
    r: Vec<f32>,
    c: Vec<f32>,
    scratch: GroupScratch,
    worker_scratch: Option<Vec<Mutex<GroupScratch>>>,
    gap: f64,
    converged: bool,
    deadline_hit: bool,
    sweeps: usize,
    max_group: usize,
    n: usize,
}

/// Run dynamic-BCD sweeps on the current problem until convergence or the
/// sweep cap (→ `None`) or a GAP eviction (→ the plan, with the evicted
/// coefficients already folded back into the incremental residual —
/// `r += X_k β_k`, exactly the `update_group` removal step — while the
/// columns are still addressable). Instantiated at exactly two matrix
/// types per caller: `M` before the first eviction, `ScreenedView<M>`
/// after.
#[allow(clippy::too_many_arguments)]
fn bcd_dynamic_epoch<M: DesignMatrix>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    ranges: &[(usize, usize)],
    params: &SglParams,
    opts: &BcdOptions<'_>,
    group_l: &[f64],
    coloring: Option<&GroupColoring>,
    scale_ref: f64,
    state: &RefCell<GapSafeDynamic>,
    core: &mut BcdDynCore,
) -> Option<EvictPlan> {
    let p = groups.n_features();
    core.c.resize(p, 0.0);
    let vprob = SglProblem::new(x, y, groups);
    // Trivially-sequential colorings degrade to the plain sweep, exactly
    // like the static path.
    let coloring = coloring.filter(|c| !c.is_trivially_sequential());
    while core.sweeps < opts.max_sweeps {
        core.sweeps += 1;
        sweep_once(
            x,
            groups,
            ranges,
            params,
            opts.inner_steps,
            group_l,
            coloring,
            &mut core.beta,
            &mut core.r,
            &mut core.scratch,
            &mut core.worker_scratch,
            core.max_group,
            core.n,
        );
        if core.sweeps % opts.check_every == 0 || core.sweeps == opts.max_sweeps {
            crate::util::fault::maybe_poison_residual(&mut core.r);
            x.matvec_t(&core.r, &mut core.c);
            let (g, s_feas) = duality_gap(&vprob, params, &core.beta, &core.r, &core.c);
            core.gap = g;
            if g <= opts.tol * scale_ref {
                core.converged = true;
                return None;
            }
            if !g.is_finite() {
                // Same recovery as the static loop: stop on a poisoned
                // evaluation, report `converged = false`.
                return None;
            }
            if super::fista::deadline_passed(opts.deadline) {
                core.deadline_hit = true;
                return None;
            }
            if core.sweeps < opts.max_sweeps {
                // Gap floored at the f32 evaluation noise scale (see
                // `gap_with_noise_floor`).
                if let Some(plan) = state.borrow_mut().check(
                    groups,
                    params.lambda2,
                    &core.c,
                    crate::screening::gap_safe::gap_with_noise_floor(g, scale_ref),
                    s_feas,
                ) {
                    for (k, &kept) in plan.feature_kept.iter().enumerate() {
                        if !kept && core.beta[k] != 0.0 {
                            x.col_axpy(k, core.beta[k], &mut core.r);
                        }
                    }
                    return Some(plan);
                }
            }
        }
    }
    None
}

/// The dynamic-screening BCD solve. Phase 1 sweeps the caller's matrix
/// directly (no view indirection until an eviction fires); each eviction
/// compacts the iterate, group structure and per-group Lipschitz
/// constants and — for pool-parallel sweeps — re-projects the coloring
/// onto the survivors (class-disjointness is preserved under subsetting,
/// the same argument as the per-λ projection in the path driver), then
/// sweeping continues on a survivor [`ScreenedView`].
fn solve_bcd_dynamic<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    params: &SglParams,
    warm_start: Option<&[f32]>,
    opts: &BcdOptions<'_>,
    state: &RefCell<GapSafeDynamic>,
) -> super::fista::SolveResult {
    let n = prob.n_samples();
    let p0 = prob.n_features();
    let scale_ref = null_objective(prob.y).max(1e-10);

    let ranges0 = prob.groups.ranges();
    // Owned spectral/coloring data so evictions can project them.
    let mut group_l: Vec<f64> = match opts.group_lipschitz {
        Some(gl) => {
            assert_eq!(gl.len(), ranges0.len(), "group_lipschitz entries must match groups");
            gl.to_vec()
        }
        None => bcd_group_lipschitz(prob.x, &ranges0),
    };
    let mut coloring: Option<GroupColoring> = if opts.parallel_groups {
        match opts.coloring {
            Some(c) => {
                assert_eq!(c.n_groups(), ranges0.len(), "coloring must cover every group");
                Some(c.clone())
            }
            None => Some(GroupColoring::compute(prob.x, prob.groups)),
        }
    } else {
        None
    };

    let beta0: Vec<f32> = match warm_start {
        Some(b) => b.to_vec(),
        None => vec![0.0; p0],
    };
    let mut r0 = vec![0.0f32; n];
    residual(prob, &beta0, &mut r0);
    // Scratch sized for the original problem: group sizes only shrink.
    let max_group = ranges0.iter().map(|&(s, e)| e - s).max().unwrap_or(0);
    let mut core = BcdDynCore {
        beta: beta0,
        r: r0,
        c: Vec::new(),
        scratch: GroupScratch::new(max_group, n),
        worker_scratch: None,
        gap: f64::INFINITY,
        converged: false,
        deadline_hit: false,
        sweeps: 0,
        max_group,
        n,
    };
    let mut cols: Vec<usize> = (0..p0).collect();
    let mut all_zero = false;

    // Phase 1: the caller's problem, zero overhead vs the static loop.
    let mut pending = bcd_dynamic_epoch(
        prob.x,
        prob.y,
        prob.groups,
        &ranges0,
        params,
        opts,
        &group_l,
        coloring.as_ref(),
        scale_ref,
        state,
        &mut core,
    );
    // Phase 2: compact and continue on survivor views until done.
    let mut groups: Option<GroupStructure> = None;
    while let Some(plan) = pending.take() {
        retain_by_mask(&mut core.beta, &plan.feature_kept);
        retain_by_mask(&mut cols, &plan.feature_kept);
        let compacted = groups
            .as_ref()
            .unwrap_or(prob.groups)
            .compact(&plan.feature_kept);
        match compacted {
            Some((g2, gmap)) => {
                group_l = gmap.iter().map(|&g| group_l[g]).collect();
                coloring = coloring.as_ref().map(|cl| cl.project(&gmap));
                groups = Some(g2);
            }
            None => {
                core.beta.clear();
                cols.clear();
                core.gap = 0.0;
                core.converged = true;
                all_zero = true;
                break;
            }
        }
        let cur = groups.as_ref().expect("set above");
        let ranges = cur.ranges();
        let view = ScreenedView::new(prob.x, cols.clone());
        pending = bcd_dynamic_epoch(
            &view,
            prob.y,
            cur,
            &ranges,
            params,
            opts,
            &group_l,
            coloring.as_ref(),
            scale_ref,
            state,
            &mut core,
        );
    }

    // Scatter to the caller's space; final residual/objective over the
    // full problem equal the survivor view's (evicted coords are zero).
    let mut full = vec![0.0f32; p0];
    for (k, &j) in cols.iter().enumerate() {
        full[j] = core.beta[k];
    }
    let (objective, resid) = if all_zero {
        (null_objective(prob.y), prob.y.to_vec())
    } else {
        residual(prob, &full, &mut core.r);
        let obj = objective_with_residual(prob, params, &full, &core.r).total();
        (obj, core.r)
    };
    super::fista::SolveResult {
        beta: full,
        iters: core.sweeps,
        gap: core.gap,
        objective,
        converged: core.converged,
        budget_exhausted: core.deadline_hit
            || (!core.converged && core.sweeps == opts.max_sweeps),
        resid,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::groups::GroupStructure;
    use crate::linalg::DenseMatrix;
    use crate::screening::lambda_max::sgl_lambda_max;
    use crate::sgl::fista::{solve_fista, FistaOptions};
    use crate::util::Rng;

    fn problem(seed: u64, n: usize, p: usize, gsize: usize) -> (DenseMatrix, Vec<f32>, GroupStructure) {
        let mut rng = Rng::seed_from_u64(seed);
        let x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian() as f32);
        let g = GroupStructure::uniform(p, p / gsize);
        let mut beta = vec![0.0f32; p];
        for j in 0..p / 5 {
            beta[j * 5] = rng.normal(0.0, 1.0) as f32;
        }
        let mut y = vec![0.0f32; n];
        x.matvec(&beta, &mut y);
        for v in y.iter_mut() {
            *v += rng.normal(0.0, 0.01) as f32;
        }
        (x, y, g)
    }

    #[test]
    fn bcd_matches_fista_objective() {
        let (x, y, g) = problem(31, 25, 40, 4);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 1.0);
        let params = SglParams::from_alpha_lambda(1.0, 0.3 * lm.lambda_max);
        // f32 data puts an absolute floor on the attainable gap; 1e-7
        // relative is comfortably above it for this problem scale.
        let fr = solve_fista(&prob, &params, None, &FistaOptions { tol: 1e-7, ..Default::default() });
        let br = solve_bcd(&prob, &params, None, &BcdOptions { tol: 1e-7, ..Default::default() });
        assert!(br.converged && fr.converged);
        assert!(
            (fr.objective - br.objective).abs() < 1e-4 * fr.objective.abs().max(1.0),
            "fista={} bcd={}",
            fr.objective,
            br.objective
        );
        // Support sets should agree too.
        for j in 0..x.cols() {
            let zf = fr.beta[j].abs() < 1e-4;
            let zb = br.beta[j].abs() < 1e-4;
            assert_eq!(zf, zb, "support mismatch at {j}");
        }
    }

    #[test]
    fn bcd_zero_at_lambda_max() {
        let (x, y, g) = problem(32, 15, 20, 4);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 0.8);
        let params = SglParams::from_alpha_lambda(0.8, lm.lambda_max * 1.001);
        let r = solve_bcd(&prob, &params, None, &BcdOptions::default());
        assert!(r.beta.iter().all(|&b| b == 0.0));
    }

    /// Paired-block sparse design on [`crate::sgl::coloring::paired_block_band`]
    /// — the red/black 2-colorable structure the coloring tests validate,
    /// here with random values and a planted signal.
    fn paired_block_problem(
        blocks: usize,
        cols_per_group: usize,
        seed: u64,
    ) -> (crate::linalg::CscMatrix, Vec<f32>, GroupStructure) {
        let mut rng = Rng::seed_from_u64(seed);
        let n = 8 * blocks;
        let g_count = 2 * blocks;
        let p = g_count * cols_per_group;
        let groups = GroupStructure::uniform(p, g_count);
        let d = DenseMatrix::from_fn(n, p, |i, j| {
            let (lo, hi) = crate::sgl::coloring::paired_block_band(j / cols_per_group);
            if i >= lo && i < hi {
                rng.gaussian() as f32
            } else {
                0.0
            }
        });
        let x = crate::linalg::CscMatrix::from_dense(&d);
        let mut beta = vec![0.0f32; p];
        for g in 0..g_count {
            if g % 3 != 2 {
                beta[g * cols_per_group] = rng.normal(0.0, 1.0) as f32;
            }
        }
        let mut y = vec![0.0f32; n];
        DesignMatrix::matvec(&x, &beta, &mut y);
        for v in y.iter_mut() {
            *v += rng.normal(0.0, 0.01) as f32;
        }
        (x, y, groups)
    }

    #[test]
    fn colored_sweep_bitwise_matches_sequential_on_sparse_blocks() {
        let (x, y, g) = paired_block_problem(5, 3, 61);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 1.0);
        let params = SglParams::from_alpha_lambda(1.0, 0.25 * lm.lambda_max);
        let opts_seq = BcdOptions { tol: 1e-7, ..Default::default() };
        let seq = solve_bcd(&prob, &params, None, &opts_seq);
        // Self-computed coloring.
        let par = solve_bcd(
            &prob,
            &params,
            None,
            &BcdOptions { parallel_groups: true, ..opts_seq.clone() },
        );
        // Caller-cached coloring (the path runners' mode).
        let col = crate::sgl::GroupColoring::compute(&x, &g);
        assert!(col.max_class_len() > 1, "design must actually be parallelizable");
        let par_cached = solve_bcd(
            &prob,
            &params,
            None,
            &BcdOptions { parallel_groups: true, coloring: Some(&col), ..opts_seq.clone() },
        );
        for other in [&par, &par_cached] {
            assert_eq!(seq.iters, other.iters, "sweep counts diverged");
            assert_eq!(seq.gap.to_bits(), other.gap.to_bits(), "gap diverged");
            assert_eq!(
                seq.objective.to_bits(),
                other.objective.to_bits(),
                "objective diverged"
            );
            for j in 0..seq.beta.len() {
                assert_eq!(
                    seq.beta[j].to_bits(),
                    other.beta[j].to_bits(),
                    "β[{j}] colored ≠ sequential"
                );
            }
        }
        assert!(seq.converged);
    }

    /// Seed a deliberately *invalid* coloring — two paired-block groups
    /// that share residual rows forced into one class — and assert the
    /// `race-check` shadow-ownership checker panics on the overlapping
    /// cross-worker row claims before any corrupted write lands.
    #[test]
    #[cfg(feature = "race-check")]
    fn race_check_catches_seeded_bad_coloring() {
        if pool::num_threads() < 2 {
            // The claims only race under a real pool dispatch; with one
            // thread the class runs serially (and correctly).
            return;
        }
        let (x, y, g) = paired_block_problem(2, 3, 67);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 1.0);
        let params = SglParams::from_alpha_lambda(1.0, 0.25 * lm.lambda_max);
        // Groups 0 and 1 share a row band (they are a block pair), so a
        // class [0, 1] violates the coloring invariant.
        let bad = GroupColoring::from_classes(vec![vec![0, 1], vec![2], vec![3]], 4);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            solve_bcd(
                &prob,
                &params,
                None,
                &BcdOptions {
                    parallel_groups: true,
                    coloring: Some(&bad),
                    ..Default::default()
                },
            )
        }))
        .expect_err("bad coloring must trip the shadow-ownership checker");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string panic>".into());
        assert!(msg.contains("race-check"), "unexpected panic: {msg}");
        assert!(msg.contains("residual touched rows"), "unexpected panic: {msg}");
    }

    #[test]
    fn colored_sweep_on_dense_degenerates_to_sequential() {
        // Dense columns touch every row → singleton classes in index order;
        // parallel_groups must be a bitwise no-op.
        let (x, y, g) = problem(35, 20, 24, 3);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 1.0);
        let params = SglParams::from_alpha_lambda(1.0, 0.4 * lm.lambda_max);
        let seq = solve_bcd(&prob, &params, None, &BcdOptions::default());
        let par = solve_bcd(
            &prob,
            &params,
            None,
            &BcdOptions { parallel_groups: true, ..Default::default() },
        );
        assert_eq!(seq.iters, par.iters);
        for j in 0..seq.beta.len() {
            assert_eq!(seq.beta[j].to_bits(), par.beta[j].to_bits());
        }
    }

    #[test]
    fn dynamic_screening_matches_static_support() {
        let (x, y, g) = problem(36, 25, 40, 4);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 1.0);
        let params = SglParams::from_alpha_lambda(1.0, 0.3 * lm.lambda_max);
        let opts = BcdOptions { tol: 1e-7, ..Default::default() };
        let plain = solve_bcd(&prob, &params, None, &opts);
        let mut rng = Rng::seed_from_u64(0xD8);
        let gs = group_spectral_norms(&x, &g.ranges(), 1e-6, 500, &mut rng);
        let state = std::cell::RefCell::new(crate::screening::gap_safe::GapSafeDynamic::new(
            1.0,
            x.col_norms(),
            gs,
        ));
        let dynamic = solve_bcd(
            &prob,
            &params,
            None,
            &BcdOptions { dynamic_screen: Some(&state), ..opts },
        );
        assert!(dynamic.converged);
        assert_eq!(dynamic.beta.len(), prob.n_features());
        assert!(
            (plain.objective - dynamic.objective).abs()
                < 1e-4 * plain.objective.abs().max(1.0),
            "objectives diverged: {} vs {}",
            plain.objective,
            dynamic.objective
        );
        assert!(
            crate::screening::gap_safe::same_support_at_resolution(&plain.beta, &dynamic.beta),
            "support mismatch between static and dynamic solves"
        );
    }

    #[test]
    fn dynamic_screening_composes_with_colored_sweeps() {
        // Eviction must re-project the coloring; the solve stays correct
        // (same optimum as the sequential dynamic solve) on the canonical
        // 2-colorable paired-block design.
        let (x, y, g) = paired_block_problem(5, 3, 62);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 1.0);
        let params = SglParams::from_alpha_lambda(1.0, 0.25 * lm.lambda_max);
        let opts = BcdOptions { tol: 1e-7, ..Default::default() };
        let reference = solve_bcd(&prob, &params, None, &opts);
        let mk_state = || {
            let mut rng = Rng::seed_from_u64(0xD9);
            let gs = group_spectral_norms(&x, &g.ranges(), 1e-6, 500, &mut rng);
            std::cell::RefCell::new(crate::screening::gap_safe::GapSafeDynamic::new(
                1.0,
                DesignMatrix::col_norms(&x),
                gs,
            ))
        };
        let seq_state = mk_state();
        let seq = solve_bcd(
            &prob,
            &params,
            None,
            &BcdOptions { dynamic_screen: Some(&seq_state), ..opts.clone() },
        );
        let par_state = mk_state();
        let par = solve_bcd(
            &prob,
            &params,
            None,
            &BcdOptions {
                parallel_groups: true,
                dynamic_screen: Some(&par_state),
                ..opts.clone()
            },
        );
        // Colored + dynamic is bitwise identical to sequential + dynamic:
        // the sweep arithmetic is shared and evictions are decided by the
        // same worker-count-invariant gap checks.
        assert_eq!(seq.iters, par.iters);
        for j in 0..seq.beta.len() {
            assert_eq!(seq.beta[j].to_bits(), par.beta[j].to_bits(), "β[{j}] diverged");
        }
        assert_eq!(seq_state.borrow().evicted(), par_state.borrow().evicted());
        assert!(
            crate::screening::gap_safe::same_support_at_resolution(&reference.beta, &seq.beta),
            "support mismatch between plain and dynamic solves"
        );
    }

    #[test]
    fn bcd_warm_start() {
        let (x, y, g) = problem(33, 20, 24, 3);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 1.0);
        let p1 = SglParams::from_alpha_lambda(1.0, 0.5 * lm.lambda_max);
        let r1 = solve_bcd(&prob, &p1, None, &BcdOptions::default());
        let p2 = SglParams::from_alpha_lambda(1.0, 0.45 * lm.lambda_max);
        let warm = solve_bcd(&prob, &p2, Some(&r1.beta), &BcdOptions::default());
        let cold = solve_bcd(&prob, &p2, None, &BcdOptions::default());
        assert!(warm.iters <= cold.iters);
    }
}
