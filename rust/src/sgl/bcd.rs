//! Block coordinate descent solver for SGL (SLEP-style baseline).
//!
//! Cyclic sweeps over groups maintaining the residual incrementally. For
//! each group the zero test `‖S_{λ₂}(X_gᵀ r̃_g)‖ ≤ λ₁√n_g` (the group-level
//! KKT condition, cf. the paper's eq. (30)) is checked first; surviving
//! groups run a few inner proximal-gradient steps with the *group-local*
//! Lipschitz constant `‖X_g‖₂²`, which converges far faster per flop than
//! global-step methods when groups are small.
//!
//! This is the solver role SLEP [12] plays in the paper's experiments; the
//! benches compare it against [`super::fista`] as an ablation.

use super::dual::{duality_gap, null_objective};
use super::objective::{objective_with_residual, residual};
use super::problem::{SglParams, SglProblem};
use crate::linalg::power::group_spectral_norms;
use crate::linalg::DesignMatrix;
use crate::prox::{sgl_prox_group, shrink_norm};
use crate::util::Rng;

/// Options for the BCD solver.
#[derive(Debug, Clone)]
pub struct BcdOptions<'a> {
    /// Max full sweeps over all groups.
    pub max_sweeps: usize,
    /// Relative duality-gap tolerance (same semantics as FISTA's).
    pub tol: f64,
    /// Inner proximal-gradient steps per group per sweep.
    pub inner_steps: usize,
    /// Gap-check cadence in sweeps.
    pub check_every: usize,
    /// Pre-computed per-group Lipschitz constants `L_g = ‖X_g‖₂²` (one per
    /// group, in group order). When `None` (the default, and the behaviour
    /// of standalone calls) they are computed by power iteration per call.
    /// The path runners supply the full-matrix values cached once per path:
    /// for a screened subproblem `σmax(X_g[:,S]) ≤ σmax(X_g)`, so the
    /// cached constants are valid (conservative) upper bounds.
    pub group_lipschitz: Option<&'a [f64]>,
}

impl Default for BcdOptions<'_> {
    fn default() -> Self {
        BcdOptions {
            max_sweeps: 2000,
            tol: 1e-6,
            inner_steps: 4,
            check_every: 5,
            group_lipschitz: None,
        }
    }
}

/// Per-group Lipschitz constants `L_g = ‖X_g‖₂²` with the solver's
/// canonical power-iteration recipe (seed `0xBCD`, tol `1e-6`, ≤500
/// iterations). The single source of truth shared by [`solve_bcd`]'s
/// self-computing fallback and the path runners' once-per-path caches —
/// keeping both sites on one recipe guarantees the cached constants match
/// what the solver would compute for the full problem.
pub fn bcd_group_lipschitz<M: DesignMatrix>(x: &M, ranges: &[(usize, usize)]) -> Vec<f64> {
    let mut rng = Rng::seed_from_u64(0xBCD);
    group_spectral_norms(x, ranges, 1e-6, 500, &mut rng)
        .into_iter()
        .map(|s| (s * s).max(f64::MIN_POSITIVE))
        .collect()
}

/// Solve SGL by cyclic block coordinate descent.
pub fn solve_bcd<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    params: &SglParams,
    warm_start: Option<&[f32]>,
    opts: &BcdOptions<'_>,
) -> super::fista::SolveResult {
    let n = prob.n_samples();
    let p = prob.n_features();
    let scale_ref = null_objective(prob.y).max(1e-10);

    // Group-local Lipschitz constants ‖X_g‖₂² — taken from the caller's
    // path-level cache when provided, otherwise computed here (one power
    // iteration per group, per call).
    let ranges = prob.groups.ranges();
    let computed_l: Vec<f64>;
    let group_l: &[f64] = match opts.group_lipschitz {
        Some(gl) => {
            assert_eq!(
                gl.len(),
                ranges.len(),
                "group_lipschitz has {} entries for {} groups",
                gl.len(),
                ranges.len()
            );
            gl
        }
        None => {
            computed_l = bcd_group_lipschitz(prob.x, &ranges);
            &computed_l
        }
    };

    let mut beta: Vec<f32> = match warm_start {
        Some(b) => b.to_vec(),
        None => vec![0.0; p],
    };
    let mut r = vec![0.0f32; n];
    residual(prob, &beta, &mut r);

    let max_group = ranges.iter().map(|&(s, e)| e - s).max().unwrap_or(0);
    let mut cg = vec![0.0f32; max_group];
    let mut wg = vec![0.0f32; max_group];
    let mut bg_new = vec![0.0f32; max_group];
    // Work buffers hoisted out of the sweep loop — the hot solve is
    // allocation-free after this point.
    let mut xb = vec![0.0f32; n];
    let mut c = vec![0.0f32; p];

    let mut gap = f64::INFINITY;
    let mut converged = false;
    let mut sweeps = 0;

    for sweep in 0..opts.max_sweeps {
        sweeps = sweep + 1;
        for (g, s_idx, e_idx) in prob.groups.iter() {
            let m = e_idx - s_idx;
            let bg = &mut beta[s_idx..e_idx];
            let has_nonzero = bg.iter().any(|&v| v != 0.0);
            // r̃_g = r + X_g β_g (residual with this group removed).
            if has_nonzero {
                for (k, &bj) in bg.iter().enumerate() {
                    if bj != 0.0 {
                        prob.x.col_axpy(s_idx + k, bj, &mut r);
                    }
                }
            }
            // c_g = X_gᵀ r̃_g
            for k in 0..m {
                cg[k] = prob.x.col_dot(s_idx + k, &r);
            }
            // Group-level zero test (KKT / eq. (30)).
            let lim = params.lambda1 * prob.groups.weight(g);
            if shrink_norm(&cg[..m], params.lambda2) <= lim {
                bg.fill(0.0);
                continue; // r already excludes the group
            }
            // Inner prox-gradient on the group subproblem.
            let lg = group_l[g];
            let step = 1.0 / lg;
            for _ in 0..opts.inner_steps {
                // grad = X_gᵀ(X_g β_g − r̃_g) = (X_gᵀ X_g β_g) − c_g.
                // Compute X_g β_g then dot per column (m is small).
                // u = β_g − step * grad
                // Using: grad_k = dot(x_k, X_g β_g) − c_k.
                xb.fill(0.0);
                for (k, &bj) in bg.iter().enumerate() {
                    if bj != 0.0 {
                        prob.x.col_axpy(s_idx + k, bj, &mut xb);
                    }
                }
                for k in 0..m {
                    let grad_k = prob.x.col_dot(s_idx + k, &xb) - cg[k];
                    wg[k] = bg[k] - (step as f32) * grad_k;
                }
                sgl_prox_group(
                    &wg[..m],
                    step * params.lambda2,
                    step * lim,
                    &mut bg_new[..m],
                );
                bg.copy_from_slice(&bg_new[..m]);
            }
            // Put the group's contribution back into the residual.
            for (k, &bj) in bg.iter().enumerate() {
                if bj != 0.0 {
                    prob.x.col_axpy(s_idx + k, -bj, &mut r);
                }
            }
        }

        if (sweep + 1) % opts.check_every == 0 || sweep + 1 == opts.max_sweeps {
            prob.x.matvec_t(&r, &mut c);
            let (g, _) = duality_gap(prob, params, &beta, &r, &c);
            gap = g;
            if gap <= opts.tol * scale_ref {
                converged = true;
                break;
            }
        }
    }

    residual(prob, &beta, &mut r);
    let objective = objective_with_residual(prob, params, &beta, &r).total();
    super::fista::SolveResult { beta, iters: sweeps, gap, objective, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::groups::GroupStructure;
    use crate::linalg::DenseMatrix;
    use crate::screening::lambda_max::sgl_lambda_max;
    use crate::sgl::fista::{solve_fista, FistaOptions};
    use crate::util::Rng;

    fn problem(seed: u64, n: usize, p: usize, gsize: usize) -> (DenseMatrix, Vec<f32>, GroupStructure) {
        let mut rng = Rng::seed_from_u64(seed);
        let x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian() as f32);
        let g = GroupStructure::uniform(p, p / gsize);
        let mut beta = vec![0.0f32; p];
        for j in 0..p / 5 {
            beta[j * 5] = rng.normal(0.0, 1.0) as f32;
        }
        let mut y = vec![0.0f32; n];
        x.matvec(&beta, &mut y);
        for v in y.iter_mut() {
            *v += rng.normal(0.0, 0.01) as f32;
        }
        (x, y, g)
    }

    #[test]
    fn bcd_matches_fista_objective() {
        let (x, y, g) = problem(31, 25, 40, 4);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 1.0);
        let params = SglParams::from_alpha_lambda(1.0, 0.3 * lm.lambda_max);
        // f32 data puts an absolute floor on the attainable gap; 1e-7
        // relative is comfortably above it for this problem scale.
        let fr = solve_fista(&prob, &params, None, &FistaOptions { tol: 1e-7, ..Default::default() });
        let br = solve_bcd(&prob, &params, None, &BcdOptions { tol: 1e-7, ..Default::default() });
        assert!(br.converged && fr.converged);
        assert!(
            (fr.objective - br.objective).abs() < 1e-4 * fr.objective.abs().max(1.0),
            "fista={} bcd={}",
            fr.objective,
            br.objective
        );
        // Support sets should agree too.
        for j in 0..x.cols() {
            let zf = fr.beta[j].abs() < 1e-4;
            let zb = br.beta[j].abs() < 1e-4;
            assert_eq!(zf, zb, "support mismatch at {j}");
        }
    }

    #[test]
    fn bcd_zero_at_lambda_max() {
        let (x, y, g) = problem(32, 15, 20, 4);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 0.8);
        let params = SglParams::from_alpha_lambda(0.8, lm.lambda_max * 1.001);
        let r = solve_bcd(&prob, &params, None, &BcdOptions::default());
        assert!(r.beta.iter().all(|&b| b == 0.0));
    }

    #[test]
    fn bcd_warm_start() {
        let (x, y, g) = problem(33, 20, 24, 3);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 1.0);
        let p1 = SglParams::from_alpha_lambda(1.0, 0.5 * lm.lambda_max);
        let r1 = solve_bcd(&prob, &p1, None, &BcdOptions::default());
        let p2 = SglParams::from_alpha_lambda(1.0, 0.45 * lm.lambda_max);
        let warm = solve_bcd(&prob, &p2, Some(&r1.beta), &BcdOptions::default());
        let cold = solve_bcd(&prob, &p2, None, &BcdOptions::default());
        assert!(warm.iters <= cold.iters);
    }
}
