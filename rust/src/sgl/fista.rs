//! FISTA (accelerated proximal gradient) solver for SGL.
//!
//! Standard Beck–Teboulle acceleration with the exact composite SGL prox
//! ([`crate::prox::sgl_prox_group`]) and a *duality-gap* stopping rule —
//! exactness of the gap matters here because TLFre's safety guarantee is
//! stated for exact dual optima; the integration tests solve to tight gaps
//! before asserting the safety property.

use super::dual::{duality_gap, null_objective};
use super::objective::objective_with_residual;
use super::problem::{SglParams, SglProblem};
use crate::groups::GroupStructure;
use crate::linalg::power::spectral_norm;
use crate::linalg::{DesignMatrix, ScreenedView};
use crate::prox::sgl_prox_group;
use crate::screening::gap_safe::{EvictPlan, GapSafeDynamic};
use crate::util::{retain_by_mask, Rng};
use std::cell::RefCell;

/// Options controlling the FISTA solve.
#[derive(Debug, Clone)]
pub struct FistaOptions<'a> {
    /// Hard iteration cap.
    pub max_iter: usize,
    /// Relative duality-gap tolerance: stop when
    /// `gap ≤ tol · max(½‖y‖², ε)`.
    pub tol: f64,
    /// Gap-check cadence in iterations.
    pub check_every: usize,
    /// Pre-computed Lipschitz constant `L = ‖X‖₂²`; computed via power
    /// iteration when `None`.
    pub lipschitz: Option<f64>,
    /// Restart acceleration when the objective increases (adaptive
    /// restart; improves robustness on ill-conditioned reduced problems).
    pub adaptive_restart: bool,
    /// In-solver dynamic GAP-safe screening
    /// ([`crate::screening::gap_safe`]). At every gap check the state's
    /// sphere test runs on the check's own `(c, gap, scale)` — no extra
    /// matvec — and certified-zero features are **evicted from the live
    /// problem**: β/momentum state compact, the group structure drops
    /// emptied groups (original weights kept), and iteration continues on
    /// a survivor view of the caller's matrix. The returned β is scattered
    /// back to the caller's index space, and the cumulative eviction count
    /// is readable from the state afterwards. `None` (default) is the
    /// plain solve, byte-for-byte the pre-dynamic behaviour.
    pub dynamic_screen: Option<&'a RefCell<GapSafeDynamic>>,
    /// Wall-clock deadline for graceful degradation. Checked at gap-check
    /// cadence *after* the gap is measured: once past the deadline the
    /// solver returns best-so-far with `converged = false`, the last
    /// measured gap as a certified suboptimality bound, and
    /// `budget_exhausted = true`. `None` (default) never times out.
    /// Bitwise-parity paths must leave this unset — wall-clock varies by
    /// machine and worker count.
    pub deadline: Option<std::time::Instant>,
}

impl Default for FistaOptions<'_> {
    fn default() -> Self {
        FistaOptions {
            max_iter: 20_000,
            tol: 1e-6,
            check_every: 10,
            lipschitz: None,
            adaptive_restart: true,
            dynamic_screen: None,
            deadline: None,
        }
    }
}

/// True when a configured deadline has passed. Shared by all three solver
/// families; called only at gap-check cadence, so budget granularity is
/// `check_every` iterations (never mid-iteration — the returned iterate is
/// always a completed prox step).
#[inline]
pub(crate) fn deadline_passed(deadline: Option<std::time::Instant>) -> bool {
    deadline.is_some_and(|dl| std::time::Instant::now() >= dl)
}

/// Solver output.
///
/// This is the record the streaming path driver
/// ([`crate::coordinator::driver`]) folds into each per-λ step it emits to
/// a `PathSink`: `beta` is scattered into the full-space vector handed to
/// sinks, `iters`/`gap` land in the step statistics. Solver options are
/// constructed by the driver's single `SolverKind` dispatch — there is no
/// per-consumer solver wiring to drift.
#[derive(Debug, Clone)]
pub struct SolveResult {
    /// The solution β.
    pub beta: Vec<f32>,
    /// Iterations performed.
    pub iters: usize,
    /// Final duality gap (absolute).
    pub gap: f64,
    /// Final primal objective.
    pub objective: f64,
    /// Whether the gap tolerance was met within `max_iter`.
    pub converged: bool,
    /// True when the solve stopped on an exhausted budget — the iteration
    /// cap or the wall-clock [`FistaOptions::deadline`] — rather than
    /// meeting the gap tolerance. `beta` is still the best completed
    /// iterate and `gap` its last measured (certified) suboptimality;
    /// never garbage.
    pub budget_exhausted: bool,
    /// Final residual `y − Xβ` for the returned `beta`, in the problem the
    /// solver was given. For a reduced problem this equals the full-space
    /// residual (discarded coordinates are zero), which lets the driver's
    /// per-round KKT post-checks skip the residual matvec
    /// ([`crate::screening::strong_rule::kkt_violations_with_resid`]).
    pub resid: Vec<f32>,
}

/// Lipschitz constant of the smooth part: `‖X‖₂²`.
///
/// Power iteration converges to σmax *from below*, so the estimate is
/// inflated by 2% — an overestimate only shrinks the step slightly, while
/// an underestimate can destabilize FISTA.
pub fn lipschitz<M: DesignMatrix>(prob: &SglProblem<'_, M>) -> f64 {
    lipschitz_of(prob.x)
}

/// [`lipschitz`] for a bare design matrix — the same seed/tolerance/2%
/// recipe, callable on a survivor view without building an `SglProblem`.
/// Used by the path runners' amortized per-view refresh
/// (`PathConfig::lipschitz_refresh_every`), which must produce exactly the
/// constant the solver would self-compute for that view.
pub fn lipschitz_of<M: DesignMatrix>(x: &M) -> f64 {
    let mut rng = Rng::seed_from_u64(0x11_57FA);
    let s = spectral_norm(x, 1e-6, 500, &mut rng).sigma * 1.02;
    (s * s).max(f64::MIN_POSITIVE)
}

/// One FISTA iteration — the fused gradient/prox/momentum pass plus the
/// Beck–Teboulle momentum update. The **single** arithmetic home shared by
/// the static loop and the dynamic-screening loop, so the two execute
/// byte-for-byte the same per-iteration operations (the same
/// construction that keeps BCD's colored/sequential sweeps comparable via
/// `sweep_once`).
#[allow(clippy::too_many_arguments)]
fn fista_iteration<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    params: &SglParams,
    step: f64,
    stepf: f32,
    t_l1: f64,
    t_k: &mut f64,
    beta: &mut Vec<f32>,
    beta_prev: &mut Vec<f32>,
    z: &mut [f32],
    xz: &mut [f32],
    grad: &mut [f32],
    w: &mut [f32],
) {
    // Gradient of the smooth part at z: ∇ = Xᵀ(Xz − y), with the
    // residual fused into the matvec (one pass instead of two).
    prob.x.residual_matvec(z, prob.y, xz);
    prob.x.matvec_t(xz, grad);
    // Fused gradient/prox/momentum pass, group by group: while a
    // group's slices are cache-hot, compute w_g = z_g − step·∇_g, prox
    // it into β_g, and immediately extrapolate z_g — two full-p sweeps
    // of traffic instead of the former four (w, prox, swap, momentum).
    // Per-element arithmetic is identical to the unfused passes.
    let t_next = 0.5 * (1.0 + (1.0 + 4.0 * *t_k * *t_k).sqrt());
    let omega = ((*t_k - 1.0) / t_next) as f32;
    std::mem::swap(beta, beta_prev);
    for (g, s_idx, e_idx) in prob.groups.iter() {
        let t_l2 = step * params.lambda1 * prob.groups.weight(g);
        for j in s_idx..e_idx {
            w[j] = z[j] - stepf * grad[j];
        }
        sgl_prox_group(&w[s_idx..e_idx], t_l1, t_l2, &mut beta[s_idx..e_idx]);
        for j in s_idx..e_idx {
            z[j] = beta[j] + omega * (beta[j] - beta_prev[j]);
        }
    }
    *t_k = t_next;
}

/// Solve SGL with FISTA. `warm_start` (if given) initializes β.
///
/// With [`FistaOptions::dynamic_screen`] set, the solve additionally
/// shrinks its own problem at gap-check cadence (see the option docs); the
/// result is still reported in the caller's full index space.
pub fn solve_fista<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    params: &SglParams,
    warm_start: Option<&[f32]>,
    opts: &FistaOptions<'_>,
) -> SolveResult {
    if let Some(state) = opts.dynamic_screen {
        return solve_fista_dynamic(prob, params, warm_start, opts, state);
    }
    let n = prob.n_samples();
    let p = prob.n_features();
    let l = opts.lipschitz.unwrap_or_else(|| lipschitz(prob));
    let step = 1.0 / l;
    let scale_ref = null_objective(prob.y).max(1e-10);

    let mut beta: Vec<f32> = match warm_start {
        Some(b) => {
            assert_eq!(b.len(), p, "warm start dimension mismatch");
            b.to_vec()
        }
        None => vec![0.0; p],
    };
    let mut beta_prev = beta.clone();
    let mut z = beta.clone();
    let mut t_k = 1.0f64;

    // Work buffers, allocated once.
    let mut xz = vec![0.0f32; n];
    let mut grad = vec![0.0f32; p];
    let mut w = vec![0.0f32; p];
    let mut r = vec![0.0f32; n];
    let mut c = vec![0.0f32; p];

    let mut last_obj = f64::INFINITY;
    let mut gap = f64::INFINITY;
    let mut converged = false;
    let mut deadline_hit = false;
    let mut iters = 0;
    // Objective from a gap check at the *current* β — reused on exit so a
    // converged solve never re-runs the residual/objective it just computed.
    let mut checked_obj: Option<f64> = None;

    let stepf = step as f32;
    let t_l1 = step * params.lambda2;
    for k in 0..opts.max_iter {
        iters = k + 1;
        checked_obj = None;
        fista_iteration(
            prob,
            params,
            step,
            stepf,
            t_l1,
            &mut t_k,
            &mut beta,
            &mut beta_prev,
            &mut z,
            &mut xz,
            &mut grad,
            &mut w,
        );

        // Convergence check (and optional restart) on a cadence.
        if (k + 1) % opts.check_every == 0 || k + 1 == opts.max_iter {
            super::objective::residual(prob, &beta, &mut r);
            crate::util::fault::maybe_poison_residual(&mut r);
            prob.x.matvec_t(&r, &mut c);
            let obj = objective_with_residual(prob, params, &beta, &r).total();
            if opts.adaptive_restart && obj > last_obj {
                t_k = 1.0;
                z.copy_from_slice(&beta);
            }
            last_obj = obj;
            checked_obj = Some(obj);
            let (g, _) = duality_gap(prob, params, &beta, &r, &c);
            gap = g;
            if gap <= opts.tol * scale_ref {
                converged = true;
                break;
            }
            if !gap.is_finite() {
                // Poisoned/overflowed evaluation: no stopping rule can
                // ever fire on a NaN gap, so surface `converged = false`
                // with the non-finite gap instead of spinning to the cap.
                break;
            }
            if deadline_passed(opts.deadline) {
                deadline_hit = true;
                break;
            }
        }
    }

    // Every loop exit (converged break, or the forced check at
    // k+1 == max_iter) leaves `checked_obj` holding the objective at the
    // final β; recompute only in the degenerate max_iter == 0 case.
    let objective = match checked_obj {
        Some(o) => o,
        None => {
            super::objective::residual(prob, &beta, &mut r);
            objective_with_residual(prob, params, &beta, &r).total()
        }
    };
    let budget_exhausted = deadline_hit || (!converged && iters == opts.max_iter);
    // Every exit path above leaves `r` holding the residual at the final β
    // (the gap check computed it, or the `checked_obj: None` branch did).
    SolveResult { beta, iters, gap, objective, converged, budget_exhausted, resid: r }
}

/// Mutable state of a dynamic-screening FISTA solve, shared across
/// screening epochs (an epoch = the iterations between two compactions).
/// Buffers are resized, not reallocated, as the problem shrinks.
struct FistaDynCore {
    beta: Vec<f32>,
    beta_prev: Vec<f32>,
    z: Vec<f32>,
    t_k: f64,
    xz: Vec<f32>,
    r: Vec<f32>,
    grad: Vec<f32>,
    w: Vec<f32>,
    c: Vec<f32>,
    last_obj: f64,
    gap: f64,
    converged: bool,
    deadline_hit: bool,
    iters: usize,
    objective: Option<f64>,
}

/// Run dynamic-FISTA iterations on the *current* problem until
/// convergence or the iteration cap (→ `None`) or a GAP eviction (→ the
/// plan). Per-iteration arithmetic is [`fista_iteration`], identical to
/// the static loop; the sphere test rides each check's own `(c, gap, s)`
/// — no extra sweep — and is skipped on the terminal check (no
/// iterations left to benefit). Instantiated at exactly two matrix types
/// per caller: the caller's own `M` (before any eviction fires) and
/// `ScreenedView<M>` (after).
#[allow(clippy::too_many_arguments)]
fn fista_dynamic_epoch<M: DesignMatrix>(
    vprob: &SglProblem<'_, M>,
    params: &SglParams,
    opts: &FistaOptions<'_>,
    step: f64,
    stepf: f32,
    t_l1: f64,
    scale_ref: f64,
    state: &RefCell<GapSafeDynamic>,
    core: &mut FistaDynCore,
) -> Option<EvictPlan> {
    let p = vprob.n_features();
    core.grad.resize(p, 0.0);
    core.w.resize(p, 0.0);
    core.c.resize(p, 0.0);
    while core.iters < opts.max_iter {
        core.iters += 1;
        fista_iteration(
            vprob,
            params,
            step,
            stepf,
            t_l1,
            &mut core.t_k,
            &mut core.beta,
            &mut core.beta_prev,
            &mut core.z,
            &mut core.xz,
            &mut core.grad,
            &mut core.w,
        );
        if core.iters % opts.check_every == 0 || core.iters == opts.max_iter {
            super::objective::residual(vprob, &core.beta, &mut core.r);
            crate::util::fault::maybe_poison_residual(&mut core.r);
            vprob.x.matvec_t(&core.r, &mut core.c);
            let obj = objective_with_residual(vprob, params, &core.beta, &core.r).total();
            if opts.adaptive_restart && obj > core.last_obj {
                core.t_k = 1.0;
                core.z.copy_from_slice(&core.beta);
            }
            core.last_obj = obj;
            core.objective = Some(obj);
            let (g, s_feas) = duality_gap(vprob, params, &core.beta, &core.r, &core.c);
            core.gap = g;
            if g <= opts.tol * scale_ref {
                core.converged = true;
                return None;
            }
            if !g.is_finite() {
                // Same recovery as the static loop: a non-finite gap can
                // never satisfy the stopping rule (and the sphere test
                // would be meaningless) — stop, report `converged = false`.
                return None;
            }
            if deadline_passed(opts.deadline) {
                core.deadline_hit = true;
                return None;
            }
            if core.iters < opts.max_iter {
                // Gap floored at the f32 evaluation noise scale — see
                // `gap_with_noise_floor`.
                if let Some(plan) = state.borrow_mut().check(
                    vprob.groups,
                    params.lambda2,
                    &core.c,
                    crate::screening::gap_safe::gap_with_noise_floor(g, scale_ref),
                    s_feas,
                ) {
                    return Some(plan);
                }
            }
        }
    }
    None
}

/// The dynamic-screening FISTA solve. Phase 1 iterates on the caller's
/// matrix directly (no view indirection until an eviction actually
/// fires); each eviction compacts the iterate/momentum state and the
/// group structure, and iteration continues on a survivor
/// [`ScreenedView`]. Momentum (`t_k`, the extrapolation point `z`)
/// carries across compactions — evicted coordinates are zero at the
/// optimum, so restricting the iterate is a projection onto a face
/// containing the solution, not a restart.
fn solve_fista_dynamic<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    params: &SglParams,
    warm_start: Option<&[f32]>,
    opts: &FistaOptions<'_>,
    state: &RefCell<GapSafeDynamic>,
) -> SolveResult {
    let n = prob.n_samples();
    let p0 = prob.n_features();
    // The caller-supplied (or full-problem) step bound stays valid for
    // every survivor view: σmax over a column subset only shrinks.
    let l = opts.lipschitz.unwrap_or_else(|| lipschitz(prob));
    let step = 1.0 / l;
    let stepf = step as f32;
    let t_l1 = step * params.lambda2;
    let scale_ref = null_objective(prob.y).max(1e-10);

    let beta0: Vec<f32> = match warm_start {
        Some(b) => {
            assert_eq!(b.len(), p0, "warm start dimension mismatch");
            b.to_vec()
        }
        None => vec![0.0; p0],
    };
    let mut core = FistaDynCore {
        beta_prev: beta0.clone(),
        z: beta0.clone(),
        beta: beta0,
        t_k: 1.0,
        xz: vec![0.0; n],
        r: vec![0.0; n],
        grad: Vec::new(),
        w: Vec::new(),
        c: Vec::new(),
        last_obj: f64::INFINITY,
        gap: f64::INFINITY,
        converged: false,
        deadline_hit: false,
        iters: 0,
        objective: None,
    };
    let mut cols: Vec<usize> = (0..p0).collect();

    // Phase 1: the caller's problem, zero overhead vs the static loop.
    let mut pending =
        fista_dynamic_epoch(prob, params, opts, step, stepf, t_l1, scale_ref, state, &mut core);
    // Phase 2: compact and continue on survivor views until done. The
    // group structure starts as the caller's and compacts per plan.
    let mut groups: Option<GroupStructure> = None;
    while let Some(plan) = pending.take() {
        retain_by_mask(&mut core.beta, &plan.feature_kept);
        retain_by_mask(&mut core.beta_prev, &plan.feature_kept);
        retain_by_mask(&mut core.z, &plan.feature_kept);
        retain_by_mask(&mut cols, &plan.feature_kept);
        let compacted = groups
            .as_ref()
            .unwrap_or(prob.groups)
            .compact(&plan.feature_kept);
        match compacted {
            Some((g2, _)) => groups = Some(g2),
            None => {
                // Everything certified zero: the reduced problem's
                // optimum is β ≡ 0 with an exactly-zero gap.
                core.beta.clear();
                cols.clear();
                core.gap = 0.0;
                core.converged = true;
                core.objective = Some(null_objective(prob.y));
                core.r.copy_from_slice(prob.y);
                break;
            }
        }
        let view = ScreenedView::new(prob.x, cols.clone());
        let vprob =
            SglProblem::new(&view, prob.y, groups.as_ref().expect("set above"));
        pending = fista_dynamic_epoch(
            &vprob, params, opts, step, stepf, t_l1, scale_ref, state, &mut core,
        );
    }

    // Scatter the survivor iterate back to the caller's index space.
    let mut full = vec![0.0f32; p0];
    for (k, &j) in cols.iter().enumerate() {
        full[j] = core.beta[k];
    }
    // `core.r` was recomputed at the last gap check of the final epoch (or
    // reset to y when everything was evicted), so it is the residual at the
    // scattered `full`; only the degenerate no-check case recomputes.
    let (objective, resid) = match core.objective {
        Some(o) => (o, core.r),
        None => {
            // Degenerate max_iter == 0: no check ever ran.
            let mut rr = vec![0.0f32; n];
            super::objective::residual(prob, &full, &mut rr);
            let o = objective_with_residual(prob, params, &full, &rr).total();
            (o, rr)
        }
    };
    SolveResult {
        beta: full,
        iters: core.iters,
        gap: core.gap,
        objective,
        converged: core.converged,
        budget_exhausted: core.deadline_hit
            || (!core.converged && core.iters == opts.max_iter),
        resid,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::groups::GroupStructure;
    use crate::linalg::DenseMatrix;
    use crate::screening::lambda_max::sgl_lambda_max;
    use crate::util::Rng;

    fn small_problem(seed: u64) -> (DenseMatrix, Vec<f32>, GroupStructure) {
        let mut rng = Rng::seed_from_u64(seed);
        let n = 20;
        let p = 30;
        let x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian() as f32);
        let g = GroupStructure::uniform(p, 6);
        // Planted sparse signal.
        let mut beta = vec![0.0f32; p];
        for j in [0, 1, 5, 12] {
            beta[j] = rng.normal(0.0, 1.0) as f32;
        }
        let mut y = vec![0.0f32; n];
        x.matvec(&beta, &mut y);
        for v in y.iter_mut() {
            *v += rng.normal(0.0, 0.01) as f32;
        }
        (x, y, g)
    }

    #[test]
    fn converges_to_small_gap() {
        let (x, y, g) = small_problem(21);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 1.0);
        let params = SglParams::from_alpha_lambda(1.0, 0.3 * lm.lambda_max);
        let res = solve_fista(&prob, &params, None, &FistaOptions::default());
        assert!(res.converged, "gap={}", res.gap);
        assert!(res.gap <= 1e-6 * super::null_objective(&y).max(1e-10) + 1e-12);
    }

    #[test]
    fn zero_solution_at_lambda_max() {
        let (x, y, g) = small_problem(22);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 2.0);
        let params = SglParams::from_alpha_lambda(2.0, lm.lambda_max * 1.0001);
        let res = solve_fista(&prob, &params, None, &FistaOptions::default());
        assert!(res.beta.iter().all(|&b| b == 0.0), "β≠0 at λ ≥ λmax");
    }

    #[test]
    fn warm_start_converges_faster() {
        let (x, y, g) = small_problem(23);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 1.0);
        let p1 = SglParams::from_alpha_lambda(1.0, 0.5 * lm.lambda_max);
        let p2 = SglParams::from_alpha_lambda(1.0, 0.45 * lm.lambda_max);
        let o = FistaOptions { tol: 1e-8, ..Default::default() };
        let r1 = solve_fista(&prob, &p1, None, &o);
        let cold = solve_fista(&prob, &p2, None, &o);
        let warm = solve_fista(&prob, &p2, Some(&r1.beta), &o);
        assert!(warm.iters <= cold.iters, "warm {} > cold {}", warm.iters, cold.iters);
        assert!((warm.objective - cold.objective).abs() < 1e-4 * cold.objective.abs().max(1.0));
    }

    #[test]
    fn objective_below_null_for_small_lambda() {
        let (x, y, g) = small_problem(24);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 1.0);
        let params = SglParams::from_alpha_lambda(1.0, 0.1 * lm.lambda_max);
        let res = solve_fista(&prob, &params, None, &FistaOptions::default());
        assert!(res.objective < super::null_objective(&y));
        assert!(res.beta.iter().any(|&b| b != 0.0));
    }

    #[test]
    fn dynamic_screening_reaches_same_optimum() {
        use crate::linalg::power::group_spectral_norms;
        let (x, y, g) = small_problem(26);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 1.0);
        let params = SglParams::from_alpha_lambda(1.0, 0.35 * lm.lambda_max);
        let opts = FistaOptions { tol: 1e-8, ..Default::default() };
        let plain = solve_fista(&prob, &params, None, &opts);
        let mut rng = Rng::seed_from_u64(0xD7);
        let gs = group_spectral_norms(&x, &g.ranges(), 1e-6, 500, &mut rng);
        let state = std::cell::RefCell::new(crate::screening::gap_safe::GapSafeDynamic::new(
            1.0,
            x.col_norms(),
            gs,
        ));
        let dynamic = solve_fista(
            &prob,
            &params,
            None,
            &FistaOptions { dynamic_screen: Some(&state), ..opts },
        );
        assert!(dynamic.converged, "gap={}", dynamic.gap);
        assert_eq!(dynamic.beta.len(), prob.n_features());
        assert!(
            (plain.objective - dynamic.objective).abs()
                < 1e-5 * plain.objective.abs().max(1.0),
            "objectives diverged: {} vs {}",
            plain.objective,
            dynamic.objective
        );
        // Same support at solver resolution (the shared hysteresis
        // comparator).
        assert!(
            crate::screening::gap_safe::same_support_at_resolution(&plain.beta, &dynamic.beta),
            "support mismatch between static and dynamic solves"
        );
        // Near the optimum the sphere shrinks below the inactive features'
        // slack — a mid-path λ on this planted problem must evict.
        assert!(state.borrow().evicted() > 0, "dynamic screening never fired");
    }

    #[test]
    fn expired_deadline_returns_best_so_far() {
        let (x, y, g) = small_problem(27);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 1.0);
        let params = SglParams::from_alpha_lambda(1.0, 0.3 * lm.lambda_max);
        let opts = FistaOptions {
            deadline: Some(std::time::Instant::now()),
            ..Default::default()
        };
        let res = solve_fista(&prob, &params, None, &opts);
        // First gap check sees the expired deadline: best-so-far comes
        // back with a finite certified gap, never garbage.
        assert!(!res.converged);
        assert!(res.budget_exhausted);
        assert!(res.gap.is_finite());
        assert!(res.objective.is_finite());
        assert_eq!(res.iters, opts.check_every);
        assert_eq!(res.beta.len(), prob.n_features());
    }

    #[test]
    fn iteration_cap_marks_budget_exhausted() {
        let (x, y, g) = small_problem(28);
        let prob = SglProblem::new(&x, &y, &g);
        let lm = sgl_lambda_max(&prob, 1.0);
        let params = SglParams::from_alpha_lambda(1.0, 0.2 * lm.lambda_max);
        let opts = FistaOptions { max_iter: 3, tol: 1e-14, ..Default::default() };
        let res = solve_fista(&prob, &params, None, &opts);
        assert!(!res.converged);
        assert!(res.budget_exhausted);
        assert_eq!(res.iters, 3);
        assert!(res.gap.is_finite());
    }

    #[test]
    fn provided_lipschitz_matches_computed() {
        let (x, y, g) = small_problem(25);
        let prob = SglProblem::new(&x, &y, &g);
        let l = lipschitz(&prob);
        let lm = sgl_lambda_max(&prob, 1.0);
        let params = SglParams::from_alpha_lambda(1.0, 0.4 * lm.lambda_max);
        let a = solve_fista(&prob, &params, None, &FistaOptions { lipschitz: Some(l), ..Default::default() });
        let b = solve_fista(&prob, &params, None, &FistaOptions::default());
        assert!((a.objective - b.objective).abs() < 1e-5 * a.objective.abs().max(1.0));
    }
}
