//! Problem container and parameterization.

use crate::groups::GroupStructure;
use crate::linalg::{DenseMatrix, DesignMatrix};

/// Regularization parameters of SGL.
///
/// The paper uses two equivalent forms: problem (2) with `(λ₁, λ₂)` and
/// problem (3) with `(λ, α)` where `λ₁ = αλ, λ₂ = λ`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SglParams {
    /// Group-lasso weight λ₁ (multiplies `√n_g ‖β_g‖₂`).
    pub lambda1: f64,
    /// Lasso weight λ₂ (multiplies `‖β‖₁`).
    pub lambda2: f64,
}

impl SglParams {
    /// From the `(λ, α)` parameterization of problem (3).
    pub fn from_alpha_lambda(alpha: f64, lambda: f64) -> SglParams {
        assert!(alpha > 0.0 && lambda > 0.0, "alpha and lambda must be positive");
        SglParams { lambda1: alpha * lambda, lambda2: lambda }
    }

    /// Back to `(λ, α)`: `λ = λ₂`, `α = λ₁/λ₂`.
    pub fn to_alpha_lambda(&self) -> (f64, f64) {
        (self.lambda1 / self.lambda2, self.lambda2)
    }
}

/// A borrowed SGL problem instance: design matrix, response, groups.
///
/// Generic over the [`DesignMatrix`] backend (dense, CSC, or a screened
/// view); defaults to [`DenseMatrix`] so existing dense call sites read
/// unchanged.
pub struct SglProblem<'a, M: DesignMatrix = DenseMatrix> {
    pub x: &'a M,
    pub y: &'a [f32],
    pub groups: &'a GroupStructure,
}

impl<'a, M: DesignMatrix> SglProblem<'a, M> {
    pub fn new(x: &'a M, y: &'a [f32], groups: &'a GroupStructure) -> Self {
        assert_eq!(x.rows(), y.len(), "X rows must match y length");
        x.check_groups(groups);
        SglProblem { x, y, groups }
    }

    #[inline]
    pub fn n_samples(&self) -> usize {
        self.x.rows()
    }

    #[inline]
    pub fn n_features(&self) -> usize {
        self.x.cols()
    }

    #[inline]
    pub fn n_groups(&self) -> usize {
        self.groups.n_groups()
    }
}

// Manual Clone/Copy/Debug: the derives would demand `M: Clone/Copy/Debug`
// even though only references are stored.
impl<M: DesignMatrix> Clone for SglProblem<'_, M> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<M: DesignMatrix> Copy for SglProblem<'_, M> {}

impl<M: DesignMatrix> std::fmt::Debug for SglProblem<'_, M> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SglProblem")
            .field("n_samples", &self.n_samples())
            .field("n_features", &self.n_features())
            .field("n_groups", &self.n_groups())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_conversions_roundtrip() {
        let p = SglParams::from_alpha_lambda(2.0, 0.5);
        assert_eq!(p.lambda1, 1.0);
        assert_eq!(p.lambda2, 0.5);
        let (a, l) = p.to_alpha_lambda();
        assert!((a - 2.0).abs() < 1e-12);
        assert!((l - 0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn nonpositive_params_panic() {
        SglParams::from_alpha_lambda(0.0, 1.0);
    }

    #[test]
    fn problem_dims() {
        let x = DenseMatrix::zeros(4, 6);
        let y = vec![0.0f32; 4];
        let g = GroupStructure::uniform(6, 3);
        let p = SglProblem::new(&x, &y, &g);
        assert_eq!(p.n_samples(), 4);
        assert_eq!(p.n_features(), 6);
        assert_eq!(p.n_groups(), 3);
    }

    #[test]
    fn problem_over_csc_backend() {
        let x = DenseMatrix::zeros(4, 6);
        let s = crate::linalg::CscMatrix::from_dense(&x);
        let y = vec![0.0f32; 4];
        let g = GroupStructure::uniform(6, 3);
        let p = SglProblem::new(&s, &y, &g);
        assert_eq!(p.n_features(), 6);
    }

    #[test]
    #[should_panic]
    fn mismatched_y_panics() {
        let x = DenseMatrix::zeros(4, 6);
        let y = vec![0.0f32; 3];
        let g = GroupStructure::uniform(6, 3);
        SglProblem::new(&x, &y, &g);
    }
}
