//! The Sparse-Group Lasso problem and its solvers.
//!
//! Problem (2) of the paper:
//!
//! ```text
//! min_β ½‖y − Σ_g X_g β_g‖² + λ₁ Σ_g √n_g ‖β_g‖₂ + λ₂ ‖β‖₁
//! ```
//!
//! with the (λ, α) parameterization of problem (3) given by `λ₁ = αλ`,
//! `λ₂ = λ`. Internally everything uses `(λ₁, λ₂)`; [`SglParams`] converts.
//!
//! Two solvers are provided:
//! * [`fista`] — accelerated proximal gradient with the exact SGL prox and a
//!   duality-gap stopping rule (the default, used on both the full and the
//!   screened/reduced problem);
//! * [`bcd`] — cyclic block coordinate descent in the style of SLEP [12]
//!   (the solver the paper benchmarked), used as a cross-check and for the
//!   ablation benches.

pub mod bcd;
pub mod coloring;
pub mod dual;
pub mod fista;
pub mod objective;
pub mod problem;

pub use coloring::GroupColoring;
pub use fista::{solve_fista, FistaOptions, SolveResult};
pub use problem::{SglParams, SglProblem};
