//! Dual feasibility and duality gap for SGL.
//!
//! In the `(λ₁, λ₂)` parameterization (problem (2)), the Fenchel dual (28) is
//!
//! ```text
//! inf_θ ½‖y − θ‖² − ½‖y‖²   s.t.  ‖S_{λ₂}(X_gᵀθ)‖₂ ≤ λ₁√n_g ∀g
//! ```
//!
//! with `θ* = y − Xβ*` and dual value `D(θ) = ½‖y‖² − ½‖y − θ‖²`.
//! The solvers obtain a feasible dual point by radially scaling the
//! residual `θ̂ = y − Xβ`: `‖S_{λ₂}(s·c_g)‖` is nondecreasing in `s ≥ 0`,
//! so the largest feasible scale is found by bisection on the precomputed
//! correlation vector `c = Xᵀθ̂` (one matvec, then O(p) per probe).

use super::problem::{SglParams, SglProblem};
use crate::linalg::ops;
use crate::linalg::DesignMatrix;
use crate::prox::shrink_norm_sq;

/// Maximum infeasibility `max_g (‖S_{λ₂}(s c_g)‖² − (λ₁√n_g)²)` at scale `s`.
fn max_violation<M: DesignMatrix>(prob: &SglProblem<'_, M>, params: &SglParams, c: &[f32], s: f64) -> f64 {
    let mut worst = f64::NEG_INFINITY;
    // ‖S_λ₂(s·c_g)‖ = s·‖S_{λ₂/s}(c_g)‖ for s>0; evaluate directly on a
    // scaled copy-free pass instead.
    for (g, a, b) in prob.groups.iter() {
        let lim = params.lambda1 * prob.groups.weight(g);
        let mut acc = 0.0f64;
        for &v in &c[a..b] {
            let t = ((v as f64) * s).abs() - params.lambda2;
            if t > 0.0 {
                acc += t * t;
            }
        }
        worst = worst.max(acc - lim * lim);
        if worst > 0.0 && s <= 1.0 {
            // early exit only matters for feasibility probes
        }
    }
    worst
}

/// Largest `s ∈ [0, 1]` such that `s·θ̂` is dual feasible.
///
/// `c` must be `Xᵀθ̂`. Returns 1.0 when θ̂ itself is feasible.
pub fn dual_feasible_scale<M: DesignMatrix>(prob: &SglProblem<'_, M>, params: &SglParams, c: &[f32]) -> f64 {
    if max_violation(prob, params, c, 1.0) <= 0.0 {
        return 1.0;
    }
    let (mut lo, mut hi) = (0.0f64, 1.0f64);
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if max_violation(prob, params, c, mid) <= 0.0 {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi - lo < 1e-14 {
            break;
        }
    }
    lo
}

/// Dual objective `D(θ) = ½‖y‖² − ½‖y − θ‖²` for `θ = s·θ̂`.
pub fn dual_value(y: &[f32], theta_hat: &[f32], s: f64) -> f64 {
    debug_assert_eq!(y.len(), theta_hat.len());
    let mut d = 0.0f64;
    let mut ynsq = 0.0f64;
    for i in 0..y.len() {
        let yi = y[i] as f64;
        let diff = yi - s * theta_hat[i] as f64;
        d += diff * diff;
        ynsq += yi * yi;
    }
    0.5 * ynsq - 0.5 * d
}

/// Duality gap at β given its residual `r = y − Xβ` and `c = Xᵀr`.
///
/// Returns `(gap, scale)` with `gap = P(β) − D(s·r) ≥ 0` up to numerics.
pub fn duality_gap<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    params: &SglParams,
    beta: &[f32],
    r: &[f32],
    c: &[f32],
) -> (f64, f64) {
    let obj = super::objective::objective_with_residual(prob, params, beta, r);
    let s = dual_feasible_scale(prob, params, c);
    let d = dual_value(prob.y, r, s);
    ((obj.total() - d).max(0.0), s)
}

/// Check dual feasibility of an explicit θ (used in tests and the safety
/// verifier): `max_g ‖S_{λ₂}(X_gᵀθ)‖ − λ₁√n_g`.
pub fn feasibility_margin<M: DesignMatrix>(prob: &SglProblem<'_, M>, params: &SglParams, theta: &[f32]) -> f64 {
    let mut c = vec![0.0f32; prob.n_features()];
    prob.x.matvec_t(theta, &mut c);
    let mut worst = f64::NEG_INFINITY;
    for (g, a, b) in prob.groups.iter() {
        let norm = shrink_norm_sq(&c[a..b], params.lambda2).sqrt();
        worst = worst.max(norm - params.lambda1 * prob.groups.weight(g));
    }
    worst
}

/// ½‖y‖² — the objective at β = 0 and the natural scale for relative gaps.
pub fn null_objective(y: &[f32]) -> f64 {
    0.5 * ops::nrm2_sq(y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::groups::GroupStructure;
    use crate::linalg::DenseMatrix;
    use crate::util::Rng;

    fn random_problem(
        n: usize,
        p: usize,
        sizes: &[usize],
        seed: u64,
    ) -> (DenseMatrix, Vec<f32>, GroupStructure) {
        let mut rng = Rng::seed_from_u64(seed);
        let x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian() as f32);
        let y: Vec<f32> = (0..n).map(|_| rng.gaussian() as f32).collect();
        (x, y, GroupStructure::from_sizes(sizes))
    }

    #[test]
    fn scale_one_when_feasible() {
        let (x, y, g) = random_problem(5, 6, &[2, 2, 2], 1);
        let prob = SglProblem::new(&x, &y, &g);
        // Enormous λ values: any θ̂ feasible.
        let params = SglParams { lambda1: 1e6, lambda2: 1e6 };
        let mut c = vec![0.0f32; 6];
        prob.x.matvec_t(&y, &mut c);
        assert_eq!(dual_feasible_scale(&prob, &params, &c), 1.0);
    }

    #[test]
    fn scaled_point_is_feasible() {
        let (x, y, g) = random_problem(8, 12, &[3, 3, 3, 3], 2);
        let prob = SglProblem::new(&x, &y, &g);
        let params = SglParams { lambda1: 0.5, lambda2: 0.3 };
        let mut c = vec![0.0f32; 12];
        prob.x.matvec_t(&y, &mut c);
        let s = dual_feasible_scale(&prob, &params, &c);
        assert!(s > 0.0 && s < 1.0);
        let theta: Vec<f32> = y.iter().map(|&v| (v as f64 * s) as f32).collect();
        assert!(feasibility_margin(&prob, &params, &theta) <= 1e-4);
        // slightly larger scale must violate
        let theta2: Vec<f32> = y.iter().map(|&v| (v as f64 * (s * 1.05)) as f32).collect();
        assert!(feasibility_margin(&prob, &params, &theta2) > 0.0);
    }

    #[test]
    fn gap_nonnegative_and_zero_at_lambda_max() {
        let (x, y, g) = random_problem(10, 9, &[3, 3, 3], 3);
        let prob = SglProblem::new(&x, &y, &g);
        // At β = 0 with λ ≥ λmax the gap must be ~0 (θ = y feasible, Thm 8).
        let params = SglParams { lambda1: 1e5, lambda2: 1e5 };
        let beta = vec![0.0f32; 9];
        let r = y.clone();
        let mut c = vec![0.0f32; 9];
        prob.x.matvec_t(&r, &mut c);
        let (gap, s) = duality_gap(&prob, &params, &beta, &r, &c);
        assert_eq!(s, 1.0);
        assert!(gap.abs() < 1e-6, "gap={gap}");
    }

    #[test]
    fn dual_value_formula() {
        let y = vec![1.0f32, 2.0];
        let th = vec![1.0f32, 2.0];
        // s=1: D = ½‖y‖² = 2.5
        assert!((dual_value(&y, &th, 1.0) - 2.5).abs() < 1e-9);
        // s=0: D = 0
        assert!(dual_value(&y, &th, 0.0).abs() < 1e-9);
    }
}
