//! Red-black (conflict-graph) group coloring for pool-parallel BCD sweeps.
//!
//! A BCD sweep updates groups one at a time because every update reads and
//! writes the shared residual `r = y − Xβ`. But a group only touches `r` at
//! the rows its columns' **storage** touches ([`DesignMatrix::col_touched_rows`]):
//! all rows for dense columns, only the stored entries for CSC. Two groups
//! whose touched-row sets are disjoint operate on disjoint memory — their
//! updates commute *exactly* (bitwise), so they can sweep concurrently on
//! the worker pool without changing a single bit of the result.
//!
//! ## The schedule and its determinism contract
//!
//! [`GroupColoring::compute`] assigns each group a **level** (color class):
//!
//! ```text
//! level(g) = 1 + max{ level(h) : h < g, touched(h) ∩ touched(g) ≠ ∅ }
//! ```
//!
//! (0 when no earlier group conflicts). Executing classes in level order,
//! groups within a class in ascending index order, is a linear extension of
//! the conflict DAG (edges `h → g` for conflicting `h < g`): conflicting
//! pairs keep their sequential relative order, and non-conflicting pairs
//! commute exactly. The colored sweep — serial *or* pool-parallel, at any
//! worker count — is therefore **bitwise identical to the plain sequential
//! index-order sweep**. This is a stronger guarantee than classic greedy
//! smallest-free-color coloring, which can reorder *conflicting* groups
//! across classes and thereby change the f32 trajectory.
//!
//! What the schedule buys depends on the conflict structure:
//!
//! * **disjoint row blocks** (one-hot / block-diagonal designs): every
//!   group lands in class 0 — one dispatch sweeps them all concurrently;
//! * **pairwise-overlapping blocks** (groups `2k` and `2k+1` sharing a row
//!   band, blocks disjoint): levels alternate 0/1 — the classic red/black
//!   schedule;
//! * **an overlapping chain** (`g` overlaps `g+1` for all `g`): levels
//!   escalate `0,1,2,…` — bitwise equivalence to the sequential sweep
//!   genuinely forbids reordering conflicting neighbours, so a chain stays
//!   sequential (a classic smallest-free-color greedy would 2-color it, at
//!   the price of a different — still convergent, but not bitwise-equal —
//!   f32 trajectory, which the acceptance contract here rules out);
//! * **dense designs**: every group touches every row, classes degenerate
//!   to singletons and the sweep stays sequential (correct, just without
//!   speedup). `CscMatrix` workloads are where the parallelism lives.

use crate::groups::GroupStructure;
use crate::linalg::DesignMatrix;

/// A partition of the groups into conflict-free classes (see module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GroupColoring {
    /// `classes[c]` = group indices at level `c`, ascending.
    classes: Vec<Vec<usize>>,
    n_groups: usize,
}

/// Row band of group `g` in the canonical **paired-block** red/black test
/// design: block `k` owns rows `[8k, 8k+8)`, group `2k` sits on
/// `[8k, 8k+5)` and group `2k+1` on `[8k+3, 8k+8)` — the pair overlaps,
/// the blocks don't, so the coloring is exactly 2 classes (evens, odds).
/// Single source of truth shared by this module's tests, the BCD
/// colored-vs-sequential parity tests and `benches/perf_kernels.rs`'s
/// `red_black_bcd` section, so the structure the bench measures is the
/// same one the tests validate as 2-colorable. A design needs
/// `8 · blocks` rows for `2 · blocks` groups.
#[doc(hidden)]
pub fn paired_block_band(g: usize) -> (usize, usize) {
    let k = g / 2;
    if g % 2 == 0 {
        (8 * k, 8 * k + 5)
    } else {
        (8 * k + 3, 8 * k + 8)
    }
}

/// OR `src` into `dst` (equal-length bitset words).
fn or_into(dst: &mut [u64], src: &[u64]) {
    for (d, s) in dst.iter_mut().zip(src) {
        *d |= s;
    }
}

fn intersects(a: &[u64], b: &[u64]) -> bool {
    a.iter().zip(b).any(|(x, y)| x & y != 0)
}

impl GroupColoring {
    /// Compute the level schedule for `groups` over `x`'s storage pattern.
    ///
    /// Cost: one [`DesignMatrix::col_touched_rows`] pass per column plus
    /// `O(G · classes · N/64)` bitset intersections — run once per path
    /// (the path runners cache it next to the spectral constants) or once
    /// per standalone [`crate::sgl::bcd::solve_bcd`] call.
    pub fn compute<M: DesignMatrix>(x: &M, groups: &GroupStructure) -> GroupColoring {
        x.check_groups(groups);
        let words = x.rows().div_ceil(64).max(1);
        let g_count = groups.n_groups();
        // Per-group touched-row bitsets, flat.
        let mut supports = vec![0u64; words * g_count];
        for (g, s, e) in groups.iter() {
            let bits = &mut supports[g * words..(g + 1) * words];
            for j in s..e {
                x.col_touched_rows(j, bits);
            }
        }
        // unions[c] = OR of supports already assigned to level c.
        let mut unions: Vec<Vec<u64>> = Vec::new();
        let mut classes: Vec<Vec<usize>> = Vec::new();
        for g in 0..g_count {
            let sup = &supports[g * words..(g + 1) * words];
            // level = 1 + highest level holding a conflicting earlier group
            // (a class union intersects `sup` iff some member conflicts).
            let mut level = 0usize;
            for (c, u) in unions.iter().enumerate().rev() {
                if intersects(sup, u) {
                    level = c + 1;
                    break;
                }
            }
            if level == unions.len() {
                unions.push(vec![0u64; words]);
                classes.push(Vec::new());
            }
            or_into(&mut unions[level], sup);
            classes[level].push(g);
        }
        GroupColoring { classes, n_groups: g_count }
    }

    /// Build a coloring from explicit classes — **test/diagnostic only**.
    /// Validates that the classes partition `0..n_groups` (each group
    /// exactly once) but takes the conflict-freedom of each class on
    /// faith. Exists so the `race-check` tests can seed a deliberately
    /// invalid schedule and assert the shadow-ownership checker rejects
    /// it; never construct solver input this way.
    #[doc(hidden)]
    pub fn from_classes(classes: Vec<Vec<usize>>, n_groups: usize) -> GroupColoring {
        let mut seen = vec![false; n_groups];
        for class in &classes {
            for &g in class {
                assert!(g < n_groups, "group {g} out of range (n_groups {n_groups})");
                assert!(!seen[g], "group {g} appears in two classes");
                seen[g] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "classes must cover every group");
        GroupColoring { classes, n_groups }
    }

    /// The color classes, in execution order; each class's group indices
    /// are ascending and pairwise conflict-free.
    #[inline]
    pub fn classes(&self) -> &[Vec<usize>] {
        &self.classes
    }

    #[inline]
    pub fn n_groups(&self) -> usize {
        self.n_groups
    }

    #[inline]
    pub fn n_classes(&self) -> usize {
        self.classes.len()
    }

    /// Size of the largest class — the available parallelism per dispatch.
    pub fn max_class_len(&self) -> usize {
        self.classes.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Whether every class is a singleton — the colored sweep would equal
    /// the sequential sweep with pure dispatch overhead on top, so callers
    /// skip the pool entirely (the dense-backend case).
    pub fn is_trivially_sequential(&self) -> bool {
        self.classes.iter().all(|c| c.len() <= 1)
    }

    /// Project onto a reduced problem: `group_map[i]` is reduced group `i`'s
    /// index in the full structure (see
    /// [`crate::coordinator::reduce::ReducedProblem::group_map`]). A reduced
    /// group's columns are a subset of the full group's, so its touched-row
    /// set shrinks — full-matrix classes stay conflict-free, and the level
    /// order still linearly extends the (sparser) reduced conflict DAG.
    /// Empty classes are dropped.
    pub fn project(&self, group_map: &[usize]) -> GroupColoring {
        // full group id -> reduced index (groups outside the map are gone).
        let mut reduced_of = vec![usize::MAX; self.n_groups];
        for (i, &g) in group_map.iter().enumerate() {
            assert!(g < self.n_groups, "group_map entry {g} out of range");
            reduced_of[g] = i;
        }
        let classes: Vec<Vec<usize>> = self
            .classes
            .iter()
            .map(|class| {
                class.iter().filter_map(|&g| {
                    let i = reduced_of[g];
                    (i != usize::MAX).then_some(i)
                }).collect::<Vec<usize>>()
            })
            .filter(|c: &Vec<usize>| !c.is_empty())
            .collect();
        GroupColoring { classes, n_groups: group_map.len() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{CscMatrix, DenseMatrix, ScreenedView};
    use crate::util::Rng;

    fn touched(x: &impl DesignMatrix, groups: &GroupStructure, g: usize) -> Vec<u64> {
        let words = x.rows().div_ceil(64).max(1);
        let mut bits = vec![0u64; words];
        let (s, e) = groups.range(g);
        for j in s..e {
            x.col_touched_rows(j, &mut bits);
        }
        bits
    }

    fn assert_valid_coloring(x: &impl DesignMatrix, groups: &GroupStructure, col: &GroupColoring) {
        // Every group appears exactly once.
        let mut seen = vec![false; groups.n_groups()];
        for class in col.classes() {
            for &g in class {
                assert!(!seen[g], "group {g} colored twice");
                seen[g] = true;
            }
            assert!(class.windows(2).all(|w| w[0] < w[1]), "class not ascending");
        }
        assert!(seen.iter().all(|&s| s), "missing group");
        // Conflict-freedom within classes.
        for class in col.classes() {
            for (a_pos, &a) in class.iter().enumerate() {
                for &b in &class[a_pos + 1..] {
                    assert!(
                        !intersects(&touched(x, groups, a), &touched(x, groups, b)),
                        "groups {a} and {b} share a touched row inside one class"
                    );
                }
            }
        }
        // Linear extension: conflicting g < h ⇒ level(g) < level(h).
        let mut level = vec![0usize; groups.n_groups()];
        for (c, class) in col.classes().iter().enumerate() {
            for &g in class {
                level[g] = c;
            }
        }
        for g in 0..groups.n_groups() {
            for h in g + 1..groups.n_groups() {
                if intersects(&touched(x, groups, g), &touched(x, groups, h)) {
                    assert!(
                        level[g] < level[h],
                        "conflicting pair ({g},{h}) not ordered by level"
                    );
                }
            }
        }
    }

    #[test]
    fn property_random_sparse_colorings_are_conflict_free_linear_extensions() {
        // Property test over random CSC matrices and random group shapes.
        for seed in 0..20u64 {
            let mut rng = Rng::seed_from_u64(seed * 31 + 7);
            let n = 8 + rng.below(120);
            let mut sizes = Vec::new();
            let mut p = 0usize;
            while p < 30 {
                let s = 1 + rng.below(6);
                sizes.push(s);
                p += s;
            }
            let groups = GroupStructure::from_sizes(&sizes);
            let density = 0.02 + 0.3 * rng.uniform_range(0.0, 1.0);
            let d = DenseMatrix::from_fn(n, p, |_, _| {
                if rng.uniform_range(0.0, 1.0) < density {
                    rng.gaussian() as f32
                } else {
                    0.0
                }
            });
            let s = CscMatrix::from_dense(&d);
            let col = GroupColoring::compute(&s, &groups);
            assert_eq!(col.n_groups(), groups.n_groups());
            assert_valid_coloring(&s, &groups, &col);
        }
    }

    #[test]
    fn dense_design_degenerates_to_singletons_in_index_order() {
        let d = DenseMatrix::from_fn(6, 8, |i, j| (i + j) as f32 + 1.0);
        let groups = GroupStructure::uniform(8, 4);
        let col = GroupColoring::compute(&d, &groups);
        assert!(col.is_trivially_sequential());
        assert_eq!(col.n_classes(), 4);
        let flat: Vec<usize> = col.classes().iter().flatten().copied().collect();
        assert_eq!(flat, vec![0, 1, 2, 3], "dense schedule must be the sequential order");
    }

    /// Paired-block design via [`paired_block_band`] — the classic
    /// red/black structure (pairs overlap, blocks don't).
    fn paired_block_design(blocks: usize, cols_per_group: usize) -> (CscMatrix, GroupStructure) {
        let n = 8 * blocks;
        let g_count = 2 * blocks;
        let groups = GroupStructure::uniform(g_count * cols_per_group, g_count);
        let d = DenseMatrix::from_fn(n, g_count * cols_per_group, |i, j| {
            let (lo, hi) = paired_block_band(j / cols_per_group);
            if i >= lo && i < hi {
                ((i * 3 + j * 7) % 5) as f32 + 1.0
            } else {
                0.0
            }
        });
        (CscMatrix::from_dense(&d), groups)
    }

    #[test]
    fn paired_blocks_are_red_black_two_colorable() {
        let (s, groups) = paired_block_design(6, 2);
        let col = GroupColoring::compute(&s, &groups);
        assert_eq!(col.n_classes(), 2, "paired blocks must 2-color: {:?}", col.classes());
        assert_eq!(col.classes()[0], vec![0, 2, 4, 6, 8, 10]);
        assert_eq!(col.classes()[1], vec![1, 3, 5, 7, 9, 11]);
        assert!(!col.is_trivially_sequential());
        assert_eq!(col.max_class_len(), 6);
        assert_valid_coloring(&s, &groups, &col);
    }

    #[test]
    fn overlapping_chain_stays_sequential_by_design() {
        // Group g on rows [4g, 4g+8): each band overlaps the next, so the
        // bitwise-equivalence contract forbids any reordering — levels
        // escalate instead of 2-coloring (see module docs).
        let g_count = 5usize;
        let n = 4 * g_count + 4;
        let groups = GroupStructure::uniform(2 * g_count, g_count);
        let d = DenseMatrix::from_fn(n, 2 * g_count, |i, j| {
            let g = j / 2;
            if i >= 4 * g && i < 4 * g + 8 {
                1.0
            } else {
                0.0
            }
        });
        let s = CscMatrix::from_dense(&d);
        let col = GroupColoring::compute(&s, &groups);
        assert!(col.is_trivially_sequential());
        assert_eq!(col.n_classes(), g_count);
        assert_valid_coloring(&s, &groups, &col);
    }

    #[test]
    fn projection_keeps_order_and_drops_empty_classes() {
        let (s, groups) = paired_block_design(3, 2);
        let col = GroupColoring::compute(&s, &groups);
        assert_eq!(col.classes(), &[vec![0, 2, 4], vec![1, 3, 5]]);
        // Survivors: full groups 1, 2, 5 → reduced ids 0, 1, 2.
        let proj = col.project(&[1, 2, 5]);
        assert_eq!(proj.n_groups(), 3);
        assert_eq!(proj.classes(), &[vec![1], vec![0, 2]]);
        // Projecting onto a view's reduced structure stays conflict-free.
        let keep: Vec<usize> = [1usize, 2, 5]
            .iter()
            .flat_map(|&g| {
                let (s_idx, e_idx) = groups.range(g);
                s_idx..e_idx
            })
            .collect();
        let view = ScreenedView::new(&s, keep);
        let red_groups = GroupStructure::uniform(6, 3);
        for class in proj.classes() {
            for (a_pos, &a) in class.iter().enumerate() {
                for &b in &class[a_pos + 1..] {
                    assert!(!intersects(
                        &touched(&view, &red_groups, a),
                        &touched(&view, &red_groups, b)
                    ));
                }
            }
        }
    }
}
