//! Primal objective and residual bookkeeping.

use super::problem::{SglParams, SglProblem};
use crate::linalg::ops;
use crate::linalg::DesignMatrix;

/// Components of the primal objective at a point β.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Objective {
    /// ½‖y − Xβ‖².
    pub loss: f64,
    /// λ₁ Σ_g √n_g ‖β_g‖₂.
    pub group_penalty: f64,
    /// λ₂ ‖β‖₁.
    pub l1_penalty: f64,
}

impl Objective {
    #[inline]
    pub fn total(&self) -> f64 {
        self.loss + self.group_penalty + self.l1_penalty
    }
}

/// Compute the residual `r = y − Xβ` into `r_out` (fused single pass via
/// [`DesignMatrix::residual`] — no separate subtraction sweep; large
/// sweeps are row-blocked across the worker pool, bitwise identical to
/// serial).
pub fn residual<M: DesignMatrix>(prob: &SglProblem<'_, M>, beta: &[f32], r_out: &mut [f32]) {
    prob.x.residual(beta, prob.y, r_out);
}

/// Penalty value `λ₁ Σ √n_g‖β_g‖ + λ₂‖β‖₁` of a coefficient vector.
pub fn penalty<M: DesignMatrix>(prob: &SglProblem<'_, M>, params: &SglParams, beta: &[f32]) -> (f64, f64) {
    let mut group_pen = 0.0f64;
    for (g, s, e) in prob.groups.iter() {
        group_pen += prob.groups.weight(g) * ops::nrm2(&beta[s..e]);
    }
    let l1 = ops::nrm1(beta);
    (params.lambda1 * group_pen, params.lambda2 * l1)
}

/// Full primal objective at β (computes the residual internally).
pub fn objective<M: DesignMatrix>(prob: &SglProblem<'_, M>, params: &SglParams, beta: &[f32]) -> Objective {
    let mut r = vec![0.0f32; prob.n_samples()];
    residual(prob, beta, &mut r);
    objective_with_residual(prob, params, beta, &r)
}

/// Primal objective when the residual is already available (avoids the
/// matvec — the solvers maintain `r` incrementally).
pub fn objective_with_residual<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    params: &SglParams,
    beta: &[f32],
    r: &[f32],
) -> Objective {
    let loss = 0.5 * ops::nrm2_sq(r);
    let (group_penalty, l1_penalty) = penalty(prob, params, beta);
    Objective { loss, group_penalty, l1_penalty }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::groups::GroupStructure;
    use crate::linalg::DenseMatrix;

    #[test]
    fn objective_zero_beta_is_half_ynorm() {
        let x = DenseMatrix::from_fn(3, 4, |i, j| (i + j) as f32);
        let y = vec![1.0f32, 2.0, 2.0];
        let g = GroupStructure::uniform(4, 2);
        let prob = SglProblem::new(&x, &y, &g);
        let params = SglParams { lambda1: 0.3, lambda2: 0.7 };
        let o = objective(&prob, &params, &[0.0; 4]);
        assert!((o.loss - 4.5).abs() < 1e-9);
        assert_eq!(o.group_penalty, 0.0);
        assert_eq!(o.l1_penalty, 0.0);
        assert!((o.total() - 4.5).abs() < 1e-9);
    }

    #[test]
    fn objective_known_value() {
        // X = I (2x2), y = (1, 0), groups = singletons; β = (0.5, -0.25)
        let x = DenseMatrix::from_col_major(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let y = vec![1.0f32, 0.0];
        let g = GroupStructure::singletons(2);
        let prob = SglProblem::new(&x, &y, &g);
        let params = SglParams { lambda1: 2.0, lambda2: 3.0 };
        let beta = vec![0.5f32, -0.25];
        let o = objective(&prob, &params, &beta);
        // loss = ½((1-0.5)² + (0.25)²) = ½(0.25+0.0625)
        assert!((o.loss - 0.15625).abs() < 1e-9);
        // group pen = 2(0.5 + 0.25), l1 = 3(0.75)
        assert!((o.group_penalty - 1.5).abs() < 1e-9);
        assert!((o.l1_penalty - 2.25).abs() < 1e-9);
    }

    #[test]
    fn residual_and_with_residual_agree() {
        let x = DenseMatrix::from_fn(3, 4, |i, j| ((i * 7 + j * 3) % 5) as f32 - 2.0);
        let y = vec![0.5f32, -1.0, 2.0];
        let g = GroupStructure::from_sizes(&[1, 3]);
        let prob = SglProblem::new(&x, &y, &g);
        let params = SglParams { lambda1: 0.1, lambda2: 0.2 };
        let beta = vec![0.3f32, -0.2, 0.0, 0.1];
        let mut r = vec![0.0f32; 3];
        residual(&prob, &beta, &mut r);
        let a = objective(&prob, &params, &beta);
        let b = objective_with_residual(&prob, &params, &beta, &r);
        assert!((a.total() - b.total()).abs() < 1e-9);
    }
}
