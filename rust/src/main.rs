//! `tlfre` — CLI entry point for the TLFre reproduction.

fn main() {
    tlfre::util::logger::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match tlfre::cli::run(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
