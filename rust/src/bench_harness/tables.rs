//! Paper-format table and series rendering.

use crate::coordinator::runner::PathOutput;
use crate::coordinator::dpc_runner::DpcPathOutput;
use crate::util::harness::Table;
use crate::util::json::Json;

/// One α-column of a Table-1/2-style timing comparison.
#[derive(Debug, Clone)]
pub struct SpeedupColumn {
    pub label: String,
    /// Baseline: solver without screening (seconds, whole path).
    pub solver_s: f64,
    /// Screening-only time (seconds, whole path).
    pub screen_s: f64,
    /// Screening + reduced solves (seconds, whole path).
    pub combined_s: f64,
}

impl SpeedupColumn {
    pub fn speedup(&self) -> f64 {
        if self.combined_s > 0.0 {
            self.solver_s / self.combined_s
        } else {
            f64::INFINITY
        }
    }
}

/// Render the paper's Table 1/2 layout:
/// rows = solver / TLFre / TLFre+solver / speedup, columns = α.
pub fn render_speedup_table(dataset: &str, cols: &[SpeedupColumn]) -> String {
    let mut header = vec![dataset];
    let labels: Vec<&str> = cols.iter().map(|c| c.label.as_str()).collect();
    header.extend(labels);
    let mut t = Table::new(&header);
    let row = |name: &str, f: &dyn Fn(&SpeedupColumn) -> f64| -> Vec<String> {
        let mut cells = vec![name.to_string()];
        cells.extend(cols.iter().map(|c| format!("{:.2}", f(c))));
        cells
    };
    t.row(row("solver", &|c| c.solver_s));
    t.row(row("screen", &|c| c.screen_s));
    t.row(row("screen+solver", &|c| c.combined_s));
    t.row(row("speedup", &|c| c.speedup()));
    t.render()
}

/// Render a rejection-ratio series (one figure panel) as text:
/// `λ/λmax  r1  r2  r1+r2` rows plus the per-layer screening counts —
/// layer-1 rejected groups (`L1grp`), layer-2 rejected features (`L2feat`),
/// in-solver dynamic evictions (`dyn`), KKT re-admissions (`kkt`,
/// heuristic pipelines only), and the working-set outer loop's round count
/// (`wsR`) and final set size in features (`wsN`; both 0 outside `ws`
/// pipelines).
pub fn render_rejection_series(title: &str, out: &PathOutput) -> String {
    let mut s = format!("-- {title} (λmax = {:.4}) --\n", out.lambda_max);
    s.push_str(
        "  λ/λmax      r1      r2   r1+r2  active   L1grp  L2feat     dyn     kkt  wsR     wsN\n",
    );
    for st in &out.steps {
        s.push_str(&format!(
            "  {:8.4}  {:6.3}  {:6.3}  {:6.3}  {:6}  {:6}  {:6}  {:6}  {:6}  {:3}  {:6}\n",
            st.lambda / out.lambda_max,
            st.r1,
            st.r2,
            st.r1 + st.r2,
            st.active_features,
            st.groups_rejected,
            st.features_rejected,
            st.dynamic_evicted,
            st.kkt_readmitted,
            st.ws_rounds,
            st.ws_final_size,
        ));
    }
    s.push_str(&format!(
        "  mean r1 = {:.3}, mean r1+r2 = {:.3}\n",
        out.mean_r1(),
        out.mean_total_rejection()
    ));
    let dyn_total: usize = out.steps.iter().map(|st| st.dynamic_evicted).sum();
    let kkt_total: usize = out.steps.iter().map(|st| st.kkt_readmitted).sum();
    s.push_str(&format!(
        "  dynamic evictions = {dyn_total}, kkt re-admissions = {kkt_total}\n"
    ));
    // Per-rule efficacy (marginal rejections in pipeline order), summed
    // over the path — the ablation view of a composed pipeline.
    let mut rules: Vec<(&'static str, usize, usize)> = Vec::new();
    for st in &out.steps {
        for l in &st.layers {
            match rules.iter_mut().find(|(name, _, _)| *name == l.rule) {
                Some((_, g, f)) => {
                    *g += l.groups;
                    *f += l.features;
                }
                None => rules.push((l.rule, l.groups, l.features)),
            }
        }
    }
    for (name, g, f) in &rules {
        s.push_str(&format!("  rule {name:>8}: {g} groups, {f} features rejected\n"));
    }
    s
}

/// Render a DPC rejection series (Fig. 5 panel).
pub fn render_dpc_series(title: &str, out: &DpcPathOutput) -> String {
    let mut s = format!("-- {title} (λmax = {:.4}) --\n", out.lambda_max);
    s.push_str("  λ/λmax  rejection  active     dyn\n");
    for st in &out.steps {
        s.push_str(&format!(
            "  {:8.4}  {:9.3}  {:6}  {:6}\n",
            st.lambda / out.lambda_max,
            st.rejection,
            st.active_features,
            st.dynamic_evicted,
        ));
    }
    s.push_str(&format!("  mean rejection = {:.3}\n", out.mean_rejection()));
    let dyn_total: usize = out.steps.iter().map(|st| st.dynamic_evicted).sum();
    if dyn_total > 0 {
        s.push_str(&format!("  dynamic evictions = {dyn_total}\n"));
    }
    s
}

/// JSON form of a rejection series (consumed by plotting scripts).
pub fn series_to_json(out: &PathOutput) -> Json {
    Json::obj()
        .set("lambda_max", out.lambda_max)
        .set("lambda", out.steps.iter().map(|s| s.lambda).collect::<Vec<_>>())
        .set("r1", out.steps.iter().map(|s| s.r1).collect::<Vec<_>>())
        .set("r2", out.steps.iter().map(|s| s.r2).collect::<Vec<_>>())
        .set("active", out.steps.iter().map(|s| s.active_features as f64).collect::<Vec<_>>())
        .set(
            "groups_rejected",
            out.steps.iter().map(|s| s.groups_rejected as f64).collect::<Vec<_>>(),
        )
        .set(
            "features_rejected",
            out.steps.iter().map(|s| s.features_rejected as f64).collect::<Vec<_>>(),
        )
        .set(
            "dynamic_evicted",
            out.steps.iter().map(|s| s.dynamic_evicted as f64).collect::<Vec<_>>(),
        )
        .set(
            "kkt_readmitted",
            out.steps.iter().map(|s| s.kkt_readmitted as f64).collect::<Vec<_>>(),
        )
        .set("ws_rounds", out.steps.iter().map(|s| s.ws_rounds as f64).collect::<Vec<_>>())
        .set(
            "ws_final_size",
            out.steps.iter().map(|s| s.ws_final_size as f64).collect::<Vec<_>>(),
        )
        .set(
            "budget_exhausted",
            out.steps.iter().map(|s| s.budget_exhausted).collect::<Vec<_>>(),
        )
        .set(
            "certified_suboptimality",
            out.steps.iter().map(|s| s.certified_suboptimality).collect::<Vec<_>>(),
        )
        .set("truncated", out.truncated)
        .set("screen_total_s", out.screen_total_s)
        .set("solve_total_s", out.solve_total_s)
}

/// JSON form of a speedup table.
pub fn speedup_to_json(dataset: &str, cols: &[SpeedupColumn]) -> Json {
    Json::obj().set("dataset", dataset).set(
        "columns",
        Json::Arr(
            cols.iter()
                .map(|c| {
                    Json::obj()
                        .set("alpha", c.label.as_str())
                        .set("solver_s", c.solver_s)
                        .set("screen_s", c.screen_s)
                        .set("combined_s", c.combined_s)
                        .set("speedup", c.speedup())
                })
                .collect(),
        ),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn col(label: &str) -> SpeedupColumn {
        SpeedupColumn { label: label.into(), solver_s: 100.0, screen_s: 0.5, combined_s: 5.0 }
    }

    #[test]
    fn speedup_math() {
        assert!((col("a").speedup() - 20.0).abs() < 1e-12);
        let z = SpeedupColumn { combined_s: 0.0, ..col("z") };
        assert!(z.speedup().is_infinite());
    }

    #[test]
    fn table_renders_rows() {
        let s = render_speedup_table("Synthetic 1", &[col("tan(5°)"), col("tan(45°)")]);
        assert!(s.contains("solver"));
        assert!(s.contains("speedup"));
        assert!(s.contains("20.00"));
        assert!(s.lines().count() >= 6);
    }

    #[test]
    fn speedup_json_shape() {
        let j = speedup_to_json("ds", &[col("a")]);
        let cols = j.get("columns").unwrap().as_arr().unwrap();
        assert_eq!(cols.len(), 1);
        assert_eq!(cols[0].get("speedup").unwrap().as_f64(), Some(20.0));
    }

    #[test]
    fn working_set_counters_flow_into_table_and_json() {
        use crate::coordinator::runner::PathStep;
        let step = PathStep {
            lambda: 0.5,
            active_features: 7,
            ws_rounds: 3,
            ws_final_size: 42,
            ..Default::default()
        };
        let out = PathOutput {
            lambda_max: 1.0,
            steps: vec![step],
            screen_total_s: 0.0,
            solve_total_s: 0.0,
            truncated: false,
        };
        let text = render_rejection_series("t", &out);
        assert!(text.contains("wsR"), "{text}");
        assert!(text.contains("wsN"), "{text}");
        assert!(text.contains("  3  "), "{text}");
        assert!(text.contains("42"), "{text}");
        let j = series_to_json(&out);
        assert_eq!(j.get("ws_rounds").unwrap().as_arr().unwrap()[0].as_f64(), Some(3.0));
        assert_eq!(j.get("ws_final_size").unwrap().as_arr().unwrap()[0].as_f64(), Some(42.0));
    }
}
