//! Experiment harness shared by `rust/benches/*` — runs the paper's
//! workloads and prints tables/series in the paper's own format.
//!
//! Each bench binary (one per table/figure) parses a common set of flags
//! ([`BenchArgs`]), builds its data sets, calls into the coordinator, and
//! renders through [`tables`].

pub mod tables;

/// Common command-line arguments for bench binaries.
///
/// Default profile is reduced for the single-core CI box; `--full`
/// reproduces the paper's exact grid (7 α × 100 λ, full dimensions).
#[derive(Debug, Clone)]
pub struct BenchArgs {
    /// Paper-scale run.
    pub full: bool,
    /// Dataset seed.
    pub seed: u64,
    /// Override λ-grid size.
    pub n_lambda: Option<usize>,
    /// Override α count (first k of the paper's grid).
    pub n_alpha: Option<usize>,
    /// Override the simulated-real-data feature scale.
    pub scale: Option<f64>,
    /// Override the CV fold count.
    pub k_folds: Option<usize>,
    /// Emit a machine-readable JSON report to this path.
    pub json_out: Option<String>,
    /// RAM budget in MiB for the out-of-core scale section (the streamed
    /// dataset file is sized to several multiples of this).
    pub scale_budget_mib: Option<usize>,
}

impl Default for BenchArgs {
    fn default() -> Self {
        BenchArgs {
            full: false,
            seed: 42,
            n_lambda: None,
            n_alpha: None,
            scale: None,
            k_folds: None,
            json_out: None,
            scale_budget_mib: None,
        }
    }
}

impl BenchArgs {
    /// Parse from `std::env::args` (ignores unknown flags that cargo-bench
    /// passes, e.g. `--bench`).
    pub fn from_env() -> BenchArgs {
        let mut a = BenchArgs::default();
        let mut args = std::env::args().skip(1);
        while let Some(arg) = args.next() {
            match arg.as_str() {
                "--full" => a.full = true,
                "--seed" => a.seed = args.next().and_then(|v| v.parse().ok()).unwrap_or(a.seed),
                "--n-lambda" => a.n_lambda = args.next().and_then(|v| v.parse().ok()),
                "--n-alpha" => a.n_alpha = args.next().and_then(|v| v.parse().ok()),
                "--scale" => a.scale = args.next().and_then(|v| v.parse().ok()),
                "--k-folds" => a.k_folds = args.next().and_then(|v| v.parse().ok()),
                "--json-out" => a.json_out = args.next(),
                "--scale-budget" => a.scale_budget_mib = args.next().and_then(|v| v.parse().ok()),
                _ => {} // cargo bench passes --bench etc.
            }
        }
        a
    }

    /// λ-grid size for this profile (paper: 100).
    pub fn n_lambda(&self) -> usize {
        self.n_lambda.unwrap_or(if self.full { 100 } else { 50 })
    }

    /// α values for this profile (paper: all seven tan(ψ)).
    pub fn alphas(&self) -> Vec<f64> {
        let all = crate::coordinator::path::alpha_grid_from_angles(
            &crate::coordinator::path::PAPER_ALPHA_ANGLES,
        );
        let k = self.n_alpha.unwrap_or(if self.full { 7 } else { 3 });
        // reduced default: a spread (tan 5°, tan 45°, tan 85°)
        if k >= all.len() {
            all
        } else if k == 3 && self.n_alpha.is_none() {
            vec![all[0], all[3], all[6]]
        } else {
            all.into_iter().take(k.max(1)).collect()
        }
    }

    /// Angle labels matching [`Self::alphas`].
    pub fn alpha_labels(&self) -> Vec<String> {
        let angles = crate::coordinator::path::PAPER_ALPHA_ANGLES;
        let k = self.n_alpha.unwrap_or(if self.full { 7 } else { 3 });
        let idx: Vec<usize> = if k >= 7 {
            (0..7).collect()
        } else if k == 3 && self.n_alpha.is_none() {
            vec![0, 3, 6]
        } else {
            (0..k.max(1).min(7)).collect()
        };
        idx.iter().map(|&i| format!("tan({}°)", angles[i])).collect()
    }

    /// Simulated-real-set feature scale.
    pub fn scale(&self) -> f64 {
        self.scale.unwrap_or(if self.full { 1.0 } else { 0.02 })
    }

    /// CV fold count for this profile (paper-style model selection: 5).
    pub fn k_folds(&self) -> usize {
        self.k_folds.unwrap_or(if self.full { 5 } else { 3 })
    }

    /// RAM budget in MiB for the out-of-core scale section. The streamed
    /// dataset is sized to ≥ 4× this so the mmap path demonstrably works
    /// on an X payload that would not fit the budget.
    pub fn scale_budget_mib(&self) -> usize {
        self.scale_budget_mib.unwrap_or(if self.full { 64 } else { 16 }).max(1)
    }

    /// Synthetic data set dimensions `(n, p, groups)` for this profile.
    pub fn synthetic_dims(&self) -> (usize, usize, usize) {
        if self.full {
            (250, 10_000, 1000)
        } else {
            (250, 2_000, 200)
        }
    }

    /// Write the JSON report if `--json-out` was given.
    pub fn maybe_write_json(&self, report: &crate::util::json::Json) {
        if let Some(path) = &self.json_out {
            if let Err(e) = std::fs::write(path, report.to_string_pretty()) {
                eprintln!("warning: could not write {path}: {e}");
            } else {
                println!("json report written to {path}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_profile_reduced() {
        let a = BenchArgs::default();
        assert_eq!(a.n_lambda(), 50);
        assert_eq!(a.alphas().len(), 3);
        assert_eq!(a.alpha_labels().len(), 3);
        assert!(a.scale() < 1.0);
        assert_eq!(a.synthetic_dims().0, 250);
    }

    #[test]
    fn full_profile_matches_paper() {
        let a = BenchArgs { full: true, ..Default::default() };
        assert_eq!(a.n_lambda(), 100);
        assert_eq!(a.alphas().len(), 7);
        assert_eq!(a.scale(), 1.0);
        assert_eq!(a.synthetic_dims(), (250, 10_000, 1000));
        // α grid endpoints: tan 5° ≈ 0.0875, tan 85° ≈ 11.43
        let al = a.alphas();
        assert!((al[0] - 0.0875).abs() < 1e-3);
        assert!((al[6] - 11.43).abs() < 0.01);
    }

    #[test]
    fn labels_align_with_alphas() {
        let a = BenchArgs { n_alpha: Some(2), ..Default::default() };
        assert_eq!(a.alphas().len(), 2);
        assert_eq!(a.alpha_labels(), vec!["tan(5°)", "tan(15°)"]);
    }
}
