//! Proximal / shrinkage operators.
//!
//! * [`shrink`] — the paper's shrinkage operator `S_γ` (eq. (1)), which is
//!   also `w − P_{γB∞}(w)` (eq. (19), Remark 1) — the decomposition at the
//!   heart of TLFre.
//! * [`proj_linf`] — projection onto `γB∞`.
//! * [`sgl_prox_group`] — the exact prox of `t(c₂‖·‖₂ + c₁‖·‖₁)`:
//!   soft-threshold then group soft-threshold (Friedman et al. 2010).
//! * [`nonneg_l1_prox`] — prox of `tλ‖·‖₁ + I_{R₊}` for nonnegative Lasso.

/// Scalar soft-threshold `(|w|−γ)₊ sgn(w)`.
#[inline]
pub fn soft_threshold(w: f64, gamma: f64) -> f64 {
    if w > gamma {
        w - gamma
    } else if w < -gamma {
        w + gamma
    } else {
        0.0
    }
}

/// Vector shrinkage `S_γ(w)` into `out`.
pub fn shrink(w: &[f32], gamma: f64, out: &mut [f32]) {
    debug_assert_eq!(w.len(), out.len());
    let g = gamma as f32;
    for i in 0..w.len() {
        let v = w[i];
        out[i] = if v > g {
            v - g
        } else if v < -g {
            v + g
        } else {
            0.0
        };
    }
}

/// In-place shrinkage.
pub fn shrink_inplace(w: &mut [f32], gamma: f64) {
    let g = gamma as f32;
    for v in w.iter_mut() {
        *v = if *v > g {
            *v - g
        } else if *v < -g {
            *v + g
        } else {
            0.0
        };
    }
}

/// `‖S_γ(w)‖₂` without materializing the shrunk vector (screening hot path).
#[inline]
pub fn shrink_norm(w: &[f32], gamma: f64) -> f64 {
    shrink_norm_sq(w, gamma).sqrt()
}

/// `‖S_γ(w)‖₂²` (f64 accumulation).
#[inline]
pub fn shrink_norm_sq(w: &[f32], gamma: f64) -> f64 {
    let g = gamma;
    let mut acc = 0.0f64;
    for &v in w {
        let a = (v.abs() as f64 - g).max(0.0);
        acc += a * a;
    }
    acc
}

/// Projection onto the ℓ∞ ball of radius `gamma`: `P_{γB∞}(w)`.
pub fn proj_linf(w: &[f32], gamma: f64, out: &mut [f32]) {
    debug_assert_eq!(w.len(), out.len());
    let g = gamma as f32;
    for i in 0..w.len() {
        out[i] = w[i].clamp(-g, g);
    }
}

/// Group soft-threshold: `max(0, 1 − s/‖u‖₂)·u` in place.
/// Returns the post-threshold group norm.
pub fn group_soft_threshold_inplace(u: &mut [f32], s: f64) -> f64 {
    let norm = crate::linalg::ops::nrm2(u);
    if norm <= s {
        u.fill(0.0);
        0.0
    } else {
        let scale = ((norm - s) / norm) as f32;
        for v in u.iter_mut() {
            *v *= scale;
        }
        norm - s
    }
}

/// Exact prox of the SGL composite penalty restricted to one group:
///
/// `prox_{t(c₂‖·‖₂ + c₁‖·‖₁)}(v) = GST(S_{t c₁}(v), t c₂)`
///
/// where `GST` is the group soft-threshold. The composition is exact for
/// this penalty pair (prox decomposition of ℓ₁ inside ℓ₂, Friedman et al.).
/// Writes the result into `out`; returns true iff the group is zeroed.
pub fn sgl_prox_group(v: &[f32], t_l1: f64, t_l2: f64, out: &mut [f32]) -> bool {
    shrink(v, t_l1, out);
    group_soft_threshold_inplace(out, t_l2) == 0.0
}

/// Prox of `tλ‖·‖₁ + I_{R₊^p}`: `max(0, v − tλ)` elementwise.
pub fn nonneg_l1_prox(v: &[f32], t_l1: f64, out: &mut [f32]) {
    debug_assert_eq!(v.len(), out.len());
    let g = t_l1 as f32;
    for i in 0..v.len() {
        out[i] = (v[i] - g).max(0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops::nrm2;
    use crate::util::Rng;

    #[test]
    fn scalar_soft_threshold() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(1.0, 1.0), 0.0);
    }

    #[test]
    fn shrink_matches_scalar_and_identity_at_zero() {
        let w = vec![2.0f32, -0.5, 0.0, 1.5, -3.0];
        let mut out = vec![0.0f32; 5];
        shrink(&w, 1.0, &mut out);
        assert_eq!(out, vec![1.0, 0.0, 0.0, 0.5, -2.0]);
        shrink(&w, 0.0, &mut out);
        assert_eq!(out, w);
    }

    #[test]
    fn shrink_is_w_minus_projection() {
        // Remark 1 / eq. (19): S_γ(w) = w − P_{γB∞}(w).
        let mut rng = Rng::seed_from_u64(11);
        for _ in 0..100 {
            let w: Vec<f32> = (0..8).map(|_| rng.normal(0.0, 2.0) as f32).collect();
            let gamma = rng.uniform_range(0.0, 3.0);
            let mut s = vec![0.0f32; 8];
            let mut p = vec![0.0f32; 8];
            shrink(&w, gamma, &mut s);
            proj_linf(&w, gamma, &mut p);
            for i in 0..8 {
                assert!((s[i] + p[i] - w[i]).abs() < 1e-6);
                assert!(p[i].abs() <= gamma as f32 + 1e-6);
            }
        }
    }

    #[test]
    fn shrink_norm_consistent() {
        let mut rng = Rng::seed_from_u64(12);
        for _ in 0..50 {
            let w: Vec<f32> = (0..13).map(|_| rng.normal(0.0, 1.5) as f32).collect();
            let gamma = rng.uniform_range(0.0, 2.0);
            let mut s = vec![0.0f32; 13];
            shrink(&w, gamma, &mut s);
            assert!((shrink_norm(&w, gamma) - nrm2(&s)).abs() < 1e-5);
        }
    }

    #[test]
    fn group_soft_threshold_cases() {
        let mut u = vec![3.0f32, 4.0]; // norm 5
        let n = group_soft_threshold_inplace(&mut u, 1.0);
        assert!((n - 4.0).abs() < 1e-6);
        assert!((u[0] - 3.0 * 0.8).abs() < 1e-6);
        let mut z = vec![0.3f32, 0.4]; // norm 0.5 <= 1
        assert_eq!(group_soft_threshold_inplace(&mut z, 1.0), 0.0);
        assert_eq!(z, vec![0.0, 0.0]);
    }

    #[test]
    fn sgl_prox_optimality_vs_grid() {
        // prox output must minimize ½‖b−v‖² + t_l2‖b‖ + t_l1‖b‖₁ —
        // verify against random perturbations.
        let mut rng = Rng::seed_from_u64(13);
        let obj = |b: &[f32], v: &[f32], c1: f64, c2: f64| -> f64 {
            let d: f64 = b.iter().zip(v).map(|(x, y)| ((x - y) as f64).powi(2)).sum();
            let l2 = nrm2(b);
            let l1: f64 = b.iter().map(|x| x.abs() as f64).sum();
            0.5 * d + c2 * l2 + c1 * l1
        };
        for _ in 0..50 {
            let v: Vec<f32> = (0..5).map(|_| rng.normal(0.0, 1.0) as f32).collect();
            let c1 = rng.uniform_range(0.0, 0.8);
            let c2 = rng.uniform_range(0.0, 0.8);
            let mut b = vec![0.0f32; 5];
            sgl_prox_group(&v, c1, c2, &mut b);
            let fb = obj(&b, &v, c1, c2);
            for _ in 0..200 {
                let pert: Vec<f32> =
                    b.iter().map(|x| x + rng.normal(0.0, 0.05) as f32).collect();
                assert!(
                    obj(&pert, &v, c1, c2) >= fb - 1e-6,
                    "prox not optimal: {} < {}",
                    obj(&pert, &v, c1, c2),
                    fb
                );
            }
        }
    }

    #[test]
    fn nonneg_prox_cases() {
        let v = vec![2.0f32, 0.5, -1.0, 1.0];
        let mut out = vec![0.0f32; 4];
        nonneg_l1_prox(&v, 1.0, &mut out);
        assert_eq!(out, vec![1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn nonneg_prox_optimality_vs_grid() {
        let mut rng = Rng::seed_from_u64(14);
        let obj = |b: f64, v: f64, c: f64| 0.5 * (b - v) * (b - v) + c * b;
        for _ in 0..200 {
            let v = rng.normal(0.0, 2.0);
            let c = rng.uniform_range(0.0, 1.5);
            let mut out = [0.0f32];
            nonneg_l1_prox(&[v as f32], c, &mut out);
            let b = out[0] as f64;
            assert!(b >= 0.0);
            let fb = obj(b, v, c);
            for k in 0..100 {
                let cand = k as f64 * 0.05;
                assert!(obj(cand, v, c) >= fb - 1e-5);
            }
        }
    }

    #[test]
    fn prox_nonexpansive() {
        // ‖prox(u) − prox(v)‖ ≤ ‖u − v‖ for the SGL group prox.
        let mut rng = Rng::seed_from_u64(15);
        for _ in 0..100 {
            let u: Vec<f32> = (0..6).map(|_| rng.normal(0.0, 1.0) as f32).collect();
            let v: Vec<f32> = (0..6).map(|_| rng.normal(0.0, 1.0) as f32).collect();
            let (c1, c2) = (rng.uniform_range(0.0, 1.0), rng.uniform_range(0.0, 1.0));
            let mut pu = vec![0.0f32; 6];
            let mut pv = vec![0.0f32; 6];
            sgl_prox_group(&u, c1, c2, &mut pu);
            sgl_prox_group(&v, c1, c2, &mut pv);
            let d_in = crate::linalg::ops::dist2(&u, &v);
            let d_out = crate::linalg::ops::dist2(&pu, &pv);
            assert!(d_out <= d_in + 1e-5, "{d_out} > {d_in}");
        }
    }
}
