//! Linear-algebra substrate: the design-matrix backends and vector kernels.
//!
//! The paper's entire compute profile is level-1/level-2 operations over a
//! tall-skinny design matrix `X ∈ R^{N×p}` (N ≪ p): the solvers need `Xβ`
//! and `Xᵀr` every iteration, and the screening rules need one `Xᵀo` sweep
//! per path step plus per-column and per-group-block norms. No BLAS is
//! available offline, so the kernels are hand-written loops (compiled with
//! `target-cpu=native`), organized around the [`DesignMatrix`] backend
//! trait:
//!
//! * [`traits`] — [`DesignMatrix`] (the backend contract every solver,
//!   screening rule and coordinator is generic over) and [`SelectRows`].
//! * [`dense`] — [`DenseMatrix`], column-major dense storage.
//! * [`sparse`] — [`CscMatrix`], compressed sparse column storage for
//!   one-hot / n-gram / dictionary workloads.
//! * [`mmap`] — [`MmapDenseMatrix`], the out-of-core backend: a `TLFREDS1`
//!   file's X payload memory-mapped (or positioned-read on non-unix) and
//!   served column-by-column without ever loading it.
//! * [`sharded`] — [`ShardedMatrix`], a row-sharded composite of boxed
//!   backends whose forward sweeps dispatch one shard per pool worker.
//! * [`view`] — [`ScreenedView`], the zero-copy survivor-column view that
//!   reduced problems are built on after screening.
//! * [`ops`] — vector kernels: dot, axpy, nrm2, scale, …
//! * [`power`] — power iteration for spectral norms `‖X_g‖₂` (generic over
//!   the backend).
//!
//! See `rust/src/linalg/README.md` for backend selection guidance and the
//! `TLFRE_THREADS` parallelism knob.

pub mod dense;
pub mod mmap;
pub mod ops;
pub mod power;
pub mod sharded;
pub mod sparse;
pub mod traits;
pub mod view;

pub use dense::DenseMatrix;
pub use mmap::MmapDenseMatrix;
pub use sharded::ShardedMatrix;
pub use sparse::CscMatrix;
pub use traits::{col_norms_blocked, DesignMatrix, SelectRows};
pub use view::ScreenedView;
