//! Dense linear algebra substrate.
//!
//! The paper's entire compute profile is dense level-1/level-2 BLAS over a
//! tall-skinny design matrix `X ∈ R^{N×p}` (N ≪ p): the solver needs `Xβ`
//! and `Xᵀr` every iteration, and the screening rules need one `Xᵀo` sweep
//! per path step plus per-column and per-group-block norms. No BLAS is
//! available offline, so the kernels here are hand-written, column-major,
//! unroll-friendly loops (compiled with `target-cpu=native`).
//!
//! * [`dense`] — [`dense::DenseMatrix`], column-major storage with
//!   group-block views.
//! * [`ops`] — vector kernels: dot, axpy, nrm2, scale, …
//! * [`power`] — power iteration for spectral norms `‖X_g‖₂`.

pub mod dense;
pub mod ops;
pub mod power;

pub use dense::DenseMatrix;
