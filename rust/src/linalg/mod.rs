//! Linear-algebra substrate: the design-matrix backends and vector kernels.
//!
//! The paper's entire compute profile is level-1/level-2 operations over a
//! tall-skinny design matrix `X ∈ R^{N×p}` (N ≪ p): the solvers need `Xβ`
//! and `Xᵀr` every iteration, and the screening rules need one `Xᵀo` sweep
//! per path step plus per-column and per-group-block norms. No BLAS is
//! available offline, so the kernels are hand-written loops (compiled with
//! `target-cpu=native`), organized around the [`DesignMatrix`] backend
//! trait:
//!
//! * [`traits`] — [`DesignMatrix`] (the backend contract every solver,
//!   screening rule and coordinator is generic over) and [`SelectRows`].
//! * [`dense`] — [`DenseMatrix`], column-major dense storage.
//! * [`sparse`] — [`CscMatrix`], compressed sparse column storage for
//!   one-hot / n-gram / dictionary workloads.
//! * [`view`] — [`ScreenedView`], the zero-copy survivor-column view that
//!   reduced problems are built on after screening.
//! * [`ops`] — vector kernels: dot, axpy, nrm2, scale, …
//! * [`power`] — power iteration for spectral norms `‖X_g‖₂` (generic over
//!   the backend).
//!
//! See `rust/src/linalg/README.md` for backend selection guidance and the
//! `TLFRE_THREADS` parallelism knob.

pub mod dense;
pub mod ops;
pub mod power;
pub mod sparse;
pub mod traits;
pub mod view;

pub use dense::DenseMatrix;
pub use sparse::CscMatrix;
pub use traits::{DesignMatrix, SelectRows};
pub use view::ScreenedView;
