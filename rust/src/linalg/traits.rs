//! The design-matrix backend abstraction.
//!
//! Every layer above linalg — solvers, screening rules, the pathwise
//! coordinator, the CLI — is generic over [`DesignMatrix`], which captures
//! the small operation set the whole paper needs:
//!
//! * the two hot sweeps `Xβ` ([`DesignMatrix::matvec`]) and `Xᵀv`
//!   ([`DesignMatrix::matvec_t`], parallelized over column chunks via
//!   [`crate::util::pool`] — set `TLFRE_THREADS` to bound the workers);
//! * per-column primitives ([`DesignMatrix::col_dot`],
//!   [`DesignMatrix::col_axpy`], [`DesignMatrix::col_norm`]) used by the
//!   BCD group loops, power iteration and the screening rules;
//! * subset sweeps for active-set solvers.
//!
//! Three backends implement it: [`super::DenseMatrix`] (column-major dense),
//! [`super::CscMatrix`] (compressed sparse column) and
//! [`super::ScreenedView`] (a zero-copy survivor-column view used for
//! reduced problems after screening — no per-λ gather copy).

use crate::groups::GroupStructure;
use crate::util::pool;

/// Minimum `rows·cols` product before the default [`DesignMatrix::matvec_t`]
/// fans out over threads. Below this, a serial sweep wins: even with the
/// persistent pool (no per-call thread spawn) a dispatch still costs a
/// channel send plus a wake/latch round-trip per worker — microseconds,
/// which would dominate a sub-0.1 ms sweep on a small reduced problem.
/// The parallel and serial sweeps are bitwise identical, so the threshold
/// never affects results — only wall-clock. `TLFRE_THREADS=1` forces
/// serial regardless.
pub const PAR_MIN_WORK: usize = 1 << 18;

/// Column-oriented design-matrix backend.
///
/// `Sync` is part of the contract: the default `matvec_t` fans the
/// per-column dot products out across threads.
pub trait DesignMatrix: Sync {
    /// Sample dimension `N`.
    fn rows(&self) -> usize;

    /// Feature dimension `p`.
    fn cols(&self) -> usize;

    /// `x_jᵀ v` (f32 accumulation — the solvers' inner-loop dot).
    fn col_dot(&self, j: usize, v: &[f32]) -> f32;

    /// `x_jᵀ v` with f64 accumulation (λmax boundary computations, where
    /// the argmax over columns is sensitive to rounding).
    fn col_dot_f64(&self, j: usize, v: &[f32]) -> f64;

    /// `out += alpha · x_j`.
    fn col_axpy(&self, j: usize, alpha: f32, out: &mut [f32]);

    /// `‖x_j‖₂` (f64 accumulation).
    fn col_norm(&self, j: usize) -> f64;

    /// Materialize column `j` into a dense buffer of length `rows()`.
    fn col_to_dense(&self, j: usize, out: &mut [f32]);

    /// Approximate scalar-op count of one full `Xᵀv` sweep — the quantity
    /// the parallel-dispatch threshold compares against [`PAR_MIN_WORK`].
    /// Dense backends do `rows·cols` work; sparse backends override this
    /// with their nonzero count so low-density sweeps stay serial instead
    /// of paying thread-spawn overhead for microseconds of work.
    fn sweep_work(&self) -> usize {
        self.rows().saturating_mul(self.cols())
    }

    /// `out = X β` — accumulates only over columns with nonzero coefficient,
    /// which is what makes warm-started sparse iterates cheap.
    fn matvec(&self, beta: &[f32], out: &mut [f32]) {
        assert_eq!(beta.len(), self.cols());
        assert_eq!(out.len(), self.rows());
        out.fill(0.0);
        for (j, &bj) in beta.iter().enumerate() {
            if bj != 0.0 {
                self.col_axpy(j, bj, out);
            }
        }
    }

    /// `out = Xᵀ v` — the screening sweep. The default implementation
    /// parallelizes over contiguous column chunks; each `out[j]` is an
    /// independent dot product, so the result is bitwise identical to the
    /// serial sweep regardless of the worker count. Small sweeps (under
    /// [`PAR_MIN_WORK`] scalar ops) stay serial: scoped-thread spawn costs
    /// tens of microseconds, which would dominate the solvers' inner loops
    /// on small reduced problems.
    fn matvec_t(&self, v: &[f32], out: &mut [f32]) {
        assert_eq!(v.len(), self.rows());
        assert_eq!(out.len(), self.cols());
        if self.sweep_work() < PAR_MIN_WORK {
            for (j, o) in out.iter_mut().enumerate() {
                *o = self.col_dot(j, v);
            }
        } else {
            pool::parallel_fill(out, |j| self.col_dot(j, v));
        }
    }

    /// `out = Xβ − y` in one fused pass — the FISTA gradient residual.
    ///
    /// `out` is initialized to `−y` and the nonzero columns of β are
    /// accumulated on top, which removes the separate full-`N` subtraction
    /// sweep the solvers used to pay on every iteration after `matvec`.
    /// (Accumulation starts from `−y` instead of `0`, so the result can
    /// differ from `matvec`-then-subtract in the last bit of rounding —
    /// both orderings are valid f32 evaluations of the same sum.)
    fn residual_matvec(&self, beta: &[f32], y: &[f32], out: &mut [f32]) {
        assert_eq!(beta.len(), self.cols());
        assert_eq!(y.len(), self.rows());
        assert_eq!(out.len(), self.rows());
        for (o, &yi) in out.iter_mut().zip(y) {
            *o = -yi;
        }
        for (j, &bj) in beta.iter().enumerate() {
            if bj != 0.0 {
                self.col_axpy(j, bj, out);
            }
        }
    }

    /// `out = y − Xβ` in one fused pass — the reporting/screening residual,
    /// the mirror image of [`Self::residual_matvec`]: `out` starts from `y`
    /// and each nonzero column's contribution is subtracted via
    /// [`Self::col_axpy`]. Single source of truth for every `y − Xβ` in the
    /// solvers and path runners.
    fn residual(&self, beta: &[f32], y: &[f32], out: &mut [f32]) {
        assert_eq!(beta.len(), self.cols());
        assert_eq!(y.len(), self.rows());
        assert_eq!(out.len(), self.rows());
        out.copy_from_slice(y);
        for (j, &bj) in beta.iter().enumerate() {
            if bj != 0.0 {
                self.col_axpy(j, -bj, out);
            }
        }
    }

    /// `Xᵀ v` restricted to the columns in `idx` (active-set solver sweeps).
    fn matvec_t_subset(&self, v: &[f32], idx: &[usize], out: &mut [f32]) {
        assert_eq!(out.len(), idx.len());
        for (k, &j) in idx.iter().enumerate() {
            out[k] = self.col_dot(j, v);
        }
    }

    /// Per-column euclidean norms `‖x_j‖₂`.
    fn col_norms(&self) -> Vec<f64> {
        (0..self.cols()).map(|j| self.col_norm(j)).collect()
    }

    /// Validate that a group structure covers this matrix's columns.
    fn check_groups(&self, groups: &GroupStructure) {
        assert_eq!(
            groups.n_features(),
            self.cols(),
            "group structure covers {} features but matrix has {} columns",
            groups.n_features(),
            self.cols()
        );
    }
}

/// Row subsetting — needed by cross-validation fold extraction. Implemented
/// by the owning backends ([`super::DenseMatrix`], [`super::CscMatrix`]);
/// views re-run screening on the fold instead.
pub trait SelectRows: Sized {
    /// Extract the submatrix with the given rows (kept order).
    fn select_rows(&self, rows: &[usize]) -> Self;
}
