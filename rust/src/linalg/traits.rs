//! The design-matrix backend abstraction.
//!
//! Every layer above linalg — solvers, screening rules, the pathwise
//! coordinator, the CLI — is generic over [`DesignMatrix`], which captures
//! the small operation set the whole paper needs:
//!
//! * the two hot sweeps `Xβ` ([`DesignMatrix::matvec`]) and `Xᵀv`
//!   ([`DesignMatrix::matvec_t`], parallelized over column chunks via
//!   [`crate::util::pool`] — set `TLFRE_THREADS` to bound the workers);
//! * per-column primitives ([`DesignMatrix::col_dot`],
//!   [`DesignMatrix::col_axpy`], [`DesignMatrix::col_norm`]) used by the
//!   BCD group loops, power iteration and the screening rules;
//! * subset sweeps for active-set solvers.
//!
//! Three backends implement it: [`super::DenseMatrix`] (column-major dense),
//! [`super::CscMatrix`] (compressed sparse column) and
//! [`super::ScreenedView`] (a zero-copy survivor-column view used for
//! reduced problems after screening — no per-λ gather copy).

use crate::groups::GroupStructure;
use crate::util::pool;

/// Minimum `rows·cols` product before the default [`DesignMatrix::matvec_t`]
/// fans out over threads. Below this, a serial sweep wins: even with the
/// persistent pool (no per-call thread spawn) a dispatch still costs a
/// channel send plus a wake/latch round-trip per worker — microseconds,
/// which would dominate a sub-0.1 ms sweep on a small reduced problem.
/// The parallel and serial sweeps are bitwise identical, so the threshold
/// never affects results — only wall-clock. `TLFRE_THREADS=1` forces
/// serial regardless.
pub const PAR_MIN_WORK: usize = 1 << 18;

/// Column-oriented design-matrix backend.
///
/// `Sync` is part of the contract: the default `matvec_t` fans the
/// per-column dot products out across threads.
pub trait DesignMatrix: Sync {
    /// Sample dimension `N`.
    fn rows(&self) -> usize;

    /// Feature dimension `p`.
    fn cols(&self) -> usize;

    /// `x_jᵀ v` (f32 accumulation — the solvers' inner-loop dot).
    fn col_dot(&self, j: usize, v: &[f32]) -> f32;

    /// `x_jᵀ v` with f64 accumulation (λmax boundary computations, where
    /// the argmax over columns is sensitive to rounding).
    fn col_dot_f64(&self, j: usize, v: &[f32]) -> f64;

    /// `out += alpha · x_j`.
    fn col_axpy(&self, j: usize, alpha: f32, out: &mut [f32]);

    /// `‖x_j‖₂` (f64 accumulation).
    fn col_norm(&self, j: usize) -> f64;

    /// Materialize column `j` into a dense buffer of length `rows()`.
    fn col_to_dense(&self, j: usize, out: &mut [f32]);

    /// The row-restricted form of [`Self::col_axpy`]: accumulate rows
    /// `[row_start, row_end)` of `alpha · x_j` into `out`, where `out[k]`
    /// holds row `row_start + k` (`out.len() == row_end − row_start`).
    ///
    /// This is the kernel the row-blocked parallel [`Self::matvec`] is
    /// built on: each pool worker owns a disjoint row chunk of the output
    /// and replays the same per-column accumulation order as the serial
    /// sweep, so restricting a column to a row range must add **exactly**
    /// the additions the unrestricted kernel would have performed on those
    /// rows — nothing more (no touched-row set growth), nothing reordered.
    fn col_axpy_rows(
        &self,
        j: usize,
        alpha: f32,
        row_start: usize,
        row_end: usize,
        out: &mut [f32],
    );

    /// OR the rows **touched** by column `j`'s storage into a `u64` bitset
    /// (row `i` ↦ `bits[i / 64]`, bit `i % 64`; `bits` must hold
    /// `rows().div_ceil(64)` words). "Touched" means the rows
    /// [`Self::col_axpy`] reads or writes — *all* rows for dense storage
    /// (an explicit `+ 0.0` is still a write), only the stored entries for
    /// CSC. This is the conflict notion behind the red-black BCD group
    /// coloring ([`crate::sgl::coloring`]): two groups whose touched-row
    /// sets are disjoint commute exactly and may sweep concurrently.
    fn col_touched_rows(&self, j: usize, bits: &mut [u64]) {
        let _ = j;
        debug_assert!(bits.len() >= self.rows().div_ceil(64));
        // Default (dense storage): every row is touched.
        let n = self.rows();
        for word in bits.iter_mut().take(n / 64) {
            *word = u64::MAX;
        }
        if n % 64 != 0 {
            bits[n / 64] |= (1u64 << (n % 64)) - 1;
        }
    }

    /// Approximate scalar-op count of one full `Xᵀv` sweep — the quantity
    /// the parallel-dispatch threshold compares against [`PAR_MIN_WORK`].
    /// Dense backends do `rows·cols` work; sparse backends override this
    /// with their nonzero count so low-density sweeps stay serial instead
    /// of paying thread-spawn overhead for microseconds of work.
    fn sweep_work(&self) -> usize {
        self.rows().saturating_mul(self.cols())
    }

    /// `out = X β` — accumulates only over columns with nonzero coefficient,
    /// which is what makes warm-started sparse iterates cheap.
    ///
    /// Large sweeps are **row-blocked across the worker pool**: each worker
    /// owns a disjoint row range of `out` and accumulates the nonzero
    /// columns into it in the serial column order (via
    /// [`Self::col_axpy_rows`]), so the result is bitwise identical to the
    /// serial sweep at every worker count — row partitioning decides which
    /// thread owns an output element, never the order of additions into it.
    /// Sweeps under [`PAR_MIN_WORK`] estimated scalar ops stay serial.
    fn matvec(&self, beta: &[f32], out: &mut [f32]) {
        assert_eq!(beta.len(), self.cols());
        assert_eq!(out.len(), self.rows());
        out.fill(0.0);
        accumulate_cols(self, beta, 1.0, out);
    }

    /// The serial reference for [`Self::matvec`]: the plain column-order
    /// accumulation loop, never dispatched to the pool. Kept public for the
    /// bitwise-parity tests (`tests/backend_parity.rs`) and the
    /// before/after bench in `benches/perf_kernels.rs`; production callers
    /// use [`Self::matvec`].
    fn matvec_serial(&self, beta: &[f32], out: &mut [f32]) {
        assert_eq!(beta.len(), self.cols());
        assert_eq!(out.len(), self.rows());
        out.fill(0.0);
        for (j, &bj) in beta.iter().enumerate() {
            if bj != 0.0 {
                self.col_axpy(j, bj, out);
            }
        }
    }

    /// [`Self::matvec`] with an explicit row-chunking worker count,
    /// bypassing the [`PAR_MIN_WORK`] threshold. Exposed for the parity
    /// tests and the parallel-matvec bench; bitwise identical to
    /// [`Self::matvec_serial`] for every `workers`.
    fn matvec_with_workers(&self, beta: &[f32], out: &mut [f32], workers: usize) {
        assert_eq!(beta.len(), self.cols());
        assert_eq!(out.len(), self.rows());
        out.fill(0.0);
        accumulate_cols_with_workers(self, beta, 1.0, out, workers);
    }

    /// `out = Xᵀ v` — the screening sweep. The default implementation
    /// parallelizes over contiguous column chunks; each `out[j]` is an
    /// independent dot product, so the result is bitwise identical to the
    /// serial sweep regardless of the worker count. Small sweeps (under
    /// [`PAR_MIN_WORK`] scalar ops) stay serial: scoped-thread spawn costs
    /// tens of microseconds, which would dominate the solvers' inner loops
    /// on small reduced problems.
    fn matvec_t(&self, v: &[f32], out: &mut [f32]) {
        assert_eq!(v.len(), self.rows());
        assert_eq!(out.len(), self.cols());
        if self.sweep_work() < PAR_MIN_WORK {
            for (j, o) in out.iter_mut().enumerate() {
                *o = self.col_dot(j, v);
            }
        } else {
            pool::parallel_fill(out, |j| self.col_dot(j, v));
        }
    }

    /// `out = Xβ − y` in one fused pass — the FISTA gradient residual.
    ///
    /// `out` is initialized to `−y` and the nonzero columns of β are
    /// accumulated on top, which removes the separate full-`N` subtraction
    /// sweep the solvers used to pay on every iteration after `matvec`.
    /// (Accumulation starts from `−y` instead of `0`, so the result can
    /// differ from `matvec`-then-subtract in the last bit of rounding —
    /// both orderings are valid f32 evaluations of the same sum.)
    /// Row-blocked across the pool exactly like [`Self::matvec`] — the
    /// `−y` initialization is per-element, so parallelism stays bitwise
    /// invisible.
    fn residual_matvec(&self, beta: &[f32], y: &[f32], out: &mut [f32]) {
        assert_eq!(beta.len(), self.cols());
        assert_eq!(y.len(), self.rows());
        assert_eq!(out.len(), self.rows());
        for (o, &yi) in out.iter_mut().zip(y) {
            *o = -yi;
        }
        accumulate_cols(self, beta, 1.0, out);
    }

    /// `out = y − Xβ` in one fused pass — the reporting/screening residual,
    /// the mirror image of [`Self::residual_matvec`]: `out` starts from `y`
    /// and each nonzero column's contribution is subtracted. Single source
    /// of truth for every `y − Xβ` in the solvers and path runners;
    /// row-blocked across the pool exactly like [`Self::matvec`].
    fn residual(&self, beta: &[f32], y: &[f32], out: &mut [f32]) {
        assert_eq!(beta.len(), self.cols());
        assert_eq!(y.len(), self.rows());
        assert_eq!(out.len(), self.rows());
        out.copy_from_slice(y);
        accumulate_cols(self, beta, -1.0, out);
    }

    /// `Xᵀ v` restricted to the columns in `idx` (active-set solver sweeps).
    fn matvec_t_subset(&self, v: &[f32], idx: &[usize], out: &mut [f32]) {
        assert_eq!(out.len(), idx.len());
        for (k, &j) in idx.iter().enumerate() {
            out[k] = self.col_dot(j, v);
        }
    }

    /// Per-column euclidean norms `‖x_j‖₂`.
    fn col_norms(&self) -> Vec<f64> {
        (0..self.cols()).map(|j| self.col_norm(j)).collect()
    }

    /// Validate that a group structure covers this matrix's columns.
    fn check_groups(&self, groups: &GroupStructure) {
        assert_eq!(
            groups.n_features(),
            self.cols(),
            "group structure covers {} features but matrix has {} columns",
            groups.n_features(),
            self.cols()
        );
    }
}

/// `out[i] += sign · Σ_j β_j x_{ij}` — the shared accumulation core of
/// [`DesignMatrix::matvec`] / [`DesignMatrix::residual_matvec`] /
/// [`DesignMatrix::residual`] (which differ only in how `out` was
/// initialized and in the sign). Fans out over row chunks when the
/// estimated work (per-column sweep cost × nonzero coefficients) crosses
/// [`PAR_MIN_WORK`]; otherwise runs the plain serial column loop. Both
/// paths are bitwise identical (see [`accumulate_cols_with_workers`]).
fn accumulate_cols<M: DesignMatrix + ?Sized>(x: &M, beta: &[f32], sign: f32, out: &mut [f32]) {
    let nnz_b = beta.iter().filter(|&&b| b != 0.0).count();
    let cols = x.cols().max(1);
    let work = (x.sweep_work() / cols).saturating_mul(nnz_b);
    let workers = if work < PAR_MIN_WORK { 1 } else { pool::num_threads() };
    accumulate_cols_with_workers(x, beta, sign, out, workers);
}

/// [`accumulate_cols`] with an explicit row-chunking worker count.
///
/// ## Determinism contract
///
/// Each worker owns a disjoint contiguous row range of `out` and visits the
/// nonzero columns **in the same ascending order as the serial loop**,
/// restricted to its rows via [`DesignMatrix::col_axpy_rows`]. Every output
/// element therefore receives exactly the serial sequence of additions, so
/// the result is bitwise identical to the serial loop for every `workers`
/// value and every chunk partition — there are no per-worker partial
/// vectors and no merge step whose association order could differ. Exposed
/// `pub` for the parity tests (`tests/backend_parity.rs`) and the
/// parallel-matvec bench; production callers go through the trait defaults.
pub fn accumulate_cols_with_workers<M: DesignMatrix + ?Sized>(
    x: &M,
    beta: &[f32],
    sign: f32,
    out: &mut [f32],
    workers: usize,
) {
    assert_eq!(beta.len(), x.cols());
    assert_eq!(out.len(), x.rows());
    if workers <= 1 || out.is_empty() {
        for (j, &bj) in beta.iter().enumerate() {
            if bj != 0.0 {
                x.col_axpy(j, sign * bj, out);
            }
        }
        return;
    }
    pool::parallel_chunks_mut(out, workers, |start, chunk| {
        let end = start + chunk.len();
        if start == 0 && end == x.rows() {
            // Serial fallback inside the pool primitive (1 effective
            // worker / nested dispatch): identical full-range kernel.
            for (j, &bj) in beta.iter().enumerate() {
                if bj != 0.0 {
                    x.col_axpy(j, sign * bj, chunk);
                }
            }
        } else {
            for (j, &bj) in beta.iter().enumerate() {
                if bj != 0.0 {
                    x.col_axpy_rows(j, sign * bj, start, end, chunk);
                }
            }
        }
    });
}

/// Per-column norms computed in **column blocks** of at most `block_cols`
/// columns — the out-of-core form of [`DesignMatrix::col_norms`].
///
/// Each block's entries are filled over the pool (per-column `col_norm`
/// calls are independent), then the sweep advances to the next block, so
/// the working set at any instant is one block of columns. Over an
/// [`super::MmapDenseMatrix`] that bounds the resident X pages to
/// `rows · block_cols · 4` bytes per block and lets the kernel reclaim the
/// previous block's pages; the in-RAM backends just get the same answer.
/// Every entry is the same independent `col_norm(j)` the unblocked default
/// computes, so the result is **exactly** equal (bitwise) for every
/// `block_cols` and worker count.
pub fn col_norms_blocked<M: DesignMatrix + ?Sized>(x: &M, block_cols: usize) -> Vec<f64> {
    let p = x.cols();
    let block = block_cols.max(1);
    let mut out = vec![0.0f64; p];
    let mut j0 = 0;
    while j0 < p {
        let j1 = (j0 + block).min(p);
        pool::parallel_fill(&mut out[j0..j1], |k| x.col_norm(j0 + k));
        j0 = j1;
    }
    out
}

/// Row subsetting — needed by cross-validation fold extraction. Implemented
/// by the owning backends ([`super::DenseMatrix`], [`super::CscMatrix`]);
/// views re-run screening on the fold instead.
pub trait SelectRows: Sized {
    /// Extract the submatrix with the given rows (kept order).
    fn select_rows(&self, rows: &[usize]) -> Self;
}
