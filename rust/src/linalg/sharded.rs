//! Row-sharded composite backend.
//!
//! [`ShardedMatrix`] stacks any number of [`DesignMatrix`] shards vertically
//! (`X = [X₁; X₂; …]`, row offsets recording where each shard starts) and
//! implements the full backend contract over them. Shards are trait objects,
//! so a composite can mix storage — dense blocks next to CSC blocks next to
//! mmapped files — which is the shape a future distributed split needs: each
//! worker owns the rows it can serve cheaply.
//!
//! ## Bitwise contract
//!
//! The repo invariant (results bitwise identical to the serial dense sweep
//! at every worker count) constrains the kernels in two different ways:
//!
//! * **Reductions** (`col_dot`, `col_dot_f64`, `col_norm`): summing per
//!   shard and combining would re-associate the lane-blocked accumulation
//!   in [`ops`], changing the last bits. Instead the full column is
//!   materialized into a thread-local scratch (one `col_to_dense` per
//!   shard, disjoint ranges) and the *identical* whole-column kernel runs
//!   over it — same sequence of adds as [`super::DenseMatrix`], bitwise
//!   equal results, at the cost of one column copy per call.
//! * **Accumulations** (`col_axpy`, `col_axpy_rows`, the forward sweeps):
//!   element-wise, so they delegate per shard into disjoint sub-ranges of
//!   the output with no cross-shard arithmetic — bitwise equality is free.
//!
//! Forward sweeps (`matvec` / `residual*`) dispatch **one shard per pool
//! worker** via [`pool::parallel_chunks_mut_at`] with the shard row offsets
//! as chunk boundaries: a worker's chunk is exactly one shard's row range,
//! so each `col_axpy_rows` stays inside a single shard (no straddled
//! calls, good locality when a shard is an mmapped file). Boundary choice
//! never affects results — only which thread owns a row.

use super::dense::DenseMatrix;
use super::ops;
use super::traits::{DesignMatrix, PAR_MIN_WORK};
use crate::util::pool;
use std::cell::RefCell;

thread_local! {
    /// Scratch for whole-column materialization (reduction kernels).
    static COL_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Vertical concatenation of [`DesignMatrix`] shards (see module doc).
pub struct ShardedMatrix {
    shards: Vec<Box<dyn DesignMatrix + Send>>,
    /// `row_offsets[s]..row_offsets[s+1]` is shard `s`'s global row range.
    row_offsets: Vec<usize>,
    cols: usize,
}

impl ShardedMatrix {
    /// Stack `shards` vertically. All shards must share the column count
    /// and be nonempty.
    pub fn new(shards: Vec<Box<dyn DesignMatrix + Send>>) -> ShardedMatrix {
        assert!(!shards.is_empty(), "ShardedMatrix needs at least one shard");
        let cols = shards[0].cols();
        let mut row_offsets = Vec::with_capacity(shards.len() + 1);
        row_offsets.push(0usize);
        for s in &shards {
            assert_eq!(s.cols(), cols, "all shards must share the column count");
            assert!(s.rows() > 0, "empty shard");
            row_offsets.push(row_offsets.last().unwrap() + s.rows());
        }
        ShardedMatrix { shards, row_offsets, cols }
    }

    /// Split a dense matrix into `n_shards` contiguous row blocks (the last
    /// may be smaller). Clamped to at least 1 and at most `rows` shards.
    pub fn from_dense(x: &DenseMatrix, n_shards: usize) -> ShardedMatrix {
        let n = x.rows();
        assert!(n > 0, "cannot shard an empty matrix");
        let chunk = n.div_ceil(n_shards.clamp(1, n));
        let mut shards: Vec<Box<dyn DesignMatrix + Send>> = Vec::new();
        let mut r0 = 0;
        while r0 < n {
            let r1 = (r0 + chunk).min(n);
            let mut data = Vec::with_capacity((r1 - r0) * x.cols());
            for j in 0..x.cols() {
                data.extend_from_slice(&x.col(j)[r0..r1]);
            }
            shards.push(Box::new(DenseMatrix::from_col_major(r1 - r0, x.cols(), data)));
            r0 = r1;
        }
        ShardedMatrix::new(shards)
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Global row offsets, length `n_shards() + 1`.
    pub fn row_offsets(&self) -> &[usize] {
        &self.row_offsets
    }

    #[inline]
    fn shard_range(&self, s: usize) -> (usize, usize) {
        (self.row_offsets[s], self.row_offsets[s + 1])
    }

    /// Materialize column `j` (all shards, disjoint ranges) into the
    /// thread-local scratch and run `f` over it — the reduction-kernel path
    /// of the bitwise contract (module doc).
    fn with_full_col<R>(&self, j: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        COL_SCRATCH.with(|cell| {
            let mut buf = cell.borrow_mut();
            let n = self.rows();
            buf.resize(n, 0.0);
            for (s, shard) in self.shards.iter().enumerate() {
                let (lo, hi) = self.shard_range(s);
                shard.col_to_dense(j, &mut buf[lo..hi]);
            }
            f(&buf)
        })
    }

    /// Shared forward-sweep core: `out[i] += sign·Σ_j β_j x_{ij}` with
    /// shard-aligned pool dispatch (or the plain serial loop under the
    /// [`PAR_MIN_WORK`] threshold). Bitwise identical either way.
    fn accumulate(&self, beta: &[f32], sign: f32, out: &mut [f32], force_workers: Option<usize>) {
        assert_eq!(beta.len(), self.cols());
        assert_eq!(out.len(), self.rows());
        let nnz_b = beta.iter().filter(|&&b| b != 0.0).count();
        let cols = self.cols().max(1);
        let parallel = match force_workers {
            Some(w) => w > 1,
            None => {
                (self.sweep_work() / cols).saturating_mul(nnz_b) >= PAR_MIN_WORK
                    && pool::num_threads() > 1
            }
        };
        if !parallel {
            for (j, &bj) in beta.iter().enumerate() {
                if bj != 0.0 {
                    self.col_axpy(j, sign * bj, out);
                }
            }
            return;
        }
        let interior = &self.row_offsets[1..self.row_offsets.len() - 1];
        pool::parallel_chunks_mut_at(out, interior, |start, chunk| {
            let end = start + chunk.len();
            if start == 0 && end == self.rows() {
                // Serial fallback inside the pool primitive: whole-range
                // kernel, identical accumulation order.
                for (j, &bj) in beta.iter().enumerate() {
                    if bj != 0.0 {
                        self.col_axpy(j, sign * bj, chunk);
                    }
                }
            } else {
                for (j, &bj) in beta.iter().enumerate() {
                    if bj != 0.0 {
                        self.col_axpy_rows(j, sign * bj, start, end, chunk);
                    }
                }
            }
        });
    }
}

impl std::fmt::Debug for ShardedMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardedMatrix")
            .field("rows", &self.rows())
            .field("cols", &self.cols)
            .field("shards", &self.shards.len())
            .finish()
    }
}

impl DesignMatrix for ShardedMatrix {
    #[inline]
    fn rows(&self) -> usize {
        *self.row_offsets.last().unwrap()
    }

    #[inline]
    fn cols(&self) -> usize {
        self.cols
    }

    fn col_dot(&self, j: usize, v: &[f32]) -> f32 {
        self.with_full_col(j, |c| ops::dot_f32(c, v))
    }

    fn col_dot_f64(&self, j: usize, v: &[f32]) -> f64 {
        self.with_full_col(j, |c| ops::dot(c, v))
    }

    fn col_axpy(&self, j: usize, alpha: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.rows());
        for (s, shard) in self.shards.iter().enumerate() {
            let (lo, hi) = self.shard_range(s);
            shard.col_axpy(j, alpha, &mut out[lo..hi]);
        }
    }

    fn col_norm(&self, j: usize) -> f64 {
        self.with_full_col(j, ops::nrm2)
    }

    fn col_to_dense(&self, j: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.rows());
        for (s, shard) in self.shards.iter().enumerate() {
            let (lo, hi) = self.shard_range(s);
            shard.col_to_dense(j, &mut out[lo..hi]);
        }
    }

    fn col_axpy_rows(&self, j: usize, alpha: f32, rs: usize, re: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), re - rs);
        for (s, shard) in self.shards.iter().enumerate() {
            let (lo, hi) = self.shard_range(s);
            let a = rs.max(lo);
            let b = re.min(hi);
            if a < b {
                shard.col_axpy_rows(j, alpha, a - lo, b - lo, &mut out[a - rs..b - rs]);
            }
        }
    }

    fn col_touched_rows(&self, j: usize, bits: &mut [u64]) {
        for (s, shard) in self.shards.iter().enumerate() {
            let (lo, hi) = self.shard_range(s);
            let local_rows = hi - lo;
            let mut local = vec![0u64; local_rows.div_ceil(64)];
            shard.col_touched_rows(j, &mut local);
            or_shifted(bits, &local, lo, local_rows);
        }
    }

    fn sweep_work(&self) -> usize {
        self.shards.iter().map(|s| s.sweep_work()).fold(0usize, usize::saturating_add)
    }

    fn matvec(&self, beta: &[f32], out: &mut [f32]) {
        out.fill(0.0);
        self.accumulate(beta, 1.0, out, None);
    }

    fn matvec_with_workers(&self, beta: &[f32], out: &mut [f32], workers: usize) {
        out.fill(0.0);
        self.accumulate(beta, 1.0, out, Some(workers));
    }

    fn residual_matvec(&self, beta: &[f32], y: &[f32], out: &mut [f32]) {
        assert_eq!(y.len(), self.rows());
        assert_eq!(out.len(), self.rows());
        for (o, &yi) in out.iter_mut().zip(y) {
            *o = -yi;
        }
        self.accumulate(beta, 1.0, out, None);
    }

    fn residual(&self, beta: &[f32], y: &[f32], out: &mut [f32]) {
        assert_eq!(y.len(), self.rows());
        assert_eq!(out.len(), self.rows());
        out.copy_from_slice(y);
        self.accumulate(beta, -1.0, out, None);
    }
}

/// OR the first `n_bits` bits of `src` into `dst`, shifted left by
/// `offset` bit positions (shard-local row bits → global row bits).
fn or_shifted(dst: &mut [u64], src: &[u64], offset: usize, n_bits: usize) {
    let word_off = offset / 64;
    let bit_off = offset % 64;
    for (w, &raw) in src.iter().enumerate() {
        let base = w * 64;
        if base >= n_bits {
            break;
        }
        let mut word = raw;
        if n_bits - base < 64 {
            word &= (1u64 << (n_bits - base)) - 1;
        }
        if word == 0 {
            continue;
        }
        dst[word_off + w] |= word << bit_off;
        if bit_off != 0 {
            let hi = word >> (64 - bit_off);
            if hi != 0 {
                dst[word_off + w + 1] |= hi;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::CscMatrix;

    fn sample(n: usize, p: usize) -> DenseMatrix {
        DenseMatrix::from_fn(n, p, |i, j| {
            if (i * 7 + j * 3) % 5 == 0 {
                0.0
            } else {
                ((i * 13 + j * 11) % 17) as f32 * 0.21 - 1.6
            }
        })
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn reduction_kernels_bitwise_match_dense() {
        let dense = sample(37, 12);
        let v: Vec<f32> = (0..37).map(|i| (i as f32 * 0.4).sin()).collect();
        for n_shards in [1usize, 2, 3, 5, 37] {
            let sh = ShardedMatrix::from_dense(&dense, n_shards);
            assert_eq!(DesignMatrix::rows(&sh), 37);
            for j in 0..12 {
                assert_eq!(sh.col_dot(j, &v).to_bits(), dense.col_dot(j, &v).to_bits());
                assert_eq!(
                    sh.col_dot_f64(j, &v).to_bits(),
                    dense.col_dot_f64(j, &v).to_bits()
                );
                assert_eq!(sh.col_norm(j).to_bits(), dense.col_norm(j).to_bits());
            }
            let mut a = vec![0.0f32; 12];
            let mut b = vec![0.0f32; 12];
            DesignMatrix::matvec_t(&sh, &v, &mut a);
            DesignMatrix::matvec_t(&dense, &v, &mut b);
            assert_eq!(bits(&a), bits(&b), "n_shards={n_shards}");
        }
    }

    #[test]
    fn accumulation_kernels_bitwise_match_dense() {
        let dense = sample(41, 9);
        let beta: Vec<f32> =
            (0..9).map(|j| if j % 2 == 0 { (j as f32 * 0.7).cos() } else { 0.0 }).collect();
        let y: Vec<f32> = (0..41).map(|i| (i as f32 * 0.9).sin()).collect();
        for n_shards in [2usize, 3, 4] {
            let sh = ShardedMatrix::from_dense(&dense, n_shards);
            let mut serial = vec![0.0f32; 41];
            dense.matvec_serial(&beta, &mut serial);
            for workers in [1usize, 2, 3, 4, 8] {
                let mut par = vec![0.0f32; 41];
                sh.matvec_with_workers(&beta, &mut par, workers);
                assert_eq!(bits(&par), bits(&serial), "shards={n_shards} workers={workers}");
            }
            let mut ra = vec![0.0f32; 41];
            let mut rb = vec![0.0f32; 41];
            sh.residual(&beta, &y, &mut ra);
            DesignMatrix::residual(&dense, &beta, &y, &mut rb);
            assert_eq!(bits(&ra), bits(&rb));
            sh.residual_matvec(&beta, &y, &mut ra);
            DesignMatrix::residual_matvec(&dense, &beta, &y, &mut rb);
            assert_eq!(bits(&ra), bits(&rb));
            // Row-restricted accumulation across shard boundaries.
            for (rs, re) in [(0usize, 41usize), (5, 30), (13, 14), (20, 41)] {
                let mut full = vec![0.5f32; 41];
                dense.col_axpy(4, 1.1, &mut full);
                let mut part = vec![0.5f32; re - rs];
                sh.col_axpy_rows(4, 1.1, rs, re, &mut part);
                assert_eq!(bits(&part), bits(&full[rs..re]), "rows {rs}..{re}");
            }
        }
    }

    #[test]
    fn touched_rows_exact_for_mixed_shards() {
        // CSC shards report only stored rows; the composite must shift the
        // shard-local bits to global positions exactly.
        let dense = sample(70, 6);
        let n_words = 70usize.div_ceil(64);
        for n_shards in [2usize, 3, 7] {
            let top = ShardedMatrix::from_dense(&dense, n_shards);
            let csc_shards: Vec<Box<dyn DesignMatrix + Send>> = {
                let chunk = 70usize.div_ceil(n_shards);
                let mut v: Vec<Box<dyn DesignMatrix + Send>> = Vec::new();
                let mut r0 = 0;
                while r0 < 70 {
                    let r1 = (r0 + chunk).min(70);
                    let mut data = Vec::new();
                    for j in 0..6 {
                        data.extend_from_slice(&dense.col(j)[r0..r1]);
                    }
                    let block = DenseMatrix::from_col_major(r1 - r0, 6, data);
                    v.push(Box::new(CscMatrix::from_dense(&block)));
                    r0 = r1;
                }
                v
            };
            let sparse_sh = ShardedMatrix::new(csc_shards);
            for j in 0..6 {
                // Reference: per-row scan of the dense column.
                let mut expect = vec![0u64; n_words];
                for i in 0..70 {
                    if dense.get(i, j) != 0.0 {
                        expect[i / 64] |= 1u64 << (i % 64);
                    }
                }
                let mut got = vec![0u64; n_words];
                sparse_sh.col_touched_rows(j, &mut got);
                assert_eq!(got, expect, "j={j} shards={n_shards}");
                // Dense shards: every row touched.
                let mut all = vec![0u64; n_words];
                top.col_touched_rows(j, &mut all);
                let mut full = vec![u64::MAX; n_words];
                full[70 / 64] = (1u64 << (70 % 64)) - 1;
                assert_eq!(all, full);
            }
        }
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn mismatched_shard_cols_panic() {
        ShardedMatrix::new(vec![
            Box::new(DenseMatrix::zeros(3, 4)),
            Box::new(DenseMatrix::zeros(3, 5)),
        ]);
    }
}
