//! Compressed-sparse-column design matrix.
//!
//! The paper's screening story is strongest exactly where dense storage is
//! weakest: one-hot genomics designs, text n-grams, dictionary features —
//! matrices with a few percent density where every `Xᵀv` sweep over a dense
//! buffer wastes 20–100× the necessary bandwidth. `CscMatrix` stores each
//! column as `(row index, value)` pairs, so the per-column kernels the
//! [`DesignMatrix`] trait needs (`col_dot`, `col_axpy`, `col_norm`) touch
//! only the nonzeros, and the screening sweep scales with nnz instead of
//! `N·p`.
//!
//! Row indices are `u32` (the data loaders cap `N` at `2²⁴`), which halves
//! index memory relative to `usize` and keeps a column's index+value
//! streams cache-friendly.

use super::dense::DenseMatrix;
use super::ops;
use super::traits::{DesignMatrix, SelectRows};

/// Sparse `rows × cols` matrix in CSC layout, `f32` values.
#[derive(Debug, Clone, PartialEq)]
pub struct CscMatrix {
    rows: usize,
    cols: usize,
    /// Column pointers, length `cols + 1`; column `j`'s entries live at
    /// `indptr[j]..indptr[j+1]` in `indices`/`values`.
    indptr: Vec<usize>,
    /// Row index of each stored entry (strictly increasing within a column).
    indices: Vec<u32>,
    /// Stored values (no explicit zeros by construction of the builders;
    /// `from_parts` accepts them but the kernels remain correct either way).
    values: Vec<f32>,
}

impl CscMatrix {
    /// Build from raw CSC arrays. Panics on inconsistent shapes.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        values: Vec<f32>,
    ) -> CscMatrix {
        assert_eq!(indptr.len(), cols + 1, "indptr length must be cols+1");
        assert_eq!(indptr[0], 0, "indptr must start at 0");
        assert_eq!(*indptr.last().unwrap(), indices.len(), "indptr must end at nnz");
        assert_eq!(indices.len(), values.len(), "one value per index");
        assert!(indptr.windows(2).all(|w| w[0] <= w[1]), "indptr must be nondecreasing");
        assert!(indices.iter().all(|&i| (i as usize) < rows), "row index out of bounds");
        for j in 0..cols {
            let col = &indices[indptr[j]..indptr[j + 1]];
            assert!(
                col.windows(2).all(|w| w[0] < w[1]),
                "row indices must be strictly increasing within column {j} (duplicates would \
                 make the summing kernels disagree with the densified matrix)"
            );
        }
        CscMatrix { rows, cols, indptr, indices, values }
    }

    /// Build from a dense matrix, keeping entries with `|v| > 0`.
    pub fn from_dense(x: &DenseMatrix) -> CscMatrix {
        Self::from_dense_thresholded(x, 0.0)
    }

    /// Build from a dense matrix, dropping entries with `|v| ≤ eps`.
    pub fn from_dense_thresholded(x: &DenseMatrix, eps: f32) -> CscMatrix {
        let (rows, cols) = (x.rows(), x.cols());
        let mut indptr = Vec::with_capacity(cols + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0usize);
        for j in 0..cols {
            for (i, &v) in x.col(j).iter().enumerate() {
                if v.abs() > eps {
                    indices.push(i as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len());
        }
        CscMatrix { rows, cols, indptr, indices, values }
    }

    /// Materialize as a dense column-major matrix.
    pub fn to_dense(&self) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(self.rows, self.cols);
        for j in 0..self.cols {
            let (idx, val) = self.col(j);
            let col = out.col_mut(j);
            for (&i, &v) in idx.iter().zip(val) {
                col[i as usize] = v;
            }
        }
        out
    }

    /// Stored-entry count.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// nnz / (rows·cols).
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            0.0
        } else {
            self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
        }
    }

    /// Column `j` as `(row indices, values)` slices.
    #[inline]
    pub fn col(&self, j: usize) -> (&[u32], &[f32]) {
        debug_assert!(j < self.cols);
        let (s, e) = (self.indptr[j], self.indptr[j + 1]);
        (&self.indices[s..e], &self.values[s..e])
    }
}

impl DesignMatrix for CscMatrix {
    #[inline]
    fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    fn cols(&self) -> usize {
        self.cols
    }

    fn col_dot(&self, j: usize, v: &[f32]) -> f32 {
        debug_assert_eq!(v.len(), self.rows);
        let (idx, val) = self.col(j);
        let mut acc = 0.0f32;
        for (&i, &x) in idx.iter().zip(val) {
            acc += x * v[i as usize];
        }
        acc
    }

    fn col_dot_f64(&self, j: usize, v: &[f32]) -> f64 {
        debug_assert_eq!(v.len(), self.rows);
        let (idx, val) = self.col(j);
        let mut acc = 0.0f64;
        for (&i, &x) in idx.iter().zip(val) {
            acc += (x * v[i as usize]) as f64;
        }
        acc
    }

    fn col_axpy(&self, j: usize, alpha: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.rows);
        let (idx, val) = self.col(j);
        for (&i, &x) in idx.iter().zip(val) {
            out[i as usize] += alpha * x;
        }
    }

    fn col_norm(&self, j: usize) -> f64 {
        let (_, val) = self.col(j);
        ops::nrm2(val)
    }

    fn col_to_dense(&self, j: usize, out: &mut [f32]) {
        assert_eq!(out.len(), self.rows);
        out.fill(0.0);
        let (idx, val) = self.col(j);
        for (&i, &x) in idx.iter().zip(val) {
            out[i as usize] = x;
        }
    }

    fn col_axpy_rows(
        &self,
        j: usize,
        alpha: f32,
        row_start: usize,
        row_end: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), row_end - row_start);
        let (idx, val) = self.col(j);
        // Row indices are strictly increasing within a column, so the
        // entries falling in [row_start, row_end) form one contiguous
        // sub-range, found by binary search. The entries are then visited
        // in exactly the order the unrestricted `col_axpy` visits them —
        // the row-blocked matvec stays bitwise identical to serial.
        let lo = idx.partition_point(|&i| (i as usize) < row_start);
        let hi = lo + idx[lo..].partition_point(|&i| (i as usize) < row_end);
        for (&i, &x) in idx[lo..hi].iter().zip(&val[lo..hi]) {
            out[i as usize - row_start] += alpha * x;
        }
    }

    fn col_touched_rows(&self, j: usize, bits: &mut [u64]) {
        debug_assert!(bits.len() >= self.rows.div_ceil(64));
        let (idx, _) = self.col(j);
        for &i in idx {
            bits[i as usize / 64] |= 1u64 << (i as usize % 64);
        }
    }

    fn sweep_work(&self) -> usize {
        // A sweep touches each stored entry once.
        self.nnz()
    }
}

impl SelectRows for CscMatrix {
    fn select_rows(&self, rows: &[usize]) -> CscMatrix {
        // old row -> new row (or None if dropped)
        let mut map = vec![u32::MAX; self.rows];
        for (new_i, &old_i) in rows.iter().enumerate() {
            assert!(old_i < self.rows, "row index out of bounds");
            map[old_i] = new_i as u32;
        }
        let mut indptr = Vec::with_capacity(self.cols + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0usize);
        for j in 0..self.cols {
            let (idx, val) = self.col(j);
            // Collect surviving entries, then order by the NEW row index so
            // the within-column invariant holds for arbitrary `rows` order.
            let mut ents: Vec<(u32, f32)> = idx
                .iter()
                .zip(val)
                .filter_map(|(&i, &v)| {
                    let ni = map[i as usize];
                    if ni == u32::MAX {
                        None
                    } else {
                        Some((ni, v))
                    }
                })
                .collect();
            ents.sort_unstable_by_key(|&(i, _)| i);
            for (i, v) in ents {
                indices.push(i);
                values.push(v);
            }
            indptr.push(indices.len());
        }
        CscMatrix { rows: rows.len(), cols: self.cols, indptr, indices, values }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample_dense() -> DenseMatrix {
        // 3x4 with structural zeros
        DenseMatrix::from_col_major(
            3,
            4,
            vec![1.0, 0.0, 2.0, 0.0, 0.0, 0.0, -3.0, 4.0, 0.0, 0.0, 5.0, 6.0],
        )
    }

    #[test]
    fn roundtrip_dense_csc_dense() {
        let d = sample_dense();
        let s = CscMatrix::from_dense(&d);
        assert_eq!(s.nnz(), 6);
        assert!((s.density() - 0.5).abs() < 1e-12);
        assert_eq!(s.to_dense(), d);
    }

    #[test]
    fn kernels_match_dense() {
        let mut rng = Rng::seed_from_u64(7);
        let d = DenseMatrix::from_fn(9, 13, |_, _| {
            if rng.below(3) == 0 {
                rng.gaussian() as f32
            } else {
                0.0
            }
        });
        let s = CscMatrix::from_dense(&d);
        let v: Vec<f32> = (0..9).map(|_| rng.gaussian() as f32).collect();
        let beta: Vec<f32> = (0..13).map(|_| rng.gaussian() as f32).collect();

        let mut dmv = vec![0.0f32; 9];
        let mut smv = vec![0.0f32; 9];
        d.matvec(&beta, &mut dmv);
        DesignMatrix::matvec(&s, &beta, &mut smv);
        for i in 0..9 {
            assert!((dmv[i] - smv[i]).abs() < 1e-4, "matvec[{i}]");
        }

        let mut dt = vec![0.0f32; 13];
        let mut st = vec![0.0f32; 13];
        d.matvec_t(&v, &mut dt);
        DesignMatrix::matvec_t(&s, &v, &mut st);
        for j in 0..13 {
            assert!((dt[j] - st[j]).abs() < 1e-4, "matvec_t[{j}]");
        }

        let dn = d.col_norms();
        let sn = DesignMatrix::col_norms(&s);
        for j in 0..13 {
            assert!((dn[j] - sn[j]).abs() < 1e-10, "col_norms[{j}]");
        }
    }

    #[test]
    fn select_rows_matches_dense_gather() {
        let d = sample_dense();
        let s = CscMatrix::from_dense(&d);
        let rows = [2usize, 0];
        let sr = s.select_rows(&rows);
        assert_eq!(sr.rows, 2);
        let dr = sr.to_dense();
        for j in 0..4 {
            for (ni, &oi) in rows.iter().enumerate() {
                assert_eq!(dr.get(ni, j), d.get(oi, j), "({ni},{j})");
            }
        }
    }

    #[test]
    fn col_to_dense_scatters() {
        let s = CscMatrix::from_dense(&sample_dense());
        let mut buf = vec![9.0f32; 3];
        s.col_to_dense(2, &mut buf);
        assert_eq!(buf, vec![0.0, -3.0, 4.0]);
    }

    #[test]
    #[should_panic]
    fn bad_indptr_panics() {
        CscMatrix::from_parts(2, 2, vec![0, 1], vec![0], vec![1.0]);
    }

    #[test]
    fn col_axpy_rows_matches_restricted_col_axpy() {
        let s = CscMatrix::from_dense(&sample_dense());
        for j in 0..4 {
            let mut full = vec![0.5f32; 3];
            s.col_axpy(j, -2.0, &mut full);
            for (rs, re) in [(0usize, 3usize), (0, 1), (1, 3), (2, 2), (1, 2)] {
                let mut part = vec![0.5f32; re - rs];
                s.col_axpy_rows(j, -2.0, rs, re, &mut part);
                for k in 0..re - rs {
                    assert_eq!(part[k].to_bits(), full[rs + k].to_bits(), "j={j} rows {rs}..{re}");
                }
            }
        }
    }

    #[test]
    fn col_touched_rows_marks_exactly_stored_entries() {
        let s = CscMatrix::from_dense(&sample_dense());
        for j in 0..4 {
            let mut bits = vec![0u64; 1];
            s.col_touched_rows(j, &mut bits);
            let (idx, _) = s.col(j);
            for i in 0..3u32 {
                let marked = bits[0] >> i & 1 == 1;
                assert_eq!(marked, idx.contains(&i), "col {j} row {i}");
            }
        }
        // Dense default: every row marked.
        let d = sample_dense();
        let mut bits = vec![0u64; 1];
        d.col_touched_rows(1, &mut bits);
        assert_eq!(bits[0], 0b111);
    }

    #[test]
    fn parallel_matvec_matches_serial_reference() {
        let mut rng = Rng::seed_from_u64(23);
        let d = DenseMatrix::from_fn(13, 9, |_, _| {
            if rng.below(2) == 0 {
                rng.gaussian() as f32
            } else {
                0.0
            }
        });
        let s = CscMatrix::from_dense(&d);
        let beta: Vec<f32> = (0..9).map(|_| rng.gaussian() as f32).collect();
        let mut serial = vec![0.0f32; 13];
        s.matvec_serial(&beta, &mut serial);
        for workers in [2usize, 3, 5, 8] {
            let mut par = vec![0.0f32; 13];
            s.matvec_with_workers(&beta, &mut par, workers);
            for i in 0..13 {
                assert_eq!(par[i].to_bits(), serial[i].to_bits(), "i={i} workers={workers}");
            }
        }
    }
}
