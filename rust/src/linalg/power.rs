//! Power iteration for spectral norms.
//!
//! TLFre's group rule needs `‖X_g‖₂` (Theorem 15's radius `r‖X_g‖₂`) and the
//! solvers need the Lipschitz constant `L = ‖X‖₂²`. The paper computes these
//! with the power method ([8] in the paper) once per data set; this module
//! does the same, operating directly on column blocks through the
//! [`DesignMatrix`] per-column kernels — no submatrix copy, any backend.

use super::ops;
use super::traits::DesignMatrix;
use crate::util::Rng;

thread_local! {
    /// Per-thread count of power-iteration invocations (see
    /// [`spectral_call_count`]).
    static SPECTRAL_CALLS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Number of power-iteration invocations ([`spectral_norm_block`] entries)
/// made **by the calling thread** since process start. Thread-local so
/// concurrently running tests don't perturb each other's deltas.
///
/// This is the observability hook behind the path-level spectral-caching
/// guarantee: `run_tlfre_path`'s per-λ loop performs *zero* power
/// iterations by default, so the delta across a path run is independent of
/// the λ-grid length (asserted in `tests/lipschitz_cache.rs`). The exact
/// single-column shortcut in [`group_spectral_norms`] is not counted — it
/// is a plain column norm, not an iteration.
pub fn spectral_call_count() -> u64 {
    SPECTRAL_CALLS.get()
}

/// Result of a spectral-norm estimation.
#[derive(Debug, Clone, Copy)]
pub struct SpectralNorm {
    /// Estimated largest singular value.
    pub sigma: f64,
    /// Iterations used.
    pub iters: usize,
    /// Relative change in the last iteration (convergence measure).
    pub rel_change: f64,
}

/// Power iteration on `AᵀA` for the columns `[col_start, col_end)` of `x`.
///
/// Returns `σ_max` of the block. `tol` is the relative eigenvalue change
/// stopping threshold; the estimate is a lower bound that converges to
/// `σ_max` geometrically in `(σ₂/σ₁)²`.
pub fn spectral_norm_block<M: DesignMatrix>(
    x: &M,
    col_start: usize,
    col_end: usize,
    tol: f64,
    max_iter: usize,
    rng: &mut Rng,
) -> SpectralNorm {
    let n = x.rows();
    let m = col_end - col_start;
    assert!(m > 0, "empty column block");
    SPECTRAL_CALLS.set(SPECTRAL_CALLS.get() + 1);
    // v ∈ R^m (feature space), u ∈ R^n (sample space)
    let mut v: Vec<f32> = (0..m).map(|_| rng.gaussian() as f32).collect();
    let nv = ops::nrm2(&v).max(f64::MIN_POSITIVE) as f32;
    ops::scale(1.0 / nv, &mut v);
    let mut u = vec![0.0f32; n];
    let mut sigma_sq_prev = 0.0f64;
    let mut rel = f64::INFINITY;
    let mut it = 0;
    while it < max_iter {
        it += 1;
        // u = A v
        u.fill(0.0);
        for (k, &vk) in v.iter().enumerate() {
            if vk != 0.0 {
                x.col_axpy(col_start + k, vk, &mut u);
            }
        }
        // w = Aᵀ u ; σ² estimate = ‖w‖ (since v normalized, ‖AᵀAv‖ → σ²)
        for (k, vk) in v.iter_mut().enumerate() {
            *vk = x.col_dot(col_start + k, &u);
        }
        let sigma_sq = ops::nrm2(&v);
        if sigma_sq <= 0.0 {
            // Zero block.
            return SpectralNorm { sigma: 0.0, iters: it, rel_change: 0.0 };
        }
        ops::scale(1.0 / sigma_sq as f32, &mut v);
        rel = (sigma_sq - sigma_sq_prev).abs() / sigma_sq.max(f64::MIN_POSITIVE);
        if rel < tol {
            sigma_sq_prev = sigma_sq;
            break;
        }
        sigma_sq_prev = sigma_sq;
    }
    SpectralNorm { sigma: sigma_sq_prev.sqrt(), iters: it, rel_change: rel }
}

/// Spectral norm of the whole matrix.
pub fn spectral_norm<M: DesignMatrix>(
    x: &M,
    tol: f64,
    max_iter: usize,
    rng: &mut Rng,
) -> SpectralNorm {
    spectral_norm_block(x, 0, x.cols(), tol, max_iter, rng)
}

/// Per-group spectral norms `‖X_g‖₂` for a group structure given as
/// `(start, end)` column ranges.
pub fn group_spectral_norms<M: DesignMatrix>(
    x: &M,
    ranges: &[(usize, usize)],
    tol: f64,
    max_iter: usize,
    rng: &mut Rng,
) -> Vec<f64> {
    ranges
        .iter()
        .map(|&(s, e)| {
            if e - s == 1 {
                // Single column: σ = ‖x_j‖₂ exactly.
                x.col_norm(s)
            } else {
                spectral_norm_block(x, s, e, tol, max_iter, rng).sigma
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::dense::DenseMatrix;
    use super::super::sparse::CscMatrix;

    #[test]
    fn diagonal_matrix_sigma_max() {
        // diag(3, 1) embedded in 2x2
        let x = DenseMatrix::from_col_major(2, 2, vec![3.0, 0.0, 0.0, 1.0]);
        let mut rng = Rng::seed_from_u64(1);
        let s = spectral_norm(&x, 1e-12, 500, &mut rng);
        assert!((s.sigma - 3.0).abs() < 1e-6, "sigma={}", s.sigma);
    }

    #[test]
    fn rank_one_matrix() {
        // X = u vᵀ with ‖u‖=√(1+4)=√5, ‖v‖=√(9+16)=5 → σ = 5√5
        let u = [1.0f32, 2.0];
        let v = [3.0f32, 4.0];
        let x = DenseMatrix::from_fn(2, 2, |i, j| u[i] * v[j]);
        let mut rng = Rng::seed_from_u64(2);
        let s = spectral_norm(&x, 1e-12, 500, &mut rng);
        assert!((s.sigma - 5.0 * 5f64.sqrt()).abs() < 1e-4, "sigma={}", s.sigma);
    }

    #[test]
    fn single_column_is_exact_norm() {
        let x = DenseMatrix::from_col_major(3, 2, vec![1.0, 2.0, 2.0, 0.5, 0.5, 0.5]);
        let mut rng = Rng::seed_from_u64(3);
        let norms = group_spectral_norms(&x, &[(0, 1), (1, 2)], 1e-10, 200, &mut rng);
        assert!((norms[0] - 3.0).abs() < 1e-9);
        assert!((norms[1] - (0.75f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn zero_block() {
        let x = DenseMatrix::zeros(4, 3);
        let mut rng = Rng::seed_from_u64(4);
        let s = spectral_norm(&x, 1e-10, 100, &mut rng);
        assert_eq!(s.sigma, 0.0);
    }

    #[test]
    fn block_norm_bounded_by_frobenius_and_ge_col_norm() {
        let mut rng = Rng::seed_from_u64(5);
        let x = DenseMatrix::from_fn(10, 8, |_, _| rng.gaussian() as f32);
        let s = spectral_norm_block(&x, 2, 7, 1e-10, 1000, &mut rng).sigma;
        let sub = x.select_cols(&[2, 3, 4, 5, 6]);
        let fro = sub.fro_norm();
        let max_col = sub.col_norms().into_iter().fold(0.0f64, f64::max);
        assert!(s <= fro + 1e-6, "sigma {s} > fro {fro}");
        assert!(s >= max_col - 1e-6, "sigma {s} < max col norm {max_col}");
    }

    #[test]
    fn csc_backend_agrees_with_dense() {
        let mut rng = Rng::seed_from_u64(6);
        let x = DenseMatrix::from_fn(12, 9, |_, _| {
            if rng.below(2) == 0 {
                rng.gaussian() as f32
            } else {
                0.0
            }
        });
        let sp = CscMatrix::from_dense(&x);
        let mut r1 = Rng::seed_from_u64(7);
        let mut r2 = Rng::seed_from_u64(7);
        let a = spectral_norm(&x, 1e-10, 500, &mut r1).sigma;
        let b = spectral_norm(&sp, 1e-10, 500, &mut r2).sigma;
        assert!((a - b).abs() < 1e-4 * a.max(1.0), "dense {a} vs csc {b}");
    }
}
