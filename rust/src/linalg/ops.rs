//! Level-1 vector kernels (f64 accumulation over f32 data where it matters).
//!
//! All hot loops are written to autovectorize under `target-cpu=native`:
//! straight-line indexed loops over slices with bounds hoisted by
//! `chunks_exact`.

/// Dot product with 4-lane partial sums (f32 in, f64 out for stability on
/// long vectors).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s0 = 0.0f64;
    let mut s1 = 0.0f64;
    let mut s2 = 0.0f64;
    let mut s3 = 0.0f64;
    let ca = a.chunks_exact(4);
    let cb = b.chunks_exact(4);
    let ra = ca.remainder();
    let rb = cb.remainder();
    for (x, y) in ca.zip(cb) {
        s0 += (x[0] * y[0]) as f64;
        s1 += (x[1] * y[1]) as f64;
        s2 += (x[2] * y[2]) as f64;
        s3 += (x[3] * y[3]) as f64;
    }
    let mut tail = 0.0f64;
    for (x, y) in ra.iter().zip(rb) {
        tail += (x * y) as f64;
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Single-precision dot (used inside the innermost solver loops where the
/// vectors are short — length N ≤ a few thousand).
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = [0.0f32; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (x, y) in ca.zip(cb) {
        for k in 0..8 {
            s[k] += x[k] * y[k];
        }
    }
    let mut acc = ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7]));
    for (x, y) in ra.iter().zip(rb) {
        acc += x * y;
    }
    acc
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm ‖x‖₂ (f64 accumulation).
#[inline]
pub fn nrm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// Squared euclidean norm.
#[inline]
pub fn nrm2_sq(x: &[f32]) -> f64 {
    dot(x, x)
}

/// ℓ∞ norm.
#[inline]
pub fn nrm_inf(x: &[f32]) -> f32 {
    x.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

/// ℓ₁ norm.
#[inline]
pub fn nrm1(x: &[f32]) -> f64 {
    x.iter().map(|&v| v.abs() as f64).sum()
}

/// In-place scale `x *= alpha`.
#[inline]
pub fn scale(alpha: f32, x: &mut [f32]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// `out = a - b`.
#[inline]
pub fn sub(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for i in 0..out.len() {
        out[i] = a[i] - b[i];
    }
}

/// `out = a + alpha*b` (FISTA extrapolation).
#[inline]
pub fn add_scaled(a: &[f32], alpha: f32, b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for i in 0..out.len() {
        out[i] = a[i] + alpha * b[i];
    }
}

/// ‖a − b‖₂ without materializing the difference.
#[inline]
pub fn dist2(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f64;
    for i in 0..a.len() {
        let d = (a[i] - b[i]) as f64;
        s += d * d;
    }
    s.sqrt()
}

/// Count of exact zeros (used for sparsity/rejection accounting).
#[inline]
pub fn count_zeros(x: &[f32]) -> usize {
    x.iter().filter(|&&v| v == 0.0).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(x, y)| (x * y) as f64).sum()
    }

    #[test]
    fn dot_matches_naive_various_lengths() {
        for n in [0, 1, 3, 4, 7, 8, 17, 100, 255] {
            let a: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin()).collect();
            let b: Vec<f32> = (0..n).map(|i| (i as f32 * 0.11).cos()).collect();
            assert!((dot(&a, &b) - naive_dot(&a, &b)).abs() < 1e-4, "n={n}");
            assert!((dot_f32(&a, &b) as f64 - naive_dot(&a, &b)).abs() < 1e-3, "n={n}");
        }
    }

    #[test]
    fn axpy_and_scale() {
        let x = vec![1.0f32, 2.0, 3.0];
        let mut y = vec![10.0f32, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0, 36.0]);
        scale(0.5, &mut y);
        assert_eq!(y, vec![6.0, 12.0, 18.0]);
    }

    #[test]
    fn norms() {
        let x = vec![3.0f32, -4.0];
        assert!((nrm2(&x) - 5.0).abs() < 1e-9);
        assert!((nrm2_sq(&x) - 25.0).abs() < 1e-9);
        assert_eq!(nrm_inf(&x), 4.0);
        assert!((nrm1(&x) - 7.0).abs() < 1e-9);
        assert_eq!(nrm_inf(&[]), 0.0);
    }

    #[test]
    fn sub_add_dist() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![0.5f32, 1.0, 1.5];
        let mut out = vec![0.0f32; 3];
        sub(&a, &b, &mut out);
        assert_eq!(out, vec![0.5, 1.0, 1.5]);
        add_scaled(&a, 2.0, &b, &mut out);
        assert_eq!(out, vec![2.0, 4.0, 6.0]);
        assert!((dist2(&a, &b) - nrm2(&[0.5, 1.0, 1.5])).abs() < 1e-9);
    }

    #[test]
    fn zero_counting() {
        assert_eq!(count_zeros(&[0.0, 1.0, 0.0, -0.0]), 3);
        assert_eq!(count_zeros(&[]), 0);
    }
}
