//! Column-major dense matrix.
//!
//! Column-major is the right layout for this workload: both hot sweeps —
//! `Xᵀv` (one dot per column) and `Xβ` (one axpy per *nonzero* column of β)
//! — walk contiguous column slices, and extracting the reduced matrix after
//! screening is a straight `memcpy` per surviving column.

use super::ops;
use super::traits::{DesignMatrix, SelectRows};
use crate::groups::GroupStructure;

/// Dense `rows × cols` matrix, column-major, `f32` storage.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl DenseMatrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> DenseMatrix {
        DenseMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a column-major buffer (length must be `rows*cols`).
    pub fn from_col_major(rows: usize, cols: usize, data: Vec<f32>) -> DenseMatrix {
        assert_eq!(data.len(), rows * cols, "buffer length mismatch");
        DenseMatrix { rows, cols, data }
    }

    /// Build from a generator `f(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> DenseMatrix {
        let mut data = Vec::with_capacity(rows * cols);
        for j in 0..cols {
            for i in 0..rows {
                data.push(f(i, j));
            }
        }
        DenseMatrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Raw column-major buffer.
    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Contiguous column slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f32] {
        debug_assert!(j < self.cols);
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f32] {
        debug_assert!(j < self.cols);
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[j * self.rows + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[j * self.rows + i] = v;
    }

    /// Contiguous view of a group block `X_g` (columns `[start, end)`).
    #[inline]
    pub fn col_range(&self, start: usize, end: usize) -> &[f32] {
        debug_assert!(start <= end && end <= self.cols);
        &self.data[start * self.rows..end * self.rows]
    }

    // ----- products ---------------------------------------------------------
    //
    // The kernels live in the `DesignMatrix` trait impl below (single source
    // of truth); these inherent wrappers only exist so concretely-typed
    // callers (tests, data generators, examples) don't need the trait in
    // scope — and they get the identical code path, including the
    // column-chunk parallel sweep.

    /// `out = X β` — accumulates only over columns with nonzero coefficient,
    /// which is what makes warm-started sparse iterates cheap.
    pub fn matvec(&self, beta: &[f32], out: &mut [f32]) {
        DesignMatrix::matvec(self, beta, out);
    }

    /// `out = Xᵀ v` — one dot product per column (the screening sweep).
    pub fn matvec_t(&self, v: &[f32], out: &mut [f32]) {
        DesignMatrix::matvec_t(self, v, out);
    }

    /// `Xᵀ v` restricted to the columns in `idx` (active-set solver sweeps).
    pub fn matvec_t_subset(&self, v: &[f32], idx: &[usize], out: &mut [f32]) {
        DesignMatrix::matvec_t_subset(self, v, idx, out);
    }

    /// Per-column euclidean norms `‖x_j‖₂`.
    pub fn col_norms(&self) -> Vec<f64> {
        DesignMatrix::col_norms(self)
    }

    /// Extract the submatrix with the given columns (kept order).
    pub fn select_cols(&self, idx: &[usize]) -> DenseMatrix {
        let mut data = Vec::with_capacity(self.rows * idx.len());
        for &j in idx {
            data.extend_from_slice(self.col(j));
        }
        DenseMatrix { rows: self.rows, cols: idx.len(), data }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        ops::nrm2(&self.data)
    }

    /// Normalize every column to unit ℓ₂ norm (standard preprocessing for
    /// screening experiments; zero columns are left untouched).
    pub fn normalize_cols(&mut self) {
        for j in 0..self.cols {
            let n = ops::nrm2(self.col(j)) as f32;
            if n > 0.0 {
                ops::scale(1.0 / n, self.col_mut(j));
            }
        }
    }

    /// Validate that a group structure covers this matrix's columns.
    pub fn check_groups(&self, groups: &GroupStructure) {
        assert_eq!(
            groups.n_features(),
            self.cols,
            "group structure covers {} features but matrix has {} columns",
            groups.n_features(),
            self.cols
        );
    }
}

impl DesignMatrix for DenseMatrix {
    #[inline]
    fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    fn col_dot(&self, j: usize, v: &[f32]) -> f32 {
        ops::dot_f32(self.col(j), v)
    }

    #[inline]
    fn col_dot_f64(&self, j: usize, v: &[f32]) -> f64 {
        ops::dot(self.col(j), v)
    }

    #[inline]
    fn col_axpy(&self, j: usize, alpha: f32, out: &mut [f32]) {
        ops::axpy(alpha, self.col(j), out);
    }

    #[inline]
    fn col_norm(&self, j: usize) -> f64 {
        ops::nrm2(self.col(j))
    }

    fn col_to_dense(&self, j: usize, out: &mut [f32]) {
        out.copy_from_slice(self.col(j));
    }

    #[inline]
    fn col_axpy_rows(&self, j: usize, alpha: f32, rs: usize, re: usize, out: &mut [f32]) {
        ops::axpy(alpha, &self.col(j)[rs..re], out);
    }

    // col_touched_rows: the trait default (all rows) is exact for dense
    // storage — col_axpy writes every row, zero values included.

    // The trait defaults for matvec/matvec_t/col_norms produce exactly the
    // same arithmetic as the inherent methods above (same slices, same
    // kernels, per-column independence), with matvec_t fanned out over
    // column chunks and matvec row-blocked over the worker pool.
}

impl SelectRows for DenseMatrix {
    fn select_rows(&self, rows: &[usize]) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(rows.len(), self.cols);
        for j in 0..self.cols {
            let src = self.col(j);
            let dst = out.col_mut(j);
            for (k, &i) in rows.iter().enumerate() {
                dst[k] = src[i];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DenseMatrix {
        // 2x3 matrix [[1,2,3],[4,5,6]]
        DenseMatrix::from_col_major(2, 3, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0])
    }

    #[test]
    fn indexing_and_cols() {
        let m = sample();
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(1, 2), 6.0);
        assert_eq!(m.col(1), &[2.0, 5.0]);
        assert_eq!(m.col_range(1, 3), &[2.0, 5.0, 3.0, 6.0]);
    }

    #[test]
    fn from_fn_matches_set() {
        let m = DenseMatrix::from_fn(3, 2, |i, j| (i * 10 + j) as f32);
        assert_eq!(m.get(2, 1), 21.0);
        assert_eq!(m.col(0), &[0.0, 10.0, 20.0]);
    }

    #[test]
    fn matvec_known() {
        let m = sample();
        let mut out = vec![0.0; 2];
        m.matvec(&[1.0, 0.0, 2.0], &mut out);
        assert_eq!(out, vec![1.0 + 6.0, 4.0 + 12.0]);
    }

    #[test]
    fn matvec_t_known() {
        let m = sample();
        let mut out = vec![0.0; 3];
        m.matvec_t(&[1.0, 1.0], &mut out);
        assert_eq!(out, vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn matvec_t_subset_matches_full() {
        let m = sample();
        let mut full = vec![0.0; 3];
        m.matvec_t(&[0.5, -1.0], &mut full);
        let idx = vec![2usize, 0];
        let mut sub = vec![0.0; 2];
        m.matvec_t_subset(&[0.5, -1.0], &idx, &mut sub);
        assert_eq!(sub, vec![full[2], full[0]]);
    }

    #[test]
    fn select_and_norms() {
        let m = sample();
        let s = m.select_cols(&[2, 0]);
        assert_eq!(s.cols(), 2);
        assert_eq!(s.col(0), &[3.0, 6.0]);
        assert_eq!(s.col(1), &[1.0, 4.0]);
        let norms = m.col_norms();
        assert!((norms[0] - (17.0f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn normalize_cols_unit() {
        let mut m = sample();
        m.normalize_cols();
        for n in m.col_norms() {
            assert!((n - 1.0).abs() < 1e-6);
        }
        // zero column stays zero
        let mut z = DenseMatrix::zeros(3, 1);
        z.normalize_cols();
        assert_eq!(z.col(0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn from_col_major_length_mismatch_panics() {
        DenseMatrix::from_col_major(2, 2, vec![1.0; 3]);
    }

    #[test]
    fn trait_kernels_match_inherent() {
        let m = sample();
        let v = [0.5f32, -1.0];
        let beta = [1.0f32, 0.0, 2.0];
        // trait matvec_t (parallel default) vs inherent (serial)
        let mut a = vec![0.0f32; 3];
        let mut b = vec![0.0f32; 3];
        m.matvec_t(&v, &mut a);
        DesignMatrix::matvec_t(&m, &v, &mut b);
        assert_eq!(a, b);
        let mut ma = vec![0.0f32; 2];
        let mut mb = vec![0.0f32; 2];
        m.matvec(&beta, &mut ma);
        DesignMatrix::matvec(&m, &beta, &mut mb);
        assert_eq!(ma, mb);
        assert_eq!(m.col_norms(), DesignMatrix::col_norms(&m));
        let mut buf = vec![0.0f32; 2];
        m.col_to_dense(1, &mut buf);
        assert_eq!(&buf[..], m.col(1));
    }

    #[test]
    fn col_axpy_rows_matches_restricted_col_axpy() {
        let m = DenseMatrix::from_fn(7, 3, |i, j| (i as f32 + 1.0) * (j as f32 - 0.5));
        for j in 0..3 {
            let mut full = vec![0.25f32; 7];
            m.col_axpy(j, 1.5, &mut full);
            for (s, e) in [(0usize, 7usize), (0, 3), (2, 7), (3, 3), (1, 6)] {
                let mut part = vec![0.25f32; e - s];
                m.col_axpy_rows(j, 1.5, s, e, &mut part);
                for k in 0..e - s {
                    assert_eq!(part[k].to_bits(), full[s + k].to_bits(), "j={j} rows {s}..{e}");
                }
            }
        }
    }

    #[test]
    fn parallel_matvec_matches_serial_reference() {
        let m = DenseMatrix::from_fn(9, 6, |i, j| ((i * 5 + j * 3) % 7) as f32 - 3.0);
        let beta = [0.7f32, 0.0, -1.2, 0.0, 0.3, 2.0];
        let mut serial = vec![0.0f32; 9];
        m.matvec_serial(&beta, &mut serial);
        for workers in [1usize, 2, 3, 4, 8] {
            let mut par = vec![0.0f32; 9];
            m.matvec_with_workers(&beta, &mut par, workers);
            for i in 0..9 {
                assert_eq!(par[i].to_bits(), serial[i].to_bits(), "i={i} workers={workers}");
            }
        }
        let mut default = vec![0.0f32; 9];
        DesignMatrix::matvec(&m, &beta, &mut default);
        assert_eq!(default, serial);
    }

    #[test]
    fn select_rows_gathers() {
        let m = sample();
        let r = m.select_rows(&[1, 0]);
        assert_eq!(DesignMatrix::rows(&r), 2);
        assert_eq!(r.col(0), &[4.0, 1.0]);
        assert_eq!(r.col(2), &[6.0, 3.0]);
    }
}
