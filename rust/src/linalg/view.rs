//! Zero-copy screened-column views.
//!
//! After a TLFre/DPC screening pass, the solver only needs the surviving
//! columns of `X`. The seed implementation materialized a column-gathered
//! copy per path step — an O(N·|survivors|) memcpy at *every* λ.
//! [`ScreenedView`] replaces that with an index indirection: it borrows the
//! full backend matrix and remaps column `j` to `col_map[j]`, so building a
//! reduced problem is O(|survivors|) bookkeeping and the solver's kernels
//! run directly on the original storage.
//!
//! Because every per-column kernel delegates to the base backend on the
//! *same* underlying buffers, solves on a view are bitwise identical to
//! solves on the gathered copy (verified by `tests/backend_parity.rs`).

use super::dense::DenseMatrix;
use super::traits::DesignMatrix;

/// A column-subset view over any [`DesignMatrix`] backend.
#[derive(Debug, Clone)]
pub struct ScreenedView<'a, M: DesignMatrix> {
    base: &'a M,
    /// View column `j` is base column `col_map[j]`.
    col_map: Vec<usize>,
}

impl<'a, M: DesignMatrix> ScreenedView<'a, M> {
    /// Build from the base matrix and the surviving column indices
    /// (kept order). Panics on out-of-bounds indices.
    pub fn new(base: &'a M, col_map: Vec<usize>) -> ScreenedView<'a, M> {
        let p = base.cols();
        assert!(col_map.iter().all(|&j| j < p), "survivor index out of bounds");
        ScreenedView { base, col_map }
    }

    /// The survivor index map (view column → base column).
    #[inline]
    pub fn col_map(&self) -> &[usize] {
        &self.col_map
    }

    /// The borrowed base matrix.
    #[inline]
    pub fn base(&self) -> &'a M {
        self.base
    }

    /// Materialize the view as a dense gathered copy (the seed behaviour;
    /// kept for the equivalence tests and for callers that will iterate
    /// over one reduced problem many times on a cold cache).
    pub fn to_dense(&self) -> DenseMatrix {
        let n = self.base.rows();
        let mut out = DenseMatrix::zeros(n, self.col_map.len());
        for (j, &bj) in self.col_map.iter().enumerate() {
            self.base.col_to_dense(bj, out.col_mut(j));
        }
        out
    }
}

impl<M: DesignMatrix> DesignMatrix for ScreenedView<'_, M> {
    #[inline]
    fn rows(&self) -> usize {
        self.base.rows()
    }

    #[inline]
    fn cols(&self) -> usize {
        self.col_map.len()
    }

    #[inline]
    fn col_dot(&self, j: usize, v: &[f32]) -> f32 {
        self.base.col_dot(self.col_map[j], v)
    }

    #[inline]
    fn col_dot_f64(&self, j: usize, v: &[f32]) -> f64 {
        self.base.col_dot_f64(self.col_map[j], v)
    }

    #[inline]
    fn col_axpy(&self, j: usize, alpha: f32, out: &mut [f32]) {
        self.base.col_axpy(self.col_map[j], alpha, out);
    }

    #[inline]
    fn col_norm(&self, j: usize) -> f64 {
        self.base.col_norm(self.col_map[j])
    }

    #[inline]
    fn col_to_dense(&self, j: usize, out: &mut [f32]) {
        self.base.col_to_dense(self.col_map[j], out);
    }

    #[inline]
    fn col_axpy_rows(
        &self,
        j: usize,
        alpha: f32,
        row_start: usize,
        row_end: usize,
        out: &mut [f32],
    ) {
        self.base.col_axpy_rows(self.col_map[j], alpha, row_start, row_end, out);
    }

    #[inline]
    fn col_touched_rows(&self, j: usize, bits: &mut [u64]) {
        self.base.col_touched_rows(self.col_map[j], bits);
    }

    fn sweep_work(&self) -> usize {
        // Average per-column work of the base backend, over our columns.
        let base_cols = self.base.cols().max(1);
        (self.base.sweep_work() / base_cols).saturating_mul(self.col_map.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sparse::CscMatrix;
    use crate::util::Rng;

    #[test]
    fn view_matches_gathered_copy() {
        let mut rng = Rng::seed_from_u64(11);
        let d = DenseMatrix::from_fn(8, 12, |_, _| rng.gaussian() as f32);
        let keep = vec![0usize, 3, 4, 9, 11];
        let view = ScreenedView::new(&d, keep.clone());
        let gathered = d.select_cols(&keep);

        assert_eq!(view.cols(), 5);
        assert_eq!(view.rows(), 8);
        assert_eq!(view.to_dense(), gathered);

        let v: Vec<f32> = (0..8).map(|_| rng.gaussian() as f32).collect();
        let beta: Vec<f32> = (0..5).map(|_| rng.gaussian() as f32).collect();

        let mut a = vec![0.0f32; 5];
        let mut b = vec![0.0f32; 5];
        view.matvec_t(&v, &mut a);
        gathered.matvec_t(&v, &mut b);
        assert_eq!(a, b, "matvec_t must be bitwise identical");

        let mut ma = vec![0.0f32; 8];
        let mut mb = vec![0.0f32; 8];
        view.matvec(&beta, &mut ma);
        gathered.matvec(&beta, &mut mb);
        assert_eq!(ma, mb, "matvec must be bitwise identical");

        for j in 0..5 {
            assert_eq!(view.col_norm(j), gathered.col_norm(j));
        }
    }

    #[test]
    fn view_over_csc() {
        let mut rng = Rng::seed_from_u64(12);
        let d = DenseMatrix::from_fn(6, 10, |_, _| {
            if rng.below(2) == 0 {
                rng.gaussian() as f32
            } else {
                0.0
            }
        });
        let s = CscMatrix::from_dense(&d);
        let keep = vec![1usize, 2, 7];
        let vd = ScreenedView::new(&d, keep.clone());
        let vs = ScreenedView::new(&s, keep);
        let v: Vec<f32> = (0..6).map(|_| rng.gaussian() as f32).collect();
        let mut a = vec![0.0f32; 3];
        let mut b = vec![0.0f32; 3];
        vd.matvec_t(&v, &mut a);
        vs.matvec_t(&v, &mut b);
        for j in 0..3 {
            assert!((a[j] - b[j]).abs() < 1e-5);
        }
    }

    #[test]
    fn row_kernels_delegate_through_col_map() {
        let mut rng = Rng::seed_from_u64(13);
        let d = DenseMatrix::from_fn(5, 8, |_, _| {
            if rng.below(2) == 0 {
                rng.gaussian() as f32
            } else {
                0.0
            }
        });
        let s = CscMatrix::from_dense(&d);
        let keep = vec![6usize, 1, 4];
        let v = ScreenedView::new(&s, keep.clone());
        for (j, &bj) in keep.iter().enumerate() {
            let mut a = vec![0.1f32; 3];
            let mut b = vec![0.1f32; 3];
            v.col_axpy_rows(j, 0.75, 1, 4, &mut a);
            s.col_axpy_rows(bj, 0.75, 1, 4, &mut b);
            assert_eq!(a, b, "col_axpy_rows view col {j}");
            let mut wa = vec![0u64; 1];
            let mut wb = vec![0u64; 1];
            v.col_touched_rows(j, &mut wa);
            s.col_touched_rows(bj, &mut wb);
            assert_eq!(wa, wb, "col_touched_rows view col {j}");
        }
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_survivor_panics() {
        let d = DenseMatrix::zeros(2, 3);
        ScreenedView::new(&d, vec![0, 3]);
    }
}
