//! Memory-mapped dense backend: the out-of-core workhorse.
//!
//! [`MmapDenseMatrix`] exposes the col-major f32 X payload of a `TLFREDS1`
//! file (see `crate::data::io`) through the [`DesignMatrix`] trait without
//! ever loading it: on unix the whole file is `mmap`ed (raw `mmap`/`munmap`
//! through `extern "C"` declarations — the zero-dependency rule rules out a
//! memmap crate) and each column is a plain `&[f32]` into the mapping, so
//! every kernel is the *same* `ops::` call over the same values as
//! [`super::DenseMatrix`] — results are bitwise identical, and the OS page
//! cache decides what is resident. Elsewhere a portable positioned-read
//! fallback stages one column (or row range) at a time through a
//! thread-local buffer: correct and bounded-memory, but disk-bound —
//! the mapped path is the one the benches measure.
//!
//! The fallback's positioned reads go through [`read_exact_at`], which
//! retries `EINTR` and loops on short reads (both are legitimate kernel
//! behaviour, not corruption) and surfaces only *hard* failures — a true
//! I/O error or EOF (file truncated underneath us) — as typed
//! `io::Error`s. See "Failure modes & recovery" in `linalg/README.md`.
//!
//! ## Safety / aliasing notes
//!
//! * The mapping is `PROT_READ` + `MAP_PRIVATE`: nothing in this process
//!   writes through it, so handing out `&[f32]` slices is sound as long as
//!   the file is not truncated concurrently by another process (the usual
//!   mmap caveat; generators write to a tmp path and never rewrite files
//!   they serve).
//! * The X payload offset is 4-byte-aligned by construction (the writer
//!   pads the header — validated here), and `mmap` bases are page-aligned,
//!   so the `&[f32]` reinterpretation is well-aligned.
//! * The struct is `Send`/`Sync`: the mapping is immutable shared memory
//!   for its whole lifetime, released by `munmap` on drop.

use super::ops;
use super::traits::DesignMatrix;
use crate::bail;
use crate::error::{Context, Result};
use std::path::Path;

#[cfg(unix)]
mod sys {
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

/// Read-only mapping of a whole dataset file (unix).
#[cfg(unix)]
struct Store {
    base: *const u8,
    map_len: usize,
    x_offset: usize,
}

// SAFETY: the mapping is PROT_READ and private; the pointed-to memory is
// immutable shared state for the lifetime of the struct, so concurrent
// reads from any thread are fine and ownership may move between threads.
#[cfg(unix)]
unsafe impl Send for Store {}
#[cfg(unix)]
unsafe impl Sync for Store {}

#[cfg(unix)]
impl Drop for Store {
    fn drop(&mut self) {
        // SAFETY: base/map_len are exactly what mmap returned; unmapping
        // once on drop is the release of that acquisition.
        unsafe {
            sys::munmap(self.base as *mut std::ffi::c_void, self.map_len);
        }
    }
}

/// Positioned-read fallback (non-unix): one shared file handle, columns
/// staged through a thread-local buffer.
#[cfg(not(unix))]
struct Store {
    file: std::sync::Mutex<std::fs::File>,
    x_offset: u64,
}

#[cfg(not(unix))]
thread_local! {
    static COL_BUF: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// Fill `bytes` from `file` starting at byte `offset`, retrying
/// interrupted syscalls and looping on short reads — `read(2)` may
/// legitimately return fewer bytes than asked (signals, readahead
/// boundaries) and `EINTR` is transient; neither means the file is bad.
/// Hard failures come back as the underlying typed `io::Error`; reaching
/// EOF early (file truncated underneath us) is `UnexpectedEof`.
///
/// Compiled on unix too (test builds and fault-injection builds) so the
/// retry/error discipline is unit-testable on the CI hosts even though
/// the hot path there is the mapping.
#[cfg(any(not(unix), test))]
fn read_exact_at(file: &mut std::fs::File, offset: u64, bytes: &mut [u8]) -> std::io::Result<()> {
    use std::io::{Read, Seek, SeekFrom};
    file.seek(SeekFrom::Start(offset))?;
    let mut filled = 0usize;
    while filled < bytes.len() {
        // Fault-injection probes (constant false in normal builds) model
        // the three kernel behaviours this loop must survive or surface.
        let res = if crate::util::fault::take_eintr() {
            Err(std::io::Error::new(
                std::io::ErrorKind::Interrupted,
                "fault-inject: EINTR",
            ))
        } else if crate::util::fault::take_read_error() {
            Err(std::io::Error::other("fault-inject: hard read error"))
        } else {
            let want = if crate::util::fault::take_short_read() {
                ((bytes.len() - filled) / 2).max(1)
            } else {
                bytes.len() - filled
            };
            file.read(&mut bytes[filled..filled + want])
        };
        match res {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "unexpected end of file (dataset truncated?)",
                ))
            }
            Ok(k) => filled += k,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Dense col-major design matrix backed by a `TLFREDS1` file on disk.
///
/// Construct via [`MmapDenseMatrix::from_file`] (raw offsets) or the
/// header-aware `crate::data::io::open_mmap`.
pub struct MmapDenseMatrix {
    rows: usize,
    cols: usize,
    store: Store,
}

impl MmapDenseMatrix {
    /// Map `rows × cols` f32 columns starting at byte `x_offset` of `path`.
    ///
    /// Validates the alignment contract (`x_offset % 4 == 0`) and that the
    /// file actually holds the payload before mapping, so a truncated file
    /// fails here instead of faulting mid-sweep.
    pub fn from_file(path: &Path, x_offset: u64, rows: usize, cols: usize) -> Result<MmapDenseMatrix> {
        if rows == 0 || cols == 0 {
            bail!("mmap backend: empty dimensions {rows}×{cols}");
        }
        if x_offset % 4 != 0 {
            bail!("mmap backend: X offset {x_offset} is not 4-byte aligned");
        }
        let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let file_len = f.metadata()?.len();
        let need = x_offset + 4 * (rows as u64) * (cols as u64);
        if file_len < need {
            bail!(
                "mmap backend: {path:?} holds {file_len} bytes but the X payload \
                 needs {need} ({rows}×{cols} f32 at offset {x_offset})"
            );
        }
        let store = Self::open_store(&f, file_len, x_offset, path)?;
        Ok(MmapDenseMatrix { rows, cols, store })
    }

    #[cfg(unix)]
    fn open_store(f: &std::fs::File, file_len: u64, x_offset: u64, path: &Path) -> Result<Store> {
        use std::os::unix::io::AsRawFd;
        let map_len = file_len as usize;
        // SAFETY: fd is a live handle to a regular file of length file_len;
        // we map it read-only/private from offset 0 (page-aligned by
        // definition). The kernel keeps the mapping valid after the fd is
        // closed.
        let base = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                map_len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                f.as_raw_fd(),
                0,
            )
        };
        if base as isize == -1 {
            bail!("mmap {path:?} failed: {}", std::io::Error::last_os_error());
        }
        Ok(Store { base: base as *const u8, map_len, x_offset: x_offset as usize })
    }

    #[cfg(not(unix))]
    fn open_store(f: &std::fs::File, _file_len: u64, x_offset: u64, path: &Path) -> Result<Store> {
        // Keep an independent handle so the caller's `f` can drop.
        let file = std::fs::File::open(path).with_context(|| format!("reopen {path:?}"))?;
        let _ = f;
        Ok(Store { file: std::sync::Mutex::new(file), x_offset })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Bytes of X payload served from disk.
    pub fn x_payload_bytes(&self) -> u64 {
        4 * self.rows as u64 * self.cols as u64
    }

    /// `"mmap"` when the payload is memory-mapped, `"pread"` on the
    /// positioned-read fallback — benches record which path they measured.
    pub fn backend_kind() -> &'static str {
        if cfg!(unix) {
            "mmap"
        } else {
            "pread"
        }
    }

    /// Run `f` on column `j` as a contiguous `&[f32]`.
    ///
    /// Mapped path: a zero-copy slice into the mapping (reads may fault
    /// pages in). Fallback: the column is read into a thread-local buffer.
    #[cfg(unix)]
    #[inline]
    fn with_col<R>(&self, j: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        f(self.mapped_col(j))
    }

    /// [`Self::with_col`] restricted to rows `[rs, re)`.
    #[cfg(unix)]
    #[inline]
    fn with_col_rows<R>(&self, j: usize, rs: usize, re: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        f(&self.mapped_col(j)[rs..re])
    }

    #[cfg(unix)]
    #[inline]
    fn mapped_col(&self, j: usize) -> &[f32] {
        debug_assert!(j < self.cols);
        // SAFETY: from_file validated that the mapping covers
        // x_offset + 4·rows·cols bytes and that x_offset is 4-aligned;
        // j < cols keeps the slice inside the payload. The memory is
        // immutable for self's lifetime (PROT_READ).
        unsafe {
            let ptr = self.store.base.add(self.store.x_offset + 4 * j * self.rows);
            std::slice::from_raw_parts(ptr as *const f32, self.rows)
        }
    }

    #[cfg(not(unix))]
    fn with_col<R>(&self, j: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        self.with_col_rows(j, 0, self.rows, f)
    }

    #[cfg(not(unix))]
    fn with_col_rows<R>(&self, j: usize, rs: usize, re: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        debug_assert!(j < self.cols && rs <= re && re <= self.rows);
        COL_BUF.with(|cell| {
            let mut buf = cell.borrow_mut();
            buf.resize(re - rs, 0.0);
            {
                let mut file = self.store.file.lock().expect("mmap fallback: poisoned lock");
                let off = self.store.x_offset + 4 * (j as u64 * self.rows as u64 + rs as u64);
                // SAFETY: `buf` was just resized to `re - rs` initialized
                // f32s, so the byte view covers exactly its allocation; u8
                // has no alignment requirement and the exclusive borrow of
                // `buf` pins it while `bytes` lives.
                let bytes = unsafe {
                    std::slice::from_raw_parts_mut(buf.as_mut_ptr() as *mut u8, buf.len() * 4)
                };
                // EINTR and short reads are retried inside read_exact_at;
                // only hard errors reach here, and the DesignMatrix
                // kernels are infallible — fail loudly with full context
                // rather than hand the solver a half-filled buffer.
                if let Err(e) = read_exact_at(&mut file, off, bytes) {
                    panic!(
                        "mmap fallback: positioned read of column {j} rows {rs}..{re} failed: {e}"
                    );
                }
            }
            f(&buf)
        })
    }
}

impl std::fmt::Debug for MmapDenseMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MmapDenseMatrix")
            .field("rows", &self.rows)
            .field("cols", &self.cols)
            .field("kind", &Self::backend_kind())
            .finish()
    }
}

impl DesignMatrix for MmapDenseMatrix {
    #[inline]
    fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    fn col_dot(&self, j: usize, v: &[f32]) -> f32 {
        self.with_col(j, |c| ops::dot_f32(c, v))
    }

    #[inline]
    fn col_dot_f64(&self, j: usize, v: &[f32]) -> f64 {
        self.with_col(j, |c| ops::dot(c, v))
    }

    #[inline]
    fn col_axpy(&self, j: usize, alpha: f32, out: &mut [f32]) {
        self.with_col(j, |c| ops::axpy(alpha, c, out));
    }

    #[inline]
    fn col_norm(&self, j: usize) -> f64 {
        self.with_col(j, ops::nrm2)
    }

    fn col_to_dense(&self, j: usize, out: &mut [f32]) {
        self.with_col(j, |c| out.copy_from_slice(c));
    }

    #[inline]
    fn col_axpy_rows(&self, j: usize, alpha: f32, rs: usize, re: usize, out: &mut [f32]) {
        self.with_col_rows(j, rs, re, |c| ops::axpy(alpha, c, out));
    }

    // col_touched_rows: the trait default (all rows) is exact — the payload
    // is dense storage, so col_axpy writes every row.
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::io;
    use crate::data::synthetic::{generate_synthetic, SyntheticSpec};

    fn tmp(file: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("tlfre_mmap_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(file)
    }

    #[test]
    #[cfg_attr(miri, ignore)] // mmap FFI (unsupported under Miri)
    fn kernels_bitwise_match_dense() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(16, 40, 8), 11);
        let path = tmp("kernels.bin");
        io::save(&ds, &path).unwrap();
        let m = io::open_mmap(&path).unwrap();
        assert_eq!(m.x.rows(), ds.n());
        assert_eq!(m.x.cols(), ds.p());
        assert_eq!(m.y, ds.y);
        assert_eq!(m.groups, ds.groups);

        let v: Vec<f32> = (0..ds.n()).map(|i| (i as f32 * 0.3).sin()).collect();
        for j in 0..ds.p() {
            assert_eq!(m.x.col_dot(j, &v).to_bits(), ds.x.col_dot(j, &v).to_bits());
            assert_eq!(
                m.x.col_dot_f64(j, &v).to_bits(),
                ds.x.col_dot_f64(j, &v).to_bits()
            );
            assert_eq!(m.x.col_norm(j).to_bits(), ds.x.col_norm(j).to_bits());
            let mut a = v.clone();
            let mut b = v.clone();
            m.x.col_axpy(j, -0.7, &mut a);
            ds.x.col_axpy(j, -0.7, &mut b);
            assert_eq!(a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                       b.iter().map(|x| x.to_bits()).collect::<Vec<_>>());
            let mut pa = vec![0.1f32; 7];
            let mut pb = vec![0.1f32; 7];
            m.x.col_axpy_rows(j, 1.3, 5, 12, &mut pa);
            ds.x.col_axpy_rows(j, 1.3, 5, 12, &mut pb);
            assert_eq!(pa.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                       pb.iter().map(|x| x.to_bits()).collect::<Vec<_>>());
        }
        let mut col = vec![0.0f32; ds.n()];
        m.x.col_to_dense(3, &mut col);
        assert_eq!(&col[..], ds.x.col(3));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // mmap FFI (unsupported under Miri)
    fn matvec_with_workers_bitwise_matches_serial() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(32, 60, 12), 12);
        let path = tmp("workers.bin");
        io::save(&ds, &path).unwrap();
        let m = io::open_mmap(&path).unwrap();
        let beta: Vec<f32> =
            (0..ds.p()).map(|j| if j % 3 == 0 { (j as f32 * 0.1).cos() } else { 0.0 }).collect();
        let mut serial = vec![0.0f32; ds.n()];
        ds.x.matvec_serial(&beta, &mut serial);
        for workers in [1usize, 2, 3, 4, 8] {
            let mut par = vec![0.0f32; ds.n()];
            m.x.matvec_with_workers(&beta, &mut par, workers);
            for i in 0..ds.n() {
                assert_eq!(par[i].to_bits(), serial[i].to_bits(), "i={i} workers={workers}");
            }
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    #[cfg_attr(miri, ignore)] // mmap FFI (unsupported under Miri)
    fn from_file_rejects_unaligned_offset_and_short_file() {
        let path = tmp("bad.bin");
        std::fs::write(&path, vec![0u8; 64]).unwrap();
        assert!(MmapDenseMatrix::from_file(&path, 2, 2, 2).is_err());
        assert!(MmapDenseMatrix::from_file(&path, 0, 100, 100).is_err());
        assert!(MmapDenseMatrix::from_file(&path, 0, 4, 4).is_ok());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn read_exact_at_reads_and_reports_truncation() {
        let path = tmp("pread.bin");
        let payload: Vec<u8> = (0..64u8).collect();
        std::fs::write(&path, &payload).unwrap();
        let mut f = std::fs::File::open(&path).unwrap();
        let mut buf = [0u8; 16];
        read_exact_at(&mut f, 8, &mut buf).unwrap();
        assert_eq!(&buf[..], &payload[8..24]);
        // Reading past EOF is a typed UnexpectedEof, not garbage.
        let mut big = [0u8; 32];
        let err = read_exact_at(&mut f, 48, &mut big).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
        std::fs::remove_file(&path).unwrap();
    }

    // Injected-fault coverage of the retry loop. Serialized on a private
    // mutex: the fault counters are process-global.
    #[cfg(feature = "fault-inject")]
    mod injected {
        use super::*;
        use crate::util::fault;

        static FAULT_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

        fn fixture(name: &str) -> (std::path::PathBuf, Vec<u8>) {
            let path = tmp(name);
            let payload: Vec<u8> = (0..128u8).map(|b| b.wrapping_mul(7)).collect();
            std::fs::write(&path, &payload).unwrap();
            (path, payload)
        }

        #[test]
        fn short_reads_and_eintr_are_retried_to_completion() {
            let _g = FAULT_LOCK.lock().unwrap();
            let (path, payload) = fixture("inj_retry.bin");
            let mut f = std::fs::File::open(&path).unwrap();
            let mut buf = [0u8; 64];
            fault::reset();
            fault::arm_short_reads(3);
            fault::arm_eintrs(2);
            read_exact_at(&mut f, 16, &mut buf).unwrap();
            assert_eq!(&buf[..], &payload[16..80], "recovered read must be exact");
            fault::reset();
            std::fs::remove_file(&path).unwrap();
        }

        #[test]
        fn hard_read_error_is_typed_not_garbage() {
            let _g = FAULT_LOCK.lock().unwrap();
            let (path, _) = fixture("inj_hard.bin");
            let mut f = std::fs::File::open(&path).unwrap();
            let mut buf = [0u8; 32];
            fault::reset();
            // Survive one short read, then die on the second syscall.
            fault::arm_short_reads(1);
            fault::arm_read_error(2);
            let err = read_exact_at(&mut f, 0, &mut buf).unwrap_err();
            assert!(err.to_string().contains("hard read error"), "{err}");
            fault::reset();
            // The same handle still works once the fault clears.
            read_exact_at(&mut f, 0, &mut buf).unwrap();
            std::fs::remove_file(&path).unwrap();
        }
    }
}
