//! Nonnegative Lasso (Section 5 of the paper).
//!
//! ```text
//! min_{β ≥ 0} ½‖y − Xβ‖² + λ‖β‖₁                    (80)
//! ```
//!
//! The Fenchel dual (82) is `inf_θ ½‖y/λ − θ‖² − ½‖y‖²` over the polytope
//! `{θ : ⟨x_i, θ⟩ ≤ 1}`, with KKT `λθ* = y − Xβ*`. The solver is projected
//! FISTA with the closed-form prox `max(0, v − tλ)` and a duality-gap stop
//! using the radial feasibility scaling of `θ̂ = (y − Xβ)/λ`. Both per-
//! iteration sweeps run on the worker pool: `Xᵀv` column-chunked, the
//! fused `Xz − y` forward pass row-blocked — each bitwise identical to its
//! serial counterpart at every `TLFRE_THREADS`.

use crate::linalg::ops;
use crate::linalg::power::spectral_norm;
use crate::linalg::{DenseMatrix, DesignMatrix, ScreenedView};
use crate::prox::nonneg_l1_prox;
use crate::screening::gap_safe::{EvictPlan, GapSafeDynamicNonneg};
use crate::util::{retain_by_mask, Rng};
use std::cell::RefCell;

/// A borrowed nonnegative-Lasso problem instance, generic over the
/// [`DesignMatrix`] backend (defaults to [`DenseMatrix`]).
pub struct NonnegProblem<'a, M: DesignMatrix = DenseMatrix> {
    pub x: &'a M,
    pub y: &'a [f32],
}

impl<'a, M: DesignMatrix> NonnegProblem<'a, M> {
    pub fn new(x: &'a M, y: &'a [f32]) -> Self {
        assert_eq!(x.rows(), y.len());
        NonnegProblem { x, y }
    }
}

// Manual Clone/Copy/Debug: the derives would demand bounds on `M` even
// though only references are stored.
impl<M: DesignMatrix> Clone for NonnegProblem<'_, M> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<M: DesignMatrix> Copy for NonnegProblem<'_, M> {}

impl<M: DesignMatrix> std::fmt::Debug for NonnegProblem<'_, M> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("NonnegProblem")
            .field("n_samples", &self.x.rows())
            .field("n_features", &self.x.cols())
            .finish()
    }
}

/// Options (same semantics as the SGL FISTA options).
#[derive(Debug, Clone)]
pub struct NonnegOptions<'a> {
    pub max_iter: usize,
    pub tol: f64,
    pub check_every: usize,
    pub lipschitz: Option<f64>,
    /// In-solver dynamic GAP-safe screening (Theorem 22 geometry; see
    /// [`crate::screening::gap_safe::GapSafeDynamicNonneg`]): checked at
    /// every gap check, certified-zero features drop out of the live
    /// problem and the solve continues on a survivor view. The result is
    /// reported in the caller's index space. `None` (default) is the
    /// plain solve.
    pub dynamic_screen: Option<&'a RefCell<GapSafeDynamicNonneg>>,
    /// Wall-clock deadline for graceful degradation (same contract as
    /// [`crate::sgl::fista::FistaOptions::deadline`]): checked at gap-check
    /// cadence after the gap is measured; once past it the solve returns
    /// best-so-far with `converged = false` and `budget_exhausted = true`.
    /// `None` (default) never times out.
    pub deadline: Option<std::time::Instant>,
}

impl Default for NonnegOptions<'_> {
    fn default() -> Self {
        NonnegOptions {
            max_iter: 20_000,
            tol: 1e-6,
            check_every: 10,
            lipschitz: None,
            dynamic_screen: None,
            deadline: None,
        }
    }
}

/// Solver output.
#[derive(Debug, Clone)]
pub struct NonnegResult {
    pub beta: Vec<f32>,
    pub iters: usize,
    pub gap: f64,
    pub objective: f64,
    pub converged: bool,
    /// True when the solve stopped on an exhausted budget (iteration cap
    /// or wall-clock [`NonnegOptions::deadline`]) rather than meeting the
    /// gap tolerance; `beta`/`gap` are the best completed iterate and its
    /// last measured (certified) suboptimality.
    pub budget_exhausted: bool,
}

/// Primal objective ½‖y−Xβ‖² + λ‖β‖₁ (β assumed ≥ 0).
pub fn objective<M: DesignMatrix>(_prob: &NonnegProblem<'_, M>, lambda: f64, beta: &[f32], r: &[f32]) -> f64 {
    0.5 * ops::nrm2_sq(r) + lambda * ops::nrm1(beta)
}

/// The solver's step bound `L = (1.02·σmax(X))²` — 2% inflation because
/// power iteration approaches σmax from below. The single source of truth
/// for the seed/tolerance recipe, shared by [`solve_nonneg`]'s fallback and
/// the DPC path runners' once-per-path caches (which rely on producing the
/// *same* constant the solver would compute for the full problem).
pub fn nonneg_lipschitz<M: DesignMatrix>(x: &M) -> f64 {
    let mut rng = Rng::seed_from_u64(0x22_57FA);
    let s = spectral_norm(x, 1e-6, 500, &mut rng).sigma * 1.02;
    (s * s).max(f64::MIN_POSITIVE)
}

/// λmax = max_i ⟨x_i, y⟩ (Theorem 20) and its argmax column.
pub fn lambda_max<M: DesignMatrix>(prob: &NonnegProblem<'_, M>) -> (f64, usize) {
    let mut best = f64::NEG_INFINITY;
    let mut arg = 0;
    for j in 0..prob.x.cols() {
        let v = prob.x.col_dot_f64(j, prob.y);
        if v > best {
            best = v;
            arg = j;
        }
    }
    (best, arg)
}

/// Duality gap at β. `r` is the residual `y − Xβ`, `c = Xᵀr`.
///
/// The dual candidate is `θ = s·r/λ` with the largest `s ∈ [0,1]` making it
/// feasible for (82): `s = min(1, λ / max_i c_i)` (only *positive*
/// correlations constrain — the feasible set is one-sided).
/// Gap = P(β) − D(θ) with `D(θ) = ½‖y‖² − ½‖y − λθ‖²`.
pub fn duality_gap<M: DesignMatrix>(
    prob: &NonnegProblem<'_, M>,
    lambda: f64,
    beta: &[f32],
    r: &[f32],
    c: &[f32],
) -> (f64, f64) {
    let p = objective(prob, lambda, beta, r);
    let cmax = c.iter().fold(f64::NEG_INFINITY, |m, &v| m.max(v as f64));
    let s = if cmax <= lambda { 1.0 } else { lambda / cmax };
    // λθ = s·r  →  D = ½‖y‖² − ½‖y − s·r‖².
    let mut ynsq = 0.0f64;
    let mut dn = 0.0f64;
    for i in 0..prob.y.len() {
        let yi = prob.y[i] as f64;
        ynsq += yi * yi;
        let d = yi - s * r[i] as f64;
        dn += d * d;
    }
    let dual = 0.5 * ynsq - 0.5 * dn;
    ((p - dual).max(0.0), s)
}

/// One projected-FISTA iteration — gradient, projected prox, momentum.
/// The single arithmetic home shared by the static and dynamic-screening
/// loops (same construction as `sgl::fista::fista_iteration`).
#[allow(clippy::too_many_arguments)]
fn nonneg_iteration<M: DesignMatrix>(
    x: &M,
    y: &[f32],
    lambda: f64,
    step: f64,
    t_k: &mut f64,
    beta: &mut Vec<f32>,
    beta_prev: &mut Vec<f32>,
    z: &mut [f32],
    xz: &mut [f32],
    grad: &mut [f32],
    w: &mut [f32],
) {
    // ∇ = Xᵀ(Xz − y), residual fused into the matvec.
    x.residual_matvec(z, y, xz);
    x.matvec_t(xz, grad);
    ops::add_scaled(z, -(step as f32), grad, w);
    std::mem::swap(beta, beta_prev);
    nonneg_l1_prox(w, step * lambda, beta);

    let t_next = 0.5 * (1.0 + (1.0 + 4.0 * *t_k * *t_k).sqrt());
    let omega = ((*t_k - 1.0) / t_next) as f32;
    for j in 0..z.len() {
        z[j] = beta[j] + omega * (beta[j] - beta_prev[j]);
    }
    *t_k = t_next;
}

/// Solve nonnegative Lasso by projected FISTA.
pub fn solve_nonneg<M: DesignMatrix>(
    prob: &NonnegProblem<'_, M>,
    lambda: f64,
    warm_start: Option<&[f32]>,
    opts: &NonnegOptions<'_>,
) -> NonnegResult {
    if let Some(state) = opts.dynamic_screen {
        return solve_nonneg_dynamic(prob, lambda, warm_start, opts, state);
    }
    let n = prob.x.rows();
    let p = prob.x.cols();
    let l = opts.lipschitz.unwrap_or_else(|| nonneg_lipschitz(prob.x));
    let step = 1.0 / l;
    let scale_ref = (0.5 * ops::nrm2_sq(prob.y)).max(1e-10);

    let mut beta: Vec<f32> = warm_start.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; p]);
    let mut beta_prev = beta.clone();
    let mut z = beta.clone();
    let mut t_k = 1.0f64;

    let mut xz = vec![0.0f32; n];
    let mut grad = vec![0.0f32; p];
    let mut w = vec![0.0f32; p];
    let mut r = vec![0.0f32; n];
    let mut c = vec![0.0f32; p];

    let mut gap = f64::INFINITY;
    let mut converged = false;
    let mut deadline_hit = false;
    let mut iters = 0;
    let mut last_obj = f64::INFINITY;
    // Objective from a gap check at the current β, reused on exit (see
    // `sgl::fista::solve_fista` — same skip of the duplicated recompute).
    let mut checked_obj: Option<f64> = None;

    for k in 0..opts.max_iter {
        iters = k + 1;
        checked_obj = None;
        nonneg_iteration(
            prob.x,
            prob.y,
            lambda,
            step,
            &mut t_k,
            &mut beta,
            &mut beta_prev,
            &mut z,
            &mut xz,
            &mut grad,
            &mut w,
        );

        if (k + 1) % opts.check_every == 0 || k + 1 == opts.max_iter {
            prob.x.residual(&beta, prob.y, &mut r);
            crate::util::fault::maybe_poison_residual(&mut r);
            prob.x.matvec_t(&r, &mut c);
            let obj = objective(prob, lambda, &beta, &r);
            if obj > last_obj {
                t_k = 1.0;
                z.copy_from_slice(&beta);
            }
            last_obj = obj;
            checked_obj = Some(obj);
            let (g, _) = duality_gap(prob, lambda, &beta, &r, &c);
            gap = g;
            if gap <= opts.tol * scale_ref {
                converged = true;
                break;
            }
            if !gap.is_finite() {
                // A non-finite gap can never satisfy the stopping rule —
                // stop and surface `converged = false`.
                break;
            }
            if crate::sgl::fista::deadline_passed(opts.deadline) {
                deadline_hit = true;
                break;
            }
        }
    }

    // Both loop exits (converged break, forced check at max_iter) leave
    // `checked_obj` fresh at the final β; only max_iter == 0 recomputes.
    let objective = match checked_obj {
        Some(o) => o,
        None => {
            prob.x.residual(&beta, prob.y, &mut r);
            objective(prob, lambda, &beta, &r)
        }
    };
    let budget_exhausted = deadline_hit || (!converged && iters == opts.max_iter);
    NonnegResult { beta, iters, gap, objective, converged, budget_exhausted }
}

/// Mutable state of a dynamic-screening nonneg solve, shared across
/// epochs.
struct NonnegDynCore {
    beta: Vec<f32>,
    beta_prev: Vec<f32>,
    z: Vec<f32>,
    t_k: f64,
    xz: Vec<f32>,
    r: Vec<f32>,
    grad: Vec<f32>,
    w: Vec<f32>,
    c: Vec<f32>,
    last_obj: f64,
    gap: f64,
    converged: bool,
    deadline_hit: bool,
    iters: usize,
    objective: Option<f64>,
}

/// Run dynamic projected-FISTA iterations on the current problem until
/// convergence or the iteration cap (→ `None`) or a GAP eviction (→ the
/// plan). Per-iteration arithmetic is [`nonneg_iteration`], identical to
/// the static loop. Instantiated at exactly two matrix types per caller:
/// `M` before the first eviction, `ScreenedView<M>` after.
#[allow(clippy::too_many_arguments)]
fn nonneg_dynamic_epoch<M: DesignMatrix>(
    x: &M,
    y: &[f32],
    lambda: f64,
    opts: &NonnegOptions<'_>,
    step: f64,
    scale_ref: f64,
    state: &RefCell<GapSafeDynamicNonneg>,
    core: &mut NonnegDynCore,
) -> Option<EvictPlan> {
    let p = x.cols();
    core.grad.resize(p, 0.0);
    core.w.resize(p, 0.0);
    core.c.resize(p, 0.0);
    let vprob = NonnegProblem::new(x, y);
    while core.iters < opts.max_iter {
        core.iters += 1;
        nonneg_iteration(
            x,
            y,
            lambda,
            step,
            &mut core.t_k,
            &mut core.beta,
            &mut core.beta_prev,
            &mut core.z,
            &mut core.xz,
            &mut core.grad,
            &mut core.w,
        );
        if core.iters % opts.check_every == 0 || core.iters == opts.max_iter {
            x.residual(&core.beta, y, &mut core.r);
            crate::util::fault::maybe_poison_residual(&mut core.r);
            x.matvec_t(&core.r, &mut core.c);
            let obj = objective(&vprob, lambda, &core.beta, &core.r);
            if obj > core.last_obj {
                core.t_k = 1.0;
                core.z.copy_from_slice(&core.beta);
            }
            core.last_obj = obj;
            core.objective = Some(obj);
            let (g, s_feas) = duality_gap(&vprob, lambda, &core.beta, &core.r, &core.c);
            core.gap = g;
            if g <= opts.tol * scale_ref {
                core.converged = true;
                return None;
            }
            if !g.is_finite() {
                // Same recovery as the static loop: stop on a poisoned
                // evaluation, report `converged = false`.
                return None;
            }
            if crate::sgl::fista::deadline_passed(opts.deadline) {
                core.deadline_hit = true;
                return None;
            }
            if core.iters < opts.max_iter {
                // Gap floored at the f32 evaluation noise scale (see
                // `gap_with_noise_floor`).
                let floored =
                    crate::screening::gap_safe::gap_with_noise_floor(g, scale_ref);
                if let Some(plan) = state.borrow_mut().check(lambda, &core.c, floored, s_feas) {
                    return Some(plan);
                }
            }
        }
    }
    None
}

/// The dynamic-screening nonneg solve: phase 1 iterates on the caller's
/// matrix directly (no view indirection until an eviction fires), then
/// continues on survivor views (see `sgl::fista::solve_fista_dynamic`
/// for the shared design rationale).
fn solve_nonneg_dynamic<M: DesignMatrix>(
    prob: &NonnegProblem<'_, M>,
    lambda: f64,
    warm_start: Option<&[f32]>,
    opts: &NonnegOptions<'_>,
    state: &RefCell<GapSafeDynamicNonneg>,
) -> NonnegResult {
    let n = prob.x.rows();
    let p0 = prob.x.cols();
    // The caller's (or full-problem) bound stays valid for every survivor
    // view: subset operator norms only shrink.
    let l = opts.lipschitz.unwrap_or_else(|| nonneg_lipschitz(prob.x));
    let step = 1.0 / l;
    let scale_ref = (0.5 * ops::nrm2_sq(prob.y)).max(1e-10);

    let beta0: Vec<f32> = warm_start.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; p0]);
    let mut core = NonnegDynCore {
        beta_prev: beta0.clone(),
        z: beta0.clone(),
        beta: beta0,
        t_k: 1.0,
        xz: vec![0.0; n],
        r: vec![0.0; n],
        grad: Vec::new(),
        w: Vec::new(),
        c: Vec::new(),
        last_obj: f64::INFINITY,
        gap: f64::INFINITY,
        converged: false,
        deadline_hit: false,
        iters: 0,
        objective: None,
    };
    let mut cols: Vec<usize> = (0..p0).collect();

    // Phase 1: the caller's problem, zero overhead vs the static loop.
    let mut pending =
        nonneg_dynamic_epoch(prob.x, prob.y, lambda, opts, step, scale_ref, state, &mut core);
    // Phase 2: compact and continue on survivor views until done.
    while let Some(plan) = pending.take() {
        retain_by_mask(&mut core.beta, &plan.feature_kept);
        retain_by_mask(&mut core.beta_prev, &plan.feature_kept);
        retain_by_mask(&mut core.z, &plan.feature_kept);
        retain_by_mask(&mut cols, &plan.feature_kept);
        if cols.is_empty() {
            core.gap = 0.0;
            core.converged = true;
            core.objective = Some(0.5 * ops::nrm2_sq(prob.y));
            break;
        }
        let view = ScreenedView::new(prob.x, cols.clone());
        pending =
            nonneg_dynamic_epoch(&view, prob.y, lambda, opts, step, scale_ref, state, &mut core);
    }

    let mut full = vec![0.0f32; p0];
    for (k, &j) in cols.iter().enumerate() {
        full[j] = core.beta[k];
    }
    let objective = core.objective.unwrap_or_else(|| {
        prob.x.residual(&full, prob.y, &mut core.r);
        self::objective(prob, lambda, &full, &core.r)
    });
    NonnegResult {
        beta: full,
        iters: core.iters,
        gap: core.gap,
        objective,
        converged: core.converged,
        budget_exhausted: core.deadline_hit
            || (!core.converged && core.iters == opts.max_iter),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn problem(seed: u64, n: usize, p: usize) -> (DenseMatrix, Vec<f32>) {
        let mut rng = Rng::seed_from_u64(seed);
        let x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian().abs() as f32);
        let mut beta = vec![0.0f32; p];
        for j in 0..p / 10 + 1 {
            beta[j * 7 % p] = rng.uniform_range(0.2, 1.5) as f32;
        }
        let mut y = vec![0.0f32; n];
        x.matvec(&beta, &mut y);
        for v in y.iter_mut() {
            *v += rng.normal(0.0, 0.01) as f32;
        }
        (x, y)
    }

    #[test]
    fn solution_nonnegative_and_converged() {
        let (x, y) = problem(41, 20, 50);
        let prob = NonnegProblem::new(&x, &y);
        let (lmax, _) = lambda_max(&prob);
        let res = solve_nonneg(&prob, 0.2 * lmax, None, &NonnegOptions::default());
        assert!(res.converged, "gap={}", res.gap);
        assert!(res.beta.iter().all(|&b| b >= 0.0));
        assert!(res.beta.iter().any(|&b| b > 0.0));
    }

    #[test]
    fn zero_solution_at_lambda_max() {
        let (x, y) = problem(42, 15, 30);
        let prob = NonnegProblem::new(&x, &y);
        let (lmax, _) = lambda_max(&prob);
        let res = solve_nonneg(&prob, lmax * 1.0001, None, &NonnegOptions::default());
        assert!(res.beta.iter().all(|&b| b == 0.0));
        // Just below λmax the solution must be nonzero.
        let res2 = solve_nonneg(&prob, lmax * 0.95, None, &NonnegOptions::default());
        assert!(res2.beta.iter().any(|&b| b > 0.0));
    }

    #[test]
    fn dynamic_screening_matches_static() {
        let (x, y) = problem(45, 25, 60);
        let prob = NonnegProblem::new(&x, &y);
        let (lmax, _) = lambda_max(&prob);
        let lambda = 0.3 * lmax;
        let opts = NonnegOptions { tol: 1e-8, ..Default::default() };
        let plain = solve_nonneg(&prob, lambda, None, &opts);
        let state = std::cell::RefCell::new(
            crate::screening::gap_safe::GapSafeDynamicNonneg::new(x.col_norms()),
        );
        let dynamic = solve_nonneg(
            &prob,
            lambda,
            None,
            &NonnegOptions { dynamic_screen: Some(&state), ..opts },
        );
        assert!(dynamic.converged);
        assert_eq!(dynamic.beta.len(), x.cols());
        assert!(
            (plain.objective - dynamic.objective).abs()
                < 1e-5 * plain.objective.abs().max(1.0)
        );
        assert!(
            crate::screening::gap_safe::same_support_at_resolution(&plain.beta, &dynamic.beta),
            "support mismatch between static and dynamic solves"
        );
        // Anti-correlated / slack columns must get evicted on this
        // planted-sparse problem.
        assert!(state.borrow().evicted() > 0, "nonneg dynamic screening never fired");
    }

    #[test]
    fn kkt_at_optimum() {
        // Theorem 19(ii)/(85): active coords have ⟨x_i, θ*⟩ = 1, all ≤ 1.
        let (x, y) = problem(43, 25, 40);
        let prob = NonnegProblem::new(&x, &y);
        let (lmax, _) = lambda_max(&prob);
        let lambda = 0.3 * lmax;
        let res =
            solve_nonneg(&prob, lambda, None, &NonnegOptions { tol: 1e-10, ..Default::default() });
        let mut r = vec![0.0f32; x.rows()];
        x.matvec(&res.beta, &mut r);
        for i in 0..r.len() {
            r[i] = y[i] - r[i];
        }
        for j in 0..x.cols() {
            let corr = ops::dot(x.col(j), &r) / lambda;
            assert!(corr <= 1.0 + 1e-3, "dual infeasible at {j}: {corr}");
            if res.beta[j] > 1e-4 {
                assert!((corr - 1.0).abs() < 1e-2, "active {j} corr={corr}");
            }
        }
    }

    #[test]
    fn gap_scale_bounds() {
        let (x, y) = problem(44, 10, 20);
        let prob = NonnegProblem::new(&x, &y);
        let beta = vec![0.0f32; 20];
        let r = y.clone();
        let mut c = vec![0.0f32; 20];
        x.matvec_t(&r, &mut c);
        let (lmax, _) = lambda_max(&prob);
        let (gap, s) = duality_gap(&prob, lmax, &beta, &r, &c);
        assert!((s - 1.0).abs() < 1e-9);
        assert!(gap.abs() < 1e-6);
    }
}
