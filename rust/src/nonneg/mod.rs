//! Nonnegative Lasso (Section 5 of the paper).
//!
//! ```text
//! min_{β ≥ 0} ½‖y − Xβ‖² + λ‖β‖₁                    (80)
//! ```
//!
//! The Fenchel dual (82) is `inf_θ ½‖y/λ − θ‖² − ½‖y‖²` over the polytope
//! `{θ : ⟨x_i, θ⟩ ≤ 1}`, with KKT `λθ* = y − Xβ*`. The solver is projected
//! FISTA with the closed-form prox `max(0, v − tλ)` and a duality-gap stop
//! using the radial feasibility scaling of `θ̂ = (y − Xβ)/λ`. Both per-
//! iteration sweeps run on the worker pool: `Xᵀv` column-chunked, the
//! fused `Xz − y` forward pass row-blocked — each bitwise identical to its
//! serial counterpart at every `TLFRE_THREADS`.

use crate::linalg::ops;
use crate::linalg::power::spectral_norm;
use crate::linalg::{DenseMatrix, DesignMatrix};
use crate::prox::nonneg_l1_prox;
use crate::util::Rng;

/// A borrowed nonnegative-Lasso problem instance, generic over the
/// [`DesignMatrix`] backend (defaults to [`DenseMatrix`]).
pub struct NonnegProblem<'a, M: DesignMatrix = DenseMatrix> {
    pub x: &'a M,
    pub y: &'a [f32],
}

impl<'a, M: DesignMatrix> NonnegProblem<'a, M> {
    pub fn new(x: &'a M, y: &'a [f32]) -> Self {
        assert_eq!(x.rows(), y.len());
        NonnegProblem { x, y }
    }
}

// Manual Clone/Copy/Debug: the derives would demand bounds on `M` even
// though only references are stored.
impl<M: DesignMatrix> Clone for NonnegProblem<'_, M> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<M: DesignMatrix> Copy for NonnegProblem<'_, M> {}

impl<M: DesignMatrix> std::fmt::Debug for NonnegProblem<'_, M> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("NonnegProblem")
            .field("n_samples", &self.x.rows())
            .field("n_features", &self.x.cols())
            .finish()
    }
}

/// Options (same semantics as the SGL FISTA options).
#[derive(Debug, Clone)]
pub struct NonnegOptions {
    pub max_iter: usize,
    pub tol: f64,
    pub check_every: usize,
    pub lipschitz: Option<f64>,
}

impl Default for NonnegOptions {
    fn default() -> Self {
        NonnegOptions { max_iter: 20_000, tol: 1e-6, check_every: 10, lipschitz: None }
    }
}

/// Solver output.
#[derive(Debug, Clone)]
pub struct NonnegResult {
    pub beta: Vec<f32>,
    pub iters: usize,
    pub gap: f64,
    pub objective: f64,
    pub converged: bool,
}

/// Primal objective ½‖y−Xβ‖² + λ‖β‖₁ (β assumed ≥ 0).
pub fn objective<M: DesignMatrix>(_prob: &NonnegProblem<'_, M>, lambda: f64, beta: &[f32], r: &[f32]) -> f64 {
    0.5 * ops::nrm2_sq(r) + lambda * ops::nrm1(beta)
}

/// The solver's step bound `L = (1.02·σmax(X))²` — 2% inflation because
/// power iteration approaches σmax from below. The single source of truth
/// for the seed/tolerance recipe, shared by [`solve_nonneg`]'s fallback and
/// the DPC path runners' once-per-path caches (which rely on producing the
/// *same* constant the solver would compute for the full problem).
pub fn nonneg_lipschitz<M: DesignMatrix>(x: &M) -> f64 {
    let mut rng = Rng::seed_from_u64(0x22_57FA);
    let s = spectral_norm(x, 1e-6, 500, &mut rng).sigma * 1.02;
    (s * s).max(f64::MIN_POSITIVE)
}

/// λmax = max_i ⟨x_i, y⟩ (Theorem 20) and its argmax column.
pub fn lambda_max<M: DesignMatrix>(prob: &NonnegProblem<'_, M>) -> (f64, usize) {
    let mut best = f64::NEG_INFINITY;
    let mut arg = 0;
    for j in 0..prob.x.cols() {
        let v = prob.x.col_dot_f64(j, prob.y);
        if v > best {
            best = v;
            arg = j;
        }
    }
    (best, arg)
}

/// Duality gap at β. `r` is the residual `y − Xβ`, `c = Xᵀr`.
///
/// The dual candidate is `θ = s·r/λ` with the largest `s ∈ [0,1]` making it
/// feasible for (82): `s = min(1, λ / max_i c_i)` (only *positive*
/// correlations constrain — the feasible set is one-sided).
/// Gap = P(β) − D(θ) with `D(θ) = ½‖y‖² − ½‖y − λθ‖²`.
pub fn duality_gap<M: DesignMatrix>(
    prob: &NonnegProblem<'_, M>,
    lambda: f64,
    beta: &[f32],
    r: &[f32],
    c: &[f32],
) -> (f64, f64) {
    let p = objective(prob, lambda, beta, r);
    let cmax = c.iter().fold(f64::NEG_INFINITY, |m, &v| m.max(v as f64));
    let s = if cmax <= lambda { 1.0 } else { lambda / cmax };
    // λθ = s·r  →  D = ½‖y‖² − ½‖y − s·r‖².
    let mut ynsq = 0.0f64;
    let mut dn = 0.0f64;
    for i in 0..prob.y.len() {
        let yi = prob.y[i] as f64;
        ynsq += yi * yi;
        let d = yi - s * r[i] as f64;
        dn += d * d;
    }
    let dual = 0.5 * ynsq - 0.5 * dn;
    ((p - dual).max(0.0), s)
}

/// Solve nonnegative Lasso by projected FISTA.
pub fn solve_nonneg<M: DesignMatrix>(
    prob: &NonnegProblem<'_, M>,
    lambda: f64,
    warm_start: Option<&[f32]>,
    opts: &NonnegOptions,
) -> NonnegResult {
    let n = prob.x.rows();
    let p = prob.x.cols();
    let l = opts.lipschitz.unwrap_or_else(|| nonneg_lipschitz(prob.x));
    let step = 1.0 / l;
    let scale_ref = (0.5 * ops::nrm2_sq(prob.y)).max(1e-10);

    let mut beta: Vec<f32> = warm_start.map(|b| b.to_vec()).unwrap_or_else(|| vec![0.0; p]);
    let mut beta_prev = beta.clone();
    let mut z = beta.clone();
    let mut t_k = 1.0f64;

    let mut xz = vec![0.0f32; n];
    let mut grad = vec![0.0f32; p];
    let mut w = vec![0.0f32; p];
    let mut r = vec![0.0f32; n];
    let mut c = vec![0.0f32; p];

    let mut gap = f64::INFINITY;
    let mut converged = false;
    let mut iters = 0;
    let mut last_obj = f64::INFINITY;
    // Objective from a gap check at the current β, reused on exit (see
    // `sgl::fista::solve_fista` — same skip of the duplicated recompute).
    let mut checked_obj: Option<f64> = None;

    for k in 0..opts.max_iter {
        iters = k + 1;
        checked_obj = None;
        // ∇ = Xᵀ(Xz − y), residual fused into the matvec.
        prob.x.residual_matvec(&z, prob.y, &mut xz);
        prob.x.matvec_t(&xz, &mut grad);
        ops::add_scaled(&z, -(step as f32), &grad, &mut w);
        std::mem::swap(&mut beta, &mut beta_prev);
        nonneg_l1_prox(&w, step * lambda, &mut beta);

        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t_k * t_k).sqrt());
        let omega = ((t_k - 1.0) / t_next) as f32;
        for j in 0..p {
            z[j] = beta[j] + omega * (beta[j] - beta_prev[j]);
        }
        t_k = t_next;

        if (k + 1) % opts.check_every == 0 || k + 1 == opts.max_iter {
            prob.x.residual(&beta, prob.y, &mut r);
            prob.x.matvec_t(&r, &mut c);
            let obj = objective(prob, lambda, &beta, &r);
            if obj > last_obj {
                t_k = 1.0;
                z.copy_from_slice(&beta);
            }
            last_obj = obj;
            checked_obj = Some(obj);
            let (g, _) = duality_gap(prob, lambda, &beta, &r, &c);
            gap = g;
            if gap <= opts.tol * scale_ref {
                converged = true;
                break;
            }
        }
    }

    // Both loop exits (converged break, forced check at max_iter) leave
    // `checked_obj` fresh at the final β; only max_iter == 0 recomputes.
    let objective = match checked_obj {
        Some(o) => o,
        None => {
            prob.x.residual(&beta, prob.y, &mut r);
            objective(prob, lambda, &beta, &r)
        }
    };
    NonnegResult { beta, iters, gap, objective, converged }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn problem(seed: u64, n: usize, p: usize) -> (DenseMatrix, Vec<f32>) {
        let mut rng = Rng::seed_from_u64(seed);
        let x = DenseMatrix::from_fn(n, p, |_, _| rng.gaussian().abs() as f32);
        let mut beta = vec![0.0f32; p];
        for j in 0..p / 10 + 1 {
            beta[j * 7 % p] = rng.uniform_range(0.2, 1.5) as f32;
        }
        let mut y = vec![0.0f32; n];
        x.matvec(&beta, &mut y);
        for v in y.iter_mut() {
            *v += rng.normal(0.0, 0.01) as f32;
        }
        (x, y)
    }

    #[test]
    fn solution_nonnegative_and_converged() {
        let (x, y) = problem(41, 20, 50);
        let prob = NonnegProblem::new(&x, &y);
        let (lmax, _) = lambda_max(&prob);
        let res = solve_nonneg(&prob, 0.2 * lmax, None, &NonnegOptions::default());
        assert!(res.converged, "gap={}", res.gap);
        assert!(res.beta.iter().all(|&b| b >= 0.0));
        assert!(res.beta.iter().any(|&b| b > 0.0));
    }

    #[test]
    fn zero_solution_at_lambda_max() {
        let (x, y) = problem(42, 15, 30);
        let prob = NonnegProblem::new(&x, &y);
        let (lmax, _) = lambda_max(&prob);
        let res = solve_nonneg(&prob, lmax * 1.0001, None, &NonnegOptions::default());
        assert!(res.beta.iter().all(|&b| b == 0.0));
        // Just below λmax the solution must be nonzero.
        let res2 = solve_nonneg(&prob, lmax * 0.95, None, &NonnegOptions::default());
        assert!(res2.beta.iter().any(|&b| b > 0.0));
    }

    #[test]
    fn kkt_at_optimum() {
        // Theorem 19(ii)/(85): active coords have ⟨x_i, θ*⟩ = 1, all ≤ 1.
        let (x, y) = problem(43, 25, 40);
        let prob = NonnegProblem::new(&x, &y);
        let (lmax, _) = lambda_max(&prob);
        let lambda = 0.3 * lmax;
        let res =
            solve_nonneg(&prob, lambda, None, &NonnegOptions { tol: 1e-10, ..Default::default() });
        let mut r = vec![0.0f32; x.rows()];
        x.matvec(&res.beta, &mut r);
        for i in 0..r.len() {
            r[i] = y[i] - r[i];
        }
        for j in 0..x.cols() {
            let corr = ops::dot(x.col(j), &r) / lambda;
            assert!(corr <= 1.0 + 1e-3, "dual infeasible at {j}: {corr}");
            if res.beta[j] > 1e-4 {
                assert!((corr - 1.0).abs() < 1e-2, "active {j} corr={corr}");
            }
        }
    }

    #[test]
    fn gap_scale_bounds() {
        let (x, y) = problem(44, 10, 20);
        let prob = NonnegProblem::new(&x, &y);
        let beta = vec![0.0f32; 20];
        let r = y.clone();
        let mut c = vec![0.0f32; 20];
        x.matvec_t(&r, &mut c);
        let (lmax, _) = lambda_max(&prob);
        let (gap, s) = duality_gap(&prob, lmax, &beta, &r, &c);
        assert!((s - 1.0).abs() < 1e-9);
        assert!(gap.abs() < 1e-6);
    }
}
