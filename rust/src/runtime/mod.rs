//! PJRT runtime — loads and executes the AOT-compiled Layer-1/Layer-2
//! artifacts produced by `python/compile/aot.py`.
//!
//! Interchange format is **HLO text** (`artifacts/*.hlo.txt`): jax ≥ 0.5
//! serializes `HloModuleProto`s with 64-bit instruction ids that the
//! crate's bundled XLA (xla_extension 0.5.1) rejects; the text parser
//! reassigns ids and round-trips cleanly.
//!
//! Python never runs at request time: the rust binary discovers artifacts
//! through `artifacts/manifest.json`, compiles each once per process
//! ([`Runtime`] caches the loaded executables) and executes them through
//! the PJRT C API. The design matrix is staged into a device buffer once
//! per data set ([`ScreenEngine`]) so the per-λ hot call only uploads the
//! small `θ`-side inputs.
//!
//! # Feature gating
//!
//! The PJRT path needs the vendored `xla` crate, which is not part of the
//! dependency-free default build. Everything here is therefore compiled in
//! two flavours:
//!
//! * `--features pjrt` — the real implementation (requires supplying the
//!   `xla` crate via a `[patch]`/path dependency);
//! * default — API-compatible stubs whose constructors return a descriptive
//!   error, so callers (CLI `runtime-info`, the runtime integration tests,
//!   the e2e example) degrade to a skip instead of failing to compile.
//!
//! [`ArtifactManifest`] parsing is pure rust and always available.

pub mod artifacts;
pub mod engine;

pub use artifacts::{ArtifactManifest, ArtifactSpec};
pub use engine::ScreenEngine;

use std::path::PathBuf;

#[cfg(feature = "pjrt")]
mod pjrt_runtime {
    use crate::error::{Context, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    /// A process-wide PJRT client with a compile cache.
    pub struct Runtime {
        client: xla::PjRtClient,
        cache: HashMap<PathBuf, xla::PjRtLoadedExecutable>,
    }

    impl Runtime {
        /// Create a CPU PJRT runtime.
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime { client, cache: HashMap::new() })
        }

        /// Backend platform name (e.g. "cpu").
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn client(&self) -> &xla::PjRtClient {
            &self.client
        }

        /// Load an HLO-text artifact, compiling it on first use.
        pub fn load(&mut self, path: &Path) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.cache.contains_key(path) {
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 artifact path")?,
                )
                .with_context(|| format!("parsing HLO text {path:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .with_context(|| format!("compiling {path:?}"))?;
                self.cache.insert(path.to_path_buf(), exe);
            }
            Ok(&self.cache[path])
        }

        /// Execute an artifact on f32 literal inputs, returning the flat f32
        /// contents of every output in the result tuple.
        pub fn execute_f32(
            &mut self,
            path: &Path,
            inputs: &[(&[f32], &[i64])],
        ) -> Result<Vec<Vec<f32>>> {
            let exe = self.load(path)?;
            let lits: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, dims)| -> Result<xla::Literal> {
                    let l = xla::Literal::vec1(data);
                    Ok(if dims.len() == 1 { l } else { l.reshape(dims)? })
                })
                .collect::<Result<_>>()?;
            let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
            let parts = result.to_tuple()?;
            parts
                .into_iter()
                .map(|p| p.to_vec::<f32>().context("reading f32 output"))
                .collect()
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_runtime::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub_runtime {
    use crate::error::Result;
    use std::path::Path;

    /// Stub runtime used when the crate is built without `--features pjrt`.
    /// Construction fails with a descriptive error; callers are expected to
    /// skip gracefully (the CLI and tests do).
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        /// Always errors: the PJRT backend is not compiled in.
        pub fn cpu() -> Result<Runtime> {
            Err(crate::anyhow!(
                "tlfre was built without the `pjrt` feature; \
                 PJRT/XLA artifact execution is unavailable \
                 (rebuild with `--features pjrt` and a vendored `xla` crate)"
            ))
        }

        /// Backend platform name.
        pub fn platform(&self) -> String {
            "unavailable (built without pjrt)".to_string()
        }

        /// Stub load — unreachable in practice (`cpu()` never succeeds).
        pub fn load(&mut self, _path: &Path) -> Result<()> {
            Err(crate::anyhow!("pjrt feature not compiled in"))
        }

        /// Stub execute — unreachable in practice.
        pub fn execute_f32(
            &mut self,
            _path: &Path,
            _inputs: &[(&[f32], &[i64])],
        ) -> Result<Vec<Vec<f32>>> {
            Err(crate::anyhow!("pjrt feature not compiled in"))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub_runtime::Runtime;

/// Whether the PJRT backend is compiled into this binary.
pub const fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}

/// Default artifacts directory: `$TLFRE_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("TLFRE_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Probe helper shared by tests and the e2e example: a `Runtime` if the
/// backend is compiled in and constructible, else `None`.
pub fn try_runtime() -> Option<Runtime> {
    match Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("PJRT runtime unavailable: {e:#}");
            None
        }
    }
}
