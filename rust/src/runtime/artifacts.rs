//! Artifact manifest — the contract between `python/compile/aot.py` and
//! the rust runtime.
//!
//! `artifacts/manifest.json` layout (written by the AOT pipeline):
//!
//! ```json
//! {
//!   "version": 1,
//!   "artifacts": [
//!     {"name": "screen", "file": "screen_n250_p10000_g10.hlo.txt",
//!      "kind": "tlfre_screen", "n": 250, "p": 10000, "group_size": 10}
//!   ]
//! }
//! ```

use crate::util::json::Json;
use crate::bail;
use crate::error::{Context, Result};
use std::path::{Path, PathBuf};

/// One AOT-compiled computation.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    /// Kind tag: `tlfre_screen`, `dpc_screen`, `fista_step`, …
    pub kind: String,
    /// Sample dimension the artifact was specialized for.
    pub n: usize,
    /// Feature dimension.
    pub p: usize,
    /// Uniform group size (0 when not applicable).
    pub group_size: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    pub artifacts: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

impl ArtifactManifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str, dir: &Path) -> Result<ArtifactManifest> {
        let v = Json::parse(text).context("manifest.json is not valid JSON")?;
        let version = v.get("version").and_then(|x| x.as_usize()).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let arr = v
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .context("manifest missing 'artifacts' array")?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for (i, a) in arr.iter().enumerate() {
            let get_str = |k: &str| -> Result<String> {
                Ok(a.get(k)
                    .and_then(|x| x.as_str())
                    .with_context(|| format!("artifact[{i}] missing '{k}'"))?
                    .to_string())
            };
            let get_num =
                |k: &str| -> usize { a.get(k).and_then(|x| x.as_usize()).unwrap_or(0) };
            artifacts.push(ArtifactSpec {
                name: get_str("name")?,
                file: get_str("file")?,
                kind: get_str("kind")?,
                n: get_num("n"),
                p: get_num("p"),
                group_size: get_num("group_size"),
            });
        }
        Ok(ArtifactManifest { artifacts, dir: dir.to_path_buf() })
    }

    /// Find an artifact by kind and exact shape.
    pub fn find(&self, kind: &str, n: usize, p: usize) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.kind == kind && a.n == n && a.p == p)
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "artifacts": [
            {"name": "screen_small", "file": "screen_n8_p32_g4.hlo.txt",
             "kind": "tlfre_screen", "n": 8, "p": 32, "group_size": 4},
            {"name": "dpc_small", "file": "dpc_n8_p32.hlo.txt",
             "kind": "dpc_screen", "n": 8, "p": 32}
        ]
    }"#;

    #[test]
    fn parse_and_find() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/tmp/artifacts")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.find("tlfre_screen", 8, 32).unwrap();
        assert_eq!(a.group_size, 4);
        assert_eq!(m.path_of(a), PathBuf::from("/tmp/artifacts/screen_n8_p32_g4.hlo.txt"));
        assert!(m.find("tlfre_screen", 9, 32).is_none());
        let d = m.find("dpc_screen", 8, 32).unwrap();
        assert_eq!(d.group_size, 0);
    }

    #[test]
    fn rejects_bad_version_and_shape() {
        assert!(ArtifactManifest::parse(r#"{"version": 2, "artifacts": []}"#, Path::new(".")).is_err());
        assert!(ArtifactManifest::parse(r#"{"artifacts": []}"#, Path::new(".")).is_err());
        assert!(ArtifactManifest::parse("[]", Path::new(".")).is_err());
        assert!(ArtifactManifest::parse("{garbage", Path::new(".")).is_err());
        // missing required name
        let bad = r#"{"version":1,"artifacts":[{"file":"x","kind":"k"}]}"#;
        assert!(ArtifactManifest::parse(bad, Path::new(".")).is_err());
    }
}
