//! The screening engine: executes the AOT-compiled fused screening kernel
//! on a pre-staged design matrix.
//!
//! Layout note: [`crate::linalg::DenseMatrix`] stores `X (N×p)` column-
//! major, which is byte-identical to a row-major `(p, N)` array — exactly
//! the `Xᵀ` the artifact expects as its first parameter. Staging is
//! therefore a zero-copy reinterpretation; it happens once per data set,
//! and each per-λ call only uploads the `o ∈ R^N` ball center.
//!
//! Like [`super::Runtime`], the real implementation requires the vendored
//! `xla` crate and is compiled only under `--features pjrt`; the default
//! build ships an API-compatible stub whose constructors error.

use super::artifacts::{ArtifactManifest, ArtifactSpec};
use super::Runtime;
use crate::error::Result;
use crate::linalg::DenseMatrix;

/// Output of one fused screening-kernel execution.
#[derive(Debug, Clone)]
pub struct ScreenKernelOut {
    /// `c = Xᵀ o`, length p.
    pub c: Vec<f32>,
    /// Per-group `‖S₁(c_g)‖²`, length G (uniform groups).
    pub group_shrink_sq: Vec<f32>,
    /// Per-group `‖c_g‖∞`, length G.
    pub group_cinf: Vec<f32>,
}

#[cfg(feature = "pjrt")]
mod imp {
    use super::*;
    use crate::error::Context;

    /// A data-set-bound handle: staged `Xᵀ` buffer + compiled screen artifact.
    pub struct ScreenEngine {
        exe: xla::PjRtLoadedExecutable,
        x_buf: xla::PjRtBuffer,
        n: usize,
        p: usize,
        pub group_size: usize,
    }

    impl ScreenEngine {
        /// Build from a manifest: finds the `tlfre_screen` artifact matching
        /// the matrix shape, compiles it, stages `Xᵀ`.
        pub fn for_matrix(
            rt: &mut Runtime,
            manifest: &ArtifactManifest,
            x: &DenseMatrix,
        ) -> Result<ScreenEngine> {
            let spec = manifest
                .find("tlfre_screen", x.rows(), x.cols())
                .with_context(|| {
                    format!(
                        "no tlfre_screen artifact for {}×{} — regenerate with `make artifacts`",
                        x.rows(),
                        x.cols()
                    )
                })?
                .clone();
            Self::from_spec(rt, manifest, &spec, x)
        }

        /// Build from an explicit artifact spec.
        pub fn from_spec(
            rt: &mut Runtime,
            manifest: &ArtifactManifest,
            spec: &ArtifactSpec,
            x: &DenseMatrix,
        ) -> Result<ScreenEngine> {
            crate::ensure!(
                spec.n == x.rows() && spec.p == x.cols(),
                "artifact shape {}×{} does not match matrix {}×{}",
                spec.n,
                spec.p,
                x.rows(),
                x.cols()
            );
            // Compile an engine-owned executable (PjRtLoadedExecutable is not
            // Clone, so the Runtime cache can't hand out copies).
            let path = manifest.path_of(spec);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = rt.client().compile(&comp).with_context(|| format!("compiling {path:?}"))?;
            // Col-major (N×p) == row-major (p×N): stage as Xᵀ.
            let x_buf = rt
                .client()
                .buffer_from_host_buffer::<f32>(x.data(), &[x.cols(), x.rows()], None)
                .context("staging design matrix")?;
            Ok(ScreenEngine { exe, x_buf, n: x.rows(), p: x.cols(), group_size: spec.group_size })
        }

        /// Execute the fused kernel for a ball center `o` (length N).
        pub fn run(&self, rt: &Runtime, o: &[f32]) -> Result<ScreenKernelOut> {
            crate::ensure!(o.len() == self.n, "o has length {} ≠ N={}", o.len(), self.n);
            let o_buf = rt.client().buffer_from_host_buffer::<f32>(o, &[self.n], None)?;
            let result = self.exe.execute_b(&[&self.x_buf, &o_buf])?[0][0].to_literal_sync()?;
            let parts = result.to_tuple()?;
            crate::ensure!(parts.len() == 3, "screen artifact returned {} outputs", parts.len());
            let c = parts[0].to_vec::<f32>()?;
            let group_shrink_sq = parts[1].to_vec::<f32>()?;
            let group_cinf = parts[2].to_vec::<f32>()?;
            crate::ensure!(c.len() == self.p, "c length mismatch");
            Ok(ScreenKernelOut { c, group_shrink_sq, group_cinf })
        }

        #[inline]
        pub fn n(&self) -> usize {
            self.n
        }

        #[inline]
        pub fn p(&self) -> usize {
            self.p
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::*;

    /// Stub engine compiled without `--features pjrt`; constructors error,
    /// so the fields exist only to keep the API shape (never constructed).
    #[allow(dead_code)]
    pub struct ScreenEngine {
        n: usize,
        p: usize,
        pub group_size: usize,
    }

    impl ScreenEngine {
        /// Always errors: the PJRT backend is not compiled in.
        pub fn for_matrix(
            _rt: &mut Runtime,
            _manifest: &ArtifactManifest,
            _x: &DenseMatrix,
        ) -> Result<ScreenEngine> {
            Err(crate::anyhow!("ScreenEngine requires the `pjrt` feature"))
        }

        /// Always errors: the PJRT backend is not compiled in.
        pub fn from_spec(
            _rt: &mut Runtime,
            _manifest: &ArtifactManifest,
            _spec: &ArtifactSpec,
            _x: &DenseMatrix,
        ) -> Result<ScreenEngine> {
            Err(crate::anyhow!("ScreenEngine requires the `pjrt` feature"))
        }

        /// Unreachable in practice — construction never succeeds.
        pub fn run(&self, _rt: &Runtime, _o: &[f32]) -> Result<ScreenKernelOut> {
            Err(crate::anyhow!("ScreenEngine requires the `pjrt` feature"))
        }

        #[inline]
        pub fn n(&self) -> usize {
            self.n
        }

        #[inline]
        pub fn p(&self) -> usize {
            self.p
        }
    }
}

pub use imp::ScreenEngine;
