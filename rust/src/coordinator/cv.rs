//! Cross-validation driver over the (α, λ) grid.
//!
//! The paper's Remark 3 motivates TLFre with exactly this workload:
//! "commonly used approaches such as cross validation and stability
//! selection involve solving SGL many times over a grid of parameter
//! values". This module runs k-fold CV where every fold×α path is a
//! TLFre-screened path — the end-to-end setting in which screening's
//! speedup multiplies across the whole model-selection procedure.

use super::runner::{run_tlfre_path, PathConfig};
use crate::groups::GroupStructure;
use crate::linalg::ops;
use crate::linalg::{DesignMatrix, SelectRows};
use crate::util::Rng;

/// One grid point's cross-validated error.
#[derive(Debug, Clone)]
pub struct CvPoint {
    pub alpha: f64,
    /// λ/λmax^α position on the path (grids differ per α, so positions are
    /// compared by normalized index).
    pub lambda_ratio: f64,
    /// Mean held-out MSE across folds.
    pub mse: f64,
    /// Nonzero count (averaged over folds).
    pub mean_nnz: f64,
}

/// Cross-validation output.
#[derive(Debug, Clone)]
pub struct CvOutput {
    pub points: Vec<CvPoint>,
    pub best: CvPoint,
    /// Total screening / solving time across all folds (seconds).
    pub screen_total_s: f64,
    pub solve_total_s: f64,
}

/// Split `n` samples into `k` folds (seeded permutation).
pub fn make_folds(n: usize, k: usize, seed: u64) -> Vec<Vec<usize>> {
    assert!(k >= 2 && k <= n, "need 2 ≤ k ≤ n");
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Rng::seed_from_u64(seed);
    rng.shuffle(&mut idx);
    let mut folds = vec![Vec::new(); k];
    for (i, &s) in idx.iter().enumerate() {
        folds[i % k].push(s);
    }
    folds
}

/// Run k-fold CV over `alphas` with TLFre-screened paths. Works over any
/// backend that supports fold extraction ([`SelectRows`]: dense and CSC).
pub fn cross_validate<M: DesignMatrix + SelectRows>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    alphas: &[f64],
    k_folds: usize,
    base_cfg: &PathConfig,
    seed: u64,
) -> CvOutput {
    let n = x.rows();
    let folds = make_folds(n, k_folds, seed);
    let n_lambda = base_cfg.n_lambda;

    // mse[alpha_idx][lambda_idx] accumulated over folds
    let mut mse = vec![vec![0.0f64; n_lambda]; alphas.len()];
    let mut nnz = vec![vec![0.0f64; n_lambda]; alphas.len()];
    let mut screen_total = 0.0;
    let mut solve_total = 0.0;

    for fold in &folds {
        // Train rows = complement of the fold.
        let in_fold: std::collections::BTreeSet<usize> = fold.iter().copied().collect();
        let train_rows: Vec<usize> = (0..n).filter(|i| !in_fold.contains(i)).collect();
        let x_train = x.select_rows(&train_rows);
        let y_train: Vec<f32> = train_rows.iter().map(|&i| y[i]).collect();
        let x_test = x.select_rows(fold);
        let y_test: Vec<f32> = fold.iter().map(|&i| y[i]).collect();

        for (ai, &alpha) in alphas.iter().enumerate() {
            let cfg = PathConfig { alpha, ..base_cfg.clone() };
            let out = run_tlfre_path(&x_train, &y_train, groups, &cfg);
            screen_total += out.screen_total_s;
            solve_total += out.solve_total_s;
            // Held-out MSE per path step requires β per step; the runner
            // reports stats only, so re-walk the path cheaply: we re-run
            // predictions from the final coefficients of each step by
            // recomputing them here. To keep the runner lean we instead
            // evaluate only the *reported* sparsity and recompute β via a
            // second screened pass storing coefficients.
            let betas = path_coefficients(&x_train, &y_train, groups, &cfg);
            for (li, beta) in betas.iter().enumerate() {
                let mut pred = vec![0.0f32; fold.len()];
                x_test.matvec(beta, &mut pred);
                let mut e = 0.0f64;
                for (p, t) in pred.iter().zip(&y_test) {
                    let d = (p - t) as f64;
                    e += d * d;
                }
                mse[ai][li] += e / fold.len() as f64;
                nnz[ai][li] += (beta.len() - ops::count_zeros(beta)) as f64;
            }
        }
    }

    let kf = folds.len() as f64;
    let mut points = Vec::new();
    for (ai, &alpha) in alphas.iter().enumerate() {
        for li in 0..n_lambda {
            points.push(CvPoint {
                alpha,
                lambda_ratio: ratio_at(li, n_lambda, base_cfg.lambda_min_ratio),
                mse: mse[ai][li] / kf,
                mean_nnz: nnz[ai][li] / kf,
            });
        }
    }
    let best = points
        .iter()
        .min_by(|a, b| a.mse.partial_cmp(&b.mse).unwrap())
        .expect("nonempty grid")
        .clone();
    CvOutput { points, best, screen_total_s: screen_total, solve_total_s: solve_total }
}

/// λ/λmax at grid index `i` for a log grid with the given floor.
fn ratio_at(i: usize, k: usize, min_ratio: f64) -> f64 {
    (min_ratio.ln() * i as f64 / (k - 1) as f64).exp()
}

/// Re-run a screened path, returning the coefficient vector at every λ.
///
/// Dispatches on [`PathConfig::solver`] through the same
/// [`super::runner::solve`] match the runner uses — a BCD-configured CV
/// walks a BCD path, with the per-group Lipschitz constants cached once
/// per path (and the amortized [`GroupRefresher`] schedule) exactly as
/// `run_tlfre_path` supplies them.
pub fn path_coefficients<M: DesignMatrix>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    cfg: &PathConfig,
) -> Vec<Vec<f32>> {
    use crate::coordinator::path::log_lambda_grid;
    use crate::coordinator::reduce::ReducedProblem;
    use crate::coordinator::refresh::{GroupRefresher, ScalarRefresher};
    use crate::coordinator::runner::{solve, SolverKind, SpectralCache};
    use crate::screening::lambda_max::sgl_lambda_max;
    use crate::screening::tlfre::{tlfre_screen_inexact, TlfreContext};
    use crate::sgl::bcd::bcd_group_lipschitz;
    use crate::sgl::fista::lipschitz_of;
    use crate::sgl::problem::{SglParams, SglProblem};

    let prob = SglProblem::new(x, y, groups);
    let p = prob.n_features();
    let lmax = sgl_lambda_max(&prob, cfg.alpha);
    let ctx = TlfreContext::precompute(&prob);
    let grid = log_lambda_grid(lmax.lambda_max, cfg.lambda_min_ratio, cfg.n_lambda);
    // Same path-level spectral cache — and the same amortized per-view
    // refresh schedule — as `run_tlfre_path`: the two walks must stay in
    // numerical lockstep (the integration tests compare their per-step
    // sparsity exactly), so every step-size decision is mirrored here.
    let spectral = SpectralCache::for_path(&prob, cfg);
    let refresh_every = if cfg.exact_view_lipschitz { None } else { cfg.lipschitz_refresh_every };
    let mut scalar_refresh = match (refresh_every, cfg.solver) {
        (Some(k), SolverKind::Fista) => Some(ScalarRefresher::new(k, p)),
        _ => None,
    };
    let mut group_refresh = match (refresh_every, cfg.solver) {
        (Some(k), SolverKind::Bcd) => Some(GroupRefresher::new(k, p, groups.n_groups())),
        _ => None,
    };

    let mut betas = Vec::with_capacity(grid.len());
    let mut beta = vec![0.0f32; p];
    betas.push(beta.clone());
    let mut lambda_bar = grid[0];
    let mut resid = vec![0.0f32; prob.n_samples()];
    let mut corr = vec![0.0f32; p];
    for &lambda in &grid[1..] {
        crate::sgl::objective::residual(&prob, &beta, &mut resid);
        let params_bar = SglParams::from_alpha_lambda(cfg.alpha, lambda_bar);
        prob.x.matvec_t(&resid, &mut corr);
        let (gap, s_feas) =
            crate::sgl::dual::duality_gap(&prob, &params_bar, &beta, &resid, &corr);
        let theta_bar: Vec<f32> =
            resid.iter().map(|&v| (v as f64 * s_feas / lambda_bar) as f32).collect();
        let outcome = tlfre_screen_inexact(
            &prob,
            cfg.alpha,
            lambda,
            lambda_bar,
            &theta_bar,
            gap * cfg.gap_inflation,
            &lmax,
            &ctx,
        );
        let params = SglParams::from_alpha_lambda(cfg.alpha, lambda);
        match ReducedProblem::build(x, groups, &outcome) {
            None => beta.fill(0.0),
            Some(red) => {
                let step_lip = match &mut scalar_refresh {
                    Some(rf) => Some(rf.step(
                        red.feature_map(),
                        spectral.lip.expect("cached full-matrix bound exists in refresh mode"),
                        || lipschitz_of(&red.x),
                    )),
                    None => spectral.lip,
                };
                let step_group_l = match &mut group_refresh {
                    Some(rf) => Some(rf.step(
                        red.feature_map(),
                        &red.groups.ranges(),
                        &red.group_map,
                        spectral.group_l.as_deref().expect("cached full-matrix bounds exist"),
                        || bcd_group_lipschitz(&red.x, &red.groups.ranges()),
                    )),
                    None => spectral.reduced_group_l(&red),
                };
                let red_coloring = spectral.reduced_coloring(&red);
                let rp = SglProblem::new(&red.x, y, &red.groups);
                let warm = red.gather(&beta);
                let res = solve(
                    &rp,
                    &params,
                    Some(&warm),
                    cfg,
                    step_lip,
                    step_group_l.as_deref(),
                    red_coloring.as_ref(),
                );
                red.scatter(&res.beta, &mut beta);
            }
        }
        betas.push(beta.clone());
        lambda_bar = lambda;
    }
    betas
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_synthetic, SyntheticSpec};

    #[test]
    fn folds_partition_samples() {
        let folds = make_folds(23, 4, 1);
        assert_eq!(folds.len(), 4);
        let mut all: Vec<usize> = folds.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..23).collect::<Vec<_>>());
        // balanced within 1
        let sizes: Vec<usize> = folds.iter().map(|f| f.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn cv_picks_sensible_lambda() {
        // Planted sparse model: CV should prefer an interior λ (not the
        // densest end with overfitting noise, not λmax with β = 0).
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(60, 200, 20), 401);
        let cfg = PathConfig {
            n_lambda: 12,
            lambda_min_ratio: 0.01,
            tol: 1e-5,
            ..Default::default()
        };
        let out = cross_validate(&ds.x, &ds.y, &ds.groups, &[0.5, 1.0], 3, &cfg, 7);
        assert_eq!(out.points.len(), 2 * 12);
        assert!(out.best.lambda_ratio < 1.0, "best at λmax (underfit)");
        assert!(out.best.mse.is_finite());
        // The best model recovers roughly the planted sparsity order.
        assert!(out.best.mean_nnz >= 1.0);
        assert!(out.best.mean_nnz < 150.0);
    }

    #[test]
    fn path_coefficients_matches_runner_sparsity() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 120, 12), 402);
        let cfg = PathConfig { n_lambda: 8, lambda_min_ratio: 0.05, tol: 1e-6, ..Default::default() };
        let betas = path_coefficients(&ds.x, &ds.y, &ds.groups, &cfg);
        let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
        assert_eq!(betas.len(), out.steps.len());
        for (b, s) in betas.iter().zip(&out.steps) {
            let nnz = b.len() - ops::count_zeros(b);
            assert_eq!(nnz, s.nonzeros, "λ={}", s.lambda);
        }
    }

    #[test]
    fn path_coefficients_honors_bcd_solver() {
        // Regression: `path_coefficients` used to hardcode FISTA while the
        // runner dispatched on `cfg.solver`, so a BCD-configured CV
        // silently evaluated a different solver's path than the one the
        // runner reported. The BCD walk must now stay in per-step sparsity
        // lockstep with `run_tlfre_path` under the same config.
        use crate::coordinator::runner::SolverKind;
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 120, 12), 403);
        let cfg = PathConfig {
            solver: SolverKind::Bcd,
            n_lambda: 8,
            lambda_min_ratio: 0.05,
            tol: 1e-6,
            ..Default::default()
        };
        let betas = path_coefficients(&ds.x, &ds.y, &ds.groups, &cfg);
        let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
        assert_eq!(betas.len(), out.steps.len());
        for (b, s) in betas.iter().zip(&out.steps) {
            let nnz = b.len() - ops::count_zeros(b);
            assert_eq!(nnz, s.nonzeros, "BCD lockstep broke at λ={}", s.lambda);
        }
        // The refresh schedule must stay mirrored for BCD too.
        let refresh_cfg = PathConfig { lipschitz_refresh_every: Some(2), ..cfg };
        let betas_r = path_coefficients(&ds.x, &ds.y, &ds.groups, &refresh_cfg);
        let out_r = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &refresh_cfg);
        for (b, s) in betas_r.iter().zip(&out_r.steps) {
            let nnz = b.len() - ops::count_zeros(b);
            assert_eq!(nnz, s.nonzeros, "BCD refresh lockstep broke at λ={}", s.lambda);
        }
    }
}
