//! Cross-validation driver over the (α, λ) grid.
//!
//! The paper's Remark 3 motivates TLFre with exactly this workload:
//! "commonly used approaches such as cross validation and stability
//! selection involve solving SGL many times over a grid of parameter
//! values". This module runs k-fold CV where every fold×α path is a
//! TLFre-screened path — the end-to-end setting in which screening's
//! speedup multiplies across the whole model-selection procedure.
//!
//! ## One walk per fold×α
//!
//! Each fold×α grid is walked **exactly once**: the streaming driver
//! ([`super::driver`]) screens/solves the path and a
//! [`HoldoutSink`] folds every step's β into held-out predictions on the
//! spot. (The pre-driver implementation walked every path twice — once in
//! `run_tlfre_path` for stats, once in a hand-mirrored `path_coefficients`
//! for β — and the mirror had drifted: it hardcoded FISTA while the runner
//! dispatched on `cfg.solver`.) The single-walk property is observable:
//! the power-iteration counter delta of a CV run equals the sum of the
//! per-path deltas, asserted in `tests/cv_parallel.rs`.
//!
//! ## Fold-parallel sharding, bitwise deterministic
//!
//! Fold×α path tasks are sharded across the persistent
//! [`crate::util::pool`] ([`pool::parallel_map_with_workers`]). Each path
//! stays internally serial from the pool's point of view (nested sweeps
//! degrade to serial loops on pool workers — which are bitwise identical
//! to the parallel sweeps by the pool's determinism guarantee), tasks run
//! in fold-major order-preserving chunks, and the fold accumulation below
//! replays exactly the serial sweep's addition order. Consequence: CV
//! output is **bitwise identical** to [`cross_validate_serial`] at every
//! `TLFRE_THREADS` / worker count (enforced by `tests/cv_parallel.rs` and
//! the CI thread matrix).
//!
//! ## Screening pipelines compose with CV
//!
//! `PathConfig::screen` flows through unchanged: every fold×α walk uses
//! the configured [`crate::screening::rule::ScreenPipeline`], including
//! in-solver dynamic GAP screening (`tlfre+gap` / `gap`) — eviction
//! decisions ride the solver's own worker-count-invariant gap checks, so
//! the bitwise serial/sharded equality above holds for every pipeline,
//! and a `strong+kkt` fold path still runs its KKT recovery inside the
//! engine before the sink ever sees β.

use super::driver::{drive_tlfre_path, CoefficientSink, HoldoutSink};
use super::runner::PathConfig;
use crate::groups::GroupStructure;
use crate::linalg::{DesignMatrix, SelectRows};
use crate::util::{pool, Rng};

/// One grid point's cross-validated error.
#[derive(Debug, Clone)]
pub struct CvPoint {
    pub alpha: f64,
    /// λ/λmax^α position on the path (grids differ per α, so positions are
    /// compared by normalized index).
    pub lambda_ratio: f64,
    /// Mean held-out MSE across folds.
    pub mse: f64,
    /// Nonzero count (averaged over folds).
    pub mean_nnz: f64,
}

/// Cross-validation output.
#[derive(Debug, Clone)]
pub struct CvOutput {
    pub points: Vec<CvPoint>,
    pub best: CvPoint,
    /// Total screening / solving time across all folds (seconds).
    pub screen_total_s: f64,
    pub solve_total_s: f64,
    /// Grid points whose cross-fold mean MSE came out non-finite (diverged
    /// solve, degenerate fold). They are skipped in the [`Self::best`]
    /// selection instead of poisoning it; a nonzero count is the caller's
    /// cue to inspect the grid.
    pub nonfinite_points: usize,
}

/// Split `n` samples into `k` folds (seeded permutation).
pub fn make_folds(n: usize, k: usize, seed: u64) -> Vec<Vec<usize>> {
    assert!(k >= 2 && k <= n, "need 2 ≤ k ≤ n");
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Rng::seed_from_u64(seed);
    rng.shuffle(&mut idx);
    let mut folds = vec![Vec::new(); k];
    for (i, &s) in idx.iter().enumerate() {
        folds[i % k].push(s);
    }
    folds
}

/// Per-task result of one fold×α screened path walk.
struct FoldAlphaResult {
    /// Held-out MSE per λ grid point.
    mse: Vec<f64>,
    /// Nonzero count per λ grid point.
    nnz: Vec<f64>,
    screen_s: f64,
    solve_s: f64,
}

/// Train/test split of one fold, extracted once before the fan-out.
struct FoldData<M> {
    x_train: M,
    y_train: Vec<f32>,
    x_test: M,
    y_test: Vec<f32>,
}

/// Run k-fold CV over `alphas` with TLFre-screened paths, sharding the
/// fold×α path tasks across the persistent worker pool. Works over any
/// backend that supports fold extraction ([`SelectRows`]: dense and CSC).
///
/// Output is bitwise identical to [`cross_validate_serial`] at every
/// worker count (see the module docs for why).
pub fn cross_validate<M: DesignMatrix + SelectRows>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    alphas: &[f64],
    k_folds: usize,
    base_cfg: &PathConfig,
    seed: u64,
) -> CvOutput {
    cross_validate_with_workers(x, y, groups, alphas, k_folds, base_cfg, seed, pool::num_threads())
}

/// The serial reference sweep: identical output, one fold×α path at a
/// time on the calling thread. Kept public for A/B parity tests and the
/// `perf_kernels` before/after bench.
pub fn cross_validate_serial<M: DesignMatrix + SelectRows>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    alphas: &[f64],
    k_folds: usize,
    base_cfg: &PathConfig,
    seed: u64,
) -> CvOutput {
    cross_validate_with_workers(x, y, groups, alphas, k_folds, base_cfg, seed, 1)
}

/// [`cross_validate`] with an explicit worker count (the parity tests
/// sweep it; production callers use the `TLFRE_THREADS`-derived default).
#[allow(clippy::too_many_arguments)]
pub fn cross_validate_with_workers<M: DesignMatrix + SelectRows>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    alphas: &[f64],
    k_folds: usize,
    base_cfg: &PathConfig,
    seed: u64,
    workers: usize,
) -> CvOutput {
    base_cfg.validate();
    assert!(!alphas.is_empty(), "need at least one alpha");
    let n = x.rows();
    // k > n would leave empty folds (and 0/0 NaN fold MSEs downstream);
    // make_folds holds the same invariant, re-asserted here so the message
    // names the CV entry point's arguments.
    assert!(
        k_folds >= 2 && k_folds <= n,
        "need 2 ≤ k_folds ≤ n samples (got k_folds={k_folds}, n={n})"
    );
    let folds = make_folds(n, k_folds, seed);
    let n_lambda = base_cfg.n_lambda;

    // Fold extraction runs once, serially, before the fan-out — each
    // fold's train/test split is shared by all of its α tasks (and by
    // concurrently running workers, which is why all k splits are
    // materialized upfront: peak memory is ~k× the design matrix for the
    // duration of the CV run, the price of sharing splits across the
    // fold×α fan-out without re-extracting per task).
    let fold_data: Vec<FoldData<M>> = folds
        .iter()
        .map(|fold| {
            let in_fold: std::collections::BTreeSet<usize> = fold.iter().copied().collect();
            let train_rows: Vec<usize> = (0..n).filter(|i| !in_fold.contains(i)).collect();
            FoldData {
                x_train: x.select_rows(&train_rows),
                y_train: train_rows.iter().map(|&i| y[i]).collect(),
                x_test: x.select_rows(fold),
                y_test: fold.iter().map(|&i| y[i]).collect(),
            }
        })
        .collect();

    // Fold-major task order — the serial sweep's loop order. The pooled
    // map preserves item order and the accumulation below replays it, so
    // the sharded output is bitwise identical to the serial sweep.
    let tasks: Vec<(usize, usize)> = (0..folds.len())
        .flat_map(|fi| (0..alphas.len()).map(move |ai| (fi, ai)))
        .collect();
    let results: Vec<FoldAlphaResult> =
        pool::parallel_map_with_workers(&tasks, workers, |&(fi, ai)| {
            let fd = &fold_data[fi];
            let cfg = PathConfig { alpha: alphas[ai], ..base_cfg.clone() };
            // ONE screened walk: per-task spectral/coloring caches are
            // built once inside the engine (projected per reduced problem)
            // and the holdout sink consumes each step's β as it streams.
            let mut sink = HoldoutSink::new(&fd.x_test, &fd.y_test[..]);
            let totals = drive_tlfre_path(&fd.x_train, &fd.y_train, groups, &cfg, &mut sink);
            FoldAlphaResult {
                mse: sink.mse,
                nnz: sink.nnz,
                screen_s: totals.screen_total_s,
                solve_s: totals.solve_total_s,
            }
        });

    // mse[alpha_idx][lambda_idx] accumulated over folds, in task order.
    let mut mse = vec![vec![0.0f64; n_lambda]; alphas.len()];
    let mut nnz = vec![vec![0.0f64; n_lambda]; alphas.len()];
    let mut screen_total = 0.0f64;
    let mut solve_total = 0.0f64;
    for (&(_, ai), res) in tasks.iter().zip(&results) {
        debug_assert_eq!(res.mse.len(), n_lambda);
        screen_total += res.screen_s;
        solve_total += res.solve_s;
        for li in 0..n_lambda {
            mse[ai][li] += res.mse[li];
            nnz[ai][li] += res.nnz[li];
        }
    }

    let kf = folds.len() as f64;
    let mut points = Vec::with_capacity(alphas.len() * n_lambda);
    for (ai, &alpha) in alphas.iter().enumerate() {
        for li in 0..n_lambda {
            points.push(CvPoint {
                alpha,
                lambda_ratio: ratio_at(li, n_lambda, base_cfg.lambda_min_ratio),
                mse: mse[ai][li] / kf,
                mean_nnz: nnz[ai][li] / kf,
            });
        }
    }
    let (best, nonfinite_points) = select_best(&points);
    CvOutput {
        points,
        best,
        screen_total_s: screen_total,
        solve_total_s: solve_total,
        nonfinite_points,
    }
}

/// Model selection over the grid: minimum mean MSE among **finite** points
/// (ordered by [`f64::total_cmp`]), with the count of skipped non-finite
/// points surfaced. A single NaN fold MSE used to panic the old
/// `partial_cmp(..).unwrap()` selection; now it can only cost its own grid
/// point. Falls back to the first grid point if nothing is finite.
fn select_best(points: &[CvPoint]) -> (CvPoint, usize) {
    assert!(!points.is_empty(), "nonempty grid");
    let nonfinite = points.iter().filter(|p| !p.mse.is_finite()).count();
    if nonfinite > 0 {
        crate::util::logger::warn(
            "cv",
            &format!("{nonfinite}/{} grid points have non-finite MSE; skipped", points.len()),
        );
    }
    let finite_min =
        points.iter().filter(|p| p.mse.is_finite()).min_by(|a, b| a.mse.total_cmp(&b.mse));
    let best = match finite_min {
        Some(p) => p.clone(),
        None => points[0].clone(),
    };
    (best, nonfinite)
}

/// λ/λmax at grid index `i` for a log grid with the given floor. The
/// single-point grid (`k == 1`) is the λmax endpoint alone — ratio 1.0
/// (the old `(k − 1)`-denominator form divided by zero there and returned
/// NaN).
fn ratio_at(i: usize, k: usize, min_ratio: f64) -> f64 {
    if k <= 1 {
        return 1.0;
    }
    (min_ratio.ln() * i as f64 / (k - 1) as f64).exp()
}

/// Re-run a screened path, returning the coefficient vector at every λ.
///
/// A [`CoefficientSink`] over the same streaming driver the runner uses —
/// per-step lockstep with `run_tlfre_path` (solver dispatch, spectral
/// cache, refresh schedule, everything) holds by construction.
pub fn path_coefficients<M: DesignMatrix>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    cfg: &PathConfig,
) -> Vec<Vec<f32>> {
    let mut sink = CoefficientSink::new();
    drive_tlfre_path(x, y, groups, cfg, &mut sink);
    sink.betas
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::runner::{run_tlfre_path, SolveControls, SolverKind};
    use crate::data::synthetic::{generate_synthetic, SyntheticSpec};
    use crate::linalg::ops;

    #[test]
    fn folds_partition_samples() {
        let folds = make_folds(23, 4, 1);
        assert_eq!(folds.len(), 4);
        let mut all: Vec<usize> = folds.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..23).collect::<Vec<_>>());
        // balanced within 1
        let sizes: Vec<usize> = folds.iter().map(|f| f.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn cv_picks_sensible_lambda() {
        // Planted sparse model: CV should prefer an interior λ (not the
        // densest end with overfitting noise, not λmax with β = 0).
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(60, 200, 20), 401);
        let cfg = PathConfig {
            controls: SolveControls {
                n_lambda: 12,
                lambda_min_ratio: 0.01,
                tol: 1e-5,
                ..Default::default()
            },
            ..Default::default()
        };
        let out = cross_validate(&ds.x, &ds.y, &ds.groups, &[0.5, 1.0], 3, &cfg, 7);
        assert_eq!(out.points.len(), 2 * 12);
        assert_eq!(out.nonfinite_points, 0);
        assert!(out.best.lambda_ratio < 1.0, "best at λmax (underfit)");
        assert!(out.best.mse.is_finite());
        // The best model recovers roughly the planted sparsity order.
        assert!(out.best.mean_nnz >= 1.0);
        assert!(out.best.mean_nnz < 150.0);
    }

    #[test]
    fn single_point_grid_has_ratio_one_not_nan() {
        // n_lambda == 1 used to divide by (k − 1) == 0 in ratio_at.
        assert_eq!(ratio_at(0, 1, 0.01), 1.0);
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(24, 80, 8), 404);
        let cfg = PathConfig {
            controls: SolveControls {
                n_lambda: 1,
                lambda_min_ratio: 0.1,
                ..Default::default()
            },
            ..Default::default()
        };
        let out = cross_validate_serial(&ds.x, &ds.y, &ds.groups, &[1.0], 3, &cfg, 5);
        assert_eq!(out.points.len(), 1);
        assert_eq!(out.points[0].lambda_ratio, 1.0);
        assert!(out.points[0].mse.is_finite(), "λmax MSE is the null-model MSE");
        assert_eq!(out.points[0].mean_nnz, 0.0, "β ≡ 0 at λmax");
        assert_eq!(out.nonfinite_points, 0);
    }

    #[test]
    fn non_finite_points_do_not_poison_selection() {
        let mk = |mse: f64| CvPoint { alpha: 1.0, lambda_ratio: 0.5, mse, mean_nnz: 1.0 };
        // NaN and +inf points are skipped, not selected — and not panicked
        // on (the old partial_cmp(..).unwrap() died here).
        let pts = vec![mk(f64::NAN), mk(0.25), mk(f64::INFINITY), mk(0.75)];
        let (best, nonfinite) = select_best(&pts);
        assert_eq!(best.mse, 0.25);
        assert_eq!(nonfinite, 2);
        // All-non-finite grid: fall back to the first point, count = all.
        let pts = vec![mk(f64::NAN), mk(f64::NAN)];
        let (best, nonfinite) = select_best(&pts);
        assert!(best.mse.is_nan());
        assert_eq!(nonfinite, 2);
    }

    #[test]
    fn path_coefficients_matches_runner_sparsity() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 120, 12), 402);
        let cfg = PathConfig {
            controls: SolveControls {
                n_lambda: 8,
                lambda_min_ratio: 0.05,
                tol: 1e-6,
                ..Default::default()
            },
            ..Default::default()
        };
        let betas = path_coefficients(&ds.x, &ds.y, &ds.groups, &cfg);
        let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
        assert_eq!(betas.len(), out.steps.len());
        for (b, s) in betas.iter().zip(&out.steps) {
            let nnz = b.len() - ops::count_zeros(b);
            assert_eq!(nnz, s.nonzeros, "λ={}", s.lambda);
        }
    }

    #[test]
    fn path_coefficients_honors_bcd_solver() {
        // Regression: `path_coefficients` used to hardcode FISTA while the
        // runner dispatched on `cfg.solver`, so a BCD-configured CV
        // silently evaluated a different solver's path than the one the
        // runner reported. The BCD walk must now stay in per-step sparsity
        // lockstep with `run_tlfre_path` under the same config.
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 120, 12), 403);
        let cfg = PathConfig {
            solver: SolverKind::Bcd,
            controls: SolveControls {
                n_lambda: 8,
                lambda_min_ratio: 0.05,
                tol: 1e-6,
                ..Default::default()
            },
            ..Default::default()
        };
        let betas = path_coefficients(&ds.x, &ds.y, &ds.groups, &cfg);
        let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
        assert_eq!(betas.len(), out.steps.len());
        for (b, s) in betas.iter().zip(&out.steps) {
            let nnz = b.len() - ops::count_zeros(b);
            assert_eq!(nnz, s.nonzeros, "BCD lockstep broke at λ={}", s.lambda);
        }
        // The refresh schedule must stay mirrored for BCD too.
        let refresh_cfg = {
            let mut c = cfg;
            c.lipschitz_refresh_every = Some(2);
            c
        };
        let betas_r = path_coefficients(&ds.x, &ds.y, &ds.groups, &refresh_cfg);
        let out_r = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &refresh_cfg);
        for (b, s) in betas_r.iter().zip(&out_r.steps) {
            let nnz = b.len() - ops::count_zeros(b);
            assert_eq!(nnz, s.nonzeros, "BCD refresh lockstep broke at λ={}", s.lambda);
        }
    }
}
