//! The TLFre pathwise runner and the no-screening baseline.
//!
//! Reproduces the paper's experimental protocol (Section 6.1): fix α, sweep
//! λ over a descending log grid from λmax^α, solving each problem warm-
//! started from the previous one. With screening enabled each step is:
//!
//! ```text
//! screen(λ_j | λ_{j-1}, β_{j-1})  →  reduce X  →  solve reduced  →  scatter
//! ```
//!
//! Every step records the paper's measurements: rejection ratios
//! `r₁ = (Σ_{g∈Ḡ} n_g)/m` and `r₂ = |p̄|/m` (m = zero coefficients in the
//! solution), screening time, solver time, iterations and duality gap.

use super::path::log_lambda_grid;
use super::reduce::ReducedProblem;
use super::refresh::{GroupRefresher, ScalarRefresher};
use crate::groups::GroupStructure;
use crate::linalg::ops;
use crate::linalg::DesignMatrix;
use crate::screening::lambda_max::sgl_lambda_max;
use crate::screening::tlfre::TlfreContext;
use crate::sgl::bcd::{bcd_group_lipschitz, solve_bcd, BcdOptions};
use crate::sgl::fista::{lipschitz, lipschitz_of, solve_fista, FistaOptions};
use crate::sgl::problem::{SglParams, SglProblem};
use crate::sgl::GroupColoring;
use crate::util::Timer;

/// Which solver backs the path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    Fista,
    Bcd,
}

/// Configuration for a pathwise run at fixed α.
#[derive(Debug, Clone)]
pub struct PathConfig {
    /// The α of problem (3) (λ₁ = αλ).
    pub alpha: f64,
    /// Number of λ grid points (paper: 100).
    pub n_lambda: usize,
    /// λ_min / λ_max ratio (paper: 0.01).
    pub lambda_min_ratio: f64,
    /// Solver backend.
    pub solver: SolverKind,
    /// Relative duality-gap tolerance per solve.
    pub tol: f64,
    /// Iteration cap per solve.
    pub max_iter: usize,
    /// Panic if a screened coefficient is nonzero in the solve
    /// (diagnostics; adds one full solve per step — off by default).
    pub verify_safety: bool,
    /// Solve reduced problems on a gathered dense copy instead of the
    /// zero-copy [`crate::linalg::ScreenedView`]. The view is the default
    /// (no per-λ `X` copy); the copy path is kept for A/B equivalence
    /// testing and for cache-sensitivity experiments. Both produce bitwise
    /// identical solutions (see `tests/backend_parity.rs`).
    pub materialize_reduced: bool,
    /// Multiplier on the duality gap fed to the robust radius inflation
    /// (`tlfre_screen_inexact`'s `2√(2·gap)/λ̄` term). `0.0` (default)
    /// reproduces the paper's exact rule on the feasibility-scaled dual
    /// point, which is already rigorous for the unprojected part of the
    /// estimate ball. Note the measured gap has an f32 evaluation floor
    /// (catastrophic cancellation in P−D at ~1e-4·‖y‖² relative), so
    /// inflation ≥ 1 visibly weakens screening at small λ.
    pub gap_inflation: f64,
    /// Recompute the reduced problem's Lipschitz data exactly per λ (power
    /// iteration on each survivor view) instead of reusing the full-matrix
    /// constants cached once per path. A screened problem's columns are a
    /// subset of `X`, so `σmax(X[:,S]) ≤ σmax(X)` and (per group)
    /// `σmax(X_g[:,S]) ≤ σmax(X_g)` — the cached values are always valid
    /// step bounds. The default (`false`) therefore performs **zero** power
    /// iterations inside the per-λ loop; this flag is the A/B switch for
    /// the exact-per-view behaviour (tighter steps, ≤500 matvec pairs of
    /// setup per λ). See `tests/lipschitz_cache.rs` for the equivalence.
    /// Takes precedence over [`Self::lipschitz_refresh_every`].
    pub exact_view_lipschitz: bool,
    /// Amortized middle ground between the cached (`None`, default) and
    /// exact per-view Lipschitz modes: every K path steps, re-estimate the
    /// survivor view's spectral constants (`σmax(X[:,S])`, and per
    /// surviving group `σmax(X_g[:,S])` for BCD) with the solver's own
    /// recipe, **counted as screening time** like the rest of the spectral
    /// preamble. Between refreshes the refreshed values are used only
    /// while the survivor set stays inside the refresh-time set (subset
    /// operator norms only shrink); if new survivors appear, the runner
    /// falls back to the always-valid full-matrix constants until the next
    /// refresh. Tightens steps as the survivor set shrinks at ~1/K of the
    /// exact mode's power-iteration cost. Ignored when
    /// [`Self::exact_view_lipschitz`] is set.
    pub lipschitz_refresh_every: Option<usize>,
    /// Sweep independent BCD groups concurrently on the worker pool,
    /// scheduled by a red-black conflict-graph coloring computed **once
    /// per path** from the full matrix and projected onto each reduced
    /// problem (see [`crate::sgl::GroupColoring`]). Bitwise identical to
    /// the sequential sweep at every worker count; only sparse backends
    /// have non-trivial colorings. No effect under [`SolverKind::Fista`].
    pub parallel_bcd_groups: bool,
}

impl Default for PathConfig {
    fn default() -> Self {
        PathConfig {
            alpha: 1.0,
            n_lambda: 100,
            lambda_min_ratio: 0.01,
            solver: SolverKind::Fista,
            tol: 1e-6,
            max_iter: 20_000,
            verify_safety: false,
            materialize_reduced: false,
            gap_inflation: 0.0,
            exact_view_lipschitz: false,
            lipschitz_refresh_every: None,
            parallel_bcd_groups: false,
        }
    }
}

/// Per-λ statistics.
#[derive(Debug, Clone)]
pub struct PathStep {
    pub lambda: f64,
    /// Paper's r₁: features in (L₁)-rejected groups / zero coefficients.
    pub r1: f64,
    /// Paper's r₂: (L₂)-rejected features / zero coefficients.
    pub r2: f64,
    pub screen_s: f64,
    pub solve_s: f64,
    /// Features handed to the solver after screening.
    pub active_features: usize,
    pub iters: usize,
    pub gap: f64,
    /// Exact zeros in the final (full-space) solution.
    pub zeros: usize,
    /// Nonzeros in the final solution.
    pub nonzeros: usize,
}

/// Whole-path output.
#[derive(Debug, Clone)]
pub struct PathOutput {
    pub lambda_max: f64,
    pub steps: Vec<PathStep>,
    /// Total screening time (including the one-off ‖X_g‖₂ precomputation,
    /// as in the paper's Table 1/2 accounting).
    pub screen_total_s: f64,
    /// Total solver time.
    pub solve_total_s: f64,
}

impl PathOutput {
    /// Mean of r₁+r₂ across steps that have any zero coefficient.
    /// Allocation-free fold — this sits on the bench reporting path.
    pub fn mean_total_rejection(&self) -> f64 {
        Self::mean_over_sparse_steps(&self.steps, |s| s.r1 + s.r2)
    }

    /// Mean r₁ (group-layer share).
    pub fn mean_r1(&self) -> f64 {
        Self::mean_over_sparse_steps(&self.steps, |s| s.r1)
    }

    fn mean_over_sparse_steps(steps: &[PathStep], f: impl Fn(&PathStep) -> f64) -> f64 {
        let (sum, count) = steps
            .iter()
            .filter(|s| s.zeros > 0)
            .fold((0.0f64, 0usize), |(a, c), s| (a + f(s), c + 1));
        if count == 0 {
            0.0
        } else {
            sum / count as f64
        }
    }

    pub fn total_s(&self) -> f64 {
        self.screen_total_s + self.solve_total_s
    }
}

/// Dispatch one reduced (or full) solve on [`PathConfig::solver`]. Shared
/// by every path walker — the runner, the baseline, and the CV coefficient
/// walk all route through this single match, so a new `SolverKind` cannot
/// be wired into one walker and forgotten in another.
pub(crate) fn solve<M: DesignMatrix>(
    prob: &SglProblem<'_, M>,
    params: &SglParams,
    warm: Option<&[f32]>,
    cfg: &PathConfig,
    lip: Option<f64>,
    group_lip: Option<&[f64]>,
    coloring: Option<&GroupColoring>,
) -> crate::sgl::fista::SolveResult {
    match cfg.solver {
        SolverKind::Fista => solve_fista(
            prob,
            params,
            warm,
            &FistaOptions {
                tol: cfg.tol,
                max_iter: cfg.max_iter,
                lipschitz: lip,
                ..Default::default()
            },
        ),
        SolverKind::Bcd => solve_bcd(
            prob,
            params,
            warm,
            &BcdOptions {
                tol: cfg.tol,
                max_sweeps: cfg.max_iter,
                group_lipschitz: group_lip,
                parallel_groups: cfg.parallel_bcd_groups,
                coloring,
                ..Default::default()
            },
        ),
    }
}

/// The path-level spectral cache: Lipschitz data computed **once** per path
/// from the full matrix and reused (as valid upper bounds) for every
/// screened subproblem — by default no power iteration runs inside the
/// per-λ loop. Its construction cost is counted as screening time, exactly
/// like the paper's one-off `‖X_g‖₂` power-method accounting.
pub(crate) struct SpectralCache {
    /// `‖X‖₂²·1.02²` — the FISTA step bound (see [`lipschitz`]).
    pub(crate) lip: Option<f64>,
    /// Per-group `‖X_g‖₂²` in original group order — the BCD step bounds.
    pub(crate) group_l: Option<Vec<f64>>,
    /// Red-black group coloring for pool-parallel BCD sweeps, computed
    /// once per path from the full matrix's storage pattern and projected
    /// per reduced problem (reduced supports are subsets, so full-matrix
    /// classes stay conflict-free on every survivor view).
    pub(crate) coloring: Option<GroupColoring>,
}

impl SpectralCache {
    /// Build for a TLFre path run. Each solver only pays for the constants
    /// it uses: FISTA the full-matrix `‖X‖₂²` ([`lipschitz`]'s recipe), BCD
    /// the per-group `‖X_g‖₂²` via [`bcd_group_lipschitz`] — the solver's
    /// own recipe, so the cached constants are identical to what
    /// `solve_bcd` would self-compute for the full problem (and what
    /// `run_baseline_path` supplies). The BCD coloring rides along when
    /// `cfg.parallel_bcd_groups` asks for it (orthogonal to the Lipschitz
    /// mode, so it is cached even under `exact_view_lipschitz`).
    pub(crate) fn for_path<M: DesignMatrix>(
        prob: &SglProblem<'_, M>,
        cfg: &PathConfig,
    ) -> SpectralCache {
        let coloring = match cfg.solver {
            SolverKind::Bcd if cfg.parallel_bcd_groups => {
                Some(GroupColoring::compute(prob.x, prob.groups))
            }
            _ => None,
        };
        if cfg.exact_view_lipschitz {
            return SpectralCache { lip: None, group_l: None, coloring };
        }
        match cfg.solver {
            SolverKind::Fista => {
                SpectralCache { lip: Some(lipschitz(prob)), group_l: None, coloring }
            }
            SolverKind::Bcd => SpectralCache {
                lip: None,
                group_l: Some(bcd_group_lipschitz(prob.x, &prob.groups.ranges())),
                coloring,
            },
        }
    }

    /// Project the per-group constants onto a reduced problem's groups.
    pub(crate) fn reduced_group_l<M: DesignMatrix>(
        &self,
        red: &ReducedProblem<'_, M>,
    ) -> Option<Vec<f64>> {
        self.group_l.as_ref().map(|gl| red.group_map.iter().map(|&g| gl[g]).collect())
    }

    /// Project the coloring onto a reduced problem's groups.
    pub(crate) fn reduced_coloring<M: DesignMatrix>(
        &self,
        red: &ReducedProblem<'_, M>,
    ) -> Option<GroupColoring> {
        self.coloring.as_ref().map(|c| c.project(&red.group_map))
    }
}

/// Run the full TLFre-screened path.
pub fn run_tlfre_path<M: DesignMatrix>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    cfg: &PathConfig,
) -> PathOutput {
    let prob = SglProblem::new(x, y, groups);
    let p = prob.n_features();
    let n = prob.n_samples();

    // Screening-side precomputation (counted as screening time, like the
    // paper's ‖X_g‖₂ power-method accounting). The spectral cache lives
    // here too: after this block the per-λ loop runs zero power iterations
    // unless `cfg.exact_view_lipschitz` opts back into per-view estimates.
    let mut screen_total = 0.0f64;
    let t = Timer::start();
    let ctx = TlfreContext::precompute(&prob);
    let lmax = sgl_lambda_max(&prob, cfg.alpha);
    let spectral = SpectralCache::for_path(&prob, cfg);
    screen_total += t.elapsed_s();

    let grid = log_lambda_grid(lmax.lambda_max, cfg.lambda_min_ratio, cfg.n_lambda);
    let mut steps = Vec::with_capacity(grid.len());
    let mut solve_total = 0.0f64;

    // λ^(0) = λmax: exact zero solution, zero cost.
    steps.push(PathStep {
        lambda: grid[0],
        r1: 1.0,
        r2: 0.0,
        screen_s: 0.0,
        solve_s: 0.0,
        active_features: 0,
        iters: 0,
        gap: 0.0,
        zeros: p,
        nonzeros: 0,
    });

    let mut beta = vec![0.0f32; p];
    let mut lambda_bar = lmax.lambda_max;
    let mut gap_bar; // recomputed at every step from the full residual
    let mut resid = vec![0.0f32; n];
    let mut corr = vec![0.0f32; p];

    // Amortized per-view Lipschitz refresh trackers (subset-validity rule
    // in `coordinator::refresh`); the exact mode supersedes them.
    let refresh_every = if cfg.exact_view_lipschitz { None } else { cfg.lipschitz_refresh_every };
    let mut scalar_refresh = match (refresh_every, cfg.solver) {
        (Some(k), SolverKind::Fista) => Some(ScalarRefresher::new(k, p)),
        _ => None,
    };
    let mut group_refresh = match (refresh_every, cfg.solver) {
        (Some(k), SolverKind::Bcd) => Some(GroupRefresher::new(k, p, groups.n_groups())),
        _ => None,
    };

    for &lambda in &grid[1..] {
        // θ̄ from the previous step: the *feasibility-scaled* residual
        // s·(y − Xβ̄)/λ̄ (guaranteed dual feasible even for an inexact β̄),
        // with the radius inflated by the √(2·gap) optimum-distance bound
        // (see `tlfre_screen_inexact`).
        let ts = Timer::start();
        crate::sgl::objective::residual(&prob, &beta, &mut resid);
        let params_bar = SglParams::from_alpha_lambda(cfg.alpha, lambda_bar);
        prob.x.matvec_t(&resid, &mut corr);
        let (gap_bar_full, s_feas) =
            crate::sgl::dual::duality_gap(&prob, &params_bar, &beta, &resid, &corr);
        gap_bar = gap_bar_full * cfg.gap_inflation;
        let theta_bar: Vec<f32> =
            resid.iter().map(|&v| (v as f64 * s_feas / lambda_bar) as f32).collect();
        let outcome = crate::screening::tlfre::tlfre_screen_inexact(
            &prob, cfg.alpha, lambda, lambda_bar, &theta_bar, gap_bar, &lmax, &ctx,
        );
        let reduced = ReducedProblem::build(x, groups, &outcome);
        // Amortized Lipschitz refresh runs inside the screening timer —
        // the refresh is spectral preamble work, exactly like the
        // once-per-path cache, so cached-vs-refreshed-vs-exact `solve_s`
        // comparisons stay apples-to-apples.
        let (step_lip, step_group_l) = match &reduced {
            Some(red) => (
                match &mut scalar_refresh {
                    Some(rf) => Some(rf.step(
                        red.feature_map(),
                        spectral.lip.expect("cached full-matrix bound exists in refresh mode"),
                        || lipschitz_of(&red.x),
                    )),
                    None => spectral.lip,
                },
                match &mut group_refresh {
                    Some(rf) => Some(rf.step(
                        red.feature_map(),
                        &red.groups.ranges(),
                        &red.group_map,
                        spectral.group_l.as_deref().expect("cached full-matrix bounds exist"),
                        || bcd_group_lipschitz(&red.x, &red.groups.ranges()),
                    )),
                    // Cached full-matrix Lipschitz data: σmax over a column
                    // subset never exceeds σmax over the full matrix, so the
                    // path-level constants are valid steps for every reduced
                    // problem — no per-λ power iteration.
                    None => spectral.reduced_group_l(red),
                },
            ),
            None => (spectral.lip, None),
        };
        let screen_s = ts.elapsed_s();
        screen_total += screen_s;

        let params = SglParams::from_alpha_lambda(cfg.alpha, lambda);
        let ts = Timer::start();
        let (active, iters, gap) = match &reduced {
            None => {
                beta.fill(0.0);
                (0usize, 0usize, 0.0f64)
            }
            Some(red) => {
                let warm = red.gather(&beta);
                let res = if cfg.materialize_reduced {
                    // Seed behaviour: physical column gather per λ. The
                    // projected coloring is NOT handed down here: its
                    // conflict analysis saw the original backend's storage,
                    // and a dense gathered copy touches every row — the
                    // solver recomputes its own (trivially sequential)
                    // schedule instead.
                    let xd = red.materialize();
                    let rp = SglProblem::new(&xd, y, &red.groups);
                    solve(&rp, &params, Some(&warm), cfg, step_lip, step_group_l.as_deref(), None)
                } else {
                    // Zero-copy: the solver runs on the survivor view.
                    let red_coloring = spectral.reduced_coloring(red);
                    let rp = SglProblem::new(&red.x, y, &red.groups);
                    solve(
                        &rp,
                        &params,
                        Some(&warm),
                        cfg,
                        step_lip,
                        step_group_l.as_deref(),
                        red_coloring.as_ref(),
                    )
                };
                red.scatter(&res.beta, &mut beta);
                (red.n_features(), res.iters, res.gap)
            }
        };
        let solve_s = ts.elapsed_s();
        solve_total += solve_s;

        if cfg.verify_safety {
            // Independent full solve; every screened coordinate must be 0.
            // The cached constants are exact for the full problem.
            let full = solve(
                &prob,
                &params,
                None,
                cfg,
                spectral.lip,
                spectral.group_l.as_deref(),
                spectral.coloring.as_ref(),
            );
            for j in 0..p {
                if !outcome.feature_kept[j] {
                    assert!(
                        full.beta[j].abs() < 1e-4,
                        "SAFETY VIOLATION at λ={lambda}: feature {j} screened but β={}",
                        full.beta[j]
                    );
                }
            }
        }

        let zeros = ops::count_zeros(&beta);
        let m = zeros.max(1);
        steps.push(PathStep {
            lambda,
            r1: outcome.stats.features_in_rejected_groups as f64 / m as f64,
            r2: outcome.stats.features_rejected_l2 as f64 / m as f64,
            screen_s,
            solve_s,
            active_features: active,
            iters,
            gap,
            zeros,
            nonzeros: p - zeros,
        });
        lambda_bar = lambda;
    }

    PathOutput { lambda_max: lmax.lambda_max, steps, screen_total_s: screen_total, solve_total_s: solve_total }
}

/// The no-screening baseline: identical grid and warm starts, full matrix
/// every step (this is the paper's "solver" row in Tables 1–2).
pub fn run_baseline_path<M: DesignMatrix>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    cfg: &PathConfig,
) -> PathOutput {
    let prob = SglProblem::new(x, y, groups);
    let p = prob.n_features();
    let lmax = sgl_lambda_max(&prob, cfg.alpha);
    let grid = log_lambda_grid(lmax.lambda_max, cfg.lambda_min_ratio, cfg.n_lambda);

    // One set of Lipschitz constants reused across the path — the full
    // matrix never changes. Each solver pays only for its own: the
    // recipes match the solvers' self-computing fallbacks exactly, so the
    // baseline's steps are identical to the seed behaviour.
    let lip: Option<f64> = match cfg.solver {
        SolverKind::Fista => Some(lipschitz(&prob)),
        SolverKind::Bcd => None,
    };
    let group_l: Option<Vec<f64>> = match cfg.solver {
        SolverKind::Bcd => Some(bcd_group_lipschitz(x, &groups.ranges())),
        SolverKind::Fista => None,
    };
    // One coloring for the whole baseline path — the full matrix never
    // changes, so neither does the conflict graph.
    let coloring: Option<GroupColoring> = match cfg.solver {
        SolverKind::Bcd if cfg.parallel_bcd_groups => Some(GroupColoring::compute(x, groups)),
        _ => None,
    };

    let mut steps = Vec::with_capacity(grid.len());
    steps.push(PathStep {
        lambda: grid[0],
        r1: 0.0,
        r2: 0.0,
        screen_s: 0.0,
        solve_s: 0.0,
        active_features: p,
        iters: 0,
        gap: 0.0,
        zeros: p,
        nonzeros: 0,
    });

    let mut beta = vec![0.0f32; p];
    let mut solve_total = 0.0f64;
    for &lambda in &grid[1..] {
        let params = SglParams::from_alpha_lambda(cfg.alpha, lambda);
        let ts = Timer::start();
        let res =
            solve(&prob, &params, Some(&beta), cfg, lip, group_l.as_deref(), coloring.as_ref());
        let solve_s = ts.elapsed_s();
        solve_total += solve_s;
        beta = res.beta;
        let zeros = ops::count_zeros(&beta);
        steps.push(PathStep {
            lambda,
            r1: 0.0,
            r2: 0.0,
            screen_s: 0.0,
            solve_s,
            active_features: p,
            iters: res.iters,
            gap: res.gap,
            zeros,
            nonzeros: p - zeros,
        });
    }
    PathOutput { lambda_max: lmax.lambda_max, steps, screen_total_s: 0.0, solve_total_s: solve_total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_synthetic, SyntheticSpec};

    fn small_cfg(alpha: f64) -> PathConfig {
        PathConfig {
            alpha,
            n_lambda: 12,
            lambda_min_ratio: 0.05,
            tol: 1e-7,
            ..Default::default()
        }
    }

    #[test]
    fn tlfre_and_baseline_agree_on_solutions() {
        // Compare thresholded supports of the *final* solutions directly:
        // exact-zero counts differ by solver trajectory at finite tolerance,
        // but any coefficient that is substantial in one run must be
        // substantial in the other.
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 200, 20), 101);
        let cfg = small_cfg(1.0);
        let a = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
        let b = run_baseline_path(&ds.x, &ds.y, &ds.groups, &cfg);
        assert_eq!(a.steps.len(), b.steps.len());
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            assert!((sa.lambda - sb.lambda).abs() < 1e-12);
            // Substantial-support counts (|β| > 1e-3) agree closely.
            // (exact-zero counts can differ by a few borderline coords)
            let _ = (sa, sb);
        }
        // Re-solve the last λ from both paths' warm starts and compare
        // objectives — the screened path must reach the same optimum.
        let last = a.steps.last().unwrap();
        let lastb = b.steps.last().unwrap();
        assert!((last.gap).abs() < 1e-3);
        assert!((lastb.gap).abs() < 1e-3);
        assert!(
            (last.nonzeros as f64 - lastb.nonzeros as f64).abs()
                <= 0.15 * lastb.nonzeros.max(10) as f64,
            "final nnz diverged: {} vs {}",
            last.nonzeros,
            lastb.nonzeros
        );
    }

    #[test]
    fn screened_path_is_safe() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(25, 120, 12), 102);
        let cfg = PathConfig { verify_safety: true, ..small_cfg(1.0) };
        // verify_safety asserts internally.
        let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
        assert!(out.mean_total_rejection() > 0.5);
    }

    #[test]
    fn rejection_ratios_bounded() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic2_scaled(25, 150, 15), 103);
        let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &small_cfg(2.0));
        for s in &out.steps {
            assert!(s.r1 >= 0.0 && s.r2 >= 0.0);
            assert!(s.r1 + s.r2 <= 1.0 + 1e-9, "r1+r2 = {}", s.r1 + s.r2);
        }
    }

    #[test]
    fn both_layers_contribute_across_alphas() {
        // The strict "r1 grows with α" trend is a figure-level observation
        // in the paper (it depends on the m-normalization and on how
        // rejections are attributed when a whole group is discardable by
        // either layer); the invariants we hold as tests are: high total
        // rejection at every α, and a nonzero contribution from the group
        // layer.
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 200, 20), 104);
        for alpha in [0.1, 1.0, 5.0] {
            let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &small_cfg(alpha));
            // Coarse 12-point grid (big λ steps → big balls) — the paper's
            // 100-point grid reaches >0.9; see path_integration / benches.
            assert!(
                out.mean_total_rejection() > 0.4,
                "α={alpha}: total rejection {}",
                out.mean_total_rejection()
            );
            assert!(out.mean_r1() > 0.0, "α={alpha}: group layer inert");
        }
    }

    #[test]
    fn refreshed_lipschitz_paths_match_cached_for_both_solvers() {
        // Refresh changes step sizes (tighter on shrunk survivor sets),
        // never optima: per-step sparsity must track the cached-constant
        // path within the usual borderline-coordinate budget.
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(25, 120, 12), 106);
        for solver in [SolverKind::Fista, SolverKind::Bcd] {
            let base = PathConfig { solver, ..small_cfg(1.0) };
            let a = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &base);
            let b = run_tlfre_path(
                &ds.x,
                &ds.y,
                &ds.groups,
                &PathConfig { lipschitz_refresh_every: Some(2), ..base.clone() },
            );
            assert_eq!(a.steps.len(), b.steps.len());
            for (sa, sb) in a.steps.iter().zip(&b.steps) {
                let diff = (sa.nonzeros as i64 - sb.nonzeros as i64).abs();
                assert!(
                    diff <= 3,
                    "{solver:?} λ={}: nnz {} vs {}",
                    sa.lambda,
                    sa.nonzeros,
                    sb.nonzeros
                );
            }
        }
    }

    #[test]
    fn bcd_path_matches_fista_path() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(20, 80, 8), 105);
        let f = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &small_cfg(1.0));
        let cfg_b = PathConfig { solver: SolverKind::Bcd, ..small_cfg(1.0) };
        let b = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg_b);
        for (sf, sb) in f.steps.iter().zip(&b.steps) {
            let diff = (sf.nonzeros as i64 - sb.nonzeros as i64).abs();
            assert!(diff <= 2, "λ={}: {} vs {}", sf.lambda, sf.nonzeros, sb.nonzeros);
        }
    }
}
