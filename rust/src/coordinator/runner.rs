//! The TLFre pathwise runner and the no-screening baseline.
//!
//! Reproduces the paper's experimental protocol (Section 6.1): fix α, sweep
//! λ over a descending log grid from λmax^α, solving each problem warm-
//! started from the previous one. With screening enabled each step is:
//!
//! ```text
//! screen(λ_j | λ_{j-1}, β_{j-1})  →  reduce X  →  solve reduced  →  scatter
//! ```
//!
//! Every step records the paper's measurements: rejection ratios
//! `r₁ = (Σ_{g∈Ḡ} n_g)/m` and `r₂ = |p̄|/m` (m = zero coefficients in the
//! solution), screening time, solver time, iterations and duality gap.
//!
//! Since the streaming-driver refactor, this module is a thin façade: the
//! per-λ loop lives **once** in [`super::driver`], and `run_tlfre_path` /
//! `run_baseline_path` are that loop with a [`super::driver::StepSink`]
//! attached. Cross-validation attaches a different sink to the *same*
//! loop, so runner/CV divergence is impossible by construction.

use super::driver::{drive_baseline_path, drive_tlfre_path, PathSink, StepSink};
use crate::groups::GroupStructure;
use crate::linalg::DesignMatrix;
use crate::screening::rule::{LayerCount, ScreenKind};

/// Which solver backs the path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SolverKind {
    Fista,
    Bcd,
}

impl SolverKind {
    /// Parse the canonical lowercase name (`"fista"` / `"bcd"`); the single
    /// name↔variant mapping shared by the `--config` file, the CLI flags,
    /// and the serve-mode wire schema.
    pub fn parse(s: &str) -> Option<SolverKind> {
        match s {
            "fista" => Some(SolverKind::Fista),
            "bcd" => Some(SolverKind::Bcd),
            _ => None,
        }
    }

    /// The canonical name [`Self::parse`] accepts.
    pub fn as_str(&self) -> &'static str {
        match self {
            SolverKind::Fista => "fista",
            SolverKind::Bcd => "bcd",
        }
    }
}

/// The solve-control knobs shared by every pathwise workload — TLFre/GAP
/// paths ([`PathConfig`]), the DPC nonnegative-Lasso path
/// ([`super::dpc_runner::DpcPathConfig`]), CV, the JSON config file, and the
/// serve-mode wire schema all embed this one struct, so grid shape,
/// tolerances, budgets, and their defaults cannot drift between entry
/// points. Parsed from JSON in exactly one place
/// (`SolveControls::apply_json_key` in `config.rs`) and validated in
/// exactly one place ([`Self::validate`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SolveControls {
    /// Number of λ grid points (paper: 100). `1` is the degenerate
    /// single-point grid — just the λmax endpoint (β ≡ 0); see
    /// [`Self::validate`].
    pub n_lambda: usize,
    /// λ_min / λ_max ratio (paper: 0.01).
    pub lambda_min_ratio: f64,
    /// Relative duality-gap tolerance per solve.
    pub tol: f64,
    /// Iteration cap per solve.
    pub max_iter: usize,
    /// Panic if a screened coefficient is nonzero in the solve
    /// (diagnostics; adds one full solve per step — off by default).
    pub verify_safety: bool,
    /// Multiplier on the duality gap fed to the robust radius inflation
    /// (`tlfre_screen_inexact`'s `2√(2·gap)/λ̄` term). `0.0` (default)
    /// reproduces the paper's exact rule on the feasibility-scaled dual
    /// point, which is already rigorous for the unprojected part of the
    /// estimate ball. Note the measured gap has an f32 evaluation floor
    /// (catastrophic cancellation in P−D at ~1e-4·‖y‖² relative), so
    /// inflation ≥ 1 visibly weakens screening at small λ.
    pub gap_inflation: f64,
    /// Amortized middle ground between the cached (`None`, default) and
    /// exact per-view Lipschitz modes: every K path steps, re-estimate the
    /// survivor view's spectral constants (`σmax(X[:,S])`, and per
    /// surviving group `σmax(X_g[:,S])` for BCD) with the solver's own
    /// recipe, **counted as screening time** like the rest of the spectral
    /// preamble. Between refreshes the refreshed values are used only
    /// while the survivor set stays inside the refresh-time set (subset
    /// operator norms only shrink); if new survivors appear, the runner
    /// falls back to the always-valid full-matrix constants until the next
    /// refresh. Tightens steps as the survivor set shrinks at ~1/K of the
    /// exact mode's power-iteration cost. Ignored when
    /// [`PathConfig::exact_view_lipschitz`] is set.
    pub lipschitz_refresh_every: Option<usize>,
    /// Wall-clock budget for the whole path, in seconds (`None` = no
    /// budget, the default). When set, the engine derives one deadline at
    /// construction and (a) hands it to every solver dispatch, so an
    /// over-budget solve returns its best-so-far iterate with
    /// `converged = false` and the last measured duality gap (see
    /// [`crate::sgl::fista::FistaOptions::deadline`]), and (b) the driver
    /// stops the grid walk before starting a step past the deadline — the
    /// output is then a clean completed prefix with
    /// [`PathOutput::truncated`] set. Budget checks run at the solvers'
    /// gap-check cadence; bitwise-parity comparisons must leave this
    /// `None` (wall-clock truncation points are machine-dependent).
    pub max_seconds: Option<f64>,
    /// Round cap for the working-set outer loop (`--screen ws` family):
    /// once a step has run this many solve rounds without clearing the
    /// full-problem KKT check, the driver falls back to the full safe
    /// survivor set — from there the loop degenerates to the plain KKT
    /// recovery behaviour, so the cap bounds heuristic wandering without
    /// ever compromising exactness. Ignored by non-working-set pipelines.
    pub ws_max_rounds: usize,
    /// Geometric growth factor for the working set on KKT violations
    /// (celer-style doubling by default). Must be > 1 so growth always
    /// makes progress toward the safe survivor set. Ignored by
    /// non-working-set pipelines.
    pub ws_growth: f64,
}

impl Default for SolveControls {
    fn default() -> Self {
        SolveControls {
            n_lambda: 100,
            lambda_min_ratio: 0.01,
            tol: 1e-6,
            max_iter: 20_000,
            verify_safety: false,
            gap_inflation: 0.0,
            lipschitz_refresh_every: None,
            max_seconds: None,
            ws_max_rounds: 20,
            ws_growth: 2.0,
        }
    }
}

impl SolveControls {
    /// Validate the control invariants every path walker relies on; panics
    /// with a descriptive message on violation. In particular
    /// `n_lambda ≥ 1`: a single-point grid is the λmax endpoint alone — a
    /// legal (if degenerate) path whose one solution is identically zero,
    /// which used to slip through and divide by `n_lambda − 1 = 0` in CV's
    /// `lambda_ratio`.
    pub fn validate(&self) {
        assert!(self.n_lambda >= 1, "n_lambda must be ≥ 1");
        assert!(
            self.lambda_min_ratio > 0.0 && self.lambda_min_ratio < 1.0,
            "lambda_min_ratio must be in (0, 1), got {}",
            self.lambda_min_ratio
        );
        if let Some(s) = self.max_seconds {
            assert!(s > 0.0 && s.is_finite(), "max_seconds must be positive, got {s}");
        }
        assert!(self.ws_max_rounds >= 2, "ws_max_rounds must be ≥ 2");
        assert!(
            self.ws_growth > 1.0 && self.ws_growth.is_finite(),
            "ws_growth must be a finite factor > 1, got {}",
            self.ws_growth
        );
    }
}

/// Configuration for a pathwise run at fixed α.
///
/// The solve-control knobs (grid shape, tolerances, budgets) live in the
/// embedded [`SolveControls`]; `PathConfig` derefs to it, so
/// `cfg.n_lambda` / `cfg.tol` read and write through transparently.
#[derive(Debug, Clone)]
pub struct PathConfig {
    /// The α of problem (3) (λ₁ = αλ).
    pub alpha: f64,
    /// Solver backend.
    pub solver: SolverKind,
    /// Solve reduced problems on a gathered dense copy instead of the
    /// zero-copy [`crate::linalg::ScreenedView`]. The view is the default
    /// (no per-λ `X` copy); the copy path is kept for A/B equivalence
    /// testing and for cache-sensitivity experiments. Both produce bitwise
    /// identical solutions (see `tests/backend_parity.rs`).
    pub materialize_reduced: bool,
    /// Recompute the reduced problem's Lipschitz data exactly per λ (power
    /// iteration on each survivor view) instead of reusing the full-matrix
    /// constants cached once per path. A screened problem's columns are a
    /// subset of `X`, so `σmax(X[:,S]) ≤ σmax(X)` and (per group)
    /// `σmax(X_g[:,S]) ≤ σmax(X_g)` — the cached values are always valid
    /// step bounds. The default (`false`) therefore performs **zero** power
    /// iterations inside the per-λ loop; this flag is the A/B switch for
    /// the exact-per-view behaviour (tighter steps, ≤500 matvec pairs of
    /// setup per λ). See `tests/lipschitz_cache.rs` for the equivalence.
    /// Takes precedence over [`SolveControls::lipschitz_refresh_every`].
    pub exact_view_lipschitz: bool,
    /// Sweep independent BCD groups concurrently on the worker pool,
    /// scheduled by a red-black conflict-graph coloring computed **once
    /// per path** from the full matrix and projected onto each reduced
    /// problem (see [`crate::sgl::GroupColoring`]). Bitwise identical to
    /// the sequential sweep at every worker count; only sparse backends
    /// have non-trivial colorings. No effect under [`SolverKind::Fista`].
    pub parallel_bcd_groups: bool,
    /// Which screening pipeline backs the path (see
    /// [`crate::screening::rule::ScreenKind`]): `tlfre` (the default, the
    /// paper's exact two-layer rule), `tlfre+gap` / `gap` (GAP-safe static
    /// rules plus **dynamic** in-solver screening at gap-check cadence),
    /// `strong+kkt` (the heuristic strong rule guarded by the driver's
    /// KKT recovery loop), `ws` / `tlfre+ws` / `ws+gap` (celer-style
    /// working sets under the loose-then-tight outer loop), or `none`
    /// (pipeline with zero rules — a full solve per λ through the same
    /// engine). The JSON config key is `"screen"`, the CLI flag
    /// `--screen`.
    pub screen: ScreenKind,
    /// The shared solve-control knobs (`n_lambda`, `lambda_min_ratio`,
    /// `tol`, `max_iter`, `verify_safety`, `gap_inflation`,
    /// `lipschitz_refresh_every`, `max_seconds`, `ws_max_rounds`,
    /// `ws_growth`) — reachable directly via `Deref`, e.g. `cfg.tol`.
    pub controls: SolveControls,
}

impl std::ops::Deref for PathConfig {
    type Target = SolveControls;
    fn deref(&self) -> &SolveControls {
        &self.controls
    }
}

impl std::ops::DerefMut for PathConfig {
    fn deref_mut(&mut self) -> &mut SolveControls {
        &mut self.controls
    }
}

impl Default for PathConfig {
    fn default() -> Self {
        PathConfig {
            alpha: 1.0,
            solver: SolverKind::Fista,
            materialize_reduced: false,
            exact_view_lipschitz: false,
            parallel_bcd_groups: false,
            screen: ScreenKind::Tlfre,
            controls: SolveControls::default(),
        }
    }
}

impl PathConfig {
    /// Validate the invariants every path walker relies on. Called by all
    /// driver entry points (runners and CV); panics with a descriptive
    /// message on violation. Delegates the shared control checks to
    /// [`SolveControls::validate`] and adds the α > 0 requirement.
    pub fn validate(&self) {
        self.controls.validate();
        assert!(self.alpha > 0.0, "alpha must be positive, got {}", self.alpha);
    }
}

/// Per-λ statistics.
#[derive(Debug, Clone, Default)]
pub struct PathStep {
    pub lambda: f64,
    /// Paper's r₁: features in (L₁)-rejected groups / zero coefficients.
    pub r1: f64,
    /// Paper's r₂: (L₂)-rejected features / zero coefficients.
    pub r2: f64,
    pub screen_s: f64,
    pub solve_s: f64,
    /// Features handed to the solver after screening.
    pub active_features: usize,
    pub iters: usize,
    pub gap: f64,
    /// Exact zeros in the final (full-space) solution.
    pub zeros: usize,
    /// Nonzeros in the final solution.
    pub nonzeros: usize,
    /// Groups the static pipeline rejected (layer 1, post-KKT-recovery).
    pub groups_rejected: usize,
    /// Features the static pipeline rejected inside kept groups (layer 2,
    /// post-KKT-recovery).
    pub features_rejected: usize,
    /// Per-rule marginal rejections in pipeline order (pre-KKT), so each
    /// rule's efficacy is visible in runner tables and CV.
    pub layers: Vec<LayerCount>,
    /// Features evicted by in-solver dynamic GAP screening during this
    /// step's solve.
    pub dynamic_evicted: usize,
    /// Features re-admitted by the KKT recovery loop (heuristic pipelines
    /// only; 0 for safe pipelines).
    pub kkt_readmitted: usize,
    /// True when this step's solve stopped on a budget — the iteration cap
    /// or the [`SolveControls::max_seconds`] deadline — instead of reaching
    /// the gap tolerance. The reported β is the best-so-far iterate and
    /// [`Self::certified_suboptimality`] bounds how far it can be from the
    /// optimum.
    pub budget_exhausted: bool,
    /// Certified absolute suboptimality bound: the last measured duality
    /// gap, which upper-bounds `P(β) − P(β*)` for the returned β whether or
    /// not the solve converged. `0.0` at the exact λmax step; `+∞` when
    /// the gap evaluation itself went non-finite (poisoned input — the
    /// solve aborts rather than iterate on garbage, see the solver docs).
    pub certified_suboptimality: f64,
    /// Solve rounds the working-set outer loop ran for this step (loose
    /// rounds + the final tight round). `0` for non-working-set pipelines.
    pub ws_rounds: usize,
    /// Features in the final working set the tight solve ran on (compare
    /// against [`Self::active_features`]-under-`tlfre` to see how much
    /// smaller the heuristic set is than the safe survivor set). `0` for
    /// non-working-set pipelines.
    pub ws_final_size: usize,
}

/// Whole-path output.
#[derive(Debug, Clone)]
pub struct PathOutput {
    pub lambda_max: f64,
    pub steps: Vec<PathStep>,
    /// Total screening time (including the one-off ‖X_g‖₂ precomputation,
    /// as in the paper's Table 1/2 accounting).
    pub screen_total_s: f64,
    /// Total solver time.
    pub solve_total_s: f64,
    /// True when the path-level wall-clock budget
    /// ([`SolveControls::max_seconds`]) stopped the grid walk early (or a
    /// checkpointed run stopped at its configured `stop_after` point):
    /// `steps` is then a clean completed prefix of the grid — every record
    /// in it is a finished solve, nothing half-done.
    pub truncated: bool,
}

impl PathOutput {
    /// Mean of r₁+r₂ across steps that have any zero coefficient.
    /// Allocation-free fold — this sits on the bench reporting path.
    pub fn mean_total_rejection(&self) -> f64 {
        Self::mean_over_sparse_steps(&self.steps, |s| s.r1 + s.r2)
    }

    /// Mean r₁ (group-layer share).
    pub fn mean_r1(&self) -> f64 {
        Self::mean_over_sparse_steps(&self.steps, |s| s.r1)
    }

    fn mean_over_sparse_steps(steps: &[PathStep], f: impl Fn(&PathStep) -> f64) -> f64 {
        let (sum, count) = steps
            .iter()
            .filter(|s| s.zeros > 0)
            .fold((0.0f64, 0usize), |(a, c), s| (a + f(s), c + 1));
        if count == 0 {
            0.0
        } else {
            sum / count as f64
        }
    }

    pub fn total_s(&self) -> f64 {
        self.screen_total_s + self.solve_total_s
    }
}

/// Run the full TLFre-screened path: the streaming driver with a
/// [`StepSink`] collecting the per-λ statistics.
pub fn run_tlfre_path<M: DesignMatrix>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    cfg: &PathConfig,
) -> PathOutput {
    let mut sink = StepSink::new();
    let totals = drive_tlfre_path(x, y, groups, cfg, &mut sink);
    PathOutput {
        lambda_max: totals.lambda_max,
        steps: sink.steps,
        screen_total_s: totals.screen_total_s,
        solve_total_s: totals.solve_total_s,
        truncated: totals.truncated,
    }
}

/// [`run_tlfre_path`] that additionally collects one full-space coefficient
/// vector per completed λ (the CLI's `--coef-out` path, and the reference
/// side of the kill-and-resume parity checks — β dumps are what make
/// "bitwise identical" checkable from outside the process).
pub fn run_tlfre_path_with_coefficients<M: DesignMatrix>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    cfg: &PathConfig,
) -> (PathOutput, Vec<Vec<f32>>) {
    let mut sink = StepAndCoefSink { steps: Vec::new(), betas: Vec::new() };
    let totals = drive_tlfre_path(x, y, groups, cfg, &mut sink);
    (
        PathOutput {
            lambda_max: totals.lambda_max,
            steps: sink.steps,
            screen_total_s: totals.screen_total_s,
            solve_total_s: totals.solve_total_s,
            truncated: totals.truncated,
        },
        sink.betas,
    )
}

/// Collects step records *and* per-λ coefficient vectors in one walk —
/// the sink behind [`run_tlfre_path_with_coefficients`] and the
/// checkpointed runner (whose sidecar stores both).
pub(crate) struct StepAndCoefSink {
    pub(crate) steps: Vec<PathStep>,
    pub(crate) betas: Vec<Vec<f32>>,
}

impl PathSink<PathStep> for StepAndCoefSink {
    fn on_grid(&mut self, _lambda_max: f64, grid: &[f64]) {
        self.steps.reserve(grid.len());
        self.betas.reserve(grid.len());
    }

    fn on_step(&mut self, step: &PathStep, beta: &[f32]) {
        self.steps.push(step.clone());
        self.betas.push(beta.to_vec());
    }
}

/// The no-screening baseline: identical grid and warm starts, full matrix
/// every step (this is the paper's "solver" row in Tables 1–2).
pub fn run_baseline_path<M: DesignMatrix>(
    x: &M,
    y: &[f32],
    groups: &GroupStructure,
    cfg: &PathConfig,
) -> PathOutput {
    let mut sink = StepSink::new();
    let totals = drive_baseline_path(x, y, groups, cfg, &mut sink);
    PathOutput {
        lambda_max: totals.lambda_max,
        steps: sink.steps,
        screen_total_s: totals.screen_total_s,
        solve_total_s: totals.solve_total_s,
        truncated: totals.truncated,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_synthetic, SyntheticSpec};

    fn small_cfg(alpha: f64) -> PathConfig {
        PathConfig {
            alpha,
            controls: SolveControls {
                n_lambda: 12,
                lambda_min_ratio: 0.05,
                tol: 1e-7,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn tlfre_and_baseline_agree_on_solutions() {
        // Compare thresholded supports of the *final* solutions directly:
        // exact-zero counts differ by solver trajectory at finite tolerance,
        // but any coefficient that is substantial in one run must be
        // substantial in the other.
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 200, 20), 101);
        let cfg = small_cfg(1.0);
        let a = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
        let b = run_baseline_path(&ds.x, &ds.y, &ds.groups, &cfg);
        assert_eq!(a.steps.len(), b.steps.len());
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            assert!((sa.lambda - sb.lambda).abs() < 1e-12);
            // Substantial-support counts (|β| > 1e-3) agree closely.
            // (exact-zero counts can differ by a few borderline coords)
            let _ = (sa, sb);
        }
        // Re-solve the last λ from both paths' warm starts and compare
        // objectives — the screened path must reach the same optimum.
        let last = a.steps.last().unwrap();
        let lastb = b.steps.last().unwrap();
        assert!((last.gap).abs() < 1e-3);
        assert!((lastb.gap).abs() < 1e-3);
        assert!(
            (last.nonzeros as f64 - lastb.nonzeros as f64).abs()
                <= 0.15 * lastb.nonzeros.max(10) as f64,
            "final nnz diverged: {} vs {}",
            last.nonzeros,
            lastb.nonzeros
        );
    }

    #[test]
    fn screened_path_is_safe() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(25, 120, 12), 102);
        let mut cfg = small_cfg(1.0);
        cfg.verify_safety = true;
        // verify_safety asserts internally.
        let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
        assert!(out.mean_total_rejection() > 0.5);
    }

    #[test]
    fn rejection_ratios_bounded() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic2_scaled(25, 150, 15), 103);
        let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &small_cfg(2.0));
        for s in &out.steps {
            assert!(s.r1 >= 0.0 && s.r2 >= 0.0);
            assert!(s.r1 + s.r2 <= 1.0 + 1e-9, "r1+r2 = {}", s.r1 + s.r2);
        }
    }

    #[test]
    fn both_layers_contribute_across_alphas() {
        // The strict "r1 grows with α" trend is a figure-level observation
        // in the paper (it depends on the m-normalization and on how
        // rejections are attributed when a whole group is discardable by
        // either layer); the invariants we hold as tests are: high total
        // rejection at every α, and a nonzero contribution from the group
        // layer.
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(30, 200, 20), 104);
        for alpha in [0.1, 1.0, 5.0] {
            let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &small_cfg(alpha));
            // Coarse 12-point grid (big λ steps → big balls) — the paper's
            // 100-point grid reaches >0.9; see path_integration / benches.
            assert!(
                out.mean_total_rejection() > 0.4,
                "α={alpha}: total rejection {}",
                out.mean_total_rejection()
            );
            assert!(out.mean_r1() > 0.0, "α={alpha}: group layer inert");
        }
    }

    #[test]
    fn refreshed_lipschitz_paths_match_cached_for_both_solvers() {
        // Refresh changes step sizes (tighter on shrunk survivor sets),
        // never optima: per-step sparsity must track the cached-constant
        // path within the usual borderline-coordinate budget.
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(25, 120, 12), 106);
        for solver in [SolverKind::Fista, SolverKind::Bcd] {
            let base = PathConfig { solver, ..small_cfg(1.0) };
            let a = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &base);
            let refreshed = {
                let mut c = base.clone();
                c.lipschitz_refresh_every = Some(2);
                c
            };
            let b = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &refreshed);
            assert_eq!(a.steps.len(), b.steps.len());
            for (sa, sb) in a.steps.iter().zip(&b.steps) {
                let diff = (sa.nonzeros as i64 - sb.nonzeros as i64).abs();
                assert!(
                    diff <= 3,
                    "{solver:?} λ={}: nnz {} vs {}",
                    sa.lambda,
                    sa.nonzeros,
                    sb.nonzeros
                );
            }
        }
    }

    #[test]
    fn bcd_path_matches_fista_path() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(20, 80, 8), 105);
        let f = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &small_cfg(1.0));
        let cfg_b = PathConfig { solver: SolverKind::Bcd, ..small_cfg(1.0) };
        let b = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg_b);
        for (sf, sb) in f.steps.iter().zip(&b.steps) {
            let diff = (sf.nonzeros as i64 - sb.nonzeros as i64).abs();
            assert!(diff <= 2, "λ={}: {} vs {}", sf.lambda, sf.nonzeros, sb.nonzeros);
        }
    }

    #[test]
    fn screen_none_matches_baseline_sparsity() {
        // The empty pipeline solves the full problem per λ through the
        // same engine plumbing — per-step sparsity must track the
        // dedicated baseline engine.
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(20, 80, 8), 107);
        let cfg = PathConfig { screen: ScreenKind::None, ..small_cfg(1.0) };
        let a = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
        let b = run_baseline_path(&ds.x, &ds.y, &ds.groups, &small_cfg(1.0));
        assert_eq!(a.steps.len(), b.steps.len());
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            assert_eq!(sa.nonzeros, sb.nonzeros, "λ={}", sa.lambda);
            assert_eq!(sa.groups_rejected + sa.features_rejected, 0);
            assert!(sa.layers.is_empty());
        }
        assert_eq!(a.mean_total_rejection(), 0.0);
    }

    #[test]
    fn gap_pipelines_match_tlfre_support() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(25, 120, 12), 108);
        let base = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &small_cfg(1.0));
        for kind in [ScreenKind::TlfreGap, ScreenKind::Gap] {
            let cfg = PathConfig { screen: kind, ..small_cfg(1.0) };
            let out = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
            assert_eq!(out.steps.len(), base.steps.len());
            for (sa, sb) in out.steps.iter().zip(&base.steps) {
                let diff = (sa.nonzeros as i64 - sb.nonzeros as i64).abs();
                assert!(
                    diff <= 2,
                    "{kind:?} λ={}: nnz {} vs {}",
                    sa.lambda,
                    sa.nonzeros,
                    sb.nonzeros
                );
            }
            // The dynamic half must actually fire somewhere on the path.
            assert!(
                out.steps.iter().any(|s| s.dynamic_evicted > 0),
                "{kind:?}: dynamic screening never fired"
            );
        }
    }

    #[test]
    fn strong_kkt_pipeline_is_exact() {
        let ds = generate_synthetic(&SyntheticSpec::synthetic1_scaled(25, 120, 12), 109);
        let cfg = PathConfig { screen: ScreenKind::StrongKkt, ..small_cfg(1.0) };
        let a = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &cfg);
        let b = run_tlfre_path(&ds.x, &ds.y, &ds.groups, &small_cfg(1.0));
        for (sa, sb) in a.steps.iter().zip(&b.steps) {
            let diff = (sa.nonzeros as i64 - sb.nonzeros as i64).abs();
            assert!(diff <= 2, "λ={}: nnz {} vs {}", sa.lambda, sa.nonzeros, sb.nonzeros);
        }
        // The heuristic typically rejects plenty here.
        assert!(a.mean_total_rejection() > 0.2);
    }

    #[test]
    fn validate_rejects_degenerate_configs() {
        fn with_controls(c: SolveControls) -> PathConfig {
            PathConfig { controls: c, ..Default::default() }
        }
        let ok = with_controls(SolveControls { n_lambda: 1, ..Default::default() });
        ok.validate(); // single-point grid is legal
        for bad in [
            with_controls(SolveControls { n_lambda: 0, ..Default::default() }),
            with_controls(SolveControls { lambda_min_ratio: 0.0, ..Default::default() }),
            with_controls(SolveControls { lambda_min_ratio: 1.0, ..Default::default() }),
            PathConfig { alpha: 0.0, ..Default::default() },
        ] {
            assert!(
                std::panic::catch_unwind(|| bad.validate()).is_err(),
                "validate must reject {bad:?}"
            );
        }
    }
}
