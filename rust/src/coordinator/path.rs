//! Parameter grids.
//!
//! The paper samples 100 λ values equally spaced on the *logarithmic* scale
//! of λ/λmax from 1.0 down to 0.01, and seven α values
//! `tan(ψ), ψ ∈ {5°, 15°, 30°, 45°, 60°, 75°, 85°}` (Section 6.1).

/// The paper's seven α angles in degrees.
pub const PAPER_ALPHA_ANGLES: [f64; 7] = [5.0, 15.0, 30.0, 45.0, 60.0, 75.0, 85.0];

/// `α = tan(ψ°)` grid.
pub fn alpha_grid_from_angles(angles_deg: &[f64]) -> Vec<f64> {
    angles_deg.iter().map(|&a| (a * std::f64::consts::PI / 180.0).tan()).collect()
}

/// Descending log-spaced grid of `k` values from `lambda_max` to
/// `min_ratio·lambda_max` (inclusive on both ends). `k == 1` is the
/// degenerate single-point grid: just `[lambda_max]` (the floor is
/// unreachable with one point, so `min_ratio` only needs to be a valid
/// ratio, not attained).
pub fn log_lambda_grid(lambda_max: f64, min_ratio: f64, k: usize) -> Vec<f64> {
    assert!(k >= 1, "need at least one grid point");
    assert!(lambda_max > 0.0 && min_ratio > 0.0 && min_ratio < 1.0);
    if k == 1 {
        return vec![lambda_max];
    }
    let log_min = min_ratio.ln();
    (0..k)
        .map(|i| {
            let t = i as f64 / (k - 1) as f64;
            lambda_max * (t * log_min).exp()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_endpoints_and_monotone() {
        let g = log_lambda_grid(2.0, 0.01, 100);
        assert_eq!(g.len(), 100);
        assert!((g[0] - 2.0).abs() < 1e-12);
        assert!((g[99] - 0.02).abs() < 1e-12);
        for w in g.windows(2) {
            assert!(w[0] > w[1]);
        }
    }

    #[test]
    fn single_point_grid_is_lambda_max() {
        assert_eq!(log_lambda_grid(2.5, 0.01, 1), vec![2.5]);
    }

    #[test]
    fn grid_log_spacing_constant_ratio() {
        let g = log_lambda_grid(1.0, 0.01, 5);
        let r0 = g[1] / g[0];
        for w in g.windows(2) {
            assert!((w[1] / w[0] - r0).abs() < 1e-12);
        }
    }

    #[test]
    fn alpha_grid_matches_tan() {
        let a = alpha_grid_from_angles(&PAPER_ALPHA_ANGLES);
        assert_eq!(a.len(), 7);
        assert!((a[3] - 1.0).abs() < 1e-12); // tan 45° = 1
        assert!(a[0] < 0.1 && a[6] > 11.0);
        for w in a.windows(2) {
            assert!(w[0] < w[1]);
        }
    }
}
